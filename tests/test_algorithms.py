"""Algorithm semantics: the production implementation must match the paper's
algebra step-for-step (via the dense matrix-form simulator) and satisfy the
obvious reductions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import AlgoConfig
from repro.core import make_algorithm, mixing
from repro.core.algorithms import AlgoVars
from repro.optim import sgd
from repro.training import make_round_step, make_train_state
from repro.optim import schedules

D = 6
M = 4


def quad_loss(params, batch):
    """0.5‖A x − b‖² with per-worker (A, b) — deterministic gradients."""
    A, b = batch
    r = A @ params["x"] - b
    loss = 0.5 * jnp.sum(r * r)
    return loss, dict(loss=loss)


def make_setup(algo_name, tau, alpha, lr=0.05, beta=0.0):
    params = {"x": jnp.asarray(np.random.default_rng(0).normal(size=D), jnp.float32)}
    algo = make_algorithm(AlgoConfig(name=algo_name, tau=tau, alpha=alpha, anchor_beta=beta))
    opt = sgd(momentum=0.0, nesterov=False, weight_decay=0.0)
    state = make_train_state(params, M, opt, algo, None)
    step = make_round_step(quad_loss, opt, algo, schedules.constant(lr), None)
    return params, algo, state, jax.jit(step)


def batch_for(rng, tau):
    A = rng.normal(size=(tau, M, D, D)).astype(np.float32)
    b = rng.normal(size=(tau, M, D)).astype(np.float32)
    return jnp.asarray(A), jnp.asarray(b)


def test_overlap_matches_matrix_form_exactly():
    """Implementation ≡ eq. (8) X_{k+1} = (X_k − γ G_k) W_k, every step."""
    tau, alpha, lr = 3, 0.6, 0.05
    rng = np.random.default_rng(42)
    params, algo, state, step = make_setup("overlap_local_sgd", tau, alpha, lr, beta=0.0)
    sim = mixing.MatrixFormSim(np.asarray(params["x"]), M, alpha, tau, lr)

    for r in range(4):
        A, b = batch_for(rng, tau)
        state, _ = step(state, (A, b))
        for k in range(tau):
            grads = np.stack(
                [np.asarray(A[k, i]).T @ (np.asarray(A[k, i]) @ sim.locals[:, i] - np.asarray(b[k, i])) for i in range(M)],
                axis=1,
            )
            sim.step(grads)
        np.testing.assert_allclose(np.asarray(state.x["x"]).T, sim.locals, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(state.vars.z["x"]), sim.anchor, rtol=1e-5, atol=1e-5)


def test_overlap_momentum_reduces_to_vanilla_at_beta_zero():
    rng = np.random.default_rng(3)
    _, _, s0, step0 = make_setup("overlap_local_sgd", 2, 0.5, beta=0.0)
    _, _, s1, step1 = make_setup("overlap_local_sgd", 2, 0.5, beta=1e-12)
    A, b = batch_for(rng, 2)
    s0, _ = step0(s0, (A, b))
    s1, _ = step1(s1, (A, b))
    np.testing.assert_allclose(np.asarray(s0.x["x"]), np.asarray(s1.x["x"]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(s0.vars.z["x"]), np.asarray(s1.vars.z["x"]), rtol=1e-5)


def test_local_sgd_boundary_equalizes_workers():
    rng = np.random.default_rng(4)
    _, _, state, step = make_setup("local_sgd", 2, 0.0)
    A, b = batch_for(rng, 2)
    state, _ = step(state, (A, b))
    x = np.asarray(state.x["x"])
    np.testing.assert_allclose(x, np.tile(x[:1], (M, 1)), atol=1e-6)


def test_sync_sgd_equals_single_worker_on_mean_gradient():
    rng = np.random.default_rng(5)
    params, _, state, step = make_setup("sync_sgd", 1, 0.0, lr=0.05)
    A, b = batch_for(rng, 1)
    state, _ = step(state, (A, b))
    # manual: one SGD step on the mean of per-worker gradients
    x0 = np.asarray(params["x"])
    grads = np.stack([np.asarray(A[0, i]).T @ (np.asarray(A[0, i]) @ x0 - np.asarray(b[0, i])) for i in range(M)])
    expected = x0 - 0.05 * grads.mean(0)
    for i in range(M):
        np.testing.assert_allclose(np.asarray(state.x["x"])[i], expected, rtol=1e-5)


def test_overlap_alpha_one_pulls_locals_onto_anchor():
    rng = np.random.default_rng(6)
    _, _, state, step = make_setup("overlap_local_sgd", 2, 1.0)
    A, b = batch_for(rng, 2)
    state, _ = step(state, (A, b))
    x = np.asarray(state.x["x"])
    np.testing.assert_allclose(x, np.tile(x[:1], (M, 1)), atol=1e-6)


def test_anchor_is_stale_by_one_round():
    """The pullback at round r must use the anchor computed at round r−1."""
    rng = np.random.default_rng(7)
    tau, alpha = 2, 0.6
    _, _, state, step = make_setup("overlap_local_sgd", tau, alpha)
    z0 = np.asarray(state.vars.z["x"]).copy()
    A, b = batch_for(rng, tau)
    state1, _ = step(state, (A, b))
    # the anchor used inside round 1's pullback is z0; verify by recomputing
    # the pullback from the pre-boundary locals: run tau plain SGD steps
    x = np.tile(z0[None], (M, 1))
    for k in range(tau):
        for i in range(M):
            g = np.asarray(A[k, i]).T @ (np.asarray(A[k, i]) @ x[i] - np.asarray(b[k, i]))
            x[i] = x[i] - 0.05 * g
    pulled = (1 - alpha) * x + alpha * z0[None]
    np.testing.assert_allclose(np.asarray(state1.x["x"]), pulled, rtol=1e-5, atol=1e-5)
    # and the new anchor is the mean of the pulled-back locals (eq. 5)
    np.testing.assert_allclose(np.asarray(state1.vars.z["x"]), pulled.mean(0), rtol=1e-5, atol=1e-5)


def test_cocod_decouples_but_reaches_consensus_direction():
    rng = np.random.default_rng(8)
    _, _, state, step = make_setup("cocod", 2, 0.0)
    A, b = batch_for(rng, 2)
    state, _ = step(state, (A, b))
    # x_i = avg(x_start) + delta_i; with equal init x_start equal, so
    # differences between workers equal differences of their deltas
    assert np.isfinite(np.asarray(state.x["x"])).all()


def test_powersgd_compresses_and_converges_direction():
    rng = np.random.default_rng(9)
    params = {"w": jnp.asarray(rng.normal(size=(D, D)), jnp.float32)}

    def loss(p, batch):
        A, b = batch
        r = A @ p["w"] - b
        l = 0.5 * jnp.sum(r * r)
        return l, dict(loss=l)

    algo = make_algorithm(AlgoConfig(name="powersgd", powersgd_rank=2))
    opt = sgd(momentum=0.0, nesterov=False)
    state = make_train_state(params, M, opt, algo, None)
    step = jax.jit(make_round_step(loss, opt, algo, schedules.constant(0.02), None))
    losses = []
    for r in range(30):
        A = jnp.asarray(rng.normal(size=(1, M, D, D)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(1, M, D)), jnp.float32) * 0.1
        state, ms = step(state, (A, b))
        losses.append(float(ms["loss"].mean()))
    assert losses[-1] < losses[0] * 0.5
    # workers stay exactly in sync (decoded gradient identical across workers)
    x = np.asarray(state.x["w"])
    np.testing.assert_allclose(x, np.tile(x[:1], (M, 1, 1)), atol=1e-5)


@pytest.mark.parametrize("algo_name,tau", [("overlap_local_sgd", 4), ("easgd", 4), ("local_sgd", 4), ("cocod", 4)])
def test_all_algorithms_converge_on_quadratic(algo_name, tau):
    rng = np.random.default_rng(10)
    Afix = rng.normal(size=(M, D, D)).astype(np.float32)
    x_true = rng.normal(size=D).astype(np.float32)
    bfix = np.einsum("mij,j->mi", Afix, x_true).astype(np.float32)  # consistent: F* = 0
    _, _, state, step = make_setup(algo_name, tau, 0.5, lr=0.03)
    losses = []
    for r in range(40):
        A = jnp.asarray(np.tile(Afix[None], (tau, 1, 1, 1)))
        b = jnp.asarray(np.tile(bfix[None], (tau, 1, 1)))
        state, ms = step(state, (A, b))
        losses.append(float(ms["loss"].mean()))
    assert losses[-1] < losses[0] * 0.1, (algo_name, losses[0], losses[-1])
