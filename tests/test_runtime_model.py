"""Runtime-model validation against the paper's measured constants (§4)."""
import numpy as np
import pytest

from repro.core.runtime_model import RuntimeConfig, simulate

# Paper constants: 16 nodes, ~24 steps/epoch (50000/(128·16)), compute 4.6 s/epoch
STEPS = 24
CFG = RuntimeConfig(m=16, t_step=4.6 / STEPS, t_comm=1.5 / STEPS, t_handshake=0.02)


def test_sync_sgd_comm_ratio_matches_paper():
    """Fully-sync: ~1.5 s comm per 4.6 s compute epoch (≈33%, paper: 34.6%)."""
    r = simulate("sync_sgd", 1, STEPS, CFG)
    assert abs(r.exposed_comm - 1.5) < 1e-9
    assert 0.30 < r.comm_ratio < 0.36


@pytest.mark.parametrize("tau", [1, 2, 8, 24])
def test_overlap_hides_communication(tau):
    """Paper Fig. 4(a): Overlap-Local-SGD's additional latency is ~negligible
    (0.1 s vs 1.5 s per epoch) because τ·t_step ≥ t_comm already at τ=1."""
    r = simulate("overlap_local_sgd", tau, STEPS, CFG)
    assert r.exposed_comm <= 0.11, (tau, r.exposed_comm)
    r_sync = simulate("sync_sgd", 1, STEPS, CFG)
    assert r.total_time < r_sync.total_time


def test_local_sgd_reduces_comm_by_tau():
    r1 = simulate("local_sgd", 1, STEPS, CFG)
    r8 = simulate("local_sgd", 8, STEPS, CFG)
    assert abs(r1.exposed_comm / max(r8.exposed_comm, 1e-12) - 8.0) < 1e-6


def test_overlap_exposes_comm_when_compute_too_short():
    """If τ·t_step < t_comm the collective can't hide completely."""
    cfg = RuntimeConfig(m=16, t_step=0.01, t_comm=0.2)
    r = simulate("overlap_local_sgd", 1, 50, cfg)
    assert r.exposed_comm > 0.5  # most rounds stall on the in-flight collective


def test_powersgd_keeps_handshake_cost():
    """Paper: compression can't remove handshake latency — PowerSGD exposed
    comm stays ≥ steps × handshake."""
    r = simulate("powersgd", 1, STEPS, CFG)
    assert r.exposed_comm >= STEPS * CFG.t_handshake
    sync = simulate("sync_sgd", 1, STEPS, CFG)
    assert r.exposed_comm < sync.exposed_comm  # but still beats uncompressed


def test_straggler_mitigation():
    """Paper §2: non-blocking boundaries absorb stragglers; blocking Local SGD
    pays the max over workers every round."""
    cfg = RuntimeConfig(m=16, t_step=0.19, t_comm=0.0625, straggle_prob=0.05, straggle_factor=5.0, seed=3)
    r_local = simulate("local_sgd", 2, 200, cfg)
    r_overlap = simulate("overlap_local_sgd", 2, 200, cfg)
    assert r_overlap.total_time < r_local.total_time
    assert r_overlap.idle_time < r_local.idle_time
