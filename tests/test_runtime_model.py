"""Runtime-model validation against the paper's measured constants (§4),
plus regression traces for the ISSUE-8 clock bugfixes (trailing partial
segment, final in-flight collective, all-dead rounds, idle/critical-path
accounting) and the topology-aware gossip branch."""
import numpy as np
import pytest

from repro.core.runtime_model import GOSSIP, RuntimeConfig, gossip_comm_time, simulate
from repro.fault.plan import FaultPlan

# Paper constants: 16 nodes, ~24 steps/epoch (50000/(128·16)), compute 4.6 s/epoch
STEPS = 24
CFG = RuntimeConfig(m=16, t_step=4.6 / STEPS, t_comm=1.5 / STEPS, t_handshake=0.02)


def test_sync_sgd_comm_ratio_matches_paper():
    """Fully-sync: ~1.5 s comm per 4.6 s compute epoch (≈33%, paper: 34.6%)."""
    r = simulate("sync_sgd", 1, STEPS, CFG)
    assert abs(r.exposed_comm - 1.5) < 1e-9
    assert 0.30 < r.comm_ratio < 0.36


@pytest.mark.parametrize("tau", [1, 2, 8, 24])
def test_overlap_hides_communication(tau):
    """Paper Fig. 4(a): Overlap-Local-SGD's additional latency is ~negligible
    (0.1 s vs 1.5 s per epoch) because τ·t_step ≥ t_comm already at τ=1."""
    r = simulate("overlap_local_sgd", tau, STEPS, CFG)
    assert r.exposed_comm <= 0.11, (tau, r.exposed_comm)
    r_sync = simulate("sync_sgd", 1, STEPS, CFG)
    assert r.total_time < r_sync.total_time


def test_local_sgd_reduces_comm_by_tau():
    r1 = simulate("local_sgd", 1, STEPS, CFG)
    r8 = simulate("local_sgd", 8, STEPS, CFG)
    assert abs(r1.exposed_comm / max(r8.exposed_comm, 1e-12) - 8.0) < 1e-6


def test_overlap_exposes_comm_when_compute_too_short():
    """If τ·t_step < t_comm the collective can't hide completely."""
    cfg = RuntimeConfig(m=16, t_step=0.01, t_comm=0.2)
    r = simulate("overlap_local_sgd", 1, 50, cfg)
    assert r.exposed_comm > 0.5  # most rounds stall on the in-flight collective


def test_powersgd_keeps_handshake_cost():
    """Paper: compression can't remove handshake latency — PowerSGD exposed
    comm stays ≥ steps × handshake."""
    r = simulate("powersgd", 1, STEPS, CFG)
    assert r.exposed_comm >= STEPS * CFG.t_handshake
    sync = simulate("sync_sgd", 1, STEPS, CFG)
    assert r.exposed_comm < sync.exposed_comm  # but still beats uncompressed


def test_straggler_mitigation():
    """Paper §2: non-blocking boundaries absorb stragglers; blocking Local SGD
    pays the max over workers every round."""
    cfg = RuntimeConfig(m=16, t_step=0.19, t_comm=0.0625, straggle_prob=0.05, straggle_factor=5.0, seed=3)
    r_local = simulate("local_sgd", 2, 200, cfg)
    r_overlap = simulate("overlap_local_sgd", 2, 200, cfg)
    assert r_overlap.total_time < r_local.total_time
    assert r_overlap.idle_time < r_local.idle_time


# -- ISSUE-8 regression traces (hand-computed clocks) -------------------------


def test_trailing_partial_segment_advances_clocks():
    """Bugfix: steps % tau != 0 used to silently drop the tail compute in
    BOTH branches. 10 steps at tau=4 is 2 rounds + 2 local steps of tail:
    blocking total = 2·(4 + 0.5) + 2 = 11 (old model said 9, same as 8
    steps); overlapped = 10 (the 2-step tail hides the final 0.5 comm)."""
    cfg = RuntimeConfig(m=2, t_step=1.0, t_comm=0.5, t_handshake=0.0)
    assert simulate("local_sgd", 4, 8, cfg).total_time == 9.0
    assert simulate("local_sgd", 4, 10, cfg).total_time == 11.0
    assert simulate("overlap_local_sgd", 4, 10, cfg).total_time == 10.0


def test_overlap_final_inflight_collective_charged():
    """Bugfix: the overlapped total used to end at the last worker arrival,
    ignoring the final boundary's still-in-flight collective. Hand trace
    (m=2, t_step=1, t_comm=10, tau=1, steps=2): round 0 arrives at 1 and
    launches (ready 11); round 1 arrives at 2, stalls 9, launches at 11
    (ready 21). Total = 21 (old: 11); exposed = 9 + 10 = 19."""
    cfg = RuntimeConfig(m=2, t_step=1.0, t_comm=10.0, t_handshake=0.0)
    r = simulate("overlap_local_sgd", 1, 2, cfg)
    assert r.total_time == 21.0 and r.exposed_comm == 19.0


def test_all_crashed_round_skips_collective():
    """Bugfix: an all-crashed round used to reduce arrive[live].max() over an
    empty array. Now the collective is skipped (no barrier, no comm), clocks
    advance by the round's compute, and the round is counted. 4 rounds at
    tau=1, round 1 all-dead: total = 4·1 + 3·0.5 = 5.5."""
    plan = FaultPlan(m=2, crashes=((0, 1, 2), (1, 1, 2)))
    assert plan.mask_at(1).sum() == 0  # crash windows are authoritative
    cfg = RuntimeConfig(m=2, t_step=1.0, t_comm=0.5, t_handshake=0.0)
    for algo in ("local_sgd", "overlap_local_sgd", "gossip_ring"):
        r = simulate(algo, 1, 4, cfg, fault_plan=plan)
        assert r.skipped_rounds == 1, (algo, r)
    r = simulate("local_sgd", 1, 4, cfg, fault_plan=plan)
    assert r.total_time == 5.5, r


def test_idle_per_live_worker_and_critical_compute():
    """Bugfix: idle used to normalize by m (dead workers diluted it) and the
    critical-path compute was computed then discarded. m=3, worker 0 a 2x
    straggler, worker 2 crashed: each round the one nominal live worker
    waits 1s → idle = 0.5/round over 2 live, NOT 1/3; compute_critical is
    the straggler's 2·2 = 4."""
    plan = FaultPlan(m=3, crashes=((2, 0, None),), slowdown=((0, 2.0),), deadline_factor=10.0)
    cfg = RuntimeConfig(m=3, t_step=1.0, t_comm=0.0, t_handshake=0.0)
    r = simulate("local_sgd", 1, 2, cfg, fault_plan=plan)
    assert r.idle_time == 1.0, r  # 0.5 per round × 2 rounds (old model: 2/3)
    assert r.compute_critical == 4.0, r
    assert r.total_time >= r.compute_critical


def test_eventless_plan_still_matches_no_plan_exactly():
    """The historical fully-live model is preserved value for value: an
    eventless FaultPlan changes nothing, including the new result fields
    (dataclass equality covers compute_critical / skipped_rounds)."""
    cfg = RuntimeConfig(m=8, straggle_std=0.3, seed=5)
    for algo in ("local_sgd", "overlap_local_sgd", "sync_sgd", "gossip_exp"):
        a = simulate(algo, 4, 64, cfg)
        b = simulate(algo, 4, 64, cfg, fault_plan=FaultPlan(m=8))
        assert a == b and a.skipped_rounds == 0


# -- gossip branch: neighbor-set barriers, degree pricing ---------------------


def test_gossip_full_prices_like_global_overlap():
    """The degenerate fully-connected gossip must reproduce the global
    overlapped model exactly — degree m−1 prices to t_comm and the neighbor
    set is everyone."""
    cfg = RuntimeConfig(m=8, straggle_std=0.2, seed=1)
    for steps in (64, 66):  # with and without a tail
        assert simulate("gossip_full", 4, steps, cfg) == simulate("overlap_local_sgd", 4, steps, cfg)
    assert gossip_comm_time(cfg, 7) == cfg.t_comm


def test_gossip_fleet_projection():
    """The reason the branch exists: at fleet scale (t_comm grows with m for
    the all-to-all payload) sparse gossip keeps per-round cost flat — a ring
    worker at m=4096 waits on 2 neighbors and ships 2 model copies."""
    totals = {}
    for m in (16, 256, 4096):
        cfg = RuntimeConfig(m=m, t_comm=0.065 * m / 16, straggle_std=0.2, seed=0)
        totals[m] = {a: simulate(a, 4, 32, cfg).total_time for a in ("gossip_full", "gossip_ring", "gossip_exp")}
        assert totals[m]["gossip_ring"] < totals[m]["gossip_full"]
        assert totals[m]["gossip_exp"] < totals[m]["gossip_full"]
    # full degrades superlinearly with the fleet; ring/exp stay near-flat
    assert totals[4096]["gossip_full"] > 10 * totals[4096]["gossip_ring"]
    assert totals[4096]["gossip_ring"] < 1.2 * totals[16]["gossip_ring"]


def test_gossip_respects_straggler_locality():
    """A single straggler on a ring only stalls its out-neighbors' clocks;
    the global barrier stalls everyone. Ring total beats full under one
    persistent slow worker."""
    plan = FaultPlan(m=16, slowdown=((0, 3.0),), deadline_factor=100.0)
    cfg = plan.runtime_config(base=RuntimeConfig(m=16, t_step=0.19, t_comm=0.5, t_handshake=0.02))
    slow_full = simulate("gossip_full", 4, 64, cfg, fault_plan=plan)
    slow_ring = simulate("gossip_ring", 4, 64, cfg, fault_plan=plan)
    assert slow_ring.total_time < slow_full.total_time
    with pytest.raises(ValueError):
        simulate("gossip_ring", 4, 16, RuntimeConfig(m=4), topology="torus")
    assert set(GOSSIP) == {"gossip_pushsum", "gossip_full", "gossip_ring", "gossip_exp"}


def test_offload_schedule_breakeven():
    """DESIGN.md §9: the offload analogue of the paper's overlap condition —
    exposed transfer is max(0, stream_s − τ·t_step), zero exactly at
    breakeven_tau."""
    from repro.core.runtime_model import offload_schedule

    t_step, gbps = 0.5, 10.0
    nbytes = 25e9  # stream_s = 2.5 s -> breakeven at τ = 5
    s = offload_schedule(nbytes, gbps, tau=2, t_step=t_step)
    assert s["stream_s"] == pytest.approx(2.5) and s["breakeven_tau"] == 5
    assert s["exposed_s"] == pytest.approx(1.5) and not s["hidden"]
    s = offload_schedule(nbytes, gbps, tau=5, t_step=t_step)
    assert s["exposed_s"] == 0.0 and s["hidden"]
    assert offload_schedule(nbytes, 0.0, 2, t_step)["stream_s"] == float("inf")


def test_offload_exposed_transfer_hidden_at_breakeven():
    """simulate() prices the host stream against each round's compute segment:
    exposed_transfer > 0 below breakeven τ, exactly 0 at/above it, and the
    plane-resident run (offload_bytes=0) never pays the term."""
    base = dict(m=16, t_step=0.19, t_comm=0.0625)
    cfg = RuntimeConfig(**base, offload_bytes_per_round=7.6e9, offload_gbps=10.0)
    # stream_s = 0.76 s vs window τ·0.19 -> breakeven τ = 4
    r2 = simulate("overlap_local_sgd", 2, 64, cfg)
    assert r2.exposed_transfer > 0
    r4 = simulate("overlap_local_sgd", 4, 64, cfg)
    assert r4.exposed_transfer == 0.0
    assert r4.total_time < r2.total_time + r2.exposed_transfer + 1e-9
    resident = simulate("overlap_local_sgd", 2, 64, RuntimeConfig(**base))
    assert resident.exposed_transfer == 0.0
    # the exposed stream stretches the round segments: total reflects the lag
    assert r2.total_time > resident.total_time
