"""Fault-tolerant boundaries (ISSUE 7): deterministic FaultPlan schedules,
elastic membership masks through every strategy's boundary (packed vs
per-leaf bitwise), the harness's anchor re-sync, controller fault_hold
composition, runtime-model fault simulation and calibration, and the
serving robustness guards."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import AlgoConfig
from repro.control import TauController, schedule_block
from repro.core import make_strategy
from repro.core.runtime_model import RuntimeConfig, calibrated_config, simulate
from repro.core.strategy import _worker_mean
from repro.fault import FaultHarness, FaultPlan, from_mask, full, resync_from_anchor
from repro.kernels import flags
from repro.parallel.packing import pack, unpack

M = 4


# -- FaultPlan: determinism + grammar ----------------------------------------


def test_plan_determinism():
    """Same (spec, seed) → identical per-round schedule, from independent
    instances and in any query order; a different seed departs."""
    mk = lambda seed: FaultPlan.parse("std:0.4,prob:0.1@5,jitter:0.2", m=8, seed=seed)
    a, b = mk(3), mk(3)
    for r in (5, 0, 11, 2):  # order-independent: per-(seed, round) substreams
        np.testing.assert_array_equal(a.mask_at(r), b.mask_at(r))
        np.testing.assert_array_equal(a.round_compute_factors(r), b.round_compute_factors(r))
        assert a.comm_jitter(r) == b.comm_jitter(r)
    c = mk(4)
    assert any(
        not np.array_equal(a.round_compute_factors(r), c.round_compute_factors(r)) for r in range(8)
    )


def test_plan_parse_grammar():
    plan = FaultPlan.parse("crash:1@2-5, slow:2x4, std:0.2, prob:0.05@6, jitter:0.1, deadline:2.5", m=4, seed=7)
    assert plan.crashes == ((1, 2, 5),) and plan.slowdown == ((2, 4.0),)
    assert plan.straggle_std == 0.2 and plan.straggle_prob == 0.05 and plan.straggle_factor == 6.0
    assert plan.jitter_std == 0.1 and plan.deadline_factor == 2.5
    # permanent crash (no rejoin round)
    assert FaultPlan.parse("crash:0@3", m=2).crashes == ((0, 3, None),)
    with pytest.raises(ValueError):
        FaultPlan.parse("explode:1@2", m=4)


def test_plan_validation():
    with pytest.raises(ValueError):
        FaultPlan(m=4, crashes=((7, 1, None),))  # worker out of range
    with pytest.raises(ValueError):
        FaultPlan(m=4, crashes=((1, 5, 3),))  # rejoin before crash
    with pytest.raises(ValueError):
        FaultPlan(m=4, slowdown=((0, -1.0),))


def test_plan_schedule_semantics():
    """Crash window [2, 5), persistent straggler past the deadline, rejoin
    re-sync exactly at the window's end."""
    plan = FaultPlan.parse("crash:1@2-5,slow:2x4", m=4, seed=7)  # deadline 3.0 < 4x
    for r in range(8):
        mask = plan.mask_at(r)
        assert not mask[2], "persistent straggler must miss every deadline"
        assert mask[1] == (not 2 <= r < 5)
    np.testing.assert_array_equal(plan.resync_at(5), [False, True, False, False])
    assert plan.resync_at(0).sum() == 0
    block = plan.degraded_rounds(8)
    assert block["degraded"] == 8 and block["rounds"] == 8
    assert [r["round"] for r in block["schedule"] if r["resynced"]] == [5]
    assert plan.fault_reason(3) == "crash+deadline"
    assert plan.fault_reason(5) == "deadline+rejoin"
    assert FaultPlan(m=4).fault_reason(0) is None


def test_mask_at_keeps_one_live():
    """A boundary over zero workers is undefined: when every worker is
    excluded, the fastest survives."""
    plan = FaultPlan(m=3, slowdown=((0, 10.0), (1, 8.0), (2, 12.0)))
    mask = plan.mask_at(0)
    assert mask.sum() == 1 and mask[1]  # 8x is the least-slow


# -- Membership ---------------------------------------------------------------


def test_membership_from_mask():
    mem = from_mask(np.array([1.0, 0.0, 1.0, 1.0], np.float32))
    np.testing.assert_allclose(np.asarray(mem.weights), [1 / 3, 0.0, 1 / 3, 1 / 3])
    assert int(mem.live_count()) == 3 and not mem.is_full()
    assert full(4).is_full()
    with pytest.raises(ValueError):
        from_mask(np.zeros(4, np.float32))  # no live workers
    with pytest.raises(ValueError):
        from_mask(np.ones((2, 2), np.float32))


# -- masked boundaries: packed vs per-leaf, dead-row passthrough --------------


def _leafy(rng):
    p = {"s": jnp.float32(rng.normal())}
    for i in range(4):
        p[f"w{i}"] = jnp.asarray(rng.normal(size=(3 + i, 5 + 2 * i)), jnp.float32)
    p["aligned"] = jnp.asarray(rng.normal(size=(2, 128)), jnp.float32)
    return p


def _stacked(rng, params):
    return jax.tree.map(
        lambda t: jnp.asarray(rng.normal(size=(M,) + t.shape), jnp.float32), params
    )


def test_masked_worker_mean_matches_oracle(rng):
    """The membership-weighted worker mean (per-leaf and packed) equals the
    explicit masked-fp32 oracle bitwise, for any mask."""
    x = _stacked(rng, _leafy(rng))
    w = jnp.asarray([0.5, 0.0, 0.25, 0.25], jnp.float32)
    mean = _worker_mean(x, w)
    for leaf, got in zip(jax.tree.leaves(x), jax.tree.leaves(mean)):
        wf = np.asarray(w, np.float32).reshape((-1,) + (1,) * (leaf.ndim - 1))
        want = np.sum(np.asarray(leaf, np.float32) * wf, axis=0)
        np.testing.assert_array_equal(np.asarray(got), want)
    # packed plane agrees bitwise with the per-leaf path
    from repro.core.strategy import _packed_worker_mean

    px = pack(x, lead=1)
    pm = _packed_worker_mean(px, w)
    for a, b in zip(jax.tree.leaves(unpack(pm)), jax.tree.leaves(mean)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


MASKED_STRATEGIES = [
    ("overlap_local_sgd", dict(anchor_beta=0.0)),
    ("overlap_local_sgd", dict(anchor_beta=0.7)),
    ("local_sgd", {}),
    ("easgd", {}),
    ("cocod", {}),
    ("delayed_avg", dict(delay_steps=3)),  # boundary-phase consume
    ("sparse_anchor", dict(sparse_k=0.5)),
    ("gossip_full", {}),   # degenerate push-sum == membership-weighted mean
    ("gossip_ring", {}),   # sparse mixing composed with the live mask
    ("gossip_exp", {}),
]


@pytest.mark.parametrize("name,kw", MASKED_STRATEGIES, ids=[f"{n}-{v}" for n, v in MASKED_STRATEGIES])
def test_masked_boundary_packed_matches_perleaf(name, kw, rng):
    """Tentpole golden test: under a partial membership the packed boundary
    stays bitwise-identical to the per-leaf path, and every dead worker's
    row passes through the boundary untouched."""
    cfg = AlgoConfig(name=name, tau=3, alpha=0.6, packed=True, **kw)
    mem = from_mask(np.array([1.0, 0.0, 1.0, 1.0], np.float32))
    x = _stacked(rng, _leafy(rng))

    strat_l = make_strategy(dataclasses.replace(cfg, packed=False))
    vars_l = strat_l.init_vars(x, None)
    infl_l = strat_l.init_inflight(x, vars_l, None)
    x_l, vars_l2, infl_l2 = strat_l.boundary_round(x, vars_l, infl_l, None, membership=mem)

    strat_p = make_strategy(cfg)
    px = pack(x, lead=1)
    vars_p = strat_p.init_vars(px, None)
    infl_p = strat_p.init_inflight(px, vars_p, None)
    px2, vars_p2, infl_p2 = strat_p.boundary_round(px, vars_p, infl_p, None, membership=mem)

    for a, b in zip(jax.tree.leaves(unpack(px2)), jax.tree.leaves(x_l)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # dead worker 1: parameters pass through the boundary untouched
    for before, after in zip(jax.tree.leaves(x), jax.tree.leaves(x_l)):
        np.testing.assert_array_equal(np.asarray(before)[1], np.asarray(after)[1])


def test_masked_pullback_kernel_matches_ref(rng):
    """The masked anchor-mix kernels (fused pullback+mean, fused
    pullback+momentum) match the jnp reference to f32 ULP tolerance (the
    same bound the unmasked fused-kernel sweeps pin — XLA may fuse the
    where/mul chain differently inside the pallas body)."""
    from repro.kernels.anchor_mix import ops as ops_
    from repro.kernels.anchor_mix import ref as ref_

    tol = dict(rtol=1e-6, atol=5e-7)
    for n in (128, 257):
        x = jnp.asarray(rng.normal(size=(M, n)), jnp.float32)
        z = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
        w = jnp.asarray([0.5, 0.0, 0.25, 0.25], jnp.float32)
        with flags.force_pallas():
            got = ops_.pullback_mean(x, z, 0.6, weights=w)
        want = ref_.pullback_mean(x, z, 0.6, weights=w)
        for a, b in zip(got, want):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), **tol)
        with flags.force_pallas():
            got_m = ops_.pullback_mean_momentum(x, z, v, 0.6, 0.7, weights=w)
        want_m = ref_.pullback_mean_momentum(x, z, v, 0.6, 0.7, weights=w)
        for a, b in zip(got_m, want_m):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), **tol)
        # dead worker 1 passes through both paths untouched, exactly
        np.testing.assert_array_equal(np.asarray(got[0])[1], np.asarray(x)[1])
        np.testing.assert_array_equal(np.asarray(want[0])[1], np.asarray(x)[1])


def test_fully_live_trace_unchanged(rng):
    """membership=None must produce byte-for-byte the same boundary program
    as not passing membership at all — the fully-live path keeps the pinned
    launch/collective budgets."""
    cfg = AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=0.7, packed=True)
    strat = make_strategy(cfg)
    px = pack(_stacked(rng, _leafy(rng)), lead=1)
    vars_ = strat.init_vars(px, None)
    infl = strat.init_inflight(px, vars_, None)
    base = jax.make_jaxpr(lambda a, b, c: strat.boundary_round(a, b, c, None))(px, vars_, infl)
    explicit = jax.make_jaxpr(
        lambda a, b, c: strat.boundary_round(a, b, c, None, membership=None)
    )(px, vars_, infl)
    assert str(base) == str(explicit)


# -- harness: anchor re-sync + end-to-end -------------------------------------


def test_resync_from_anchor(rng):
    """A rejoining worker's plane row is replaced by the anchor; live rows
    are untouched. Packed and per-leaf states behave identically."""
    from repro.training import make_train_state
    from repro.optim import sgd

    params = _leafy(rng)
    for packed in (True, False):
        cfg = AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=0.7, packed=packed)
        state = make_train_state(params, M, sgd(), make_strategy(cfg), None)
        resync = np.array([False, True, False, False])
        out = resync_from_anchor(state, resync)
        x_old = unpack(state.x) if packed else state.x
        x_new = unpack(out.x) if packed else out.x
        anchor = state.inflight
        anchor = getattr(anchor, "avg", anchor)
        a_tree = unpack(anchor) if packed else anchor
        for old, new, anc in zip(jax.tree.leaves(x_old), jax.tree.leaves(x_new), jax.tree.leaves(a_tree)):
            old, new, anc = np.asarray(old), np.asarray(new), np.asarray(anc)
            np.testing.assert_array_equal(new[1], anc.astype(new.dtype))
            np.testing.assert_array_equal(new[[0, 2, 3]], old[[0, 2, 3]])


def test_faulted_training_end_to_end():
    """Acceptance: a seeded plan (crash at 2, rejoin at 5, persistent 4x
    straggler) trains to completion; the fault log records the exclusions
    and the single anchor re-sync; the final state is fully live; loss
    still improves."""
    from repro.api import Experiment

    plan = FaultPlan.parse("crash:1@2-5,slow:2x4", m=M, seed=7)
    exp = Experiment(workers=M, strategy="overlap_local_sgd", seed=0)
    res = exp.fit(rounds=8, faults=plan)
    assert np.isfinite(res.losses).all() and res.losses[-1] < res.losses[0]
    assert exp.state.membership is None
    by_round = {rec["round"]: rec for rec in res.fault_log}
    assert by_round[3]["excluded"] == [1, 2]
    assert by_round[5]["resynced"] == [1]
    assert all(2 in rec["excluded"] for rec in res.fault_log)


def test_faulted_training_composes_with_adaptive_tau():
    from repro.api import Experiment

    plan = FaultPlan.parse("crash:1@1-3", m=M, seed=0)
    exp = Experiment(workers=M, strategy="overlap_local_sgd", seed=0)
    ctrl = TauController(tau=2, tau_min=1, tau_max=8)
    res = exp.fit(rounds=4, faults=plan, adaptive_tau=ctrl)
    decisions = {h["round"]: h for h in res.tau_schedule}
    assert decisions[1]["decision"] == "fault_hold" and decisions[1]["fault"] == "crash"
    assert decisions[3]["decision"] == "fault_hold" and decisions[3]["fault"] == "rejoin"
    assert "fault" not in decisions[0]

    with pytest.raises(ValueError):
        exp.fit(rounds=1, faults=FaultPlan(m=M + 1))  # worker-count mismatch


# -- controller + schedule ----------------------------------------------------


def test_controller_fault_hold():
    """A fault round holds τ regardless of drift and does not consume the
    cooldown window."""
    ctrl = TauController(tau=4, cooldown_rounds=2)
    ctrl.update(drift=0.0, scale=1.0, fault="crash")  # drift would say grow
    assert ctrl.tau == 4 and ctrl.history[-1]["decision"] == "fault_hold"
    assert ctrl.history[-1]["fault"] == "crash"
    ctrl.update(drift=0.0, scale=1.0)
    assert ctrl.history[-1]["decision"] == "grow" and ctrl.tau == 8
    ctrl.update(drift=0.0, scale=1.0, fault="deadline")  # mid-cooldown fault
    assert ctrl.history[-1]["decision"] == "fault_hold"
    ctrl.update(drift=0.0, scale=1.0)
    assert ctrl.history[-1]["decision"] == "cooldown"
    assert ctrl._cooldown == 1  # the fault round did not consume cooldown


def test_schedule_block_records_fault_holds():
    plan = FaultPlan.parse("crash:1@2-5", m=16, seed=0)
    ctrl = TauController(tau=2, tau_min=1, tau_max=32)
    block = schedule_block("overlap_local_sgd", ctrl, rounds=10, fault_plan=plan)
    faulted = [t for t in block["trajectory"] if t["decision"] == "fault_hold"]
    assert [t["round"] for t in faulted] == [2, 3, 4, 5]
    assert faulted[-1]["fault"] == "rejoin"


# -- runtime model ------------------------------------------------------------


def test_runtime_model_noop_plan_matches_no_plan():
    """A plan with no fault events must leave the simulated clocks exactly
    at the historical fully-live model."""
    cfg = RuntimeConfig(m=8, straggle_std=0.3, seed=5)
    for algo in ("local_sgd", "overlap_local_sgd", "sync_sgd"):
        a = simulate(algo, 4, 64, cfg)
        b = simulate(algo, 4, 64, cfg, fault_plan=FaultPlan(m=8))  # eventless plan
        assert a == b


def test_runtime_model_faults_slow_the_run():
    """Straggler/crash plans reshape the clocks: a blocked algorithm pays
    the straggler in idle time unless the deadline policy excludes it; the
    overlapped algorithm with an excluded straggler keeps its round time."""
    plan_slow = FaultPlan(m=8, slowdown=((0, 4.0),), deadline_factor=100.0)  # never excluded
    plan_cut = FaultPlan(m=8, slowdown=((0, 4.0),))  # deadline 3.0 excludes it
    cfg = plan_slow.runtime_config()
    base = simulate("local_sgd", 4, 64, cfg)
    slow = simulate("local_sgd", 4, 64, cfg, fault_plan=plan_slow)
    cut = simulate("local_sgd", 4, 64, cfg, fault_plan=plan_cut)
    assert slow.total_time > base.total_time * 2  # the straggler holds every barrier
    assert cut.total_time < slow.total_time  # deadline exclusion releases the barrier
    assert cut.idle_time < slow.idle_time
    # plan/config worker-count mismatch is an error
    with pytest.raises(ValueError):
        simulate("local_sgd", 4, 16, RuntimeConfig(m=4), fault_plan=plan_cut)


def test_calibrated_config_from_dryrun_json():
    d = dict(
        plan=dict(workers=32, fsdp=4, tensor=2),
        tau=4,
        roofline=dict(compute_s=0.8, memory_s=0.4),
        boundary_collectives={"all-reduce": dict(count=2, bytes=4e9)},
    )
    cfg = calibrated_config(d, link_gbps=40.0)
    assert cfg.m == 32
    np.testing.assert_allclose(cfg.t_step, 0.2)
    np.testing.assert_allclose(cfg.t_comm, cfg.t_handshake + 4e9 / 5e9)
    # plane-bytes fallback when the boundary probe was skipped
    d2 = dict(plan=dict(workers=8), tau=1, roofline={}, plane=dict(x_buffer_bytes=1e9))
    cfg2 = calibrated_config(d2, link_gbps=100.0)
    assert cfg2.m == 8 and cfg2.t_step == RuntimeConfig().t_step
    np.testing.assert_allclose(cfg2.t_comm, cfg2.t_handshake + 1e9 / 12.5e9)
    # a fault plan's runtime_config rides on the calibrated constants
    rt = FaultPlan(m=32, seed=9).runtime_config(base=cfg)
    assert rt.m == 32 and rt.t_step == cfg.t_step and rt.seed == 9


# -- serving robustness -------------------------------------------------------


def test_engine_guards():
    from repro.serving.engine import BatchedEngine

    eng = BatchedEngine(cfg=None, params=None, slots=2, max_len=16)
    with pytest.raises(ValueError, match="non-empty"):
        eng.submit("a", np.zeros((0,), np.int32), 4)
    with pytest.raises(ValueError, match="max_new"):
        eng.submit("a", np.array([1, 2]), 0)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit("a", np.arange(10), 10)
    eng.submit("a", np.array([1, 2]), 4)
    with pytest.raises(ValueError, match="duplicate"):
        eng.submit("a", np.array([1, 2]), 4)
    with pytest.raises(ValueError, match="slots"):
        BatchedEngine(cfg=None, params=None, slots=0)


def test_generate_guards():
    from repro.serving.engine import generate

    with pytest.raises(ValueError, match="empty"):
        generate(None, None, jnp.zeros((0, 4), jnp.int32), 4)
    with pytest.raises(ValueError, match="batch, seq"):
        generate(None, None, jnp.zeros((4,), jnp.int32), 4)
    with pytest.raises(ValueError, match="max_new"):
        generate(None, None, jnp.ones((1, 4), jnp.int32), 0)


def test_hot_swap_retries_transient_reads(tmp_path, monkeypatch):
    """hot_swap rides through transient read failures with backoff, raises
    after the retry budget, and never retries structural mismatches."""
    from repro.serving import engine as eng

    template = {"w": jnp.ones((2, 2), jnp.float32)}
    calls = {"n": 0}

    import repro.checkpoint as ckpt

    good = {"w": jnp.full((2, 2), 3.0, jnp.float32)}

    def flaky(path, tmpl):
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("file mid-write")
        return good

    sleeps = []
    monkeypatch.setattr(ckpt, "restore", flaky)
    out = eng.hot_swap("x.npz", template, retries=3, backoff=0.01, _sleep=sleeps.append)
    np.testing.assert_array_equal(np.asarray(out["w"]), 3.0)
    assert calls["n"] == 3 and sleeps == [0.01, 0.02]

    calls["n"] = -10  # always failing
    with pytest.raises(OSError):
        eng.hot_swap("x.npz", template, retries=2, backoff=0.0, _sleep=lambda s: None)

    def structural(path, tmpl):
        raise KeyError("checkpoint missing 'w'")

    monkeypatch.setattr(ckpt, "restore", structural)
    with pytest.raises(KeyError):
        eng.hot_swap("x.npz", template, retries=5, backoff=0.0, _sleep=lambda s: None)
