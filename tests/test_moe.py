"""MoE dispatch correctness against a loop-based reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import MoEConfig
from repro.models import params as PB
from repro.models.layers import moe as moe_mod


def loop_reference(params, cfg, x, capacity_factor=64.0):
    """Token-by-token routed computation (dropless)."""
    b, s, d = x.shape
    xt = np.asarray(x.reshape(-1, d), np.float64)
    logits = xt @ np.asarray(params["router"], np.float64)
    if cfg.num_shared_experts:
        scores = 1 / (1 + np.exp(-logits))
    else:
        e = np.exp(logits - logits.max(-1, keepdims=True))
        scores = e / e.sum(-1, keepdims=True)
    k = cfg.top_k
    out = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        idx = np.argsort(-scores[t])[:k]
        g = scores[t, idx]
        g = g / g.sum()
        for e_i, gi in zip(idx, g):
            wg = np.asarray(params["wi_gate"][e_i], np.float64)
            wu = np.asarray(params["wi_up"][e_i], np.float64)
            wo = np.asarray(params["wo"][e_i], np.float64)
            h = xt[t] @ wg
            u = xt[t] @ wu
            act = h / (1 + np.exp(-h))  # silu
            out[t] += gi * ((act * u) @ wo)
    return out.reshape(b, s, d)


def test_moe_matches_loop_reference(rng):
    cfg = MoEConfig(num_experts=4, top_k=2, expert_ff=16)
    params, _ = PB.build(moe_mod.init_moe, jax.random.PRNGKey(0), jnp.float32, "moe", 8, cfg)
    params = params["moe"]
    x = jnp.asarray(rng.normal(size=(2, 5, 8)).astype(np.float32))
    out, stats = moe_mod.moe_apply(params, cfg, x, capacity_factor=64.0)
    ref = loop_reference(params, cfg, x)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)
    assert float(stats["dropped"]) == 0.0


def test_moe_capacity_drops_tokens(rng):
    """With capacity 1 per expert and all tokens preferring one expert,
    overflow tokens must be dropped (gate 0), not corrupt other slots."""
    cfg = MoEConfig(num_experts=2, top_k=1, expert_ff=8, capacity_factor=0.01)
    params, _ = PB.build(moe_mod.init_moe, jax.random.PRNGKey(1), jnp.float32, "moe", 4, cfg)
    params = params["moe"]
    # bias router so expert 0 wins for every token
    params = dict(params, router=jnp.asarray(np.stack([np.ones(4) * 5, -np.ones(4) * 5], 1), jnp.float32))
    x = jnp.asarray(rng.normal(size=(1, 6, 4)).astype(np.float32))
    out, stats = moe_mod.moe_apply(params, cfg, x)
    assert float(stats["dropped"]) > 0.5
    # dropped tokens produce zero output rows
    zero_rows = np.where(np.abs(np.asarray(out[0])).sum(-1) < 1e-9)[0]
    assert len(zero_rows) >= 4


def test_moe_shared_and_dense_residual_paths(rng):
    cfg = MoEConfig(num_experts=4, top_k=2, expert_ff=16, num_shared_experts=1, shared_expert_ff=16, dense_residual_ff=16)
    params, _ = PB.build(moe_mod.init_moe, jax.random.PRNGKey(2), jnp.float32, "moe", 8, cfg)
    params = params["moe"]
    x = jnp.asarray(rng.normal(size=(2, 4, 8)).astype(np.float32))
    out, stats = moe_mod.moe_apply(params, cfg, x, capacity_factor=64.0)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    # removing shared path changes the output (it is actually used)
    params2 = dict(params, shared=jax.tree.map(jnp.zeros_like, params["shared"]))
    out2, _ = moe_mod.moe_apply(params2, cfg, x, capacity_factor=64.0)
    assert np.abs(np.asarray(out) - np.asarray(out2)).max() > 1e-4


def test_router_aux_loss_balanced_vs_skewed(rng):
    cfg = MoEConfig(num_experts=4, top_k=1, expert_ff=8)
    params, _ = PB.build(moe_mod.init_moe, jax.random.PRNGKey(3), jnp.float32, "moe", 4, cfg)
    params = params["moe"]
    x = jnp.asarray(rng.normal(size=(4, 16, 4)).astype(np.float32))
    _, stats_bal = moe_mod.moe_apply(params, cfg, x)
    skew = dict(params, router=jnp.asarray(np.stack([np.ones(4) * 5] + [-np.ones(4) * 5] * 3, 1), jnp.float32))
    _, stats_skew = moe_mod.moe_apply(skew, cfg, x)
    assert float(stats_skew["aux_loss"]) > float(stats_bal["aux_loss"])
