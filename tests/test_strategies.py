"""Two-phase CommStrategy protocol: golden equivalence against the seed
single-hook Algorithm path, plus semantics of the two strategies the old
API could not express (delayed averaging, sparse anchor averaging)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import AlgoConfig, get_arch
from repro.core import make_algorithm, make_strategy, sparsify_topk
from repro.core.strategy import LegacyStrategy
from repro.models import transformer as T
from repro.optim import schedules, sgd
from repro.training import make_round_step, make_train_state

D = 6
M = 4


def quad_loss(params, batch):
    A, b = batch
    r = A @ params["x"] - b
    loss = 0.5 * jnp.sum(r * r)
    return loss, dict(loss=loss)


def _quad_setup(cfg: AlgoConfig, algo, lr=0.05):
    params = {"x": jnp.asarray(np.random.default_rng(0).normal(size=D), jnp.float32)}
    opt = sgd(momentum=0.0, nesterov=False, weight_decay=0.0)
    state = make_train_state(params, M, opt, algo, None)
    step = jax.jit(make_round_step(quad_loss, opt, algo, schedules.constant(lr), None))
    return state, step


def _quad_batches(rng, tau):
    A = jnp.asarray(rng.normal(size=(tau, M, D, D)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(tau, M, D)), jnp.float32)
    return A, b


def _run_pair(cfg: AlgoConfig, rounds=4, lr=0.05):
    """Run the legacy Algorithm and the native CommStrategy on identical
    batches; return the two final states."""
    legacy, native = make_algorithm(cfg), make_strategy(cfg)
    s_l, step_l = _quad_setup(cfg, legacy, lr)
    s_n, step_n = _quad_setup(cfg, native, lr)
    rng = np.random.default_rng(1)
    for _ in range(rounds):
        batch = _quad_batches(rng, legacy.tau)
        s_l, _ = step_l(s_l, batch)
        s_n, _ = step_n(s_n, batch)
    return s_l, s_n


@pytest.mark.parametrize(
    "name,beta",
    [
        ("overlap_local_sgd", 0.0),
        ("overlap_local_sgd", 0.7),
        ("local_sgd", 0.0),
        ("sync_sgd", 0.0),
        ("easgd", 0.0),
        ("cocod", 0.0),
        ("powersgd", 0.0),
    ],
)
def test_native_port_bitwise_matches_legacy(name, beta):
    """Every seed algorithm, ported onto the two-phase protocol, must be
    bit-for-bit identical to its legacy single-hook form."""
    cfg = AlgoConfig(name=name, tau=3, alpha=0.6, anchor_beta=beta)
    s_l, s_n = _run_pair(cfg)
    np.testing.assert_array_equal(np.asarray(s_l.x["x"]), np.asarray(s_n.x["x"]))
    if name == "overlap_local_sgd":
        # legacy carries the pending anchor in vars.z; natively it is the
        # explicit in-flight collective
        np.testing.assert_array_equal(np.asarray(s_l.vars.z["x"]), np.asarray(s_n.inflight["x"]))


def test_overlap_golden_qwen2_reduced_bitwise():
    """ISSUE golden test: OverlapLocalSGD under CommStrategy produces
    bitwise-identical params to the seed Algorithm.boundary path for 3
    rounds on the reduced qwen2 config."""
    cfg_model = get_arch("qwen2-7b").model.reduced()
    params, axes = T.init_model(cfg_model, jax.random.PRNGKey(0))
    acfg = AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=0.7)
    opt = sgd(momentum=0.9, nesterov=True, weight_decay=0.0)
    loss_fn = lambda p, b: T.lm_loss(cfg_model, p, b)

    states, steps = [], []
    for algo in (make_algorithm(acfg), make_strategy(acfg)):
        states.append(make_train_state(params, 2, opt, algo, axes))
        steps.append(jax.jit(make_round_step(loss_fn, opt, algo, schedules.constant(1e-2), axes)))

    rng = np.random.default_rng(0)
    for _ in range(3):
        toks = rng.integers(0, cfg_model.vocab_size, (2, 2, 2, 16)).astype(np.int32)
        tgts = rng.integers(0, cfg_model.vocab_size, (2, 2, 2, 16)).astype(np.int32)
        batch = dict(tokens=jnp.asarray(toks), targets=jnp.asarray(tgts))
        states = [step(s, batch)[0] for step, s in zip(steps, states)]

    s_legacy, s_native = states
    for a, b in zip(jax.tree.leaves(s_legacy.x), jax.tree.leaves(s_native.x)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # pending anchor: legacy vars.z ≡ native inflight
    for a, b in zip(jax.tree.leaves(s_legacy.vars.z), jax.tree.leaves(s_native.inflight)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_legacy_strategy_wrapper_is_identity_semantics():
    """as_strategy-wrapped Algorithm (everything in the apply phase) is the
    reference path; its inflight slot stays empty."""
    cfg = AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=0.0)
    algo = make_algorithm(cfg)
    state, _ = _quad_setup(cfg, algo)
    assert isinstance(state.inflight, type(None))
    wrapped = LegacyStrategy(algo)
    assert wrapped.tau == algo.tau and wrapped.name == algo.name


# ---------------------------------------------------------------------------
# delayed averaging (DaSGD-style)
# ---------------------------------------------------------------------------


def _manual_delayed_sim(x0, As, bs, lr, tau, delay, rounds):
    """NumPy reference: plain local SGD; the round-average launched at each
    boundary is applied `delay` steps into the next round as
    x_i ← avg(x_launch) + (x_i − x_launch_i)."""
    x = np.tile(x0[None], (M, 1)).astype(np.float32)
    avg, x_launch = x.mean(0), x.copy()  # init_inflight
    for r in range(rounds):
        for k in range(tau):
            A, b = As[r, k], bs[r, k]
            for i in range(M):
                g = A[i].T @ (A[i] @ x[i] - b[i])
                x[i] = x[i] - lr * g
            if delay < tau and k == delay - 1:
                x = avg[None] + (x - x_launch)
        if delay >= tau:
            x = avg[None] + (x - x_launch)
        avg, x_launch = x.mean(0), x.copy()  # boundary_launch
    return x


@pytest.mark.parametrize("delay", [1, 2, 4])
def test_delayed_averaging_consumes_at_step_k(delay):
    tau, lr, rounds = 4, 0.05, 3
    cfg = AlgoConfig(name="delayed_avg", tau=tau, delay_steps=delay)
    strat = make_strategy(cfg)
    state, step = _quad_setup(cfg, strat, lr)
    x0 = np.asarray(state.x["x"][0])

    rng = np.random.default_rng(5)
    As = rng.normal(size=(rounds, tau, M, D, D)).astype(np.float32)
    bs = rng.normal(size=(rounds, tau, M, D)).astype(np.float32)
    for r in range(rounds):
        state, _ = step(state, (jnp.asarray(As[r]), jnp.asarray(bs[r])))

    expected = _manual_delayed_sim(x0, As, bs, lr, tau, delay, rounds)
    np.testing.assert_allclose(np.asarray(state.x["x"]), expected, rtol=2e-5, atol=2e-5)


def test_delayed_averaging_at_full_delay_matches_cocod():
    """delay = τ degenerates to boundary consumption — exactly CoCoD-SGD."""
    tau = 3
    cfg_d = AlgoConfig(name="delayed_avg", tau=tau, delay_steps=tau)
    cfg_c = AlgoConfig(name="cocod", tau=tau)
    s_d, step_d = _quad_setup(cfg_d, make_strategy(cfg_d))
    s_c, step_c = _quad_setup(cfg_c, make_strategy(cfg_c))
    rng = np.random.default_rng(6)
    for _ in range(3):
        batch = _quad_batches(rng, tau)
        s_d, _ = step_d(s_d, batch)
        s_c, _ = step_c(s_c, batch)
    np.testing.assert_allclose(np.asarray(s_d.x["x"]), np.asarray(s_c.x["x"]), rtol=1e-6, atol=1e-6)


def test_delayed_averaging_rejects_bad_delay():
    with pytest.raises(ValueError):
        make_strategy(AlgoConfig(name="delayed_avg", tau=2, delay_steps=3))
    with pytest.raises(ValueError):
        make_strategy(AlgoConfig(name="delayed_avg", tau=2, delay_steps=0))


# ---------------------------------------------------------------------------
# sparse anchor averaging (LOSCAR-style)
# ---------------------------------------------------------------------------


def test_sparse_anchor_dense_matches_overlap_bitwise():
    """sparse_k = 100% must be exactly vanilla Overlap-Local-SGD."""
    tau = 3
    cfg_s = AlgoConfig(name="sparse_anchor", tau=tau, alpha=0.6, sparse_k=1.0)
    cfg_o = AlgoConfig(name="overlap_local_sgd", tau=tau, alpha=0.6, anchor_beta=0.0)
    s_s, step_s = _quad_setup(cfg_s, make_strategy(cfg_s))
    s_o, step_o = _quad_setup(cfg_o, make_strategy(cfg_o))
    rng = np.random.default_rng(7)
    for _ in range(4):
        batch = _quad_batches(rng, tau)
        s_s, _ = step_s(s_s, batch)
        s_o, _ = step_o(s_o, batch)
    np.testing.assert_array_equal(np.asarray(s_s.x["x"]), np.asarray(s_o.x["x"]))
    np.testing.assert_array_equal(np.asarray(s_s.inflight["x"]), np.asarray(s_o.inflight["x"]))


def test_sparsify_topk_keeps_top_fraction():
    d = {"w": jnp.asarray(np.arange(1.0, 101.0, dtype=np.float32))}
    s = sparsify_topk(d, 0.25)["w"]
    assert int(jnp.sum(s != 0)) in (25, 26)  # quantile ties may keep one extra
    assert float(s[-1]) == 100.0 and float(s[0]) == 0.0
    np.testing.assert_array_equal(np.asarray(sparsify_topk(d, 1.0)["w"]), np.asarray(d["w"]))


def test_sparse_anchor_error_feedback_conserves_delta():
    """s + e' = Δ + e: the truncated residual is carried, not dropped."""
    tau = 2
    cfg = AlgoConfig(name="sparse_anchor", tau=tau, alpha=0.6, sparse_k=0.5)
    strat = make_strategy(cfg)
    state, step = _quad_setup(cfg, strat)
    rng = np.random.default_rng(8)
    # after one round: z_new − z_old (the transmitted sparse payload) plus
    # the carried error must equal the dense delta mean(x) − z_old
    z_old = np.asarray(state.inflight["x"])  # anchor consumed in round 1
    state, _ = step(state, _quad_batches(rng, tau))
    z_new = np.asarray(state.inflight["x"])
    err = np.asarray(state.vars.extra["x"])
    dense_delta = np.asarray(state.x["x"]).mean(0) - z_old  # x is post-pullback
    np.testing.assert_allclose((z_new - z_old) + err, dense_delta, rtol=1e-5, atol=1e-6)
    assert np.any(err != 0)  # something was actually truncated


@pytest.mark.parametrize(
    "name,kw",
    [("delayed_avg", dict(delay_steps=2)), ("sparse_anchor", dict(sparse_k=0.5))],
)
def test_new_strategies_converge_on_quadratic(name, kw):
    tau = 4
    cfg = AlgoConfig(name=name, tau=tau, alpha=0.5, **kw)
    strat = make_strategy(cfg)
    state, step = _quad_setup(cfg, strat, lr=0.03)
    rng = np.random.default_rng(10)
    Afix = rng.normal(size=(M, D, D)).astype(np.float32)
    x_true = rng.normal(size=D).astype(np.float32)
    bfix = np.einsum("mij,j->mi", Afix, x_true).astype(np.float32)
    losses = []
    for _ in range(40):
        A = jnp.asarray(np.tile(Afix[None], (tau, 1, 1, 1)))
        b = jnp.asarray(np.tile(bfix[None], (tau, 1, 1)))
        state, ms = step(state, (A, b))
        losses.append(float(ms["loss"].mean()))
    assert losses[-1] < losses[0] * 0.1, (name, losses[0], losses[-1])
