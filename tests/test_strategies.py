"""Two-phase CommStrategy protocol: golden equivalence against the seed
single-hook Algorithm path, plus semantics of the two strategies the old
API could not express (delayed averaging, sparse anchor averaging), plus
the packed-boundary path (flat parameter plane) pinned bitwise to the
per-leaf reference oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import AlgoConfig, get_arch
from repro.core import make_algorithm, make_strategy, sparsify_topk
from repro.core.strategy import LegacyStrategy
from repro.kernels import flags
from repro.models import transformer as T
from repro.optim import schedules, sgd
from repro.training import make_round_step, make_train_state

D = 6
M = 4


from conftest import unpack_view as _unp  # packed-state pytree view


def quad_loss(params, batch):
    A, b = batch
    r = A @ params["x"] - b
    loss = 0.5 * jnp.sum(r * r)
    return loss, dict(loss=loss)


def _quad_setup(cfg: AlgoConfig, algo, lr=0.05):
    params = {"x": jnp.asarray(np.random.default_rng(0).normal(size=D), jnp.float32)}
    opt = sgd(momentum=0.0, nesterov=False, weight_decay=0.0)
    state = make_train_state(params, M, opt, algo, None)
    step = jax.jit(make_round_step(quad_loss, opt, algo, schedules.constant(lr), None))
    return state, step


def _quad_batches(rng, tau):
    A = jnp.asarray(rng.normal(size=(tau, M, D, D)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(tau, M, D)), jnp.float32)
    return A, b


def _run_pair(cfg: AlgoConfig, rounds=4, lr=0.05):
    """Run the legacy Algorithm and the native CommStrategy on identical
    batches; return the two final states."""
    legacy, native = make_algorithm(cfg), make_strategy(cfg)
    s_l, step_l = _quad_setup(cfg, legacy, lr)
    s_n, step_n = _quad_setup(cfg, native, lr)
    rng = np.random.default_rng(1)
    for _ in range(rounds):
        batch = _quad_batches(rng, legacy.tau)
        s_l, _ = step_l(s_l, batch)
        s_n, _ = step_n(s_n, batch)
    return s_l, s_n


@pytest.mark.parametrize(
    "name,beta",
    [
        ("overlap_local_sgd", 0.0),
        ("overlap_local_sgd", 0.7),
        ("local_sgd", 0.0),
        ("sync_sgd", 0.0),
        ("easgd", 0.0),
        ("cocod", 0.0),
        ("powersgd", 0.0),
    ],
)
def test_native_port_bitwise_matches_legacy(name, beta):
    """Every seed algorithm, ported onto the two-phase protocol, must be
    bit-for-bit identical to its legacy single-hook form."""
    cfg = AlgoConfig(name=name, tau=3, alpha=0.6, anchor_beta=beta)
    s_l, s_n = _run_pair(cfg)
    # the native strategy runs plane-resident; compare through the view
    np.testing.assert_array_equal(np.asarray(s_l.x["x"]), np.asarray(_unp(s_n.x)["x"]))
    if name == "overlap_local_sgd":
        # legacy carries the pending anchor in vars.z; natively it is the
        # explicit in-flight collective
        np.testing.assert_array_equal(np.asarray(s_l.vars.z["x"]), np.asarray(_unp(s_n.inflight)["x"]))


def test_overlap_golden_qwen2_reduced_bitwise():
    """ISSUE golden test: OverlapLocalSGD under CommStrategy produces
    bitwise-identical params to the seed Algorithm.boundary path for 3
    rounds on the reduced qwen2 config."""
    cfg_model = get_arch("qwen2-7b").model.reduced()
    params, axes = T.init_model(cfg_model, jax.random.PRNGKey(0))
    acfg = AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=0.7)
    opt = sgd(momentum=0.9, nesterov=True, weight_decay=0.0)
    loss_fn = lambda p, b: T.lm_loss(cfg_model, p, b)

    states, steps = [], []
    for algo in (make_algorithm(acfg), make_strategy(acfg)):
        states.append(make_train_state(params, 2, opt, algo, axes))
        steps.append(jax.jit(make_round_step(loss_fn, opt, algo, schedules.constant(1e-2), axes)))

    rng = np.random.default_rng(0)
    for _ in range(3):
        toks = rng.integers(0, cfg_model.vocab_size, (2, 2, 2, 16)).astype(np.int32)
        tgts = rng.integers(0, cfg_model.vocab_size, (2, 2, 2, 16)).astype(np.int32)
        batch = dict(tokens=jnp.asarray(toks), targets=jnp.asarray(tgts))
        states = [step(s, batch)[0] for step, s in zip(steps, states)]

    s_legacy, s_native = states
    for a, b in zip(jax.tree.leaves(s_legacy.x), jax.tree.leaves(_unp(s_native.x))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # pending anchor: legacy vars.z ≡ native inflight (a packed plane)
    for a, b in zip(jax.tree.leaves(s_legacy.vars.z), jax.tree.leaves(_unp(s_native.inflight))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_legacy_strategy_wrapper_is_identity_semantics():
    """as_strategy-wrapped Algorithm (everything in the apply phase) is the
    reference path; its inflight slot stays empty."""
    cfg = AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=0.0)
    algo = make_algorithm(cfg)
    state, _ = _quad_setup(cfg, algo)
    assert isinstance(state.inflight, type(None))
    wrapped = LegacyStrategy(algo)
    assert wrapped.tau == algo.tau and wrapped.name == algo.name


# ---------------------------------------------------------------------------
# delayed averaging (DaSGD-style)
# ---------------------------------------------------------------------------


def _manual_delayed_sim(x0, As, bs, lr, tau, delay, rounds):
    """NumPy reference: plain local SGD; the round-average launched at each
    boundary is applied `delay` steps into the next round as
    x_i ← avg(x_launch) + (x_i − x_launch_i)."""
    x = np.tile(x0[None], (M, 1)).astype(np.float32)
    avg, x_launch = x.mean(0), x.copy()  # init_inflight
    for r in range(rounds):
        for k in range(tau):
            A, b = As[r, k], bs[r, k]
            for i in range(M):
                g = A[i].T @ (A[i] @ x[i] - b[i])
                x[i] = x[i] - lr * g
            if delay < tau and k == delay - 1:
                x = avg[None] + (x - x_launch)
        if delay >= tau:
            x = avg[None] + (x - x_launch)
        avg, x_launch = x.mean(0), x.copy()  # boundary_launch
    return x


@pytest.mark.parametrize("delay", [1, 2, 4])
def test_delayed_averaging_consumes_at_step_k(delay):
    tau, lr, rounds = 4, 0.05, 3
    cfg = AlgoConfig(name="delayed_avg", tau=tau, delay_steps=delay)
    strat = make_strategy(cfg)
    state, step = _quad_setup(cfg, strat, lr)
    x0 = np.asarray(_unp(state.x)["x"][0])

    rng = np.random.default_rng(5)
    As = rng.normal(size=(rounds, tau, M, D, D)).astype(np.float32)
    bs = rng.normal(size=(rounds, tau, M, D)).astype(np.float32)
    for r in range(rounds):
        state, _ = step(state, (jnp.asarray(As[r]), jnp.asarray(bs[r])))

    expected = _manual_delayed_sim(x0, As, bs, lr, tau, delay, rounds)
    np.testing.assert_allclose(np.asarray(_unp(state.x)["x"]), expected, rtol=2e-5, atol=2e-5)


def test_delayed_averaging_at_full_delay_matches_cocod():
    """delay = τ degenerates to boundary consumption — exactly CoCoD-SGD."""
    tau = 3
    cfg_d = AlgoConfig(name="delayed_avg", tau=tau, delay_steps=tau)
    cfg_c = AlgoConfig(name="cocod", tau=tau)
    s_d, step_d = _quad_setup(cfg_d, make_strategy(cfg_d))
    s_c, step_c = _quad_setup(cfg_c, make_strategy(cfg_c))
    rng = np.random.default_rng(6)
    for _ in range(3):
        batch = _quad_batches(rng, tau)
        s_d, _ = step_d(s_d, batch)
        s_c, _ = step_c(s_c, batch)
    np.testing.assert_allclose(
        np.asarray(_unp(s_d.x)["x"]), np.asarray(_unp(s_c.x)["x"]), rtol=1e-6, atol=1e-6
    )


def test_delayed_averaging_rejects_bad_delay():
    with pytest.raises(ValueError):
        make_strategy(AlgoConfig(name="delayed_avg", tau=2, delay_steps=3))
    with pytest.raises(ValueError):
        make_strategy(AlgoConfig(name="delayed_avg", tau=2, delay_steps=0))


# ---------------------------------------------------------------------------
# sparse anchor averaging (LOSCAR-style)
# ---------------------------------------------------------------------------


def test_sparse_anchor_dense_matches_overlap_bitwise():
    """sparse_k = 100% must be exactly vanilla Overlap-Local-SGD."""
    tau = 3
    cfg_s = AlgoConfig(name="sparse_anchor", tau=tau, alpha=0.6, sparse_k=1.0)
    cfg_o = AlgoConfig(name="overlap_local_sgd", tau=tau, alpha=0.6, anchor_beta=0.0)
    s_s, step_s = _quad_setup(cfg_s, make_strategy(cfg_s))
    s_o, step_o = _quad_setup(cfg_o, make_strategy(cfg_o))
    rng = np.random.default_rng(7)
    for _ in range(4):
        batch = _quad_batches(rng, tau)
        s_s, _ = step_s(s_s, batch)
        s_o, _ = step_o(s_o, batch)
    np.testing.assert_array_equal(np.asarray(_unp(s_s.x)["x"]), np.asarray(_unp(s_o.x)["x"]))
    np.testing.assert_array_equal(
        np.asarray(_unp(s_s.inflight)["x"]), np.asarray(_unp(s_o.inflight)["x"])
    )


def test_sparsify_topk_keeps_top_fraction():
    d = {"w": jnp.asarray(np.arange(1.0, 101.0, dtype=np.float32))}
    s = sparsify_topk(d, 0.25)["w"]
    assert int(jnp.sum(s != 0)) in (25, 26)  # quantile ties may keep one extra
    assert float(s[-1]) == 100.0 and float(s[0]) == 0.0
    np.testing.assert_array_equal(np.asarray(sparsify_topk(d, 1.0)["w"]), np.asarray(d["w"]))


def test_sparse_anchor_error_feedback_conserves_delta():
    """s + e' = Δ + e: the truncated residual is carried, not dropped."""
    tau = 2
    cfg = AlgoConfig(name="sparse_anchor", tau=tau, alpha=0.6, sparse_k=0.5)
    strat = make_strategy(cfg)
    state, step = _quad_setup(cfg, strat)
    rng = np.random.default_rng(8)
    # after one round: z_new − z_old (the transmitted sparse payload) plus
    # the carried error must equal the dense delta mean(x) − z_old
    z_old = np.asarray(_unp(state.inflight)["x"])  # anchor consumed in round 1
    state, _ = step(state, _quad_batches(rng, tau))
    z_new = np.asarray(_unp(state.inflight)["x"])
    err = np.asarray(_unp(state.vars.extra)["x"])
    dense_delta = np.asarray(_unp(state.x)["x"]).mean(0) - z_old  # x is post-pullback
    np.testing.assert_allclose((z_new - z_old) + err, dense_delta, rtol=1e-5, atol=1e-6)
    assert np.any(err != 0)  # something was actually truncated


@pytest.mark.parametrize(
    "name,kw",
    [("delayed_avg", dict(delay_steps=2)), ("sparse_anchor", dict(sparse_k=0.5))],
)
def test_new_strategies_converge_on_quadratic(name, kw):
    tau = 4
    cfg = AlgoConfig(name=name, tau=tau, alpha=0.5, **kw)
    strat = make_strategy(cfg)
    state, step = _quad_setup(cfg, strat, lr=0.03)
    rng = np.random.default_rng(10)
    Afix = rng.normal(size=(M, D, D)).astype(np.float32)
    x_true = rng.normal(size=D).astype(np.float32)
    bfix = np.einsum("mij,j->mi", Afix, x_true).astype(np.float32)
    losses = []
    for _ in range(40):
        A = jnp.asarray(np.tile(Afix[None], (tau, 1, 1, 1)))
        b = jnp.asarray(np.tile(bfix[None], (tau, 1, 1)))
        state, ms = step(state, (A, b))
        losses.append(float(ms["loss"].mean()))
    assert losses[-1] < losses[0] * 0.1, (name, losses[0], losses[-1])


# ---------------------------------------------------------------------------
# packed parameter plane: golden parity vs the per-leaf oracle
# ---------------------------------------------------------------------------

# a deliberately leafy tree: many shapes, aligned and ragged, plus scalars
def _leafy_params(rng, n_mats=6):
    p = {"s": jnp.float32(rng.normal())}
    for i in range(n_mats):
        p[f"w{i}"] = jnp.asarray(rng.normal(size=(3 + i, 5 + 2 * i)), jnp.float32)
        p[f"b{i}"] = jnp.asarray(rng.normal(size=(5 + 2 * i,)), jnp.float32)
    p["aligned"] = jnp.asarray(rng.normal(size=(2, 128)), jnp.float32)
    return p


def leafy_loss(params, batch):
    A, b = batch
    flat = jnp.concatenate([jnp.ravel(l) for l in jax.tree.leaves(params)])
    r = A @ flat - b
    loss = 0.5 * jnp.sum(r * r)
    return loss, dict(loss=loss)


ALL_PACKABLE = [
    ("overlap_local_sgd", dict(anchor_beta=0.0)),
    ("overlap_local_sgd", dict(anchor_beta=0.7)),
    ("local_sgd", {}),
    ("sync_sgd", {}),
    ("easgd", {}),
    ("cocod", {}),
    ("powersgd", {}),
    ("delayed_avg", dict(delay_steps=2)),  # mid-round consume (delay < tau)
    ("delayed_avg", dict(delay_steps=3)),  # boundary consume (delay = tau)
    ("sparse_anchor", dict(sparse_k=0.5)),  # error feedback active
    ("sparse_anchor", dict(sparse_k=1.0)),
]


@pytest.mark.parametrize("name,kw", ALL_PACKABLE, ids=[f"{n}-{v}" for n, v in ALL_PACKABLE])
def test_packed_boundary_bitwise_matches_perleaf(name, kw, rng):
    """ISSUE golden test: the packed flat-plane boundary is numerically
    identical to the per-leaf reference path, for every strategy, on a
    many-leaf mixed-shape tree — x, carried inflight, and strategy vars."""
    tau = 3
    cfg = AlgoConfig(name=name, tau=tau, alpha=0.6, packed=True, **kw)
    cfg_ref = dataclasses.replace(cfg, packed=False)
    params = _leafy_params(rng)
    n_flat = sum(l.size for l in jax.tree.leaves(params))
    opt = sgd(momentum=0.9, nesterov=True, weight_decay=1e-4)

    states, steps, strats = [], [], []
    for c in (cfg, cfg_ref):
        strat = make_strategy(c)
        strats.append(strat)
        states.append(make_train_state(params, M, opt, strat, None))
        steps.append(jax.jit(make_round_step(leafy_loss, opt, strat, schedules.constant(0.03), None)))
    assert strats[0].packed and not strats[1].packed

    for r in range(3):
        A = jnp.asarray(rng.normal(size=(strats[0].tau, M, 4, n_flat)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(strats[0].tau, M, 4)), jnp.float32)
        states = [step(s, (A, b))[0] for step, s in zip(steps, states)]

    s_p, s_r = states
    for a, b_ in zip(jax.tree.leaves(_unp(s_p.x)), jax.tree.leaves(s_r.x)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_), err_msg=name)
    # carried collective and strategy vars agree through the pytree view
    for slot in ("inflight",):
        pv, rv = _unp(getattr(s_p, slot)), getattr(s_r, slot)
        if isinstance(pv, tuple) and hasattr(pv, "_fields"):  # Inflight NamedTuple
            pv = type(pv)(*(_unp(f) for f in pv))
        for a, b_ in zip(jax.tree.leaves(pv), jax.tree.leaves(rv)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b_), err_msg=f"{name}.{slot}")
    for f in ("z", "v", "extra"):
        pv, rv = _unp(getattr(s_p.vars, f)), getattr(s_r.vars, f)
        if pv is None or rv is None:
            assert (pv is None) == (rv is None) or name == "powersgd"
            continue
        for a, b_ in zip(jax.tree.leaves(pv), jax.tree.leaves(rv)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b_), err_msg=f"{name}.vars.{f}")


def test_packed_boundary_bitwise_matches_perleaf_bf16(rng):
    """Mixed-dtype plane: bf16 params bucket separately and the packed cast
    chains still match the per-leaf oracle bit for bit."""
    tau = 2
    params = {
        "w16": jnp.asarray(rng.normal(size=(17, 33)), jnp.bfloat16),
        "w32": jnp.asarray(rng.normal(size=(9, 11)), jnp.float32),
        "b16": jnp.asarray(rng.normal(size=(257,)), jnp.bfloat16),
    }
    cfg = AlgoConfig(name="overlap_local_sgd", tau=tau, alpha=0.6, anchor_beta=0.7, packed=True)
    strat_p = make_strategy(cfg)
    strat_r = make_strategy(dataclasses.replace(cfg, packed=False))
    x = jax.tree.map(lambda t: jnp.tile(t[None], (M,) + (1,) * t.ndim), params)
    # drift workers apart deterministically, then compare one full boundary
    x = jax.tree.map(lambda t: t + jnp.arange(M, dtype=jnp.float32).reshape((M,) + (1,) * (t.ndim - 1)).astype(t.dtype), x)
    out = []
    for strat in (strat_p, strat_r):
        vars_ = strat.init_vars(x, None)
        inflight = strat.init_inflight(x, vars_, None)
        xb, vb, fb = jax.jit(lambda xx, vv, ff: strat.boundary_round(xx, vv, ff, None))(x, vars_, inflight)
        out.append((xb, _unp(fb), _unp(vb.v)))
    for a, b_ in zip(jax.tree.leaves(out[0]), jax.tree.leaves(out[1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


# ---------------------------------------------------------------------------
# packed boundary op counts: one collective + one kernel launch per boundary
# ---------------------------------------------------------------------------


def _count_primitives(jaxpr, names, _inside_pallas=False):
    """Count equation primitives by name, recursing through sub-jaxprs but
    not into pallas_call bodies (their internal reduces are in-VMEM, not
    HBM collectives)."""
    counts = dict.fromkeys(names, 0)
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in counts:
            counts[eqn.primitive.name] += 1
        if eqn.primitive.name == "pallas_call":
            continue
        for v in eqn.params.values():
            sub = None
            if isinstance(v, jax.extend.core.ClosedJaxpr):
                sub = v.jaxpr
            elif hasattr(v, "eqns"):
                sub = v
            if sub is not None:
                for k, c in _count_primitives(sub, names).items():
                    counts[k] += c
    return counts


def _boundary_jaxpr(cfg, params, force_pallas):
    strat = make_strategy(cfg)
    x = jax.tree.map(lambda t: jnp.tile(t[None], (M,) + (1,) * t.ndim), params)
    vars_ = strat.init_vars(x, None)
    inflight = strat.init_inflight(x, vars_, None)
    fn = lambda xx, vv, ff: strat.boundary_round(xx, vv, ff, None)
    if force_pallas:
        with flags.force_pallas():
            return jax.make_jaxpr(fn)(x, vars_, inflight)
    return jax.make_jaxpr(fn)(x, vars_, inflight)


@pytest.mark.parametrize("beta", [0.0, 0.7])
def test_packed_boundary_single_kernel_launch(rng, beta):
    """ISSUE acceptance: regardless of leaf count, the packed overlap
    boundary issues exactly ONE fused anchor-mix kernel launch (jaxpr
    inspection under forced Pallas dispatch)."""
    params = _leafy_params(rng)  # 14 leaves
    assert len(jax.tree.leaves(params)) >= 10
    cfg = AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=beta, packed=True)
    jaxpr = _boundary_jaxpr(cfg, params, force_pallas=True)
    n = _count_primitives(jaxpr.jaxpr, ["pallas_call"])["pallas_call"]
    assert n == 1, f"expected 1 fused kernel launch, jaxpr has {n}"


@pytest.mark.parametrize("beta", [0.0, 0.7])
def test_packed_boundary_single_worker_mean(rng, beta):
    """One worker-mean reduction per boundary on the packed plane vs one per
    leaf on the reference path (ref dispatch: the mean is the only
    reduce_sum in the boundary program)."""
    params = _leafy_params(rng)
    n_leaves = len(jax.tree.leaves(params))
    cfg = AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=beta, packed=True)
    packed_counts = _count_primitives(
        _boundary_jaxpr(cfg, params, force_pallas=False).jaxpr, ["reduce_sum"]
    )
    assert packed_counts["reduce_sum"] == 1, packed_counts
    ref_counts = _count_primitives(
        _boundary_jaxpr(dataclasses.replace(cfg, packed=False), params, force_pallas=False).jaxpr,
        ["reduce_sum"],
    )
    assert ref_counts["reduce_sum"] == n_leaves  # the per-leaf path pays one per tensor
