"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each family (2 layers, d_model ≤ 512, ≤ 4 experts) runs one forward and
one full Overlap-Local-SGD train round on CPU; output shapes and finiteness
are asserted. The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import AlgoConfig, get_arch, list_archs
from repro.core import make_algorithm
from repro.models import transformer as T
from repro.optim import schedules, sgd
from repro.training import make_round_step, make_train_state

ARCHS = list_archs()
M = 2  # workers in the smoke round


def make_batch(cfg, rng, b=2, s=16, tau=None):
    def one():
        if cfg.frontend and cfg.frontend.kind == "audio":
            k = cfg.frontend.num_codebooks
            return dict(
                tokens=rng.integers(0, cfg.vocab_size, (b, k, s)).astype(np.int32),
                targets=rng.integers(0, cfg.vocab_size, (b, k, s)).astype(np.int32),
            )
        if cfg.frontend and cfg.frontend.kind == "vision":
            return dict(
                tokens=rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32),
                image_embeds=rng.normal(size=(b, cfg.frontend.tokens_per_item, cfg.frontend.embed_dim)).astype(np.float32),
                targets=rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32),
            )
        return dict(
            tokens=rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32),
            targets=rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32),
        )

    if tau is None:
        return jax.tree.map(jnp.asarray, one())
    steps = [[one() for _ in range(M)] for _ in range(tau)]
    stacked = jax.tree.map(lambda *xs: np.stack(xs), *[jax.tree.map(lambda *ys: np.stack(ys), *row) for row in steps])
    return jax.tree.map(jnp.asarray, stacked)


@pytest.mark.parametrize("arch_name", ARCHS)
def test_smoke_forward_shapes_and_finiteness(arch_name, rng):
    arch = get_arch(arch_name)
    cfg = arch.model.reduced()
    assert cfg.num_layers <= 2 or cfg.shared_attn_every
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params, axes = T.init_model(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, rng)
    logits, aux = T.apply_model(cfg, params, batch, mode="train")
    if cfg.frontend and cfg.frontend.kind == "audio":
        assert logits.shape == (2, cfg.frontend.num_codebooks, 16, cfg.vocab_size)
    elif cfg.frontend and cfg.frontend.kind == "vision":
        assert logits.shape == (2, 16 + cfg.frontend.tokens_per_item, cfg.vocab_size)
    else:
        assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch_name


@pytest.mark.parametrize("arch_name", ARCHS)
def test_smoke_overlap_train_round(arch_name, rng):
    """One full Overlap-Local-SGD round (τ=2, m=2 workers) per architecture."""
    arch = get_arch(arch_name)
    cfg = arch.model.reduced()
    params, axes = T.init_model(cfg, jax.random.PRNGKey(0))

    def loss_fn(p, b):
        return T.lm_loss(cfg, p, b)

    algo = make_algorithm(AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=0.7))
    opt = sgd(momentum=0.9, nesterov=True)
    state = make_train_state(params, M, opt, algo, axes)
    step = make_round_step(loss_fn, opt, algo, schedules.constant(1e-2), axes)
    batch = make_batch(cfg, rng, tau=2)
    state, metrics = jax.jit(step)(state, batch)
    loss = np.asarray(metrics["loss"])
    assert loss.shape == (2, M)
    assert np.isfinite(loss).all(), arch_name
    # anchor exists and is finite
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(state.vars.z))
    assert int(state.step) == 2
