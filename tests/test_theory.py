"""Property tests for the paper's theory (§2, §5, Appendix A)."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import mixing

m_strategy = st.integers(min_value=2, max_value=64)
alpha_strategy = st.floats(min_value=0.05, max_value=0.95)


@given(m=m_strategy, alpha=alpha_strategy)
@settings(max_examples=50, deadline=None)
def test_mixing_matrix_column_stochastic(m, alpha):
    P = mixing.mixing_matrix(m, alpha)
    np.testing.assert_allclose(P.sum(axis=0), np.ones(m + 1), atol=1e-12)
    assert (P >= 0).all()


@given(m=m_strategy, alpha=alpha_strategy)
@settings(max_examples=50, deadline=None)
def test_fixed_vector_is_fixed(m, alpha):
    P = mixing.mixing_matrix(m, alpha)
    v = mixing.fixed_vector(m, alpha)
    np.testing.assert_allclose(P @ v, v, atol=1e-12)
    np.testing.assert_allclose(v.sum(), 1.0, atol=1e-12)


@given(m=m_strategy, alpha=alpha_strategy)
@settings(max_examples=50, deadline=None)
def test_zeta_bounded_by_one_minus_alpha(m, alpha):
    """Appendix A: ζ = ‖P − v1ᵀ‖₂ ≤ 1 − α (PageRank second-eigenvalue bound)."""
    P = mixing.mixing_matrix(m, alpha)
    v = mixing.fixed_vector(m, alpha)
    z = mixing.zeta(P, v)
    assert z <= (1 - alpha) + 1e-9
    assert z < 1.0  # contraction — required for Theorem 1's bound (29)


@given(m=st.integers(2, 16), alpha=alpha_strategy)
@settings(max_examples=30, deadline=None)
def test_matrix_powers_converge_to_v1T(m, alpha):
    """Column-stochastic P: Pᵏ → v·1ᵀ (the anchor consensus limit)."""
    P = mixing.mixing_matrix(m, alpha)
    v = mixing.fixed_vector(m, alpha)
    Pk = np.linalg.matrix_power(P, 200)
    # geometric convergence at rate ζ ≤ (1−α): tolerance tracks the bound
    atol = 3 * (1 - alpha) ** 200 + 1e-9
    np.testing.assert_allclose(Pk, np.outer(v, np.ones(m + 1)), atol=atol)


def test_easgd_matrix_is_doubly_stochastic_vs_ours_column_only():
    m, alpha = 8, 0.3
    ours = mixing.mixing_matrix(m, alpha)
    easgd = mixing.easgd_mixing_matrix(m, alpha)
    # EASGD: rows AND columns sum to 1; ours: columns only (paper §2)
    np.testing.assert_allclose(easgd.sum(axis=1), np.ones(m + 1), atol=1e-12)
    np.testing.assert_allclose(easgd.sum(axis=0), np.ones(m + 1), atol=1e-12)
    np.testing.assert_allclose(ours.sum(axis=0), np.ones(m + 1), atol=1e-12)
    assert not np.allclose(ours.sum(axis=1), np.ones(m + 1))


@given(
    m=st.integers(2, 8),
    alpha=st.floats(0.1, 0.9),
    tau=st.integers(1, 5),
    d=st.integers(1, 6),
)
@settings(max_examples=25, deadline=None)
def test_virtual_sequence_identity(m, alpha, tau, d):
    """Eq. (19): y_{k+1} = y_k − γ_eff · (1/m) Σ g_i, with γ_eff = (1−α)γ,
    for EVERY k (boundary or not) — the key reduction in the proof."""
    rng = np.random.default_rng(0)
    gamma = 0.05
    sim = mixing.MatrixFormSim(rng.normal(size=d), m, alpha, tau, gamma)
    for k in range(3 * tau + 1):
        y_before = sim.virtual_sequence()
        grads = rng.normal(size=(d, m))
        sim.step(grads)
        y_after = sim.virtual_sequence()
        expected = y_before - (1 - alpha) * gamma * grads.mean(axis=1)
        np.testing.assert_allclose(y_after, expected, atol=1e-10)


def test_matrix_form_anchor_equals_mean_of_pulled_back_locals():
    """Paper eq. (5) ⇔ matrix column: z_{k+1} = mean_i x_{k+1}^(i)."""
    rng = np.random.default_rng(1)
    m, alpha, tau, d = 4, 0.6, 3, 5
    sim = mixing.MatrixFormSim(rng.normal(size=d), m, alpha, tau, 0.1)
    for k in range(tau):
        sim.step(rng.normal(size=(d, m)))
    np.testing.assert_allclose(sim.anchor, sim.locals.mean(axis=1), atol=1e-10)
