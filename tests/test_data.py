"""Data pipeline properties (hypothesis)."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data import (
    WorkerBatcher,
    lm_batch_stream,
    make_classification,
    partition_iid,
    partition_noniid,
    skewness,
)


@given(m=st.integers(2, 16), seed=st.integers(0, 5))
@settings(max_examples=15, deadline=None)
def test_iid_partition_disjoint_and_even(m, seed):
    data = make_classification(n=2000, dim=8, seed=seed)
    parts = partition_iid(data, m, seed=seed)
    all_idx = np.concatenate(parts)
    assert len(np.unique(all_idx)) == len(all_idx)  # disjoint
    sizes = {len(p) for p in parts}
    assert len(sizes) == 1  # even


@given(m=st.sampled_from([10, 20]), skew=st.floats(0.3, 0.8))
@settings(max_examples=10, deadline=None)
def test_noniid_partition_skew(m, skew):
    # feasibility: per-worker majority draw must fit in its class's pool
    data = make_classification(n=20000, dim=8, num_classes=10, seed=0)
    parts = partition_noniid(data, m, skew=skew, seed=0)
    all_idx = np.concatenate(parts)
    assert len(np.unique(all_idx)) == len(all_idx)
    s = skewness(data, parts)
    assert s > skew * 0.9  # majority class dominates as requested
    iid = skewness(data, partition_iid(data, m))
    assert s > iid + 0.1


def test_paper_noniid_construction():
    """§4: 3125 samples per node, 2000 of one class (skew 0.64), 16 nodes."""
    data = make_classification(n=50000, dim=8, num_classes=10, seed=0)
    parts = partition_noniid(data, 16, skew=0.64, seed=0)
    assert all(len(p) == 3125 for p in parts)
    for i, p in enumerate(parts):
        counts = np.bincount(data.y[p], minlength=10)
        # ≥2000 from the assigned class (the uniform remainder may add more)
        assert counts[i % 10] >= 2000


def test_worker_batcher_shapes_and_epoch():
    data = make_classification(n=1000, dim=8, seed=0)
    parts = partition_iid(data, 4)
    b = WorkerBatcher(data, parts, 16)
    x, y = next(b)
    assert x.shape == (4, 16, 8) and y.shape == (4, 16)
    assert b.steps_per_epoch() == 250 // 16


def test_lm_stream_learnable_structure():
    """The bigram permutation must make next-token prediction learnable."""
    it = lm_batch_stream(batch=8, seq_len=64, vocab_size=32, seed=0)
    toks, tgts = next(it)
    assert toks.shape == (8, 64) and tgts.shape == (8, 64)
    np.testing.assert_array_equal(toks[:, 1:], tgts[:, :-1])
    # deterministic follow-up happens ~75% of the time
    toks2, _ = next(it)
    assert toks2.min() >= 0 and toks2.max() < 32
