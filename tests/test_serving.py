"""Serving correctness: prefill+decode must reproduce the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch
from repro.models import transformer as T
from repro.serving import BatchedEngine, decode_step, generate, prefill
from repro.serving.engine import _grow_all

# one representative per cache family: full-attn, SWA ring, MLA latent,
# recurrent SSM, hybrid, MoE, audio
CONSISTENCY_ARCHS = ["qwen2-7b", "h2o-danube-1.8b", "deepseek-v3-671b", "rwkv6-7b", "zamba2-1.2b"]


@pytest.mark.parametrize("arch_name", CONSISTENCY_ARCHS)
def test_decode_matches_prefill(arch_name, rng):
    cfg = get_arch(arch_name).model.reduced()
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    b, s = 2, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    full_logits, _ = prefill(cfg, params, dict(tokens=toks))
    _, caches = prefill(cfg, params, dict(tokens=toks[:, : s - 1]))
    caches = _grow_all(caches, cfg, s)
    dec_logits, _ = decode_step(cfg, params, toks[:, s - 1 :], caches, jnp.asarray(s - 1, jnp.int32))
    a, b_ = np.asarray(full_logits[:, -1]), np.asarray(dec_logits[:, -1])
    err = np.abs(a - b_).max() / (np.abs(a).max() + 1e-9)
    assert err < 2e-3, (arch_name, err)


def test_multi_step_generation_consistent_with_teacher_forcing(rng):
    """Greedy generation then teacher-forced forward on the generated tokens
    must reproduce the same argmax chain."""
    cfg = get_arch("qwen2-7b").model.reduced()
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 6)), jnp.int32)
    gen = generate(cfg, params, prompt, max_new=5)
    seq = jnp.concatenate([prompt, jnp.asarray(gen)], axis=1)
    logits, _ = T.apply_model(cfg, params, dict(tokens=seq), mode="train")
    for t in range(5):
        pred = int(jnp.argmax(logits[0, 5 + t]))
        assert pred == int(gen[0, t]), t


def test_sliding_window_ring_buffer_generation(rng):
    """Generate past the window: ring buffer must stay consistent with a
    teacher-forced forward (danube, window shrunk to 8)."""
    import dataclasses

    cfg = get_arch("h2o-danube-1.8b").model.reduced()
    cfg = dataclasses.replace(cfg, attention=dataclasses.replace(cfg.attention, sliding_window=8))
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 6)), jnp.int32)
    gen = generate(cfg, params, prompt, max_new=10)  # crosses the window
    seq = jnp.concatenate([prompt, jnp.asarray(gen)], axis=1)
    logits, _ = T.apply_model(cfg, params, dict(tokens=seq), mode="train")
    for t in range(10):
        pred = int(jnp.argmax(logits[0, 5 + t]))
        assert pred == int(gen[0, t]), t


def test_batched_engine_serves_queue(rng):
    cfg = get_arch("qwen2-7b").model.reduced()
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    eng = BatchedEngine(cfg, params, slots=2)
    for i in range(5):
        eng.submit(f"req{i}", rng.integers(0, cfg.vocab_size, (4 + i,)).astype(np.int32), max_new=4)
    results = eng.run()
    assert set(results) == {f"req{i}" for i in range(5)}
    assert all(len(v) == 4 for v in results.values())


def test_audio_decode_shapes(rng):
    cfg = get_arch("musicgen-large").model.reduced()
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    k = cfg.frontend.num_codebooks
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, k, 8)), jnp.int32)
    logits, caches = prefill(cfg, params, dict(tokens=toks))
    assert logits.shape == (2, k, 8, cfg.vocab_size)
    caches = _grow_all(caches, cfg, 9)
    step_logits, _ = decode_step(cfg, params, toks[..., -1:], caches, jnp.asarray(8, jnp.int32))
    assert step_logits.shape == (2, k, 1, cfg.vocab_size)
