"""Round-engine behaviour: microbatch accumulation, metrics, schedules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import AlgoConfig
from repro.core import make_algorithm
from repro.models.classifier import init_mlp, mlp_loss
from repro.optim import schedules, sgd
from repro.training import make_round_step, make_train_state

M = 4


def _setup(microbatch=None, momentum=0.0, lr=0.05, tau=2):
    params, axes = init_mlp(jax.random.PRNGKey(0), 8, 4)
    algo = make_algorithm(AlgoConfig(name="overlap_local_sgd", tau=tau, alpha=0.5, anchor_beta=0.0))
    opt = sgd(momentum=momentum, nesterov=False)
    state = make_train_state(params, M, opt, algo, axes)
    step = make_round_step(mlp_loss, opt, algo, schedules.constant(lr), axes, microbatch=microbatch)
    return state, jax.jit(step)


def _batch(rng, tau, b):
    x = rng.normal(size=(tau, M, b, 8)).astype(np.float32)
    y = rng.integers(0, 4, size=(tau, M, b)).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


def test_microbatch_accumulation_matches_full_batch(rng):
    """grad-accumulated microbatches == one big batch (momentum 0, fresh opt)."""
    batch = _batch(rng, 2, 16)
    s_full, step_full = _setup(microbatch=None)
    s_micro, step_micro = _setup(microbatch=4)
    s_full, _ = step_full(s_full, batch)
    s_micro, _ = step_micro(s_micro, batch)
    for a, b in zip(jax.tree.leaves(s_full.x), jax.tree.leaves(s_micro.x)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


def test_microbatch_metrics_average_over_microbatches(rng):
    """The accumulation scan must report the mean of per-microbatch metrics,
    not the last microbatch's (seed bug)."""
    batch = _batch(rng, 2, 16)
    s_full, step_full = _setup(microbatch=None)
    s_micro, step_micro = _setup(microbatch=4)
    _, ms_full = step_full(s_full, batch)
    _, ms_micro = step_micro(s_micro, batch)
    # equal-sized microbatches: mean of microbatch means == full-batch mean
    np.testing.assert_allclose(
        np.asarray(ms_micro["loss"]), np.asarray(ms_full["loss"]), rtol=2e-5, atol=2e-5
    )
    np.testing.assert_allclose(
        np.asarray(ms_micro["acc"]), np.asarray(ms_full["acc"]), rtol=2e-5, atol=2e-5
    )


def test_schedule_applied_per_local_step(rng):
    params, axes = init_mlp(jax.random.PRNGKey(0), 8, 4)
    algo = make_algorithm(AlgoConfig(name="local_sgd", tau=3))
    opt = sgd(momentum=0.0)
    sched = schedules.warmup_step_decay(1.0, warmup_steps=10, boundaries=())
    state = make_train_state(params, M, opt, algo, axes)
    step = jax.jit(make_round_step(mlp_loss, opt, algo, sched, axes))
    batch = _batch(rng, 3, 8)
    state, ms = step(state, batch)
    lrs = np.asarray(ms["lr"])[:, 0]
    np.testing.assert_allclose(lrs, [0.1, 0.2, 0.3], rtol=1e-6)


def test_paper_lr_schedule_shape():
    """Paper §4: warmup 5 epochs, ×0.1 at epochs 150 and 250."""
    steps_per_epoch = 24
    sched = schedules.warmup_step_decay(
        0.1, warmup_steps=5 * steps_per_epoch, boundaries=(150 * steps_per_epoch, 250 * steps_per_epoch)
    )
    assert float(sched(0)) < 0.001 + 1e-6
    assert abs(float(sched(5 * steps_per_epoch)) - 0.1) < 1e-6
    assert abs(float(sched(200 * steps_per_epoch)) - 0.01) < 1e-7
    assert abs(float(sched(260 * steps_per_epoch)) - 0.001) < 1e-8


def test_consensus_distance_grows_then_resets_with_pullback(rng):
    """During a round workers drift apart (non-IID batches); the α=1 pullback
    collapses them back onto the anchor."""
    params, axes = init_mlp(jax.random.PRNGKey(0), 8, 4)
    algo = make_algorithm(AlgoConfig(name="overlap_local_sgd", tau=2, alpha=1.0, anchor_beta=0.0))
    opt = sgd(momentum=0.0)
    state = make_train_state(params, M, opt, algo, axes)
    step = jax.jit(make_round_step(mlp_loss, opt, algo, schedules.constant(0.1), axes))
    state, _ = step(state, _batch(rng, 2, 8))
    x = np.concatenate([np.asarray(l).reshape(M, -1) for l in jax.tree.leaves(state.x)], axis=1)
    spread = np.abs(x - x.mean(0, keepdims=True)).max()
    assert spread < 1e-6  # alpha=1: all equal after pullback


def test_microbatch_accumulation_plane_resident(rng):
    """Gradient accumulation over the plane-resident step (flat f32
    accumulator buffers in the scan carry) matches the one-big-batch round
    — same pin as the per-leaf test above, on the packed path."""
    from repro.core import make_strategy
    from repro.parallel.packing import Packed, unpack

    def setup(microbatch):
        params, axes = init_mlp(jax.random.PRNGKey(0), 8, 4)
        strat = make_strategy(AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.5, anchor_beta=0.0, packed=True))
        opt = sgd(momentum=0.0, nesterov=False)
        state = make_train_state(params, M, opt, strat, axes)
        step = make_round_step(mlp_loss, opt, strat, schedules.constant(0.05), axes, microbatch=microbatch)
        return state, jax.jit(step)

    batch = _batch(rng, 2, 16)
    s_full, step_full = setup(None)
    s_micro, step_micro = setup(4)
    s_full, ms_full = step_full(s_full, batch)
    s_micro, ms_micro = step_micro(s_micro, batch)
    assert isinstance(s_full.x, Packed) and isinstance(s_micro.x, Packed)
    for a, b in zip(jax.tree.leaves(unpack(s_full.x)), jax.tree.leaves(unpack(s_micro.x))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(
        np.asarray(ms_micro["loss"]), np.asarray(ms_full["loss"]), rtol=2e-5, atol=2e-5
    )


def test_train_fn_migrates_perleaf_state_before_rounds_scan(rng):
    """A PR3-era state (pytree x, packed opt) fed to make_train_fn with
    rounds_per_call > 1 must migrate to the plane BEFORE the rounds scan —
    packing inside round_step would change the scan carry structure."""
    from repro.core import make_strategy
    from repro.parallel.packing import Packed, pack
    from repro.training import TrainState, make_train_fn

    params, axes = init_mlp(jax.random.PRNGKey(0), 8, 4)
    strat = make_strategy(AlgoConfig(name="local_sgd", tau=2, packed=True))
    opt = sgd(momentum=0.0)
    state = make_train_state(params, M, opt, strat, axes)
    assert isinstance(state.x, Packed)
    # reconstruct the pre-plane layout: pytree x, packed everything else
    legacy = TrainState(
        x=jax.tree.map(lambda t: jnp.tile(t[None], (M,) + (1,) * t.ndim), params),
        opt=state.opt, vars=state.vars, step=state.step, inflight=state.inflight,
    )
    fn = make_train_fn(mlp_loss, opt, strat, schedules.constant(0.05), axes, rounds_per_call=2, donate=False)
    x = jnp.asarray(rng.normal(size=(2, 2, M, 8, 8)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 4, size=(2, 2, M, 8)), jnp.int32)
    out, ms = fn(legacy, (x, y))
    assert isinstance(out.x, Packed)
    # and the migrated run matches starting from the plane-resident state
    out2, _ = fn(state, (x, y))
    for a, b in zip(jax.tree.leaves(out.x), jax.tree.leaves(out2.x)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
