"""Docs-reference check (ISSUE 5 satellite): every DESIGN.md/EXPERIMENTS.md
citation in the source tree resolves to an existing file + section header.
CI runs tools/check_doc_refs.py standalone; this wraps it in tier-1 and
pins the checker's own failure modes so it cannot rot into a no-op."""
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import check_doc_refs as cdr  # noqa: E402


def test_all_repo_citations_resolve():
    assert cdr.check(REPO) == []


def test_known_citations_are_collected():
    """The collector must see the load-bearing citations this PR resolves —
    if the regex rots, this fails before the check() no-op can pass."""
    cites = {(doc, sect) for doc, sect, _ in cdr.collect_citations(REPO)}
    assert ("DESIGN", "Arch-applicability") in cites  # launch/dryrun.py
    assert ("EXPERIMENTS", "Perf") in cites  # launch/specs.py --opt variant
    assert ("DESIGN", "3") in cites  # core state-layout docstrings


def test_missing_file_and_section_are_errors(tmp_path):
    # citations assembled piecewise so the repo-wide scan (which also reads
    # THIS file) never sees a dangling literal of its own
    bad_section = "DESIGN" + ".md §Nope"
    missing_doc = "EXPERIMENTS" + ".md §Perf"
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "mod.py").write_text(f"# see {bad_section} and {missing_doc}\n")
    (tmp_path / "DESIGN.md").write_text("# doc\n## §Real section\n")
    errors = cdr.check(tmp_path)
    assert any("§Nope" in e for e in errors)
    assert any("EXPERIMENTS.md, which does not exist" in e for e in errors)


def test_section_prefix_does_not_false_match(tmp_path):
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "mod.py").write_text("# see " + "DESIGN" + ".md §3\n")
    (tmp_path / "DESIGN.md").write_text("# doc\n## §30 Misc\n")
    assert any("§3" in e for e in cdr.check(tmp_path))


def test_cli_entrypoint_passes_on_repo():
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_doc_refs.py"), str(REPO)],
        capture_output=True,
        text=True,
        timeout=60,
    )
    assert proc.returncode == 0, proc.stderr
    assert "0 unresolved" in proc.stdout
