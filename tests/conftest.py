import os
import sys

# Tests run on the default single CPU device. The dry-run (and only the
# dry-run) uses 512 placeholder devices — launched via subprocess in
# test_dryrun.py so this process's jax stays single-device.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def unpack_view(v):
    """Pytree view of a state slot: unpack flat packed planes (recursing
    through NamedTuple containers like Inflight/PowerState), pass trees
    through. Shared by the packed-vs-per-leaf differential suites."""
    from repro.parallel.packing import Packed, unpack

    if isinstance(v, Packed):
        return unpack(v)
    if isinstance(v, tuple) and hasattr(v, "_fields"):
        return type(v)(*(unpack_view(f) for f in v))
    return v
