import os
import sys

# Tests run on the default single CPU device. The dry-run (and only the
# dry-run) uses 512 placeholder devices — launched via subprocess in
# test_dryrun.py so this process's jax stays single-device.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
