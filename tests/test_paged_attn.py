"""Differential paged-vs-dense harness (DESIGN.md §10).

Pins the paged-KV decode path bitwise against the dense-cache oracle: the
same model, same params, same token sequence decoded through (a) the dense
``decode_step`` over a manually built full-length cache and (b) ``paged_step``
over the page pool must produce *identical* f32 logits at every position —
across cache families (full GQA, sliding-window GQA, MLA latent, parallel
block), ragged batch lengths, block-boundary-straddling positions, and
sequence lengths that are not a multiple of the page size.

The dense oracle always uses a full-length cache (slot i = position i) even
for sliding-window archs: the window is enforced by masking, like the paged
path, so the softmax accumulates in the same position order — the ring
buffer's reordering would change summation order and break bitwise equality
while still being numerically correct.

Also here: interpret-mode Pallas-kernel parity with the jnp reference, and a
jaxpr budget asserting the decode path performs zero full-cache copies
(no `_grow_all`-style pad/concatenate growth).
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch
from repro.kernels import flags
from repro.kernels.paged_attn import kernel as pa_kernel
from repro.kernels.paged_attn import ref as pa_ref
from repro.models import transformer as T
from repro.serving import paged_step
from repro.serving.engine import decode_step
from repro.serving.paged_cache import init_paged_pools, paged_supported

PAGED_ARCHS = ["qwen2-7b", "h2o-danube-1.8b", "deepseek-v3-671b", "command-r-35b"]


def _cfg(arch_name, dtype="float32", window=None):
    cfg = dataclasses.replace(get_arch(arch_name).model.reduced(), dtype=dtype)
    if window is not None:
        cfg = dataclasses.replace(cfg, attention=dataclasses.replace(cfg.attention, sliding_window=window))
    return cfg


def _dense_empty_caches(cfg, batch: int, length: int):
    """Empty full-length caches, slot i ↔ position i — the bitwise oracle."""
    a = cfg.attention
    caches = {}
    for si, (kind, n) in enumerate(T.segments(cfg)):
        if a.kind == "mla":
            one = dict(
                ckv=jnp.zeros((batch, length, a.kv_lora_rank), cfg.param_dtype),
                krope=jnp.zeros((batch, length, a.qk_rope_head_dim), cfg.param_dtype),
                pos=jnp.asarray(0, jnp.int32),
            )
        else:
            one = dict(
                k=jnp.zeros((batch, length, a.num_kv_heads, a.head_dim), cfg.param_dtype),
                v=jnp.zeros((batch, length, a.num_kv_heads, a.head_dim), cfg.param_dtype),
                positions=jnp.full((length,), -1, jnp.int32),
                pos=jnp.asarray(0, jnp.int32),
            )
        caches[f"seg{si}"] = jax.tree.map(lambda t: jnp.broadcast_to(t[None], (n,) + t.shape), one)
    return caches


def _paged_setup(cfg, batch: int, maxp: int, page_size: int):
    """Pools + a page table giving every slot ``maxp`` pre-assigned pages."""
    pools = init_paged_pools(cfg, batch * maxp + 1, page_size)
    pt = np.arange(1, batch * maxp + 1, dtype=np.int32).reshape(batch, maxp)
    return pools, jnp.asarray(pt)


def _teacher_forced(cfg, params, toks, page_size=4):
    """Decode ``toks`` token-by-token through both paths; returns the stacked
    (steps, B, V) logits of each."""
    b, seq = toks.shape
    maxp = -(-seq // page_size)
    length = maxp * page_size
    dcaches = _dense_empty_caches(cfg, b, length)
    pools, pt = _paged_setup(cfg, b, maxp, page_size)
    dense_fn = jax.jit(functools.partial(decode_step, cfg))
    paged_fn = jax.jit(functools.partial(paged_step, cfg))
    out_d, out_p = [], []
    for t in range(seq):
        tok = toks[:, t : t + 1]
        ld, dcaches = dense_fn(params, tok, dcaches, jnp.asarray(t, jnp.int32))
        lp, pools = paged_fn(params, tok, pools, pt, jnp.full((b,), t, jnp.int32))
        out_d.append(np.asarray(ld[:, 0]))
        out_p.append(np.asarray(lp[:, 0]))
    return np.stack(out_d), np.stack(out_p)


@pytest.mark.parametrize("arch_name", PAGED_ARCHS)
def test_paged_decode_bitwise_equals_dense_oracle(arch_name, rng):
    """f32: every logit at every position identical — page-size 4 with seq 13
    crosses three page boundaries and leaves the last page partial."""
    window = 8 if arch_name == "h2o-danube-1.8b" else None
    cfg = _cfg(arch_name, window=window)
    assert paged_supported(cfg)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 13)), jnp.int32)
    dense, paged = _teacher_forced(cfg, params, toks, page_size=4)
    assert dense.dtype == np.float32
    np.testing.assert_array_equal(dense, paged)


def test_paged_decode_bf16_storage_within_ulps(rng):
    """bf16 param/pool storage: both paths cast the same stored values to f32
    before the softmax, so they stay bitwise-equal there too."""
    cfg = _cfg("qwen2-7b", dtype="bfloat16")
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (1, 9)), jnp.int32)
    dense, paged = _teacher_forced(cfg, params, toks, page_size=4)
    # few-ulp budget at bf16 scale (eps = 2^-8), bitwise in practice
    tol = np.abs(dense).max() * 2.0**-8 * 2
    assert np.abs(dense - paged).max() <= tol


def test_chunked_prefill_matches_dense_token_by_token(rng):
    """A T>1 chunk through paged_step (in-chunk causal mask) matches T=1
    teacher-forced decode per position. Near-equality, not bitwise: XLA tiles
    the projection matmuls differently for (1,6,d) vs (1,1,d) operands, so
    the inputs to attention already differ in the last float32 ulps — the
    bitwise contract applies to the decode path, where shapes coincide.

    What must hold exactly: the pool left behind by the chunk and by
    token-by-token appends holds the same pages (same K/V bytes modulo that
    matmul jitter), checked via the follow-up decode below."""
    cfg = _cfg("qwen2-7b")
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (1, 6)), jnp.int32)
    page_size, maxp = 4, 2
    pools, pt = _paged_setup(cfg, 1, maxp, page_size)
    chunk_logits, pools = paged_step(cfg, params, toks, pools, pt, jnp.zeros((1,), jnp.int32))
    dense, _ = _teacher_forced(cfg, params, toks, page_size=page_size)
    np.testing.assert_allclose(np.asarray(chunk_logits)[0], dense[:, 0], atol=1e-5, rtol=1e-4)
    # decoding one more token from the chunk-filled pool agrees with the
    # dense continuation to the same tolerance
    nxt = jnp.asarray([[7]], jnp.int32)
    lp, _ = paged_step(cfg, params, nxt, pools, pt, jnp.asarray([6], jnp.int32))
    dcaches = _dense_empty_caches(cfg, 1, maxp * page_size)
    fn = jax.jit(functools.partial(decode_step, cfg))
    for t in range(6):
        _, dcaches = fn(params, toks[:, t : t + 1], dcaches, jnp.asarray(t, jnp.int32))
    ld, _ = fn(params, nxt, dcaches, jnp.asarray(6, jnp.int32))
    np.testing.assert_allclose(np.asarray(lp[0, 0]), np.asarray(ld[0, 0]), atol=1e-5, rtol=1e-4)


def test_ragged_joint_decode_bitwise_per_row(rng):
    """Three slots at different lengths (mid-page, boundary-adjacent,
    end-of-page) decoded in ONE joint paged step.

    Bitwise claim: a row's logits depend only on its own pages, length, and
    token — replacing every *other* row with an idle trash row (token 0,
    zero page table, length 0) leaves it bit-identical, which is exactly the
    continuous-batching invariant (co-batched neighbours can't perturb a
    request). Against the per-row dense oracle the comparison is
    tight-tolerance only, because a (3,1,d) and a (1,1,d) projection matmul
    tile differently in XLA — the bitwise oracle equality is pinned at
    matching batch shapes by the tests above."""
    cfg = _cfg("qwen2-7b")
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    page_size, maxp = 4, 4
    length = maxp * page_size
    lens = [3, 7, 12]
    b = len(lens)
    pools, pt = _paged_setup(cfg, b, maxp, page_size)
    prompts = [jnp.asarray(rng.integers(1, cfg.vocab_size, (1, L)), jnp.int32) for L in lens]
    fn = jax.jit(functools.partial(paged_step, cfg))
    for i, p in enumerate(prompts):  # fill each slot token-by-token
        for t in range(lens[i]):
            _, pools = fn(params, p[:, t : t + 1], pools, pt[i : i + 1], jnp.asarray([t], jnp.int32))
    nxt = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, 1)), jnp.int32)
    joint, _ = fn(params, nxt, pools, pt, jnp.asarray(lens, jnp.int32))
    for i, (p, L) in enumerate(zip(prompts, lens)):
        # (a) bitwise: same step with every other row idled to the trash page
        solo_toks = np.zeros((b, 1), np.int32)
        solo_toks[i] = np.asarray(nxt[i])
        solo_pt = np.zeros_like(np.asarray(pt))
        solo_pt[i] = np.asarray(pt[i])
        solo_lens = np.zeros((b,), np.int32)
        solo_lens[i] = L
        solo, _ = fn(params, jnp.asarray(solo_toks), pools, jnp.asarray(solo_pt), jnp.asarray(solo_lens))
        np.testing.assert_array_equal(np.asarray(solo[i, 0]), np.asarray(joint[i, 0]), err_msg=f"row {i}")
        # (b) numeric anchor: per-row dense oracle at B=1
        dcaches = _dense_empty_caches(cfg, 1, length)
        dfn = jax.jit(functools.partial(decode_step, cfg))
        for t in range(L):
            _, dcaches = dfn(params, p[:, t : t + 1], dcaches, jnp.asarray(t, jnp.int32))
        ld, _ = dfn(params, nxt[i : i + 1], dcaches, jnp.asarray(L, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(ld[0, 0]), np.asarray(joint[i, 0]), atol=1e-5, rtol=1e-4, err_msg=f"row {i}"
        )


# -- kernel parity (interpret mode) -----------------------------------------


def test_append_kernel_interpret_parity(rng):
    pool = jnp.zeros((9, 8, 2, 16), jnp.float32)  # page_size 8 → kernel-eligible
    new = jnp.asarray(rng.normal(size=(3, 2, 16)), jnp.float32)
    pt = jnp.asarray(rng.permutation(8)[:6].reshape(3, 2) + 1, jnp.int32)
    lens = jnp.asarray([0, 5, 13], jnp.int32)
    want = pa_ref.paged_append(pool, new[:, None], pt, lens)
    got = pa_kernel.paged_append_decode(
        jnp.pad(pool, ((0, 0), (0, 0), (0, 0), (0, 112))),
        jnp.pad(new, ((0, 0), (0, 0), (0, 112))),
        pt, lens, interpret=True,
    )[..., :16]
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.parametrize("window", [None, 10])
def test_attend_kernel_interpret_parity(window, rng):
    s, kv, g, d, page, maxp = 3, 2, 4, 16, 8, 3
    pool_k = jnp.asarray(rng.normal(size=(s * maxp + 1, page, kv, d)), jnp.float32)
    pool_v = jnp.asarray(rng.normal(size=pool_k.shape), jnp.float32)
    pt = jnp.arange(1, s * maxp + 1, dtype=jnp.int32).reshape(s, maxp)
    lens = jnp.asarray([2, 11, 23], jnp.int32)
    q = jnp.asarray(rng.normal(size=(s, 1, kv * g, d)), jnp.float32)
    want = pa_ref.paged_attend_gqa(q, pool_k, pool_v, pt, lens, window=window)
    qk = jnp.pad(q.reshape(s, kv, g, d), ((0, 0), (0, 0), (0, 4), (0, 112)))
    got = pa_kernel.paged_attend_decode(
        qk,
        jnp.pad(pool_k, ((0, 0), (0, 0), (0, 0), (0, 112))),
        jnp.pad(pool_v, ((0, 0), (0, 0), (0, 0), (0, 112))),
        pt, lens, window=window, interpret=True,
    )[:, :, :g, :d].reshape(s, 1, kv * g, d)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got), atol=2e-6, rtol=2e-6)


def test_ops_route_to_kernel_when_forced(rng):
    """force_pallas() exercises the dispatch layer end-to-end in interpret
    mode on CPU: results must agree with the reference within kernel tolerance."""
    from repro.kernels.paged_attn import ops

    pool = jnp.asarray(rng.normal(size=(7, 8, 2, 16)), jnp.float32)
    new = jnp.asarray(rng.normal(size=(2, 1, 2, 16)), jnp.float32)
    pt = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    lens = jnp.asarray([4, 17], jnp.int32)
    q = jnp.asarray(rng.normal(size=(2, 1, 8, 16)), jnp.float32)
    ref_pool = pa_ref.paged_append(pool, new, pt, lens)
    ref_out = pa_ref.paged_attend_gqa(q, ref_pool, ref_pool, pt, lens, window=None)
    with flags.force_pallas():
        assert flags.use_pallas() and flags.interpret_mode()
        k_pool = ops.paged_append(pool, new, pt, lens)
        k_out = ops.paged_attend_gqa(q, k_pool, k_pool, pt, lens, window=None)
    np.testing.assert_array_equal(np.asarray(ref_pool), np.asarray(k_pool))
    np.testing.assert_allclose(np.asarray(ref_out), np.asarray(k_out), atol=2e-6, rtol=2e-6)


# -- structural: no full-cache copies on the decode path ---------------------


def _walk_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):  # closed sub-jaxpr (scan/cond/jit bodies)
                yield from _walk_eqns(v.jaxpr)
            elif isinstance(v, (list, tuple)):
                for u in v:
                    if hasattr(u, "jaxpr"):
                        yield from _walk_eqns(u.jaxpr)


def test_paged_decode_jaxpr_has_no_cache_growth(rng):
    """The structural pin behind the perf claim: the paged decode program
    contains no pad/concatenate producing a cache-sized array — appends are
    O(tokens) scatters, unlike the `_grow_all` pad-chain it replaces."""
    cfg = _cfg("qwen2-7b")
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    pools, pt = _paged_setup(cfg, 2, 4, 8)
    toks = jnp.zeros((2, 1), jnp.int32)
    lens = jnp.zeros((2,), jnp.int32)
    pool_leaf_bytes = min(l.size * l.dtype.itemsize for l in jax.tree.leaves(pools))
    jaxpr = jax.make_jaxpr(functools.partial(paged_step, cfg))(params, toks, pools, pt, lens)
    grow = [
        e
        for e in _walk_eqns(jaxpr.jaxpr)
        if e.primitive.name in ("pad", "concatenate")
        and any(o.aval.size * o.aval.dtype.itemsize >= pool_leaf_bytes for o in e.outvars)
    ]
    assert not grow, f"cache-sized {[e.primitive.name for e in grow]} on the paged decode path"
    # and the appends are there: scatter into the pool
    assert any(e.primitive.name.startswith("scatter") for e in _walk_eqns(jaxpr.jaxpr))


# -- reference-op unit coverage ---------------------------------------------


def test_paged_gather_reconstructs_position_order(rng):
    pool = jnp.asarray(rng.normal(size=(5, 4, 3)), jnp.float32)
    pt = jnp.asarray([[2, 4], [1, 3]], jnp.int32)
    g = pa_ref.paged_gather(pool, pt)
    assert g.shape == (2, 8, 3)
    np.testing.assert_array_equal(np.asarray(g[0, :4]), np.asarray(pool[2]))
    np.testing.assert_array_equal(np.asarray(g[1, 4:]), np.asarray(pool[3]))


def test_append_targets_clamps_past_table_end():
    pt = jnp.asarray([[3, 7]], jnp.int32)
    page_ids, offsets = pa_ref.append_targets(pt, jnp.asarray([6], jnp.int32), 4, 4)
    # positions 6..9: page 1 (slots 2,3), then clamped to last page (slots 0,1)
    np.testing.assert_array_equal(np.asarray(page_ids[0]), [7, 7, 7, 7])
    np.testing.assert_array_equal(np.asarray(offsets[0]), [2, 3, 0, 1])
