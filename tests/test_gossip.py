"""Push-sum/gossip CommStrategy family (ISSUE 8): topology matrix algebra,
golden parity of fully-connected gossip against the existing membership-
weighted boundary (bitwise, packed AND per-leaf), push-weight mass
conservation under elastic membership, ring consensus on a constant-
disagreement plane, jaxpr launch/collective budgets, and end-to-end
Experiment smoke (including faults through the gossip anchor)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import AlgoConfig
from repro.core import make_strategy
from repro.core.topology import TOPOLOGIES, cached_topology, compose_membership, make_topology
from repro.fault import FaultPlan, from_mask
from repro.parallel.packing import pack, unpack

from conftest import unpack_view as _unp
from test_strategies import _boundary_jaxpr, _count_primitives, _leafy_params, _quad_batches

M = 4


# -- topology matrices --------------------------------------------------------


@pytest.mark.parametrize("name", TOPOLOGIES)
@pytest.mark.parametrize("m", [1, 2, 3, 4, 5, 8])
def test_topology_doubly_stochastic_fully_live(name, m):
    """Every family is column-stochastic by contract and doubly stochastic
    fully live (so push weights sit at their fixed point w ≡ 1), with
    self-loops in every phase."""
    topo = make_topology(name, m)
    for l in range(topo.num_phases):
        P = topo.matrix(l)
        np.testing.assert_allclose(P.sum(axis=0), 1.0, atol=1e-6)  # column
        np.testing.assert_allclose(P.sum(axis=1), 1.0, atol=1e-6)  # row
        assert (np.diag(P) > 0).all(), (name, m, l)


def test_topology_degrees():
    assert make_topology("full", 8).degree == 7
    assert make_topology("ring", 8).degree == 2
    assert make_topology("exp", 8).degree == 1  # one peer per phase
    assert make_topology("exp", 8).num_phases == 3  # log2(8) hypercube dims
    # ring degenerates to full below 3 workers
    assert make_topology("ring", 2).degree == 1


def test_topology_errors_and_cache():
    with pytest.raises(ValueError):
        make_topology("torus", 4)
    with pytest.raises(ValueError):
        make_topology("ring", 0)
    assert cached_topology("ring", 8) is cached_topology("ring", 8)


def test_compose_membership_renormalizes_columns():
    """Dead workers neither send nor receive; live columns stay stochastic
    over the surviving rows; the full matrix composed with a mask has rows
    that ARE the renormalized Membership weights."""
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    for name in TOPOLOGIES:
        P = make_topology(name, 4).matrix(0)
        Pm = np.asarray(compose_membership(P, mask))
        assert (Pm[1] == 0).all() and (Pm[:, 1] == 0).all()
        np.testing.assert_allclose(Pm[:, [0, 2, 3]].sum(axis=0), 1.0, atol=1e-6)
    Pf = np.asarray(compose_membership(make_topology("full", 4).matrix(0), mask))
    mem = from_mask(np.asarray(mask, np.float32))
    for i in (0, 2, 3):
        np.testing.assert_allclose(Pf[i], np.asarray(mem.weights), atol=1e-7)


# -- golden parity: fully-connected gossip ≡ the existing boundary ------------


def _stacked(rng, params):
    return jax.tree.map(lambda t: jnp.asarray(rng.normal(size=(M,) + t.shape), t.dtype), params)


@pytest.mark.parametrize("packed", [True, False], ids=["packed", "perleaf"])
@pytest.mark.parametrize("masked", [False, True], ids=["live", "masked"])
def test_gossip_full_boundary_bitwise_matches_overlap(rng, packed, masked):
    """ISSUE acceptance: the degenerate fully-connected gossip boundary
    reproduces the existing membership-weighted masked worker mean bit for
    bit — x and the launched collective — on a mixed f32/bf16 plane."""
    params = {
        "w16": jnp.asarray(rng.normal(size=(17, 33)), jnp.bfloat16),
        "w32": jnp.asarray(rng.normal(size=(9, 11)), jnp.float32),
        "b16": jnp.asarray(rng.normal(size=(257,)), jnp.bfloat16),
        "s": jnp.float32(rng.normal()),
    }
    x = _stacked(rng, params)
    mem = from_mask(np.array([1.0, 0.0, 1.0, 1.0], np.float32)) if masked else None
    outs = []
    for name in ("gossip_full", "overlap_local_sgd"):
        cfg = AlgoConfig(name=name, tau=2, alpha=0.6, anchor_beta=0.0, packed=packed)
        strat = make_strategy(cfg)
        xx = pack(x, lead=1) if packed else x
        vars_ = strat.init_vars(xx, None)
        infl = strat.init_inflight(xx, vars_, None)
        for _ in range(3):
            xx, vars_, infl = strat.boundary_round(xx, vars_, infl, None, membership=mem)
        outs.append((_unp(xx), _unp(infl)))
    for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("opt_name", ["sgd", "adamw"])
def test_gossip_full_training_bitwise_matches_overlap(opt_name):
    """Full round programs (local steps + boundary) under {sgd, adamw}:
    gossip_full trains bit-for-bit identically to overlap_local_sgd(β=0)."""
    from repro.optim import adamw, schedules, sgd
    from repro.training import make_round_step, make_train_state
    from test_strategies import quad_loss

    opt = sgd(momentum=0.9, nesterov=True) if opt_name == "sgd" else adamw(b1=0.9, b2=0.95)
    tau = 3
    params = {"x": jnp.asarray(np.random.default_rng(0).normal(size=6), jnp.float32)}
    states, steps = [], []
    for name in ("gossip_full", "overlap_local_sgd"):
        cfg = AlgoConfig(name=name, tau=tau, alpha=0.6, anchor_beta=0.0, packed=True)
        strat = make_strategy(cfg)
        states.append(make_train_state(params, M, opt, strat, None))
        steps.append(jax.jit(make_round_step(quad_loss, opt, strat, schedules.constant(0.05), None)))
    rng = np.random.default_rng(1)
    for _ in range(4):
        batch = _quad_batches(rng, tau)
        states = [step(s, batch)[0] for step, s in zip(steps, states)]
    s_g, s_o = states
    np.testing.assert_array_equal(np.asarray(_unp(s_g.x)["x"]), np.asarray(_unp(s_o.x)["x"]))
    np.testing.assert_array_equal(
        np.asarray(_unp(s_g.inflight)["x"]), np.asarray(_unp(s_o.inflight)["x"])
    )


# -- sparse topologies: packed ≡ per-leaf, mass conservation, consensus -------


@pytest.mark.parametrize("name", ["gossip_ring", "gossip_exp"])
@pytest.mark.parametrize("masked", [False, True], ids=["live", "masked"])
def test_gossip_sparse_packed_matches_perleaf(rng, name, masked):
    """The packed sparse-gossip boundary (per-bucket anchor_mix + one plane
    matmul) is bitwise-identical to the per-leaf einsum oracle — x, push
    weights, and the launched mix — masked and unmasked."""
    x = _stacked(rng, _leafy_params(rng))
    mem = from_mask(np.array([1.0, 0.0, 1.0, 1.0], np.float32)) if masked else None
    outs = []
    for packed in (True, False):
        cfg = AlgoConfig(name=name, tau=2, alpha=0.6, packed=packed)
        strat = make_strategy(cfg)
        xx = pack(x, lead=1) if packed else x
        vars_ = strat.init_vars(xx, None)
        infl = strat.init_inflight(xx, vars_, None)
        for _ in range(3):
            xx, vars_, infl = strat.boundary_round(xx, vars_, infl, None, membership=mem)
        outs.append((_unp(xx), np.asarray(vars_.extra[0]), _unp(infl.mix), np.asarray(infl.w)))
    for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # dead worker's row passes through the boundary untouched
    if masked:
        for before, after in zip(jax.tree.leaves(x), jax.tree.leaves(outs[1][0])):
            np.testing.assert_array_equal(np.asarray(before)[1], np.asarray(after)[1])


def test_push_weight_mass_conservation():
    """Column-stochasticity conserves total push-weight mass. Fully live the
    exp weights stay EXACTLY 1 (entries are binary fractions); under a fixed
    membership the live mass stays exactly at the live count."""
    x = {"w": jnp.asarray(np.arange(M * 8, dtype=np.float32).reshape(M, 8))}
    for name, exact in (("gossip_exp", True), ("gossip_ring", False)):
        strat = make_strategy(AlgoConfig(name=name, tau=1, alpha=0.6))
        vars_ = strat.init_vars(x, None)
        infl = strat.init_inflight(x, vars_, None)
        xx = x
        for _ in range(6):
            xx, vars_, infl = strat.boundary_round(xx, vars_, infl, None)
        np.testing.assert_array_equal(np.asarray(vars_.extra[0]), 1.0)  # fixed point
        assert int(vars_.extra[1]) == 6  # phase counter advanced

        mem = from_mask(np.array([1.0, 0.0, 1.0, 1.0], np.float32))
        vars_ = strat.init_vars(x, None)
        infl = strat.init_inflight(x, vars_, None)
        xx = x
        for _ in range(6):
            xx, vars_, infl = strat.boundary_round(xx, vars_, infl, None, membership=mem)
        w = np.asarray(vars_.extra[0])
        assert w[1] == 1.0  # dead worker's weight frozen
        live_mass = float(w[[0, 2, 3]].sum())
        if exact:
            assert live_mass == 3.0, w  # exact in f32: binary-fraction matrix
        else:
            np.testing.assert_allclose(live_mass, 3.0, rtol=1e-6)


@pytest.mark.parametrize("name", ["gossip_ring", "gossip_exp"])
def test_gossip_reaches_consensus_on_constant_disagreement(name):
    """Worker i starts at the constant plane x_i ≡ i; repeated gossip with
    α=1 must contract the disagreement to ~0 while preserving the mean
    (doubly stochastic mixing)."""
    m = 8
    x = {"w": jnp.tile(jnp.arange(m, dtype=jnp.float32)[:, None], (1, 16))}
    strat = make_strategy(AlgoConfig(name=name, tau=1, alpha=1.0))
    vars_ = strat.init_vars(x, None)
    infl = strat.init_inflight(x, vars_, None)
    for _ in range(60):
        x, vars_, infl = strat.boundary_round(x, vars_, infl, None)
    w = np.asarray(x["w"])
    np.testing.assert_allclose(w.mean(), 3.5, rtol=1e-5)  # mean preserved
    assert w.std() < 1e-3, w.std()  # disagreement contracted ~to consensus


# -- jaxpr launch/collective budgets ------------------------------------------


def test_gossip_full_packed_budget(rng):
    """The degenerate full topology keeps Overlap-Local-SGD's exact packed
    budget: ONE fused pullback+mean kernel launch, ONE worker-mean reduce."""
    params = _leafy_params(rng)
    cfg = AlgoConfig(name="gossip_full", tau=2, alpha=0.6, packed=True)
    n_pallas = _count_primitives(_boundary_jaxpr(cfg, params, force_pallas=True).jaxpr, ["pallas_call"])
    assert n_pallas["pallas_call"] == 1, n_pallas
    n_red = _count_primitives(_boundary_jaxpr(cfg, params, force_pallas=False).jaxpr, ["reduce_sum"])
    assert n_red["reduce_sum"] == 1, n_red


def test_gossip_sparse_packed_budget(rng):
    """Sparse gossip on the packed plane: one anchor_mix kernel launch per
    dtype bucket (here: one) and ONE (m, m) × plane matmul for the push —
    the collective payload is the mix plane, independent of leaf count."""
    params = _leafy_params(rng)  # one f32 bucket, 14 leaves
    for name in ("gossip_ring", "gossip_exp"):
        cfg = AlgoConfig(name=name, tau=2, alpha=0.6, packed=True)
        jp = _boundary_jaxpr(cfg, params, force_pallas=True)
        counts = _count_primitives(jp.jaxpr, ["pallas_call", "dot_general"])
        assert counts["pallas_call"] == 1, (name, counts)
        assert counts["dot_general"] == 1, (name, counts)


# -- registry / config plumbing -----------------------------------------------


def test_gossip_registry_and_aliases():
    from repro.core.strategy import STRATEGIES

    for name in ("gossip_pushsum", "gossip_full", "gossip_ring", "gossip_exp"):
        assert name in STRATEGIES
    assert make_strategy(AlgoConfig(name="sgp")).name == "gossip_pushsum"
    # gossip_pushsum reads cfg.topology; fixed-name registry entries pin it
    assert make_strategy(AlgoConfig(name="gossip_pushsum", topology="ring")).topo_name == "ring"
    assert make_strategy(AlgoConfig(name="gossip_exp", topology="ring")).topo_name == "exp"
    bad = make_strategy(AlgoConfig(name="gossip_pushsum", topology="torus"))
    x = {"w": jnp.zeros((4, 2))}
    with pytest.raises(ValueError, match="unknown topology"):
        bad.boundary_launch(x, bad.init_vars(x, None))


# -- end-to-end Experiment smoke ----------------------------------------------


def test_gossip_experiment_converges_with_faults():
    """A gossip_ring Experiment trains to completion under a crash/rejoin
    plan: the harness re-syncs the rejoining worker from the gossip
    inflight's mass-weighted consensus (Σ mix / Σ w) and the loss improves."""
    from repro.api import Experiment

    plan = FaultPlan.parse("crash:1@2-4", m=M, seed=0)
    exp = Experiment(workers=M, strategy=AlgoConfig(name="gossip_ring", tau=2, alpha=0.6), seed=0)
    res = exp.fit(rounds=8, faults=plan)
    assert np.isfinite(res.losses).all() and res.losses[-1] < res.losses[0]
    by_round = {rec["round"]: rec for rec in res.fault_log}
    assert by_round[4]["resynced"] == [1]
