"""Continuous-batching scheduler + paged engine behaviour (DESIGN.md §10).

Covers the scheduler contract (deterministic replay, FIFO admission,
evict-requeue under pool exhaustion, no page leaks), the engine-level
exactness guarantee (per-request outputs equal solo ``generate`` regardless
of co-batching — the regression pin for the old left-padded ``run()``), and
plane hot-swap under load (swap applies only at step boundaries; continuing
on the new plane is bitwise-equal to restarting the in-flight state on it).
"""
import copy
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch
from repro.models import transformer as T
from repro.serving import BatchedEngine, PageAllocator, Request, Scheduler, generate


def _cfg(arch_name="qwen2-7b", dtype="float32"):
    return dataclasses.replace(get_arch(arch_name).model.reduced(), dtype=dtype)


@pytest.fixture(scope="module")
def qwen_setup():
    cfg = _cfg()
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _trace(rng, n, vmax, lp=(3, 20), mn=(2, 9)):
    return [
        (f"r{i}", rng.integers(1, vmax, (int(rng.integers(*lp)),)).astype(np.int32), int(rng.integers(*mn)))
        for i in range(n)
    ]


# -- allocator ---------------------------------------------------------------


def test_allocator_never_issues_trash_page_and_detects_double_free():
    al = PageAllocator(5)
    assert al.capacity == 4
    got = al.alloc(4)
    assert 0 not in got and al.alloc(1) is None
    al.free([got[0]])
    with pytest.raises(ValueError, match="double free"):
        al.free([got[0]])
    with pytest.raises(ValueError, match="invalid page"):
        al.free([0])


def test_allocator_partial_requests_never_granted():
    al = PageAllocator(4)
    assert al.alloc(5) is None  # nothing handed out
    assert al.available == 3


# -- scheduler ---------------------------------------------------------------


def test_scheduler_rejects_request_that_can_never_fit():
    s = Scheduler(slots=2, num_pages=4, page_size=4, max_pages_per_slot=3)
    with pytest.raises(ValueError, match="needs"):
        s.submit(Request("big", np.ones(12, np.int32), 4))  # 15 tokens → 4 pages > capacity 3


def test_scheduler_admits_fifo_and_stops_at_first_nonfit():
    s = Scheduler(slots=3, num_pages=4, page_size=4, max_pages_per_slot=4)  # capacity 3
    s.submit(Request("a", np.ones(4, np.int32), 2))  # prefill 1 page + 1 headroom ≤ 3
    s.submit(Request("b", np.ones(10, np.int32), 2))  # prefill 3 pages + 1 > 3 — can't admit yet
    s.submit(Request("c", np.ones(2, np.int32), 2))  # would fit, but FIFO blocks behind b
    s.admit()
    assert [e for e in s.events if e[0] == "admit"] == [("admit", "a", 0)]
    assert [r.rid for r in s.queue] == ["b", "c"]


def test_scheduler_eviction_requeues_youngest_never_oldest():
    s = Scheduler(slots=2, num_pages=4, page_size=4, max_pages_per_slot=3)  # capacity 3
    s.submit(Request("old", np.ones(4, np.int32), 9))
    s.submit(Request("young", np.ones(4, np.int32), 9))
    s.admit()
    assert s.ensure_pages(0, 3) and s.ensure_pages(1, 3)  # 1 page each, 1 free
    assert s.ensure_pages(0, 11)  # old grows to 3 pages → pool exhausted...
    # ...but 'young' was evicted (not 'old'), and its request is back in front
    assert ("evict", "young", 1) in s.events
    assert [r.rid for r in s.queue] == ["young"]
    assert s.active[1] is None and s.active[0].req.rid == "old"
    # growing the survivor returns False only when it evicts itself — here it fit
    s.complete(0)
    assert s.alloc.available == s.alloc.capacity  # everything returned


def test_scheduler_replay_is_deterministic(qwen_setup, rng):
    cfg, params = qwen_setup

    def run_once():
        eng = BatchedEngine(cfg, params, slots=2, max_len=24, page_size=4, num_pages=9, chunk=8)
        trace = _trace(np.random.default_rng(42), 6, cfg.vocab_size, lp=(3, 14), mn=(2, 6))
        for rid, prompt, mn in trace[:4]:
            eng.submit(rid, prompt, mn)
        steps = 0
        while eng.sched.busy:
            eng.step()
            steps += 1
            if steps == 2:  # mid-run arrivals at a fixed step index
                for rid, prompt, mn in trace[4:]:
                    eng.submit(rid, prompt, mn)
        return eng.sched.events, {k: v.tolist() for k, v in eng.results.items()}, eng

    ev1, res1, _ = run_once()
    ev2, res2, eng = run_once()
    assert ev1 == ev2
    assert res1 == res2
    assert eng.sched.alloc.available == eng.sched.alloc.capacity  # no page leak


# -- engine exactness (the padded-batch regression pin) ----------------------


def test_cobatched_outputs_equal_solo_generate(qwen_setup, rng):
    """Ragged prompts and ragged max_new co-batched through the paged engine
    reproduce each request's solo ``generate`` exactly. The old engine
    left-padded prompts as attended tokens and decoded max(max_new) steps
    for everyone — either bug breaks this equality."""
    cfg, params = qwen_setup
    eng = BatchedEngine(cfg, params, slots=3, max_len=48, page_size=8, chunk=8)
    trace = _trace(rng, 6, cfg.vocab_size, lp=(3, 30), mn=(2, 8))
    for rid, prompt, mn in trace:
        eng.submit(rid, prompt, mn)
    res = eng.run()
    for rid, prompt, mn in trace:
        solo = np.asarray(generate(cfg, params, jnp.asarray(prompt)[None], mn)[0])
        np.testing.assert_array_equal(solo, res[rid], err_msg=rid)
        assert len(res[rid]) == mn  # per-request max_new, not max over the batch


def test_dense_fallback_is_exact_per_request(rng):
    """Recurrent archs (no pages to manage) fall back to solo decoding —
    also exact, also per-request max_new."""
    cfg = _cfg("rwkv6-7b")
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    eng = BatchedEngine(cfg, params, slots=2, max_len=32)
    assert not eng.paged
    trace = _trace(rng, 3, cfg.vocab_size, lp=(3, 10), mn=(2, 6))
    for rid, prompt, mn in trace:
        eng.submit(rid, prompt, mn)
    res = eng.run()
    for rid, prompt, mn in trace:
        solo = np.asarray(generate(cfg, params, jnp.asarray(prompt)[None], mn)[0])
        np.testing.assert_array_equal(solo, res[rid], err_msg=rid)


def test_eviction_under_exhaustion_completes_all_requests(qwen_setup, rng):
    """Pool too small for co-residency: requests must evict+requeue (never
    drop) and still produce exact outputs."""
    cfg, params = qwen_setup
    eng = BatchedEngine(cfg, params, slots=2, max_len=16, page_size=4, num_pages=6, chunk=8)
    trace = _trace(rng, 3, cfg.vocab_size, lp=(8, 9), mn=(8, 9))
    for rid, prompt, mn in trace:
        eng.submit(rid, prompt, mn)
    res = eng.run()
    assert any(e[0] == "evict" for e in eng.sched.events)
    assert sorted(res) == sorted(r for r, _, _ in trace)  # nothing dropped
    for rid, prompt, mn in trace:
        solo = np.asarray(generate(cfg, params, jnp.asarray(prompt)[None], mn)[0])
        np.testing.assert_array_equal(solo, res[rid], err_msg=rid)
    assert eng.sched.alloc.available == eng.sched.alloc.capacity


def test_stop_token_frees_slot_early(qwen_setup, rng):
    cfg, params = qwen_setup
    prompt = rng.integers(1, cfg.vocab_size, (6,)).astype(np.int32)
    free = generate(cfg, params, jnp.asarray(prompt)[None], 8)[0]
    stop = int(free[2])  # force an early stop at the 3rd generated token
    eng = BatchedEngine(cfg, params, slots=2, max_len=32, page_size=8)
    eng.submit("s", prompt, 8, stop=stop)
    res = eng.run()
    np.testing.assert_array_equal(np.asarray(free[:3]), res["s"])
    assert eng.sched.alloc.available == eng.sched.alloc.capacity


def test_submit_validations(qwen_setup):
    cfg, params = qwen_setup
    eng = BatchedEngine(cfg, params, slots=2, max_len=16, page_size=4)
    eng.submit("a", np.ones(4, np.int32), 2)
    with pytest.raises(ValueError, match="duplicate"):
        eng.submit("a", np.ones(4, np.int32), 2)
    with pytest.raises(ValueError, match="non-empty 1-D"):
        eng.submit("b", np.ones((2, 2), np.int32), 2)
    with pytest.raises(ValueError, match="max_new"):
        eng.submit("c", np.ones(4, np.int32), 0)
    with pytest.raises(ValueError, match="exceeds"):
        eng.submit("d", np.ones(14, np.int32), 8)


# -- plane hot-swap under load ----------------------------------------------


def _plane_pair():
    from repro.api import Experiment

    exp = Experiment(arch="qwen2-7b", strategy="overlap_local_sgd", workers=2, rounds=1)
    exp.fit()
    return exp, exp.consensus_plane(), exp.anchor_plane()


def test_swap_plane_applies_at_step_boundary_only(rng):
    """Tokens decoded before the swap boundary are identical to a no-swap
    run; the served plane object is unchanged until the next step() call."""
    exp, plane1, plane2 = _plane_pair()
    cfg = exp.model_cfg
    prompt = rng.integers(1, cfg.vocab_size, (6,)).astype(np.int32)

    def engine():
        e = BatchedEngine(cfg, plane1, slots=2, max_len=32, page_size=8)
        e.submit("x", prompt, 8)
        return e

    base = engine()
    base.run()
    swp = engine()
    for _ in range(4):  # chunked prefill + first decode steps on plane1
        swp.step()
    swp.swap_plane(plane2)
    assert swp.plane is plane1  # pending — never applied mid-stream
    pre_swap = list(next(a for a in swp.sched.active if a is not None).generated)
    np.testing.assert_array_equal(base.results["x"][: len(pre_swap)], pre_swap)
    res = swp.run()
    assert swp.plane is plane2  # zero-copy: the exact object is now served
    assert len(res["x"]) == 8


def test_swap_under_load_bitwise_equals_restart_on_new_plane(rng):
    """The acceptance pin: swap_plane on a live engine with in-flight
    requests must produce exactly the tokens a fresh engine on the new plane
    would produce when handed the same mid-flight state (pools, page tables,
    scheduler bookkeeping)."""
    exp, plane1, plane2 = _plane_pair()
    cfg = exp.model_cfg
    gen = np.random.default_rng(7)
    live = exp.serve(slots=2, max_len=32, page_size=8)
    for i in range(3):
        live.submit(f"r{i}", gen.integers(1, cfg.vocab_size, (5 + 3 * i,)).astype(np.int32), 6)
    for _ in range(5):
        live.step()
    # snapshot the in-flight state at the boundary, then swap
    control = exp.serve(slots=2, max_len=32, page_size=8)
    control.swap_plane(plane2)
    control.pools = live.pools  # device arrays are immutable — safe to share
    control.sched = copy.deepcopy(live.sched)
    control.results = {k: v.copy() for k, v in live.results.items()}
    live.swap_plane(plane2)
    res_live = live.run()
    res_ctrl = control.run()
    assert sorted(res_live) == sorted(res_ctrl)
    for rid in res_live:
        np.testing.assert_array_equal(res_live[rid], res_ctrl[rid], err_msg=rid)


def test_live_fit_anchor_plane_swap_is_zero_copy(rng):
    """Serving a training run's anchor: fit → serve → fit more → swap the
    fresh anchor in. The engine serves the trainer's plane buffers by
    reference at every point — no copy is ever made."""
    from repro.api import Experiment

    exp = Experiment(arch="qwen2-7b", strategy="overlap_local_sgd", workers=2, rounds=1)
    exp.fit()
    eng = exp.serve(slots=2, max_len=32, page_size=8)
    cfg = exp.model_cfg
    eng.submit("a", rng.integers(1, cfg.vocab_size, (6,)).astype(np.int32), 4)
    eng.step()
    exp.fit()  # the anchor advances under the live engine
    z = exp.anchor_plane()
    assert all(a is b for a, b in zip(z.buffers, exp.state.vars.z.buffers))  # no copy out of state
    eng.swap_plane(z)
    res = eng.run()
    assert len(res["a"]) == 4
    assert all(a is b for a, b in zip(eng.plane.buffers, z.buffers))  # no copy into the engine
