"""Per-kernel correctness: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flags
from repro.kernels.anchor_mix import ops as am_ops
from repro.kernels.anchor_mix import ref as am_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.rmsnorm import ops as rms_ops
from repro.kernels.rmsnorm import ref as rms_ref
from repro.kernels.rwkv6_wkv import ops as wkv_ops
from repro.kernels.rwkv6_wkv import ref as wkv_ref
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.kernels.ssd_scan import ref as ssd_ref


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=4e-4, atol=4e-4)


@pytest.mark.parametrize("rows,d", [(8, 64), (33, 128), (128, 300), (1, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(rng, rows, d, dtype):
    x = jnp.asarray(rng.normal(size=(rows, d)), dtype)
    s = jnp.asarray(rng.normal(size=(d,)), dtype)
    with flags.force_pallas():
        out = rms_ops.rmsnorm(x, s)
    ref = rms_ref.rmsnorm(x, s)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype))


@pytest.mark.parametrize(
    "b,sq,sk,h,hkv,d,causal,window",
    [
        (2, 64, 64, 4, 2, 32, True, None),
        (1, 130, 130, 4, 4, 64, True, None),  # non-multiple of block
        (2, 64, 64, 8, 2, 32, True, 16),  # sliding window
        (1, 64, 64, 2, 1, 32, False, None),  # bidirectional
        (2, 1, 96, 4, 2, 32, True, None),  # single query vs cache
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(rng, b, sq, sk, h, hkv, d, causal, window, dtype):
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, sk, hkv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, sk, hkv, d)), dtype)
    q_off = sk - sq if sq < sk else 0
    out = fa_ops.flash_attention(q, k, v, causal, window, q_off)
    ref = fa_ref.mha_reference(q, k, v, causal=causal, window=window, q_offset=q_off)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype))


@pytest.mark.parametrize("block_q,block_k", [(16, 16), (32, 64), (1024, 1024)])
def test_chunked_mha_blocks(rng, block_q, block_k):
    q = jnp.asarray(rng.normal(size=(2, 70, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 70, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 70, 2, 16)), jnp.float32)
    out = fa_ref.chunked_mha(q, k, v, block_q=block_q, block_k=block_k)
    ref = fa_ref.mha_reference(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=4e-4, atol=4e-4)


def test_flash_attention_grads_match_reference(rng):
    q = jnp.asarray(rng.normal(size=(1, 32, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 32, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 32, 2, 16)), jnp.float32)
    g1 = jax.grad(lambda *a: fa_ops.flash_attention(*a).sum(), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: fa_ref.mha_reference(*a).sum(), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [(2, 32, 4, 8, 2, 5, 8), (1, 37, 2, 16, 1, 8, 16), (2, 64, 4, 8, 4, 4, 64)])
def test_ssd_kernel_sweep(rng, b, s, h, p, g, n, chunk):
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(b, s, h))) * 0.5, jnp.float32)
    A = jnp.asarray(-np.abs(rng.normal(size=(h,))), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(h,)), jnp.float32)
    y_ref, s_ref = ssd_ref.ssd_reference(x, dt, A, B, C, D)
    y_chunk, s_chunk = ssd_ref.ssd_chunked(x, dt, A, B, C, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_ref), rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(s_chunk), np.asarray(s_ref), rtol=5e-3, atol=5e-3)
    if s % chunk == 0:
        with flags.force_pallas():
            y_pal, s_pal = ssd_ops.ssd_scan(x, dt, A, B, C, D, chunk)
        np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref), rtol=5e-4, atol=5e-4)
        np.testing.assert_allclose(np.asarray(s_pal), np.asarray(s_ref), rtol=5e-3, atol=5e-3)


def test_ssd_decode_step_matches_reference(rng):
    b, s, h, p, g, n = 1, 9, 2, 4, 1, 3
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(b, s, h))) * 0.5, jnp.float32)
    A = jnp.asarray(-np.abs(rng.normal(size=(h,))), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(h,)), jnp.float32)
    y_ref, _ = ssd_ref.ssd_reference(x, dt, A, B, C, D)
    state = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        y, state = ssd_ops.ssd_decode_step(state, x[:, t], dt[:, t], A, B[:, t], C[:, t], D)
        ys.append(y)
    np.testing.assert_allclose(np.stack(ys, 1), np.asarray(y_ref), rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("b,s,h,n,p,chunk", [(2, 24, 3, 8, 6, 8), (1, 45, 2, 16, 16, 16), (2, 32, 4, 8, 8, 32)])
def test_wkv_kernel_sweep(rng, b, s, h, n, p, chunk):
    r = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    w = jnp.asarray(0.2 + 0.79 * rng.random(size=(b, s, h, n)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, n)), jnp.float32)
    y_ref, s_ref = wkv_ref.wkv_reference(r, k, v, w, u)
    y_chunk, s_chunk = wkv_ref.wkv_chunked(r, k, v, w, u, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_ref), rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(s_chunk), np.asarray(s_ref), rtol=5e-3, atol=5e-3)
    if s % chunk == 0:
        with flags.force_pallas():
            y_pal, s_pal = wkv_ops.wkv(r, k, v, w, u, chunk)
        np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref), rtol=5e-4, atol=5e-4)


def test_wkv_decode_step_matches_reference(rng):
    b, s, h, n, p = 1, 7, 2, 4, 4
    r = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    w = jnp.asarray(0.2 + 0.79 * rng.random(size=(b, s, h, n)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, n)), jnp.float32)
    y_ref, _ = wkv_ref.wkv_reference(r, k, v, w, u)
    state = jnp.zeros((b, h, n, p), jnp.float32)
    ys = []
    for t in range(s):
        y, state = wkv_ops.wkv_decode_step(state, r[:, t], k[:, t], v[:, t], w[:, t], u)
        ys.append(y)
    np.testing.assert_allclose(np.stack(ys, 1), np.asarray(y_ref), rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("shape", [(8,), (13, 7), (3, 5, 9), (128, 128)])
@pytest.mark.parametrize("alpha", [0.0, 0.5, 0.6, 1.0])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_anchor_mix_sweep(rng, shape, alpha, dtype):
    x = jnp.asarray(rng.normal(size=shape), dtype)
    z = jnp.asarray(rng.normal(size=shape), dtype)
    with flags.force_pallas():
        out = am_ops.anchor_mix(x, z, alpha)
    ref = am_ref.anchor_mix(x, z, alpha)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype))


def test_pullback_tree(rng):
    x = {"a": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32), "b": jnp.asarray(rng.normal(size=(7,)), jnp.float32)}
    z = jax.tree.map(jnp.zeros_like, x)
    out = am_ops.pullback_tree(x, z, 0.25)
    for k in x:
        np.testing.assert_allclose(np.asarray(out[k]), 0.75 * np.asarray(x[k]), rtol=1e-6)


@pytest.mark.parametrize("m,n", [(2, 128), (4, 384), (3, 257), (8, 1)])
@pytest.mark.parametrize("mean_pre", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pullback_mean_fused_sweep(rng, m, n, mean_pre, dtype):
    """Fused pullback+mean kernel vs oracle, aligned and ragged planes."""
    from repro.kernels.anchor_mix import ops as ops_
    from repro.kernels.anchor_mix import ref as ref_

    x = jnp.asarray(rng.normal(size=(m, n)), dtype)
    z = jnp.asarray(rng.normal(size=(n,)), dtype)
    with flags.force_pallas():
        xn, mean = ops_.pullback_mean(x, z, 0.6, mean_pre=mean_pre)
    xn_r, mean_r = ref_.pullback_mean(x, z, 0.6, mean_pre=mean_pre)
    np.testing.assert_allclose(np.asarray(xn, np.float32), np.asarray(xn_r, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.asarray(mean, np.float32), np.asarray(mean_r, np.float32), **tol(dtype))


@pytest.mark.parametrize("m,n", [(2, 256), (4, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pullback_mean_momentum_fused_sweep(rng, m, n, dtype):
    """Fused pullback+momentum kernel (eqs. 4,10,11 in one pass) vs oracle."""
    from repro.kernels.anchor_mix import ops as ops_
    from repro.kernels.anchor_mix import ref as ref_

    x = jnp.asarray(rng.normal(size=(m, n)), dtype)
    z = jnp.asarray(rng.normal(size=(n,)), dtype)
    v = jnp.asarray(rng.normal(size=(n,)), dtype)
    with flags.force_pallas():
        out = ops_.pullback_mean_momentum(x, z, v, 0.6, 0.7)
    ref_out = ref_.pullback_mean_momentum(x, z, v, 0.6, 0.7)
    for a, b in zip(out, ref_out):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32), **tol(dtype))


def test_anchor_mix_aligned_skips_pad(rng):
    """n % 128 == 0 must not pay the pad+slice round-trip: the traced
    program contains no pad primitive (and stays correct)."""
    x = jnp.asarray(rng.normal(size=(4, 128)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(4, 128)), jnp.float32)
    with flags.force_pallas():
        jaxpr = jax.make_jaxpr(lambda a, b: am_ops.anchor_mix(a, b, 0.5))(x, z)
        out = am_ops.anchor_mix(x, z, 0.5)
    assert "pad" not in [e.primitive.name for e in jaxpr.jaxpr.eqns]
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(am_ref.anchor_mix(x, z, 0.5)), rtol=4e-4, atol=4e-4
    )
    # ragged sizes still pad (and still match the oracle)
    xr = jnp.asarray(rng.normal(size=(7, 13)), jnp.float32)
    zr = jnp.asarray(rng.normal(size=(7, 13)), jnp.float32)
    with flags.force_pallas():
        out_r = am_ops.anchor_mix(xr, zr, 0.5)
    np.testing.assert_allclose(
        np.asarray(out_r), np.asarray(am_ref.anchor_mix(xr, zr, 0.5)), rtol=4e-4, atol=4e-4
    )
