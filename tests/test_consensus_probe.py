"""Consensus-distance probe: differential + launch-budget suite (DESIGN.md §6).

Pins the adaptive-τ controller's measurement path three ways:

1. differential — the packed probe (full-buffer sums over the plane) equals
   the bit-exact per-leaf ``repro.control.consensus_drift`` oracle across
   {f32, bf16} dtype buckets and padded (n % 128 ≠ 0) planes, on both the
   jnp fallback and the Pallas kernels in interpret mode;
2. fusion — ``pullback_mean(_momentum)`` with ``probe=True`` returns the
   same stats AND bitwise-identical boundary math as ``probe=False``, and
   every strategy's probed ``boundary_round`` leaves x/vars/inflight
   untouched relative to the unprobed call;
3. budget — jaxpr ``pallas_call`` counts: the probe adds ZERO launches for
   pullback-family strategies (overlap ± momentum, easgd, sparse_anchor)
   and exactly one launch per dtype bucket for strategies whose boundary
   does not read the plane through the pullback (local_sgd, cocod).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import AlgoConfig
from repro.control import consensus_drift
from repro.core import make_strategy
from repro.kernels import flags
from repro.kernels.anchor_mix import ops as anchor_ops
from repro.kernels.consensus_probe import ops as probe_ops
from repro.kernels.consensus_probe import ref as probe_ref
from repro.kernels.consensus_probe.kernel import probe_block, probe_flat
from repro.optim import schedules, sgd
from repro.parallel.packing import Packed, pack
from repro.training import make_round_step, make_train_state

M = 4


def _stacked_tree(rng, bf16=False):
    """Worker-stacked (M, ...) tree with odd leaf sizes, so every dtype
    bucket ends up lane-padded (total elements % 128 != 0)."""
    mat = jnp.bfloat16 if bf16 else jnp.float32
    return {
        "w0": jnp.asarray(rng.normal(size=(M, 3, 5)), mat),
        "w1": jnp.asarray(rng.normal(size=(M, 4, 6)), mat),
        "vec": jnp.asarray(rng.normal(size=(M, 7)), jnp.float32),
        "scalar": jnp.asarray(rng.normal(size=(M,)), jnp.float32),
    }


def _tol(bf16):
    # bucket sums vs per-leaf sums differ only in f32 summation order
    return dict(rtol=1e-5, atol=1e-6) if not bf16 else dict(rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# kernel parity
# ---------------------------------------------------------------------------


def test_probe_block_picks_lane_aligned_divisor():
    assert probe_block(384, 1 << 13) == 384
    assert probe_block(1024, 256) == 256
    assert probe_block(640, 512) == 128  # largest 128-multiple dividing 640 that is <= 512
    assert probe_block(128, 128) == 128


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n", [128, 384, 1024])
def test_standalone_kernel_matches_ref_interpret(rng, dtype, n):
    x = jnp.asarray(rng.normal(size=(M, n)).astype(np.float32)).astype(dtype)
    d_ref, s_ref = probe_ref.plane_probe(x)
    st = probe_flat(x, block=128, interpret=True)  # multi-block grid accumulation
    np.testing.assert_allclose(float(jnp.sum(st[0])), float(d_ref), rtol=1e-6)
    np.testing.assert_allclose(float(jnp.sum(st[1])), float(s_ref), rtol=1e-6)


def test_probe_buffer_pads_with_zeros(rng):
    # n % 128 != 0: the kernel path pads; zeros must contribute 0 to both sums
    x = jnp.asarray(rng.normal(size=(M, 200)).astype(np.float32))
    d_ref, s_ref = probe_ref.plane_probe(x)
    with flags.force_pallas():
        d_k, s_k = probe_ops.probe_buffer(x)
    np.testing.assert_allclose(float(d_k), float(d_ref), rtol=1e-6)
    np.testing.assert_allclose(float(s_k), float(s_ref), rtol=1e-6)


# ---------------------------------------------------------------------------
# differential: packed probe vs per-leaf oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bf16", [False, True])
@pytest.mark.parametrize("pallas", [False, True])
def test_packed_probe_matches_per_leaf_oracle(rng, bf16, pallas):
    tree = _stacked_tree(rng, bf16)
    d_ref, s_ref = consensus_drift(tree)
    px = pack(tree, lead=1)
    if pallas:
        with flags.force_pallas():
            stats = probe_ops.packed_probe(px)
    else:
        stats = probe_ops.packed_probe(px)
    np.testing.assert_allclose(float(stats.drift), float(d_ref), **_tol(bf16))
    np.testing.assert_allclose(float(stats.scale), float(s_ref), **_tol(bf16))


def test_tree_probe_is_the_oracle(rng):
    tree = _stacked_tree(rng, bf16=True)
    d, s = consensus_drift(tree)
    stats = probe_ops.tree_probe(tree)
    assert float(stats.drift) == float(d) and float(stats.scale) == float(s)


# ---------------------------------------------------------------------------
# fusion: probed boundary kernels change nothing but add the stats
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pallas", [False, True])
def test_fused_pullback_mean_probe(rng, pallas):
    x = jnp.asarray(rng.normal(size=(M, 384)).astype(np.float32))
    z = jnp.asarray(rng.normal(size=(384,)).astype(np.float32))
    d_ref, s_ref = probe_ref.plane_probe(x)

    def run():
        plain = anchor_ops.pullback_mean(x, z, 0.6)
        probed = anchor_ops.pullback_mean(x, z, 0.6, probe=True)
        return plain, probed

    if pallas:
        with flags.force_pallas():
            (x0, m0), (x1, m1, (d, s)) = run()
    else:
        (x0, m0), (x1, m1, (d, s)) = run()
    assert (x0 == x1).all() and (m0 == m1).all()  # boundary math untouched
    np.testing.assert_allclose(float(d), float(d_ref), rtol=1e-6)
    np.testing.assert_allclose(float(s), float(s_ref), rtol=1e-6)


@pytest.mark.parametrize("pallas", [False, True])
def test_fused_pullback_momentum_probe(rng, pallas):
    x = jnp.asarray(rng.normal(size=(M, 384)).astype(np.float32))
    z = jnp.asarray(rng.normal(size=(384,)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(384,)).astype(np.float32))
    d_ref, s_ref = probe_ref.plane_probe(x)  # pre-pullback plane

    def run():
        plain = anchor_ops.pullback_mean_momentum(x, z, v, 0.6, 0.7)
        probed = anchor_ops.pullback_mean_momentum(x, z, v, 0.6, 0.7, probe=True)
        return plain, probed

    if pallas:
        with flags.force_pallas():
            (x0, z0, v0), (x1, z1, v1, (d, s)) = run()
    else:
        (x0, z0, v0), (x1, z1, v1, (d, s)) = run()
    assert (x0 == x1).all() and (z0 == z1).all() and (v0 == v1).all()
    np.testing.assert_allclose(float(d), float(d_ref), rtol=1e-6)
    np.testing.assert_allclose(float(s), float(s_ref), rtol=1e-6)


# ---------------------------------------------------------------------------
# boundary_round probe across strategies
# ---------------------------------------------------------------------------

CASES = [
    ("overlap_local_sgd", dict(alpha=0.6, anchor_beta=0.0)),
    ("overlap_local_sgd", dict(alpha=0.6, anchor_beta=0.7)),
    ("easgd", dict(alpha=0.1)),
    ("local_sgd", {}),
    ("cocod", {}),
    ("delayed_avg", dict(delay_steps=2)),
    ("sparse_anchor", dict(alpha=0.6, sparse_k=0.5)),
]


def _boundary_state(cfg: AlgoConfig, px: Packed):
    strat = make_strategy(cfg)
    vars = strat.init_vars(px)
    inflight = strat.init_inflight(px, vars)
    return strat, vars, inflight


@pytest.mark.parametrize("name,kw", CASES)
@pytest.mark.parametrize("bf16", [False, True])
def test_boundary_probe_measures_preboundary_plane(rng, name, kw, bf16):
    """Probed boundary: stats equal the per-leaf oracle of the PRE-boundary
    stacked tree, and x/vars/inflight are bitwise the unprobed results."""
    tree = _stacked_tree(rng, bf16)
    d_ref, s_ref = consensus_drift(tree)
    cfg = AlgoConfig(name=name, tau=2, packed=True, **kw)
    px = pack(tree, lead=1)
    strat, vars, inflight = _boundary_state(cfg, px)
    x0, v0, i0 = strat.boundary_round(px, vars, inflight)
    x1, v1, i1, stats = strat.boundary_round(px, vars, inflight, probe=True)
    for a, b in zip(jax.tree.leaves(x0), jax.tree.leaves(x1)):
        assert (a == b).all()
    for a, b in zip(jax.tree.leaves(v0), jax.tree.leaves(v1)):
        assert (a == b).all()
    for a, b in zip(jax.tree.leaves(i0), jax.tree.leaves(i1)):
        assert (a == b).all()
    np.testing.assert_allclose(float(stats.drift), float(d_ref), **_tol(bf16))
    np.testing.assert_allclose(float(stats.scale), float(s_ref), **_tol(bf16))


def test_per_leaf_boundary_probe_matches_oracle(rng):
    """packed=False (the oracle path) probes through tree_probe."""
    tree = _stacked_tree(rng)
    d_ref, s_ref = consensus_drift(tree)
    cfg = AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, packed=False)
    strat = make_strategy(cfg)
    vars = strat.init_vars(tree)
    inflight = strat.init_inflight(tree, vars)
    _, _, _, stats = strat.boundary_round(tree, vars, inflight, probe=True)
    assert float(stats.drift) == float(d_ref) and float(stats.scale) == float(s_ref)


# ---------------------------------------------------------------------------
# launch budget (jaxpr pallas_call counts)
# ---------------------------------------------------------------------------


def _count_primitives(jaxpr, names):
    """Count equation primitives by name, recursing through sub-jaxprs but
    not into pallas_call bodies (their internal ops are in-VMEM)."""
    counts = dict.fromkeys(names, 0)
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in counts:
            counts[eqn.primitive.name] += 1
        if eqn.primitive.name == "pallas_call":
            continue
        for v in eqn.params.values():
            sub = None
            if isinstance(v, jax.extend.core.ClosedJaxpr):
                sub = v.jaxpr
            elif hasattr(v, "eqns"):
                sub = v
            if sub is not None:
                for k, c in _count_primitives(sub, names).items():
                    counts[k] += c
    return counts


def _boundary_launches(rng, name, kw, probe, bf16=True):
    tree = _stacked_tree(rng, bf16)  # 2 dtype buckets
    cfg = AlgoConfig(name=name, tau=2, packed=True, **kw)
    px = pack(tree, lead=1)
    strat, vars, inflight = _boundary_state(cfg, px)
    with flags.force_pallas():
        jaxpr = jax.make_jaxpr(lambda x, v, i: strat.boundary_round(x, v, i, probe=probe))(
            px, vars, inflight
        )
    return _count_primitives(jaxpr.jaxpr, ["pallas_call"])["pallas_call"]


@pytest.mark.parametrize(
    "name,kw",
    [
        ("overlap_local_sgd", dict(alpha=0.6, anchor_beta=0.0)),
        ("overlap_local_sgd", dict(alpha=0.6, anchor_beta=0.7)),
        ("easgd", dict(alpha=0.1)),
        ("sparse_anchor", dict(alpha=0.6, sparse_k=0.5)),
    ],
)
def test_probe_is_free_for_pullback_family(rng, name, kw):
    """The fused probe adds ZERO extra kernel launches: the partial sums are
    extra outputs of the boundary kernels the strategy already runs."""
    plain = _boundary_launches(rng, name, kw, probe=False)
    probed = _boundary_launches(rng, name, kw, probe=True)
    assert probed == plain, (name, plain, probed)
    assert plain == 2  # one fused boundary kernel per dtype bucket


@pytest.mark.parametrize("name,kw", [("local_sgd", {}), ("cocod", {})])
def test_standalone_probe_is_one_launch_per_bucket(rng, name, kw):
    """Strategies whose boundary never reads x through the pullback kernels
    pay exactly one standalone probe launch per dtype bucket."""
    plain = _boundary_launches(rng, name, kw, probe=False)
    probed = _boundary_launches(rng, name, kw, probe=True)
    assert probed == plain + 2, (name, plain, probed)  # +1 per bucket (2 buckets)


def _loss(params, batch):
    A, b = batch
    flat = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in jax.tree.leaves(params)])
    r = A @ flat - b
    loss = 0.5 * jnp.sum(r * r)
    return loss, dict(loss=loss)


def test_full_round_budget_unchanged_with_probe(rng):
    """Whole-round jaxpr for the paper's strategy: probe=True keeps the
    packed budget — 1 fused opt step + 1 fused boundary per bucket."""
    params = {
        "w": jnp.asarray(rng.normal(size=(3, 5)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(5,)), jnp.float32),
    }
    cfg = AlgoConfig(name="overlap_local_sgd", tau=3, alpha=0.6, anchor_beta=0.7, packed=True)
    strat = make_strategy(cfg)
    optimizer = sgd()
    state = make_train_state(params, M, optimizer, strat, None)
    n_flat = sum(l.size for l in jax.tree.leaves(params))
    A = jnp.zeros((3, M, 4, n_flat), jnp.float32)
    b = jnp.zeros((3, M, 4), jnp.float32)
    counts = []
    for probe in (False, True):
        step = make_round_step(_loss, optimizer, strat, schedules.constant(0.03), None, probe=probe)
        with flags.force_pallas():
            jaxpr = jax.make_jaxpr(step)(state, (A, b))
        counts.append(_count_primitives(jaxpr.jaxpr, ["pallas_call"])["pallas_call"])
    assert counts[0] == counts[1] == 2, counts


def test_round_step_probe_metrics(rng):
    """make_round_step(probe=True) surfaces consensus_drift/_scale metrics,
    identical (up to summation order) between plane-resident and per-leaf."""
    params = {
        "w": jnp.asarray(rng.normal(size=(3, 5)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(5,)), jnp.float32),
    }
    cfg = AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=0.7, packed=True)
    optimizer = sgd()
    n_flat = sum(l.size for l in jax.tree.leaves(params))
    A = jnp.asarray(rng.normal(size=(2, M, 4, n_flat)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(2, M, 4)), jnp.float32)
    vals = []
    for c in (cfg, dataclasses.replace(cfg, packed=False)):
        strat = make_strategy(c)
        state = make_train_state(params, M, optimizer, strat, None)
        step = jax.jit(make_round_step(_loss, optimizer, strat, schedules.constant(0.03), None, probe=True))
        _, ms = step(state, (A, b))
        assert ms["consensus_drift"].shape == () and ms["consensus_scale"].shape == ()
        assert np.isfinite(float(ms["consensus_drift"])) and float(ms["consensus_scale"]) > 0
        vals.append((float(ms["consensus_drift"]), float(ms["consensus_scale"])))
    np.testing.assert_allclose(vals[0], vals[1], rtol=1e-5)
