"""Sharding integration tests on an 8-device host mesh (subprocess: the
device-count XLA flag must be set before jax initializes, and only the
dry-run may see multiple devices)."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# per-test wall-clock budget for the subprocess lowering/execution tests: a
# hung XLA compile (or a deadlocked host collective) fails the one test with
# a readable message instead of stalling the whole suite at the runner's
# global timeout. Override for slow machines via REPRO_SUBPROC_TIMEOUT.
_TIMEOUT = int(os.environ.get("REPRO_SUBPROC_TIMEOUT", "300"))


def _run_subprocess(script: str, label: str) -> subprocess.CompletedProcess:
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)  # the script pins its own device count
    try:
        return subprocess.run(
            [sys.executable, "-c", script],
            env=env,
            capture_output=True,
            text=True,
            timeout=_TIMEOUT,
        )
    except subprocess.TimeoutExpired as e:
        out = (e.stdout or b"")
        out = out.decode() if isinstance(out, bytes) else out
        pytest.fail(
            f"{label}: subprocess exceeded {_TIMEOUT}s "
            f"(REPRO_SUBPROC_TIMEOUT to raise); partial stdout:\n{out[-2000:]}"
        )

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.config import AlgoConfig, get_arch, InputShape, ParallelPlan
from repro.core import make_algorithm
from repro.launch import specs, roofline as rl
from repro.launch.mesh import make_smoke_mesh
from repro.models import transformer as T
from repro.optim import schedules, sgd
from repro.parallel import mesh_context
from repro.training.train_loop import make_round_step

mesh = make_smoke_mesh()
arch = get_arch("{arch}")
cfg = arch.model.reduced()
plan = ParallelPlan(workers=2, fsdp=2, tensor=2)
shape = InputShape("small_train", seq_len=32, global_batch=8, mode="train")
rules = specs.rules_for(shape)
algo = make_algorithm(AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=0.7))
opt = sgd()

with mesh_context(mesh, rules):
    state_sds, state_sh, axes = specs.train_state_specs(cfg, plan, algo, opt, mesh, rules)
    batch_sds = specs.train_batch_specs(cfg, shape, plan, tau=2)
    batch_sh = specs.batch_shardings(batch_sds, mesh, rules)
    loss_fn = lambda p, b: T.lm_loss(cfg, p, b, remat=True)
    step = make_round_step(loss_fn, opt, algo, schedules.constant(0.1), axes)
    lowered = jax.jit(step, in_shardings=(state_sh, batch_sh)).lower(state_sds, batch_sds)
    compiled = lowered.compile()
    stats = rl.collective_stats(compiled.as_text())
    assert any(k in stats for k in ("all-reduce", "all-gather", "reduce-scatter")), stats
    print("COLLECTIVES", sorted(stats))
    print("OK {arch}")
"""


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "deepseek-v3-671b", "rwkv6-7b", "zamba2-1.2b"])
def test_reduced_arch_lowers_on_8_device_mesh(arch):
    proc = _run_subprocess(SCRIPT.replace("{arch}", arch), f"lower {arch}")
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert f"OK {arch}" in proc.stdout


RUN_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.config import AlgoConfig, get_arch
from repro.core import make_algorithm
from repro.launch.mesh import make_smoke_mesh
from repro.models import transformer as T
from repro.optim import schedules, sgd
from repro.parallel import mesh_context
from repro.training import make_round_step, make_train_state
from repro.launch import specs

mesh = make_smoke_mesh()
cfg = get_arch("h2o-danube-1.8b").model.reduced()
rng = np.random.default_rng(0)
with mesh_context(mesh, specs.TRAIN_RULES):
    params, axes = T.init_model(cfg, jax.random.PRNGKey(0))
    algo = make_algorithm(AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=0.7))
    opt = sgd()
    state = make_train_state(params, 2, opt, algo, axes)
    step = jax.jit(make_round_step(lambda p, b: T.lm_loss(cfg, p, b), opt, algo, schedules.constant(1e-2), axes))
    batch = dict(
        tokens=jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 2, 4, 32)), jnp.int32),
        targets=jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 2, 4, 32)), jnp.int32),
    )
    state, ms = step(state, batch)
    loss = np.asarray(ms["loss"])
    assert np.isfinite(loss).all()
    # executed on 8 real (host) devices — numerics must match 1-device run
    print("LOSS", float(loss.mean()))
print("RUN OK")
"""


def test_sharded_execution_runs_on_8_devices():
    proc = _run_subprocess(RUN_SCRIPT, "sharded execution")
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "RUN OK" in proc.stdout


PACKED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.config import AlgoConfig, get_arch, InputShape, ParallelPlan
from repro.core import make_strategy
from repro.launch import specs, roofline as rl
from repro.launch.mesh import make_smoke_mesh
from repro.models import transformer as T
from repro.optim import schedules, sgd
from repro.parallel import mesh_context
from repro.parallel.packing import Packed, unpack
from repro.training import make_round_step, make_train_state

mesh = make_smoke_mesh()
cfg = get_arch("h2o-danube-1.8b").model.reduced()
plan = ParallelPlan(workers=2, fsdp=2, tensor=2)
shape = InputShape("small_train", seq_len=32, global_batch=8, mode="train")
rules = specs.rules_for(shape)
acfg = AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=0.7, packed=True)
opt = sgd()

# 1) AOT specs: the packed inflight/vars slots get flat-plane shardings
with mesh_context(mesh, rules):
    strat = make_strategy(acfg)
    state_sds, state_sh, axes = specs.train_state_specs(cfg, plan, strat, opt, mesh, rules)
    assert isinstance(state_sds.inflight, Packed) and isinstance(state_sh.inflight, Packed)
    # plane-resident state: x itself is the worker-stacked plane in the AOT specs
    assert isinstance(state_sds.x, Packed) and isinstance(state_sh.x, Packed)
    batch_sds = specs.train_batch_specs(cfg, shape, plan, tau=2)
    batch_sh = specs.batch_shardings(batch_sds, mesh, rules)
    loss_fn = lambda p, b: T.lm_loss(cfg, p, b, remat=True)
    step = make_round_step(loss_fn, opt, strat, schedules.constant(0.1), axes)
    compiled = jax.jit(step, in_shardings=(state_sh, batch_sh)).lower(state_sds, batch_sds).compile()
    stats = rl.collective_stats(compiled.as_text())
    assert any(k in stats for k in ("all-reduce", "all-gather", "reduce-scatter")), stats

# 2) execution on 8 host devices: packed round == per-leaf round (1-ULP
# tolerance: the two programs shard differently, so XLA may reassociate
# f32 reductions inside the *local steps*; the boundary math itself is
# pinned bitwise by the no-mesh golden tests)
rng = np.random.default_rng(0)
batch = dict(
    tokens=jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 2, 4, 32)), jnp.int32),
    targets=jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 2, 4, 32)), jnp.int32),
)
finals = []
with mesh_context(mesh, rules):
    params, axes = T.init_model(cfg, jax.random.PRNGKey(0))
    for c in (acfg, dataclasses.replace(acfg, packed=False)):
        strat = make_strategy(c)
        state = make_train_state(params, 2, opt, strat, axes)
        step = jax.jit(make_round_step(lambda p, b: T.lm_loss(cfg, p, b), opt, strat, schedules.constant(1e-2), axes))
        state, ms = step(state, batch)
        assert np.isfinite(np.asarray(ms["loss"])).all()
        finals.append(state)
assert isinstance(finals[0].x, Packed) and not isinstance(finals[1].x, Packed)
for a, b in zip(jax.tree.leaves(unpack(finals[0].x)), jax.tree.leaves(finals[1].x)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-7, atol=2e-7)
for a, b in zip(jax.tree.leaves(unpack(finals[0].inflight)), jax.tree.leaves(finals[1].inflight)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-7, atol=2e-7)
print("PACKED MESH OK")
"""


OPT_PLANE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.config import AlgoConfig, get_arch, InputShape, ParallelPlan
from repro.core import make_strategy
from repro.launch import specs, roofline as rl
from repro.launch.mesh import make_smoke_mesh
from repro.models import transformer as T
from repro.optim import schedules, sgd, PackedSGDState
from repro.parallel import mesh_context
from repro.parallel.packing import Packed, unpack
from repro.training import make_round_step, make_train_state

mesh = make_smoke_mesh()
cfg = get_arch("h2o-danube-1.8b").model.reduced()
plan = ParallelPlan(workers=2, fsdp=2, tensor=2)
shape = InputShape("small_train", seq_len=32, global_batch=8, mode="train")
rules = specs.rules_for(shape)
acfg = AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=0.7, packed=True)
opt = sgd(momentum=0.9, nesterov=True, weight_decay=1e-4)

# 1) AOT specs: the flat optimizer-state buffers get worker-stacked
# flat-plane shardings ((worker, fsdp) — the jax-0.4.x partially-sharded
# regime the DUS-built plane exists for)
with mesh_context(mesh, rules):
    strat = make_strategy(acfg)
    state_sds, state_sh, axes = specs.train_state_specs(cfg, plan, strat, opt, mesh, rules)
    assert isinstance(state_sds.opt, PackedSGDState), type(state_sds.opt)
    assert isinstance(state_sh.opt.momentum, Packed)
    assert isinstance(state_sds.x, Packed) and isinstance(state_sh.x, Packed)
    x_specs = {sh.spec for sh in jax.tree.leaves(state_sh.x)}
    assert any("worker" in str(sp) and "fsdp" in str(sp) for sp in x_specs), x_specs
    sh_specs = {s.spec for s in jax.tree.leaves(state_sh.opt)}
    assert any("worker" in str(sp) and "fsdp" in str(sp) for sp in sh_specs), sh_specs
    batch_sds = specs.train_batch_specs(cfg, shape, plan, tau=2)
    batch_sh = specs.batch_shardings(batch_sds, mesh, rules)
    loss_fn = lambda p, b: T.lm_loss(cfg, p, b, remat=True)
    step = make_round_step(loss_fn, opt, strat, schedules.constant(0.1), axes)
    compiled = jax.jit(step, in_shardings=(state_sh, batch_sh)).lower(state_sds, batch_sds).compile()
    stats = rl.collective_stats(compiled.as_text())
    assert any(k in stats for k in ("all-reduce", "all-gather", "reduce-scatter")), stats

# 2) executed parity on 8 host devices: a full round with the packed local
# step (flat momentum carried in the scan) matches the per-leaf oracle.
# Tolerance is a few ULPs — the two programs shard/fuse differently through
# the ENTIRE local step now, so XLA may reassociate f32 math per step; the
# update math itself is pinned bitwise by the no-mesh suite.
rng = np.random.default_rng(0)
batch = dict(
    tokens=jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 2, 4, 32)), jnp.int32),
    targets=jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 2, 4, 32)), jnp.int32),
)
finals = []
with mesh_context(mesh, rules):
    params, axes = T.init_model(cfg, jax.random.PRNGKey(0))
    for c in (acfg, dataclasses.replace(acfg, packed=False)):
        strat = make_strategy(c)
        state = make_train_state(params, 2, opt, strat, axes)
        step = jax.jit(make_round_step(lambda p, b: T.lm_loss(cfg, p, b), opt, strat, schedules.constant(1e-2), axes))
        state, ms = step(state, batch)
        assert np.isfinite(np.asarray(ms["loss"])).all()
        finals.append(state)
assert isinstance(finals[0].opt, PackedSGDState) and not isinstance(finals[1].opt, PackedSGDState)
assert isinstance(finals[0].x, Packed) and not isinstance(finals[1].x, Packed)
for a, b in zip(jax.tree.leaves(unpack(finals[0].x)), jax.tree.leaves(finals[1].x)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=5e-7)
for a, b in zip(jax.tree.leaves(unpack(finals[0].opt.momentum)), jax.tree.leaves(finals[1].opt.momentum)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=5e-7)
print("OPT PLANE MESH OK")
"""


def test_packed_opt_state_lowers_and_matches_on_8_devices():
    """Satellite (ISSUE 3): flat optimizer-state buckets get the
    (worker, fsdp) flat-plane shardings in the AOT specs, the round program
    compiles on the 8-device host mesh, and an executed round matches the
    per-leaf oracle — pinning the jax-0.4.x partially-sharded-concat
    workaround (DUS-built planes) for the optimizer buckets."""
    proc = _run_subprocess(OPT_PLANE_SCRIPT, "packed opt plane")
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OPT PLANE MESH OK" in proc.stdout


NATIVE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
import jax, jax.numpy as jnp, numpy as np
from repro.api import resolve_strategy
from repro.config import AlgoConfig, get_arch, InputShape, ParallelPlan
from repro.core.strategy import CommStrategy, LegacyStrategy
from repro.launch import specs, roofline as rl
from repro.launch.mesh import make_smoke_mesh
from repro.models import transformer as T
from repro.optim import schedules, sgd, PackedSGDState
from repro.parallel import mesh_context
from repro.parallel.packing import Packed
from repro.training.train_loop import make_round_step

# the production lowering path must never touch the deprecated shim: after
# importing the dry-run module, repro.core.algorithms is not even loaded,
# and its source has no make_algorithm reference left
import repro.launch.dryrun as dryrun
assert "repro.core.algorithms" not in sys.modules, "dryrun import pulled the deprecated shim"
src = open(dryrun.__file__).read()
assert "make_algorithm" not in src, "dryrun.py still references the legacy make_algorithm path"

mesh = make_smoke_mesh()
cfg = get_arch("h2o-danube-1.8b").model.reduced()
plan = ParallelPlan(workers=2, fsdp=2, tensor=2)
shape = InputShape("small_train", seq_len=32, global_batch=8, mode="train")
rules = specs.rules_for(shape)
opt = sgd(momentum=0.9, nesterov=True, weight_decay=1e-4)

# per-strategy native coverage: the paper's algorithm, both blocking
# baselines, DaSGD delayed averaging, and LOSCAR sparse anchor
for name in ("overlap_local_sgd", "local_sgd", "sync_sgd", "delayed_avg", "sparse_anchor"):
    strat = resolve_strategy(specs.train_algo_config(plan, name))
    assert isinstance(strat, CommStrategy) and not isinstance(strat, LegacyStrategy), name
    assert strat.packed, name
    tau = strat.tau
    with mesh_context(mesh, rules):
        state_sds, state_sh, axes = specs.train_state_specs(cfg, plan, strat, opt, mesh, rules)
        # strategy-native, plane-resident round program: x IS the packed
        # plane and the optimizer state is flat buckets, in specs and shardings
        assert isinstance(state_sds.x, Packed) and isinstance(state_sh.x, Packed), name
        assert isinstance(state_sds.opt, PackedSGDState), (name, type(state_sds.opt))
        batch_sds = specs.train_batch_specs(cfg, shape, plan, tau)
        batch_sh = specs.batch_shardings(batch_sds, mesh, rules)
        step = make_round_step(lambda p, b: T.lm_loss(cfg, p, b, remat=True), opt, strat,
                               schedules.constant(0.1), axes)
        compiled = jax.jit(step, in_shardings=(state_sh, batch_sh)).lower(state_sds, batch_sds).compile()
        stats = rl.collective_stats(compiled.as_text())
        assert any(k in stats for k in ("all-reduce", "all-gather", "reduce-scatter")), (name, stats)
    print("NATIVE OK", name)
assert "repro.core.algorithms" not in sys.modules, "native lowering pulled the deprecated shim"
print("NATIVE DRYRUN OK")
"""


def test_native_strategy_dryrun_on_8_devices():
    """Tentpole (ISSUE 5): the dry-run's train lowering is strategy-native —
    resolved through repro.api.resolve_strategy, plane-resident x + flat
    opt-state specs, per-strategy coverage (overlap/local/sync/DaSGD/LOSCAR)
    — and never imports the deprecated make_algorithm shim."""
    proc = _run_subprocess(NATIVE_SCRIPT, "native strategy dryrun")
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "NATIVE DRYRUN OK" in proc.stdout
    for name in ("overlap_local_sgd", "local_sgd", "sync_sgd", "delayed_avg", "sparse_anchor"):
        assert f"NATIVE OK {name}" in proc.stdout


def test_legacy_shim_import_and_call_warn():
    """The deprecated oracle shim is still reachable for the golden tests,
    but both pulling it out of repro.core and calling make_algorithm emit
    DeprecationWarning."""
    import warnings

    import repro.core

    from repro.config import AlgoConfig

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        make_algorithm = repro.core.make_algorithm
        assert any(issubclass(x.category, DeprecationWarning) for x in w), w
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        algo = make_algorithm(AlgoConfig(name="local_sgd"))
        assert any(issubclass(x.category, DeprecationWarning) for x in w), w
    assert algo.name == "local_sgd"


def test_packed_boundary_lowers_and_matches_on_8_devices():
    """Packed-plane boundary on a real (host) mesh: the AOT specs give the
    flat inflight/vars buffers anchor-plane shardings, the program lowers
    and compiles, and one executed round is bitwise-identical to the
    per-leaf oracle under the same sharding."""
    proc = _run_subprocess(PACKED_SCRIPT, "packed boundary")
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "PACKED MESH OK" in proc.stdout


GOSSIP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.api import resolve_strategy
from repro.config import get_arch, InputShape, ParallelPlan
from repro.core.strategy import GossipInflight
from repro.launch import specs, roofline as rl
from repro.launch.mesh import make_smoke_mesh
from repro.models import transformer as T
from repro.optim import schedules, sgd
from repro.parallel import mesh_context
from repro.parallel.packing import Packed
from repro.training import make_round_step, make_train_state

mesh = make_smoke_mesh()
cfg = get_arch("h2o-danube-1.8b").model.reduced()
plan = ParallelPlan(workers=2, fsdp=2, tensor=2)
shape = InputShape("small_train", seq_len=32, global_batch=8, mode="train")
rules = specs.rules_for(shape)
opt = sgd()

# 1) the gossip family lowers through the same strategy-native dry-run path:
# degenerate full topology reuses the anchor-shaped inflight; sparse
# topologies carry the two-slot push-sum inflight (mix plane + (m,) weights)
for name, sparse in (("gossip_full", False), ("gossip_exp", True)):
    strat = resolve_strategy(specs.train_algo_config(plan, name))
    assert strat.packed and getattr(strat, "topo_name", None) is not None, name
    with mesh_context(mesh, rules):
        state_sds, state_sh, axes = specs.train_state_specs(cfg, plan, strat, opt, mesh, rules)
        assert isinstance(state_sds.x, Packed) and isinstance(state_sh.x, Packed), name
        if sparse:
            assert isinstance(state_sds.inflight, GossipInflight), (name, type(state_sds.inflight))
            assert isinstance(state_sds.inflight.mix, Packed), name
            assert state_sds.inflight.w.shape == (2,), name
        batch_sds = specs.train_batch_specs(cfg, shape, plan, strat.tau)
        batch_sh = specs.batch_shardings(batch_sds, mesh, rules)
        step = make_round_step(lambda p, b: T.lm_loss(cfg, p, b, remat=True), opt, strat,
                               schedules.constant(0.1), axes)
        compiled = jax.jit(step, in_shardings=(state_sh, batch_sh)).lower(state_sds, batch_sds).compile()
        stats = rl.collective_stats(compiled.as_text())
        assert any(k in stats for k in ("all-reduce", "all-gather", "reduce-scatter")), (name, stats)
    print("GOSSIP LOWER OK", name)

# 2) an executed push-sum round on the 8 host devices: finite loss and the
# push weights stay a probability mass (sum == m, fully live)
rng = np.random.default_rng(0)
batch = dict(
    tokens=jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 2, 4, 32)), jnp.int32),
    targets=jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 2, 4, 32)), jnp.int32),
)
with mesh_context(mesh, rules):
    params, axes = T.init_model(cfg, jax.random.PRNGKey(0))
    strat = resolve_strategy(specs.train_algo_config(plan, "gossip_exp"))
    state = make_train_state(params, 2, opt, strat, axes)
    step = jax.jit(make_round_step(lambda p, b: T.lm_loss(cfg, p, b), opt, strat,
                                   schedules.constant(1e-2), axes))
    for _ in range(2):
        state, ms = step(state, batch)
        assert np.isfinite(np.asarray(ms["loss"])).all()
    np.testing.assert_allclose(float(np.asarray(state.inflight.w).sum()), 2.0, rtol=1e-5)
print("GOSSIP MESH OK")
"""


def test_gossip_strategies_lower_and_run_on_8_devices():
    """Tentpole (ISSUE 8): the push-sum/gossip family lowers through the
    strategy-native dry-run path on the 8-device host mesh — degenerate full
    topology plus a sparse one-peer-exponential — and an executed push-sum
    round keeps the loss finite with conserved push mass."""
    proc = _run_subprocess(GOSSIP_SCRIPT, "gossip strategies")
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "GOSSIP MESH OK" in proc.stdout
    for name in ("gossip_full", "gossip_exp"):
        assert f"GOSSIP LOWER OK {name}" in proc.stdout


MEMBERSHIP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.api import resolve_strategy
from repro.config import get_arch, InputShape, ParallelPlan
from repro.fault.membership import from_mask
from repro.launch import specs, roofline as rl
from repro.launch.mesh import make_smoke_mesh
from repro.models import transformer as T
from repro.optim import schedules, sgd
from repro.parallel import mesh_context
from repro.training import make_round_step, make_train_state

mesh = make_smoke_mesh()
cfg = get_arch("h2o-danube-1.8b").model.reduced()
plan = ParallelPlan(workers=2, fsdp=2, tensor=2)
shape = InputShape("small_train", seq_len=32, global_batch=8, mode="train")
rules = specs.rules_for(shape)
opt = sgd()
strat = resolve_strategy(specs.train_algo_config(plan, "overlap_local_sgd"))

# 1) the membership-carrying AOT specs lower + compile (the fault dry-run
# path: replicated (m,) mask/weights threaded into the masked boundary)
with mesh_context(mesh, rules):
    state_sds, state_sh, axes = specs.train_state_specs(
        cfg, plan, strat, opt, mesh, rules, with_membership=True
    )
    assert state_sds.membership is not None and state_sh.membership is not None
    batch_sds = specs.train_batch_specs(cfg, shape, plan, strat.tau)
    batch_sh = specs.batch_shardings(batch_sds, mesh, rules)
    step = make_round_step(lambda p, b: T.lm_loss(cfg, p, b, remat=True), opt, strat,
                           schedules.constant(0.1), axes)
    compiled = jax.jit(step, in_shardings=(state_sh, batch_sh)).lower(state_sds, batch_sds).compile()
    stats = rl.collective_stats(compiled.as_text())
    assert any(k in stats for k in ("all-reduce", "all-gather", "reduce-scatter")), stats

# 2) a degraded round executes on the 8 host devices: worker 1 masked out
rng = np.random.default_rng(0)
batch = dict(
    tokens=jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 2, 4, 32)), jnp.int32),
    targets=jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 2, 4, 32)), jnp.int32),
)
with mesh_context(mesh, rules):
    params, axes = T.init_model(cfg, jax.random.PRNGKey(0))
    state = make_train_state(params, 2, opt, strat, axes)
    state = state._replace(membership=from_mask(np.array([1.0, 0.0], np.float32)))
    step = jax.jit(make_round_step(lambda p, b: T.lm_loss(cfg, p, b), opt, strat,
                                   schedules.constant(1e-2), axes))
    state, ms = step(state, batch)
    assert np.isfinite(np.asarray(ms["loss"])).all()
print("MEMBERSHIP MESH OK")
"""


def test_membership_boundary_lowers_and_runs_on_8_devices():
    """Tentpole (ISSUE 7): the membership-carrying train state lowers and
    compiles on the 8-device host mesh (the fault dry-run's masked round
    program), and a degraded round executes with a masked-out worker."""
    proc = _run_subprocess(MEMBERSHIP_SCRIPT, "membership boundary")
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MEMBERSHIP MESH OK" in proc.stdout
