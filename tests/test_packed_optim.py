"""Plane-resident training: golden differential suite + budget regressions.

The packed parameter plane is the canonical representation end-to-end:
``TrainState.x`` stores the worker-stacked ``Packed`` plane across rounds,
the loss is differentiated with the plane as the primal (params reach the
model through a ``ParamView``), flat optimizer state
(``PackedSGDState``/``PackedAdamState``) rides in ``TrainState.opt``, and
``boundary_round`` consumes and returns the plane. This suite pins it four
ways:

1. differential: plane-resident vs per-leaf full rounds are bit-exact
   (≤1-ulp for f32 AdamW, whose division/sqrt chain XLA may FMA-contract
   differently) across all optimizers × {f32, mixed-bf16 params} × all 11
   strategy variants, including mid-round DaSGD consume and LOSCAR error
   feedback — and with gradient clipping on (bitwise by default;
   ``packed_clip`` per-bucket norms within a few ulps);
2. budget: jaxpr launch/collective counts for a full τ-step round stay at
   the packed budget *regardless of leaf count*, and the local-step scan
   body contains exactly ONE plane build per step — the AD transpose of the
   ParamView window read — with no pack/unpack round-trip of the carried x
   (slice and dynamic_update_slice counts are pinned per leaf);
3. numerics: packed bf16-param AdamW against an f64 NumPy reference, and
   the Pallas kernels (interpret mode) against the shared jnp formulas.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import AlgoConfig
from repro.core import make_strategy
from repro.kernels import flags
from repro.kernels.opt_step import ops as opt_ops
from repro.kernels.opt_step import ref as opt_ref
from repro.optim import (
    PackedAdamState,
    PackedSGDState,
    adamw,
    clip_by_global_norm,
    clip_packed_by_global_norm,
    packed_capable,
    schedules,
    sgd,
)
from repro.parallel.packing import Packed, pack, unpack
from repro.training import make_round_step, make_train_state

M = 4


from conftest import unpack_view as _unp  # packed-state pytree view


def _params(rng, bf16: bool):
    """Mixed-shape tree; ``bf16`` adds a second dtype bucket (bf16 matrices
    alongside f32 leaves) so the packed path must keep buckets separate."""
    mat = jnp.bfloat16 if bf16 else jnp.float32
    return {
        "w0": jnp.asarray(rng.normal(size=(3, 5)), mat),
        "w1": jnp.asarray(rng.normal(size=(4, 6)), mat),
        "vec": jnp.asarray(rng.normal(size=(7,)), jnp.float32),
        "scalar": jnp.float32(rng.normal()),
        "b0": jnp.asarray(rng.normal(size=(5,)), mat),
    }


def _loss(params, batch):
    A, b = batch
    flat = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in jax.tree.leaves(params)])
    r = A @ flat - b
    loss = 0.5 * jnp.sum(r * r)
    return loss, dict(loss=loss)


def _run_pair(cfg: AlgoConfig, optimizer, params, rounds=2, lr=0.03, seed=1, grad_clip=0.0):
    """Run packed (plane-resident) and per-leaf configurations on identical
    batches; return the two final TrainStates."""
    n_flat = sum(l.size for l in jax.tree.leaves(params))
    states, steps, strats = [], [], []
    for c in (cfg, dataclasses.replace(cfg, packed=False)):
        strat = make_strategy(c)
        strats.append(strat)
        states.append(make_train_state(params, M, optimizer, strat, None))
        steps.append(
            jax.jit(make_round_step(_loss, optimizer, strat, schedules.constant(lr), None, grad_clip=grad_clip))
        )
    assert strats[0].packed and not strats[1].packed
    rng = np.random.default_rng(seed)
    for _ in range(rounds):
        A = jnp.asarray(rng.normal(size=(strats[0].tau, M, 4, n_flat)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(strats[0].tau, M, 4)), jnp.float32)
        states = [step(s, (A, b))[0] for step, s in zip(steps, states)]
    return states


ALL_VARIANTS = [
    ("overlap_local_sgd", dict(anchor_beta=0.0)),
    ("overlap_local_sgd", dict(anchor_beta=0.7)),
    ("local_sgd", {}),
    ("sync_sgd", {}),
    ("easgd", {}),
    ("cocod", {}),
    ("powersgd", {}),
    ("delayed_avg", dict(delay_steps=2)),  # mid-round consume (delay < tau)
    ("delayed_avg", dict(delay_steps=3)),  # boundary consume (delay = tau)
    ("sparse_anchor", dict(sparse_k=0.5)),  # error feedback active
    ("sparse_anchor", dict(sparse_k=1.0)),
]

OPTIMIZERS = {
    "sgd": lambda: sgd(momentum=0.9, nesterov=True, weight_decay=1e-4),
    "adamw": lambda: adamw(b1=0.9, b2=0.95, eps=1e-8, weight_decay=1e-4),
}


def _assert_tree(tp, tr, opt_name, msg):
    """sgd: bitwise; adamw: ≤1-ulp on f32 (FMA-contraction slack)."""
    for a, b in zip(jax.tree.leaves(tp), jax.tree.leaves(tr)):
        a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
        if opt_name == "sgd":
            np.testing.assert_array_equal(a, b, err_msg=msg)
        else:
            np.testing.assert_allclose(a, b, rtol=3e-7, atol=1e-7, err_msg=msg)


@pytest.mark.parametrize("bf16", [False, True], ids=["f32", "bf16"])
@pytest.mark.parametrize("opt_name", sorted(OPTIMIZERS))
@pytest.mark.parametrize("name,kw", ALL_VARIANTS, ids=[f"{n}-{v}" for n, v in ALL_VARIANTS])
def test_packed_local_step_matches_perleaf(name, kw, opt_name, bf16, rng):
    """ISSUE golden suite: the packed local step (flat opt state + fused
    update + packed gradient hooks) reproduces the per-leaf oracle for every
    strategy × optimizer × param-dtype combination — params, optimizer
    state, carried inflight, and strategy vars."""
    cfg = AlgoConfig(name=name, tau=3, alpha=0.6, packed=True, **kw)
    optimizer = OPTIMIZERS[opt_name]()
    s_p, s_r = _run_pair(cfg, optimizer, _params(rng, bf16))

    # the packed run must actually be plane-resident: x IS the plane across
    # rounds, and the opt state uses the packed layout
    assert isinstance(s_p.x, Packed)
    assert isinstance(s_p.opt, (PackedSGDState, PackedAdamState))
    _assert_tree(_unp(s_p.x), s_r.x, opt_name, f"{name}.x")

    # optimizer state agrees through the pytree view (per-leaf Adam carries
    # one count per worker; packed carries the single shared scalar)
    po, ro = _unp(s_p.opt), s_r.opt
    if opt_name == "sgd":
        _assert_tree(po.momentum, ro.momentum, opt_name, f"{name}.opt.momentum")
    else:
        _assert_tree(po.mu, ro.mu, opt_name, f"{name}.opt.mu")
        _assert_tree(po.nu, ro.nu, opt_name, f"{name}.opt.nu")
        assert po.count.shape == ()
        np.testing.assert_array_equal(np.asarray(po.count), np.asarray(ro.count[0]))

    pv, rv = _unp(s_p.inflight), _unp(s_r.inflight)
    _assert_tree(pv, rv, opt_name, f"{name}.inflight")
    for f in ("z", "v", "extra"):
        pv, rv = _unp(getattr(s_p.vars, f)), _unp(getattr(s_r.vars, f))
        if pv is None or rv is None:
            assert (pv is None) == (rv is None)
            continue
        _assert_tree(pv, rv, opt_name, f"{name}.vars.{f}")


def test_packed_opt_state_layout(rng):
    """Satellite fix: packed AdamW keeps ONE scalar count and f32 moment
    buckets element-aligned with the (possibly bf16) parameter plane; packed
    SGD momentum stays in the parameter dtype bucket-for-bucket."""
    params = _params(rng, bf16=True)
    px = pack(jax.tree.map(lambda t: jnp.tile(t[None], (M,) + (1,) * t.ndim), params), lead=1)

    st = adamw().init_packed(px)
    assert st.count.shape == () and st.count.dtype == jnp.int32
    assert all(b.dtype == jnp.float32 for b in st.mu.buffers + st.nu.buffers)
    # element-aligned: same bucket sizes/offsets as the param plane
    assert st.mu.layout.bucket_sizes == px.layout.bucket_sizes
    assert [s.offset for s in st.mu.layout.slots] == [s.offset for s in px.layout.slots]

    ss = sgd().init_packed(px)
    assert tuple(b.dtype for b in ss.momentum.buffers) == tuple(b.dtype for b in px.buffers)


# ---------------------------------------------------------------------------
# launch/collective budget: O(dtype buckets), not O(leaves)
# ---------------------------------------------------------------------------


def _count_primitives(jaxpr, names):
    """Count equation primitives by name, recursing through sub-jaxprs but
    not into pallas_call bodies (their internal ops are in-VMEM)."""
    counts = dict.fromkeys(names, 0)
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in counts:
            counts[eqn.primitive.name] += 1
        if eqn.primitive.name == "pallas_call":
            continue
        for v in eqn.params.values():
            sub = None
            if isinstance(v, jax.extend.core.ClosedJaxpr):
                sub = v.jaxpr
            elif hasattr(v, "eqns"):
                sub = v
            if sub is not None:
                for k, c in _count_primitives(sub, names).items():
                    counts[k] += c
    return counts


def _round_jaxpr(params, opt_name="sgd", tau=3, beta=0.7):
    cfg = AlgoConfig(name="overlap_local_sgd", tau=tau, alpha=0.6, anchor_beta=beta, packed=True)
    strat = make_strategy(cfg)
    optimizer = OPTIMIZERS[opt_name]()
    state = make_train_state(params, M, optimizer, strat, None)
    step = make_round_step(_loss, optimizer, strat, schedules.constant(0.03), None)
    n_flat = sum(l.size for l in jax.tree.leaves(params))
    A = jnp.zeros((tau, M, 4, n_flat), jnp.float32)
    b = jnp.zeros((tau, M, 4), jnp.float32)
    with flags.force_pallas():
        return jax.make_jaxpr(step)(state, (A, b))


def _wide_params(rng, n_mats, bf16=False):
    mat = jnp.bfloat16 if bf16 else jnp.float32
    p = {"s": jnp.float32(rng.normal())}
    for i in range(n_mats):
        p[f"w{i}"] = jnp.asarray(rng.normal(size=(3 + i % 4, 5 + i % 3)), mat)
        p[f"b{i}"] = jnp.asarray(rng.normal(size=(5 + i % 3,)), jnp.float32)
    return p


@pytest.mark.parametrize("opt_name", sorted(OPTIMIZERS))
def test_round_launch_budget_independent_of_leaf_count(rng, opt_name):
    """ISSUE acceptance: one fused kernel launch per dtype bucket per local
    optimizer step. The τ local steps are a lax.scan, so the traced round
    program contains exactly buckets launches in the scan body (re-executed
    τ times at runtime) + buckets at the fused boundary — independent of
    how many leaves the model has."""
    counts = []
    for n_mats in (4, 12):
        params = _wide_params(rng, n_mats)
        assert len(jax.tree.leaves(params)) == 1 + 2 * n_mats
        jaxpr = _round_jaxpr(params, opt_name, tau=3)
        counts.append(_count_primitives(jaxpr.jaxpr, ["pallas_call"])["pallas_call"])
    # single f32 bucket: 1 fused opt step (scan body) + 1 fused boundary
    assert counts[0] == counts[1] == 2, counts


def test_round_launch_budget_two_buckets(rng):
    """Mixed {bf16, f32} params: the budget doubles with the bucket count,
    not with the leaf count."""
    params = _wide_params(rng, 6, bf16=True)  # bf16 mats + f32 vecs/scalar
    jaxpr = _round_jaxpr(params, "sgd", tau=2)
    n = _count_primitives(jaxpr.jaxpr, ["pallas_call"])["pallas_call"]
    assert n == 2 * 2, n  # 2 buckets × (opt step + boundary)


def _scan_bodies(jaxpr):
    """All scan-body jaxprs found at any depth (excluding pallas bodies)."""
    out = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            continue
        if eqn.primitive.name == "scan":
            out.append(eqn.params["jaxpr"].jaxpr)
        for v in eqn.params.values():
            sub = None
            if isinstance(v, jax.extend.core.ClosedJaxpr):
                sub = v.jaxpr
            elif hasattr(v, "eqns"):
                sub = v
            if sub is not None:
                out.extend(_scan_bodies(sub))
    return out


@pytest.mark.parametrize("opt_name", sorted(OPTIMIZERS))
def test_local_step_scan_body_single_plane_build(rng, opt_name):
    """ISSUE acceptance (plane-resident step): the τ-step scan body builds
    the gradient plane exactly ONCE per step — the DUS scatter emitted by
    the ParamView window read's custom VJP — and never round-trips the
    carried x through a pytree: slice count == leaf count (the forward
    window reads) and dynamic_update_slice count == leaf count (the AD
    scatter), with no second unpack/pack seam. Checked at two leaf counts
    so per-leaf regressions scale visibly."""
    for n_mats in (4, 12):
        params = _wide_params(rng, n_mats)
        n_leaves = len(jax.tree.leaves(params))
        jaxpr = _round_jaxpr(params, opt_name, tau=3)
        bodies = _scan_bodies(jaxpr.jaxpr)
        assert len(bodies) == 1, f"expected exactly the τ-step scan, got {len(bodies)}"
        counts = _count_primitives(bodies[0], ["dynamic_update_slice", "slice"])
        assert counts["dynamic_update_slice"] == n_leaves, (n_leaves, counts)
        # slices: n forward window reads + n from the harness loss's own
        # concatenate transpose (+ a few jax bookkeeping slices) — a second
        # unpack of the carried x would add another n
        assert counts["slice"] <= 2 * n_leaves + 4, (n_leaves, counts)


def test_whole_round_has_no_seam_dus(rng):
    """The round program outside the scan contains ZERO dynamic_update_slice
    ops: the boundary consumes and returns the plane (no re-pack at the
    scan→boundary seam), and state construction happens once in
    make_train_state, not per round."""
    params = _wide_params(rng, 6)
    n_leaves = len(jax.tree.leaves(params))
    jaxpr = _round_jaxpr(params, "sgd", tau=2)
    total = _count_primitives(jaxpr.jaxpr, ["dynamic_update_slice"])["dynamic_update_slice"]
    in_scan = sum(
        _count_primitives(b, ["dynamic_update_slice"])["dynamic_update_slice"]
        for b in _scan_bodies(jaxpr.jaxpr)
    )
    assert in_scan == n_leaves
    assert total == in_scan, f"{total - in_scan} DUS ops outside the scan body (seam re-pack?)"


# ---------------------------------------------------------------------------
# packed gradient clipping (satellite: AlgoConfig.packed_clip)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bf16", [False, True], ids=["f32", "bf16"])
def test_grad_clip_plane_resident_bitwise(rng, bf16):
    """Default clipping on the plane-resident step walks the layout slots in
    per-leaf order — bitwise identical to the per-leaf oracle even though
    the norm is computed off the plane."""
    cfg = AlgoConfig(name="overlap_local_sgd", tau=3, alpha=0.6, anchor_beta=0.7, packed=True)
    opt = OPTIMIZERS["sgd"]()
    # clip must actually bind: tiny max_norm so the scale is < 1 every step
    s_p, s_r = _run_pair(cfg, opt, _params(rng, bf16), grad_clip=0.5)
    _assert_tree(_unp(s_p.x), s_r.x, "sgd", "clip.x")


def test_packed_clip_per_bucket_few_ulp(rng):
    """``packed_clip=True`` swaps the per-leaf norm walk for per-bucket
    partial square-sums (O(buckets) reductions): same clip within a few
    ulps (different f32 summation order), hence opt-in."""
    cfg = AlgoConfig(
        name="overlap_local_sgd", tau=3, alpha=0.6, anchor_beta=0.7, packed=True, packed_clip=True
    )
    opt = OPTIMIZERS["sgd"]()
    s_p, s_r = _run_pair(cfg, opt, _params(rng, bf16=True), grad_clip=0.5)
    for a, b in zip(jax.tree.leaves(_unp(s_p.x)), jax.tree.leaves(s_r.x)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-5, atol=1e-6
        )


def test_clip_packed_matches_tree_clip(rng):
    """Unit differential: clip_packed_by_global_norm == clip_by_global_norm
    — bitwise with the per-leaf walk, ≤ few ulp with per-bucket sums."""
    params = _params(rng, bf16=True)
    x = jax.tree.map(lambda t: jnp.tile(t[None], (M,) + (1,) * t.ndim), params)
    x = jax.tree.map(
        lambda t: t + jnp.arange(M, dtype=jnp.float32).reshape((M,) + (1,) * (t.ndim - 1)).astype(t.dtype), x
    )
    px = pack(x, lead=1)
    for max_norm in (0.5, 1e6):  # binding and non-binding
        ref, ref_norm = jax.vmap(lambda g: clip_by_global_norm(g, max_norm))(x)
        got, norm = jax.vmap(lambda g: clip_packed_by_global_norm(g, max_norm))(px)
        np.testing.assert_array_equal(np.asarray(norm), np.asarray(ref_norm))
        for a, b in zip(jax.tree.leaves(unpack(got)), jax.tree.leaves(ref)):
            np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        got_b, norm_b = jax.vmap(lambda g: clip_packed_by_global_norm(g, max_norm, per_bucket=True))(px)
        np.testing.assert_allclose(np.asarray(norm_b), np.asarray(ref_norm), rtol=3e-7, atol=0)
        for a, b in zip(jax.tree.leaves(unpack(got_b)), jax.tree.leaves(ref)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-6, atol=1e-7
            )


def test_sync_sgd_collective_budget(rng):
    """The per-step gradient all-reduce is ONE mean per dtype bucket on the
    packed path vs one per leaf on the reference path."""
    params = _wide_params(rng, 8)
    n_leaves = len(jax.tree.leaves(params))
    x = jax.tree.map(lambda t: jnp.tile(t[None], (M,) + (1,) * t.ndim), params)
    grads = jax.tree.map(jnp.ones_like, x)
    strat = make_strategy(AlgoConfig(name="sync_sgd", packed=True))
    vars_ = strat.init_vars(x, None)

    packed_jaxpr = jax.make_jaxpr(lambda g: strat.transform_grads_packed(pack(g, lead=1), vars_)[0])(grads)
    n_packed = _count_primitives(packed_jaxpr.jaxpr, ["reduce_sum"])["reduce_sum"]
    assert n_packed == 1, n_packed  # single f32 bucket

    leaf_jaxpr = jax.make_jaxpr(lambda g: strat.transform_grads(g, vars_)[0])(grads)
    n_leaf = _count_primitives(leaf_jaxpr.jaxpr, ["reduce_sum"])["reduce_sum"]
    assert n_leaf == n_leaves


# ---------------------------------------------------------------------------
# bf16-param AdamW numerics vs an f64 reference (satellite fix)
# ---------------------------------------------------------------------------


def test_packed_adamw_bf16_vs_f64_reference(rng):
    """The packed path's f32 moment buckets + shared scalar count keep
    bf16-param AdamW within bf16 resolution of an all-f64 oracle (and the
    f32 moments within f32 resolution)."""
    n, steps = 257, 5  # lane-ragged on purpose
    b1, b2, eps, wd, lr = 0.9, 0.95, 1e-8, 1e-4, 0.02
    x0 = rng.normal(size=(M, n)).astype(np.float32)
    gs = rng.normal(size=(steps, M, n)).astype(np.float32)

    params = {"w": jnp.asarray(x0, jnp.bfloat16)}
    opt = adamw(b1=b1, b2=b2, eps=eps, weight_decay=wd)
    px = pack(params, lead=1)
    st = opt.init_packed(px)
    for k in range(steps):
        g = pack({"w": jnp.asarray(gs[k]).astype(jnp.bfloat16)}, lead=1)
        st, px = opt.step_packed(st, px, g, jnp.float32(lr))

    # f64 oracle fed the same bf16-rounded inputs
    x = np.asarray(jnp.asarray(x0, jnp.bfloat16), np.float64)
    mu = np.zeros_like(x)
    nu = np.zeros_like(x)
    for k in range(steps):
        g = np.asarray(jnp.asarray(gs[k]).astype(jnp.bfloat16), np.float64)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        c1, c2 = 1 - b1 ** (k + 1), 1 - b2 ** (k + 1)
        u = (mu / c1) / (np.sqrt(nu / c2) + eps) + wd * x
        x = np.asarray(jnp.asarray(x - lr * u, jnp.bfloat16), np.float64)

    got_x = np.asarray(unpack(px)["w"], np.float64)
    np.testing.assert_allclose(got_x, x, rtol=0, atol=2 * 2.0 ** -8 * np.abs(x).max())  # ≤2 bf16 ulps
    got_mu = np.asarray(unpack(st.mu)["w"], np.float64)
    np.testing.assert_allclose(got_mu, mu, rtol=3e-5, atol=3e-6)  # f32 moments vs f64
    assert int(st.count) == steps


# ---------------------------------------------------------------------------
# Pallas kernels (interpret mode) vs the shared jnp formulas
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [256, 300])  # aligned + lane-ragged
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_opt_step_kernels_match_ref(rng, n, dtype):
    x = jnp.asarray(rng.normal(size=(M, n)), dtype)
    g = jnp.asarray(rng.normal(size=(M, n)), dtype)
    mom = jnp.asarray(rng.normal(size=(M, n)), dtype)
    mu = jnp.asarray(rng.normal(size=(M, n)), jnp.float32)
    nu = jnp.abs(jnp.asarray(rng.normal(size=(M, n)), jnp.float32))
    lr, c1, c2 = jnp.float32(0.05), jnp.float32(0.1), jnp.float32(0.05)
    tol = dict(rtol=3e-7, atol=3e-7) if dtype == jnp.float32 else dict(rtol=1e-2, atol=1e-2)

    ref_out = opt_ref.sgd_update(x, g, mom, lr, momentum=0.9, nesterov=True, weight_decay=1e-4)
    with flags.force_pallas():
        k_out = opt_ops.sgd_step(x, g, mom, lr, momentum=0.9, nesterov=True, weight_decay=1e-4)
    for a, b in zip(ref_out, k_out):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32), **tol)

    ref_out = opt_ref.adamw_update(x, g, mu, nu, lr, c1, c2, b1=0.9, b2=0.95, eps=1e-8, weight_decay=1e-4)
    with flags.force_pallas():
        k_out = opt_ops.adamw_step(x, g, mu, nu, lr, c1, c2, b1=0.9, b2=0.95, eps=1e-8, weight_decay=1e-4)
    for a, b in zip(ref_out, k_out):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32), **tol)


def test_packed_step_not_used_without_capability(rng):
    """An optimizer without packed support must fall back to the per-leaf
    local step even under a packed strategy (and still be correct)."""
    from repro.optim.optimizers import Optimizer

    base = sgd(momentum=0.9, nesterov=True, weight_decay=0.0)
    crippled = Optimizer(init=base.init, step=base.step)  # no packed hooks
    assert not packed_capable(crippled) and packed_capable(base)
    cfg = AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, packed=True)
    params = _params(rng, bf16=False)
    s_c, s_r = _run_pair(cfg, crippled, params)
    assert not isinstance(s_c.opt, (PackedSGDState, PackedAdamState))
    _assert_tree(s_c.x, s_r.x, "sgd", "fallback.x")
