"""Adaptive-τ controller (beyond-paper extension; EXPERIMENTS.md §Perf)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import AlgoConfig
from repro.core import make_algorithm
from repro.core.adaptive import AdaptiveTau, TauScheduledTrainer, consensus_drift
from repro.models.classifier import init_mlp, mlp_loss
from repro.optim import schedules, sgd
from repro.training import make_round_step, make_train_state

M = 4


def test_controller_raises_tau_when_drift_small():
    c = AdaptiveTau(tau=2, lo=0.01, hi=0.05)
    assert c.update(drift=0.001, scale=1.0) == 4
    assert c.update(drift=0.0, scale=1.0) == 8


def test_controller_lowers_tau_when_drift_large():
    c = AdaptiveTau(tau=8, lo=0.01, hi=0.05)
    assert c.update(drift=0.5, scale=1.0) == 4
    assert c.update(drift=0.5, scale=1.0) == 2


def test_controller_clips():
    c = AdaptiveTau(tau=32, tau_max=32)
    assert c.update(0.0, 1.0) == 32
    c2 = AdaptiveTau(tau=1)
    assert c2.update(10.0, 1.0) == 1


def test_consensus_drift_zero_when_equal():
    x = {"w": jnp.ones((M, 3, 3))}
    d, s = consensus_drift(x)
    assert float(d) == 0.0 and float(s) > 0


def test_trainer_adapts_tau_end_to_end(rng):
    params, axes = init_mlp(jax.random.PRNGKey(0), 8, 4)
    algo_cache = {}

    def make_step(tau):
        algo = make_algorithm(AlgoConfig(name="overlap_local_sgd", tau=tau, alpha=0.6, anchor_beta=0.0))
        algo_cache[tau] = algo
        return jax.jit(make_round_step(mlp_loss, sgd(momentum=0.0), algo, schedules.constant(0.05), axes))

    ctrl = AdaptiveTau(tau=1, tau_max=8, lo=0.05, hi=0.5)
    trainer = TauScheduledTrainer(make_step, ctrl)
    state = make_train_state(params, M, sgd(momentum=0.0), make_algorithm(AlgoConfig(name="overlap_local_sgd", tau=1, alpha=0.6, anchor_beta=0.0)), axes)

    def batch_fn(tau):
        x = rng.normal(size=(tau, M, 16, 8)).astype(np.float32)
        y = rng.integers(0, 4, size=(tau, M, 16)).astype(np.int32)
        return jnp.asarray(x), jnp.asarray(y)

    taus = []
    for r in range(6):
        state, ms, tau = trainer.run_round(state, batch_fn)
        taus.append(tau)
        assert np.isfinite(np.asarray(ms["loss"])).all()
    # IID batches + pullback keep drift tiny → τ should have grown
    assert max(taus) > 1
    assert len(trainer._cache) == len(set(taus))  # compiled once per τ value
