"""Packed parameter plane: layout-table properties, pack∘unpack identity,
alignment invariants, mixed dtypes, stacked lead dims, and jit/scan
carry-ability of the Packed pytree."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel import packing as pk

LANE = pk.LANE


def _tree(rng, dtype=jnp.float32):
    return {
        "scalar": jnp.asarray(rng.normal(), dtype),
        "vec": jnp.asarray(rng.normal(size=(300,)), dtype),
        "mat": jnp.asarray(rng.normal(size=(17, 33)), dtype),
        "aligned": jnp.asarray(rng.normal(size=(2, LANE)), dtype),
        "nested": {"a": jnp.asarray(rng.normal(size=(3, 5, 7)), dtype)},
    }


def test_pack_unpack_identity(rng):
    tree = _tree(rng)
    packed = pk.pack(tree)
    out = pk.unpack(packed)
    assert jax.tree_util.tree_structure(out) == jax.tree_util.tree_structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_layout_alignment_invariants(rng):
    layout = pk.layout_of(_tree(rng))
    for slot in layout.slots:
        assert slot.offset % LANE == 0  # every leaf starts on a lane boundary
        assert slot.stride % LANE == 0
        assert slot.stride >= max(slot.size, 1)
        assert slot.stride - slot.size < LANE  # minimal padding
    for n in layout.bucket_sizes:
        assert n % LANE == 0
    # segments tile each bucket exactly
    for b in range(layout.num_buckets):
        segs = pk.leaf_segments(layout, b)
        assert sum(s.stride for s in segs) == layout.bucket_sizes[b]
        offs = [s.offset for s in segs]
        assert offs == sorted(offs)


def test_mixed_dtypes_bucket_separately(rng):
    tree = {
        "f32": jnp.asarray(rng.normal(size=(10,)), jnp.float32),
        "bf16": jnp.asarray(rng.normal(size=(200,)), jnp.bfloat16),
        "i32": jnp.arange(7, dtype=jnp.int32),
    }
    packed = pk.pack(tree)
    assert packed.layout.bucket_dtypes == ("bfloat16", "float32", "int32")
    assert [b.dtype.name for b in packed.buffers] == ["bfloat16", "float32", "int32"]
    out = pk.unpack(packed)
    for k in tree:
        assert out[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(np.asarray(tree[k], np.float32), np.asarray(out[k], np.float32))


def test_stacked_lead_dims_roundtrip(rng):
    m = 4
    tree = {
        "w": jnp.asarray(rng.normal(size=(m, 6, 9)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(m, 11)), jnp.float32),
    }
    packed = pk.pack(tree, lead=1)
    assert packed.lead_shape == (m,)
    assert all(b.shape[0] == m for b in packed.buffers)
    out = pk.unpack(packed)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(tree[k]), np.asarray(out[k]))


def test_view_leaf_matches_unpack(rng):
    tree = _tree(rng)
    packed = pk.pack(tree)
    leaves = jax.tree.leaves(tree)
    for i, leaf in enumerate(leaves):
        np.testing.assert_array_equal(np.asarray(pk.view_leaf(packed, i)), np.asarray(leaf))


def test_padding_lanes_are_zero(rng):
    tree = {"v": jnp.asarray(rng.normal(size=(5,)), jnp.float32)}
    packed = pk.pack(tree)
    buf = np.asarray(packed.buffers[0])
    assert buf.shape == (LANE,)
    assert np.all(buf[5:] == 0.0)


def test_layout_is_static_and_shape_only(rng):
    tree = _tree(rng)
    concrete = pk.layout_of(tree)
    abstract = pk.layout_of(jax.eval_shape(lambda: tree))
    assert concrete == abstract
    assert hash(concrete) == hash(abstract)
    # different shapes -> different table
    other = dict(tree, vec=jnp.zeros((301,), jnp.float32))
    assert pk.layout_of(other) != concrete


def test_packed_is_jit_and_scan_carryable(rng):
    tree = _tree(rng)
    packed = pk.pack(tree)

    @jax.jit
    def scale(p):
        return pk.buffer_map(lambda b: b * 2.0, p)

    out = pk.unpack(scale(packed))
    np.testing.assert_allclose(np.asarray(out["mat"]), 2.0 * np.asarray(tree["mat"]), rtol=1e-6)

    def body(carry, _):
        return pk.buffer_map(lambda b: b + 1.0, carry), None

    carried, _ = jax.lax.scan(body, packed, None, length=3)
    np.testing.assert_allclose(
        np.asarray(pk.unpack(carried)["vec"]), np.asarray(tree["vec"]) + 3.0, rtol=1e-6
    )


def test_packed_like_f32_shadow(rng):
    tree = {"w": jnp.asarray(rng.normal(size=(9,)), jnp.bfloat16)}
    packed = pk.pack(tree)
    shadow = pk.packed_like(packed, 0.0, dtype=jnp.float32)
    assert shadow.buffers[0].dtype == jnp.float32
    assert shadow.buffers[0].shape == packed.buffers[0].shape
    # same slots element-for-element: offsets/strides preserved
    assert [s.offset for s in shadow.layout.slots] == [s.offset for s in packed.layout.slots]


def test_empty_tree_packs_to_no_buffers():
    packed = pk.pack({})
    assert packed.buffers == ()
    assert pk.unpack(packed) == {}


@pytest.mark.parametrize("sizes", [(1,), (127,), (128,), (129,), (128 * 7,)])
def test_single_leaf_sizes_property(rng, sizes):
    tree = {"x": jnp.asarray(rng.normal(size=sizes), jnp.float32)}
    packed = pk.pack(tree)
    n = int(np.prod(sizes))
    assert packed.layout.bucket_sizes[0] == ((n + LANE - 1) // LANE) * LANE
    np.testing.assert_array_equal(np.asarray(pk.unpack(packed)["x"]), np.asarray(tree["x"]))


# ---------------------------------------------------------------------------
# ParamView: the lazy path-keyed window view plane-resident training reads
# params through (tentpole of the plane-resident PR)
# ---------------------------------------------------------------------------


def _nested_tree(rng):
    return {
        "tok_emb": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
        "blocks": {
            "attn": {"wq": jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)},
            "scale": jnp.asarray(rng.normal(size=(5,)), jnp.bfloat16),
        },
    }


def test_paramview_dict_protocol(rng):
    tree = _nested_tree(rng)
    view = pk.ParamView(pk.pack(tree))
    # nested and slash-path access, get/contains/keys
    np.testing.assert_array_equal(np.asarray(view["tok_emb"]), np.asarray(tree["tok_emb"]))
    np.testing.assert_array_equal(
        np.asarray(view["blocks"]["attn"]["wq"]), np.asarray(tree["blocks"]["attn"]["wq"])
    )
    np.testing.assert_array_equal(
        np.asarray(view["blocks/attn/wq"]), np.asarray(tree["blocks"]["attn"]["wq"])
    )
    assert "blocks/attn" in view and "blocks/ffn" not in view
    assert view.get("missing") is None and view.get("tok_emb") is not None
    assert sorted(view["blocks"].keys()) == ["attn", "scale"]
    assert view["blocks"]["scale"].dtype == jnp.bfloat16
    with pytest.raises(KeyError):
        view["blocks/ffn"]


def test_paramview_flatten_matches_tree_order(rng):
    """jax.tree leaves of the view materialize in the source tree's flatten
    order — loss code written against tree.leaves sees identical values."""
    tree = _nested_tree(rng)
    view = pk.ParamView(pk.pack(tree))
    for a, b in zip(jax.tree.leaves(view), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_paramview_scan_over_stacked_lead(rng):
    """A stacked-layer subtree (leading layer dim) works as lax.scan xs: the
    scan slices the view's windows per iteration and rebuilds a concrete
    view with the same access protocol — the transformer's
    scan-over-blocks body."""
    n = 3
    tree = {"seg": {"w": jnp.asarray(rng.normal(size=(n, 4, 4)), jnp.float32),
                    "b": jnp.asarray(rng.normal(size=(n, 4)), jnp.float32)}}
    view = pk.ParamView(pk.pack(tree))

    def body(x, prm):
        assert isinstance(prm, pk.ParamView)  # concrete view inside the scan
        return jnp.tanh(x @ prm["w"] + prm["b"]), None

    x0 = jnp.ones((4,))
    out, _ = jax.lax.scan(body, x0, view["seg"])
    ref = x0
    for i in range(n):
        ref = jnp.tanh(ref @ tree["seg"]["w"][i] + tree["seg"]["b"][i])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


def test_paramview_grad_is_flat_bucket_cotangent(rng):
    """Differentiating a loss written against the view, with the plane as
    the primal, yields per-bucket cotangent buffers bitwise equal to
    packing the per-leaf gradient tree (padding lanes zero)."""
    tree = _nested_tree(rng)
    px = pk.pack(tree)

    def loss_plane(p):
        v = pk.ParamView(p)
        return (
            jnp.sum(jnp.square(v["tok_emb"]))
            + jnp.sum(v["blocks/attn/wq"] * 2.0)
            + jnp.sum(v["blocks"]["scale"].astype(jnp.float32))
        )

    def loss_tree(t):
        return (
            jnp.sum(jnp.square(t["tok_emb"]))
            + jnp.sum(t["blocks"]["attn"]["wq"] * 2.0)
            + jnp.sum(t["blocks"]["scale"].astype(jnp.float32))
        )

    g_plane = jax.grad(loss_plane)(px)
    g_tree = jax.grad(loss_tree)(tree)
    assert isinstance(g_plane, pk.Packed)
    ref = pk.pack(g_tree, layout=px.layout)
    for a, b in zip(g_plane.buffers, ref.buffers):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_paramview_partial_read_grad(rng):
    """A loss touching only SOME leaves still gets a full-plane cotangent
    with zeros in the untouched (and padding) lanes."""
    tree = _nested_tree(rng)
    px = pk.pack(tree)
    g = jax.grad(lambda p: jnp.sum(pk.ParamView(p)["tok_emb"]))(px)
    out = pk.unpack(g)
    np.testing.assert_array_equal(np.asarray(out["tok_emb"]), np.ones((8, 4), np.float32))
    np.testing.assert_array_equal(np.asarray(out["blocks"]["attn"]["wq"]), np.zeros((4, 4), np.float32))
