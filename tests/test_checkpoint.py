"""Checkpoint round-trip of the full TrainState."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore, save
from repro.config import AlgoConfig
from repro.core import make_algorithm
from repro.models.classifier import init_mlp
from repro.optim import sgd
from repro.training import make_train_state


def test_trainstate_roundtrip(tmp_path, rng):
    params, axes = init_mlp(jax.random.PRNGKey(0), 8, 4)
    algo = make_algorithm(AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=0.7))
    state = make_train_state(params, 4, sgd(), algo, axes)
    # perturb so fields differ
    state = state._replace(step=jnp.asarray(17, jnp.int32))
    path = str(tmp_path / "ckpt.npz")
    save(path, state)
    template = make_train_state(params, 4, sgd(), algo, axes)
    restored = restore(path, template)
    assert int(restored.step) == 17
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dtype_preserved(tmp_path):
    tree = {"a": jnp.ones((3,), jnp.bfloat16), "b": {"c": jnp.arange(4, dtype=jnp.int32)}}
    path = str(tmp_path / "t.npz")
    save(path, tree)
    out = restore(path, tree)
    assert out["a"].dtype == jnp.bfloat16
    assert out["b"]["c"].dtype == jnp.int32
