"""Checkpoint round-trip of the full TrainState — per-leaf, plane-resident,
and cross-format (packed checkpoint ↔ per-leaf template via the stored
layout sidecar)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, save
from repro.config import AlgoConfig
from repro.core import make_algorithm, make_strategy
from repro.models.classifier import init_mlp, mlp_loss
from repro.optim import adamw, schedules, sgd
from repro.parallel.packing import Packed, unpack
from repro.training import make_round_step, make_train_state


def test_trainstate_roundtrip(tmp_path, rng):
    params, axes = init_mlp(jax.random.PRNGKey(0), 8, 4)
    algo = make_algorithm(AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=0.7))
    state = make_train_state(params, 4, sgd(), algo, axes)
    # perturb so fields differ
    state = state._replace(step=jnp.asarray(17, jnp.int32))
    path = str(tmp_path / "ckpt.npz")
    save(path, state)
    template = make_train_state(params, 4, sgd(), algo, axes)
    restored = restore(path, template)
    assert int(restored.step) == 17
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _unp(v):
    if isinstance(v, Packed):
        return unpack(v)
    if isinstance(v, tuple) and hasattr(v, "_fields"):
        return type(v)(*(_unp(f) for f in v))
    return v


def _trained_pair(opt, rounds=2):
    """A plane-resident state and a per-leaf state trained on identical
    batches (so every slot — momentum, anchor, inflight — is non-trivial)."""
    params, axes = init_mlp(jax.random.PRNGKey(0), 8, 4)
    cfg = AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=0.7, packed=True)
    states, steps = [], []
    for c in (cfg, dataclasses.replace(cfg, packed=False)):
        strat = make_strategy(c)
        states.append(make_train_state(params, 4, opt, strat, axes))
        steps.append(jax.jit(make_round_step(mlp_loss, opt, strat, schedules.constant(0.05), axes)))
    rng = np.random.default_rng(3)
    for _ in range(rounds):
        x = jnp.asarray(rng.normal(size=(2, 4, 8, 8)), jnp.float32)
        y = jnp.asarray(rng.integers(0, 4, size=(2, 4, 8)), jnp.int32)
        states = [step(s, (x, y))[0] for step, s in zip(steps, states)]
    assert isinstance(states[0].x, Packed) and not isinstance(states[1].x, Packed)
    return states, (cfg, params, axes)


def _fresh_template(cfg, params, axes, opt, packed: bool):
    c = cfg if packed else dataclasses.replace(cfg, packed=False)
    return make_train_state(params, 4, opt, make_strategy(c), axes)


@pytest.mark.parametrize("opt_name", ["sgd", "adamw"])
def test_plane_resident_roundtrip(tmp_path, opt_name):
    """Satellite: native round-trip of a plane-resident TrainState — the
    Packed x/opt/vars/inflight buffers restore bit-exact."""
    opt = sgd() if opt_name == "sgd" else adamw()
    (s_p, _), (cfg, params, axes) = _trained_pair(opt)
    path = str(tmp_path / "plane.npz")
    save(path, s_p)
    restored = restore(path, _fresh_template(cfg, params, axes, opt, packed=True))
    assert isinstance(restored.x, Packed)
    for a, b in zip(jax.tree.leaves(s_p), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("opt_name", ["sgd", "adamw"])
def test_packed_checkpoint_restores_into_perleaf_template(tmp_path, opt_name):
    """Satellite: cross-format restore (packed checkpoint → packed=False
    template) via the stored layout sidecar — replaces the documented
    'packed checkpoints need a packed template' limitation. Values equal
    the per-leaf run trained on identical batches (sgd path is bitwise)."""
    opt = sgd() if opt_name == "sgd" else adamw()
    (s_p, s_l), (cfg, params, axes) = _trained_pair(opt)
    path = str(tmp_path / "packed.npz")
    save(path, s_p)
    restored = restore(path, _fresh_template(cfg, params, axes, opt, packed=False))
    assert not isinstance(restored.x, Packed)
    tol = dict(rtol=0, atol=0) if opt_name == "sgd" else dict(rtol=3e-7, atol=1e-7)
    for a, b in zip(jax.tree.leaves(restored.x), jax.tree.leaves(s_l.x)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **tol)
    # optimizer state converts too (incl. scalar count -> per-worker counts)
    if opt_name == "adamw":
        np.testing.assert_array_equal(np.asarray(restored.opt.count), np.asarray(s_l.opt.count))
        for a, b in zip(jax.tree.leaves(restored.opt.mu), jax.tree.leaves(s_l.opt.mu)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), **tol)
    else:
        for a, b in zip(jax.tree.leaves(restored.opt.momentum), jax.tree.leaves(s_l.opt.momentum)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), **tol)
    # anchor-shaped slots: restored per-leaf inflight equals the packed
    # run's inflight through the view
    for a, b in zip(jax.tree.leaves(restored.inflight), jax.tree.leaves(_unp(s_p.inflight))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("opt_name", ["sgd", "adamw"])
def test_perleaf_checkpoint_restores_into_packed_template(tmp_path, opt_name):
    """Satellite: the reverse direction — a per-leaf checkpoint packs into a
    plane-resident template using the template's layout table."""
    opt = sgd() if opt_name == "sgd" else adamw()
    (s_p, s_l), (cfg, params, axes) = _trained_pair(opt)
    path = str(tmp_path / "perleaf.npz")
    save(path, s_l)
    restored = restore(path, _fresh_template(cfg, params, axes, opt, packed=True))
    assert isinstance(restored.x, Packed)
    tol = dict(rtol=0, atol=0) if opt_name == "sgd" else dict(rtol=3e-7, atol=1e-7)
    for a, b in zip(jax.tree.leaves(unpack(restored.x)), jax.tree.leaves(s_l.x)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **tol)
    if opt_name == "adamw":
        assert restored.opt.count.shape == ()
        np.testing.assert_array_equal(np.asarray(restored.opt.count), np.asarray(s_l.opt.count[0]))
    for a, b in zip(jax.tree.leaves(_unp(restored.inflight)), jax.tree.leaves(s_l.inflight)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- crash-recovery determinism (ISSUE 7 satellite) --------------------------


def _round_batches(rounds, seed=3):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(rounds):
        x = jnp.asarray(rng.normal(size=(2, 4, 8, 8)), jnp.float32)
        y = jnp.asarray(rng.integers(0, 4, size=(2, 4, 8)), jnp.int32)
        out.append((x, y))
    return out


@pytest.mark.parametrize("opt_name", ["sgd", "adamw"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16], ids=["f32", "bf16"])
def test_crash_recovery_bitwise(tmp_path, opt_name, dtype):
    """Kill-and-restore determinism: checkpoint after round 2, discard the
    live state, restore, continue — bitwise-identical to the uninterrupted
    run for every {optimizer} × {param dtype} (bf16 round-trips losslessly
    through the npz f32 widening)."""
    opt = sgd() if opt_name == "sgd" else adamw()
    params, axes = init_mlp(jax.random.PRNGKey(0), 8, 4, dtype=dtype)
    cfg = AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=0.7, packed=True)
    strat = make_strategy(cfg)
    step = jax.jit(make_round_step(mlp_loss, opt, strat, schedules.constant(0.05), axes))
    batches = _round_batches(5)

    straight = make_train_state(params, 4, opt, strat, axes)
    for b in batches:
        straight = step(straight, b)[0]

    interrupted = make_train_state(params, 4, opt, strat, axes)
    for b in batches[:2]:
        interrupted = step(interrupted, b)[0]
    path = str(tmp_path / "crash.npz")
    save(path, interrupted)
    del interrupted  # the crash: only the checkpoint survives
    resumed = restore(path, _fresh_template(cfg, params, axes, opt, packed=True))
    for b in batches[2:]:
        resumed = step(resumed, b)[0]

    for a, b in zip(jax.tree.leaves(straight), jax.tree.leaves(resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("opt_name", ["sgd", "adamw"])
def test_crash_recovery_cross_format(tmp_path, opt_name):
    """Kill-and-restore across formats: a packed checkpoint written at round
    2 resumes in a per-leaf program and still matches the uninterrupted
    per-leaf run (bitwise for sgd; adamw pays the pack/unpack f32 rounding
    of its scalar-count conversion path, a few ULPs)."""
    opt = sgd() if opt_name == "sgd" else adamw()
    params, axes = init_mlp(jax.random.PRNGKey(0), 8, 4)
    cfg = AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=0.7, packed=True)
    strat_p, strat_l = make_strategy(cfg), make_strategy(dataclasses.replace(cfg, packed=False))
    step_p = jax.jit(make_round_step(mlp_loss, opt, strat_p, schedules.constant(0.05), axes))
    step_l = jax.jit(make_round_step(mlp_loss, opt, strat_l, schedules.constant(0.05), axes))
    batches = _round_batches(5)

    straight = make_train_state(params, 4, opt, strat_l, axes)
    for b in batches:
        straight = step_l(straight, b)[0]

    interrupted = make_train_state(params, 4, opt, strat_p, axes)
    for b in batches[:2]:
        interrupted = step_p(interrupted, b)[0]
    path = str(tmp_path / "crosscrash.npz")
    save(path, interrupted)
    resumed = restore(path, _fresh_template(cfg, params, axes, opt, packed=False))
    for b in batches[2:]:
        resumed = step_l(resumed, b)[0]

    tol = dict(rtol=0, atol=0) if opt_name == "sgd" else dict(rtol=3e-6, atol=1e-6)
    for a, b in zip(jax.tree.leaves(straight.x), jax.tree.leaves(resumed.x)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **tol)


# -- elastic restore (ISSUE 7 tentpole: elastic join/leave) -------------------


@pytest.mark.parametrize("m_new", [2, 6], ids=["shrink", "grow"])
def test_elastic_restore_resizes_worker_axis(tmp_path, m_new):
    """``restore(..., elastic=True)`` rehydrates a checkpoint into a
    template with a different worker count: shrink keeps the first m_new
    slots, grow seeds new slots from slot 0 (the harness re-syncs them from
    the anchor on their first round — DESIGN.md §7)."""
    opt = sgd()
    (s_p, _), (cfg, params, axes) = _trained_pair(opt)
    path = str(tmp_path / "elastic.npz")
    save(path, s_p)
    template = make_train_state(params, m_new, opt, make_strategy(cfg), axes)

    with pytest.raises(ValueError):
        restore(path, template)  # without elastic=, a resize is an error

    restored = restore(path, template, elastic=True)
    old_rows = jax.tree.leaves(unpack(s_p.x))
    new_rows = jax.tree.leaves(unpack(restored.x))
    for old, new in zip(old_rows, new_rows):
        old, new = np.asarray(old), np.asarray(new)
        assert new.shape[0] == m_new
        k = min(m_new, old.shape[0])
        np.testing.assert_array_equal(new[:k], old[:k])
        for j in range(old.shape[0], m_new):
            np.testing.assert_array_equal(new[j], old[0])


def test_elastic_restore_cross_format(tmp_path):
    """Elastic + cross-format at once: a packed m=4 checkpoint restores into
    an m=2 per-leaf template through the layout sidecar."""
    opt = sgd()
    (s_p, _), (cfg, params, axes) = _trained_pair(opt)
    path = str(tmp_path / "elastic_cross.npz")
    save(path, s_p)
    template = make_train_state(params, 2, opt, make_strategy(dataclasses.replace(cfg, packed=False)), axes)
    restored = restore(path, template, elastic=True)
    assert not isinstance(restored.x, Packed)
    for old, new in zip(jax.tree.leaves(unpack(s_p.x)), jax.tree.leaves(restored.x)):
        np.testing.assert_array_equal(np.asarray(new), np.asarray(old)[:2])


def test_dtype_preserved(tmp_path):
    tree = {"a": jnp.ones((3,), jnp.bfloat16), "b": {"c": jnp.arange(4, dtype=jnp.int32)}}
    path = str(tmp_path / "t.npz")
    save(path, tree)
    out = restore(path, tree)
    assert out["a"].dtype == jnp.bfloat16
    assert out["b"]["c"].dtype == jnp.int32
