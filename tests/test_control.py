"""Adaptive-τ control plane: controller, program cache, schedule, live fit.

Pins the DESIGN.md §6 subsystem:

* ``TauController`` decision logic — hysteresis band (no flapping at the
  edges), warmup/cooldown holds, τ_min/τ_max clamps, telemetry schema;
* the mutable-default fix — two controllers never share a history list;
* ``RoundProgramCache`` — ≤ O(log τ_max) compilations over a long
  adaptive run;
* the deprecating ``repro.core.adaptive`` shim;
* ``schedule_block`` — the dry-run's τ-schedule JSON block;
* ``Experiment.fit(adaptive_tau=...)`` end to end — τ actually grows on
  the IID task (low drift) and shrinks on the non-IID task (high drift),
  with the realized schedule in ``FitResult.tau_schedule``.
"""
import math
import warnings

import pytest

from repro.api import ClassificationSpec, Experiment, TauController
from repro.control import (
    AdaptiveTau,
    RoundProgramCache,
    per_tau_costs,
    runtime_algo,
    schedule_block,
    simulate_trajectory,
)

SCHEMA = {"round", "tau", "drift", "scale", "drift_ratio", "decision", "next_tau"}


# ---------------------------------------------------------------------------
# controller decisions
# ---------------------------------------------------------------------------


def test_grow_shrink_hold():
    c = TauController(tau=4, tau_min=1, tau_max=32, lo=0.01, hi=0.05)
    assert c.update(drift=0.005, scale=1.0) == 8  # ratio < lo → grow
    assert c.update(drift=0.03, scale=1.0) == 8  # in band → hold
    assert c.update(drift=0.2, scale=1.0) == 4  # ratio > hi → shrink
    assert [h["decision"] for h in c.history] == ["grow", "hold", "shrink"]


def test_hysteresis_band_edges_hold():
    """Ratios exactly on lo/hi hold τ — strict inequalities are the
    hysteresis band, so a boundary-riding signal cannot flap τ."""
    c = TauController(tau=4, lo=0.01, hi=0.05)
    assert c.update(drift=0.01, scale=1.0) == 4
    assert c.update(drift=0.05, scale=1.0) == 4
    assert [h["decision"] for h in c.history] == ["hold", "hold"]
    # a signal jittering anywhere inside [lo, hi] never moves τ
    c2 = TauController(tau=4, lo=0.01, hi=0.05)
    taus = [c2.update(drift=d, scale=1.0) for d in [0.011, 0.049, 0.01, 0.05, 0.03]]
    assert taus == [4] * 5
    assert {h["decision"] for h in c2.history} == {"hold"}


def test_warmup_holds_tau():
    c = TauController(tau=2, lo=0.01, hi=0.05, warmup_rounds=3)
    for _ in range(3):
        assert c.update(drift=0.001, scale=1.0) == 2  # would grow, but warmup
    assert c.update(drift=0.001, scale=1.0) == 4  # warmup over
    assert [h["decision"] for h in c.history] == ["warmup"] * 3 + ["grow"]


def test_cooldown_after_change():
    c = TauController(tau=2, lo=0.01, hi=0.05, cooldown_rounds=2)
    assert c.update(drift=0.001, scale=1.0) == 4  # grow, starts cooldown
    assert c.update(drift=0.001, scale=1.0) == 4  # cooldown 1
    assert c.update(drift=0.001, scale=1.0) == 4  # cooldown 2
    assert c.update(drift=0.001, scale=1.0) == 8  # free again
    assert [h["decision"] for h in c.history] == ["grow", "cooldown", "cooldown", "grow"]


def test_clamps():
    c = TauController(tau=32, tau_min=1, tau_max=32, lo=0.01, hi=0.05)
    assert c.update(drift=0.001, scale=1.0) == 32  # at tau_max
    assert c.history[-1]["decision"] == "clamp"
    c2 = TauController(tau=1, tau_min=1, tau_max=32, lo=0.01, hi=0.05)
    assert c2.update(drift=0.9, scale=1.0) == 1  # at tau_min
    assert c2.history[-1]["decision"] == "clamp"


def test_zero_scale_is_safe():
    c = TauController(tau=4, lo=0.01, hi=0.05)
    assert c.update(drift=1.0, scale=0.0) == 2  # huge ratio, no div-by-zero
    assert math.isfinite(c.history[-1]["drift_ratio"])


def test_telemetry_schema():
    c = TauController(tau=2, lo=0.01, hi=0.05)
    c.update(drift=0.001, scale=1.0)
    c.update(drift=0.03, scale=1.0)
    for i, h in enumerate(c.history):
        assert set(h) == SCHEMA
        assert h["round"] == i
        assert h["next_tau"] == (c.history[i + 1]["tau"] if i + 1 < len(c.history) else c.tau)
    assert c.taus_seen == [2, 4]


def test_history_not_shared_between_instances():
    """The legacy ``history: list = None`` mutable default is gone: fresh
    controllers get fresh lists."""
    a, b = TauController(), TauController()
    assert a.history is not b.history
    a.update(drift=0.001, scale=1.0)
    assert b.history == []
    a2, b2 = AdaptiveTau(), AdaptiveTau()
    assert a2.history is not b2.history


# ---------------------------------------------------------------------------
# program cache
# ---------------------------------------------------------------------------


def test_program_cache_compiles_once_per_tau():
    calls = []
    cache = RoundProgramCache(lambda tau: calls.append(tau) or (lambda s: (s, tau)))
    for tau in [1, 2, 1, 4, 2, 2, 4, 1]:
        prog = cache.program_for(tau)
        assert prog(None)[1] == tau
    assert calls == [1, 2, 4]
    assert cache.compilations == 3 == len(cache)
    assert cache.taus == [1, 2, 4] and 2 in cache and 8 not in cache


def test_adaptive_run_compiles_log_tau_max_programs():
    """50 controller-driven rounds touch at most log2(τ_max)+1 distinct τ
    values — the doubling/halving rule keeps the compile count logarithmic."""
    ctrl = TauController(tau=2, tau_min=1, tau_max=32, lo=0.01, hi=0.05)
    cache = RoundProgramCache(lambda tau: lambda s: s)
    t = 0
    for _ in range(50):
        tau = ctrl.tau
        cache.program_for(tau)
        ratio = ctrl.hi * math.sqrt(tau) / math.sqrt(1.0 + t)
        ctrl.update(drift=ratio, scale=1.0)
        t += tau
    bound = int(math.log2(ctrl.tau_max)) + 1
    assert cache.compilations <= bound
    assert set(cache.taus) == set(ctrl.taus_seen) or set(cache.taus) >= {h["tau"] for h in ctrl.history}


# ---------------------------------------------------------------------------
# legacy shim
# ---------------------------------------------------------------------------


def test_core_adaptive_shim_warns_and_forwards():
    import repro.control as control
    import repro.core.adaptive as legacy

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert legacy.AdaptiveTau is control.AdaptiveTau
        assert legacy.TauScheduledTrainer is control.TauScheduledTrainer
        assert legacy.consensus_drift is control.consensus_drift
    assert len(w) == 3 and all(issubclass(x.category, DeprecationWarning) for x in w)
    assert "repro.control" in str(w[0].message)
    assert set(legacy.__all__) <= set(dir(legacy))
    with pytest.raises(AttributeError):
        legacy.not_a_thing


# ---------------------------------------------------------------------------
# schedule / cost model
# ---------------------------------------------------------------------------


def test_runtime_algo_mapping():
    assert runtime_algo("overlap_local_sgd") == "overlap_local_sgd"
    assert runtime_algo("local_sgd") == "local_sgd"
    assert runtime_algo("delayed_avg") == "cocod"
    assert runtime_algo("sparse_anchor") == "overlap_local_sgd"
    assert runtime_algo("something_else") == "local_sgd"


def test_per_tau_costs_linear_in_tau_except_boundary():
    composed = dict(
        tau=2,
        parts={
            "block:attn": dict(mult=8.0, flops=10.0, bytes=4.0, coll=0.0),
            "optimizer": dict(mult=2.0, flops=1.0, bytes=2.0, coll=0.0),
            "boundary": dict(mult=1.0, flops=0.5, bytes=1.0, coll=3.0),
        },
    )
    rows = {r["tau"]: r for r in per_tau_costs(composed, [1, 2, 4])}
    # τ=2 reproduces the composed total exactly
    assert rows[2]["flops"] == pytest.approx(8 * 10 + 2 * 1 + 0.5)
    # τ-proportional parts halve/double; the boundary part does not
    assert rows[1]["flops"] == pytest.approx(4 * 10 + 1 * 1 + 0.5)
    assert rows[4]["coll"] == pytest.approx(3.0)  # collective cost is per-round
    assert rows[4]["bytes"] == pytest.approx(2 * (8 * 4 + 2 * 2) + 1.0)


def test_simulate_trajectory_sweeps_decisions():
    ctrl = TauController(tau=4, tau_min=1, tau_max=32, lo=0.01, hi=0.05)
    hist = simulate_trajectory(ctrl, 50)
    assert len(hist) == 50
    decisions = {h["decision"] for h in hist}
    assert "grow" in decisions  # the √(1+t) decay eventually relaxes τ
    assert all(ctrl.tau_min <= h["next_tau"] <= ctrl.tau_max for h in hist)


def test_schedule_block_structure():
    ctrl = TauController(tau=2, tau_min=1, tau_max=32, lo=0.01, hi=0.05)
    block = schedule_block("overlap_local_sgd", ctrl, rounds=40)
    assert set(block["controller"]) == {
        "tau0", "tau_min", "tau_max", "lo", "hi", "warmup_rounds", "cooldown_rounds",
    }
    assert block["rounds"] == 40 and len(block["trajectory"]) == 40
    assert block["total_local_steps"] == sum(t["tau"] for t in block["trajectory"])
    assert block["compiled_programs"] <= int(math.log2(32)) + 1
    assert block["compiled_programs"] == len(block["per_tau"])
    assert all(r["round_time_s"] > 0 for r in block["per_tau"])
    assert block["total_time_s"] > 0 and block["fixed_tau_time_s"] > 0
    for t in block["trajectory"]:
        assert set(t) == {"round", "tau", "drift_ratio", "decision", "next_tau"}


def test_schedule_block_with_composed_costs():
    composed = dict(
        tau=2,
        parts={"block:mlp": dict(mult=4.0, flops=7.0, bytes=3.0, coll=0.0),
               "boundary": dict(mult=1.0, flops=0.1, bytes=0.2, coll=5.0)},
    )
    ctrl = TauController(tau=2, tau_min=1, tau_max=8, lo=0.01, hi=0.05)
    block = schedule_block("local_sgd", ctrl, rounds=20, composed=composed)
    for row in block["per_tau"]:
        assert {"flops", "bytes", "coll"} <= set(row)
        assert row["coll"] == pytest.approx(5.0)  # per-round collective


# ---------------------------------------------------------------------------
# live adaptive fit (Experiment.fit(adaptive_tau=...))
# ---------------------------------------------------------------------------


def _fit(noniid, ctrl, rounds):
    exp = Experiment(
        task=ClassificationSpec(noniid=noniid, seed=0),
        strategy="overlap_local_sgd",
        workers=4,
        rounds=rounds,
        seed=0,
    )
    res = exp.fit(adaptive_tau=ctrl)
    return exp, res


def test_fit_adaptive_grows_tau_on_iid():
    """IID workers drift little → the controller lengthens the rounds."""
    ctrl = TauController(tau=1, tau_min=1, tau_max=8, lo=0.05, hi=0.5)
    exp, res = _fit(False, ctrl, rounds=6)
    assert res.tau_schedule is not None and len(res.tau_schedule) == 6
    assert max(h["next_tau"] for h in res.tau_schedule) > 1
    assert "grow" in {h["decision"] for h in res.tau_schedule}
    # steps counts the realized local steps, not rounds × a fixed τ
    assert res.steps == sum(h["tau"] for h in res.tau_schedule)
    # one compiled program per distinct τ, within the log bound
    assert len(exp.tau_programs) == len(set(h["tau"] for h in res.tau_schedule))
    assert len(exp.tau_programs) <= int(math.log2(ctrl.tau_max)) + 1
    for h in res.tau_schedule:
        assert set(h) == SCHEMA and h["drift_ratio"] > 0


def test_fit_adaptive_shrinks_tau_on_noniid():
    """Non-IID workers drift apart during long rounds → the controller cuts
    τ back; the IID run at the same thresholds holds (discriminating pair)."""
    shrink = TauController(tau=8, tau_min=1, tau_max=8, lo=0.01, hi=0.15)
    _, res = _fit(True, shrink, rounds=4)
    assert min(h["next_tau"] for h in res.tau_schedule) < 8
    assert "shrink" in {h["decision"] for h in res.tau_schedule}
    hold = TauController(tau=8, tau_min=1, tau_max=8, lo=0.01, hi=0.15)
    _, res_iid = _fit(False, hold, rounds=1)
    assert res_iid.tau_schedule[0]["decision"] == "hold"


def test_fit_adaptive_losses_decrease():
    ctrl = TauController(tau=2, tau_min=1, tau_max=8, lo=0.05, hi=0.5, warmup_rounds=1)
    _, res = _fit(False, ctrl, rounds=5)
    assert res.losses[-1] < res.losses[0]
    assert res.tau_schedule[0]["decision"] == "warmup"
