"""repro.api.Experiment facade: fit / evaluate / serve on both task families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ClassificationSpec, Experiment, TokenStream
from repro.config import AlgoConfig, OptimizerConfig
from repro.data import make_classification_splits
from repro.optim import schedules


def test_classification_fit_and_evaluate():
    exp = Experiment(
        task=ClassificationSpec(n=4000, holdout=1000, batch_per_worker=32),
        strategy=AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=0.7),
        optimizer=OptimizerConfig(name="sgd", lr=0.1, momentum=0.9, nesterov=True, weight_decay=0.0),
        workers=8,
    )
    res = exp.fit(steps=80)
    assert res.rounds == 40 and res.steps == 80
    assert np.isfinite(res.losses).all()
    assert res.final_loss < res.losses[0]
    acc = exp.evaluate()["test_acc"]
    assert acc > 0.4  # 10 classes; far above chance after 80 steps


def test_shared_splits_and_strategy_string():
    splits = make_classification_splits(4, n=2000, holdout=500)
    accs = {}
    for name in ("sync_sgd", "delayed_avg"):
        exp = Experiment(
            task=ClassificationSpec(splits=splits, batch_per_worker=16),
            strategy=name,
            optimizer=OptimizerConfig(name="sgd", lr=0.1, momentum=0.0),
            workers=4,
        )
        exp.fit(steps=30)
        accs[name] = exp.evaluate()["test_acc"]
    assert all(np.isfinite(v) for v in accs.values())


def test_workers_splits_mismatch_raises():
    splits = make_classification_splits(4, n=1000, holdout=200)
    exp = Experiment(task=ClassificationSpec(splits=splits), workers=8)
    with pytest.raises(ValueError):
        exp.build()


def test_arch_and_task_both_given_raises():
    with pytest.raises(ValueError):
        Experiment(arch="qwen2-7b", task=ClassificationSpec())


def test_lm_fit_evaluate_serve_roundtrip():
    exp = Experiment(
        arch="qwen2-7b",  # reduced() by default
        strategy=AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=0.7),
        optimizer=OptimizerConfig(name="sgd", lr=1e-2, momentum=0.9, nesterov=True, weight_decay=0.0),
        schedule=schedules.constant(1e-2),
        data=TokenStream(batch_per_worker=2, seq_len=32),
        workers=2,
        rounds=2,
    )
    res = exp.fit()
    assert len(res.losses) == 2 and np.isfinite(res.losses).all()
    ev = exp.evaluate(eval_batches=2)
    assert np.isfinite(ev["eval_loss"])

    eng = exp.serve(slots=2)
    rng = np.random.default_rng(0)
    for i in range(3):
        eng.submit(f"r{i}", rng.integers(0, exp.model_cfg.vocab_size, (4 + i,)).astype(np.int32), 4)
    out = eng.run()
    assert set(out) == {"r0", "r1", "r2"}
    assert all(len(v) == 4 for v in out.values())


def test_fit_continues_from_current_state():
    exp = Experiment(
        task=ClassificationSpec(n=1000, holdout=200, batch_per_worker=16),
        strategy="local_sgd",
        optimizer=OptimizerConfig(name="sgd", lr=0.05, momentum=0.0),
        workers=4,
    )
    exp.fit(rounds=3)
    step_after_first = int(exp.state.step)
    exp.fit(rounds=2)
    assert int(exp.state.step) == step_after_first + 2 * exp.tau


def test_serve_rejects_classification():
    exp = Experiment(task=ClassificationSpec(n=500, holdout=100), workers=2)
    with pytest.raises(ValueError):
        exp.serve()
