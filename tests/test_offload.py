"""Host-offloaded optimizer & anchor planes (DESIGN.md §9): golden parity +
budget regressions.

With ``AlgoConfig.offload`` the flat opt-state buckets and the anchor-shaped
slots (strategy vars, inflight collective) live host-side between round
boundaries as chunked :class:`repro.parallel.offload.HostPlane` trees, and
the engine streams them through the τ-step window — opt state chunk-by-chunk
inside the local-step scan (double-buffered: prefetch chunk i+1 while
applying chunk i), anchor slots once per round at the boundary. This suite
pins the contract three ways:

1. unit: the chunk grid round-trips bitwise for lane-ragged buckets, and
   ``tree_offload``/``tree_restore`` are exact inverses;
2. differential: offloaded training reproduces plane-resident training
   across {sgd, adamw} × {f32, bf16} × the pullback-family strategies —
   sgd bit-exact through full rounds, adamw bit-exact per streamed step
   with an amplification-aware few-ulp bound over full rounds (see
   ``_assert_tree``);
3. budget: the offloaded round program adds ZERO collectives to the
   local-step scan body, and each per-bucket chunk scan keeps at most
   ``n_state_planes`` staged chunks in its carry with exactly one prefetch
   ``dynamic_slice`` per plane in the body — ≤2 device staging buffers per
   state plane per dtype bucket, the double-buffer bound the dry-run's
   ``offload.staging_bytes_per_device`` reports.

On this CPU container there is no ``pinned_host`` memory space, so the host
placement is structural (``host_memory_kind()`` is None and the transfer
annotations are identity); the chunk grid, scan structure, and numerics are
exactly what a TPU run executes — only the memory-space annotation differs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import AlgoConfig
from repro.core import make_strategy
from repro.optim import adamw, schedules, sgd
from repro.optim.optimizers import offload_capable
from repro.parallel import offload as off
from repro.parallel.packing import LANE, Packed, pack
from repro.training import make_round_step, make_train_state

M = 4
# 512-byte chunks → 128-element (one-lane) chunks, so the few-hundred-element
# test buckets walk a real multi-chunk grid
_CHUNK_MB = 1 / 2048

from conftest import unpack_view as _unp  # packed-state pytree view


def _params(rng, bf16: bool):
    """Mixed-shape tree sized so every dtype bucket spans several chunks at
    ``_CHUNK_MB`` (bf16 adds a second bucket, like the golden suite)."""
    mat = jnp.bfloat16 if bf16 else jnp.float32
    return {
        "w0": jnp.asarray(rng.normal(size=(9, 33)), mat),
        "w1": jnp.asarray(rng.normal(size=(7, 41)), mat),
        "vec": jnp.asarray(rng.normal(size=(143,)), jnp.float32),
        "scalar": jnp.float32(rng.normal()),
        "b0": jnp.asarray(rng.normal(size=(37,)), mat),
    }


def _loss(params, batch):
    A, b = batch
    flat = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in jax.tree.leaves(params)])
    r = A @ flat - b
    loss = 0.5 * jnp.sum(r * r)
    return loss, dict(loss=loss)


def _run_pair(cfg: AlgoConfig, optimizer, params, rounds=2, lr=0.03, seed=1):
    """Run the offloaded and plane-resident configurations on identical
    batches; return the two final TrainStates (offloaded first)."""
    n_flat = sum(l.size for l in jax.tree.leaves(params))
    states, steps, strats = [], [], []
    for c in (dataclasses.replace(cfg, offload=True, offload_chunk_mb=_CHUNK_MB), cfg):
        strat = make_strategy(c)
        strats.append(strat)
        states.append(make_train_state(params, M, optimizer, strat, None))
        steps.append(jax.jit(make_round_step(_loss, optimizer, strat, schedules.constant(lr), None)))
    rng = np.random.default_rng(seed)
    for _ in range(rounds):
        A = jnp.asarray(rng.normal(size=(strats[0].tau, M, 4, n_flat)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(strats[0].tau, M, 4)), jnp.float32)
        states = [step(s, (A, b))[0] for step, s in zip(steps, states)]
    return states


STRATEGY_VARIANTS = [
    ("overlap_local_sgd", dict(anchor_beta=0.7)),
    ("local_sgd", {}),
    ("delayed_avg", dict(delay_steps=2)),  # mid-round consume (delay < tau)
    ("delayed_avg", dict(delay_steps=3)),  # boundary consume (delay = tau)
]

OPTIMIZERS = {
    "sgd": lambda: sgd(momentum=0.9, nesterov=True, weight_decay=1e-4),
    "adamw": lambda: adamw(b1=0.9, b2=0.95, eps=1e-8, weight_decay=1e-4),
}


def _assert_tree(tp, tr, opt_name, msg):
    """sgd: bitwise, full rounds included. adamw: the streamed step itself
    is bit-identical (test_streamed_step_matches_packed_bitwise), but inside
    the whole-round program XLA fuses the division/sqrt chain differently
    around the chunk scan, seeding ~1-ulp update differences that the test
    loss's gradient amplifies over τ·rounds steps (measured worst ≈ 4e-5
    relative after 2 rounds; a real bug is orders of magnitude beyond)."""
    for a, b in zip(jax.tree.leaves(tp), jax.tree.leaves(tr)):
        a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
        if opt_name == "sgd":
            np.testing.assert_array_equal(a, b, err_msg=msg)
        else:
            np.testing.assert_allclose(a, b, rtol=2e-4, atol=1e-6, err_msg=msg)


# ---------------------------------------------------------------------------
# unit: chunk grid + host-plane round trips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,c", [(1, 128), (128, 128), (129, 128), (765, 128), (765, 256), (300, 512)])
def test_chunk_roundtrip_exact(rng, n, c):
    for lead in ((), (M,)):
        x = jnp.asarray(rng.normal(size=lead + (n,)), jnp.float32)
        k = -(-n // c)
        ch = off.chunk_buffer(x, k, c)
        assert ch.shape == (k,) + lead + (c,)
        back = off.unchunk_buffer(ch, n)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_offload_plan_grid(rng):
    px = pack(jax.tree.map(lambda t: jnp.tile(t[None], (M,) + (1,) * t.ndim), _params(rng, True)), lead=1)
    plan = off.OffloadPlan.for_layout(px.layout, _CHUNK_MB)
    for n, c, k in zip(px.layout.bucket_sizes, plan.chunk_elems, plan.num_chunks):
        assert c % LANE == 0
        assert k == -(-int(n) // c)
        assert k > 1  # the test buckets must actually exercise the stream
    # default chunk size swallows these tiny buckets whole
    plan1 = off.OffloadPlan.for_layout(px.layout, off.DEFAULT_CHUNK_MB)
    assert all(k == 1 for k in plan1.num_chunks)


def test_tree_offload_restore_roundtrip(rng):
    px = pack(jax.tree.map(lambda t: jnp.tile(t[None], (M,) + (1,) * t.ndim), _params(rng, True)), lead=1)
    st = adamw().init_packed(px)
    plan = off.OffloadPlan.for_layout(px.layout, _CHUNK_MB)
    host = off.tree_offload(st, plan)
    assert off.is_offloaded(host) and not off.is_offloaded(st)
    assert off.plan_of(host) == plan and off.plan_of(st) is None
    assert off.host_nbytes(host) > 0
    # the scalar count passes through untouched; the moment planes chunk
    assert host.count.shape == ()
    back = off.tree_restore(host)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# differential: streamed step and full offloaded rounds vs plane-resident
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bf16", [False, True], ids=["f32", "bf16"])
@pytest.mark.parametrize("opt_name", sorted(OPTIMIZERS))
def test_streamed_step_matches_packed_bitwise(rng, opt_name, bf16):
    """One streamed local step (double-buffered chunk scan) is bit-identical
    to the fused plane-resident step, for every plane including the f32
    moment shadows — compared jit-to-jit so XLA fuses both the same way."""
    opt = OPTIMIZERS[opt_name]()
    px = pack(jax.tree.map(lambda t: jnp.tile(t[None], (M,) + (1,) * t.ndim), _params(rng, bf16)), lead=1)
    pg = jax.tree.map(lambda b: b * 0.01 + 0.003, px)
    lr = jnp.float32(0.05)
    plan = off.OffloadPlan.for_layout(px.layout, _CHUNK_MB)
    st = opt.init_packed(px)

    st_ref, px_ref = jax.jit(lambda o, x, g: opt.step_packed(o, x, g, lr))(st, px, pg)
    host = off.tree_offload(st, plan)
    host_new, px_new = jax.jit(lambda o, x, g: opt.step_streamed(o, x, g, lr))(host, px, pg)
    assert off.is_offloaded(host_new)

    for a, b in zip(jax.tree.leaves(_unp(px_new)), jax.tree.leaves(_unp(px_ref))):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
    for a, b in zip(jax.tree.leaves(_unp(off.tree_restore(host_new))), jax.tree.leaves(_unp(st_ref))):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


@pytest.mark.parametrize("bf16", [False, True], ids=["f32", "bf16"])
@pytest.mark.parametrize("opt_name", sorted(OPTIMIZERS))
@pytest.mark.parametrize("name,kw", STRATEGY_VARIANTS, ids=[f"{n}-{v}" for n, v in STRATEGY_VARIANTS])
def test_offloaded_round_matches_resident(name, kw, opt_name, bf16, rng):
    """ISSUE golden suite: full offloaded rounds — streamed opt state in the
    τ-scan, anchor/inflight restored and re-offloaded at the boundary —
    reproduce plane-resident training exactly: params, opt state, strategy
    vars, and the carried inflight collective."""
    cfg = AlgoConfig(name=name, tau=3, alpha=0.6, packed=True, **kw)
    optimizer = OPTIMIZERS[opt_name]()
    s_o, s_r = _run_pair(cfg, optimizer, _params(rng, bf16))

    # the offloaded run keeps x device-resident (it rides the scan carry)
    # and the opt/vars/inflight slots host-resident between rounds
    assert isinstance(s_o.x, Packed)
    assert off.is_offloaded(s_o.opt)
    assert not off.is_offloaded(s_r.opt)

    _assert_tree(_unp(s_o.x), _unp(s_r.x), opt_name, f"{name}.x")
    _assert_tree(
        _unp(off.tree_restore(s_o.opt)), _unp(s_r.opt), opt_name, f"{name}.opt"
    )
    pv, rv = _unp(off.tree_restore(s_o.inflight)), _unp(s_r.inflight)
    if pv is None or rv is None:
        assert (pv is None) == (rv is None)
    else:
        _assert_tree(pv, rv, opt_name, f"{name}.inflight")
    for f in ("z", "v", "extra"):
        pv = _unp(off.tree_restore(getattr(s_o.vars, f)))
        rv = _unp(getattr(s_r.vars, f))
        if pv is None or rv is None:
            assert (pv is None) == (rv is None)
            continue
        _assert_tree(pv, rv, opt_name, f"{name}.vars.{f}")


# ---------------------------------------------------------------------------
# budget: zero extra collectives, ≤2 staging buffers per plane per bucket
# ---------------------------------------------------------------------------

COLLECTIVES = ["psum", "all_reduce", "all_gather", "reduce_scatter", "ppermute", "all_to_all"]


def _count_primitives(jaxpr, names):
    counts = dict.fromkeys(names, 0)
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in counts:
            counts[eqn.primitive.name] += 1
        if eqn.primitive.name == "pallas_call":
            continue
        for v in eqn.params.values():
            sub = None
            if isinstance(v, jax.extend.core.ClosedJaxpr):
                sub = v.jaxpr
            elif hasattr(v, "eqns"):
                sub = v
            if sub is not None:
                for k, c in _count_primitives(sub, names).items():
                    counts[k] += c
    return counts


def _scan_eqns(jaxpr):
    """All scan equations at any depth (excluding pallas bodies)."""
    out = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            continue
        if eqn.primitive.name == "scan":
            out.append(eqn)
        for v in eqn.params.values():
            sub = None
            if isinstance(v, jax.extend.core.ClosedJaxpr):
                sub = v.jaxpr
            elif hasattr(v, "eqns"):
                sub = v
            if sub is not None:
                out.extend(_scan_eqns(sub))
    return out


def _round_jaxpr(params, opt_name="sgd", tau=3, offload=False):
    cfg = AlgoConfig(
        name="overlap_local_sgd", tau=tau, alpha=0.6, anchor_beta=0.7,
        packed=True, offload=offload, offload_chunk_mb=_CHUNK_MB,
    )
    strat = make_strategy(cfg)
    optimizer = OPTIMIZERS[opt_name]()
    state = make_train_state(params, M, optimizer, strat, None)
    step = make_round_step(_loss, optimizer, strat, schedules.constant(0.03), None)
    n_flat = sum(l.size for l in jax.tree.leaves(params))
    A = jnp.zeros((tau, M, 4, n_flat), jnp.float32)
    b = jnp.zeros((tau, M, 4), jnp.float32)
    return jax.make_jaxpr(step)(state, (A, b))


@pytest.mark.parametrize("opt_name", sorted(OPTIMIZERS))
def test_offload_adds_zero_collectives(rng, opt_name):
    """ISSUE acceptance: streaming the opt state through the window must not
    change the communication schedule — the offloaded round program has
    exactly the plane-resident program's collective count (and its local-step
    scan bodies contain none at all)."""
    params = _params(rng, bf16=True)
    j_res = _round_jaxpr(params, opt_name, offload=False)
    j_off = _round_jaxpr(params, opt_name, offload=True)
    c_res = _count_primitives(j_res.jaxpr, COLLECTIVES)
    c_off = _count_primitives(j_off.jaxpr, COLLECTIVES)
    assert c_off == c_res, (c_off, c_res)
    for eqn in _scan_eqns(j_off.jaxpr):
        body = eqn.params["jaxpr"].jaxpr
        assert sum(_count_primitives(body, COLLECTIVES).values()) == 0


@pytest.mark.parametrize("opt_name,n_planes", [("sgd", 1), ("adamw", 2)])
def test_double_buffer_staging_bound(rng, opt_name, n_planes):
    """ISSUE acceptance: the per-bucket chunk scan carries exactly the
    staged state chunks (``n_planes`` arrays) and its body issues exactly
    one prefetch ``dynamic_slice`` per plane — so at most 2 device staging
    buffers (applied + prefetched) per state plane per dtype bucket are ever
    live, the ``staging_bytes_per_device`` bound in dry-run JSONs."""
    params = _params(rng, bf16=True)
    px = pack(jax.tree.map(lambda t: jnp.tile(t[None], (M,) + (1,) * t.ndim), params), lead=1)
    n_buckets = len(px.layout.bucket_sizes)

    j_off = _round_jaxpr(params, opt_name, offload=True)
    scans = _scan_eqns(j_off.jaxpr)
    # the τ-step scan is the one whose body hosts the chunk scans
    tau_scans = [e for e in scans if _scan_eqns(e.params["jaxpr"].jaxpr)]
    assert len(tau_scans) == 1, [e.params["length"] for e in scans]
    chunk_scans = _scan_eqns(tau_scans[0].params["jaxpr"].jaxpr)
    assert len(chunk_scans) == n_buckets, (len(chunk_scans), n_buckets)
    for eqn in chunk_scans:
        assert eqn.params["num_carry"] == n_planes
        body = eqn.params["jaxpr"].jaxpr
        ds = _count_primitives(body, ["dynamic_slice"])["dynamic_slice"]
        assert ds == n_planes, (ds, n_planes)

    # the resident program has no chunk scans to begin with
    j_res = _round_jaxpr(params, opt_name, offload=False)
    res_scans = _scan_eqns(j_res.jaxpr)
    assert not any(_scan_eqns(e.params["jaxpr"].jaxpr) for e in res_scans)


# ---------------------------------------------------------------------------
# engine contract: construction, adoption, capability gate
# ---------------------------------------------------------------------------


def test_train_state_constructed_offloaded(rng):
    cfg = AlgoConfig(
        name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=0.7,
        packed=True, offload=True, offload_chunk_mb=_CHUNK_MB,
    )
    strat = make_strategy(cfg)
    opt = OPTIMIZERS["sgd"]()
    assert offload_capable(opt)
    s = make_train_state(_params(rng, True), M, opt, strat, None)
    assert isinstance(s.x, Packed)
    assert off.is_offloaded(s.opt) and off.is_offloaded(s.vars) and off.is_offloaded(s.inflight)
    plan = off.plan_of(s.opt)
    assert plan is not None and all(k > 1 for k in plan.num_chunks)


def test_offload_requires_streamed_optimizer(rng):
    """The engine refuses offload with an optimizer that has no streamed
    step — silently falling back to a resident step would leave the state
    device-side and blow the HBM budget the flag was set for."""
    base = OPTIMIZERS["sgd"]()
    crippled = dataclasses.replace(base, step_streamed=None)
    assert not offload_capable(crippled)
    cfg = AlgoConfig(
        name="overlap_local_sgd", tau=2, alpha=0.6, packed=True,
        offload=True, offload_chunk_mb=_CHUNK_MB,
    )
    strat = make_strategy(cfg)
    with pytest.raises(ValueError, match="offload"):
        make_round_step(_loss, crippled, strat, schedules.constant(0.03), None)


def test_offloaded_fault_resync_matches_resident():
    """Elastic membership composes with offload (DESIGN.md §9): a rejoining
    worker re-syncs from the anchor even though the anchor-shaped slots are
    host-resident between rounds — `_anchor_of` restores a read-only view.
    The whole faulted run stays bitwise-equal to the plane-resident one
    (SGD path). Regression: resync used to crash on a HostPlane inflight."""
    from repro.api import ClassificationSpec, Experiment
    from repro.fault.plan import FaultPlan

    def run(offload):
        exp = Experiment(
            task=ClassificationSpec(n=2000, holdout=500),
            strategy=AlgoConfig(
                name="overlap_local_sgd", tau=4, alpha=0.5, anchor_beta=0.7,
                offload=offload, offload_chunk_mb=_CHUNK_MB,
            ),
        )
        return exp.fit(rounds=6, faults=FaultPlan.parse("crash:1@2-5,slow:2x4", m=4, seed=7))

    r_off, r_res = run(True), run(False)
    assert [float(a) for a in r_off.losses] == [float(b) for b in r_res.losses]
    assert r_off.losses[-1] < r_off.losses[0]
    resyncs = [r for r in r_off.fault_log if r.get("resynced")]
    assert any(1 in r["resynced"] for r in resyncs), r_off.fault_log
