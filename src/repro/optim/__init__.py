from repro.optim import schedules
from repro.optim.optimizers import (
    AdamState,
    Optimizer,
    PackedAdamState,
    PackedSGDState,
    SGDState,
    adamw,
    clip_by_global_norm,
    clip_packed_by_global_norm,
    from_config,
    global_norm,
    packed_capable,
    packed_global_norm,
    sgd,
)

__all__ = [
    "AdamState",
    "Optimizer",
    "PackedAdamState",
    "PackedSGDState",
    "SGDState",
    "adamw",
    "clip_by_global_norm",
    "clip_packed_by_global_norm",
    "from_config",
    "global_norm",
    "packed_capable",
    "packed_global_norm",
    "schedules",
    "sgd",
]
