from repro.optim import schedules
from repro.optim.optimizers import (
    AdamState,
    Optimizer,
    SGDState,
    adamw,
    clip_by_global_norm,
    from_config,
    global_norm,
    sgd,
)

__all__ = [
    "AdamState",
    "Optimizer",
    "SGDState",
    "adamw",
    "clip_by_global_norm",
    "from_config",
    "global_norm",
    "schedules",
    "sgd",
]
