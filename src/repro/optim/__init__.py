from repro.optim import schedules
from repro.optim.optimizers import (
    AdamState,
    Optimizer,
    PackedAdamState,
    PackedSGDState,
    SGDState,
    adamw,
    clip_by_global_norm,
    from_config,
    global_norm,
    packed_capable,
    sgd,
)

__all__ = [
    "AdamState",
    "Optimizer",
    "PackedAdamState",
    "PackedSGDState",
    "SGDState",
    "adamw",
    "clip_by_global_norm",
    "from_config",
    "global_norm",
    "packed_capable",
    "schedules",
    "sgd",
]
