"""Learning-rate schedules.

Includes the paper's schedule (linear warmup for the first epochs, step decay
by ``decay_factor`` at given boundaries — CIFAR-10 recipe of Goyal et al. [4]
as used in §4) plus cosine for the LM examples.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax.numpy as jnp


def warmup_step_decay(
    base_lr: float,
    warmup_steps: int,
    boundaries: Sequence[int],
    decay_factor: float = 0.1,
) -> Callable:
    boundaries = tuple(boundaries)

    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        lr = jnp.asarray(base_lr, jnp.float32)
        for b in boundaries:
            lr = jnp.where(step >= b, lr * decay_factor, lr)
        if warmup_steps > 0:
            warm = base_lr * (step + 1.0) / warmup_steps
            lr = jnp.where(step < warmup_steps, warm, lr)
        return lr

    return schedule


def cosine(base_lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1) -> Callable:
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * (step + 1.0) / max(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup_steps, warm, base_lr * cos)

    return schedule


def constant(base_lr: float) -> Callable:
    def schedule(step):
        return jnp.full((), base_lr, jnp.float32)

    return schedule


def from_config(cfg) -> Callable:
    """Build a schedule from an OptimizerConfig."""
    if cfg.decay_steps:
        return warmup_step_decay(cfg.lr, cfg.warmup_steps, cfg.decay_steps, cfg.decay_factor)
    if cfg.warmup_steps:
        return warmup_step_decay(cfg.lr, cfg.warmup_steps, ())
    return constant(cfg.lr)
