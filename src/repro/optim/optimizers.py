"""Local optimizers (the per-worker update inside every distributed algorithm).

The paper uses SGD with Nesterov momentum for local updates; the momentum
buffer is updated *only from local gradients* (§2, Momentum Variant). AdamW is
provided for the LM examples (§6 of the paper notes the technique extends to
Adam).

Functional style: ``init(params) -> state``, ``step(state, params, grads, lr)
-> (state, params)``. All states preserve parameter dtype; Adam moments are
kept in f32.

Packed path (``init_packed``/``step_packed``): the τ local updates that
dominate each round are pure memory-bound sweeps, yet the per-leaf step pays
~5 XLA ops *per pytree leaf*. The packed path instead keeps the optimizer
state as :class:`repro.parallel.packing.Packed` flat buffers between round
boundaries — SGD momentum in the parameter-dtype buckets, AdamW mu/nu as f32
shadow buckets element-aligned with the parameter plane, and a *single*
scalar step count shared by all workers (they step in lockstep, so the
per-leaf path's vmapped per-worker count is redundant bookkeeping) — and
applies the whole update chain through the fused ``kernels/opt_step`` ops:
one kernel launch per dtype bucket per local step instead of O(leaves) ops.
The per-leaf ``init``/``step`` stay as the bit-exact oracle; the golden
differential suite (tests/test_packed_optim.py) pins packed to per-leaf for
every optimizer × dtype × strategy combination.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import OptimizerConfig
from repro.kernels.opt_step import ops as opt_ops
from repro.parallel import offload
from repro.parallel.packing import Packed, buffer_map, packed_like, view_leaf


class SGDState(NamedTuple):
    momentum: dict  # pytree like params


class AdamState(NamedTuple):
    mu: dict
    nu: dict
    count: jnp.ndarray


class PackedSGDState(NamedTuple):
    momentum: Packed  # worker-stacked parameter-dtype plane, like packed x


class PackedAdamState(NamedTuple):
    mu: Packed  # f32 shadow of the worker-stacked parameter plane
    nu: Packed
    count: jnp.ndarray  # ONE scalar for all workers/leaves (lockstep steps)


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    step: Callable  # (state, params, grads, lr) -> (state, params)
    # packed-plane variants (None = per-leaf only):
    #   init_packed(px: Packed) -> packed state
    #   step_packed(state, px: Packed, pg: Packed, lr) -> (state, px_new)
    init_packed: Optional[Callable] = None
    step_packed: Optional[Callable] = None
    # host-offload variant (None = resident only): same update as
    # step_packed but with the state planes host-resident as HostPlanes,
    # streamed chunk-by-chunk through offload.streamed_update:
    #   step_streamed(state, px: Packed, pg: Packed, lr) -> (state, px_new)
    step_streamed: Optional[Callable] = None


def packed_capable(opt: Optimizer) -> bool:
    """Whether ``opt`` supports the packed local-step path."""
    return opt.init_packed is not None and opt.step_packed is not None


def offload_capable(opt: Optimizer) -> bool:
    """Whether ``opt`` supports the host-offloaded streamed local step."""
    return packed_capable(opt) and opt.step_streamed is not None


def offload_state(state, plan: offload.OffloadPlan):
    """Host-offload a packed opt state: every ``Packed`` plane becomes a
    chunked :class:`~repro.parallel.offload.HostPlane`; scalars (the Adam
    count) stay device-resident."""
    return offload.tree_offload(state, plan)


def _apply_weight_decay(grads, params, wd):
    if wd == 0.0:
        return grads
    return jax.tree.map(lambda g, p: g + wd * p.astype(g.dtype), grads, params)


def sgd(momentum: float = 0.9, nesterov: bool = True, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return SGDState(momentum=jax.tree.map(jnp.zeros_like, params))

    def step(state: SGDState, params, grads, lr):
        grads = _apply_weight_decay(grads, params, weight_decay)
        new_m = jax.tree.map(lambda m, g: (momentum * m + g).astype(m.dtype), state.momentum, grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: momentum * m + g, new_m, grads)
        else:
            upd = new_m
        new_p = jax.tree.map(lambda p, u: (p - lr * u).astype(p.dtype), params, upd)
        return SGDState(momentum=new_m), new_p

    def init_packed(px: Packed) -> PackedSGDState:
        return PackedSGDState(momentum=packed_like(px, 0.0))

    def step_packed(state: PackedSGDState, px: Packed, pg: Packed, lr):
        outs = [
            opt_ops.sgd_step(bx, bg, bm, lr, momentum=momentum, nesterov=nesterov, weight_decay=weight_decay)
            for bx, bg, bm in zip(px.buffers, pg.buffers, state.momentum.buffers)
        ]
        px_new = Packed(tuple(o[0] for o in outs), px.layout)
        m_new = Packed(tuple(o[1] for o in outs), state.momentum.layout)
        return PackedSGDState(momentum=m_new), px_new

    def step_streamed(state: PackedSGDState, px: Packed, pg: Packed, lr):
        # same fused kernel as step_packed, applied per chunk: sgd_step is
        # elementwise, so the chunked walk is bitwise-identical to the
        # whole-bucket sweep (the zero-padded tail maps to zero and is
        # dropped on unchunk)
        def apply_chunk(x_c, g_c, m_c):
            return opt_ops.sgd_step(
                x_c, g_c, m_c, lr, momentum=momentum, nesterov=nesterov, weight_decay=weight_decay
            )

        px_new, (m_new,) = offload.streamed_update(apply_chunk, (state.momentum,), px, pg)
        return PackedSGDState(momentum=m_new), px_new

    return Optimizer(
        init=init,
        step=step,
        init_packed=init_packed,
        step_packed=step_packed,
        step_streamed=step_streamed,
    )


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(mu=jax.tree.map(f32, params), nu=jax.tree.map(f32, params), count=jnp.zeros((), jnp.int32))

    def step(state: AdamState, params, grads, lr):
        count = state.count + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.nu, grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(p, m, v):
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p - lr * u).astype(p.dtype)

        new_p = jax.tree.map(upd, params, mu, nu)
        return AdamState(mu=mu, nu=nu, count=count), new_p

    def init_packed(px: Packed) -> PackedAdamState:
        # f32 moment buckets element-aligned with the parameter plane (same
        # offsets/strides, retagged dtype) — for bf16 params this is the
        # clean form of the per-leaf path's awkward mixed-dtype moment trees
        return PackedAdamState(
            mu=packed_like(px, 0.0, dtype=jnp.float32),
            nu=packed_like(px, 0.0, dtype=jnp.float32),
            count=jnp.zeros((), jnp.int32),
        )

    def step_packed(state: PackedAdamState, px: Packed, pg: Packed, lr):
        count = state.count + 1
        # bias corrections: scalar work, computed ONCE per step (the per-leaf
        # path recomputes them per worker under vmap — same values)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        outs = [
            opt_ops.adamw_step(bx, bg, bmu, bnu, lr, c1, c2, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay)
            for bx, bg, bmu, bnu in zip(px.buffers, pg.buffers, state.mu.buffers, state.nu.buffers)
        ]
        px_new = Packed(tuple(o[0] for o in outs), px.layout)
        mu_new = Packed(tuple(o[1] for o in outs), state.mu.layout)
        nu_new = Packed(tuple(o[2] for o in outs), state.nu.layout)
        return PackedAdamState(mu=mu_new, nu=nu_new, count=count), px_new

    def step_streamed(state: PackedAdamState, px: Packed, pg: Packed, lr):
        count = state.count + 1
        # bias corrections stay scalar, once per step, OUTSIDE the chunk
        # scan — identical values to step_packed
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def apply_chunk(x_c, g_c, mu_c, nu_c):
            return opt_ops.adamw_step(
                x_c, g_c, mu_c, nu_c, lr, c1, c2, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay
            )

        px_new, (mu_new, nu_new) = offload.streamed_update(
            apply_chunk, (state.mu, state.nu), px, pg
        )
        return PackedAdamState(mu=mu_new, nu=nu_new, count=count), px_new

    return Optimizer(
        init=init,
        step=step,
        init_packed=init_packed,
        step_packed=step_packed,
        step_streamed=step_streamed,
    )


def global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.vdot(x.astype(jnp.float32), x.astype(jnp.float32)) for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def packed_global_norm(pg: Packed, per_bucket: bool = False) -> jnp.ndarray:
    """Global gradient norm of a packed plane.

    ``per_bucket=False`` (the default) walks the layout slots and reduces
    each leaf's window separately, in flatten order — the *same* f32
    summation order as :func:`global_norm` on the pytree, so the result is
    bitwise identical and the plane-resident step keeps the golden pin even
    with clipping on. ``per_bucket=True`` (``AlgoConfig.packed_clip``) is
    the O(buckets) form: one partial square-sum per dtype bucket (padding
    lanes are zero, so they contribute nothing) feeding the one global
    scale — a different summation order, within a few ulps of the per-leaf
    walk."""
    if per_bucket:
        sq = sum(jnp.sum(jnp.square(b.astype(jnp.float32))) for b in pg.buffers)
    else:
        sq = sum(
            jnp.vdot(v.astype(jnp.float32), v.astype(jnp.float32))
            for v in (view_leaf(pg, s.index) for s in pg.layout.slots)
        )
    return jnp.sqrt(sq)


def clip_packed_by_global_norm(pg: Packed, max_norm: float, per_bucket: bool = False):
    """:func:`clip_by_global_norm` over the packed plane: the scale applies
    buffer-wise (elementwise identical to scaling each leaf)."""
    norm = packed_global_norm(pg, per_bucket=per_bucket)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return buffer_map(lambda b: (b * scale).astype(b.dtype), pg), norm


def from_config(cfg: OptimizerConfig) -> Optimizer:
    if cfg.name == "sgd":
        return sgd(cfg.momentum, cfg.nesterov, cfg.weight_decay)
    if cfg.name == "adamw":
        return adamw(cfg.adam_b1, cfg.adam_b2, cfg.adam_eps, cfg.weight_decay)
    raise ValueError(f"unknown optimizer {cfg.name}")
