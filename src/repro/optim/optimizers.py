"""Local optimizers (the per-worker update inside every distributed algorithm).

The paper uses SGD with Nesterov momentum for local updates; the momentum
buffer is updated *only from local gradients* (§2, Momentum Variant). AdamW is
provided for the LM examples (§6 of the paper notes the technique extends to
Adam).

Functional style: ``init(params) -> state``, ``step(state, params, grads, lr)
-> (state, params)``. All states preserve parameter dtype; Adam moments are
kept in f32.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import OptimizerConfig


class SGDState(NamedTuple):
    momentum: dict  # pytree like params


class AdamState(NamedTuple):
    mu: dict
    nu: dict
    count: jnp.ndarray


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    step: Callable  # (state, params, grads, lr) -> (state, params)


def _apply_weight_decay(grads, params, wd):
    if wd == 0.0:
        return grads
    return jax.tree.map(lambda g, p: g + wd * p.astype(g.dtype), grads, params)


def sgd(momentum: float = 0.9, nesterov: bool = True, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return SGDState(momentum=jax.tree.map(jnp.zeros_like, params))

    def step(state: SGDState, params, grads, lr):
        grads = _apply_weight_decay(grads, params, weight_decay)
        new_m = jax.tree.map(lambda m, g: (momentum * m + g).astype(m.dtype), state.momentum, grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: momentum * m + g, new_m, grads)
        else:
            upd = new_m
        new_p = jax.tree.map(lambda p, u: (p - lr * u).astype(p.dtype), params, upd)
        return SGDState(momentum=new_m), new_p

    return Optimizer(init=init, step=step)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(mu=jax.tree.map(f32, params), nu=jax.tree.map(f32, params), count=jnp.zeros((), jnp.int32))

    def step(state: AdamState, params, grads, lr):
        count = state.count + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.nu, grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(p, m, v):
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p - lr * u).astype(p.dtype)

        new_p = jax.tree.map(upd, params, mu, nu)
        return AdamState(mu=mu, nu=nu, count=count), new_p

    return Optimizer(init=init, step=step)


def global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.vdot(x.astype(jnp.float32), x.astype(jnp.float32)) for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def from_config(cfg: OptimizerConfig) -> Optimizer:
    if cfg.name == "sgd":
        return sgd(cfg.momentum, cfg.nesterov, cfg.weight_decay)
    if cfg.name == "adamw":
        return adamw(cfg.adam_b1, cfg.adam_b2, cfg.adam_eps, cfg.weight_decay)
    raise ValueError(f"unknown optimizer {cfg.name}")
