"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (v5e constants from
launch/mesh.py; cost_analysis numbers are per-partition, i.e. per chip):

    compute    = HLO_FLOPs / peak_FLOP/s
    memory     = HLO_bytes_accessed / HBM_bw
    collective = Σ collective operand bytes / ICI_bw   (per chip)

collective bytes are parsed from the compiled HLO text: every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
op's operand shapes are summed (start/done async pairs counted once).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1,
    "u8": 1,
    "s16": 2,
    "u16": 2,
    "bf16": 2,
    "f16": 2,
    "s32": 4,
    "u32": 4,
    "f32": 4,
    "s64": 8,
    "u64": 8,
    "f64": 8,
    "c64": 8,
    "c128": 16,
}

# e.g. "bf16[16,128]{1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|ragged-all-to-all)"
    r"(?:-start)?\("
)


def shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dtype, dims = m.groups()
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _line_operand_bytes(line: str, op_start: int) -> int:
    """Sum the result-side shapes of a collective op line (the bytes that hit
    the interconnect, per participating device). The result shape(s) —
    possibly a tuple — sit between '=' and the op name:
    ``%x = (bf16[4,8]{1,0}, f32[2]) all-reduce(...)``."""
    eq = line.find("=")
    if eq < 0 or op_start <= eq:
        return 0
    head = line[eq + 1 : op_start]
    total = 0
    for m in _SHAPE_RE.finditer(head):
        dtype, dims = m.groups()
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind {count, bytes} from compiled HLO text."""
    stats: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # async completion: counted at -start
        m = _COLLECTIVE_RE.search(line)
        if not m or "=" not in line[: m.start()]:
            continue
        kind = m.group(1)
        b = _line_operand_bytes(line, m.start())
        s = stats.setdefault(kind, {"count": 0, "bytes": 0})
        s["count"] += 1
        s["bytes"] += b
    return stats


@dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    collectives: dict
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def compute_s(self) -> float:
        return self.flops / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / self.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / self.ici_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s, "collective": self.collective_s}
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return dict(
            flops=self.flops,
            bytes_accessed=self.bytes_accessed,
            collective_bytes=self.collective_bytes,
            compute_s=self.compute_s,
            memory_s=self.memory_s,
            collective_s=self.collective_s,
            dominant=self.dominant,
            collectives=self.collectives,
        )


def analyze(compiled, hlo_text: Optional[str] = None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    colls = collective_stats(text)
    cbytes = sum(v["bytes"] for v in colls.values())
    return Roofline(flops=flops, bytes_accessed=bytes_acc, collective_bytes=cbytes, collectives=colls)


def model_flops(n_params_active: int, tokens: int, mode: str = "train") -> float:
    """MODEL_FLOPS = 6·N·D for a train step (2·N·D for inference forward)."""
    mult = 6.0 if mode == "train" else 2.0
    return mult * n_params_active * tokens
