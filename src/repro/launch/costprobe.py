"""Scan-corrected HLO cost accounting for the roofline analysis.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, so the
production round program (layers/τ/microbatch scans) under-reports FLOPs,
bytes and collective bytes by the trip-count product. Instead of unrolling
the 61-layer program (compile blow-up), we lower *component probes* with all
inner recurrences unrolled (``kernels.flags.unrolled_costs``) and compose:

    train:   Σ_kind n_layers · τ · n_micro · C(block fwd+bwd)
           + τ · n_micro · C(embed+head+CE fwd+bwd)
           + τ · C(optimizer step)
           + 1 · C(algorithm boundary)          ← the paper's pullback+anchor
    prefill: Σ_kind n_layers · C(block fwd) + C(embed+head fwd)
    decode:  same as prefill with 1-token inputs against the full cache

Each probe uses the exact production shapes and shardings, so per-device
numbers compose exactly (loop bodies are literally identical across
iterations). Memory analysis still comes from the full program.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.base import ArchConfig, InputShape, ModelConfig, ParallelPlan
from repro.core.strategy import CommStrategy, resolve_strategy
from repro.kernels import flags as kflags
from repro.launch import roofline as rl
from repro.launch import specs
from repro.models import params as PB
from repro.models import transformer as T
from repro.models.layers import rope as rope_mod
from repro.models.layers.norms import rmsnorm
from repro.parallel import sharding as sh
from repro.optim import optimizers as opt_mod


def _block_abstract(cfg: ModelConfig, kind: str):
    prm, axes = PB.build(T._init_block, jax.random.PRNGKey(0), cfg.param_dtype, cfg, kind, abstract=True)
    return prm, axes


def _shard_tree(mesh, rules, axes, sds, prefix=()):
    is_axes_leaf = lambda t: isinstance(t, tuple) and all(isinstance(e, (str, type(None))) for e in t)
    return jax.tree.map(
        lambda ax, s: NamedSharding(mesh, sh.fit_spec(sh.spec_for(tuple(prefix) + tuple(ax), rules), s.shape, mesh)),
        axes,
        sds,
        is_leaf=is_axes_leaf,
    )


def _cost(lowered) -> Dict[str, float]:
    compiled = lowered.compile()
    roof = rl.analyze(compiled)
    return dict(flops=roof.flops, bytes=roof.bytes_accessed, coll=roof.collective_bytes, collectives=roof.collectives)


def _rope_args(cfg: ModelConfig, b, s):
    a = cfg.attention
    if a is None or a.rope == "none":
        return None, None
    dim = a.qk_rope_head_dim if a.kind == "mla" else a.head_dim
    if a.rope == "mrope":
        return rope_mod.mrope_cos_sin(rope_mod.text_mrope_positions(b, s), dim, a.rope_theta, a.mrope_sections)
    return rope_mod.rope_cos_sin(rope_mod.text_positions(b, s), dim, a.rope_theta)


# ---------------------------------------------------------------------------
# probes
# ---------------------------------------------------------------------------


def probe_block_train(cfg: ModelConfig, kind: str, plan: ParallelPlan, mesh: Mesh, rules: dict, mb: int, s: int):
    m = plan.workers
    prm_sds, axes = _block_abstract(cfg, kind)
    prm_m = jax.tree.map(lambda t: jax.ShapeDtypeStruct((m,) + tuple(t.shape), t.dtype), prm_sds)
    prm_sh = _shard_tree(mesh, rules, axes, prm_m, prefix=("worker",))
    x_sds = jax.ShapeDtypeStruct((m, mb, s, cfg.d_model), cfg.param_dtype)
    x_sh = NamedSharding(mesh, sh.fit_spec(P("worker", "fsdp", None, None), x_sds.shape, mesh))

    def f(prm, x):
        def one(prm_i, x_i):
            cos, sin = _rope_args(cfg, mb, s)
            out, _, stats = T._apply_block(cfg, kind, prm_i, x_i, cos, sin, mode="train", cache=None, eps=cfg.norm_eps)
            l = jnp.sum(out.astype(jnp.float32) ** 2)
            if stats is not None:
                l = l + stats["aux_loss"]
            return l

        return jnp.sum(jax.vmap(one)(prm, x))

    g = jax.grad(f, argnums=(0, 1))
    with kflags.unrolled_costs():
        lowered = jax.jit(g, in_shardings=(prm_sh, x_sh)).lower(prm_m, x_sds)
    return _cost(lowered)


def probe_embed_head_train(cfg: ModelConfig, plan: ParallelPlan, mesh: Mesh, rules: dict, shape: InputShape, mb: int):
    m = plan.workers
    batch_sds = specs.train_batch_specs(cfg, shape, plan, tau=1)
    # (1, m, b, ...) -> (m, mb, ...)
    batch_sds = jax.tree.map(
        lambda t: jax.ShapeDtypeStruct((m, mb) + tuple(t.shape[3:]), t.dtype), batch_sds
    )
    batch_sh = jax.tree.map(
        lambda t: NamedSharding(mesh, sh.fit_spec(P("worker", "fsdp", *(None,) * (len(t.shape) - 2)), t.shape, mesh)),
        batch_sds,
    )
    full_sds, axes = T.init_model(cfg, jax.random.PRNGKey(0), abstract=True)
    keep = [k for k in full_sds if not k.startswith("seg") and k not in ("shared_block", "mtp")]
    prm_sds = {k: full_sds[k] for k in keep}
    prm_axes = {k: axes[k] for k in keep}
    prm_m = jax.tree.map(lambda t: jax.ShapeDtypeStruct((m,) + tuple(t.shape), t.dtype), prm_sds)
    prm_sh = _shard_tree(mesh, rules, prm_axes, prm_m, prefix=("worker",))

    def f(prm, batch):
        def one(prm_i, b_i):
            x, mask = T._embed(cfg, prm_i, b_i)
            hidden = rmsnorm(prm_i["final_norm"], x, cfg.norm_eps)
            logits = T._head(cfg, prm_i, hidden)
            tgt = b_i["targets"]
            fe = cfg.frontend
            if fe is not None and fe.kind == "vision":
                return T.softmax_xent(logits[:, -tgt.shape[1]:], tgt)
            return T.softmax_xent(logits, tgt)

        return jnp.sum(jax.vmap(one)(prm, batch))

    g = jax.grad(f)
    with kflags.unrolled_costs():
        lowered = jax.jit(g, in_shardings=(prm_sh, batch_sh)).lower(prm_m, batch_sds)
    return _cost(lowered)


def probe_optimizer(cfg: ModelConfig, plan: ParallelPlan, mesh: Mesh, rules: dict, optimizer):
    state_sds, axes = T.init_model(cfg, jax.random.PRNGKey(0), abstract=True)
    m = plan.workers
    x_m = jax.tree.map(lambda t: jax.ShapeDtypeStruct((m,) + tuple(t.shape), t.dtype), state_sds)
    x_sh = _shard_tree(mesh, rules, axes, x_m, prefix=("worker",))
    opt_sds = opt_mod.SGDState(momentum=x_m)
    opt_sh = opt_mod.SGDState(momentum=x_sh)

    def f(opt, x, g):
        return jax.vmap(lambda o, xi, gi: optimizer.step(o, xi, gi, 0.1))(opt, x, g)

    lowered = jax.jit(f, in_shardings=(opt_sh, x_sh, x_sh)).lower(opt_sds, x_m, x_m)
    return _cost(lowered)


def probe_boundary(cfg: ModelConfig, plan: ParallelPlan, mesh: Mesh, rules: dict, strategy: CommStrategy):
    """One ``boundary_round`` of a two-phase strategy — the production
    boundary program (plane-resident x for packed strategies, flat inflight
    slots), lowered through the same ``strategy_state_specs`` the dry-run's
    round program uses. The returned ``collectives`` dict is the boundary's
    collective schedule, surfaced in dry-run JSONs next to the
    ``boundary/*`` rows of BENCH_kernels.json."""
    from repro.parallel import mesh_context

    with mesh_context(mesh, rules):
        (x_sds, x_sh), (vars_sds, vars_sh), (inflight_sds, inflight_sh), axes = specs.strategy_state_specs(
            cfg, plan, strategy, mesh, rules
        )

        def f(x, vars, inflight):
            return strategy.boundary_round(x, vars, inflight, axes)

        lowered = jax.jit(f, in_shardings=(x_sh, vars_sh, inflight_sh)).lower(x_sds, vars_sds, inflight_sds)
    return _cost(lowered)


def probe_block_serve(cfg: ModelConfig, kind: str, mesh: Mesh, rules: dict, shape: InputShape, mode: str):
    prm_sds, axes = _block_abstract(cfg, kind)
    prm_sh = _shard_tree(mesh, rules, axes, prm_sds)
    b = shape.global_batch
    s = 1 if mode == "decode" else shape.seq_len
    if cfg.frontend is not None and cfg.frontend.kind == "vision" and mode != "decode":
        s = shape.seq_len  # total positions incl. image tokens
    batch_axes = rules["batch"]
    b_ax = tuple(batch_axes) if batch_axes else None
    x_sds = jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.param_dtype)
    x_sh = NamedSharding(mesh, sh.fit_spec(P(b_ax, None, None), x_sds.shape, mesh))

    cache_sds = cache_sh = None
    if mode == "decode":
        one = jax.eval_shape(lambda: T._init_block_cache(cfg, kind, b, shape.seq_len, cfg.param_dtype))
        cache_sds, cache_sh = specs.cache_tree_shardings(one, mesh, rules)

    def f(prm, x, cache):
        cos, sin = _rope_args(cfg, b, s) if mode != "decode" else _rope_args(cfg, b, 1)
        out, nc, _ = T._apply_block(cfg, kind, prm, x, cos, sin, mode=mode, cache=cache, eps=cfg.norm_eps)
        return out

    with kflags.unrolled_costs():
        lowered = jax.jit(f, in_shardings=(prm_sh, x_sh, cache_sh)).lower(prm_sds, x_sds, cache_sds)
    return _cost(lowered)


def probe_embed_head_serve(cfg: ModelConfig, mesh: Mesh, rules: dict, shape: InputShape, mode: str):
    full_sds, axes = T.init_model(cfg, jax.random.PRNGKey(0), abstract=True)
    keep = [k for k in full_sds if not k.startswith("seg") and k not in ("shared_block", "mtp")]
    prm_sds = {k: full_sds[k] for k in keep}
    prm_axes = {k: axes[k] for k in keep}
    prm_sh = _shard_tree(mesh, rules, prm_axes, prm_sds)
    if mode == "decode":
        in_sds, tok_sh = specs.decode_token_specs(cfg, shape, mesh, rules)
        in_sds = dict(tokens=in_sds)
        in_sh = dict(tokens=tok_sh)
    else:
        in_sds = specs.prefill_input_specs(cfg, shape)
        in_sh = specs.prefill_input_shardings(in_sds, mesh, rules)

    def f(prm, inputs):
        x, _ = T._embed(cfg, prm, inputs)
        hidden = rmsnorm(prm["final_norm"], x, cfg.norm_eps)
        return T._head(cfg, prm, hidden)

    with kflags.unrolled_costs():
        lowered = jax.jit(f, in_shardings=(prm_sh, in_sh)).lower(prm_sds, in_sds)
    return _cost(lowered)


def measure_host_bandwidth(nbytes: int = 64 << 20, iters: int = 3) -> dict:
    """Measured D2H/H2D bandwidth (GB/s) via timed committed ``device_put``
    round trips of one offload-chunk-sized buffer — the rate the offload
    plane's stream actually gets, not a datasheet constant. On the CPU
    backend this times the runtime's copy path, an honest stand-in for the
    pinned-host link real hardware streams over (DESIGN.md §9)."""
    import time as _time

    host = np.ones(max(nbytes, 1 << 20) // 4, np.float32)
    dev = jax.device_put(host)
    jax.block_until_ready(dev)
    h2d, d2h = [], []
    for _ in range(iters):
        t0 = _time.perf_counter()
        jax.block_until_ready(jax.device_put(host))
        h2d.append(_time.perf_counter() - t0)
        t0 = _time.perf_counter()
        # np.array (not asarray): the CPU backend exposes device buffers
        # zero-copy, which would time nothing — force the actual copy-out
        np.array(dev)
        d2h.append(_time.perf_counter() - t0)
    return dict(
        probe_bytes=int(host.nbytes),
        h2d_gbps=host.nbytes / min(h2d) / 1e9,
        d2h_gbps=host.nbytes / min(d2h) / 1e9,
    )


# ---------------------------------------------------------------------------
# composition
# ---------------------------------------------------------------------------


def _acc(total: dict, c: dict, mult: float, label: str):
    total["flops"] += mult * c["flops"]
    total["bytes"] += mult * c["bytes"]
    total["coll"] += mult * c["coll"]
    total["parts"][label] = dict(mult=mult, **{k: c[k] for k in ("flops", "bytes", "coll")})
    if c.get("collectives"):
        # per-kind {count, bytes} schedule of this component (one probe call)
        total["parts"][label]["collectives"] = c["collectives"]


def composed_cost(arch: ArchConfig, shape: InputShape, mesh: Mesh, plan: ParallelPlan, rules: dict, tau: int = 2, strategy: str = None, offload_stream_bytes: float = None) -> dict:
    from repro.optim import sgd
    from repro.parallel import mesh_context

    cfg, _variant = specs.model_for(arch, shape)
    total = dict(flops=0.0, bytes=0.0, coll=0.0, parts={})
    segs = T.segments(cfg)
    kind_counts: Dict[str, int] = {}
    for kind, n in segs:
        kind_counts[kind] = kind_counts.get(kind, 0) + n

    with mesh_context(mesh, rules):
        if shape.mode == "train":
            # resolve FIRST: sync-style strategies pin τ = 1, and every
            # per-step multiplier below must use the τ the round program
            # actually runs (the dry-run's lower_pair does the same)
            strat = resolve_strategy(specs.train_algo_config(plan, strategy, tau))
            tau = strat.tau
            total["strategy"] = strat.name
            total["tau"] = tau
            b_worker = shape.global_batch // plan.workers
            mb = min(arch.train_microbatch or b_worker, b_worker)
            n_micro = b_worker // mb
            for kind, n in kind_counts.items():
                c = probe_block_train(cfg, kind, plan, mesh, rules, mb, shape.seq_len if cfg.frontend is None or cfg.frontend.kind != "vision" else shape.seq_len)
                _acc(total, c, n * tau * n_micro, f"block:{kind}")
            c = probe_embed_head_train(cfg, plan, mesh, rules, shape, mb)
            _acc(total, c, tau * n_micro, "embed_head")
            c = probe_optimizer(cfg, plan, mesh, rules, sgd(0.9, True, 1e-4))
            _acc(total, c, tau, "optimizer")
            c = probe_boundary(cfg, plan, mesh, rules, strat)
            _acc(total, c, 1, "boundary")
            if offload_stream_bytes:
                # host-link bytes the offload plane streams per round (per
                # device) at the measured bandwidth. Deliberately NOT added
                # to total["bytes"] — those are HBM roofline bytes; the
                # stream rides a different resource and is priced by
                # runtime_model.offload_stream_time against the τ window.
                bw = measure_host_bandwidth()
                gbps = min(bw["d2h_gbps"], bw["h2d_gbps"])
                total["parts"]["offload_stream"] = dict(
                    mult=1,
                    bytes=float(offload_stream_bytes),
                    stream_s=float(offload_stream_bytes) / (gbps * 1e9),
                    **bw,
                )
        else:
            mode = "decode" if shape.mode == "decode" else "prefill"
            for kind, n in kind_counts.items():
                c = probe_block_serve(cfg, kind, mesh, rules, shape, mode)
                _acc(total, c, n, f"block:{kind}")
            c = probe_embed_head_serve(cfg, mesh, rules, shape, mode)
            _acc(total, c, 1, "embed_head")
    return total
