"""Production meshes.

``make_production_mesh`` is the assignment's fixed physical mesh: a v5e pod
is 16×16 = 256 chips with axes ("data", "model"); the multi-pod variant adds
a leading "pod" axis (2×16×16 = 512 chips, inter-pod links are the slow DCN
hop that Overlap-Local-SGD's anchor traffic hides).

Architectures reinterpret those devices through
``repro.parallel.logical_mesh`` as (worker, fsdp, tensor) — same devices,
same order (worker axis = slowest = pods first), different logical split per
ParallelPlan.

Functions, not module-level constants: importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax

from repro.parallel.sharding import make_auto_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def device_count(*, multi_pod: bool = False) -> int:
    return 512 if multi_pod else 256


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def make_smoke_mesh(workers: int = 2, fsdp: int = 2, tensor: int = 2):
    """Small host-device mesh for CI-scale sharding tests (8 devices)."""
    return make_auto_mesh((workers, fsdp, tensor), ("worker", "fsdp", "tensor"))
