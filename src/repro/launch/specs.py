"""Dry-run specifications: ShapeDtypeStruct stand-ins + NamedShardings for
every (architecture × input shape), with zero device allocation.

Train shapes lower the Overlap-Local-SGD round program (τ local steps +
pullback + anchor sync); decode shapes lower ``serve_step`` (one token vs a
seq_len cache); prefill lowers the full-sequence cache-building forward.

Sharding regimes:
* training — worker-stacked state; params P(worker, …param axes…)
* serving  — single model; request batch sharded over (worker×fsdp) i.e.
  data-parallel serving replicas when fsdp=1, one big sharded model when
  fsdp>1. long_500k (batch=1) shards the KV/window cache's *sequence* dim
  over those axes instead.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.base import AlgoConfig, ArchConfig, InputShape, ModelConfig, OptimizerConfig, ParallelPlan
from repro.core.strategy import AlgoVars, CommStrategy, PACKED_STACKED_AXES, _stacked_axes
from repro.models import transformer as T
from repro.optim import optimizers as opt_mod
from repro.parallel import offload as off
from repro.parallel import packing as pk
from repro.parallel import sharding as sh
from repro.training.train_state import TrainState

# rule tables ---------------------------------------------------------------

TRAIN_RULES = dict(sh.LOGICAL_RULES)

SERVE_RULES = dict(sh.LOGICAL_RULES)
SERVE_RULES["batch"] = ("worker", "fsdp")
SERVE_RULES["cache_seq"] = ()

LONG_RULES = dict(sh.LOGICAL_RULES)
LONG_RULES["batch"] = ()
LONG_RULES["cache_seq"] = ("worker", "fsdp")


def rules_for(shape: InputShape) -> dict:
    if shape.mode == "train":
        return TRAIN_RULES
    if shape.name == "long_500k":
        return LONG_RULES
    return SERVE_RULES


def optimized_rules(shape: InputShape) -> dict:
    """Beyond-paper §Perf variant (see EXPERIMENTS.md §Perf).

    Decode: weight-stationary pure-TP — model dims sharded over the full
    (fsdp × tensor) sub-mesh, embed replicated, KV-cache sequence sharded
    (flash-decoding). Eliminates the per-token ZeRO weight all-gathers that
    dominate the baseline's collective term (measured 29× collective-bytes
    reduction on mistral-large decode_32k).
    """
    base = rules_for(shape)
    if shape.mode != "decode":
        return base
    out = dict(base)
    out.update(
        {
            "batch": ("worker",) if shape.global_batch > 1 else (),
            "embed": (),
            "anchor_embed": (),
            "ff": ("fsdp", "tensor"),
            "act_ff": ("fsdp", "tensor"),
            "heads": ("fsdp", "tensor"),
            "kv_heads": ("fsdp", "tensor"),
            "act_heads": ("fsdp", "tensor"),
            "vocab": ("fsdp", "tensor"),
            "act_vocab": ("fsdp", "tensor"),
            "cache_seq": ("fsdp", "tensor"),
            "act_tokens": (),
        }
    )
    return out


# production training strategy ----------------------------------------------


def default_train_strategy(plan: ParallelPlan) -> str:
    """The production default: the paper's algorithm — except at w=1
    (arctic/deepseek single-pod), where Overlap-Local-SGD degenerates (no
    second replica to average with) and the honest program is the round
    WITHOUT anchor state. See DESIGN.md §Arch-applicability."""
    return "overlap_local_sgd" if plan.workers > 1 else "local_sgd"


def train_algo_config(
    plan: ParallelPlan,
    strategy: Optional[str] = None,
    tau: int = 2,
    topology: Optional[str] = None,
    offload: bool = False,
    offload_chunk_mb: Optional[float] = None,
) -> AlgoConfig:
    """The AlgoConfig the production lowering trains with (dry-run and cost
    probes resolve it through ``repro.api.resolve_strategy``, the exact
    chain ``Experiment`` uses). ``topology`` selects the gossip mixing-matrix
    family for ``gossip_pushsum`` (fixed-topology registry names like
    ``gossip_ring`` override it); other strategies ignore it. ``offload``
    turns on the host-offloaded state plane (DESIGN.md §9)."""
    return AlgoConfig(
        name=strategy or default_train_strategy(plan),
        tau=tau,
        alpha=0.6,
        anchor_beta=0.7,
        topology=topology or "full",
        offload=offload,
        offload_chunk_mb=float(offload_chunk_mb if offload_chunk_mb is not None else off.DEFAULT_CHUNK_MB),
    )


# model variant -------------------------------------------------------------


def model_for(arch: ArchConfig, shape: InputShape) -> Tuple[ModelConfig, str]:
    """Returns (model config, variant label). long_500k on full-attention
    archs runs the labelled sliding-window variant (DESIGN.md policy)."""
    cfg = arch.model
    if shape.name == "long_500k" and arch.long_context_policy == "swa_variant":
        if cfg.attention is not None and cfg.attention.sliding_window is None:
            att = dataclasses.replace(cfg.attention, sliding_window=arch.swa_variant_window)
            return dataclasses.replace(cfg, attention=att), "swa"
    return cfg, "faithful"


# input specs ---------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def train_batch_specs(cfg: ModelConfig, shape: InputShape, plan: ParallelPlan, tau: int):
    m = plan.workers
    assert shape.global_batch % m == 0, (shape.global_batch, m)
    b = shape.global_batch // m
    s = shape.seq_len
    fe = cfg.frontend
    if fe is not None and fe.kind == "audio":
        toks = _sds((tau, m, b, fe.num_codebooks, s), jnp.int32)
        return dict(tokens=toks, targets=toks)
    if fe is not None and fe.kind == "vision":
        s_img = fe.tokens_per_item
        s_text = s - s_img
        return dict(
            tokens=_sds((tau, m, b, s_text), jnp.int32),
            image_embeds=_sds((tau, m, b, s_img, fe.embed_dim), jnp.bfloat16),
            targets=_sds((tau, m, b, s_text), jnp.int32),
        )
    toks = _sds((tau, m, b, s), jnp.int32)
    return dict(tokens=toks, targets=toks)


def batch_shardings(batch_specs, mesh: Mesh, rules: dict):
    def one(s):
        # (tau, m, b, ...) -> P(None, worker, fsdp, ...)
        extra = (None,) * (len(s.shape) - 3)
        return NamedSharding(mesh, sh.fit_spec(P(None, "worker", "fsdp", *extra), s.shape, mesh))

    return jax.tree.map(one, batch_specs)


# train state specs ---------------------------------------------------------


def _axes_tree_shardings(ax_tree, sds_tree, mesh: Mesh, rules: dict):
    """Map a logical-axes tree (leaves = axes tuples, mirroring ``sds_tree``)
    to NamedShardings. A ``None`` node — the whole tree or any subtree —
    replicates the corresponding specs. An axes tuple facing a *subtree* of
    specs (e.g. the packed plane's ``("anchor_flat",)`` facing a ``Packed``
    of flat buffers) applies to every leaf of that subtree."""
    replicate = lambda sub: jax.tree.map(lambda s: NamedSharding(mesh, P()), sub)
    if ax_tree is None:
        return replicate(sds_tree)

    def one(ax, sub):
        if ax is None:
            return replicate(sub)
        return jax.tree.map(
            lambda s: NamedSharding(mesh, sh.fit_spec(sh.spec_for(ax, rules), s.shape, mesh)), sub
        )

    is_leaf = lambda t: t is None or (
        isinstance(t, tuple) and all(isinstance(e, (str, type(None))) for e in t)
    )
    return jax.tree.map(one, ax_tree, sds_tree, is_leaf=is_leaf)


def opt_state_specs(optimizer, strategy_packed: bool, x_sds, x_sh, mesh: Mesh, rules: dict):
    """Abstract optimizer state + shardings, mirroring the layout
    ``make_train_state`` actually builds.

    Packed (packed strategy + packed-capable optimizer): the state is flat
    worker-stacked buffers — one spec per dtype bucket under the
    ``("worker", "flat_param")`` rule (worker axis stacked, plane sharded
    over fsdp within a worker) instead of one per leaf; AdamW's f32 moment
    buckets follow the same rule and its single scalar count replicates.
    Per-leaf: momentum/moments mirror the stacked-parameter shardings
    leaf-for-leaf; the per-worker (m,) Adam count replicates.
    """
    packed = strategy_packed and opt_mod.packed_capable(optimizer)
    if packed:
        # plane-resident x_sds is already the packed plane; a per-leaf x_sds
        # (packed strategy with per-leaf x specs) packs abstractly here
        opt_sds = jax.eval_shape(
            lambda xs: optimizer.init_packed(xs if isinstance(xs, pk.Packed) else pk.pack(xs, lead=1)),
            x_sds,
        )

        def one(s):
            if len(s.shape) == 0:  # the shared scalar count
                return NamedSharding(mesh, P())
            return NamedSharding(mesh, sh.fit_spec(sh.spec_for(PACKED_STACKED_AXES, rules), s.shape, mesh))

        return opt_sds, jax.tree.map(one, opt_sds)
    opt_sds = jax.eval_shape(lambda xs: jax.vmap(optimizer.init)(xs), x_sds)
    if isinstance(opt_sds, opt_mod.AdamState):
        opt_sh = opt_mod.AdamState(mu=x_sh, nu=x_sh, count=NamedSharding(mesh, P()))
    else:
        opt_sh = opt_mod.SGDState(momentum=x_sh)
    return opt_sds, opt_sh


def strategy_state_specs(cfg: ModelConfig, plan: ParallelPlan, strategy: CommStrategy, mesh: Mesh, rules: dict, packed_x: Optional[bool] = None):
    """Abstract ``(x, vars, inflight)`` + shardings for one ``boundary_round``
    of a two-phase :class:`CommStrategy` — the boundary slice of
    :func:`train_state_specs`, shared with the cost probes
    (``launch/costprobe.py``) so the boundary they time is exactly the one
    the production round program runs.

    ``packed_x=None`` follows the strategy's ``packed`` flag (plane-resident
    x: one ``("worker", "flat_param")`` spec per dtype bucket); pass ``False``
    to keep per-leaf x specs (e.g. under a non-packed-capable optimizer).
    """
    params_sds, axes = T.init_model(cfg, jax.random.PRNGKey(0), abstract=True)
    m = plan.workers
    x_sds = jax.tree.map(lambda s: _sds((m,) + tuple(s.shape), s.dtype), params_sds)
    x_sh = _axes_tree_shardings(_stacked_axes(axes), x_sds, mesh, rules)
    if packed_x is None:
        packed_x = bool(getattr(strategy, "packed", False))
    if packed_x:
        # plane-resident state: x is the worker-stacked Packed plane — one
        # ("worker", "flat_param") spec per dtype bucket instead of one per
        # leaf, mirroring make_train_state
        x_sds = jax.eval_shape(lambda xs: pk.pack(xs, lead=1), x_sds)
        x_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, sh.fit_spec(sh.spec_for(PACKED_STACKED_AXES, rules), s.shape, mesh)),
            x_sds,
        )
    vars_sds = jax.eval_shape(lambda xs: strategy.init_vars(xs, None), x_sds)
    inflight_sds = jax.eval_shape(lambda xs, vs: strategy.init_inflight(xs, vs, None), x_sds, vars_sds)
    vars_axes, inflight_axes = strategy.state_axes(axes)
    vars_sh = _axes_tree_shardings(vars_axes, vars_sds, mesh, rules)
    inflight_sh = _axes_tree_shardings(inflight_axes, inflight_sds, mesh, rules)
    return (x_sds, x_sh), (vars_sds, vars_sh), (inflight_sds, inflight_sh), axes


def _offload_state_shardings(host_sds, dev_sds, dev_sh, mesh: Mesh):
    """Shardings for a host-offloaded state slot: chunked HostPlane leaves
    (one extra leading chunk axis vs their device form) keep the device
    plane's spec per chunk with the chunk axis replicated, placed in the
    backend's host memory space when it has one (``pinned_host`` on TPU —
    advisory on single-memory backends, where the spec alone is emitted).
    Untouched leaves (scalars, masks) keep their device shardings."""
    hk = off.host_memory_kind()
    kw = {"memory_kind": hk} if hk else {}
    h_leaves, tdef = jax.tree_util.tree_flatten(host_sds)
    d_leaves = jax.tree_util.tree_leaves(dev_sds)
    s_leaves = jax.tree_util.tree_leaves(dev_sh)
    out = []
    for h, d, s in zip(h_leaves, d_leaves, s_leaves):
        if len(h.shape) == len(d.shape) + 1:  # chunked: (C,) + lead + (c,)
            spec = sh.fit_spec(P(None, *tuple(s.spec)), h.shape, mesh)
            out.append(NamedSharding(mesh, spec, **kw))
        else:
            out.append(s)
    return jax.tree_util.tree_unflatten(tdef, out)


def _offload_slot(slot_sds, slot_sh, plan: off.OffloadPlan, mesh: Mesh):
    """(sds, shardings) of one state slot in its host-offloaded form."""
    if slot_sds is None:
        return None, None
    host_sds = jax.eval_shape(lambda t: off.tree_offload(t, plan), slot_sds)
    return host_sds, _offload_state_shardings(host_sds, slot_sds, slot_sh, mesh)


def membership_specs(plan: ParallelPlan, mesh: Mesh):
    """Abstract :class:`repro.fault.membership.Membership` + shardings: two
    (m,) f32 vectors, replicated — every device needs the full mask for the
    masked boundary's where/weighted-sum, and at a few bytes per worker the
    vectors are far below any useful shard granularity."""
    from repro.fault.membership import Membership

    m_sds = Membership(mask=_sds((plan.workers,), jnp.float32), weights=_sds((plan.workers,), jnp.float32))
    rep = NamedSharding(mesh, P())
    return m_sds, Membership(mask=rep, weights=rep)


def train_state_specs(cfg: ModelConfig, plan: ParallelPlan, algo, optimizer, mesh: Mesh, rules: dict, with_membership: bool = False):
    """Abstract TrainState + shardings for ``algo`` — a two-phase
    ``CommStrategy`` (whose ``state_axes`` hook supplies the vars/inflight
    layouts, including the carried anchor collective) or, for the oracle
    tests only, a legacy deprecated ``Algorithm``.

    ``with_membership`` adds the degraded-boundary membership slot
    (DESIGN.md §7) to the state specs — the fault-injection dry-run lowers
    the masked round program; the default keeps the baseline fully-live
    state (``membership=None``), whose program is pinned by the budgets."""
    strategy_packed = isinstance(algo, CommStrategy) and getattr(algo, "packed", False)
    if isinstance(algo, CommStrategy):
        plane_resident = strategy_packed and opt_mod.packed_capable(optimizer)
        (x_sds, x_sh), (vars_sds, vars_sh), (inflight_sds, inflight_sh), axes = strategy_state_specs(
            cfg, plan, algo, mesh, rules, packed_x=plane_resident
        )
        opt_sds, opt_sh = opt_state_specs(optimizer, strategy_packed, x_sds, x_sh, mesh, rules)
        if (
            plane_resident
            and bool(getattr(algo.cfg, "offload", False))
            and opt_mod.offload_capable(optimizer)
        ):
            # AlgoConfig.offload: between boundaries the opt state and
            # anchor/inflight buckets are chunked HostPlanes — mirror
            # make_train_state so the lowered round program's input state
            # is the host-resident form (DESIGN.md §9)
            chunk_mb = float(getattr(algo.cfg, "offload_chunk_mb", off.DEFAULT_CHUNK_MB))
            oplan = off.OffloadPlan.for_layout(x_sds.layout, chunk_mb)
            opt_sds, opt_sh = _offload_slot(opt_sds, opt_sh, oplan, mesh)
            vars_sds, vars_sh = _offload_slot(vars_sds, vars_sh, oplan, mesh)
            inflight_sds, inflight_sh = _offload_slot(inflight_sds, inflight_sh, oplan, mesh)
    else:
        params_sds, axes = T.init_model(cfg, jax.random.PRNGKey(0), abstract=True)
        m = plan.workers
        x_sds = jax.tree.map(lambda s: _sds((m,) + tuple(s.shape), s.dtype), params_sds)
        x_sh = _axes_tree_shardings(_stacked_axes(axes), x_sds, mesh, rules)
        opt_sds, opt_sh = opt_state_specs(optimizer, False, x_sds, x_sh, mesh, rules)
        z_sds = v_sds = None
        if algo.needs_anchor:
            z_sds = params_sds
            if getattr(algo.cfg, "anchor_beta", 0) > 0 and algo.name == "overlap_local_sgd":
                v_sds = params_sds
        extra = None
        if algo.name == "cocod":
            extra = x_sds
        vars_sds = AlgoVars(z=z_sds, v=v_sds, extra=extra)
        inflight_sds = None
        anchor_ax = sh.anchor_axes(axes)
        z_sh = _axes_tree_shardings(anchor_ax, params_sds, mesh, rules)
        vars_sh = AlgoVars(
            z=z_sh if z_sds is not None else None,
            v=z_sh if v_sds is not None else None,
            extra=x_sh if extra is not None else None,
        )
        inflight_sh = None

    mem_sds = mem_sh = None
    if with_membership:
        mem_sds, mem_sh = membership_specs(plan, mesh)
    state_sds = TrainState(
        x=x_sds, opt=opt_sds, vars=vars_sds, step=_sds((), jnp.int32), inflight=inflight_sds, membership=mem_sds
    )
    state_sh = TrainState(
        x=x_sh, opt=opt_sh, vars=vars_sh, step=NamedSharding(mesh, P()), inflight=inflight_sh, membership=mem_sh
    )
    return state_sds, state_sh, axes


# serving specs -------------------------------------------------------------


def serve_param_specs(cfg: ModelConfig, mesh: Mesh, rules: dict):
    params_sds, axes = T.init_model(cfg, jax.random.PRNGKey(0), abstract=True)
    return params_sds, _axes_tree_shardings(axes, params_sds, mesh, rules), axes


def prefill_input_specs(cfg: ModelConfig, shape: InputShape):
    b, s = shape.global_batch, shape.seq_len
    fe = cfg.frontend
    if fe is not None and fe.kind == "audio":
        return dict(tokens=_sds((b, fe.num_codebooks, s), jnp.int32))
    if fe is not None and fe.kind == "vision":
        s_img = fe.tokens_per_item
        return dict(
            tokens=_sds((b, s - s_img), jnp.int32),
            image_embeds=_sds((b, s_img, fe.embed_dim), jnp.bfloat16),
        )
    return dict(tokens=_sds((b, s), jnp.int32))


def prefill_input_shardings(specs, mesh: Mesh, rules: dict):
    batch_axes = rules["batch"]
    bspec = tuple(batch_axes) if batch_axes else None

    def one(s):
        return NamedSharding(mesh, sh.fit_spec(P(bspec, *(None,) * (len(s.shape) - 1)), s.shape, mesh))

    return jax.tree.map(one, specs)


def decode_cache_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh, rules: dict):
    """Abstract caches (warm, length seq_len) + shardings per segment kind."""
    caches = jax.eval_shape(
        lambda: T.init_caches(cfg, shape.global_batch, shape.seq_len, dtype=cfg.param_dtype)
    )
    return cache_tree_shardings(caches, mesh, rules)


def cache_tree_shardings(caches, mesh: Mesh, rules: dict):
    batch_axes = rules["batch"]
    seq_axes = rules["cache_seq"]
    b_ax = tuple(batch_axes) if batch_axes else None
    s_ax = tuple(seq_axes) if seq_axes else None
    t_ax = "tensor"

    def spec_for_leaf(path_keys, s):
        name = path_keys[-1]
        nd = len(s.shape)
        if name in ("k", "v"):  # (n?, B, L, kvh, hd)
            lead = (None,) * (nd - 4)
            return P(*lead, b_ax, s_ax, t_ax, None)
        if name in ("ckv", "krope"):  # (n?, B, L, r)
            lead = (None,) * (nd - 3)
            return P(*lead, b_ax, s_ax, None)
        if name in ("pool_k", "pool_v"):  # (n?, P, page, kvh, hd) — shared pool:
            lead = (None,) * (nd - 4)  # pages data-sharded, heads tensor-sharded
            return P(*lead, b_ax, None, t_ax, None)
        if name in ("pool_ckv", "pool_krope"):  # (n?, P, page, r)
            lead = (None,) * (nd - 3)
            return P(*lead, b_ax, None, None)
        if name == "ssd_state":  # (n?, B, H, P, N)
            lead = (None,) * (nd - 4)
            return P(*lead, b_ax, t_ax, None, None)
        if name == "wkv_state":  # (n?, B, H, N, P)
            lead = (None,) * (nd - 4)
            return P(*lead, b_ax, t_ax, None, None)
        if name == "conv_state":  # (n?, B, w, conv_dim)
            lead = (None,) * (nd - 3)
            return P(*lead, b_ax, None, t_ax)
        if name in ("tm_last", "cm_last"):  # (n?, B, d)
            lead = (None,) * (nd - 2)
            return P(*lead, b_ax, None)
        return P(*(None,) * nd)  # positions, pos

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    shardings = []
    for path, leaf in flat:
        keys = [getattr(p, "key", getattr(p, "idx", None)) for p in path]
        spec = sh.fit_spec(spec_for_leaf([str(k) for k in keys], leaf), leaf.shape, mesh)
        shardings.append(NamedSharding(mesh, spec))
    _, tdef = jax.tree_util.tree_flatten(caches)
    return caches, jax.tree_util.tree_unflatten(tdef, shardings)


def paged_decode_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh, rules: dict, page_size: int = 128):
    """Abstract paged pools + page-table/length operands for the paged decode
    lowering (DESIGN.md §10). Pool sized for full residency of every slot
    (one trash page extra); pages spread over the data axes, heads over
    tensor. Page tables and lengths are tiny int32 host-produced operands —
    replicated."""
    from repro.serving.paged_cache import init_paged_pools, pages_for

    maxp = pages_for(shape.seq_len, page_size)
    num_pages = shape.global_batch * maxp + 1
    pools_sds = jax.eval_shape(
        lambda: init_paged_pools(cfg, num_pages, page_size, cfg.param_dtype)
    )
    pools_sds, pools_sh = cache_tree_shardings(pools_sds, mesh, rules)
    pt_sds = _sds((shape.global_batch, maxp), jnp.int32)
    len_sds = _sds((shape.global_batch,), jnp.int32)
    rep = NamedSharding(mesh, P())
    info = dict(
        page_size=page_size,
        num_pages=num_pages,
        max_pages_per_slot=maxp,
        pool_bytes=int(sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(pools_sds))),
    )
    return pools_sds, pools_sh, pt_sds, len_sds, rep, info


def decode_token_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh, rules: dict):
    b = shape.global_batch
    fe = cfg.frontend
    batch_axes = rules["batch"]
    b_ax = tuple(batch_axes) if batch_axes else None
    if fe is not None and fe.kind == "audio":
        toks = _sds((b, fe.num_codebooks, 1), jnp.int32)
        shd = NamedSharding(mesh, sh.fit_spec(P(b_ax, None, None), toks.shape, mesh))
    else:
        toks = _sds((b, 1), jnp.int32)
        shd = NamedSharding(mesh, sh.fit_spec(P(b_ax, None), toks.shape, mesh))
    return toks, shd
