"""Serving launcher: batched generation with the reduced (or full) config.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --requests 6 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.config import get_arch, list_archs
from repro.models import transformer as T
from repro.serving import BatchedEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cfg = arch.model if args.full else arch.model.reduced()
    if cfg.frontend is not None:
        print("note: serving launcher demo covers text archs; "
              "VLM/audio serving paths are exercised in tests/test_serving.py")
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    eng = BatchedEngine(cfg, params, slots=args.slots, page_size=args.page_size)
    kind = f"paged (page_size={eng.page_size}, pool={eng.num_pages} pages)" if eng.paged else "dense fallback"
    print(f"engine: {kind}")
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(f"req-{i}", rng.integers(0, cfg.vocab_size, (4 + i % 5,)).astype(np.int32), args.max_new)
    t0 = time.time()
    results = eng.run()
    dt = time.time() - t0
    total = sum(len(v) for v in results.values())
    print(f"served {len(results)} requests / {total} tokens in {dt:.1f}s ({total/dt:.1f} tok/s)")
    for rid in sorted(results):
        print(f"  {rid}: {results[rid].tolist()}")


if __name__ == "__main__":
    main()
