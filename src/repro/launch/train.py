"""Training launcher.

Single-host CPU execution runs the reduced variant of the selected
architecture for a quick end-to-end check; on a real TPU slice the same
driver runs the full config over the production mesh (the dry-run validates
that path AOT — see launch/dryrun.py).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --rounds 20 \
        [--algo overlap_local_sgd] [--tau 2] [--alpha 0.6] [--workers 4] [--full]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.config import AlgoConfig, OptimizerConfig, get_arch, list_archs
from repro.core import make_algorithm
from repro.data import lm_batch_stream
from repro.models import transformer as T
from repro.optim import from_config as opt_from_config
from repro.optim import schedules
from repro.training import make_round_step, make_train_state


def make_batch_fn(cfg, m: int, batch: int, seq: int):
    streams = [lm_batch_stream(batch, seq, cfg.vocab_size, seed=i) for i in range(m)]

    def vlm_extra(rng):
        fe = cfg.frontend
        return dict(
            image_embeds=jnp.asarray(
                rng.normal(size=(m, batch, fe.tokens_per_item, fe.embed_dim)).astype(np.float32)
            )
        )

    rng = np.random.default_rng(0)

    def next_batch():
        toks, tgts = zip(*[next(s) for s in streams])
        toks, tgts = np.stack(toks), np.stack(tgts)
        fe = cfg.frontend
        if fe is not None and fe.kind == "audio":
            k = fe.num_codebooks
            toks = rng.integers(0, cfg.vocab_size, (m, batch, k, seq)).astype(np.int32)
            tgts = rng.integers(0, cfg.vocab_size, (m, batch, k, seq)).astype(np.int32)
            return dict(tokens=jnp.asarray(toks), targets=jnp.asarray(tgts))
        out = dict(tokens=jnp.asarray(toks), targets=jnp.asarray(tgts))
        if fe is not None and fe.kind == "vision":
            out.update(vlm_extra(rng))
        return out

    return next_batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--algo", default="overlap_local_sgd")
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--alpha", type=float, default=0.6)
    ap.add_argument("--anchor-beta", type=float, default=0.7)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--full", action="store_true", help="use the full (not reduced) model config")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cfg = arch.model if args.full else arch.model.reduced()
    params, axes = T.init_model(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params | {args.algo} tau={args.tau} alpha={args.alpha} m={args.workers}")

    algo = make_algorithm(AlgoConfig(name=args.algo, tau=args.tau, alpha=args.alpha, anchor_beta=args.anchor_beta))
    opt = opt_from_config(OptimizerConfig(name="sgd", lr=args.lr, momentum=0.9, nesterov=True))
    state = make_train_state(params, args.workers, opt, algo, axes)
    step = jax.jit(
        make_round_step(lambda p, b: T.lm_loss(cfg, p, b), opt, algo, schedules.constant(args.lr), axes)
    )
    next_batch = make_batch_fn(cfg, args.workers, args.batch, args.seq)

    t0 = time.time()
    for r in range(args.rounds):
        micro = [next_batch() for _ in range(algo.tau)]
        rb = jax.tree.map(lambda *xs: jnp.stack(xs), *micro)
        state, ms = step(state, rb)
        loss = float(np.asarray(ms["loss"]).mean())
        if r % max(1, args.rounds // 10) == 0 or r == args.rounds - 1:
            print(f"round {r:4d}  loss {loss:.4f}  ({time.time()-t0:.0f}s)")
    if args.ckpt:
        checkpoint.save(args.ckpt, state)
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
