"""Training launcher — a thin CLI over :class:`repro.api.Experiment`.

Single-host CPU execution runs the reduced variant of the selected
architecture for a quick end-to-end check; on a real TPU slice the same
driver runs the full config over the production mesh (the dry-run validates
that path AOT — see launch/dryrun.py).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --rounds 20 \
        [--algo overlap_local_sgd] [--tau 2] [--alpha 0.6] [--workers 4] [--full]

``--algo`` accepts every two-phase strategy, including the new
``delayed_avg`` (DaSGD) and ``sparse_anchor`` (LOSCAR) variants.
"""
from __future__ import annotations

import argparse
import time

from repro import checkpoint
from repro.api import Experiment, TokenStream
from repro.config import AlgoConfig, OptimizerConfig, list_archs
from repro.core import STRATEGIES
from repro.optim import schedules


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--algo", default="overlap_local_sgd", choices=sorted(STRATEGIES))
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--alpha", type=float, default=0.6)
    ap.add_argument("--anchor-beta", type=float, default=0.7)
    ap.add_argument("--delay-steps", type=int, default=1, help="delayed_avg: consume k steps into the round")
    ap.add_argument("--sparse-k", type=float, default=1.0, help="sparse_anchor: top-k fraction transmitted")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--full", action="store_true", help="use the full (not reduced) model config")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    exp = Experiment(
        arch=args.arch,
        strategy=AlgoConfig(
            name=args.algo,
            tau=args.tau,
            alpha=args.alpha,
            anchor_beta=args.anchor_beta,
            delay_steps=args.delay_steps,
            sparse_k=args.sparse_k,
        ),
        optimizer=OptimizerConfig(name="sgd", lr=args.lr, momentum=0.9, nesterov=True),
        schedule=schedules.constant(args.lr),
        data=TokenStream(batch_per_worker=args.batch, seq_len=args.seq),
        workers=args.workers,
        rounds=args.rounds,
        full=args.full,
    )
    exp.build()
    print(
        f"{exp.model_cfg.name}: {exp.num_params/1e6:.1f}M params | "
        f"{args.algo} tau={exp.tau} alpha={args.alpha} m={args.workers}"
    )

    t0 = time.time()
    every = max(1, args.rounds // 10)

    def log(r, loss):
        if r % every == 0 or r == args.rounds - 1:
            print(f"round {r:4d}  loss {loss:.4f}  ({time.time()-t0:.0f}s)")

    exp.fit(log=log)
    if args.ckpt:
        checkpoint.save(args.ckpt, exp.state)
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
