import os

# append (same contract as launch/dryrun.py): keep a caller-pinned device
# count or unrelated XLA flags, default to the 512 placeholder devices
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
    ).strip()

"""Recompute the probe-composed roofline numbers for existing dry-run JSONs
(used after parser/costing fixes — full-program memory/schedule fields are
kept, probe-derived costs are refreshed)."""

import argparse  # noqa: E402
import glob  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from repro.config import INPUT_SHAPES, get_arch  # noqa: E402
from repro.launch import costprobe, roofline as rl, specs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.parallel import logical_mesh  # noqa: E402


def repatch(path: str) -> None:
    d = json.load(open(path))
    arch = get_arch(d["arch"])
    shape = INPUT_SHAPES[d["shape"]]
    multi_pod = d["mesh"] == "2x16x16"
    opt = d.get("variant", "").endswith("+opt")
    prod = make_production_mesh(multi_pod=multi_pod)
    plan = arch.plan_for(shape.name, prod.devices.size)
    lmesh = logical_mesh(prod, plan)
    rules = specs.optimized_rules(shape) if opt else specs.rules_for(shape)
    t0 = time.time()
    composed = costprobe.composed_cost(arch, shape, lmesh, plan, rules, strategy=d.get("strategy"))
    composed["probe_s"] = round(time.time() - t0, 1)
    roof = rl.Roofline(
        flops=composed["flops"],
        bytes_accessed=composed["bytes"],
        collective_bytes=composed["coll"],
        collectives=d["roofline"].get("collectives", {}),
    )
    d["composed"] = composed
    # keep the top-level mirror in sync with the refreshed boundary probe
    # (run_pair writes it the same way; None for serve shapes)
    d["boundary_collectives"] = composed.get("parts", {}).get("boundary", {}).get("collectives")
    d["roofline"] = roof.as_dict()
    if d.get("model_flops_per_device") and roof.flops:
        d["useful_flops_ratio"] = d["model_flops_per_device"] / roof.flops
    with open(path, "w") as f:
        json.dump(d, f, indent=2, default=str)
    print(f"repatched {path}: coll={roof.collective_s:.3f}s mem={roof.memory_s:.3f}s comp={roof.compute_s:.3f}s ({composed['probe_s']}s)", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--glob", default="experiments/dryrun/*.json")
    args = ap.parse_args()
    for path in sorted(glob.glob(args.glob)):
        try:
            repatch(path)
        except Exception as e:  # noqa: BLE001
            print(f"FAIL {path}: {e}", flush=True)


if __name__ == "__main__":
    main()
