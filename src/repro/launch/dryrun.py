import os

# Append rather than overwrite: the 8-device test subprocesses (and any
# caller that already pinned a host-device count) keep their value, a
# pre-existing unrelated XLA_FLAGS (e.g. --xla_dump_to) is preserved, and
# the production CLI path still gets the 512 placeholder devices.
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
    ).strip()

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, print memory/cost analysis, and dump roofline terms.

This is the proof that the distribution config is coherent without real
hardware: a sharding mismatch, compile-time OOM, or unsupported collective
fails here. The 512 placeholder host devices exist ONLY in this process
(the XLA flag above must precede every other import).

Train shapes lower the NATIVE round program — the strategy is resolved
through ``repro.api.resolve_strategy`` (the exact chain ``Experiment``
uses), so the program being cost-modelled is the plane-resident program
training runs: ``TrainState.x`` is the worker-stacked ``Packed`` parameter
plane, optimizer state lives in flat dtype buckets, and the strategy's
launched-but-unconsumed collective rides in the ``inflight`` slot through
``boundary_round``. Any registered strategy lowers (``--strategy``:
overlap/local/sync-SGD, DaSGD ``delayed_avg``, LOSCAR ``sparse_anchor``,
…); the default follows ``specs.default_train_strategy`` (w=1 degenerates
to local_sgd — DESIGN.md §Arch-applicability).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --strategy delayed_avg
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.api import resolve_strategy  # noqa: E402
from repro.config import INPUT_SHAPES, get_arch, list_archs  # noqa: E402
from repro.core.strategy import STRATEGIES  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch import specs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.optim import schedules, sgd  # noqa: E402
from repro.parallel import logical_mesh, mesh_context  # noqa: E402
from repro.parallel import offload as off  # noqa: E402
from repro.parallel.packing import Packed  # noqa: E402
from repro.serving.engine import decode_step, paged_step  # noqa: E402
from repro.serving.paged_cache import paged_supported  # noqa: E402
from repro.training.train_loop import make_round_step  # noqa: E402


def _is_plane(t) -> bool:
    return isinstance(t, (Packed, off.HostPlane))


def _slot_bytes(tree) -> tuple:
    """(device_bytes, host_bytes) of one state slot: HostPlane chunks are
    host-resident between boundaries, everything else (Packed buffers,
    raw arrays/scalars) is device-resident."""
    dev = host = 0
    for leaf in jax.tree.leaves(tree, is_leaf=_is_plane):
        if isinstance(leaf, off.HostPlane):
            host += leaf.nbytes
        elif isinstance(leaf, Packed):
            dev += leaf.nbytes
        else:
            dev += int(np.prod(leaf.shape) * leaf.dtype.itemsize)
    return int(dev), int(host)


def plane_meta(state_sds) -> dict:
    """Machine-readable description of the packed-plane state in the AOT
    specs — recorded so dry-run JSONs are comparable with the
    ``boundary/*`` / ``localstep/*`` rows of BENCH_kernels.json (which time
    the same planes standalone)."""
    x = state_sds.x
    if not isinstance(x, Packed):
        return dict(plane_resident=False)
    opt_leaves = [s for s in jax.tree.leaves(state_sds.opt) if len(s.shape) > 0]
    inflight_bytes = sum(
        p.nbytes for p in jax.tree.leaves(
            state_sds.inflight, is_leaf=_is_plane
        ) if _is_plane(p)
    )
    # true residency split across the whole plane state (x + opt + vars +
    # inflight): offloaded runs report their HostPlane bytes as host-resident
    # (logical totals; the per-device split lives in the offload block)
    dev = host = 0
    for slot in (state_sds.x, state_sds.opt, state_sds.vars, state_sds.inflight):
        d, h = _slot_bytes(slot)
        dev += d
        host += h
    return dict(
        plane_resident=True,
        num_leaves=x.layout.num_leaves,
        buckets=[
            dict(dtype=d, elements=int(n))
            for d, n in zip(x.layout.bucket_dtypes, x.layout.bucket_sizes)
        ],
        x_buffer_bytes=int(x.nbytes),
        opt_buffer_bytes=int(sum(np.prod(s.shape) * s.dtype.itemsize for s in opt_leaves)),
        inflight_buffer_bytes=int(inflight_bytes),
        device_bytes=dev,
        host_bytes=host,
    )


def _host_bytes_per_device(slot_sds, slot_sh) -> int:
    """Per-device host-resident bytes of one offloaded state slot, from the
    AOT shardings (`shard_shape` of every HostPlane chunk)."""
    if slot_sds is None:
        return 0
    h_sds = [t for t in jax.tree.leaves(slot_sds, is_leaf=_is_plane) if isinstance(t, off.HostPlane)]
    h_sh = [t for t in jax.tree.leaves(slot_sh, is_leaf=_is_plane) if isinstance(t, off.HostPlane)]
    total = 0
    for hp, hs in zip(h_sds, h_sh):
        for chunk, sharding in zip(hp.chunks, hs.chunks):
            total += int(np.prod(sharding.shard_shape(chunk.shape)) * chunk.dtype.itemsize)
    return total


def _staging_bytes_per_device(slot_sds, slot_sh) -> int:
    """Per-device double-buffer staging footprint: two in-flight device
    chunks (applied + prefetched) per state plane per bucket — the bound the
    jaxpr regression in tests/test_offload.py pins."""
    h_sds = [t for t in jax.tree.leaves(slot_sds, is_leaf=_is_plane) if isinstance(t, off.HostPlane)]
    h_sh = [t for t in jax.tree.leaves(slot_sh, is_leaf=_is_plane) if isinstance(t, off.HostPlane)]
    total = 0
    for hp, hs in zip(h_sds, h_sh):
        for chunk, sharding in zip(hp.chunks, hs.chunks):
            ss = sharding.shard_shape(chunk.shape)
            total += 2 * int(np.prod(ss[1:]) * chunk.dtype.itemsize)
    return total


def _offload_meta(state_sds, state_sh, tau: int) -> dict:
    """Static offload-plan block for the dry-run JSON: what lives on the
    host between boundaries (per device), the chunk grid the stream walks,
    and the bytes it must move per round. Bandwidth/overlap terms are
    attached later by run_pair (measured, not static)."""
    plan = off.plan_of(state_sds.opt)
    if plan is None:
        return dict(enabled=False, reason="optimizer/plane not offload-capable")
    layout = state_sds.x.layout
    per_slot = dict(
        opt=_host_bytes_per_device(state_sds.opt, state_sh.opt),
        vars=_host_bytes_per_device(state_sds.vars, state_sh.vars),
        inflight=_host_bytes_per_device(state_sds.inflight, state_sh.inflight),
    )
    # opt state round-trips (H2D + D2H) once per local step inside the
    # τ-scan; anchor-shaped slots round-trip once per round at the boundary
    stream_pd = tau * 2 * per_slot["opt"] + 2 * (per_slot["vars"] + per_slot["inflight"])
    return dict(
        enabled=True,
        memory_kind=off.host_memory_kind() or "unpinned_host",
        buckets=[
            dict(dtype=d, elements=int(n), chunk_elems=int(c), num_chunks=int(k))
            for d, n, c, k in zip(
                layout.bucket_dtypes, layout.bucket_sizes, plan.chunk_elems, plan.num_chunks
            )
        ],
        host_bytes_per_device=int(sum(per_slot.values())),
        host_bytes_per_device_by_slot=per_slot,
        staging_bytes_per_device=_staging_bytes_per_device(state_sds.opt, state_sh.opt),
        stream_bytes_per_round_per_device=int(stream_pd),
    )


def _maybe_enable_x64(cfg) -> None:
    """>100B-param archs overflow the packed plane's int32 index range
    (>2^31 elements in one dtype bucket); pack() then requires int64
    indices. Flipped process-wide — the dry-run CLI owns its process."""
    if jax.config.jax_enable_x64:
        return
    sds, _ = T.init_model(cfg, jax.random.PRNGKey(0), abstract=True)
    per = {}
    for s in jax.tree.leaves(sds):
        per[str(s.dtype)] = per.get(str(s.dtype), 0) + int(np.prod(s.shape))
    if max(per.values(), default=0) > np.iinfo(np.int32).max:
        jax.config.update("jax_enable_x64", True)
        print("   (jax_enable_x64: a packed bucket exceeds the int32 index range)")


def lower_pair(arch_name: str, shape_name: str, multi_pod: bool = False, tau: int = 2, opt: bool = False, strategy: str = None, faults: str = None, topology: str = None, offload: bool = False):
    """Returns (lowered, meta) for one (arch × shape × mesh).

    ``faults`` (a :meth:`repro.fault.plan.FaultPlan.parse` spec) lowers the
    *membership-carrying* round program for train shapes: ``TrainState``
    gains the replicated live-mask/weights vectors and the boundary traces
    its masked form (DESIGN.md §7). Without it the baseline fully-live
    program — the one pinned by the collective budgets — is lowered."""
    arch = get_arch(arch_name)
    shape = INPUT_SHAPES[shape_name]
    if not arch.supports(shape):
        raise ValueError(f"{arch_name} skips {shape_name} (policy {arch.long_context_policy})")
    prod_mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = prod_mesh.devices.size
    plan = arch.plan_for(shape.name, n_dev)
    lmesh = logical_mesh(prod_mesh, plan)
    rules = specs.optimized_rules(shape) if opt else specs.rules_for(shape)
    cfg, variant = specs.model_for(arch, shape)
    if opt:
        variant = variant + "+opt"

    meta = dict(
        arch=arch_name,
        shape=shape_name,
        mesh="2x16x16" if multi_pod else "16x16",
        n_devices=n_dev,
        plan=dict(workers=plan.workers, fsdp=plan.fsdp, tensor=plan.tensor),
        variant=variant,
    )
    if faults is not None:
        meta["faults"] = faults

    with mesh_context(lmesh, rules):
        if shape.mode == "train":
            # native two-phase lowering: the same AlgoConfig → make_strategy
            # resolution Experiment.build() runs (w=1 degenerates to
            # local_sgd — see DESIGN.md §Arch-applicability)
            _maybe_enable_x64(cfg)
            strat = resolve_strategy(
                specs.train_algo_config(plan, strategy, tau, topology=topology, offload=offload)
            )
            tau = strat.tau  # sync-style strategies pin τ = 1
            meta["strategy"] = strat.name
            meta["tau"] = tau
            if getattr(strat, "topo_name", None) is not None:
                meta["topology"] = strat.topo_name
            optimizer = sgd(momentum=0.9, nesterov=True, weight_decay=1e-4)
            sched = schedules.constant(0.1)
            state_sds, state_sh, axes = specs.train_state_specs(
                cfg, plan, strat, optimizer, lmesh, rules, with_membership=faults is not None
            )
            meta["plane"] = plane_meta(state_sds)
            if offload:
                meta["offload"] = _offload_meta(state_sds, state_sh, tau)
            batch_sds = specs.train_batch_specs(cfg, shape, plan, tau)
            batch_sh = specs.batch_shardings(batch_sds, lmesh, rules)

            def loss_fn(p, b):
                return T.lm_loss(cfg, p, b, remat=True)

            round_step = make_round_step(
                loss_fn, optimizer, strat, sched, axes, microbatch=arch.train_microbatch
            )
            lowered = jax.jit(
                round_step, in_shardings=(state_sh, batch_sh), donate_argnums=(0,)
            ).lower(state_sds, batch_sds)
            meta["tokens_per_program"] = tau * shape.global_batch * shape.seq_len
            meta["mode"] = "train"
        elif shape.mode == "prefill":
            params_sds, params_sh, _ = specs.serve_param_specs(cfg, lmesh, rules)
            in_sds = specs.prefill_input_specs(cfg, shape)
            in_sh = specs.prefill_input_shardings(in_sds, lmesh, rules)

            def prefill_fn(p, inputs):
                logits, aux = T.apply_model(cfg, p, inputs, mode="prefill")
                return logits, aux["caches"]

            lowered = jax.jit(prefill_fn, in_shardings=(params_sh, in_sh)).lower(params_sds, in_sds)
            meta["tokens_per_program"] = shape.global_batch * shape.seq_len
            meta["mode"] = "prefill"
        else:  # decode
            params_sds, params_sh, _ = specs.serve_param_specs(cfg, lmesh, rules)
            tok_sds, tok_sh = specs.decode_token_specs(cfg, shape, lmesh, rules)
            # paged decode for attention-family text archs — except long_500k,
            # whose rules shard the cache sequence axis: a page-table gather
            # over a sequence-sharded pool would all-gather the pool, so the
            # long-context shape keeps the dense sequence-sharded cache
            use_paged = paged_supported(cfg) and shape.name != "long_500k"
            if use_paged:
                pools_sds, pools_sh, pt_sds, len_sds, rep, info = specs.paged_decode_specs(
                    cfg, shape, lmesh, rules
                )

                def paged_fn(p, toks, pools, pt, lens):
                    return paged_step(cfg, p, toks, pools, pt, lens)

                lowered = jax.jit(
                    paged_fn,
                    in_shardings=(params_sh, tok_sh, pools_sh, rep, rep),
                ).lower(params_sds, tok_sds, pools_sds, pt_sds, len_sds)
                meta["serving"] = dict(engine="paged", **info)
            else:
                cache_sds, cache_sh = specs.decode_cache_specs(cfg, shape, lmesh, rules)
                pos_sds = jax.ShapeDtypeStruct((), np.int32)

                def serve_fn(p, toks, caches, pos):
                    return decode_step(cfg, p, toks, caches, pos)

                lowered = jax.jit(
                    serve_fn,
                    in_shardings=(params_sh, tok_sh, cache_sh, None),
                ).lower(params_sds, tok_sds, cache_sds, pos_sds)
                meta["serving"] = dict(engine="dense")
            meta["tokens_per_program"] = shape.global_batch
            meta["mode"] = "decode"
    return lowered, meta, cfg


def active_params(cfg) -> int:
    """Parameters touched per token (MoE expert weights scaled by top_k/E)."""
    sds, _ = T.init_model(cfg, jax.random.PRNGKey(0), abstract=True)
    segs = T.segments(cfg)
    total = 0
    for key, sub in sds.items():
        frac = 1.0
        if key.startswith("seg"):
            si = int(key[3:])
            kind = segs[si][0]
            if kind == "moe" and cfg.moe is not None:
                # scale only the routed-expert weights
                moe_total = 0
                routed = 0
                for path, leaf in jax.tree_util.tree_flatten_with_path(sub)[0]:
                    n = int(np.prod(leaf.shape))
                    moe_total += n
                    keys = [str(getattr(p, "key", "")) for p in path]
                    if "ffn" in keys and any(k in ("wi_gate", "wi_up", "wo") for k in keys) and "shared" not in keys and "dense_residual" not in keys:
                        routed += n
                total += (moe_total - routed) + int(routed * cfg.moe.top_k / cfg.moe.num_experts)
                continue
        total += int(sum(np.prod(l.shape) for l in jax.tree.leaves(sub)) * frac)
    return total


def _memory_block(mem, meta: dict, hbm_gb: float) -> dict:
    """Per-device memory accounting against a configurable HBM budget.

    ``fits_hbm`` answers the question the offload plane controls: does the
    *device-resident steady-state* — program arguments (params/opt/anchor
    planes, batch, membership) minus the host-offloaded bytes, plus the
    double-buffer staging chunks — fit the budget.  All sizes from
    ``memory_analysis`` are per device (the compiler reports one shard's
    footprint).  Two deliberate exclusions/conventions:

    * ``temp_bytes`` (activation workspace) is reported raw but NOT counted
      against the budget: the host-backend lowering performs no remat (and
      logs involuntary full-remat broadcasts), so its temp accounting is
      orders of magnitude above what the rematerialized accelerator
      program holds live — see the host-mesh remat caveat in
      EXPERIMENTS.md.  Activation residency is governed by remat policy
      and microbatch size, orthogonal to state residency.
    * the budget is binary-sized: an "80GB" HBM part holds 80 GiB
      (85.9e9 bytes), so ``--hbm-gb 80`` means ``80 * 2**30`` bytes.

    ``fits_hbm_16g`` keeps the old arg+temp-vs-16e9 semantics for one
    release for older budget-diff tooling — ``fits_hbm`` +
    ``hbm_budget_gb`` is the keyed field."""
    peak = mem.argument_size_in_bytes + mem.temp_size_in_bytes
    ob = meta.get("offload", {})
    host_pd = ob.get("host_bytes_per_device", 0)
    staging_pd = ob.get("staging_bytes_per_device", 0)
    resident = mem.argument_size_in_bytes - host_pd + staging_pd
    budget = hbm_gb * 2**30
    return dict(
        argument_bytes=mem.argument_size_in_bytes,
        output_bytes=mem.output_size_in_bytes,
        temp_bytes=mem.temp_size_in_bytes,
        alias_bytes=mem.alias_size_in_bytes,
        peak_per_device=peak,
        host_offloaded_bytes_per_device=int(host_pd),
        device_resident_bytes_per_device=int(resident),
        hbm_budget_gb=float(hbm_gb),
        hbm_budget_bytes=int(budget),
        fits_hbm=bool(resident <= budget),
        fits_hbm_16g=bool(peak <= 16e9),  # deprecated: use fits_hbm + hbm_budget_gb
    )


def run_pair(
    arch_name: str,
    shape_name: str,
    multi_pod: bool = False,
    out_dir: str = None,
    verbose: bool = True,
    with_probes: bool = True,
    opt: bool = False,
    strategy: str = None,
    faults: str = None,
    topology: str = None,
    offload: bool = False,
    hbm_gb: float = 16.0,
):
    t0 = time.time()
    lowered, meta, cfg = lower_pair(
        arch_name, shape_name, multi_pod, opt=opt, strategy=strategy, faults=faults,
        topology=topology, offload=offload,
    )
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    roof_sched = rl.analyze(compiled, hlo)

    n_params_sds, _ = T.init_model(cfg, jax.random.PRNGKey(0), abstract=True)
    n_params = int(sum(np.prod(s.shape) for s in jax.tree.leaves(n_params_sds)))

    # scan-corrected per-device cost via component probes (see costprobe.py)
    composed = None
    roof = roof_sched
    if with_probes:
        from repro.launch import costprobe
        from repro.parallel import logical_mesh as _lm

        arch = get_arch(arch_name)
        shape = INPUT_SHAPES[shape_name]
        prod_mesh = make_production_mesh(multi_pod=multi_pod)
        plan = arch.plan_for(shape.name, prod_mesh.devices.size)
        lmesh = _lm(prod_mesh, plan)
        rules = specs.optimized_rules(shape) if opt else specs.rules_for(shape)
        t0 = time.time()
        composed = costprobe.composed_cost(
            arch, shape, lmesh, plan, rules, strategy=meta.get("strategy"),
            offload_stream_bytes=meta.get("offload", {}).get("stream_bytes_per_round_per_device"),
        )
        composed["probe_s"] = round(time.time() - t0, 1)
        roof = rl.Roofline(
            flops=composed["flops"],
            bytes_accessed=composed["bytes"],
            collective_bytes=composed["coll"],
            collectives=roof_sched.collectives,
        )

    # measured host-link bandwidth + overlap schedule for the offload plane:
    # is the stream hidden inside the τ-step window? (DESIGN.md §9)
    if meta.get("offload", {}).get("enabled"):
        from repro.core.runtime_model import offload_schedule
        from repro.launch.costprobe import measure_host_bandwidth

        bw = measure_host_bandwidth()
        t_step = max(roof.compute_s, roof.memory_s) / max(meta["tau"], 1)
        meta["offload"]["bandwidth"] = bw
        meta["offload"]["schedule"] = offload_schedule(
            meta["offload"]["stream_bytes_per_round_per_device"],
            min(bw["d2h_gbps"], bw["h2d_gbps"]),
            meta["tau"],
            t_step,
        )

    n_active = active_params(cfg)
    mode = meta["mode"]
    mflops = rl.model_flops(n_active, meta["tokens_per_program"], "train" if mode == "train" else "serve")
    n_dev = meta["n_devices"]  # the mesh actually built, not a re-derived constant
    mflops_per_dev = mflops / n_dev

    # the boundary's own collective schedule (per-kind count/bytes from the
    # boundary probe) — directly comparable to BENCH_kernels.json boundary rows
    boundary_collectives = None
    if composed is not None and "boundary" in composed.get("parts", {}):
        boundary_collectives = composed["parts"]["boundary"].get("collectives")

    # adaptive-τ schedule cost model (train mode): the composed cost is
    # linear in τ, so the dry-run prices the whole τ *schedule* a controller
    # would realize — per-τ program costs + simulated trajectory against
    # the runtime model (repro.control.schedule, DESIGN.md §6)
    tau_schedule = None
    degraded_rounds = None
    if meta["mode"] == "train":
        from repro.control import TauController, schedule_block

        # deterministic fault schedule (DESIGN.md §7): the membership-masked
        # program was lowered above; here the plan's resolved schedule is
        # recorded and threaded into the controller trajectory so the JSON
        # proves adaptive-τ and fault handling compose (fault_hold rounds)
        fault_plan = None
        if faults is not None:
            from repro.fault import FaultPlan

            fault_plan = FaultPlan.parse(faults, m=meta["plan"]["workers"])
            degraded_rounds = fault_plan.degraded_rounds(50)

        ctrl = TauController(tau=meta["tau"], tau_min=1, tau_max=32)
        tau_schedule = schedule_block(
            meta["strategy"], ctrl, rounds=50, composed=composed, fault_plan=fault_plan
        )

    result = dict(
        meta,
        ok=True,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        boundary_collectives=boundary_collectives,
        n_params=n_params,
        n_active_params=n_active,
        model_flops_per_device=mflops_per_dev,
        useful_flops_ratio=(mflops_per_dev / roof.flops) if roof.flops else None,
        memory=_memory_block(mem, meta, hbm_gb),
        roofline=roof.as_dict(),
        schedule_view=roof_sched.as_dict(),
        composed=composed,
        tau_schedule=tau_schedule,
        degraded_rounds=degraded_rounds,
    )
    if verbose:
        strat_note = f", strategy {meta['strategy']}" if "strategy" in meta else ""
        print(f"== {meta['arch']} × {meta['shape']} × {meta['mesh']} (plan {meta['plan']}, {meta['variant']}{strat_note})")
        print(f"   memory_analysis: {mem}")
        print(
            f"   cost/device: flops={roof.flops:.3e} bytes={roof.bytes_accessed:.3e} "
            f"collective_bytes={roof.collective_bytes:.3e} (scan-corrected={composed is not None})"
        )
        print(
            f"   roofline: compute={roof.compute_s*1e3:.2f}ms memory={roof.memory_s*1e3:.2f}ms "
            f"collective={roof.collective_s*1e3:.2f}ms -> dominant: {roof.dominant}"
        )
        ratio = result["useful_flops_ratio"]
        print(f"   MODEL_FLOPS/HLO_FLOPS = {ratio:.3f}" if ratio else "   MODEL_FLOPS ratio n/a")
        if tau_schedule is not None:
            taus = [t["tau"] for t in tau_schedule["per_tau"]]
            print(
                f"   tau schedule: {tau_schedule['rounds']} rounds over taus {taus} "
                f"({tau_schedule['compiled_programs']} programs), "
                f"scheduled {tau_schedule['total_time_s']:.1f}s vs fixed-tau {tau_schedule['fixed_tau_time_s']:.1f}s"
            )
        if degraded_rounds is not None:
            n_holds = sum(1 for tr in tau_schedule["trajectory"] if tr["decision"] == "fault_hold")
            print(
                f"   faults: {degraded_rounds['degraded']}/{degraded_rounds['rounds']} degraded rounds, "
                f"{n_holds} fault_hold tau decisions"
            )
        if meta.get("offload", {}).get("enabled"):
            ob = meta["offload"]
            sched_blk = ob["schedule"]
            print(
                f"   offload: host/device {ob['host_bytes_per_device']/1e9:.2f}GB off, "
                f"stream {sched_blk['stream_s']*1e3:.2f}ms vs window {sched_blk['window_s']*1e3:.2f}ms "
                f"-> exposed {sched_blk['exposed_s']*1e3:.2f}ms (breakeven tau {sched_blk['breakeven_tau']})"
            )
            mb = result["memory"]
            print(
                f"   hbm: resident {mb['device_resident_bytes_per_device']/1e9:.2f}GB of "
                f"{mb['hbm_budget_gb']:.0f}GiB budget -> fits_hbm={mb['fits_hbm']} "
                f"(temp {mb['temp_bytes']/1e9:.0f}GB excluded: host lowering has no remat)"
            )
        print(f"   collective schedule: {roof_sched.collectives}")
        print(f"   lower {t_lower:.1f}s compile {t_compile:.1f}s probes {composed['probe_s'] if composed else 0}s")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{meta['arch']}_{meta['shape']}_{meta['mesh'].replace('x','-')}"
        if opt:
            tag += "_opt"
        if strategy is not None and "strategy" in meta:
            # only train shapes resolve a strategy; serve pairs under
            # --all --strategy keep their untagged filenames
            tag += f"_{meta['strategy']}"
        if faults is not None and "strategy" in meta:
            # the membership-carrying lowering is a different program; keep
            # the baseline JSONs (and their budget comparisons) untouched
            tag += "_faults"
        if offload and "strategy" in meta:
            tag += "_offload"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(result, f, indent=2, default=str)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None, choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--opt", action="store_true", help="lower the beyond-paper optimized sharding variant (EXPERIMENTS.md §Perf)")
    ap.add_argument(
        "--strategy",
        type=str,
        default=None,
        choices=sorted(STRATEGIES),
        help="two-phase CommStrategy for train shapes (default: specs.default_train_strategy — "
        "overlap_local_sgd, degenerating to local_sgd at w=1)",
    )
    ap.add_argument(
        "--topology",
        type=str,
        default=None,
        help="gossip mixing-matrix family for --strategy gossip_pushsum (full|ring|exp); "
        "the fixed-topology strategy names (gossip_ring, gossip_exp, ...) override it",
    )
    ap.add_argument(
        "--faults",
        type=str,
        default=None,
        help="fault-plan spec for train shapes (repro.fault.FaultPlan.parse grammar, e.g. "
        "'crash:1@2-5,slow:2x4'): lowers the membership-masked round program and records "
        "the degraded_rounds schedule + fault_hold tau decisions (DESIGN.md §7)",
    )
    ap.add_argument(
        "--offload",
        action="store_true",
        help="lower the host-offloaded round program (AlgoConfig.offload): opt state and "
        "anchor-shaped slots live host-side between boundaries and stream through the "
        "τ-step window (DESIGN.md §9); JSON gains the offload schedule block",
    )
    ap.add_argument(
        "--hbm-gb",
        type=float,
        default=16.0,
        help="per-device HBM budget for the memory block's fits_hbm field "
        "(binary-sized, as HBM parts are: 80 means 80 GiB)",
    )
    ap.add_argument("--no-probes", action="store_true", help="skip the scan-corrected component probes (faster smoke)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default="experiments/dryrun")
    args = ap.parse_args()

    pairs = []
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    for a in archs:
        for s in shapes:
            pairs.append((a, s))

    failures = []
    for a, s in pairs:
        try:
            run_pair(
                a,
                s,
                multi_pod=args.multi_pod,
                out_dir=args.out,
                opt=args.opt,
                strategy=args.strategy,
                faults=args.faults,
                topology=args.topology,
                with_probes=not args.no_probes,
                offload=args.offload,
                hbm_gb=args.hbm_gb,
            )
        except Exception as e:  # noqa: BLE001
            failures.append((a, s, repr(e)))
            print(f"!! FAIL {a} × {s}: {e}")
            traceback.print_exc()
    print(f"\n{len(pairs) - len(failures)}/{len(pairs)} pairs OK on "
          f"{'2x16x16' if args.multi_pod else '16x16'}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
