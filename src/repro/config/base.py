"""Configuration system.

Dataclass configs describing (a) the model architecture, (b) the parallelism
plan per mesh, (c) the training algorithm (Overlap-Local-SGD and baselines),
and (d) the benchmark input shapes. Every assigned architecture registers an
``ArchConfig`` in ``repro.config.registry`` and is selectable via
``--arch <id>`` in the launchers.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Layer-level configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttentionConfig:
    """Multi-head attention (GQA / MHA / MLA)."""

    num_heads: int
    num_kv_heads: int
    head_dim: int
    kind: str = "gqa"  # "gqa" | "mla"
    qkv_bias: bool = False
    out_bias: bool = False
    sliding_window: Optional[int] = None  # tokens; None = full causal
    rope: str = "rope"  # "rope" | "mrope" | "none"
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()  # M-RoPE (t, h, w) split of head_dim/2
    # MLA (DeepSeek-V3) dimensions
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN."""

    num_experts: int
    top_k: int
    expert_ff: int
    num_shared_experts: int = 0  # DeepSeek-V3: 1 shared expert
    shared_expert_ff: int = 0
    dense_residual_ff: int = 0  # Arctic: dense FFN in parallel with MoE
    router_aux_weight: float = 0.01
    router_dtype: str = "float32"
    capacity_factor: float = 1.25  # for dropless-vs-capacity dispatch analysis
    first_k_dense: int = 0  # DeepSeek-V3: first 3 layers dense


@dataclass(frozen=True)
class SSMConfig:
    """State-space / linear-recurrence blocks (Mamba2 SSD, RWKV6 WKV)."""

    kind: str  # "mamba2" | "rwkv6"
    state_dim: int = 64
    num_heads: int = 0  # mamba2 heads / rwkv6 heads
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 64


@dataclass(frozen=True)
class FrontendConfig:
    """Stub modality frontend (VLM vision tower / audio codec).

    Per the assignment this is the single allowed stub: ``input_specs()``
    provides precomputed patch/frame embeddings with these dimensions and the
    decoder consumes them through a learned projector.
    """

    kind: str  # "vision" | "audio"
    embed_dim: int  # incoming embedding dim (e.g. ViT hidden)
    tokens_per_item: int  # patches per image / codec frames per second chunk
    num_codebooks: int = 1  # musicgen: parallel codebooks (delay pattern)


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

def _mrope_sections(head_dim: int) -> Tuple[int, int, int]:
    half = head_dim // 2
    t = half // 2
    h = half // 4
    return (t, h, half - t - h)


# Layer kinds usable in ``layer_pattern``:
#   "attn"        attention + FFN block
#   "moe"         attention + MoE block
#   "mamba2"      Mamba2 SSD block
#   "shared_attn" weight-shared attention block (Zamba2)
#   "rwkv6"       RWKV6 time-mix + channel-mix block


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    frontend: Optional[FrontendConfig] = None
    layer_pattern: Tuple[str, ...] = ()  # default: ("attn",) * num_layers
    norm_eps: float = 1e-5
    act: str = "silu"
    use_parallel_block: bool = False  # Cohere command-r: attn ∥ FFN
    use_qk_norm: bool = False
    tie_embeddings: bool = False
    logit_scale: float = 1.0
    mtp_depth: int = 0  # DeepSeek-V3 multi-token prediction modules
    shared_attn_every: int = 0  # Zamba2: shared attention block period
    dtype: str = "bfloat16"
    # citation for the registry table
    source: str = ""

    def pattern(self) -> Tuple[str, ...]:
        if self.layer_pattern:
            return self.layer_pattern
        return ("attn",) * self.num_layers

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: ≤2 layers, d_model ≤ 512, ≤4 experts.

        Keeps the same layer family mix so the smoke test exercises the same
        code paths as the full config.
        """
        d_model = min(self.d_model, 256)
        scale = d_model / self.d_model
        heads = None
        if self.attention is not None:
            a = self.attention
            num_heads = max(2, min(4, a.num_heads))
            num_kv = max(1, min(num_heads, a.num_kv_heads))
            head_dim = max(16, d_model // num_heads)
            if a.kind == "mla":
                heads = replace(
                    a,
                    num_heads=num_heads,
                    num_kv_heads=num_heads,
                    head_dim=head_dim,
                    q_lora_rank=64,
                    kv_lora_rank=64,
                    qk_nope_head_dim=head_dim,
                    qk_rope_head_dim=16,
                    v_head_dim=head_dim,
                )
            else:
                heads = replace(
                    a,
                    num_heads=num_heads,
                    num_kv_heads=num_kv,
                    head_dim=head_dim,
                    sliding_window=(64 if a.sliding_window else None),
                    mrope_sections=_mrope_sections(head_dim) if a.rope == "mrope" else (),
                )
        moe = None
        if self.moe is not None:
            moe = replace(
                self.moe,
                num_experts=min(4, self.moe.num_experts),
                top_k=min(2, self.moe.top_k),
                expert_ff=max(32, int(self.moe.expert_ff * scale)),
                num_shared_experts=min(1, self.moe.num_shared_experts),
                shared_expert_ff=max(32, int(self.moe.shared_expert_ff * scale)) if self.moe.shared_expert_ff else 0,
                dense_residual_ff=max(32, int(self.moe.dense_residual_ff * scale)) if self.moe.dense_residual_ff else 0,
                first_k_dense=min(1, self.moe.first_k_dense),
            )
        ssm = None
        if self.ssm is not None:
            ssm = replace(
                self.ssm,
                state_dim=min(16, self.ssm.state_dim),
                num_heads=max(2, min(4, self.ssm.num_heads)),
                head_dim=max(16, min(32, self.ssm.head_dim)),
                chunk_size=16,
            )
        n_layers = 2
        pattern = self._reduced_pattern(n_layers)
        frontend = None
        if self.frontend is not None:
            frontend = replace(self.frontend, embed_dim=min(128, self.frontend.embed_dim), tokens_per_item=min(16, self.frontend.tokens_per_item))
        return replace(
            self,
            name=self.name + "-smoke",
            num_layers=n_layers,
            d_model=d_model,
            d_ff=max(64, int(self.d_ff * scale)),
            vocab_size=min(512, self.vocab_size),
            attention=heads,
            moe=moe,
            ssm=ssm,
            frontend=frontend,
            layer_pattern=pattern,
            mtp_depth=min(1, self.mtp_depth),
            shared_attn_every=(2 if self.shared_attn_every else 0),
            dtype="float32",
        )

    def _reduced_pattern(self, n_layers: int) -> Tuple[str, ...]:
        full = self.pattern()
        if not full:
            return ()
        # keep the *distinct* layer kinds, in first-appearance order
        kinds: list[str] = []
        for k in full:
            if k not in kinds:
                kinds.append(k)
        out = tuple(kinds[i % len(kinds)] for i in range(max(n_layers, len(kinds))))
        return out[: max(n_layers, len(kinds))]


# ---------------------------------------------------------------------------
# Parallelism plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParallelPlan:
    """Logical parallelism factors over the production mesh.

    ``workers``: Local-SGD worker groups (the paper's m) — slowest axes.
    ``fsdp``: parameter/optimizer sharding within a worker.
    ``tensor``: tensor parallelism within a worker.
    workers * fsdp * tensor must equal the device count of the mesh.
    """

    workers: int
    fsdp: int
    tensor: int

    @property
    def num_devices(self) -> int:
        return self.workers * self.fsdp * self.tensor

    def scaled_to(self, n_devices: int) -> "ParallelPlan":
        """Scale the worker axis so the plan covers ``n_devices``."""
        base = self.fsdp * self.tensor
        assert n_devices % base == 0, (n_devices, self)
        return ParallelPlan(n_devices // base, self.fsdp, self.tensor)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# Algorithm / training config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AlgoConfig:
    """Distributed-optimization algorithm selection (the paper's subject)."""

    name: str = "overlap_local_sgd"
    # overlap_local_sgd | local_sgd | sync_sgd | easgd | cocod | powersgd
    # | delayed_avg (DaSGD) | sparse_anchor (LOSCAR)
    # | gossip_pushsum / gossip_full / gossip_ring / gossip_exp (SGP)
    tau: int = 2  # local updates per round
    alpha: float = 0.6  # pullback strength (paper: 0.6 for tau>=2, 0.5 for tau=1)
    anchor_beta: float = 0.7  # anchor momentum (paper §4)
    easgd_beta: float = 0.9  # EASGD moving-rate (symmetric variant)
    powersgd_rank: int = 2
    delay_steps: int = 1  # delayed_avg: consume the average k steps into the next round
    sparse_k: float = 1.0  # sparse_anchor: top-k fraction of the anchor delta transmitted
    # gossip_pushsum: mixing-matrix family over the worker axis
    # ("full" | "ring" | "exp", see repro.core.topology). The fixed-topology
    # registry entries (gossip_full/gossip_ring/gossip_exp) override this.
    topology: str = "full"
    sync_router_stats: bool = True  # beyond-paper: all-reduce MoE router stats at boundaries
    # run all round-boundary math over the packed parameter plane (one flat
    # 128-lane-aligned buffer per dtype — one collective + one kernel launch
    # per boundary regardless of leaf count). False = per-leaf reference
    # path, kept as the bit-exact oracle for the golden tests.
    packed: bool = True
    # gradient clipping over the packed plane: per-bucket partial square
    # sums feeding one global scale (O(buckets) reductions instead of
    # O(leaves)). Off by default — the f32 summation *order* differs from
    # the per-leaf walk, so enabling it trades the bitwise pin for ≤ a few
    # ulps (tests/test_packed_optim.py pins the tolerance). Only consulted
    # on the plane-resident local step; the per-leaf path ignores it.
    packed_clip: bool = False
    # host-offload the opt-state and anchor/inflight buckets between
    # boundaries (repro.parallel.offload): state lives host-resident as
    # chunked HostPlanes and is streamed back through two device staging
    # buffers inside the τ-step window — the same overlap that hides the
    # boundary collective hides the host link. Requires packed=True and
    # an offload-capable optimizer. Bitwise-identical to plane-resident
    # (tests/test_offload.py).
    offload: bool = False
    # chunk size of the offload stream in MiB of *param-dtype* elements
    # per chunk (LANE-aligned; state planes in wider dtypes move
    # proportionally more bytes per chunk). Small values only make sense
    # in tests, where they force multi-chunk scans on tiny planes.
    offload_chunk_mb: float = 64.0


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "sgd"  # sgd | adamw
    lr: float = 0.1
    momentum: float = 0.9
    nesterov: bool = True
    weight_decay: float = 1e-4
    warmup_steps: int = 0
    decay_steps: Tuple[int, ...] = ()
    decay_factor: float = 0.1
    grad_clip: float = 0.0
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8


@dataclass(frozen=True)
class TrainConfig:
    algo: AlgoConfig = field(default_factory=AlgoConfig)
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    rounds: int = 10
    microbatch: Optional[int] = None  # per-worker microbatch; None = whole shard
    remat: bool = True
    seed: int = 0


# ---------------------------------------------------------------------------
# Top-level per-architecture registry entry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    model: ModelConfig
    # parallelism plan keyed by input-shape name; "default" fallback.
    plans: dict
    # shapes that must run a sliding-window *variant* for long_500k (dense
    # full-attention archs); None entries are skipped and noted in DESIGN.md.
    long_context_policy: str = "native"  # native | swa_variant | skip
    swa_variant_window: int = 4096
    # per-worker gradient-accumulation microbatch for train_4k (None = whole
    # worker batch in one step) — needed on big-vocab / MoE architectures.
    train_microbatch: Optional[int] = None

    @property
    def name(self) -> str:
        return self.model.name

    def plan_for(self, shape_name: str, n_devices: int) -> ParallelPlan:
        plan = self.plans.get(shape_name, self.plans["default"])
        return plan.scaled_to(n_devices)

    def supports(self, shape: InputShape) -> bool:
        if shape.name == "long_500k":
            return self.long_context_policy != "skip"
        return True


def asdict(cfg) -> dict:
    return dataclasses.asdict(cfg)
