"""Architecture registry: ``--arch <id>`` resolution.

Config modules in ``repro.configs`` call :func:`register` at import time;
:func:`get_arch` lazily imports the whole configs package so every launcher
and test sees the full pool.
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.config.base import ArchConfig

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch config: {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def _ensure_loaded() -> None:
    importlib.import_module("repro.configs")


def get_arch(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)
