from repro.checkpoint.checkpointer import restore, save

__all__ = ["restore", "save"]
