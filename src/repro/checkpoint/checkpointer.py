"""Pytree checkpointing to .npz (path-keyed, dtype/shape-preserving).

Handles the full TrainState (stacked params, optimizer state, anchor,
counters). NamedTuples are stored with their field path; restore rebuilds
into a caller-provided template tree so custom containers round-trip.

Note on the two-phase protocol migration: TrainState gained an ``inflight``
slot, and overlapped strategies carry their pending anchor there instead of
in ``vars.z``. Checkpoints written before that change restore only into
templates built from the legacy ``Algorithm`` path (whose inflight is None);
restoring them into a native-strategy template raises KeyError on the
missing ``inflight`` paths. Retrain or re-save through the legacy shim to
migrate.

Note on the packed parameter plane (``AlgoConfig.packed``, default on):
packed strategies store anchor-shaped state and inflight slots as flat
``repro.parallel.packing.Packed`` buffers, which flatten to different
checkpoint paths than the per-leaf pytrees. Checkpoints written by per-leaf
strategies (or by pre-packed code) restore only into templates built with
``packed=False``; packed checkpoints likewise need a packed template.
"""
from __future__ import annotations

import io
import os
from typing import Any

import jax
import numpy as np

_SEP = "::"


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz has no bf16: widen losslessly
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    arrays = _flatten_with_paths(tree)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)


def restore(path: str, template: Any) -> Any:
    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = _SEP.join(_path_str(pp) for pp in p)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = arrays[key]
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    _, tdef = jax.tree_util.tree_flatten(template)
    return jax.tree_util.tree_unflatten(tdef, leaves)
