"""Pytree checkpointing to .npz (path-keyed, dtype/shape-preserving).

Handles the full TrainState (stacked params — pytree or plane-resident
``Packed`` — optimizer state, anchor, counters). NamedTuples are stored with
their field path; restore rebuilds into a caller-provided template tree so
custom containers round-trip.

Packed planes (plane-resident ``TrainState.x``, flat optimizer/anchor
state) round-trip natively: each :class:`repro.parallel.packing.Packed`
node stores its buffers under ``<prefix>::<bucket>`` plus a
``<prefix>::__layout__`` sidecar (the layout table as JSON) that makes the
checkpoint self-describing. The sidecar enables **cross-format restore**:

* a packed checkpoint restores into a ``packed=False`` template — each
  stored buffer is sliced back into the template's per-leaf arrays using
  the stored slot table (offset/size/shape/bucket per leaf, in the
  template subtree's flatten order);
* a per-leaf checkpoint restores into a packed template — the per-leaf
  arrays are packed into fresh buffers using the *template's* layout;
* the packed optimizer's single scalar step count and the per-leaf path's
  per-worker ``(m,)`` counts convert in both directions (workers step in
  lockstep, so the values agree).

Checkpoints written before the sidecar existed (pre-plane PRs) still
restore into same-format templates by direct path match; only cross-format
conversion needs the sidecar.

Note on the two-phase protocol migration: TrainState gained an ``inflight``
slot, and overlapped strategies carry their pending anchor there instead of
in ``vars.z``. Checkpoints written before that change restore only into
templates built from the legacy ``Algorithm`` path (whose inflight is None);
restoring them into a native-strategy template raises KeyError on the
missing ``inflight`` paths. Retrain or re-save through the legacy shim to
migrate.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

from repro.parallel.packing import Packed

_SEP = "::"
_LAYOUT_KEY = "__layout__"


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _join(*parts: str) -> str:
    return _SEP.join(p for p in parts if p)


def _widen(arr: np.ndarray) -> np.ndarray:
    if arr.dtype.name == "bfloat16":  # npz has no bf16: widen losslessly
        return arr.astype(np.float32)
    return arr


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {_join(*(_path_str(p) for p in path)): _widen(np.asarray(leaf)) for path, leaf in flat}


def _encode_layout(layout) -> np.ndarray:
    payload = json.dumps(
        {
            "slots": [
                [s.index, s.bucket, list(s.shape), s.dtype, s.offset, s.size, s.stride]
                for s in layout.slots
            ],
            "bucket_dtypes": list(layout.bucket_dtypes),
            "bucket_sizes": [int(n) for n in layout.bucket_sizes],
        }
    )
    return np.frombuffer(payload.encode("utf-8"), np.uint8)


def _packed_prefixes(tree):
    """(prefix, Packed) for every packed node, walked at Packed granularity."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree, is_leaf=lambda t: isinstance(t, Packed))
    return [
        (_join(*(_path_str(p) for p in path)), node)
        for path, node in flat
        if isinstance(node, Packed)
    ]


def save(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    arrays = _flatten_with_paths(tree)
    for prefix, node in _packed_prefixes(tree):
        arrays[_join(prefix, _LAYOUT_KEY)] = _encode_layout(node.layout)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)


def _fit_leaf(arr: np.ndarray, leaf, key: str, elastic: bool = False) -> np.ndarray:
    arr = np.asarray(arr)
    shape = tuple(getattr(leaf, "shape", arr.shape))
    if (
        elastic
        and arr.shape != shape
        and arr.ndim == len(shape)
        and arr.ndim >= 1
        and arr.shape[1:] == shape[1:]
    ):
        # elastic worker resize along the lead axis: a shrinking fleet keeps
        # the first m_new slots; a growing fleet seeds new slots from slot 0
        # (the fault harness re-syncs joining slots from the anchor on their
        # first round anyway — DESIGN.md §7)
        m_old, m_new = arr.shape[0], shape[0]
        if m_new < m_old:
            arr = arr[:m_new]
        else:
            pad = np.broadcast_to(arr[:1], (m_new - m_old,) + arr.shape[1:])
            arr = np.concatenate([arr, pad], axis=0)
    if arr.shape != shape:
        # packed scalar step count ↔ per-leaf (m,) per-worker counts: the
        # workers step in lockstep, so one value describes all of them
        if shape == () and arr.ndim == 1:
            arr = arr[0]
        elif arr.shape == () and len(shape) == 1:
            arr = np.broadcast_to(arr, shape).copy()
        else:
            raise ValueError(f"checkpoint leaf {key!r} has shape {arr.shape}; template wants {shape}")
    if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
        arr = arr.astype(leaf.dtype)
    return arr


def _expand_stored_packed(arrays: dict, layouts: dict, nodes) -> None:
    """Packed checkpoint → per-leaf template: slice each stored buffer back
    into per-leaf entries, keyed by the template's leaf paths (slot order ==
    the subtree's flatten order)."""
    template_packed = {p for p, n in nodes if isinstance(n, Packed)}
    for prefix, lay in layouts.items():
        if prefix in template_packed or _join(prefix, "0") not in arrays:
            continue
        key_prefix = prefix + _SEP if prefix else ""
        group = [(p, n) for p, n in nodes if p.startswith(key_prefix) and not isinstance(n, Packed)]
        slots = lay["slots"]
        if len(group) != len(slots):
            raise KeyError(
                f"packed checkpoint group {prefix!r} has {len(slots)} slots but the "
                f"template subtree has {len(group)} leaves — structures must match"
            )
        bufs = [arrays[_join(prefix, str(b))] for b in range(len(lay["bucket_sizes"]))]
        for (leaf_key, _), (_idx, bucket, shape, _dname, offset, size, _stride) in zip(group, slots):
            buf = bufs[bucket]
            lead = tuple(buf.shape[:-1])
            arrays[leaf_key] = buf[..., offset : offset + size].reshape(lead + tuple(shape))


def _pack_perleaf_into(arrays: dict, prefix: str, node: Packed):
    """Per-leaf checkpoint → packed template: gather the subtree's per-leaf
    arrays (paths derived from the template layout's treedef) and pack them
    into buffers with the template's layout. The lead (worker) axis is
    inferred from the *stored* arrays, not the template — an elastic restore
    packs at the checkpoint's worker count and lets ``_fit_leaf`` resize."""
    lay = node.layout
    dummy = jax.tree_util.tree_unflatten(lay.treedef, list(range(lay.num_leaves)))
    flat, _ = jax.tree_util.tree_flatten_with_path(dummy)
    key_by_index = {leaf: _join(*(_path_str(p) for p in path)) for path, leaf in flat}
    first_key = _join(prefix, key_by_index[lay.slots[0].index])
    if first_key not in arrays:
        raise KeyError(f"checkpoint missing {first_key!r} (needed to pack {prefix or '<root>'!r})")
    a0 = np.asarray(arrays[first_key])
    lead = tuple(int(s) for s in a0.shape[: a0.ndim - len(lay.slots[0].shape)])
    bufs = [
        np.zeros(lead + (int(n),), jax.numpy.dtype(d))
        for d, n in zip(lay.bucket_dtypes, lay.bucket_sizes)
    ]
    for slot in lay.slots:
        key = _join(prefix, key_by_index[slot.index])
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key!r} (needed to pack {prefix or '<root>'!r})")
        arr = np.asarray(arrays[key]).reshape(lead + (slot.size,))
        bufs[slot.bucket][..., slot.offset : slot.offset + slot.size] = arr.astype(bufs[slot.bucket].dtype)
    return bufs


def restore(path: str, template: Any, elastic: bool = False) -> Any:
    """Rebuild ``template``'s structure from the checkpoint at ``path``.

    ``elastic`` enables worker-count resize (DESIGN.md §7): any leaf or
    packed buffer whose trailing dims match the template but whose lead
    (worker) axis differs is resized — shrink keeps the first ``m_new``
    slots, grow seeds new slots from slot 0. The packed ``__layout__``
    sidecars make this work across formats too: a packed checkpoint from an
    m=8 fleet restores into an m=4 per-leaf template and vice versa."""
    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    layouts = {}
    for k in list(arrays):
        if k == _LAYOUT_KEY or k.endswith(_SEP + _LAYOUT_KEY):
            prefix = "" if k == _LAYOUT_KEY else k[: -(len(_LAYOUT_KEY) + len(_SEP))]
            layouts[prefix] = json.loads(bytes(arrays.pop(k).tobytes()).decode("utf-8"))

    flat, _ = jax.tree_util.tree_flatten_with_path(template, is_leaf=lambda t: isinstance(t, Packed))
    nodes = [(_join(*(_path_str(p) for p in path)), node) for path, node in flat]
    _expand_stored_packed(arrays, layouts, nodes)

    leaves = []
    for prefix, node in nodes:
        if isinstance(node, Packed):
            bufkeys = [_join(prefix, str(i)) for i in range(len(node.buffers))]
            if all(k in arrays for k in bufkeys):
                leaves.extend(_fit_leaf(arrays[k], b, k, elastic) for k, b in zip(bufkeys, node.buffers))
            else:
                leaves.extend(
                    _fit_leaf(a, b, prefix, elastic)
                    for a, b in zip(_pack_perleaf_into(arrays, prefix, node), node.buffers)
                )
        else:
            if prefix not in arrays:
                raise KeyError(f"checkpoint missing {prefix!r}")
            leaves.append(_fit_leaf(arrays[prefix], node, prefix, elastic))
    _, tdef = jax.tree_util.tree_flatten(template)
    return jax.tree_util.tree_unflatten(tdef, leaves)
