"""Kernel dispatch flags.

Pallas kernels target TPU; in this container they execute only in interpret
mode. Model code consults :func:`use_pallas` so the same model definition
runs (a) pure-jnp on CPU / in the dry-run lowering, (b) through the Pallas
kernels on a real TPU or in interpret-mode kernel tests.
"""
from __future__ import annotations

import contextlib
import os
import threading

import jax


class _State(threading.local):
    def __init__(self):
        self.forced = None  # None = auto
        self.cost_unroll = False


_STATE = _State()


def cost_unroll() -> bool:
    """When True, chunked jnp recurrences unroll their scans so XLA's HLO
    cost analysis (which counts while-loop bodies once) sees the full FLOP /
    byte / collective count. Used only by the dry-run cost probes."""
    return _STATE.cost_unroll


@contextlib.contextmanager
def unrolled_costs(on: bool = True):
    prev = _STATE.cost_unroll
    _STATE.cost_unroll = on
    try:
        yield
    finally:
        _STATE.cost_unroll = prev


def use_pallas() -> bool:
    if _STATE.forced is not None:
        return _STATE.forced
    if os.environ.get("REPRO_FORCE_PALLAS") == "1":
        return True
    return jax.default_backend() == "tpu"


def interpret_mode() -> bool:
    """Whether pallas_call must run in interpret mode (non-TPU backend)."""
    return jax.default_backend() != "tpu"


@contextlib.contextmanager
def force_pallas(on: bool = True):
    prev = _STATE.forced
    _STATE.forced = on
    try:
        yield
    finally:
        _STATE.forced = prev
