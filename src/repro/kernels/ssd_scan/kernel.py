"""Mamba2 SSD chunked-scan Pallas TPU kernel.

Grid = (B·H, n_chunks) with the chunk axis innermost (sequential on TPU), so
the running state (P×N, f32) lives in VMEM scratch across chunks. Within a
chunk everything is dense matmuls over (L×L), (L×N), (L×P) tiles — MXU work —
which is the whole point of the SSD reformulation on TPU: the recurrence
only crosses chunk boundaries.

VMEM budget per step ≈ L·(P+2N) inputs + L² decay/score + P·N state; with
L=chunk=128, P=64, N=128 that is ~250 KB — comfortably inside VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, st_ref, state, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    a = a_ref[0, 0]  # scalar A_h (negative)
    x = x_ref[0].astype(jnp.float32)  # (L, P)
    dt = dt_ref[0].astype(jnp.float32)  # (L,)
    B = b_ref[0].astype(jnp.float32)  # (L, N)
    C = c_ref[0].astype(jnp.float32)  # (L, N)

    dA = dt * a  # (L,)
    cum = jnp.cumsum(dA)  # (L,)
    xbar = x * dt[:, None]

    li = cum[:, None]
    lj = cum[None, :]
    tril = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (chunk, chunk), 1
    )
    M = jnp.exp(jnp.where(tril, li - lj, -1e9))  # (L, L)
    CB = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))  # (L, L)
    y = jax.lax.dot(CB * M, xbar)  # (L, P)

    s_prev = state[...]  # (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(C, s_prev, (((1,), (1,)), ((), ())))

    dte = jnp.exp(cum[-1] - cum)  # (L,)
    s_c = jax.lax.dot_general(xbar, B * dte[:, None], (((0,), (0,)), ((), ())))  # (P, N)
    state[...] = jnp.exp(cum[-1]) * s_prev + s_c

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == pl.num_programs(1) - 1)
    def _final():
        st_ref[0] = state[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_bh(a, x, dt, b, c, *, chunk: int = 64, interpret: bool = False):
    """a: (BH,1); x: (BH,S,P); dt: (BH,S); b/c: (BH,S,N). S % chunk == 0.

    Returns y (BH,S,P) f32-accumulated in x.dtype and final state (BH,P,N) f32.
    (The D·x skip term is applied by the ops wrapper.)
    """
    bh, s, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    grid = (bh, nc)
    y, st = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, ci: (i, 0)),
            pl.BlockSpec((1, chunk, p), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, chunk), lambda i, ci: (i, ci)),
            pl.BlockSpec((1, chunk, n), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, ci: (i, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, p, n), lambda i, ci: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), x.dtype),
            jax.ShapeDtypeStruct((bh, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(a, x, dt, b, c)
    return y, st
