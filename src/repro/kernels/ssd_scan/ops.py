"""Public wrapper for the SSD scan kernel: layout, padding, group expansion,
D-skip term, and a chunked-jnp custom VJP (recompute, no (L,L) residuals)."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flags
from repro.kernels.ssd_scan import kernel as _k
from repro.kernels.ssd_scan import ref as _ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def ssd_scan(x, dt, A, B, C, D, chunk: int = 64):
    """x (B,S,H,P); dt (B,S,H); A (H,); B/C (B,S,G,N); D (H,) -> (y, final)."""
    return _forward(x, dt, A, B, C, D, chunk)


def _forward(x, dt, A, B, C, D, chunk) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s, h, p = x.shape
    n = B.shape[-1]
    g = B.shape[2]
    pad = (-s) % chunk
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    Bp = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Cp = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    rep = h // g
    Bh = jnp.repeat(Bp, rep, axis=2)
    Ch = jnp.repeat(Cp, rep, axis=2)
    # (B,S,H,·) -> (B*H, S, ·)
    xf = jnp.transpose(xp, (0, 2, 1, 3)).reshape(b * h, sp, p)
    dtf = jnp.transpose(dtp, (0, 2, 1)).reshape(b * h, sp)
    bf = jnp.transpose(Bh, (0, 2, 1, 3)).reshape(b * h, sp, n)
    cf = jnp.transpose(Ch, (0, 2, 1, 3)).reshape(b * h, sp, n)
    af = jnp.tile(A[None, :], (b, 1)).reshape(b * h, 1)
    y, st = _k.ssd_scan_bh(af, xf, dtf, bf, cf, chunk=min(chunk, sp), interpret=flags.interpret_mode())
    y = jnp.transpose(y.reshape(b, h, sp, p), (0, 2, 1, 3))[:, :s]
    y = y + x.astype(y.dtype) * D[None, None, :, None]
    return y.astype(x.dtype), st.reshape(b, h, p, n)


def _fwd(x, dt, A, B, C, D, chunk):
    out = _forward(x, dt, A, B, C, D, chunk)
    return out, (x, dt, A, B, C, D)


def _bwd(chunk, res, cts):
    x, dt, A, B, C, D = res

    def f(x, dt, A, B, C, D):
        return _ref.ssd_chunked(x, dt, A, B, C, D, chunk=chunk)

    _, vjp = jax.vjp(f, x, dt, A, B, C, D)
    return vjp(cts)


ssd_scan.defvjp(_fwd, _bwd)

reference = _ref.ssd_reference
chunked = _ref.ssd_chunked


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t, D):
    """Single-token recurrent update for serving.

    state (B,H,P,N); x_t (B,H,P); dt_t (B,H); B_t/C_t (B,G,N) -> (y, state).
    """
    h = x_t.shape[1]
    g = B_t.shape[1]
    Bh = jnp.repeat(B_t, h // g, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C_t, h // g, axis=1).astype(jnp.float32)
    dtf = dt_t.astype(jnp.float32)
    decay = jnp.exp(dtf * A)[..., None, None]
    upd = jnp.einsum("bhp,bhn->bhpn", x_t.astype(jnp.float32) * dtf[..., None], Bh)
    state = decay * state + upd
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch) + x_t.astype(jnp.float32) * D[None, :, None]
    return y.astype(x_t.dtype), state
