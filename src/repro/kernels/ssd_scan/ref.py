"""Pure-jnp oracle for the Mamba2 SSD chunked scan [arXiv:2405.21060 as used
by Zamba2, arXiv:2411.15242].

Semantics (per batch b, head h, head_dim p, state n):
    s_t = exp(dt_t * A_h) * s_{t-1} + dt_t * B_t k-outer x_t
    y_t = C_t · s_t + D_h * x_t

Two implementations:
* :func:`ssd_reference` — step-by-step lax.scan over time (ground truth).
* :func:`ssd_chunked`   — the chunked SSD algorithm (intra-chunk dense
  matmuls + inter-chunk state recurrence) the Pallas kernel mirrors.

Shapes: x (B,S,H,P); dt (B,S,H); A (H,) with A<0; B/C (B,S,G,N) with
G | H (grouped B/C like Mamba2's n_groups); D (H,). Returns (y, final_state)
with final_state (B,H,P,N).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _expand_groups(mat, h):
    g = mat.shape[2]
    return jnp.repeat(mat, h // g, axis=2)  # (B,S,H,N)


def ssd_reference(x, dt, A, B, C, D) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s, h, p = x.shape
    n = B.shape[-1]
    Bh = _expand_groups(B, h).astype(jnp.float32)
    Ch = _expand_groups(C, h).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        decay = jnp.exp(dtt * A)[..., None, None]  # (B,H,1,1)
        upd = jnp.einsum("bhp,bhn->bhpn", xt * dtt[..., None], bt)
        state = decay * state + upd
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0), jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0))
    final, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1) + xf * D[None, None, :, None]
    return y.astype(x.dtype), final


def ssd_chunked(x, dt, A, B, C, D, chunk: int = 64) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s, h, p = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = x.shape[1]
    nc = sp // chunk
    Bh = _expand_groups(B, h).astype(jnp.float32)
    Ch = _expand_groups(C, h).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    # reshape to chunks: (B, nc, L, H, ...)
    xc = xf.reshape(b, nc, chunk, h, p)
    dtc = dtf.reshape(b, nc, chunk, h)
    Bc = Bh.reshape(b, nc, chunk, h, n)
    Cc = Ch.reshape(b, nc, chunk, h, n)

    dA = dtc * A  # (B,nc,L,H)
    cum = jnp.cumsum(dA, axis=2)  # inclusive cumsum along chunk
    total = cum[:, :, -1]  # (B,nc,H)

    # intra-chunk: M_ij = exp(cum_i - cum_j) for i>=j  (1-step-lag form:
    # contribution of x_j (scaled dt_j) to y_i includes decay exp(sum_{j+1..i} dA) =
    # exp(cum_i - cum_j))
    li = cum[:, :, :, None, :]  # (B,nc,L,1,H)
    lj = cum[:, :, None, :, :]  # (B,nc,1,L,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask exponent before exp (masked entries can overflow; inf would NaN the vjp)
    M = jnp.exp(jnp.where(mask[None, None, :, :, None], li - lj, -1e9))  # (B,nc,L,L,H)
    CB = jnp.einsum("bclhn,bcmhn->bclmh", Cc, Bc)  # (B,nc,L,L,H)
    xbar = xc * dtc[..., None]
    y_intra = jnp.einsum("bclmh,bclmh,bcmhp->bclhp", CB, M, xbar)

    # chunk summary state: S_c = sum_j exp(total - cum_j) B_j^T xbar_j -> (B,nc,H,P,N)
    decay_to_end = jnp.exp(total[:, :, None] - cum)  # (B,nc,L,H)
    S_c = jnp.einsum("bclh,bclhn,bclhp->bchpn", decay_to_end, Bc, xbar)

    # inter-chunk recurrence over chunk states
    from repro.kernels import flags as _flags

    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    if False:  # state-scan flops are negligible; unroll only bloats probe HLO (see costprobe.py)
        state = s0
        prevs = []
        for ci in range(nc):
            prevs.append(state)
            state = jnp.exp(total[:, ci])[..., None, None] * state + S_c[:, ci]
        final = state
        prev = jnp.stack(prevs, axis=1)
    else:

        def step(state, inp):
            s_c, tot = inp  # (B,H,P,N), (B,H)
            new = jnp.exp(tot)[..., None, None] * state + s_c
            return new, state  # emit state BEFORE this chunk

        final, prev_states = jax.lax.scan(step, s0, (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(total, 1, 0)))
        prev = jnp.moveaxis(prev_states, 0, 1)  # (B,nc,H,P,N) state entering each chunk

    # inter-chunk contribution: y_i += exp(cum_i) * C_i · S_prev
    y_inter = jnp.einsum("bclh,bclhn,bchpn->bclhp", jnp.exp(cum), Cc, prev)

    y = (y_intra + y_inter).reshape(b, sp, h, p) + xf * D[None, None, :, None]
    return y[:, :s].astype(x.dtype), final
