"""Pure-jnp oracle for the anchor-pullback mix (paper eq. (4)):
    out = (1 - alpha) * x + alpha * z
"""
from __future__ import annotations

import jax.numpy as jnp


def anchor_mix(x: jnp.ndarray, z: jnp.ndarray, alpha: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    zf = z.astype(jnp.float32)
    return ((1.0 - alpha) * xf + alpha * zf).astype(x.dtype)
