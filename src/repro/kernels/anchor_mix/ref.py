"""Pure-jnp oracles for the anchor-mix kernel family.

``anchor_mix`` is the paper's eq. (4) pullback; the ``pullback_mean*``
variants are the *fused round-boundary* ops used by the packed parameter
plane: eq. (4) plus the eq. (5) anchor (/momentum) update in one logical
pass over worker-stacked flat buffers. Every cast in these oracles mirrors
the historical per-leaf tree ops bit for bit — the packed boundary is pinned
to the per-leaf path by golden tests, so the cast chains here are load-
bearing, not style.
"""
from __future__ import annotations

import jax.numpy as jnp


def anchor_mix(x: jnp.ndarray, z: jnp.ndarray, alpha: float) -> jnp.ndarray:
    """out = (1 - alpha) * x + alpha * z (paper eq. 4)."""
    xf = x.astype(jnp.float32)
    zf = z.astype(jnp.float32)
    return ((1.0 - alpha) * xf + alpha * zf).astype(x.dtype)


def pullback_mean(x, z, alpha: float, mean_pre: bool = False):
    """Fused eq. (4) + worker mean over a stacked flat buffer.

    x: (m, n) worker-stacked plane, z: (n,) anchor plane.
    Returns (x_new, mean) where mean averages the pulled-back plane (or the
    pre-pullback plane when ``mean_pre`` — EASGD's symmetric W).

    Kept shape-for-shape identical to the per-leaf tree ops (no rows
    reshape, no reassociation): XLA's fusion/FMA choices are shape-
    sensitive, and any deviation breaks the bitwise pin to the per-leaf
    oracle that the golden tests enforce.
    """
    xf = x.astype(jnp.float32)
    zf = z.astype(jnp.float32)
    x_new = ((1.0 - alpha) * xf + alpha * zf[None]).astype(x.dtype)
    src = x if mean_pre else x_new
    mean = jnp.mean(src, axis=0, dtype=jnp.float32).astype(x.dtype)
    return x_new, mean


def pullback_mean_momentum(x, z, v, alpha: float, beta: float):
    """Fused eq. (4) + eqs. (10)-(11) anchor momentum in one pass.

    x: (m, n), z: (n,) consumed anchor, v: (n,) anchor momentum.
    Returns (x_new, z_next, v_new):
        x_new  = (1-α)·x + α·z                 (pullback, eq. 4)
        mean   = mean_i(x_new_i)               (eq. 5 collective)
        v_new  = β·v + (mean − z)              (eq. 10)
        z_next = z + v_new                     (eq. 11)
    """
    x_new, mean = pullback_mean(x, z, alpha)
    zf = z.astype(jnp.float32)
    v_new = (beta * v.astype(jnp.float32) + (mean.astype(jnp.float32) - zf)).astype(v.dtype)
    z_next = (zf + v_new.astype(jnp.float32)).astype(z.dtype)
    return x_new, z_next, v_new
