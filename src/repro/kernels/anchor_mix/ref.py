"""Pure-jnp oracles for the anchor-mix kernel family.

``anchor_mix`` is the paper's eq. (4) pullback; the ``pullback_mean*``
variants are the *fused round-boundary* ops used by the packed parameter
plane: eq. (4) plus the eq. (5) anchor (/momentum) update in one logical
pass over worker-stacked flat buffers. Every cast in these oracles mirrors
the historical per-leaf tree ops bit for bit — the packed boundary is pinned
to the per-leaf path by golden tests, so the cast chains here are load-
bearing, not style.

Masked boundaries (DESIGN.md §7): the fused ops accept an optional
``weights`` vector — (m,) f32 renormalized averaging weights, zero on dead
workers. A dead worker's row passes through the pullback untouched (it is
not participating this round), and the worker mean becomes the weighted sum
Σ_i w_i·x_i over live rows. ``weights=None`` is the fully-live path and is
byte-identical to the pre-fault code.
"""
from __future__ import annotations

import jax.numpy as jnp


def anchor_mix(x: jnp.ndarray, z: jnp.ndarray, alpha: float) -> jnp.ndarray:
    """out = (1 - alpha) * x + alpha * z (paper eq. 4)."""
    xf = x.astype(jnp.float32)
    zf = z.astype(jnp.float32)
    return ((1.0 - alpha) * xf + alpha * zf).astype(x.dtype)


def pullback_mean(x, z, alpha: float, mean_pre: bool = False, weights=None):
    """Fused eq. (4) + worker mean over a stacked flat buffer.

    x: (m, n) worker-stacked plane, z: (n,) anchor plane.
    Returns (x_new, mean) where mean averages the pulled-back plane (or the
    pre-pullback plane when ``mean_pre`` — EASGD's symmetric W).

    With ``weights`` ((m,) f32, zeros on dead workers) the boundary is
    membership-masked: dead rows skip the pullback and the mean is the
    weighted sum over live rows.

    Kept shape-for-shape identical to the per-leaf tree ops (no rows
    reshape, no reassociation): XLA's fusion/FMA choices are shape-
    sensitive, and any deviation breaks the bitwise pin to the per-leaf
    oracle that the golden tests enforce.
    """
    xf = x.astype(jnp.float32)
    zf = z.astype(jnp.float32)
    x_new = ((1.0 - alpha) * xf + alpha * zf[None]).astype(x.dtype)
    if weights is None:
        src = x if mean_pre else x_new
        mean = jnp.mean(src, axis=0, dtype=jnp.float32).astype(x.dtype)
        return x_new, mean
    w = weights.astype(jnp.float32)
    live = w > 0
    x_new = jnp.where(live[:, None], x_new, x)
    src = x if mean_pre else x_new
    mean = jnp.sum(src.astype(jnp.float32) * w[:, None], axis=0).astype(x.dtype)
    return x_new, mean


def pullback_mean_momentum(x, z, v, alpha: float, beta: float, weights=None):
    """Fused eq. (4) + eqs. (10)-(11) anchor momentum in one pass.

    x: (m, n), z: (n,) consumed anchor, v: (n,) anchor momentum.
    Returns (x_new, z_next, v_new):
        x_new  = (1-α)·x + α·z                 (pullback, eq. 4)
        mean   = mean_i(x_new_i)               (eq. 5 collective)
        v_new  = β·v + (mean − z)              (eq. 10)
        z_next = z + v_new                     (eq. 11)

    ``weights`` masks the pullback/mean exactly as in :func:`pullback_mean`;
    the momentum recurrence itself is anchor-shaped and unmasked.
    """
    x_new, mean = pullback_mean(x, z, alpha, weights=weights)
    zf = z.astype(jnp.float32)
    v_new = (beta * v.astype(jnp.float32) + (mean.astype(jnp.float32) - zf)).astype(v.dtype)
    z_next = (zf + v_new.astype(jnp.float32)).astype(z.dtype)
    return x_new, z_next, v_new
