"""Public wrapper for the anchor-mix kernel: pytree-level pullback.

``pullback_tree(x_tree, z_tree, alpha)`` applies the paper's eq. (4) to every
leaf. On TPU each leaf is flattened, padded to the 128-lane boundary and run
through the fused kernel; elsewhere the jnp oracle is used (and XLA fuses it
into the surrounding round program — important for the dry-run, where the
pullback must stay fusable with the anchor all-gather).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flags
from repro.kernels.anchor_mix import kernel as _k
from repro.kernels.anchor_mix import ref as _ref


def anchor_mix(x, z, alpha: float):
    if not flags.use_pallas():
        return _ref.anchor_mix(x, z, alpha)
    shape = x.shape
    n = x.size
    pad = (-n) % 128
    xf = jnp.pad(x.reshape(-1), (0, pad))
    zf = jnp.pad(z.reshape(-1), (0, pad))
    out = _k.anchor_mix_flat(xf, zf, alpha=float(alpha), interpret=flags.interpret_mode())
    return out[:n].reshape(shape)


def pullback_tree(x_tree, z_tree, alpha: float):
    return jax.tree.map(lambda x, z: anchor_mix(x, z, alpha), x_tree, z_tree)


reference = _ref.anchor_mix
