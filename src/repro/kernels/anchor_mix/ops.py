"""Public wrappers for the anchor-mix kernel family.

``pullback_tree(x_tree, z_tree, alpha)`` applies the paper's eq. (4) to every
leaf — the per-leaf reference path. The packed parameter plane instead calls
the flat-buffer ops directly: ``anchor_mix`` on one plane, or the fused
``pullback_mean`` / ``pullback_mean_momentum`` boundary ops that compute
eq. (4) and the eq. (5) anchor(/momentum) update in a single HBM pass.

On TPU the ops run through the Pallas kernels; elsewhere the jnp oracles are
used (and XLA fuses them into the surrounding round program — important for
the dry-run, where the pullback must stay fusable with the anchor
all-gather). Buffers already on the 128-lane boundary skip the pad+slice
round-trip entirely (packed planes always do).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flags
from repro.kernels.anchor_mix import kernel as _k
from repro.kernels.anchor_mix import ref as _ref
from repro.kernels.consensus_probe import ref as _probe_ref


def _pad_last(a, pad: int):
    if pad == 0:
        return a
    width = [(0, 0)] * (a.ndim - 1) + [(0, pad)]
    return jnp.pad(a, width)


def anchor_mix(x, z, alpha: float):
    if not flags.use_pallas():
        return _ref.anchor_mix(x, z, alpha)
    shape = x.shape
    n = x.size
    pad = (-n) % 128
    xf = _pad_last(x.reshape(-1), pad)
    zf = _pad_last(z.reshape(-1), pad)
    out = _k.anchor_mix_flat(xf, zf, alpha=float(alpha), interpret=flags.interpret_mode())
    if pad:
        out = out[:n]
    return out.reshape(shape)


def pullback_mean(x, z, alpha: float, mean_pre: bool = False, probe: bool = False, weights=None):
    """Fused eq. (4) + worker mean on a stacked plane. x: (m, n), z: (n,).
    Returns (x_new, mean). Aligned buffers (n % 128 == 0) run pad-free.

    With ``probe`` also returns the consensus-distance raw sums
    ``(drift_sq, scale_sq)`` of the pre-pullback plane (DESIGN.md §6) as
    extra outputs of the SAME kernel launch — the adaptive-τ probe rides
    the boundary's existing HBM pass.

    ``weights`` ((m,) f32 renormalized membership weights, zeros on dead
    workers) selects the masked boundary (DESIGN.md §7): dead rows skip the
    pullback and the mean is the weighted sum over live rows. ``None`` is
    byte-identical to the pre-fault path."""
    if not flags.use_pallas():
        out = _ref.pullback_mean(x, z, alpha, mean_pre=mean_pre, weights=weights)
        return (out + (_probe_ref.plane_probe(x),)) if probe else out
    n = x.shape[-1]
    pad = (-n) % 128
    outs = _k.pullback_mean_flat(
        _pad_last(x, pad), _pad_last(z, pad), weights,
        alpha=float(alpha), mean_pre=mean_pre, probe=probe, interpret=flags.interpret_mode(),
    )
    x_new, mean = outs[0], outs[1]
    if pad:
        x_new, mean = x_new[:, :n], mean[:n]
    if probe:
        st = outs[2]
        return x_new, mean, (jnp.sum(st[0]), jnp.sum(st[1]))
    return x_new, mean


def pullback_mean_momentum(x, z, v, alpha: float, beta: float, probe: bool = False, weights=None):
    """Fused eq. (4) + eqs. (10)-(11) on a stacked plane. x: (m, n), z/v: (n,).
    Returns (x_new, z_next, v_new); with ``probe`` also the pre-pullback
    ``(drift_sq, scale_sq)`` raw sums, from the same launch. ``weights``
    selects the membership-masked variant (see :func:`pullback_mean`)."""
    if not flags.use_pallas():
        out = _ref.pullback_mean_momentum(x, z, v, alpha, beta, weights=weights)
        return (out + (_probe_ref.plane_probe(x),)) if probe else out
    n = x.shape[-1]
    pad = (-n) % 128
    outs = _k.pullback_momentum_flat(
        _pad_last(x, pad), _pad_last(z, pad), _pad_last(v, pad), weights,
        alpha=float(alpha), beta=float(beta), probe=probe, interpret=flags.interpret_mode(),
    )
    x_new, z_next, v_new = outs[0], outs[1], outs[2]
    if pad:
        x_new, z_next, v_new = x_new[:, :n], z_next[:n], v_new[:n]
    if probe:
        st = outs[3]
        return x_new, z_next, v_new, (jnp.sum(st[0]), jnp.sum(st[1]))
    return x_new, z_next, v_new


def pullback_tree(x_tree, z_tree, alpha: float):
    return jax.tree.map(lambda x, z: anchor_mix(x, z, alpha), x_tree, z_tree)


reference = _ref.anchor_mix
