"""Anchor-mix Pallas TPU kernels — the paper's round-boundary updates.

``anchor_mix_flat`` is the plain eq. (4) pullback x ← (1−α)·x + α·z over one
flat buffer: one read of x, one of z, one write, tiled through VMEM in
lane-aligned blocks. The op is purely memory-bound (arithmetic intensity
3 flops / 6 bytes in bf16), so the kernel's value is guaranteeing exactly
3·bytes traffic at the round boundary.

``pullback_mean_flat`` / ``pullback_momentum_flat`` are the *fused boundary*
kernels for the packed parameter plane: they take the worker-stacked flat
buffer x (m, n) plus the anchor plane z (n,) and produce the pullback AND
the eq. (5) anchor(/momentum, eqs. 10–11) update in a single HBM pass —
one read of x, one of z (and v), instead of the back-to-back sweeps XLA
emits for pullback-then-mean-then-momentum (which re-reads the freshly
written x). The worker mean is computed per block entirely in VMEM: the
worker axis m lives inside the block, so no cross-program reduction is
needed and each grid step writes its (block,) slice of every output.

With ``probe=True`` the ``pullback_mean(_momentum)`` variants additionally
emit the consensus-distance partial sums of the adaptive-τ controller
(DESIGN.md §6) as one extra (2, 128) output: Σ(x_i − x̄)² and Σ x̄² of the
*pre-pullback* plane, computed from the block already resident in VMEM and
accumulated across the sequential grid — the boundary's HBM traffic and
launch count are unchanged (the zero-extra-launch contract pinned by the
probe tests). The probe mean is always the pre-pullback worker mean, so the
stats measure the drift the workers accumulated over the round regardless
of ``mean_pre``.

All cast chains mirror ``ref.py`` exactly — the packed boundary must stay
bitwise identical to the per-leaf reference path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.consensus_probe.kernel import LANE, probe_block


def _mix_kernel(x_ref, z_ref, o_ref, *, alpha: float):
    x = x_ref[...].astype(jnp.float32)
    z = z_ref[...].astype(jnp.float32)
    o_ref[...] = ((1.0 - alpha) * x + alpha * z).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("alpha", "block", "interpret"))
def anchor_mix_flat(x, z, *, alpha: float, block: int = 1 << 16, interpret: bool = False):
    """x, z: flat (n,) arrays (n % 128 == 0 after ops-side padding)."""
    (n,) = x.shape
    block = min(block, n)
    grid = (pl.cdiv(n, block),)
    return pl.pallas_call(
        functools.partial(_mix_kernel, alpha=alpha),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(x, z)


def _accum_probe(x, st_ref, acc_ref):
    """Accumulate the consensus partial sums of the pre-pullback tile x
    (m, block) into the VMEM scratch; the final grid step writes the
    (2, 128) output. Same lane-reduced accumulation as the standalone
    ``consensus_probe`` kernel."""
    i = pl.program_id(0)
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=0)  # (block,)
    drift = jnp.sum(jnp.square(xf - mean[None, :]).reshape(-1, LANE), axis=0)
    scale = jnp.sum(jnp.square(mean).reshape(-1, LANE), axis=0)

    @pl.when(i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[0, :] += drift
    acc_ref[1, :] += scale

    @pl.when(i == pl.num_programs(0) - 1)
    def _():
        st_ref[...] = acc_ref[...]


def _pullback_mean_kernel(x_ref, z_ref, *refs, alpha: float, mean_pre: bool, probe: bool, masked: bool):
    refs = list(refs)
    w_ref = refs.pop(0) if masked else None
    xo_ref, mo_ref = refs.pop(0), refs.pop(0)
    z = z_ref[...].astype(jnp.float32)  # (block,)
    x = x_ref[...]  # (m, block)
    x_new = ((1.0 - alpha) * x.astype(jnp.float32) + alpha * z[None, :]).astype(xo_ref.dtype)
    if masked:
        # membership-masked boundary (DESIGN.md §7): dead rows (w == 0) skip
        # the pullback; the mean is the renormalized weighted sum over live
        # rows — same elementwise chain as the ref/per-leaf oracle
        w = w_ref[...].astype(jnp.float32)  # (m,)
        x_new = jnp.where((w > 0)[:, None], x_new, x)
        xo_ref[...] = x_new
        src = x if mean_pre else x_new
        mo_ref[...] = jnp.sum(src.astype(jnp.float32) * w[:, None], axis=0).astype(mo_ref.dtype)
    else:
        xo_ref[...] = x_new
        src = x if mean_pre else x_new
        # mean over the worker axis lives inside the block — matches
        # jnp.mean(src, axis=0, dtype=f32).astype(param dtype) of the ref path
        mo_ref[...] = jnp.mean(src.astype(jnp.float32), axis=0).astype(mo_ref.dtype)
    if probe:
        st_ref, acc_ref = refs
        _accum_probe(x, st_ref, acc_ref)


@functools.partial(jax.jit, static_argnames=("alpha", "mean_pre", "block", "probe", "interpret"))
def pullback_mean_flat(x, z, weights=None, *, alpha: float, mean_pre: bool = False, block: int = 1 << 13, probe: bool = False, interpret: bool = False):
    """x: (m, n) stacked plane, z: (n,) anchor plane; n % 128 == 0.

    Returns (x_new, worker_mean) in one HBM pass; with ``probe`` also the
    (2, 128) consensus partial sums of the pre-pullback plane, in the same
    launch. ``weights`` ((m,) f32, zeros on dead workers) selects the
    membership-masked variant — same launch count, one extra tiny input.
    The probe stats always cover the full pre-pullback plane (the consensus
    measure is defined over all worker slots), masked or not.
    """
    m, n = x.shape
    masked = weights is not None
    block = probe_block(n, block) if probe else min(block, n)
    grid = (pl.cdiv(n, block),)
    in_specs = [
        pl.BlockSpec((m, block), lambda i: (0, i)),
        pl.BlockSpec((block,), lambda i: (i,)),
    ]
    args = [x, z]
    if masked:
        in_specs.append(pl.BlockSpec((m,), lambda i: (0,)))
        args.append(weights)
    out_specs = [
        pl.BlockSpec((m, block), lambda i: (0, i)),
        pl.BlockSpec((block,), lambda i: (i,)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((m, n), x.dtype),
        jax.ShapeDtypeStruct((n,), x.dtype),
    ]
    scratch = []
    if probe:
        out_specs.append(pl.BlockSpec((2, LANE), lambda i: (0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((2, LANE), jnp.float32))
        scratch.append(pltpu.VMEM((2, LANE), jnp.float32))
    return pl.pallas_call(
        functools.partial(_pullback_mean_kernel, alpha=alpha, mean_pre=mean_pre, probe=probe, masked=masked),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(*args)


def _pullback_momentum_kernel(x_ref, z_ref, v_ref, *refs, alpha: float, beta: float, probe: bool, masked: bool):
    refs = list(refs)
    w_ref = refs.pop(0) if masked else None
    xo_ref, zo_ref, vo_ref = refs.pop(0), refs.pop(0), refs.pop(0)
    z = z_ref[...].astype(jnp.float32)  # (block,)
    x = x_ref[...]
    x_new = ((1.0 - alpha) * x.astype(jnp.float32) + alpha * z[None, :]).astype(xo_ref.dtype)
    if masked:
        w = w_ref[...].astype(jnp.float32)  # (m,)
        x_new = jnp.where((w > 0)[:, None], x_new, x)
        xo_ref[...] = x_new
        mean = jnp.sum(x_new.astype(jnp.float32) * w[:, None], axis=0).astype(x_ref.dtype)
    else:
        xo_ref[...] = x_new
        mean = jnp.mean(x_new.astype(jnp.float32), axis=0).astype(x_ref.dtype)
    v_new = (beta * v_ref[...].astype(jnp.float32) + (mean.astype(jnp.float32) - z)).astype(vo_ref.dtype)
    vo_ref[...] = v_new
    zo_ref[...] = (z + v_new.astype(jnp.float32)).astype(zo_ref.dtype)
    if probe:
        st_ref, acc_ref = refs
        _accum_probe(x, st_ref, acc_ref)


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "block", "probe", "interpret"))
def pullback_momentum_flat(x, z, v, weights=None, *, alpha: float, beta: float, block: int = 1 << 13, probe: bool = False, interpret: bool = False):
    """x: (m, n), z/v: (n,); n % 128 == 0.

    Returns (x_new, z_next, v_new): eq. (4) pullback + eqs. (10)-(11) anchor
    momentum, one read of each input, one write of each output; with
    ``probe`` also the (2, 128) consensus partial sums, in the same launch.
    ``weights`` selects the membership-masked variant (see
    :func:`pullback_mean_flat`).
    """
    m, n = x.shape
    masked = weights is not None
    block = probe_block(n, block) if probe else min(block, n)
    grid = (pl.cdiv(n, block),)
    in_specs = [
        pl.BlockSpec((m, block), lambda i: (0, i)),
        pl.BlockSpec((block,), lambda i: (i,)),
        pl.BlockSpec((block,), lambda i: (i,)),
    ]
    args = [x, z, v]
    if masked:
        in_specs.append(pl.BlockSpec((m,), lambda i: (0,)))
        args.append(weights)
    out_specs = [
        pl.BlockSpec((m, block), lambda i: (0, i)),
        pl.BlockSpec((block,), lambda i: (i,)),
        pl.BlockSpec((block,), lambda i: (i,)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((m, n), x.dtype),
        jax.ShapeDtypeStruct((n,), z.dtype),
        jax.ShapeDtypeStruct((n,), v.dtype),
    ]
    scratch = []
    if probe:
        out_specs.append(pl.BlockSpec((2, LANE), lambda i: (0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((2, LANE), jnp.float32))
        scratch.append(pltpu.VMEM((2, LANE), jnp.float32))
    return pl.pallas_call(
        functools.partial(_pullback_momentum_kernel, alpha=alpha, beta=beta, probe=probe, masked=masked),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(*args)
