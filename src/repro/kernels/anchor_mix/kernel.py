"""Anchor-mix Pallas TPU kernels — the paper's round-boundary updates.

``anchor_mix_flat`` is the plain eq. (4) pullback x ← (1−α)·x + α·z over one
flat buffer: one read of x, one of z, one write, tiled through VMEM in
lane-aligned blocks. The op is purely memory-bound (arithmetic intensity
3 flops / 6 bytes in bf16), so the kernel's value is guaranteeing exactly
3·bytes traffic at the round boundary.

``pullback_mean_flat`` / ``pullback_momentum_flat`` are the *fused boundary*
kernels for the packed parameter plane: they take the worker-stacked flat
buffer x (m, n) plus the anchor plane z (n,) and produce the pullback AND
the eq. (5) anchor(/momentum, eqs. 10–11) update in a single HBM pass —
one read of x, one of z (and v), instead of the back-to-back sweeps XLA
emits for pullback-then-mean-then-momentum (which re-reads the freshly
written x). The worker mean is computed per block entirely in VMEM: the
worker axis m lives inside the block, so no cross-program reduction is
needed and each grid step writes its (block,) slice of every output.

All cast chains mirror ``ref.py`` exactly — the packed boundary must stay
bitwise identical to the per-leaf reference path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mix_kernel(x_ref, z_ref, o_ref, *, alpha: float):
    x = x_ref[...].astype(jnp.float32)
    z = z_ref[...].astype(jnp.float32)
    o_ref[...] = ((1.0 - alpha) * x + alpha * z).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("alpha", "block", "interpret"))
def anchor_mix_flat(x, z, *, alpha: float, block: int = 1 << 16, interpret: bool = False):
    """x, z: flat (n,) arrays (n % 128 == 0 after ops-side padding)."""
    (n,) = x.shape
    block = min(block, n)
    grid = (pl.cdiv(n, block),)
    return pl.pallas_call(
        functools.partial(_mix_kernel, alpha=alpha),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(x, z)


def _pullback_mean_kernel(x_ref, z_ref, xo_ref, mo_ref, *, alpha: float, mean_pre: bool):
    z = z_ref[...].astype(jnp.float32)  # (block,)
    x = x_ref[...]  # (m, block)
    x_new = ((1.0 - alpha) * x.astype(jnp.float32) + alpha * z[None, :]).astype(xo_ref.dtype)
    xo_ref[...] = x_new
    src = x if mean_pre else x_new
    # mean over the worker axis lives inside the block — matches
    # jnp.mean(src, axis=0, dtype=f32).astype(param dtype) of the ref path
    mo_ref[...] = jnp.mean(src.astype(jnp.float32), axis=0).astype(mo_ref.dtype)


@functools.partial(jax.jit, static_argnames=("alpha", "mean_pre", "block", "interpret"))
def pullback_mean_flat(x, z, *, alpha: float, mean_pre: bool = False, block: int = 1 << 13, interpret: bool = False):
    """x: (m, n) stacked plane, z: (n,) anchor plane; n % 128 == 0.

    Returns (x_new, worker_mean) in one HBM pass.
    """
    m, n = x.shape
    block = min(block, n)
    grid = (pl.cdiv(n, block),)
    return pl.pallas_call(
        functools.partial(_pullback_mean_kernel, alpha=alpha, mean_pre=mean_pre),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, block), lambda i: (0, i)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((m, block), lambda i: (0, i)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), x.dtype),
            jax.ShapeDtypeStruct((n,), x.dtype),
        ],
        interpret=interpret,
    )(x, z)


def _pullback_momentum_kernel(x_ref, z_ref, v_ref, xo_ref, zo_ref, vo_ref, *, alpha: float, beta: float):
    z = z_ref[...].astype(jnp.float32)  # (block,)
    x_new = ((1.0 - alpha) * x_ref[...].astype(jnp.float32) + alpha * z[None, :]).astype(xo_ref.dtype)
    xo_ref[...] = x_new
    mean = jnp.mean(x_new.astype(jnp.float32), axis=0).astype(x_ref.dtype)
    v_new = (beta * v_ref[...].astype(jnp.float32) + (mean.astype(jnp.float32) - z)).astype(vo_ref.dtype)
    vo_ref[...] = v_new
    zo_ref[...] = (z + v_new.astype(jnp.float32)).astype(zo_ref.dtype)


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "block", "interpret"))
def pullback_momentum_flat(x, z, v, *, alpha: float, beta: float, block: int = 1 << 13, interpret: bool = False):
    """x: (m, n), z/v: (n,); n % 128 == 0.

    Returns (x_new, z_next, v_new): eq. (4) pullback + eqs. (10)-(11) anchor
    momentum, one read of each input, one write of each output.
    """
    m, n = x.shape
    block = min(block, n)
    grid = (pl.cdiv(n, block),)
    return pl.pallas_call(
        functools.partial(_pullback_momentum_kernel, alpha=alpha, beta=beta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, block), lambda i: (0, i)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((m, block), lambda i: (0, i)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), x.dtype),
            jax.ShapeDtypeStruct((n,), z.dtype),
            jax.ShapeDtypeStruct((n,), v.dtype),
        ],
        interpret=interpret,
    )(x, z, v)
