"""Fused anchor-pullback Pallas TPU kernel — the paper's core update, eq. (4):

    x ← (1 − α)·x + α·z

applied to every parameter shard at a round boundary. XLA would emit two
elementwise passes (scale + add) over HBM for naive code, or one fused pass
if it fuses — we make the single pass *structural*: one read of x, one read
of z, one write, tiled through VMEM in (8·128)-aligned blocks. The op is
purely memory-bound (arithmetic intensity 3 flops / 6 bytes in bf16), so the
kernel's value is guaranteeing exactly 3·bytes traffic at the round boundary
(the pullback sits on the critical path between rounds — see §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mix_kernel(x_ref, z_ref, o_ref, *, alpha: float):
    x = x_ref[...].astype(jnp.float32)
    z = z_ref[...].astype(jnp.float32)
    o_ref[...] = ((1.0 - alpha) * x + alpha * z).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("alpha", "block", "interpret"))
def anchor_mix_flat(x, z, *, alpha: float, block: int = 1 << 16, interpret: bool = False):
    """x, z: flat (n,) arrays (n % 128 == 0 after ops-side padding)."""
    (n,) = x.shape
    block = min(block, n)
    grid = (pl.cdiv(n, block),)
    return pl.pallas_call(
        functools.partial(_mix_kernel, alpha=alpha),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(x, z)
