"""Pure-jnp oracle for the RWKV-6 "Finch" WKV recurrence [arXiv:2404.05892].

Per head with key-dim n and value-dim p, data-dependent per-channel decay
w_t ∈ (0,1)^n and bonus u ∈ R^n:

    y_t = r_t · (diag(u) k_tᵀ v_t + S_{t-1})
    S_t = diag(w_t) S_{t-1} + k_tᵀ v_t

Shapes: r,k,w (B,S,H,N); v (B,S,H,P); u (H,N). Returns (y (B,S,H,P),
final_state (B,H,N,P)).

* :func:`wkv_reference` — lax.scan over time (ground truth).
* :func:`wkv_chunked` — chunked form mirroring the Pallas kernel: cumulative
  log-decay products inside a chunk turn the recurrence into dense matmuls,
  with an inter-chunk state carried by a scan.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def wkv_reference(r, k, v, w, u) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s, h, n = r.shape
    p = v.shape[-1]
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(state, inp):
        rt, kt, vt, wt = inp  # (B,H,N),(B,H,N),(B,H,P),(B,H,N)
        kv = jnp.einsum("bhn,bhp->bhnp", kt, vt)
        y = jnp.einsum("bhn,bhnp->bhp", rt, uf[None, :, :, None] * kv + state)
        state = wt[..., None] * state + kv
        return state, y

    s0 = jnp.zeros((b, h, n, p), jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, wf))
    final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), final


def wkv_chunked(r, k, v, w, u, chunk: int = 32) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s, h, n = r.shape
    p = v.shape[-1]
    pad = (-s) % chunk
    if pad:
        zr = ((0, 0), (0, pad), (0, 0), (0, 0))
        r = jnp.pad(r, zr)
        k = jnp.pad(k, zr)
        v = jnp.pad(v, zr)
        w = jnp.pad(w, zr, constant_values=1.0)  # identity decay in padding
    sp = r.shape[1]
    nc = sp // chunk
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)

    rc = rf.reshape(b, nc, chunk, h, n)
    kc = kf.reshape(b, nc, chunk, h, n)
    vc = vf.reshape(b, nc, chunk, h, p)
    wc = wf.reshape(b, nc, chunk, h, n)

    logw = jnp.log(jnp.maximum(wc, 1e-30))
    cum = jnp.cumsum(logw, axis=2)  # (B,nc,L,H,N) inclusive
    total = cum[:, :, -1]  # (B,nc,H,N)

    # Contribution of token j<i to y_i: decay prod_{t=j+1..i-1} w_t? Careful:
    # y_i reads S_{i-1} = sum_{j<i} (prod_{t=j+1}^{i-1} w_t) k_j^T v_j.
    # In cum terms: prod_{t=j+1}^{i-1} w = exp(cum_{i-1} - cum_j).
    # Define cum_excl_i = cum_{i} - logw_i (exclusive-of-i cumsum).
    cum_excl = cum - logw
    li = cum_excl[:, :, :, None]  # (B,nc,L,1,H,N)
    lj = cum[:, :, None, :, :]  # (B,nc,1,L,H,N)
    strict = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    # mask the exponent BEFORE exp: masked entries would overflow to +inf and
    # poison the backward pass (inf * 0 cotangent = NaN)
    diff = jnp.where(strict[None, None, :, :, None, None], li - lj, -1e9)
    decay = jnp.exp(diff)
    # scores: A_ij = sum_n r_in * decay_ijn * k_jn  (strictly lower tri)
    A = jnp.einsum("bclhn,bclmhn,bcmhn->bclmh", rc, decay, kc)
    # bonus diagonal: y_i += (r_i ⊙ u ⊙ k_i) · v_i
    diag = jnp.einsum("bclhn,hn,bclhn->bclh", rc, uf, kc)
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", A, vc) + diag[..., None] * vc

    # chunk summary: S_chunk = sum_j exp(total - cum_j) k_j^T v_j
    dte = jnp.exp(total[:, :, None] - cum)  # (B,nc,L,H,N)
    S_c = jnp.einsum("bclhn,bclhn,bclhp->bchnp", dte, kc, vc)

    from repro.kernels import flags as _flags

    s0 = jnp.zeros((b, h, n, p), jnp.float32)
    if False:  # state-scan flops are negligible; unroll only bloats probe HLO (see costprobe.py)
        state = s0
        prevs = []
        for ci in range(nc):
            prevs.append(state)
            state = jnp.exp(total[:, ci])[..., None] * state + S_c[:, ci]
        final = state
        prev = jnp.stack(prevs, axis=1)
    else:

        def step(state, inp):
            s_c, tot = inp
            new = jnp.exp(tot)[..., None] * state + s_c
            return new, state

        final, prev_states = jax.lax.scan(step, s0, (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(total, 1, 0)))
        prev = jnp.moveaxis(prev_states, 0, 1)  # (B,nc,H,N,P)

    # inter-chunk: y_i += r_i · diag(exp(cum_excl_i)) S_prev
    y_inter = jnp.einsum("bclhn,bchnp->bclhp", rc * jnp.exp(cum_excl), prev)

    y = (y_intra + y_inter).reshape(b, sp, h, p)
    return y[:, :s].astype(r.dtype), final
