"""Public wrapper for the RWKV-6 WKV kernel (+ chunked-jnp custom VJP)."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flags
from repro.kernels.rwkv6_wkv import kernel as _k
from repro.kernels.rwkv6_wkv import ref as _ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def wkv(r, k, v, w, u, chunk: int = 32):
    """r/k/w (B,S,H,N); v (B,S,H,P); u (H,N) -> (y (B,S,H,P), state (B,H,N,P))."""
    return _forward(r, k, v, w, u, chunk)


def _forward(r, k, v, w, u, chunk) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s, h, n = r.shape
    p = v.shape[-1]
    pad = (-s) % chunk
    if pad:
        zr = ((0, 0), (0, pad), (0, 0), (0, 0))
        r = jnp.pad(r, zr)
        k = jnp.pad(k, zr)
        v = jnp.pad(v, zr)
        w = jnp.pad(w, zr, constant_values=1.0)
    sp = s + pad
    flat = lambda t: jnp.transpose(t, (0, 2, 1, 3)).reshape(b * h, sp, t.shape[-1])
    uf = jnp.tile(u[None], (b, 1, 1)).reshape(b * h, n)
    y, st = _k.wkv_bh(flat(r), flat(k), flat(v), flat(w), uf, chunk=min(chunk, sp), interpret=flags.interpret_mode())
    y = jnp.transpose(y.reshape(b, h, sp, p), (0, 2, 1, 3))[:, :s]
    return y, st.reshape(b, h, n, p)


def _fwd(r, k, v, w, u, chunk):
    return _forward(r, k, v, w, u, chunk), (r, k, v, w, u)


def _bwd(chunk, res, cts):
    r, k, v, w, u = res

    def f(r, k, v, w, u):
        return _ref.wkv_chunked(r, k, v, w, u, chunk=chunk)

    _, vjp = jax.vjp(f, r, k, v, w, u)
    return vjp(cts)


wkv.defvjp(_fwd, _bwd)

reference = _ref.wkv_reference
chunked = _ref.wkv_chunked


def wkv_decode_step(state, r_t, k_t, v_t, w_t, u):
    """Single-token recurrence: state (B,H,N,P); r/k/w (B,H,N); v (B,H,P)."""
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r_t, k_t, v_t, w_t))
    kv = jnp.einsum("bhn,bhp->bhnp", kf, vf)
    y = jnp.einsum("bhn,bhnp->bhp", rf, u.astype(jnp.float32)[None, :, :, None] * kv + state)
    state = wf[..., None] * state + kv
    return y.astype(r_t.dtype), state
