"""RWKV-6 chunked WKV Pallas TPU kernel.

Same chunking strategy as the SSD kernel: grid = (B·H, n_chunks), running
(N×P, f32) state in VMEM scratch across the sequential chunk axis. Unlike
SSD, the decay is a per-*channel* vector w_t ∈ (0,1)^N, so the intra-chunk
score needs a 3-D masked contraction (L,L,N); with L=32..64 and N=64 this is
≤1 MB in VMEM and the remaining contractions are MXU matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, st_ref, state, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    r = r_ref[0].astype(jnp.float32)  # (L, N)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)  # (L, P)
    w = w_ref[0].astype(jnp.float32)  # (L, N)
    u = u_ref[0].astype(jnp.float32)  # (N,)

    logw = jnp.log(jnp.maximum(w, 1e-30))
    cum = jnp.cumsum(logw, axis=0)  # (L, N) inclusive
    cum_excl = cum - logw
    total = cum[-1]  # (N,)

    # strict lower-triangular decayed scores A_lm = sum_n r_ln e^{cum_excl_l - cum_m} k_mn
    li = cum_excl[:, None, :]  # (L,1,N)
    lj = cum[None, :, :]  # (1,L,N)
    strict = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) > jax.lax.broadcasted_iota(
        jnp.int32, (chunk, chunk), 1
    )
    decay = jnp.exp(jnp.where(strict[:, :, None], li - lj, -1e9))  # (L,L,N)
    A = jnp.einsum("ln,lmn,mn->lm", r, decay, k)
    diag = jnp.sum(r * u[None, :] * k, axis=-1)  # (L,)
    y = jax.lax.dot(A, v) + diag[:, None] * v

    s_prev = state[...]  # (N, P)
    y += jax.lax.dot(r * jnp.exp(cum_excl), s_prev)

    dte = jnp.exp(total[None, :] - cum)  # (L, N)
    s_c = jax.lax.dot_general(k * dte, v, (((0,), (0,)), ((), ())))  # (N, P)
    state[...] = jnp.exp(total)[:, None] * s_prev + s_c

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == pl.num_programs(1) - 1)
    def _final():
        st_ref[0] = state[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_bh(r, k, v, w, u, *, chunk: int = 32, interpret: bool = False):
    """r/k/w: (BH,S,N); v: (BH,S,P); u: (BH,N). S % chunk == 0."""
    bh, s, n = r.shape
    p = v.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    grid = (bh, nc)
    y, st = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, n), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, chunk, p), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, n), lambda i, ci: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, n, p), lambda i, ci: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), r.dtype),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return y, st
