"""Jit'd public wrapper for the RMSNorm kernel (arbitrary leading dims)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import flags
from repro.kernels.rmsnorm import kernel as _k
from repro.kernels.rmsnorm import ref as _ref


def rmsnorm(x, scale, eps: float = 1e-5, block_rows: int = 128):
    d = x.shape[-1]
    lead = x.shape[:-1]
    rows = 1
    for s in lead:
        rows *= s
    if rows == 0:
        return x
    x2 = x.reshape(rows, d)
    out = _k.rmsnorm_2d(x2, scale, eps=eps, block_rows=block_rows, interpret=flags.interpret_mode())
    return out.reshape(*lead, d)


reference = _ref.rmsnorm
