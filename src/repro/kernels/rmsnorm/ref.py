"""Pure-jnp oracle for the fused RMSNorm kernel."""
from __future__ import annotations

import jax.numpy as jnp


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf / jnp.sqrt(ms + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)
