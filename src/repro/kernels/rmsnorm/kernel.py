"""Fused RMSNorm Pallas TPU kernel.

One pass over the row: mean-of-squares reduction and scale in VMEM, saving
the extra HBM round-trip XLA's unfused reduce+mul pair would take. Rows are
tiled ``block_rows`` at a time; the feature dim stays whole in VMEM (d_model
≤ 12288 ⇒ ≤ 12288·4B·block_rows, well inside the ~16 MB VMEM budget for
block_rows ≤ 256).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_2d(x, scale, *, eps: float = 1e-5, block_rows: int = 128, interpret: bool = False):
    """x: (rows, d) — callers flatten leading dims. scale: (d,)."""
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, scale)
