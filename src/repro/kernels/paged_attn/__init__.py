from repro.kernels.paged_attn.ops import (  # noqa: F401
    append_targets,
    paged_append,
    paged_attend_gqa,
    paged_attend_mla,
    paged_gather,
)

__all__ = [
    "append_targets",
    "paged_append",
    "paged_attend_gqa",
    "paged_attend_mla",
    "paged_gather",
]
