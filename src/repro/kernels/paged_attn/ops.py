"""Dispatch layer for paged-KV attention: Pallas kernels on TPU (or when
forced), jnp reference bodies otherwise — same contract as
kernels/flash_attention/ops.py.

The kernels cover the GQA decode hot path (one token per slot). Chunked
prefill (T > 1) and the MLA latent path stay on the jnp reference on every
backend — MLA's absorbed decode is einsum-shaped (no softmax-over-pages
structure to tile), matching the dense MLA decode which is also jnp-only.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import flags
from repro.kernels.paged_attn import kernel as pk
from repro.kernels.paged_attn import ref

paged_gather = ref.paged_gather
append_targets = ref.append_targets
paged_attend_mla = ref.paged_attend_mla


def _pad_to(x, axis: int, mult: int):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _kernel_ok(pool) -> bool:
    return flags.use_pallas() and pool.shape[1] % 8 == 0


def paged_append(pool, new, page_tables, lengths):
    """(P, page, ...) pool ← (S, T, ...) new tokens. T == 1 on the GQA pool
    shape routes to the Pallas scatter kernel."""
    if new.ndim == 4 and new.shape[1] == 1 and _kernel_ok(pool):
        d = pool.shape[-1]
        dp = d + ((-d) % 128)
        out = pk.paged_append_decode(
            _pad_to(pool, 3, 128),
            _pad_to(new[:, 0].astype(pool.dtype), 2, 128),
            page_tables,
            lengths,
            interpret=flags.interpret_mode(),
        )
        return out[..., :d] if dp != d else out
    return ref.paged_append(pool, new, page_tables, lengths)


def paged_attend_gqa(q, pool_k, pool_v, page_tables, lengths, *, window: Optional[int] = None):
    """(S, T, H, D) pre-scaled q against the pool. T == 1 routes to the
    Pallas online-softmax kernel with page-table-driven index maps."""
    if q.shape[1] == 1 and _kernel_ok(pool_k):
        s_, _, h, d = q.shape
        kv = pool_k.shape[2]
        g = h // kv
        qk = q.reshape(s_, kv, g, d)
        gp = g + ((-g) % 8)
        qk = _pad_to(_pad_to(qk, 3, 128), 2, 8)
        out = pk.paged_attend_decode(
            qk,
            _pad_to(pool_k, 3, 128),
            _pad_to(pool_v, 3, 128),
            page_tables,
            lengths,
            window=window,
            interpret=flags.interpret_mode(),
        )
        return out[:, :, :g, :d].reshape(s_, 1, h, d)
    return ref.paged_attend_gqa(q, pool_k, pool_v, page_tables, lengths, window=window)
