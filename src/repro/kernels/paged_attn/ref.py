"""Paged-KV attention — jnp reference bodies (DESIGN.md §10).

Layout: a *pool* holds fixed-size pages shared by every request slot —
``pool`` is ``(num_pages, page_size, ...)`` (GQA: trailing ``(kv_heads,
head_dim)``; MLA: trailing ``(rank,)``). A per-slot *page table*
``(slots, max_pages)`` maps logical page j of a slot to a physical page, and
``lengths (slots,)`` counts the tokens already resident, which is also the
absolute position of the first token appended this call. Physical page 0 is
the engine's trash page: idle slots carry an all-zero table row and length 0,
so their (discarded) appends land there and never touch live pages.

Bitwise contract, pinned by tests/test_paged_attn.py: gathering a slot's
pages in logical order reproduces a dense ``(B, L, ...)`` cache in position
order, and the attends below mirror the dense decode oracles op-for-op —
same einsum strings, same f32 softmax, same mask *values* (masks broadcast
from different shapes, which ``where`` evaluates elementwise) — so paged
decode is bitwise-equal to ``_decode_attend`` / the absorbed MLA decode in
f32 whenever ``max_pages * page_size`` equals the dense cache length.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def paged_gather(pool: jnp.ndarray, page_tables: jnp.ndarray) -> jnp.ndarray:
    """(P, page, ...) × (S, maxp) → (S, maxp·page, ...): a slot's cache in
    position order."""
    g = pool[page_tables]  # (S, maxp, page, ...)
    return g.reshape(g.shape[0], g.shape[1] * g.shape[2], *pool.shape[2:])


def append_targets(
    page_tables: jnp.ndarray, lengths: jnp.ndarray, t: int, page_size: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Physical (page_ids, offsets), each (S, t), for the next ``t`` tokens
    of every slot. Positions past the table's last page clamp to it — such
    tokens are prefill-chunk tail padding, written then either overwritten
    (at their real position, before any query can attend that far) or masked
    by ``lengths``."""
    pos = lengths[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]  # (S, t)
    maxp = page_tables.shape[1]
    page_idx = jnp.minimum(pos // page_size, maxp - 1)
    page_ids = jnp.take_along_axis(page_tables, page_idx, axis=1)
    return page_ids, pos % page_size


def paged_append(
    pool: jnp.ndarray,  # (P, page, ...)
    new: jnp.ndarray,  # (S, T, ...)
    page_tables: jnp.ndarray,  # (S, maxp) int32
    lengths: jnp.ndarray,  # (S,) int32 — tokens resident before this append
) -> jnp.ndarray:
    """Scatter T new tokens per slot into their pages; O(tokens) writes, no
    cache growth or copy (the dense path's `_grow_all` pad-chain is exactly
    what this replaces)."""
    page_ids, offsets = append_targets(page_tables, lengths, new.shape[1], pool.shape[1])
    return pool.at[page_ids, offsets].set(new.astype(pool.dtype))


def _causal_valid(lengths, t: int, l: int, window: Optional[int]):
    """(S, t, l) bool: key position visible to query position."""
    k_pos = jnp.arange(l, dtype=jnp.int32)
    q_pos = lengths[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]  # (S, t)
    valid = k_pos[None, None, :] <= q_pos[:, :, None]
    if window is not None:
        valid &= k_pos[None, None, :] > (q_pos[:, :, None] - window)
    return valid


def paged_attend_gqa(
    q: jnp.ndarray,  # (S, T, H, D), pre-scaled
    pool_k: jnp.ndarray,  # (P, page, KV, D)
    pool_v: jnp.ndarray,
    page_tables: jnp.ndarray,  # (S, maxp)
    lengths: jnp.ndarray,  # (S,) — position of q[:, 0]
    *,
    window: Optional[int] = None,
) -> jnp.ndarray:
    """Grouped-query attention against the (already appended) pool. Mirrors
    `_decode_attend` op-for-op; T > 1 adds in-chunk causality for chunked
    prefill."""
    b, t, h, d = q.shape
    k = paged_gather(pool_k, page_tables)  # (S, L, KV, D)
    v = paged_gather(pool_v, page_tables)
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, t, kvh, g, d)
    scores = jnp.einsum("bqhgd,blhd->bhgql", qg.astype(jnp.float32), k.astype(jnp.float32))
    valid = _causal_valid(lengths, t, k.shape[1], window)  # (S, T, L)
    scores = jnp.where(valid[:, None, None, :, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgql,blhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, t, h, d)


def paged_attend_mla(
    q_lat: jnp.ndarray,  # (S, T, H, r) — W_uk-absorbed no-pe query
    q_rope: jnp.ndarray,  # (S, T, H, dr)
    pool_ckv: jnp.ndarray,  # (P, page, r)
    pool_krope: jnp.ndarray,  # (P, page, dr)
    page_tables: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    scale,
) -> jnp.ndarray:
    """Absorbed MLA decode over the paged latent cache. Returns the latent
    output (S, T, H, r) in f32; the caller applies W_uv (param-side)."""
    ckv = paged_gather(pool_ckv, page_tables)  # (S, L, r)
    kr = paged_gather(pool_krope, page_tables)  # (S, L, dr)
    s_nope = jnp.einsum("bshr,blr->bhsl", q_lat.astype(jnp.float32), ckv.astype(jnp.float32))
    s_rope = jnp.einsum("bshk,blk->bhsl", q_rope.astype(jnp.float32), kr.astype(jnp.float32))
    scores = (s_nope + s_rope) * scale
    valid = _causal_valid(lengths, q_lat.shape[1], ckv.shape[1], None)  # (S, T, L)
    scores = jnp.where(valid[:, None, :, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhsl,blr->bshr", p, ckv.astype(jnp.float32))
