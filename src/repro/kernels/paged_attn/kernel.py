"""Paged-KV Pallas TPU kernels: page-table-indirect decode attention (gather)
and token append (scatter).

Both kernels take the page table / lengths as *scalar-prefetch* operands
(``pltpu.PrefetchScalarGridSpec``): the values are resident before the body
runs, so the BlockSpec index maps themselves chase the page table — the KV
block for grid step (s, kv, j) is DMA'd straight from physical page
``page_table[s, j]``, and the pool is never gathered or repeated in HBM.

Attention follows the flash_attention kernel structure: the page axis is the
innermost (sequential) grid dim, with the f32 accumulator and online-softmax
(m, l) statistics in VMEM scratch across pages. The append kernel writes one
token's (kv_heads, head_dim) row into its page via an index-mapped output
block, with the pool aliased input→output so unvisited pages pass through.

Alignment: the ops wrapper pads head_dim to a multiple of 128 and the GQA
group dim to a multiple of 8; page_size must be a multiple of 8 (the
engine's default is 16) or ops falls back to the jnp reference.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


# ---------------------------------------------------------------------------
# decode attention (gather/read)
# ---------------------------------------------------------------------------


def _attend_kernel(
    pt_ref,  # scalar (S, maxp) int32
    len_ref,  # scalar (S,) int32
    q_ref,  # (1, 1, G, D)
    k_ref,  # (1, page, 1, D) — physical page pt[s, j] of kv head kv
    v_ref,  # (1, page, 1, D)
    o_ref,  # (1, 1, G, D)
    acc_ref,  # VMEM (G, D) f32
    m_ref,  # VMEM (G,) f32
    l_ref,  # VMEM (G,) f32
    *,
    page: int,
    window: Optional[int],
):
    s = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)  # (page, D)
    sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, page)

    q_pos = len_ref[s]  # position of the (already appended) new token
    k_pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    mask = k_pos <= q_pos
    if window is not None:
        mask &= k_pos > (q_pos - window)
    sc = jnp.where(mask, sc, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, sc.max(axis=-1))
    safe_m = jnp.where(m_new == NEG_INF, 0.0, m_new)
    p = jnp.exp(sc - safe_m[:, None])
    corr = jnp.exp(m_prev - safe_m)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
        p, v_ref[0, :, 0].astype(jnp.float32)
    )
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...][:, None], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attend_decode(
    q,  # (S, KV, G, D) — one new token per slot, grouped by kv head
    pool_k,  # (P, page, KV, D)
    pool_v,
    page_tables,  # (S, maxp) int32
    lengths,  # (S,) int32
    *,
    window: Optional[int],
    interpret: bool = False,
):
    s_, kv, g, d = q.shape
    _, page, _, _ = pool_k.shape
    maxp = page_tables.shape[1]
    grid = (s_, kv, maxp)

    def q_index(s, kvi, j, pt, ln):
        return (s, kvi, 0, 0)

    def kv_index(s, kvi, j, pt, ln):
        return (pt[s, j], 0, kvi, 0)

    kern = functools.partial(_attend_kernel, page=page, window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d), q_index),
            pl.BlockSpec((1, page, 1, d), kv_index),
            pl.BlockSpec((1, page, 1, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), q_index),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s_, kv, g, d), q.dtype),
        interpret=interpret,
    )(page_tables, lengths, q, pool_k, pool_v)


# ---------------------------------------------------------------------------
# token append (scatter/write)
# ---------------------------------------------------------------------------


def _append_kernel(pt_ref, len_ref, pool_ref, new_ref, o_ref):
    del pt_ref, len_ref, pool_ref  # indexing happens in the BlockSpec maps
    o_ref[0, 0] = new_ref[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_append_decode(
    pool,  # (P, page, KV, D)
    new,  # (S, KV, D) — one token per slot
    page_tables,  # (S, maxp) int32
    lengths,  # (S,) int32 — write position of slot s
    *,
    interpret: bool = False,
):
    s_, kv, d = new.shape
    _, page, _, _ = pool.shape
    maxp = page_tables.shape[1]

    def pool_index(s, pt, ln):
        p = jnp.minimum(ln[s] // page, maxp - 1)
        return (pt[s, p], ln[s] % page, 0, 0)

    def new_index(s, pt, ln):
        return (s, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(s_,),
        in_specs=[
            pl.BlockSpec((1, 1, kv, d), pool_index),
            pl.BlockSpec((1, kv, d), new_index),
        ],
        out_specs=pl.BlockSpec((1, 1, kv, d), pool_index),
    )
    return pl.pallas_call(
        _append_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        # alias pool → output: pages not visited by any grid step pass through
        input_output_aliases={2: 0},
        interpret=interpret,
    )(page_tables, lengths, pool, new)
