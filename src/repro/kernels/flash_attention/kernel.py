"""Flash attention Pallas TPU kernel (causal / sliding-window / GQA).

Tiling: grid = (batch × q_heads, Sq/block_q, Sk/block_k); the KV axis is the
innermost (sequential on TPU) grid dimension, so the f32 accumulator and the
online-softmax (m, l) statistics live in VMEM scratch across KV steps.
GQA is handled in the BlockSpec index maps — the KV block for q-head h is
loaded from kv-head h // (H/Hkv); KV tensors are never repeated in HBM.

MXU alignment: block_q/block_k default to 128; head_dim is padded to a
multiple of 128 by the ops wrapper.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _fa_kernel(
    q_ref,  # (1, block_q, d)
    k_ref,  # (1, block_k, d)
    v_ref,  # (1, block_k, d)
    o_ref,  # (1, block_q, d)
    acc_ref,  # VMEM (block_q, d) f32
    m_ref,  # VMEM (block_q,) f32
    l_ref,  # VMEM (block_q,) f32
    *,
    causal: bool,
    window: Optional[int],
    q_offset: int,
    block_q: int,
    block_k: int,
    sk_valid: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (block_q, block_k)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < sk_valid
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > (q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    safe_m = jnp.where(m_new == NEG_INF, 0.0, m_new)
    p = jnp.exp(s - safe_m[:, None])
    corr = jnp.exp(m_prev - safe_m)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
        p.astype(v_ref.dtype), v_ref[0]
    ).astype(jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...][:, None], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_k", "num_kv_heads", "interpret", "sk_valid"),
)
def flash_attention_bhsd(
    q,  # (B*H, Sq, D)
    k,  # (B*Hkv, Sk, D)
    v,  # (B*Hkv, Sk, D)
    *,
    num_kv_heads: int,
    causal: bool,
    window: Optional[int],
    q_offset: int,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    sk_valid: Optional[int] = None,
):
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    sk_valid = sk if sk_valid is None else sk_valid
    h_per_b = bh // (bkv // num_kv_heads)  # q heads per batch
    group = h_per_b // num_kv_heads
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    grid = (bh, pl.cdiv(sq, block_q), pl.cdiv(sk, block_k))

    def q_index(bhi, qi, ki):
        return (bhi, qi, 0)

    def kv_index(bhi, qi, ki):
        b = bhi // h_per_b
        h = bhi % h_per_b
        return (b * num_kv_heads + h // group, ki, 0)

    kern = functools.partial(
        _fa_kernel,
        causal=causal,
        window=window,
        q_offset=q_offset,
        block_q=block_q,
        block_k=block_k,
        sk_valid=sk_valid,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_index),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_index),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
