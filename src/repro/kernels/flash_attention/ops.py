"""Public flash-attention wrapper: layout handling + GQA + custom VJP.

Forward runs the Pallas kernel; the backward pass recomputes attention with
the chunked-jnp algorithm (flash-style recompute — no S×S residuals), which
is the standard memory-saving backward on TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flags
from repro.kernels.flash_attention import kernel as _k
from repro.kernels.flash_attention import ref as _ref


def _pad_head_dim(x, mult: int = 128):
    d = x.shape[-1]
    pad = (-d) % mult
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pad)))
    return x, d


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, window: Optional[int] = None, q_offset: int = 0):
    """q: (B,Sq,H,D), k/v: (B,Sk,Hkv,D) -> (B,Sq,H,D). Pre-scaled q expected."""
    return _forward(q, k, v, causal, window, q_offset)


def _forward(q, k, v, causal, window, q_offset, block: int = 128):
    b, sq, h, d0 = q.shape
    _, sk, hkv, _ = k.shape
    qp, d = _pad_head_dim(q)
    kp, _ = _pad_head_dim(k)
    vp, _ = _pad_head_dim(v)
    # zero-pad the sequence dims to block multiples: Pallas out-of-bounds
    # block reads are undefined, and even fully-masked scores can't protect
    # against NaN garbage in V (0·NaN = NaN)
    pq = (-sq) % min(block, sq) if sq > 1 else 0
    pk = (-sk) % min(block, sk)
    if pq:
        qp = jnp.pad(qp, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        kp = jnp.pad(kp, ((0, 0), (0, pk), (0, 0), (0, 0)))
        vp = jnp.pad(vp, ((0, 0), (0, pk), (0, 0), (0, 0)))
    dpad = qp.shape[-1]
    sqp, skp = qp.shape[1], kp.shape[1]
    qf = jnp.transpose(qp, (0, 2, 1, 3)).reshape(b * h, sqp, dpad)
    kf = jnp.transpose(kp, (0, 2, 1, 3)).reshape(b * hkv, skp, dpad)
    vf = jnp.transpose(vp, (0, 2, 1, 3)).reshape(b * hkv, skp, dpad)
    out = _k.flash_attention_bhsd(
        qf,
        kf,
        vf,
        num_kv_heads=hkv,
        causal=causal,
        window=window,
        q_offset=q_offset,
        sk_valid=sk,
        block_q=block,
        block_k=block,
        interpret=flags.interpret_mode(),
    )
    out = out.reshape(b, h, sqp, dpad)[:, :, :sq, :d0]
    return jnp.transpose(out, (0, 2, 1, 3))


def _fwd(q, k, v, causal, window, q_offset):
    return _forward(q, k, v, causal, window, q_offset), (q, k, v)


def _bwd(causal, window, q_offset, res, g):
    q, k, v = res

    def f(q, k, v):
        return _ref.chunked_mha(q, k, v, causal=causal, window=window, q_offset=q_offset)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)

reference = _ref.mha_reference
chunked = _ref.chunked_mha
