"""Pure-jnp oracles for the flash-attention kernel.

* :func:`mha_reference` — exact masked softmax (materializes S×S scores).
  Used for correctness tests and short-sequence CPU paths.
* :func:`chunked_mha` — Q/KV block-tiled online softmax in plain jnp (the
  flash-attention *algorithm* without Pallas). This is what the dry-run
  lowers for long sequences so the compiled HLO has flash-like memory
  behaviour instead of an S² materialization. Masked blocks are still
  multiplied (≈2× causal-attention FLOPs in HLO cost analysis); the Pallas
  kernel on a real TPU skips nothing either in this simple form — accounted
  for in §Roofline.

All functions take q:(B,Sq,H,D), k/v:(B,Sk,Hkv,D) with H a multiple of Hkv
(GQA), optional causal/sliding-window masking, and ``q_offset`` giving the
absolute position of q[0] (for cached decode).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def mha_reference(q, k, v, *, causal: bool = True, window: Optional[int] = None, q_offset: int = 0):
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    q_pos = jnp.arange(sq) + q_offset
    k_pos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > (q_pos[:, None] - window)
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, v.shape[-1]).astype(q.dtype)


def chunked_mha(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 1024,
    block_k: int = 1024,
):
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    g = h // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    dv = v.shape[-1]
    nq, nk = qp.shape[1] // block_q, kp.shape[1] // block_k
    qb = qp.reshape(b, nq, block_q, hkv, g, d).astype(jnp.float32)
    kb = kp.reshape(b, nk, block_k, hkv, d).astype(jnp.float32)
    vb = vp.reshape(b, nk, block_k, hkv, dv).astype(jnp.float32)

    def q_block_fn(qi, q_blk, kb_b, vb_b):
        # q_blk: (block_q, hkv, g, d); kb_b/vb_b: (nk, block_k, hkv, d)
        q_pos = q_offset + qi * block_q + jnp.arange(block_q)

        def kv_step(carry, inp):
            acc, m, l = carry
            k_blk, v_blk, ki = inp
            k_pos = ki * block_k + jnp.arange(block_k)
            s = jnp.einsum("qhgd,khd->hgqk", q_blk, k_blk)
            mask = (k_pos < sk)[None, :]
            if causal:
                mask = mask & (k_pos[None, :] <= q_pos[:, None])
            else:
                mask = jnp.broadcast_to(mask, (block_q, block_k))
            if window is not None:
                mask = mask & (k_pos[None, :] > (q_pos[:, None] - window))
            s = jnp.where(mask[None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(-1))
            safe_m = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
            p = jnp.exp(s - safe_m[..., None])
            corr = jnp.exp(m - safe_m)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum("hgqk,khd->hgqd", p, v_blk)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((hkv, g, block_q, dv), jnp.float32)
        m0 = jnp.full((hkv, g, block_q), -jnp.inf)
        l0 = jnp.zeros((hkv, g, block_q))
        (acc, _, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), (kb_b, vb_b, jnp.arange(nk)))
        return acc / jnp.maximum(l[..., None], 1e-30)  # (hkv, g, block_q, d)

    from repro.kernels import flags as _flags

    if _flags.cost_unroll():
        # python-loop version: identical math, every block matmul visible to
        # HLO cost analysis (lax.scan bodies are counted once by XLA).
        def q_block_unrolled(qi, q_blk, kb_b, vb_b):
            q_pos = q_offset + qi * block_q + jnp.arange(block_q)
            acc = jnp.zeros((hkv, g, block_q, dv), jnp.float32)
            m = jnp.full((hkv, g, block_q), -jnp.inf)
            l = jnp.zeros((hkv, g, block_q))
            for ki in range(nk):
                k_blk, v_blk = kb_b[ki], vb_b[ki]
                k_pos = ki * block_k + jnp.arange(block_k)
                s = jnp.einsum("qhgd,khd->hgqk", q_blk, k_blk)
                mask = (k_pos < sk)[None, :]
                if causal:
                    mask = mask & (k_pos[None, :] <= q_pos[:, None])
                else:
                    mask = jnp.broadcast_to(mask, (block_q, block_k))
                if window is not None:
                    mask = mask & (k_pos[None, :] > (q_pos[:, None] - window))
                s = jnp.where(mask[None, None], s, -jnp.inf)
                m_new = jnp.maximum(m, s.max(-1))
                safe_m = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
                p = jnp.exp(s - safe_m[..., None])
                corr = jnp.exp(m - safe_m)
                l = l * corr + p.sum(-1)
                acc = acc * corr[..., None] + jnp.einsum("hgqk,khd->hgqd", p, v_blk)
                m = m_new
            return acc / jnp.maximum(l[..., None], 1e-30)

        rows = []
        for bi in range(b):
            rows.append(jnp.stack([q_block_unrolled(qi, qb[bi, qi], kb[bi], vb[bi]) for qi in range(nq)]))
        out = jnp.stack(rows)
    else:
        # remat each q-block: the backward pass recomputes the online-softmax
        # instead of storing per-KV-step residuals (flash-style O(block) memory)
        q_block_ckpt = jax.checkpoint(q_block_fn, policy=jax.checkpoint_policies.nothing_saveable)

        def batch_fn(args):
            qb_b, kb_b, vb_b = args
            return jax.lax.map(lambda qi: q_block_ckpt(qi, qb_b[qi], kb_b, vb_b), jnp.arange(nq))

        out = jax.lax.map(batch_fn, (qb, kb, vb))  # (b, nq, hkv, g, block_q, d)
    out = jnp.transpose(out, (0, 1, 4, 2, 3, 5)).reshape(b, nq * block_q, hkv * g, dv)
    return out[:, :sq].astype(q.dtype)
