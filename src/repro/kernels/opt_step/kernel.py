"""Fused local-optimizer-step Pallas TPU kernels for the packed plane.

One kernel launch per dtype bucket per local step: the whole update chain —
weight decay, momentum/moment updates, Nesterov or Adam bias-corrected
direction, and the parameter write — runs in a single HBM pass over the
worker-stacked flat buffer (m, n). The per-leaf path pays the same chain as
~5 separate XLA ops *per pytree leaf*; here each buffer element is read
once and written once per state tensor:

    sgd   : read x, g, m          → write x, m        (traffic 5·P·w bytes)
    adamw : read x, g, mu, nu     → write x, mu, nu   (3·P·w + 16·P bytes)

The op is purely memory-bound (≤10 flops per element), so as with the
anchor-mix family the kernel's value is guaranteeing minimal HBM traffic
and collapsing the per-leaf dispatch tax to O(dtype buckets).

Traced scalars (lr; Adam's bias corrections c1, c2 derived from the shared
step count) ride in SMEM as a tiny f32 vector — they change every step, so
they cannot be static kernel params like alpha/beta in ``anchor_mix``.

The update formulas are imported from ``ref.py`` and applied verbatim to
the VMEM blocks: the kernel and the jnp oracle literally share the cast
chain, which the golden differential suite pins to the per-leaf optimizer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.opt_step import ref as _ref


def _sgd_kernel(s_ref, x_ref, g_ref, m_ref, xo_ref, mo_ref, *, momentum, nesterov, weight_decay):
    lr = s_ref[0]
    x_new, m_new = _ref.sgd_update(
        x_ref[...], g_ref[...], m_ref[...], lr,
        momentum=momentum, nesterov=nesterov, weight_decay=weight_decay,
    )
    xo_ref[...] = x_new
    mo_ref[...] = m_new


@functools.partial(jax.jit, static_argnames=("momentum", "nesterov", "weight_decay", "block", "interpret"))
def sgd_step_flat(x, g, m, scalars, *, momentum: float, nesterov: bool, weight_decay: float,
                  block: int = 1 << 13, interpret: bool = False):
    """x, g, m: (w, n) worker-stacked buffers (n % 128 == 0); scalars: (1,)
    f32 = [lr]. Returns (x_new, m_new) in one HBM pass."""
    w, n = x.shape
    block = min(block, n)
    grid = (pl.cdiv(n, block),)
    plane = pl.BlockSpec((w, block), lambda i: (0, i))
    return pl.pallas_call(
        functools.partial(_sgd_kernel, momentum=momentum, nesterov=nesterov, weight_decay=weight_decay),
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), plane, plane, plane],
        out_specs=[plane, plane],
        out_shape=[
            jax.ShapeDtypeStruct((w, n), x.dtype),
            jax.ShapeDtypeStruct((w, n), m.dtype),
        ],
        interpret=interpret,
    )(scalars, x, g, m)


def _adamw_kernel(s_ref, x_ref, g_ref, mu_ref, nu_ref, xo_ref, muo_ref, nuo_ref, *, b1, b2, eps, weight_decay):
    lr, c1, c2 = s_ref[0], s_ref[1], s_ref[2]
    x_new, mu_new, nu_new = _ref.adamw_update(
        x_ref[...], g_ref[...], mu_ref[...], nu_ref[...], lr, c1, c2,
        b1=b1, b2=b2, eps=eps, weight_decay=weight_decay,
    )
    xo_ref[...] = x_new
    muo_ref[...] = mu_new
    nuo_ref[...] = nu_new


@functools.partial(jax.jit, static_argnames=("b1", "b2", "eps", "weight_decay", "block", "interpret"))
def adamw_step_flat(x, g, mu, nu, scalars, *, b1: float, b2: float, eps: float, weight_decay: float,
                    block: int = 1 << 13, interpret: bool = False):
    """x, g: (w, n) param-dtype buffers; mu, nu: (w, n) f32 moment buffers;
    scalars: (3,) f32 = [lr, c1, c2]. Returns (x_new, mu_new, nu_new)."""
    w, n = x.shape
    block = min(block, n)
    grid = (pl.cdiv(n, block),)
    plane = pl.BlockSpec((w, block), lambda i: (0, i))
    return pl.pallas_call(
        functools.partial(_adamw_kernel, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay),
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), plane, plane, plane, plane],
        out_specs=[plane, plane, plane],
        out_shape=[
            jax.ShapeDtypeStruct((w, n), x.dtype),
            jax.ShapeDtypeStruct((w, n), jnp.float32),
            jax.ShapeDtypeStruct((w, n), jnp.float32),
        ],
        interpret=interpret,
    )(scalars, x, g, mu, nu)
