"""Fused local-optimizer-step kernels for the packed parameter plane:
one launch per dtype bucket covers weight decay + momentum/moments +
parameter write in a single HBM pass (see kernel.py)."""
