"""Pure-jnp oracles for the fused local-optimizer-step kernel family.

``sgd_update`` / ``adamw_update`` are the single-HBM-pass forms of the
per-leaf optimizer steps in :mod:`repro.optim.optimizers` — weight decay +
momentum (+ Nesterov) or the AdamW moment/bias-correction chain plus the
parameter write, expressed once over worker-stacked flat buffers. They are
shared verbatim by the Pallas kernel bodies (``kernel.py``) and by the
non-TPU dispatch path, so the three implementations (per-leaf tree, packed
jnp, packed Pallas) cannot drift apart numerically.

Every cast in these formulas mirrors ``repro.optim.optimizers`` bit for bit
— the packed local step is pinned to the per-leaf path by the golden
differential suite (tests/test_packed_optim.py), so the cast chains here are
load-bearing, not style:

* weight decay is applied in the *gradient* dtype (``wd * x.astype(g)``);
* the SGD momentum buffer stays in the parameter dtype;
* AdamW moments are f32 regardless of parameter dtype;
* ``lr`` (and the Adam bias corrections) are f32 scalars — the schedule
  always emits f32, so for bf16 parameters the final ``x - lr*u`` runs in
  f32 before the cast back, exactly like the per-leaf path.

Padding lanes stay zero through every update: g=0, m=0 ⇒ u=0 ⇒ x stays 0
(AdamW: nu=0 ⇒ denominator = eps, u = 0/eps = 0), so packed buffers never
leak padding into real lanes.
"""
from __future__ import annotations

import jax.numpy as jnp


def sgd_update(x, g, m, lr, *, momentum: float, nesterov: bool, weight_decay: float):
    """One fused SGD(+Nesterov momentum) step over flat buffers.

    x, g, m: same-shape buffers (any lead dims); lr: f32 scalar.
    Returns (x_new, m_new). Mirrors ``repro.optim.optimizers.sgd.step``.
    """
    if weight_decay:
        g = g + weight_decay * x.astype(g.dtype)
    m_new = (momentum * m + g).astype(m.dtype)
    u = momentum * m_new + g if nesterov else m_new
    x_new = (x - lr * u).astype(x.dtype)
    return x_new, m_new


def adamw_update(x, g, mu, nu, lr, c1, c2, *, b1: float, b2: float, eps: float, weight_decay: float):
    """One fused AdamW step over flat buffers.

    x, g: parameter-dtype buffers; mu, nu: f32 moment buffers; lr, c1, c2:
    f32 scalars (c1/c2 are the bias corrections ``1 - b**count``, computed
    once per step from the shared scalar count — not per leaf, not per
    worker). Returns (x_new, mu_new, nu_new). Mirrors
    ``repro.optim.optimizers.adamw.step``.
    """
    mu_new = b1 * mu + (1 - b1) * g.astype(jnp.float32)
    nu_new = b2 * nu + (1 - b2) * jnp.square(g.astype(jnp.float32))
    u = (mu_new / c1) / (jnp.sqrt(nu_new / c2) + eps)
    if weight_decay:
        u = u + weight_decay * x.astype(jnp.float32)
    x_new = (x - lr * u).astype(x.dtype)
    return x_new, mu_new, nu_new
