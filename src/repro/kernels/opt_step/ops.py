"""Public wrappers for the fused optimizer-step kernel family.

``sgd_step`` / ``adamw_step`` take one dtype bucket of the packed parameter
plane (worker-stacked (w, n) buffers) plus the matching gradient and
optimizer-state buffers and apply one full local optimizer update in a
single fused pass. On TPU they run through the Pallas kernels; elsewhere
the shared jnp formulas in ``ref.py`` are used and XLA fuses them into the
surrounding round program. Packed-plane buffers are always 128-lane
aligned, so the TPU path is pad-free; ragged direct calls pay a pad+slice
round-trip like the anchor-mix ops.

``lr`` must be an f32 scalar (the schedule always emits one); Adam's bias
corrections are computed here — once per step from the single shared count,
not per leaf or per worker — and ride into the kernel through SMEM.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import flags
from repro.kernels.opt_step import kernel as _k
from repro.kernels.opt_step import ref as _ref


def _pad_last(a, pad: int):
    if pad == 0:
        return a
    width = [(0, 0)] * (a.ndim - 1) + [(0, pad)]
    return jnp.pad(a, width)


def sgd_step(x, g, m, lr, *, momentum: float, nesterov: bool, weight_decay: float):
    """Fused SGD(+Nesterov) step on one bucket. x, g, m: (w, n).
    Returns (x_new, m_new)."""
    if not flags.use_pallas():
        return _ref.sgd_update(x, g, m, lr, momentum=momentum, nesterov=nesterov, weight_decay=weight_decay)
    n = x.shape[-1]
    pad = (-n) % 128
    scalars = jnp.reshape(jnp.asarray(lr, jnp.float32), (1,))
    x_new, m_new = _k.sgd_step_flat(
        _pad_last(x, pad), _pad_last(g, pad), _pad_last(m, pad), scalars,
        momentum=float(momentum), nesterov=bool(nesterov), weight_decay=float(weight_decay),
        interpret=flags.interpret_mode(),
    )
    if pad:
        x_new, m_new = x_new[..., :n], m_new[..., :n]
    return x_new, m_new


def adamw_step(x, g, mu, nu, lr, c1, c2, *, b1: float, b2: float, eps: float, weight_decay: float):
    """Fused AdamW step on one bucket. x, g: (w, n) param dtype; mu, nu:
    (w, n) f32; lr/c1/c2: f32 scalars. Returns (x_new, mu_new, nu_new)."""
    if not flags.use_pallas():
        return _ref.adamw_update(x, g, mu, nu, lr, c1, c2, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay)
    n = x.shape[-1]
    pad = (-n) % 128
    scalars = jnp.stack([jnp.asarray(lr, jnp.float32), jnp.asarray(c1, jnp.float32), jnp.asarray(c2, jnp.float32)])
    x_new, mu_new, nu_new = _k.adamw_step_flat(
        _pad_last(x, pad), _pad_last(g, pad), _pad_last(mu, pad), _pad_last(nu, pad), scalars,
        b1=float(b1), b2=float(b2), eps=float(eps), weight_decay=float(weight_decay),
        interpret=flags.interpret_mode(),
    )
    if pad:
        x_new, mu_new, nu_new = x_new[..., :n], mu_new[..., :n], nu_new[..., :n]
    return x_new, mu_new, nu_new


sgd_reference = _ref.sgd_update
adamw_reference = _ref.adamw_update
