"""Consensus-probe Pallas TPU kernel — standalone form.

One pass over the worker-stacked flat buffer x (m, n): per block the worker
mean, the squared deviations and the squared mean are computed entirely in
VMEM (the worker axis m lives inside the block, exactly the ``anchor_mix``
boundary tile shape), reduced to 128-lane partial sums and accumulated in a
VMEM scratch across the sequential grid. The last grid step writes the
(2, 128) partial-sum output — row 0 the drift sum Σ(x_i − x̄)², row 1 the
scale sum Σ x̄² — which the ops wrapper reduces to two f32 scalars.

This is the ≤ 1-launch-per-dtype-bucket path for strategies whose boundary
does not already read the plane through ``pullback_mean`` (local_sgd, the
avg-rebase family, strategies with no boundary math). Pullback-family
strategies get the same partial sums fused into their existing boundary
kernels (``anchor_mix.kernel`` with ``probe=True``) for zero extra
launches.

The grid accumulation requires the single grid dimension to execute
sequentially (the Pallas TPU default for an un-annotated grid; interpret
mode is sequential by construction), and the block size must divide n so no
ragged tail feeds garbage into the sums — ``probe_block`` picks the largest
lane-aligned divisor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128


def probe_block(n: int, block: int) -> int:
    """Largest multiple of 128 that is ≤ ``block`` and divides n (n must be
    lane-aligned). Reduction kernels cannot tolerate a ragged final block."""
    block = min(block, n)
    block -= block % LANE
    while n % block:
        block -= LANE
    return block


def _probe_kernel(x_ref, st_ref, acc_ref):
    i = pl.program_id(0)
    xf = x_ref[...].astype(jnp.float32)  # (m, block)
    mean = jnp.mean(xf, axis=0)  # (block,)
    drift = jnp.sum(jnp.square(xf - mean[None, :]).reshape(-1, LANE), axis=0)
    scale = jnp.sum(jnp.square(mean).reshape(-1, LANE), axis=0)

    @pl.when(i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[0, :] += drift
    acc_ref[1, :] += scale

    @pl.when(i == pl.num_programs(0) - 1)
    def _():
        st_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def probe_flat(x, *, block: int = 1 << 13, interpret: bool = False):
    """x: (m, n) stacked plane, n % 128 == 0. Returns (2, 128) f32 partial
    sums (row 0: Σ(x_i − x̄)², row 1: Σ x̄²)."""
    m, n = x.shape
    block = probe_block(n, block)
    grid = (n // block,)
    return pl.pallas_call(
        _probe_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((m, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((2, LANE), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((2, LANE), jnp.float32),
        scratch_shapes=[pltpu.VMEM((2, LANE), jnp.float32)],
        interpret=interpret,
    )(x)
