"""Public wrappers for the consensus-probe kernel family.

``probe_buffer`` measures one worker-stacked flat buffer (one launch on
TPU, the jnp oracle elsewhere); ``packed_probe`` sweeps a whole
:class:`repro.parallel.packing.Packed` plane (≤ 1 launch per dtype bucket)
and aggregates into the :class:`ConsensusStats` pair the adaptive-τ
controller consumes. ``stats_from_partials`` is the shared aggregation used
by strategies that collect the same per-bucket raw sums as fused extra
outputs of their boundary kernels (``anchor_mix`` with ``probe=True``) —
zero extra launches on that path.

Padding lanes are zero-filled by ``pack`` and stay zero through training
(optimizer cotangents and anchors are zero there too), so full-buffer sums
equal per-leaf sums up to f32 summation order; the bit-exact per-leaf
oracle is :func:`repro.control.consensus_drift` (DESIGN.md §6).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import flags
from repro.kernels.consensus_probe import kernel as _k
from repro.kernels.consensus_probe import ref as _ref
from repro.parallel.packing import Packed


class ConsensusStats(NamedTuple):
    """The controller's two inputs, as traced f32 scalars:
    drift = mean_i ‖x_i − x̄‖ (RMS-aggregated), scale = ‖x̄‖."""

    drift: jnp.ndarray
    scale: jnp.ndarray


def probe_buffer(x):
    """x: (m, n) stacked flat buffer -> (drift_sq, scale_sq) raw f32 sums
    (not yet divided by m). One kernel launch on TPU; jnp oracle elsewhere.
    Buffers already lane-aligned (packed planes always are) run pad-free."""
    if not flags.use_pallas():
        return _ref.plane_probe(x)
    n = x.shape[-1]
    pad = (-n) % 128
    if pad:
        x = jnp.pad(x, [(0, 0), (0, pad)])  # zeros: contribute 0 to both sums
    st = _k.probe_flat(x, interpret=flags.interpret_mode())
    return jnp.sum(st[0]), jnp.sum(st[1])


def stats_from_partials(partials, m: int) -> ConsensusStats:
    """Aggregate per-bucket ``(drift_sq, scale_sq)`` raw sums into the
    controller's (drift, scale): divide the pooled drift sum by the worker
    count once, then take square roots — the same normalization as the
    per-leaf oracle (every leaf divides by the same m)."""
    drift_sq = sum(p[0] for p in partials)
    scale_sq = sum(p[1] for p in partials)
    return ConsensusStats(jnp.sqrt(drift_sq / m), jnp.sqrt(scale_sq))


def packed_probe(px: Packed) -> ConsensusStats:
    """Standalone probe of a worker-stacked plane: ≤ 1 launch per dtype
    bucket, aggregated across buckets."""
    m = int(px.lead_shape[0]) if px.lead_shape else 1
    return stats_from_partials([probe_buffer(b) for b in px.buffers], m)


def tree_probe(x_stacked) -> ConsensusStats:
    """Per-leaf pytree form (the packed=False reference path): same
    semantics as :func:`repro.control.consensus_drift`, returned as
    :class:`ConsensusStats`."""
    drift_sq = 0.0
    scale_sq = 0.0
    for t in jax.tree.leaves(x_stacked):
        tf = t.astype(jnp.float32)
        mean = jnp.mean(tf, axis=0, keepdims=True)
        drift_sq += jnp.sum(jnp.square(tf - mean)) / t.shape[0]
        scale_sq += jnp.sum(jnp.square(mean))
    return ConsensusStats(jnp.sqrt(drift_sq), jnp.sqrt(scale_sq))
