"""Fused consensus-distance probe over the packed parameter plane.

Feeds the adaptive-τ controller (DESIGN.md §6): per-dtype-bucket partial
sums of ‖x_i − x̄‖² and ‖x̄‖² over the worker-stacked flat buffers, in the
same HBM pass shape as the ``anchor_mix`` boundary kernels. Strategies whose
boundary already runs ``pullback_mean(_momentum)`` get the probe fused into
those kernels (zero extra launches); everything else uses the standalone
one-launch-per-bucket probe here.
"""
from repro.kernels.consensus_probe.ops import (
    ConsensusStats,
    packed_probe,
    probe_buffer,
    stats_from_partials,
    tree_probe,
)

__all__ = [
    "ConsensusStats",
    "packed_probe",
    "probe_buffer",
    "stats_from_partials",
    "tree_probe",
]
