"""Pure-jnp oracles for the consensus-distance probe.

The probe measures the *round-end* (pre-boundary) worker-stacked plane: how
far the workers drifted apart during the τ local steps, and how large the
consensus model is — the two inputs of the adaptive-τ controller
(DESIGN.md §6, AdaComm-style ratio test).

``plane_probe`` is the per-buffer form the kernels mirror: raw f32 sums,
NOT normalized — the aggregator (``ops.stats_from_partials``) divides the
drift sum by the worker count m once, across all dtype buckets, matching
the per-leaf ``repro.control.consensus_drift`` oracle up to f32 summation
order (each leaf's elements live contiguously in exactly one bucket, and
padding lanes are zero-filled by ``pack`` so they contribute 0 to both
sums).
"""
from __future__ import annotations

import jax.numpy as jnp


def plane_probe(x):
    """x: (m, n) worker-stacked flat buffer.

    Returns ``(drift_sq, scale_sq)`` raw f32 sums: Σ (x_i − x̄)² over all
    workers and elements, and Σ x̄² over elements, with x̄ the per-element
    worker mean in f32.
    """
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=0)
    drift_sq = jnp.sum(jnp.square(xf - mean[None, :]))
    scale_sq = jnp.sum(jnp.square(mean))
    return drift_sq, scale_sq
