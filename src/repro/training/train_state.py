"""Worker-stacked training state.

``inflight`` is the two-phase protocol's first-class slot for the collective
launched at the previous round boundary and not yet consumed (the anchor
mean for Overlap-Local-SGD). Strategies without an overlapped collective
(blocking algorithms, pure gradient-space methods) carry ``None`` there.

Under the packed boundary (``AlgoConfig.packed``, the default) the inflight
slot and anchor-shaped strategy vars are :class:`repro.parallel.packing.Packed`
flat buffers — they live packed for their whole launch→consume life, so no
repacking happens between boundaries. With a packed-capable optimizer the
state is *plane-resident*: ``x`` itself is the worker-stacked packed plane
for its entire lifetime (packed once at construction; round boundaries
consume and return the plane). :func:`params_view` recovers the pytree view
when host-side code needs leaves.

The local optimizer state follows the same rule: with a packed strategy and
a packed-capable optimizer, ``opt`` is a ``PackedSGDState``/``PackedAdamState``
of worker-stacked flat buffers (AdamW moments as f32 shadow buckets, one
scalar count) that lives packed across the whole round — the τ local steps
read and write it through the fused ``kernels/opt_step`` ops, one launch per
dtype bucket per step.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.strategy import AlgoVars, CommStrategy, as_strategy
from repro.optim.optimizers import Optimizer, offload_capable, packed_capable
from repro.parallel import offload as off
from repro.parallel.packing import Packed, pack, unpack


class TrainState(NamedTuple):
    x: Any  # stacked local params: (m, ...) pytree, or the worker-stacked
    #         Packed plane when training is plane-resident (packed strategy
    #         + packed-capable optimizer)
    opt: Any  # stacked local optimizer state (m, ...)
    vars: AlgoVars  # strategy variables (anchor z, momentum v, extras)
    step: jnp.ndarray  # global local-step counter
    inflight: Any = None  # collective launched last boundary, consumed next (eq. 5 → eq. 4)
    membership: Any = None  # live-worker Membership for degraded boundaries
    #         (repro.fault, DESIGN.md §7); None = fully live, the baseline
    #         program — the fault harness installs/clears it between rounds


def make_train_state(
    params: Any,
    m: int,
    optimizer: Optimizer,
    algorithm,  # CommStrategy, or a legacy Algorithm (wrapped automatically)
    axes_tree: Any = None,
) -> TrainState:
    """All workers start at the same point (Theorem 1's initialization).

    With a packed strategy and a packed-capable optimizer the state is
    *plane-resident*: ``x`` is stored as the worker-stacked ``Packed`` plane
    (packed exactly once, here) and every consumer — local steps, boundary
    phases, strategy init hooks — works on the plane directly.
    """
    strategy = as_strategy(algorithm)
    x = jax.tree.map(lambda t: jnp.tile(t[None], (m,) + (1,) * t.ndim), params)
    if strategy.packed and packed_capable(optimizer):
        x = pack(x, lead=1)
        opt = optimizer.init_packed(x)
    else:
        opt = jax.vmap(optimizer.init)(x)
    vars = strategy.init_vars(x, axes_tree)
    inflight = strategy.init_inflight(x, vars, axes_tree)
    if (
        bool(getattr(strategy.cfg, "offload", False))
        and isinstance(x, Packed)
        and offload_capable(optimizer)
    ):
        # AlgoConfig.offload: opt state and anchor-shaped slots start (and
        # stay, between boundaries) host-resident as chunked HostPlanes —
        # the engine streams them through the window (DESIGN.md §9)
        plan = off.OffloadPlan.for_layout(
            x.layout, float(getattr(strategy.cfg, "offload_chunk_mb", off.DEFAULT_CHUNK_MB))
        )
        opt = off.tree_offload(opt, plan)
        vars = off.tree_offload(vars, plan)
        inflight = off.tree_offload(inflight, plan)
    return TrainState(x=x, opt=opt, vars=vars, step=jnp.zeros((), jnp.int32), inflight=inflight)


def params_view(state: TrainState):
    """The stacked params as a pytree, whatever representation ``x`` is in."""
    return unpack(state.x) if isinstance(state.x, Packed) else state.x


def worker_params(state: TrainState, i: int = 0):
    return jax.tree.map(lambda t: t[i], params_view(state))


def consensus_params(state: TrainState):
    """The virtual/averaged model used for evaluation (paper's y_k): the
    mean of the local models — anchor or not, packed or per-leaf."""
    return jax.tree.map(lambda t: jnp.mean(t.astype(jnp.float32), axis=0), params_view(state))
