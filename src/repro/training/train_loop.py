"""Round-based training engine for two-phase communication strategies.

One *round* = τ local steps (lax.scan) + the strategy's two boundary phases,
driven through the combined ``boundary_round`` hook:

    boundary_apply(x, vars, inflight)      consume the collective launched at
                                           the PREVIOUS boundary (eq. 4)
    boundary_launch(x, vars) -> inflight   start this round's collective
                                           (eq. 5), carried in TrainState

Packed strategies (``AlgoConfig.packed``, the default) override
``boundary_round`` to run both phases fused over the packed parameter plane
— anchor-shaped state and inflight slots are then flat
:class:`repro.parallel.packing.Packed` buffers rather than pytrees.

Plane-resident training (packed strategy + packed-capable optimizer): the
packed parameter plane is the *canonical* representation end-to-end.
``TrainState.x`` stores the worker-stacked plane across rounds, the τ-step
scan carries it, and the loss is differentiated **with the plane buffers as
the primal argument** — the model reads parameters through a
:class:`repro.parallel.packing.ParamView` (lazy ``view_leaf`` windows whose
slices XLA fuses into the leaf consumers), so gradients arrive as one flat
cotangent buffer per dtype bucket. The engine itself never touches a
parameter pytree: the per-microstep ``pack(grads)`` call is gone (the one
plane build per step is the window read's AD transpose, emitted by the
packing layer — see ``read_windows``), there is no per-round pack/unpack
seam (``boundary_round`` consumes and returns the plane), and the
gradient hook runs as ``transform_grads_packed`` (one collective per dtype
bucket for sync-SGD; PowerSGD's elementwise error feedback per-bucket, with
only its inherently per-leaf rank-r factor math left per-leaf), the
optimizer update is one fused ``kernels/opt_step`` launch per bucket
against flat optimizer-state buffers carried in ``TrainState.opt``, and
mid-round consumers (DaSGD) rebase the plane in place via
``local_post_update_packed``. The per-leaf path remains intact as the
bit-exact oracle (``packed=False``), pinned by tests/test_packed_optim.py.

Gradient clipping follows the same split: by default the plane-resident
step computes the global norm with the per-leaf summation order (window
reads off the plane — bitwise-identical to ``clip_by_global_norm``, keeping
the golden pin); ``AlgoConfig.packed_clip`` opts into per-bucket partial
square-sums feeding the one global scale (O(buckets) reductions, a
different f32 summation order, ≤ a few ulps apart).

Because launch and consume are distinct phases separated by τ local steps,
the anchor collective's consumer lies a full round downstream when several
rounds are scanned into one program (``rounds_per_call > 1``, the production
setting) — the latency-hiding scheduler overlaps it with local compute, the
JAX-native form of the paper's communication thread. Delayed-averaging
strategies consume mid-round instead via the per-step
``local_post_update(x, vars, inflight, k)`` hook, which receives the local
step index within the round.

Legacy single-hook ``Algorithm`` objects are accepted everywhere a strategy
is and run through :class:`repro.core.strategy.LegacyStrategy` (their whole
``boundary`` executes in the apply phase — seed semantics, bit for bit).

Batch layout: a *round batch* is a pytree whose array leaves are shaped
(τ, m, per_worker_batch, ...) — scanned over τ, vmapped over m.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.strategy import as_strategy
from repro.optim.optimizers import (
    Optimizer,
    clip_by_global_norm,
    clip_packed_by_global_norm,
    offload_capable,
    packed_capable,
)
from repro.parallel import offload as off
from repro.parallel.packing import Packed, ParamView, pack
from repro.training.train_state import TrainState


def make_round_step(
    loss_fn: Callable,  # (params, batch) -> (loss, metrics)
    optimizer: Optimizer,
    strategy,  # CommStrategy or legacy Algorithm
    schedule: Callable,
    axes_tree: Any = None,
    grad_clip: float = 0.0,
    microbatch: Optional[int] = None,
    probe: bool = False,
):
    """``probe=True`` runs the boundary with the fused consensus probe
    (DESIGN.md §6) and adds scalar ``consensus_drift`` / ``consensus_scale``
    metrics — the adaptive-τ controller's inputs, measured on the round-end
    plane at zero extra kernel launches for pullback-family strategies."""
    strategy = as_strategy(strategy)
    # plane-resident local step: the scan carries the packed plane, the loss
    # is differentiated with the plane as the primal (params reach the model
    # through a ParamView), and grads flow as flat per-bucket cotangents
    # straight into the packed gradient hook + fused optimizer launch
    packed_step = strategy.packed and packed_capable(optimizer)
    packed_clip = packed_step and bool(getattr(strategy.cfg, "packed_clip", False))
    # host-offloaded state (AlgoConfig.offload): opt/anchor/inflight buckets
    # are HostPlanes between boundaries; the opt update streams them through
    # the double buffer each local step, anchor-shaped state round-trips
    # whole-plane at the window edges (DESIGN.md §9)
    offload_on = bool(getattr(strategy.cfg, "offload", False))
    if offload_on and not packed_step:
        raise ValueError("AlgoConfig.offload requires a packed strategy and a packed-capable optimizer")
    if offload_on and not offload_capable(optimizer):
        raise ValueError("AlgoConfig.offload requires an optimizer with a streamed step (step_streamed)")
    offload_chunk_mb = float(getattr(strategy.cfg, "offload_chunk_mb", off.DEFAULT_CHUNK_MB))
    if packed_step:
        # differentiate with the STACKED plane as the primal: materialize
        # the worker-stacked view once (a single read_windows site), vmap
        # the per-worker loss over it, and take the gradient of the summed
        # losses — each worker's loss cotangent seed is the same 1.0 the
        # vmapped per-worker grad uses, so the stacked cotangent plane is
        # the per-worker grads stacked, bitwise. Keeping the window read
        # (and its DUS-chain transpose) OUTSIDE the vmap matters: the DUS
        # batching rule lowers to select/iota masked writes.
        def _summed_loss(px, micro):
            view = ParamView(px).materialize()
            losses, metrics = jax.vmap(loss_fn)(view, micro)
            return jnp.sum(losses), metrics

        worker_grads = jax.grad(_summed_loss, has_aux=True)
    else:
        worker_grads = jax.vmap(jax.grad(loss_fn, has_aux=True))

    def stacked_grads(x, micro):
        """Per-worker grads, with optional gradient accumulation over
        microbatches (large per-worker batches on big-vocab/MoE archs).
        Metrics are averaged across microbatches. ``x`` is the per-mode
        primal — the stacked pytree, or the stacked plane."""
        leaves = jax.tree.leaves(micro)
        b = leaves[0].shape[1]
        if microbatch is None or b <= microbatch:
            return worker_grads(x, micro)
        k = b // microbatch
        split = jax.tree.map(
            lambda t: t.reshape((t.shape[0], k, microbatch) + t.shape[2:]).swapaxes(0, 1), micro
        )

        def acc(carry, mb):
            g_acc, m_acc = carry
            g, mets = worker_grads(x, mb)
            g_acc = jax.tree.map(lambda a, gg: a + gg.astype(a.dtype), g_acc, g)
            m_acc = jax.tree.map(lambda a, mm: a + mm.astype(jnp.float32), m_acc, mets)
            return (g_acc, m_acc), None

        g0 = jax.tree.map(lambda t: jnp.zeros(t.shape, jnp.float32), x)
        m_sds = jax.eval_shape(lambda mb: worker_grads(x, mb)[1], jax.tree.map(lambda t: t[0], split))
        m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32), m_sds)
        (g_sum, m_sum), _ = jax.lax.scan(acc, (g0, m0), split)
        grads = jax.tree.map(lambda g, xx: (g / k).astype(xx.dtype), g_sum, x)
        metrics = jax.tree.map(lambda s, ref: (s / k).astype(ref.dtype), m_sum, m_sds)
        return grads, metrics

    def round_step(state: TrainState, round_batch) -> Tuple[TrainState, dict]:
        inflight = state.inflight

        def local_step(carry, scanned):
            micro, k_in_round = scanned
            x, opt, vars, step = carry  # x: the packed plane when plane-resident
            lr = schedule(step)
            grads, metrics = stacked_grads(x, micro)
            if grad_clip > 0.0:
                if packed_step:
                    grads = jax.vmap(
                        lambda g: clip_packed_by_global_norm(g, grad_clip, per_bucket=packed_clip)[0]
                    )(grads)
                else:
                    grads = jax.vmap(lambda g: clip_by_global_norm(g, grad_clip)[0])(grads)
            if packed_step:
                pg, vars = strategy.transform_grads_packed(grads, vars)
                if offload_on:
                    # streamed update: the host-resident state buckets walk
                    # through the two device staging chunks per bucket
                    opt, x = optimizer.step_streamed(opt, x, pg, lr)
                else:
                    opt, x = optimizer.step_packed(opt, x, pg, lr)
                x = strategy.local_post_update_packed(x, vars, inflight, k_in_round)
            else:
                grads, vars = strategy.transform_grads(grads, vars)
                opt, x = jax.vmap(lambda o, xi, gi: optimizer.step(o, xi, gi, lr))(opt, x, grads)
                x = strategy.local_post_update(x, vars, inflight, k_in_round)
            metrics = dict(metrics, lr=jnp.broadcast_to(lr, metrics["loss"].shape))
            return (x, opt, vars, step + 1), metrics

        tau = jax.tree.leaves(round_batch)[0].shape[0]
        x0 = state.x
        if packed_step and not isinstance(x0, Packed):
            # migration path for states built (or restored) per-leaf: the
            # first round adopts the plane; from then on x stays resident
            x0 = pack(x0, lead=1)
        opt0, vars0 = state.opt, state.vars
        plan = None
        if offload_on:
            plan = off.plan_of(opt0)
            if plan is None:
                # adoption: a resident state entering the offloaded engine
                plan = off.OffloadPlan.for_layout(x0.layout, offload_chunk_mb)
                opt0 = off.tree_offload(opt0, plan)
            # prefetch (H2D) of the anchor-shaped state: vars ride the scan
            # carry, so they restore up front; the inflight slot restores up
            # front only for mid-round consumers (DaSGD) — otherwise right
            # at the boundary, so its device live range starts at the copy.
            # Either way the copy has no data dependency on the local scan
            # and the latency-hiding scheduler overlaps it with the τ steps,
            # exactly like the collective it rides next to.
            vars0 = off.tree_restore(vars0)
            if strategy.consumes_inflight_midround:
                inflight = off.tree_restore(inflight)
        (x, opt, vars, step), metrics = jax.lax.scan(
            local_step,
            (x0, opt0, vars0, state.step),
            (round_batch, jnp.arange(tau)),
        )
        if offload_on:
            inflight = off.tree_restore(inflight)  # no-op when already device-resident
        # apply + launch in one hook: per-leaf strategies run the two phases
        # back to back; packed strategies fuse them over the flat parameter
        # plane (one collective + one kernel launch per boundary) and return
        # the plane itself — x never leaves the packed representation, so
        # there is no pack/unpack seam at round granularity.
        # the membership installed by the fault harness (None on clean
        # rounds) masks the boundary; it is carried through unchanged — the
        # harness owns installing/clearing it between rounds (DESIGN.md §7)
        membership = state.membership
        if probe:
            x, vars, inflight, stats = strategy.boundary_round(
                x, vars, inflight, axes_tree, probe=True, membership=membership
            )
            metrics = dict(metrics, consensus_drift=stats.drift, consensus_scale=stats.scale)
        else:
            x, vars, inflight = strategy.boundary_round(x, vars, inflight, axes_tree, membership=membership)
        if offload_on:
            # D2H: the boundary's outputs go back host-resident until the
            # next window needs them (opt state already streamed back
            # chunk-by-chunk inside the scan)
            vars = off.tree_offload(vars, plan)
            inflight = off.tree_offload(inflight, plan)
        new_state = TrainState(x=x, opt=opt, vars=vars, step=step, inflight=inflight, membership=membership)
        return new_state, metrics

    return round_step


def make_train_fn(
    loss_fn: Callable,
    optimizer: Optimizer,
    strategy,  # CommStrategy or legacy Algorithm
    schedule: Callable,
    axes_tree: Any = None,
    grad_clip: float = 0.0,
    rounds_per_call: int = 1,
    donate: bool = True,
    microbatch: Optional[int] = None,
):
    """jit'd multi-round step: (state, batches[(R, τ, m, b, ...)]) -> (state, metrics)."""
    round_step = make_round_step(loss_fn, optimizer, strategy, schedule, axes_tree, grad_clip, microbatch)
    strategy_obj = as_strategy(strategy)
    packed_step = strategy_obj.packed and packed_capable(optimizer)
    offload_on = packed_step and bool(getattr(strategy_obj.cfg, "offload", False))

    def many(state, batches):
        if packed_step and not isinstance(state.x, Packed):
            # migrate a per-leaf state BEFORE the rounds scan: round_step's
            # own coercion changes the TrainState structure, which a
            # multi-round lax.scan carry cannot absorb mid-body
            state = state._replace(x=pack(state.x, lead=1))
        if offload_on and not off.is_offloaded(state.opt):
            # same structural constraint for a resident state entering the
            # offloaded engine: adopt the host form before the rounds scan
            plan = off.OffloadPlan.for_layout(
                state.x.layout, float(getattr(strategy_obj.cfg, "offload_chunk_mb", off.DEFAULT_CHUNK_MB))
            )
            state = state._replace(
                opt=off.tree_offload(state.opt, plan),
                vars=off.tree_offload(state.vars, plan),
                inflight=off.tree_offload(state.inflight, plan),
            )
        if rounds_per_call == 1:
            rb = jax.tree.map(lambda t: t[0], batches)
            return round_step(state, rb)
        return jax.lax.scan(round_step, state, batches)

    return jax.jit(many, donate_argnums=(0,) if donate else ())


def stack_round_batches(per_step_batches) -> Any:
    """List (len τ) of per-step batches with leaves (m, b, ...) -> leaves (τ, m, b, ...)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_step_batches)
