"""Round-based training engine.

One *round* = τ local steps (lax.scan) + the algorithm's boundary. The
boundary's collectives (anchor reduce-scatter for Overlap-Local-SGD, model
average for Local SGD, ...) are ordinary XLA ops; when several rounds are
scanned into one program (``rounds_per_call > 1``, the production setting),
the anchor collective's consumer lies τ steps downstream and the latency-
hiding scheduler overlaps it with local compute — the JAX-native form of the
paper's communication thread.

Batch layout: a *round batch* is a pytree whose array leaves are shaped
(τ, m, per_worker_batch, ...) — scanned over τ, vmapped over m.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.algorithms import Algorithm
from repro.optim.optimizers import Optimizer, clip_by_global_norm
from repro.training.train_state import TrainState


def make_round_step(
    loss_fn: Callable,  # (params, batch) -> (loss, metrics)
    optimizer: Optimizer,
    algorithm: Algorithm,
    schedule: Callable,
    axes_tree: Any = None,
    grad_clip: float = 0.0,
    microbatch: Optional[int] = None,
):
    grad_fn = jax.grad(loss_fn, has_aux=True)

    def stacked_grads(x, micro):
        """Per-worker grads, with optional gradient accumulation over
        microbatches (large per-worker batches on big-vocab/MoE archs)."""
        leaves = jax.tree.leaves(micro)
        b = leaves[0].shape[1]
        if microbatch is None or b <= microbatch:
            return jax.vmap(grad_fn)(x, micro)
        k = b // microbatch
        split = jax.tree.map(
            lambda t: t.reshape((t.shape[0], k, microbatch) + t.shape[2:]).swapaxes(0, 1), micro
        )

        def acc(carry, mb):
            g_acc, _ = carry
            g, mets = jax.vmap(grad_fn)(x, mb)
            g_acc = jax.tree.map(lambda a, gg: a + gg.astype(a.dtype), g_acc, g)
            return (g_acc, mets), None

        g0 = jax.tree.map(lambda t: jnp.zeros(t.shape, jnp.float32), x)
        m0 = jax.eval_shape(lambda mb: jax.vmap(grad_fn)(x, mb)[1], jax.tree.map(lambda t: t[0], split))
        m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m0)
        (g_sum, mets), _ = jax.lax.scan(acc, (g0, m0), split)
        grads = jax.tree.map(lambda g, xx: (g / k).astype(xx.dtype), g_sum, x)
        return grads, mets

    def local_step(carry, micro):
        x, opt, vars, step = carry
        lr = schedule(step)
        grads, metrics = stacked_grads(x, micro)
        if grad_clip > 0.0:
            grads = jax.vmap(lambda g: clip_by_global_norm(g, grad_clip)[0])(grads)
        grads, vars = algorithm.transform_grads(grads, vars)
        opt, x = jax.vmap(lambda o, xi, gi: optimizer.step(o, xi, gi, lr))(opt, x, grads)
        metrics = dict(metrics, lr=jnp.broadcast_to(lr, metrics["loss"].shape))
        return (x, opt, vars, step + 1), metrics

    def round_step(state: TrainState, round_batch) -> Tuple[TrainState, dict]:
        (x, opt, vars, step), metrics = jax.lax.scan(
            local_step, (state.x, state.opt, state.vars, state.step), round_batch
        )
        x, vars = algorithm.boundary(x, vars, axes_tree)
        new_state = TrainState(x=x, opt=opt, vars=vars, step=step)
        return new_state, metrics

    return round_step


def make_train_fn(
    loss_fn: Callable,
    optimizer: Optimizer,
    algorithm: Algorithm,
    schedule: Callable,
    axes_tree: Any = None,
    grad_clip: float = 0.0,
    rounds_per_call: int = 1,
    donate: bool = True,
    microbatch: Optional[int] = None,
):
    """jit'd multi-round step: (state, batches[(R, τ, m, b, ...)]) -> (state, metrics)."""
    round_step = make_round_step(loss_fn, optimizer, algorithm, schedule, axes_tree, grad_clip, microbatch)

    def many(state, batches):
        if rounds_per_call == 1:
            rb = jax.tree.map(lambda t: t[0], batches)
            return round_step(state, rb)
        return jax.lax.scan(round_step, state, batches)

    return jax.jit(many, donate_argnums=(0,) if donate else ())


def stack_round_batches(per_step_batches) -> Any:
    """List (len τ) of per-step batches with leaves (m, b, ...) -> leaves (τ, m, b, ...)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_step_batches)
