from repro.training.train_loop import make_round_step, make_train_fn, stack_round_batches
from repro.training.train_state import TrainState, consensus_params, make_train_state, worker_params

__all__ = [
    "TrainState",
    "consensus_params",
    "make_round_step",
    "make_train_fn",
    "make_train_state",
    "stack_round_batches",
    "worker_params",
]
