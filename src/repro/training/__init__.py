"""Round-based training engine for two-phase communication strategies.

``make_round_step`` drives, per round: τ local steps (scan) →
``boundary_apply`` (consume last round's collective) → ``boundary_launch``
(start this round's, carried in ``TrainState.inflight``). Most callers go
through :class:`repro.api.Experiment` instead of wiring these directly.
"""
from repro.training.train_loop import make_round_step, make_train_fn, stack_round_batches
from repro.training.train_state import TrainState, consensus_params, make_train_state, params_view, worker_params

__all__ = [
    "TrainState",
    "consensus_params",
    "make_round_step",
    "make_train_fn",
    "make_train_state",
    "params_view",
    "stack_round_batches",
    "worker_params",
]
