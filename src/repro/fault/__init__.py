"""Fault-tolerance layer: deterministic fault plans, live-worker membership,
and the host-side injection harness (DESIGN.md §7)."""
from repro.fault.harness import FaultHarness, resync_from_anchor
from repro.fault.membership import Membership, from_mask, full
from repro.fault.plan import FaultPlan

__all__ = [
    "FaultHarness",
    "FaultPlan",
    "Membership",
    "from_mask",
    "full",
    "resync_from_anchor",
]
