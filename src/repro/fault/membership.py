"""Live-worker membership for degraded round boundaries (DESIGN.md §7).

A :class:`Membership` is the traced, device-side face of the fault layer:
a {0,1} liveness mask over the worker axis plus the renormalized averaging
weights w_i = mask_i / Σ mask (Stochastic-Gradient-Push-style weight
renormalization, arXiv 1811.10792). It rides in ``TrainState.membership``
and is consumed only by the round-boundary phases: a masked boundary pulls
back / averages *live* rows only, and dead rows pass through untouched —
the re-sync of a rejoining worker happens host-side from the anchor (the
paper's recovery point), not inside the jitted round.

``membership=None`` (the default everywhere) is the fully-live fast path:
strategies take the exact pre-fault code path, so the baseline program —
and its bitwise pins and jaxpr launch/collective budgets — is untouched.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np


class Membership(NamedTuple):
    """Live-worker mask + renormalized averaging weights, both (m,) f32.

    ``mask`` holds {0., 1.} liveness; ``weights`` is the mask renormalized
    to sum to 1 over live workers — the masked worker mean is
    Σ_i w_i · x_i, which equals the plain mean when everyone is live.
    Liveness is recoverable from the weights alone (``weights > 0``), so
    kernels take only the weights vector.
    """

    mask: jnp.ndarray
    weights: jnp.ndarray

    @property
    def m(self) -> int:
        return int(self.mask.shape[0])

    def live_count(self):
        return jnp.sum(self.mask)

    def is_full(self) -> bool:
        """Host-side check (concrete arrays only): everyone live?"""
        return bool(np.asarray(self.mask).all())


def full(m: int) -> Membership:
    """The fully-live membership over ``m`` workers."""
    mask = jnp.ones((m,), jnp.float32)
    return Membership(mask=mask, weights=mask / float(m))


def from_mask(mask) -> Membership:
    """Build a membership from a {0,1} liveness mask, renormalizing the
    averaging weights over the live set. At least one worker must be live
    (an all-dead round has no defined boundary)."""
    mask = jnp.asarray(mask, jnp.float32)
    if mask.ndim != 1:
        raise ValueError(f"membership mask must be 1-D over workers, got shape {mask.shape}")
    n_live = np.asarray(jnp.sum(mask))
    if float(n_live) <= 0:
        raise ValueError("membership mask has no live workers")
    return Membership(mask=mask, weights=mask / jnp.sum(mask))
