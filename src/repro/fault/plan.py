"""Deterministic, seedable fault plans (DESIGN.md §7).

A :class:`FaultPlan` is the single source of truth for everything the fault
layer injects: crash/rejoin events, per-worker persistent slowdowns, a
lognormal per-step compute jitter, and network jitter on the collective —
all resolved into a *deterministic per-round schedule* at query time from
``(seed, round)`` substreams, so the same plan replayed anywhere produces
the same membership history (the harness, the dry-run JSON block and the
runtime model all read the same schedule).

Two exclusion mechanisms compose per round:

* **crash windows** — worker w is dead for rounds ``[crash, rejoin)``;
* **straggler deadlines** — a live worker whose simulated round compute
  exceeds ``deadline_factor ×`` the nominal round time has missed the
  overlap window (the collective cannot wait for it without exposing
  communication) and is excluded *for that round only*.

A worker excluded at round r−1 and included at round r is *rejoining*: the
harness re-syncs its plane slice from the anchor before the round runs
(``resync_at``). The JSON face of the schedule is :meth:`degraded_rounds` —
the block the dry-run records.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class FaultPlan:
    m: int  # worker count the plan is defined over
    seed: int = 0
    # crash windows: worker -> (crash_round, rejoin_round); rejoin_round may
    # be None for a permanent crash
    crashes: Tuple[Tuple[int, int, Optional[int]], ...] = ()  # (worker, crash_r, rejoin_r)
    # persistent per-worker compute slowdown factors (the paper's Fig. 5
    # "slow worker" knob); 1.0 = nominal
    slowdown: Tuple[Tuple[int, float], ...] = ()  # (worker, factor)
    # lognormal sigma on every worker's per-round compute (system noise)
    straggle_std: float = 0.0
    # probability a worker's round slows by straggle_factor (transient hiccup)
    straggle_prob: float = 0.0
    straggle_factor: float = 4.0
    # lognormal sigma on the collective's transit time (network jitter)
    jitter_std: float = 0.0
    # a worker whose simulated round compute exceeds this multiple of the
    # nominal round time misses the overlap window and sits the round out
    deadline_factor: float = 3.0

    def __post_init__(self):
        for w, r_crash, r_rejoin in self.crashes:
            if not 0 <= w < self.m:
                raise ValueError(f"crash worker {w} out of range for m={self.m}")
            if r_rejoin is not None and r_rejoin <= r_crash:
                raise ValueError(f"worker {w}: rejoin round {r_rejoin} must follow crash round {r_crash}")
        for w, f in self.slowdown:
            if not 0 <= w < self.m:
                raise ValueError(f"slowdown worker {w} out of range for m={self.m}")
            if f <= 0:
                raise ValueError(f"slowdown factor must be positive, got {f}")

    # -- deterministic per-round draws --------------------------------------

    def _rng(self, r: int) -> np.random.Generator:
        """Per-round substream: draws depend on (seed, round) only, never on
        query order — replaying any round is reproducible in isolation."""
        return np.random.default_rng([self.seed, r])

    def slow_factors(self) -> np.ndarray:
        """(m,) persistent compute-slowdown multipliers."""
        f = np.ones(self.m)
        for w, fac in self.slowdown:
            f[w] = fac
        return f

    def round_compute_factors(self, r: int) -> np.ndarray:
        """(m,) simulated compute time for round r, as a multiple of the
        nominal round time (1.0 = nominal): persistent slowdown × lognormal
        system noise × transient hiccups."""
        rng = self._rng(r)
        t = self.slow_factors().copy()
        if self.straggle_std > 0:
            t *= rng.lognormal(mean=0.0, sigma=self.straggle_std, size=self.m)
        if self.straggle_prob > 0:
            slow = rng.random(self.m) < self.straggle_prob
            t = np.where(slow, t * self.straggle_factor, t)
        return t

    def comm_jitter(self, r: int) -> float:
        """Multiplicative network jitter on round r's collective."""
        if self.jitter_std <= 0:
            return 1.0
        # dedicated substream offset so compute draws stay unchanged when
        # jitter is toggled on
        return float(np.random.default_rng([self.seed, r, 1]).lognormal(0.0, self.jitter_std))

    # -- the per-round schedule ---------------------------------------------

    def crashed_at(self, r: int) -> np.ndarray:
        """(m,) bool: dead inside a crash window at round r."""
        dead = np.zeros(self.m, bool)
        for w, r_crash, r_rejoin in self.crashes:
            if r_crash <= r and (r_rejoin is None or r < r_rejoin):
                dead[w] = True
        return dead

    def deadline_missed(self, r: int) -> np.ndarray:
        """(m,) bool: live workers whose simulated compute blew the deadline."""
        missed = self.round_compute_factors(r) > self.deadline_factor
        missed &= ~self.crashed_at(r)
        return missed

    def mask_at(self, r: int) -> np.ndarray:
        """(m,) bool liveness mask for round r (crashes ∧ deadline misses).

        Crash windows are authoritative: a crashed worker is dead, full stop.
        If every *non-crashed* worker blew its deadline, the fastest of them
        is kept (excluding all of them would turn a straggler blip into a
        lost round). A round where every worker is inside a crash window
        returns the all-False mask — that round has no boundary: the live
        path (``Membership.from_mask``) refuses to build it host-side, and
        the runtime model skips the collective and counts the round in
        ``RuntimeResult.skipped_rounds``."""
        live = ~(self.crashed_at(r) | self.deadline_missed(r))
        if not live.any():
            not_crashed = ~self.crashed_at(r)
            if not_crashed.any():
                candidates = np.nonzero(not_crashed)[0]
                live[candidates[np.argmin(self.round_compute_factors(r)[candidates])]] = True
        return live

    def resync_at(self, r: int) -> np.ndarray:
        """(m,) bool: workers rejoining at round r — excluded at r−1 (or
        crashed before round 0) and live at r. Their plane slices must be
        re-synced from the anchor before the round runs."""
        if r == 0:
            return np.zeros(self.m, bool)
        return self.mask_at(r) & ~self.mask_at(r - 1)

    # -- JSON faces ----------------------------------------------------------

    def events(self) -> dict:
        return dict(
            m=self.m,
            seed=self.seed,
            crashes=[dict(worker=w, crash_round=c, rejoin_round=j) for w, c, j in self.crashes],
            slowdown=[dict(worker=w, factor=f) for w, f in self.slowdown],
            straggle_std=self.straggle_std,
            straggle_prob=self.straggle_prob,
            straggle_factor=self.straggle_factor,
            jitter_std=self.jitter_std,
            deadline_factor=self.deadline_factor,
        )

    def degraded_rounds(self, rounds: int) -> dict:
        """The dry-run's ``degraded_rounds`` JSON block: the fault events plus
        the resolved membership schedule over ``rounds`` rounds (only rounds
        where the mask departs from fully-live, plus every re-sync)."""
        schedule: List[dict] = []
        for r in range(rounds):
            mask = self.mask_at(r)
            resync = self.resync_at(r)
            if mask.all() and not resync.any():
                continue
            schedule.append(
                dict(
                    round=r,
                    live=int(mask.sum()),
                    excluded=[int(i) for i in np.nonzero(~mask)[0]],
                    crashed=[int(i) for i in np.nonzero(self.crashed_at(r))[0]],
                    missed_deadline=[int(i) for i in np.nonzero(self.deadline_missed(r))[0]],
                    resynced=[int(i) for i in np.nonzero(resync)[0]],
                )
            )
        return dict(events=self.events(), rounds=rounds, degraded=len(schedule), schedule=schedule)

    def runtime_config(self, base=None):
        """A :class:`repro.core.runtime_model.RuntimeConfig` matched to this
        plan: worker count and seed from the plan, the cfg's own straggler
        knobs zeroed — when ``simulate(..., fault_plan=self)`` runs, the
        plan's per-round factors are the straggler model, and leaving the
        cfg knobs on would double-count the noise. ``base`` supplies the
        hardware constants (e.g. :func:`~repro.core.runtime_model.calibrated_config`
        output)."""
        from dataclasses import replace

        from repro.core.runtime_model import RuntimeConfig

        cfg = base if base is not None else RuntimeConfig()
        return replace(cfg, m=self.m, seed=self.seed, straggle_std=0.0, straggle_prob=0.0)

    def fault_reason(self, r: int) -> Optional[str]:
        """Compact per-round label for controller telemetry (None = clean)."""
        parts = []
        if self.crashed_at(r).any():
            parts.append("crash")
        if self.deadline_missed(r).any():
            parts.append("deadline")
        if self.resync_at(r).any():
            parts.append("rejoin")
        return "+".join(parts) or None

    # -- parsing --------------------------------------------------------------

    @classmethod
    def parse(cls, spec: str, m: int, seed: int = 0, **kw) -> "FaultPlan":
        """Parse the CLI/CI spec grammar, comma-separated:

            crash:W@R       worker W crashes at round R (no rejoin)
            crash:W@R-S     … and rejoins at round S
            slow:WxF        worker W runs Fx slower, persistently
            std:S           lognormal sigma S on per-round compute
            prob:P@F        each round, slow by F with probability P
            jitter:S        lognormal sigma S on collective transit
            deadline:F      deadline at F× the nominal round time

        e.g. ``"crash:1@2-5,slow:2x4"`` — worker 1 dead for rounds 2–4,
        worker 2 a persistent 4× straggler.
        """
        crashes: List[Tuple[int, int, Optional[int]]] = []
        slowdown: List[Tuple[int, float]] = []
        fields: Dict[str, float] = {}
        for item in filter(None, (s.strip() for s in spec.split(","))):
            kind, _, rest = item.partition(":")
            if kind == "crash":
                w, _, rr = rest.partition("@")
                r0, _, r1 = rr.partition("-")
                crashes.append((int(w), int(r0), int(r1) if r1 else None))
            elif kind == "slow":
                w, _, f = rest.partition("x")
                slowdown.append((int(w), float(f)))
            elif kind == "std":
                fields["straggle_std"] = float(rest)
            elif kind == "prob":
                p, _, f = rest.partition("@")
                fields["straggle_prob"] = float(p)
                if f:
                    fields["straggle_factor"] = float(f)
            elif kind == "jitter":
                fields["jitter_std"] = float(rest)
            elif kind == "deadline":
                fields["deadline_factor"] = float(rest)
            else:
                raise ValueError(f"unknown fault spec item {item!r}")
        fields.update(kw)
        return cls(m=m, seed=seed, crashes=tuple(crashes), slowdown=tuple(slowdown), **fields)
