"""Host-side fault injection around the jitted round (DESIGN.md §7).

The harness sits *between* rounds, where state is concrete: before round r
it (1) re-syncs the plane slices of workers rejoining at r from the anchor
— the paper's anchor-as-recovery-point story: the anchor z is exactly the
consensus model a recovered worker should resume from — and (2) installs
the round's :class:`~repro.fault.membership.Membership` into
``TrainState.membership`` so the jitted boundary runs masked. Strategy code
is never touched: strategies only ever see the membership kwarg their
boundary hooks already accept.

Fully-live rounds install ``membership=None`` (not a full mask), so clean
rounds execute the exact baseline program — bitwise pins and jaxpr budgets
untouched — and only degraded rounds pay the masked trace.
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.fault.membership import Membership, from_mask
from repro.fault.plan import FaultPlan
from repro.parallel import offload as off
from repro.parallel.packing import Packed, buffer_map


def _anchor_of(state) -> Optional[Any]:
    """The recovery point: the unstacked model a rejoining worker resumes
    from. Preference order: the inflight collective (the freshest anchor —
    unwrap the ``avg`` slot of avg-rebase inflights; collapse a gossip
    inflight's per-worker mixes into the debiased mass-weighted consensus
    Σ_i mix_i / Σ_i w_i), then the strategy's anchor variable z. ``None``
    means the strategy carries no anchor (local_sgd, sync_sgd): the caller
    falls back to the live-worker mean."""
    infl = state.inflight
    if infl is not None and off.is_offloaded(infl):
        # offloaded runs keep anchor-shaped slots host-resident between
        # rounds (DESIGN.md §9); re-sync only reads the anchor, so bring a
        # resident view back without touching the state's own planes
        infl = off.tree_restore(infl)
    if infl is not None:
        mix = getattr(infl, "mix", None)
        w = getattr(infl, "w", None)
        if mix is not None and w is not None:
            # gossip push-sum: each row of mix is a push-weighted partial
            # sum, so the row-sum over total push mass is the exact
            # consensus model regardless of topology sparsity
            wsum = jnp.sum(w.astype(jnp.float32))
            if isinstance(mix, Packed):
                return buffer_map(
                    lambda b: (jnp.sum(b.astype(jnp.float32), axis=0) / wsum).astype(b.dtype), mix
                )
            return jax.tree.map(
                lambda t: (jnp.sum(t.astype(jnp.float32), axis=0) / wsum).astype(t.dtype), mix
            )
        return getattr(infl, "avg", infl)
    z = getattr(state.vars, "z", None)
    if z is not None:
        return off.tree_restore(z) if off.is_offloaded(z) else z
    return None


def resync_from_anchor(state, resync_mask):
    """Overwrite the plane slices of workers flagged in ``resync_mask``
    ((m,) bool) with the anchor model; all other rows pass through.

    Only x is re-synced: the rejoining worker's local optimizer state
    (momentum/Adam moments) is left as-is — stale but structurally valid,
    matching a real recovery where optimizer state restarts from whatever
    the checkpoint held. Strategy vars are untouched (they are anchor-shaped,
    not per-worker).
    """
    mask = jnp.asarray(np.asarray(resync_mask), bool)
    anchor = _anchor_of(state)
    x = state.x
    if isinstance(x, Packed):
        if anchor is None:
            # no anchor state: recover onto the mean of the workers that
            # were NOT excluded (the live consensus)
            w = (~mask).astype(jnp.float32)
            w = w / jnp.sum(w)
            anchor = buffer_map(
                lambda b: jnp.sum(b.astype(jnp.float32) * w[:, None], axis=0).astype(b.dtype), x
            )
        x_new = buffer_map(
            lambda b, a: jnp.where(mask[:, None], a[None].astype(b.dtype), b), x, anchor, layout=x.layout
        )
    else:
        if anchor is None:
            w = (~mask).astype(jnp.float32)
            w = w / jnp.sum(w)

            def live_mean(t):
                wb = w.reshape((-1,) + (1,) * (t.ndim - 1))
                return jnp.sum(t.astype(jnp.float32) * wb, axis=0).astype(t.dtype)

            anchor = jax.tree.map(live_mean, x)

        def one(t, a):
            mb = mask.reshape((-1,) + (1,) * (t.ndim - 1))
            return jnp.where(mb, a[None].astype(t.dtype), t)

        x_new = jax.tree.map(one, x, anchor)
    return state._replace(x=x_new)


class FaultHarness:
    """Replays a :class:`FaultPlan` against a training run, round by round.

    Usage (what ``Experiment._fit_faulted`` does):

        harness = FaultHarness(plan)
        for r in range(rounds):
            state = harness.before_round(state, r)
            state, metrics = round_step(state, batches)

    ``records`` accumulates one dict per degraded round (mirror of the
    dry-run's ``degraded_rounds`` schedule) for post-hoc inspection.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.records: List[dict] = []

    def membership_at(self, r: int) -> Optional[Membership]:
        """The round's membership — ``None`` (baseline fast path) when
        everyone is live, a renormalized :class:`Membership` otherwise."""
        mask = self.plan.mask_at(r)
        if mask.all():
            return None
        return from_mask(mask.astype(np.float32))

    def before_round(self, state, r: int):
        """Apply round r's faults to concrete host-side state: re-sync
        rejoining workers from the anchor, then install the membership."""
        resync = self.plan.resync_at(r)
        if resync.any():
            state = resync_from_anchor(state, resync)
        mem = self.membership_at(r)
        if mem is not None or resync.any():
            mask = self.plan.mask_at(r)
            self.records.append(
                dict(
                    round=r,
                    live=int(mask.sum()),
                    excluded=[int(i) for i in np.nonzero(~mask)[0]],
                    resynced=[int(i) for i in np.nonzero(resync)[0]],
                    reason=self.plan.fault_reason(r),
                )
            )
        return state._replace(membership=mem)

    def fault_reason(self, r: int) -> Optional[str]:
        """Per-round label for TauController telemetry (None = clean)."""
        return self.plan.fault_reason(r)
