from repro.parallel.sharding import (
    LOGICAL_RULES,
    anchor_axes,
    constrain,
    current_mesh,
    logical_mesh,
    mesh_context,
    sharding_for,
    spec_for,
    tree_shardings,
)

__all__ = [
    "LOGICAL_RULES",
    "anchor_axes",
    "constrain",
    "current_mesh",
    "logical_mesh",
    "mesh_context",
    "sharding_for",
    "spec_for",
    "tree_shardings",
]
