"""Packed parameter plane: one flat buffer per dtype, static layout table.

The round boundary (paper eqs. 4–5) is a pure memory-bound sweep over the
parameters, yet a pytree-shaped boundary pays one op *per leaf*: per-leaf
means, per-leaf sharding constraints, and a separate padded kernel launch
per leaf in the pullback. This module collapses the plane the boundary
sweeps over into one (or a few, dtype-bucketed) contiguous 128-lane-aligned
flat buffers with a *static* layout table, so the whole boundary becomes one
collective plus one kernel launch regardless of how many tensors the model
has.

Local-step dispatch model (plane-resident training)
---------------------------------------------------
The plane is the *canonical* training representation end-to-end:
``TrainState.x`` stores the worker-stacked :class:`Packed` plane across
rounds, the round engine's scan carries it, and the loss is differentiated
**with the plane buffers as the primal argument** — the model reads
parameters through a :class:`ParamView` (lazy ``view_leaf`` windows), so
gradients arrive as one flat cotangent buffer per dtype bucket with no
``pack(grads)`` scatter chain anywhere in the step. Per local step the work
is, for a model with L leaves and B dtype buckets (B is 1–2 in practice, L
is hundreds):

    =====================  ==============  ===========================
    per local step          per-leaf path   packed path
    =====================  ==============  ===========================
    optimizer update        ~5·L ops        B fused kernel launches
    sync-SGD all-reduce     L means         B means
    PowerSGD elementwise    ~3·L ops        B sweeps (+ inherently
                                            per-leaf factor math and
                                            uncompressed-leaf means)
    DaSGD mid-round rebase  L lerps         B sweeps
    layout ops              0               window reads (slices fused
                                            into leaf consumers) +
                                            their pad transposes on the
                                            backward; zero DUS
    =====================  ==============  ===========================

Optimizer state (SGD momentum, AdamW f32 moments) lives as flat buffers in
``TrainState.opt`` between boundaries — ``pack``/``unpack`` never touch it
mid-round — and round boundaries consume and return the plane itself (no
pack/unpack seam at round granularity either). The fused update kernels are
in ``repro.kernels.opt_step``; the per-leaf optimizer remains the bit-exact
oracle (``AlgoConfig.packed`` off), pinned by tests/test_packed_optim.py.

Layout rules
------------
* Leaves are bucketed by dtype (buckets ordered by dtype name) — mixing
  dtypes in one buffer would force upcasts; bucketing keeps every boundary
  op at its native width.
* Within a bucket, leaves keep their ``jax.tree`` flatten order. Each leaf
  occupies ``stride = ceil(size / 128) * 128`` elements starting at a
  128-aligned ``offset``; the tail padding is written as zeros by ``pack``
  and never read back by ``unpack``. Every leaf therefore starts on a TPU
  lane boundary and a buffer slice is directly kernel-feedable.
* The table (:class:`Layout`) is built from shapes/dtypes only — it works
  identically on concrete arrays and ``ShapeDtypeStruct`` stand-ins, is
  hashable, and rides as pytree aux data, so a :class:`Packed` value can be
  a ``jit``/``scan``/``eval_shape`` carry.

``pack``/``unpack`` are pure layout changes (XLA fuses the pads into one
concatenate per bucket); all boundary *math* then runs on the buffers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

LANE = 128  # TPU lane width: every leaf segment is padded to this boundary


def _round_up(n: int, mult: int = LANE) -> int:
    return ((n + mult - 1) // mult) * mult


def _prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Static placement of one pytree leaf inside its dtype bucket."""

    index: int  # position in jax.tree flatten order (across all buckets)
    bucket: int  # which dtype bucket the leaf lives in
    shape: Tuple[int, ...]  # leaf shape (without any stacked lead dims)
    dtype: str  # canonical dtype name
    offset: int  # element offset of the leaf inside its bucket buffer
    size: int  # number of real elements
    stride: int  # padded extent (size rounded up to the lane boundary)


@dataclasses.dataclass(frozen=True)
class Layout:
    """Static layout table: where every leaf of a pytree lives in the packed
    plane. Hashable (usable as jit-static / pytree aux data)."""

    treedef: Any  # jax PyTreeDef of the packed tree
    slots: Tuple[LeafSlot, ...]  # one per leaf, in flatten order
    bucket_dtypes: Tuple[str, ...]  # dtype name per bucket (sorted)
    bucket_sizes: Tuple[int, ...]  # padded total elements per bucket

    @property
    def num_buckets(self) -> int:
        return len(self.bucket_dtypes)

    @property
    def num_leaves(self) -> int:
        return len(self.slots)

    def total_elements(self) -> int:
        return sum(self.bucket_sizes)

    def with_dtype(self, dtype) -> "Layout":
        """Same bucketing/offsets, every slot retagged to ``dtype`` — used
        for f32 shadows (error feedback) that must stay element-aligned with
        the param-dtype plane."""
        name = jnp.dtype(dtype).name
        slots = tuple(dataclasses.replace(s, dtype=name) for s in self.slots)
        return Layout(
            treedef=self.treedef,
            slots=slots,
            bucket_dtypes=tuple(name for _ in self.bucket_dtypes),
            bucket_sizes=self.bucket_sizes,
        )


@jax.tree_util.register_pytree_node_class
class Packed:
    """A pytree flattened into per-dtype flat buffers + its static layout.

    ``buffers[b]`` has shape ``lead + (layout.bucket_sizes[b],)`` where
    ``lead`` is any stacked prefix (e.g. the worker axis m). Registered as a
    pytree whose children are the buffers and whose aux data is the layout,
    so it carries through jit/scan/vmap/eval_shape unchanged.
    """

    __slots__ = ("buffers", "layout")

    def __init__(self, buffers: Tuple[Any, ...], layout: Layout):
        self.buffers = tuple(buffers)
        self.layout = layout

    def tree_flatten(self):
        return self.buffers, self.layout

    @classmethod
    def tree_unflatten(cls, layout, buffers):
        return cls(tuple(buffers), layout)

    @property
    def lead_shape(self) -> Tuple[int, ...]:
        return tuple(self.buffers[0].shape[:-1]) if self.buffers else ()

    @property
    def nbytes(self) -> int:
        """Total plane bytes (padding lanes and any stacked lead dims
        included). Shape/dtype arithmetic only, so it works on concrete
        arrays and ``ShapeDtypeStruct`` stand-ins alike — the dry-run
        records it for AOT specs."""
        return sum(
            _prod(b.shape) * jnp.dtype(d).itemsize
            for b, d in zip(self.buffers, self.layout.bucket_dtypes)
        )

    def __repr__(self):
        shapes = ", ".join(f"{b.shape}:{self.layout.bucket_dtypes[i]}" for i, b in enumerate(self.buffers))
        return f"Packed([{shapes}], {self.layout.num_leaves} leaves)"


def layout_of(tree, lead: int = 0) -> Layout:
    """Build the static layout table for ``tree``. ``lead`` leading dims of
    every leaf (e.g. the stacked worker axis) are excluded from the layout —
    they become the buffers' lead shape at ``pack`` time."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [tuple(int(s) for s in l.shape[lead:]) for l in leaves]
    dtypes = [jnp.dtype(l.dtype).name for l in leaves]
    bucket_dtypes = tuple(sorted(set(dtypes)))
    bucket_index = {d: i for i, d in enumerate(bucket_dtypes)}
    offsets = [0] * len(bucket_dtypes)
    slots = []
    for i, (shape, dname) in enumerate(zip(shapes, dtypes)):
        b = bucket_index[dname]
        size = _prod(shape)
        stride = _round_up(max(size, 1))
        slots.append(
            LeafSlot(index=i, bucket=b, shape=shape, dtype=dname, offset=offsets[b], size=size, stride=stride)
        )
        offsets[b] += stride
    return Layout(
        treedef=treedef,
        slots=tuple(slots),
        bucket_dtypes=bucket_dtypes,
        bucket_sizes=tuple(offsets),
    )


def pack(tree, layout: Optional[Layout] = None, lead: int = 0) -> Packed:
    """Flatten ``tree`` into the packed plane (one buffer per dtype bucket).

    The first ``lead`` dims of every leaf are carried through as the
    buffers' lead shape (all leaves must agree on them). Padding lanes are
    zero-filled.

    The plane is built by static-offset ``dynamic_update_slice`` into a
    zeros buffer rather than ``jnp.concatenate``: XLA fuses the chain into
    one write either way, padding comes for free — and, load-bearing on
    jax 0.4.x meshes, the SPMD partitioner miscompiles partially-sharded
    values downstream of a flat concatenate (partial sums across replicated
    mesh axes are double-counted) while the update-slice chain partitions
    correctly. Pinned by the packed mesh golden test in
    tests/test_dryrun_small.py.
    """
    if layout is None:
        layout = layout_of(tree, lead=lead)
    leaves = jax.tree_util.tree_leaves(tree)
    lead_shape = tuple(leaves[0].shape[:lead]) if (leaves and lead) else ()
    # offsets are dynamic_update_slice start indices: int32 unless the plane
    # outgrows it (>2^31 elements in one dtype bucket — int64 needs x64 mode)
    int32_max = jnp.iinfo(jnp.int32).max
    if max(layout.bucket_sizes, default=0) > int32_max and not jax.config.jax_enable_x64:
        raise ValueError(
            f"packed plane bucket of {max(layout.bucket_sizes)} elements exceeds the "
            "int32 index range; enable jax_enable_x64 or run with packed=False"
        )
    idx_dtype = jnp.int64 if max(layout.bucket_sizes, default=0) > int32_max else jnp.int32
    zero_idx = (jnp.zeros((), idx_dtype),) * len(lead_shape)
    buffers = [
        jnp.zeros(lead_shape + (n,), jnp.dtype(d))
        for n, d in zip(layout.bucket_sizes, layout.bucket_dtypes)
    ]
    for slot, leaf in zip(layout.slots, leaves):
        flat = jnp.reshape(leaf, lead_shape + (slot.size,))
        buffers[slot.bucket] = jax.lax.dynamic_update_slice(
            buffers[slot.bucket], flat, zero_idx + (jnp.asarray(slot.offset, idx_dtype),)
        )
    return Packed(tuple(buffers), layout)


def unpack(packed: Packed):
    """Inverse of :func:`pack`: rebuild the pytree (padding lanes dropped)."""
    layout = packed.layout
    lead_shape = packed.lead_shape
    axis = len(lead_shape)
    leaves = []
    for slot in layout.slots:
        buf = packed.buffers[slot.bucket]
        seg = jax.lax.slice_in_dim(buf, slot.offset, slot.offset + slot.size, axis=axis)
        leaves.append(jnp.reshape(seg, lead_shape + slot.shape))
    return jax.tree_util.tree_unflatten(layout.treedef, leaves)


def view_leaf(packed: Packed, index: int):
    """Cheap view of one leaf (by flatten-order index) without a full unpack."""
    slot = packed.layout.slots[index]
    buf = packed.buffers[slot.bucket]
    axis = len(packed.lead_shape)
    seg = jax.lax.slice_in_dim(buf, slot.offset, slot.offset + slot.size, axis=axis)
    return jnp.reshape(seg, packed.lead_shape + slot.shape)


def packed_like(packed: Packed, fill=0.0, dtype=None) -> Packed:
    """A packed plane with the same layout, filled with ``fill`` (optionally
    retagged to ``dtype`` — see :meth:`Layout.with_dtype`)."""
    layout = packed.layout if dtype is None else packed.layout.with_dtype(dtype)
    lead = packed.lead_shape
    buffers = tuple(
        jnp.full(lead + (n,), fill, jnp.dtype(d))
        for n, d in zip(layout.bucket_sizes, layout.bucket_dtypes)
    )
    return Packed(buffers, layout)


def buffer_map(fn, *packeds: Packed, layout: Optional[Layout] = None) -> Packed:
    """Apply ``fn`` buffer-wise across packed planes (all must share bucket
    structure element-for-element — e.g. a plane and its f32 shadow). The
    result takes ``layout`` (default: the first plane's)."""
    first = packeds[0]
    out = tuple(fn(*bufs) for bufs in zip(*(p.buffers for p in packeds)))
    return Packed(out, layout or first.layout)


def leaf_segments(layout: Layout, bucket: int) -> Tuple[LeafSlot, ...]:
    """The slots living in ``bucket``, in offset order — the per-leaf walk
    for the rare boundary ops that are inherently per-leaf (top-k quantile
    thresholds), while the sweeps stay packed."""
    return tuple(s for s in layout.slots if s.bucket == bucket)


def read_windows(packed: Packed, indices: Tuple[int, ...]):
    """Materialize the leaves at ``indices`` (static slot indices) as views
    of the plane — the differentiable read :class:`ParamView` routes every
    access through.

    Forward: plain :func:`view_leaf` slices (XLA fuses them into the leaf
    consumers). Backward (the custom part): the leaf cotangents are
    scattered straight onto zeroed plane buffers with the same
    static-offset ``dynamic_update_slice`` chain :func:`pack` uses — the
    transpose of a window read *is* a pack, emitted once here by the
    packing layer instead of as a separate post-grad step in the engine.
    The custom rule is load-bearing twice over: JAX's default transpose of
    N slices is N full-plane ``pad`` + ``add`` ops, O(leaves · plane) work
    measured 7–50× slower than this chain at production leaf counts; and
    the natural O(plane) alternative (one zero-gap ``concatenate`` per
    bucket) both lowers poorly on CPU XLA (per-operand overhead, measured
    ~20× slower than the DUS chain) and walks into the jax-0.4.x SPMD
    partially-sharded-concat miscompile the pack docstring pins. Leaf
    cotangent *values* are placed verbatim with zero padding, so the
    gradient plane is bitwise identical to packing the gradient pytree.
    """
    return _read_windows(tuple(indices), packed.layout, packed.lead_shape, packed.buffers)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _read_windows(indices, layout: Layout, lead_shape, buffers):
    packed = Packed(buffers, layout)
    return tuple(view_leaf(packed, i) for i in indices)


def _read_windows_fwd(indices, layout, lead_shape, buffers):
    return _read_windows(indices, layout, lead_shape, buffers), None


def _read_windows_bwd(indices, layout, lead_shape, _res, cts):
    lead_shape = tuple(lead_shape)
    # mirror pack()'s index-dtype choice: int32 until a bucket outgrows it
    int32_max = jnp.iinfo(jnp.int32).max
    idx_dtype = jnp.int64 if max(layout.bucket_sizes, default=0) > int32_max else jnp.int32
    zero_idx = (jnp.zeros((), idx_dtype),) * len(lead_shape)
    bufs = [
        jnp.zeros(lead_shape + (n,), jnp.dtype(d))
        for n, d in zip(layout.bucket_sizes, layout.bucket_dtypes)
    ]
    for i, ct in zip(indices, cts):
        slot = layout.slots[i]
        flat = jnp.reshape(ct, lead_shape + (slot.size,)).astype(bufs[slot.bucket].dtype)
        bufs[slot.bucket] = jax.lax.dynamic_update_slice(
            bufs[slot.bucket], flat, zero_idx + (jnp.asarray(slot.offset, idx_dtype),)
        )
    return (tuple(bufs),)


_read_windows.defvjp(_read_windows_fwd, _read_windows_bwd)


@jax.tree_util.register_pytree_node_class
class ParamView:
    """Lazy, dict-like, path-keyed view of a :class:`Packed` plane.

    Model code consumes parameters through ``view[key]`` / ``view.get`` /
    ``key in view`` (nested or ``"a/b/c"`` slash paths) exactly as it would
    a nested param dict, without ever importing :class:`Layout`: a leaf
    access materializes one :func:`view_leaf` window (a static slice XLA
    fuses into the consumer), a subtree access returns a nested view.
    Because the windows are slices of the plane buffers, differentiating a
    loss written against the view **with the plane as the primal** yields
    flat per-bucket cotangents directly — the gradient never exists as a
    pytree, so there is no per-leaf ``pack(grads)`` scatter chain.

    Registered as a pytree (flattening materializes the subtree's windows
    in layout order), so a view works as ``lax.scan`` xs: a stacked-layer
    subtree (leaves with a leading layer dim, the transformer's
    scan-over-blocks body) flattens to its ``(n, ...)`` windows, the scan
    slices them per iteration and rebuilds a *concrete* view (backed by the
    sliced arrays rather than the plane) with identical access semantics.
    """

    __slots__ = ("_packed", "_node", "_path")

    def __init__(self, packed: Optional[Packed] = None, _node=None, _path: str = ""):
        if _node is None:
            if packed is None:
                raise ValueError("ParamView needs a Packed plane (or an explicit node)")
            # lazy mode: the node tree holds leaf *indices* into the layout
            _node = jax.tree_util.tree_unflatten(
                packed.layout.treedef, list(range(packed.layout.num_leaves))
            )
        self._packed = packed
        self._node = _node
        self._path = _path

    # -- dict protocol ------------------------------------------------------
    def _leaf(self, node):
        if self._packed is not None:  # lazy: node is a slot index
            return read_windows(self._packed, (node,))[0]
        return node  # concrete: node is the materialized array

    def __getitem__(self, key):
        node, path = self._node, self._path
        for part in str(key).split("/"):
            if not (isinstance(node, dict) and part in node):
                raise KeyError(f"{path + '/' + part if path else part}")
            node = node[part]
            path = f"{path}/{part}" if path else part
        if isinstance(node, dict):
            return ParamView(self._packed, _node=node, _path=path)
        return self._leaf(node)

    def get(self, key, default=None):
        try:
            return self[key]
        except KeyError:
            return default

    def __contains__(self, key) -> bool:
        node = self._node
        for part in str(key).split("/"):
            if not (isinstance(node, dict) and part in node):
                return False
            node = node[part]
        return True

    def keys(self):
        return self._node.keys()

    def __iter__(self):
        return iter(self._node)

    def __len__(self) -> int:
        return len(self._node)

    def items(self):
        return ((k, self[k]) for k in self._node)

    def __repr__(self):
        mode = "plane" if self._packed is not None else "concrete"
        return f"ParamView({mode}, path={self._path or '/'!r}, keys={sorted(self._node)})"

    def materialize(self) -> "ParamView":
        """Concrete view of this subtree: every window read through ONE
        :func:`read_windows` site. The round engine materializes the
        worker-stacked view *before* vmapping the per-worker loss so the
        read's DUS-chain transpose sees the worker axis as a plain lead
        dim — under vmap the DUS batching rule degrades to select/iota
        masked writes (measured ~2× slower end-to-end).

        Differentiated code that touches many leaves should go through this
        (or through any whole-subtree flatten, e.g. ``lax.scan`` xs): each
        *lazy* leaf access is its own ``read_windows`` site, and every site
        contributes a full-plane cotangent that JAX then sums — fine for
        the handful of top-level reads a model makes (embeddings, norms,
        head), O(accesses · plane) if a training loss reads hundreds of
        leaves one by one."""
        leaves, aux = self.tree_flatten()
        return ParamView.tree_unflatten(aux, leaves)

    # -- pytree protocol (scan xs / tree.map / checkpointing) ---------------
    def tree_flatten(self):
        nodes, subdef = jax.tree_util.tree_flatten(self._node)
        if self._packed is not None:
            # one read_windows site for the whole subtree: its backward
            # assembles the bucket cotangents in a single pass
            return list(read_windows(self._packed, tuple(nodes))), (subdef, self._path)
        return nodes, (subdef, self._path)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        subdef, path = aux
        return cls(None, _node=jax.tree_util.tree_unflatten(subdef, list(leaves)), _path=path)
