"""Packed parameter plane: one flat buffer per dtype, static layout table.

The round boundary (paper eqs. 4–5) is a pure memory-bound sweep over the
parameters, yet a pytree-shaped boundary pays one op *per leaf*: per-leaf
means, per-leaf sharding constraints, and a separate padded kernel launch
per leaf in the pullback. This module collapses the plane the boundary
sweeps over into one (or a few, dtype-bucketed) contiguous 128-lane-aligned
flat buffers with a *static* layout table, so the whole boundary becomes one
collective plus one kernel launch regardless of how many tensors the model
has.

Local-step dispatch model (PR 3)
--------------------------------
The plane covers the τ *local steps* of each round, not just the boundary.
The round engine carries the packed plane through its scan; per local step
the work is, for a model with L leaves and B dtype buckets (B is 1–2 in
practice, L is hundreds):

    =====================  ==============  ===========================
    per local step          per-leaf path   packed path
    =====================  ==============  ===========================
    optimizer update        ~5·L ops        B fused kernel launches
    sync-SGD all-reduce     L means         B means
    PowerSGD elementwise    ~3·L ops        B sweeps (+ inherently
                                            per-leaf factor math and
                                            uncompressed-leaf means)
    DaSGD mid-round rebase  L lerps         B sweeps
    layout ops              0               1 unpack (fused into the
                                            forward's leaf consumers)
                                            + 1 gradient pack
    =====================  ==============  ===========================

Optimizer state (SGD momentum, AdamW f32 moments) lives as flat buffers in
``TrainState.opt`` between boundaries — ``pack``/``unpack`` never touch it
mid-round. The fused update kernels are in ``repro.kernels.opt_step``; the
per-leaf optimizer remains the bit-exact oracle (``AlgoConfig.packed`` off),
pinned by tests/test_packed_optim.py.

Layout rules
------------
* Leaves are bucketed by dtype (buckets ordered by dtype name) — mixing
  dtypes in one buffer would force upcasts; bucketing keeps every boundary
  op at its native width.
* Within a bucket, leaves keep their ``jax.tree`` flatten order. Each leaf
  occupies ``stride = ceil(size / 128) * 128`` elements starting at a
  128-aligned ``offset``; the tail padding is written as zeros by ``pack``
  and never read back by ``unpack``. Every leaf therefore starts on a TPU
  lane boundary and a buffer slice is directly kernel-feedable.
* The table (:class:`Layout`) is built from shapes/dtypes only — it works
  identically on concrete arrays and ``ShapeDtypeStruct`` stand-ins, is
  hashable, and rides as pytree aux data, so a :class:`Packed` value can be
  a ``jit``/``scan``/``eval_shape`` carry.

``pack``/``unpack`` are pure layout changes (XLA fuses the pads into one
concatenate per bucket); all boundary *math* then runs on the buffers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

LANE = 128  # TPU lane width: every leaf segment is padded to this boundary


def _round_up(n: int, mult: int = LANE) -> int:
    return ((n + mult - 1) // mult) * mult


def _prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Static placement of one pytree leaf inside its dtype bucket."""

    index: int  # position in jax.tree flatten order (across all buckets)
    bucket: int  # which dtype bucket the leaf lives in
    shape: Tuple[int, ...]  # leaf shape (without any stacked lead dims)
    dtype: str  # canonical dtype name
    offset: int  # element offset of the leaf inside its bucket buffer
    size: int  # number of real elements
    stride: int  # padded extent (size rounded up to the lane boundary)


@dataclasses.dataclass(frozen=True)
class Layout:
    """Static layout table: where every leaf of a pytree lives in the packed
    plane. Hashable (usable as jit-static / pytree aux data)."""

    treedef: Any  # jax PyTreeDef of the packed tree
    slots: Tuple[LeafSlot, ...]  # one per leaf, in flatten order
    bucket_dtypes: Tuple[str, ...]  # dtype name per bucket (sorted)
    bucket_sizes: Tuple[int, ...]  # padded total elements per bucket

    @property
    def num_buckets(self) -> int:
        return len(self.bucket_dtypes)

    @property
    def num_leaves(self) -> int:
        return len(self.slots)

    def total_elements(self) -> int:
        return sum(self.bucket_sizes)

    def with_dtype(self, dtype) -> "Layout":
        """Same bucketing/offsets, every slot retagged to ``dtype`` — used
        for f32 shadows (error feedback) that must stay element-aligned with
        the param-dtype plane."""
        name = jnp.dtype(dtype).name
        slots = tuple(dataclasses.replace(s, dtype=name) for s in self.slots)
        return Layout(
            treedef=self.treedef,
            slots=slots,
            bucket_dtypes=tuple(name for _ in self.bucket_dtypes),
            bucket_sizes=self.bucket_sizes,
        )


@jax.tree_util.register_pytree_node_class
class Packed:
    """A pytree flattened into per-dtype flat buffers + its static layout.

    ``buffers[b]`` has shape ``lead + (layout.bucket_sizes[b],)`` where
    ``lead`` is any stacked prefix (e.g. the worker axis m). Registered as a
    pytree whose children are the buffers and whose aux data is the layout,
    so it carries through jit/scan/vmap/eval_shape unchanged.
    """

    __slots__ = ("buffers", "layout")

    def __init__(self, buffers: Tuple[Any, ...], layout: Layout):
        self.buffers = tuple(buffers)
        self.layout = layout

    def tree_flatten(self):
        return self.buffers, self.layout

    @classmethod
    def tree_unflatten(cls, layout, buffers):
        return cls(tuple(buffers), layout)

    @property
    def lead_shape(self) -> Tuple[int, ...]:
        return tuple(self.buffers[0].shape[:-1]) if self.buffers else ()

    def __repr__(self):
        shapes = ", ".join(f"{b.shape}:{self.layout.bucket_dtypes[i]}" for i, b in enumerate(self.buffers))
        return f"Packed([{shapes}], {self.layout.num_leaves} leaves)"


def layout_of(tree, lead: int = 0) -> Layout:
    """Build the static layout table for ``tree``. ``lead`` leading dims of
    every leaf (e.g. the stacked worker axis) are excluded from the layout —
    they become the buffers' lead shape at ``pack`` time."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [tuple(int(s) for s in l.shape[lead:]) for l in leaves]
    dtypes = [jnp.dtype(l.dtype).name for l in leaves]
    bucket_dtypes = tuple(sorted(set(dtypes)))
    bucket_index = {d: i for i, d in enumerate(bucket_dtypes)}
    offsets = [0] * len(bucket_dtypes)
    slots = []
    for i, (shape, dname) in enumerate(zip(shapes, dtypes)):
        b = bucket_index[dname]
        size = _prod(shape)
        stride = _round_up(max(size, 1))
        slots.append(
            LeafSlot(index=i, bucket=b, shape=shape, dtype=dname, offset=offsets[b], size=size, stride=stride)
        )
        offsets[b] += stride
    return Layout(
        treedef=treedef,
        slots=tuple(slots),
        bucket_dtypes=bucket_dtypes,
        bucket_sizes=tuple(offsets),
    )


def pack(tree, layout: Optional[Layout] = None, lead: int = 0) -> Packed:
    """Flatten ``tree`` into the packed plane (one buffer per dtype bucket).

    The first ``lead`` dims of every leaf are carried through as the
    buffers' lead shape (all leaves must agree on them). Padding lanes are
    zero-filled.

    The plane is built by static-offset ``dynamic_update_slice`` into a
    zeros buffer rather than ``jnp.concatenate``: XLA fuses the chain into
    one write either way, padding comes for free — and, load-bearing on
    jax 0.4.x meshes, the SPMD partitioner miscompiles partially-sharded
    values downstream of a flat concatenate (partial sums across replicated
    mesh axes are double-counted) while the update-slice chain partitions
    correctly. Pinned by the packed mesh golden test in
    tests/test_dryrun_small.py.
    """
    if layout is None:
        layout = layout_of(tree, lead=lead)
    leaves = jax.tree_util.tree_leaves(tree)
    lead_shape = tuple(leaves[0].shape[:lead]) if (leaves and lead) else ()
    # offsets are dynamic_update_slice start indices: int32 unless the plane
    # outgrows it (>2^31 elements in one dtype bucket — int64 needs x64 mode)
    int32_max = jnp.iinfo(jnp.int32).max
    if max(layout.bucket_sizes, default=0) > int32_max and not jax.config.jax_enable_x64:
        raise ValueError(
            f"packed plane bucket of {max(layout.bucket_sizes)} elements exceeds the "
            "int32 index range; enable jax_enable_x64 or run with packed=False"
        )
    idx_dtype = jnp.int64 if max(layout.bucket_sizes, default=0) > int32_max else jnp.int32
    zero_idx = (jnp.zeros((), idx_dtype),) * len(lead_shape)
    buffers = [
        jnp.zeros(lead_shape + (n,), jnp.dtype(d))
        for n, d in zip(layout.bucket_sizes, layout.bucket_dtypes)
    ]
    for slot, leaf in zip(layout.slots, leaves):
        flat = jnp.reshape(leaf, lead_shape + (slot.size,))
        buffers[slot.bucket] = jax.lax.dynamic_update_slice(
            buffers[slot.bucket], flat, zero_idx + (jnp.asarray(slot.offset, idx_dtype),)
        )
    return Packed(tuple(buffers), layout)


def unpack(packed: Packed):
    """Inverse of :func:`pack`: rebuild the pytree (padding lanes dropped)."""
    layout = packed.layout
    lead_shape = packed.lead_shape
    axis = len(lead_shape)
    leaves = []
    for slot in layout.slots:
        buf = packed.buffers[slot.bucket]
        seg = jax.lax.slice_in_dim(buf, slot.offset, slot.offset + slot.size, axis=axis)
        leaves.append(jnp.reshape(seg, lead_shape + slot.shape))
    return jax.tree_util.tree_unflatten(layout.treedef, leaves)


def view_leaf(packed: Packed, index: int):
    """Cheap view of one leaf (by flatten-order index) without a full unpack."""
    slot = packed.layout.slots[index]
    buf = packed.buffers[slot.bucket]
    axis = len(packed.lead_shape)
    seg = jax.lax.slice_in_dim(buf, slot.offset, slot.offset + slot.size, axis=axis)
    return jnp.reshape(seg, packed.lead_shape + slot.shape)


def packed_like(packed: Packed, fill=0.0, dtype=None) -> Packed:
    """A packed plane with the same layout, filled with ``fill`` (optionally
    retagged to ``dtype`` — see :meth:`Layout.with_dtype`)."""
    layout = packed.layout if dtype is None else packed.layout.with_dtype(dtype)
    lead = packed.lead_shape
    buffers = tuple(
        jnp.full(lead + (n,), fill, jnp.dtype(d))
        for n, d in zip(layout.bucket_sizes, layout.bucket_dtypes)
    )
    return Packed(buffers, layout)


def buffer_map(fn, *packeds: Packed, layout: Optional[Layout] = None) -> Packed:
    """Apply ``fn`` buffer-wise across packed planes (all must share bucket
    structure element-for-element — e.g. a plane and its f32 shadow). The
    result takes ``layout`` (default: the first plane's)."""
    first = packeds[0]
    out = tuple(fn(*bufs) for bufs in zip(*(p.buffers for p in packeds)))
    return Packed(out, layout or first.layout)


def leaf_segments(layout: Layout, bucket: int) -> Tuple[LeafSlot, ...]:
    """The slots living in ``bucket``, in offset order — the per-leaf walk
    for the rare boundary ops that are inherently per-leaf (top-k quantile
    thresholds), while the sweeps stay packed."""
    return tuple(s for s in layout.slots if s.bucket == bucket)
