"""Sharding rules: logical parameter/activation axes → mesh axes.

The production mesh (launch/mesh.py) has physical axes ("pod","data","model")
/ ("data","model") per the assignment. Architectures differ in how much
within-worker sharding they need, so each ``ParallelPlan`` derives a
*logical* mesh over the same devices with axes:

    worker  — Local-SGD worker groups (the paper's m); slowest axes, so on
              the multi-pod mesh the worker boundary is the pod boundary and
              anchor traffic rides the slow inter-pod links (the exact
              communication the paper hides).
    fsdp    — parameter/optimizer sharding within a worker (ZeRO-3 style).
    tensor  — tensor parallelism within a worker.

Model code never names mesh axes directly: parameters carry *logical* axis
names ("embed", "ff", "heads", ...) and activations are constrained through
:func:`constrain`. Both are resolved through the rule table below, and both
become no-ops when no mesh context is active (pure-CPU unit tests).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.5: explicit/auto axis types on Mesh
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x: Mesh has no axis_types — positional construction
    AxisType = None

from repro.config.base import ParallelPlan

# Logical axis -> logical mesh axes. ``None`` = replicated.
LOGICAL_RULES = {
    # parameter axes
    "worker": ("worker",),
    "embed": ("fsdp",),
    "embed_no_shard": (),
    "ff": ("tensor",),
    "heads": ("tensor",),
    "kv_heads": ("tensor",),
    "head_dim": (),
    "vocab": ("tensor",),
    "experts": ("fsdp",),
    "expert_ff": ("tensor",),
    "state": (),
    "conv": (),
    "lora": (),
    None: (),
    # activation axes
    "batch": ("fsdp",),
    "stacked_batch": ("worker", "fsdp"),  # serving: no worker axis, batch over all data axes
    "seq": (),
    "act_embed": (),
    "act_heads": ("tensor",),
    "act_kv_heads": ("tensor",),
    "act_ff": ("tensor",),
    "act_vocab": ("tensor",),
    "act_experts": ("fsdp",),
    "act_expert_ff": ("tensor",),
    "act_tokens": ("fsdp",),  # flattened (B·S) token dim in MoE dispatch
    # anchor model: identical across workers => additionally sharded over
    # the worker axis (ZeRO-3-style; see DESIGN.md §2).
    "anchor_embed": ("worker", "fsdp"),
    "anchor_experts": ("worker", "fsdp"),
    # packed parameter plane (repro.parallel.packing): flat 128-lane-aligned
    # buffers carry one logical axis instead of per-leaf axes. The per-worker
    # plane shards over fsdp; the anchor plane is identical across workers so
    # it shards over EVERY mesh axis (ZeRO-3 taken to its limit — each device
    # owns a disjoint 128-multiple slice of the plane, minimal memory and a
    # pure reduce-scatter boundary). Full sharding is also load-bearing on
    # jax 0.4.x: the SPMD partitioner miscompiles *partially* sharded
    # constraints downstream of the plane's concatenate (values multiply by
    # the product of the replicated axes — pinned by the packed mesh golden
    # test in tests/test_dryrun_small.py); fully-sharded and replicated
    # layouts have no replica bookkeeping to get wrong.
    "flat_param": ("fsdp",),
    "anchor_flat": ("worker", "fsdp", "tensor"),
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: dict = LOGICAL_RULES


_CTX = _Ctx()


def make_auto_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """``jax.make_mesh`` with Auto axis types where supported (jax ≥ 0.5);
    plain construction on jax 0.4.x, where all mesh axes are implicitly
    auto-sharded."""
    if AxisType is not None:
        return jax.make_mesh(tuple(shape), tuple(axes), axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def logical_mesh(production_mesh: Mesh, plan: ParallelPlan) -> Mesh:
    """Reshape the production mesh's devices into (worker, fsdp, tensor).

    The device order is preserved, so the worker axis occupies the slowest
    physical axes (pod, then data) — anchor collectives cross the slowest
    links, tensor-parallel collectives stay on the fastest.
    """
    devices = production_mesh.devices.reshape(-1)
    n = devices.size
    assert plan.num_devices == n, (plan, n)
    arr = devices.reshape(plan.workers, plan.fsdp, plan.tensor)
    if AxisType is not None:
        return Mesh(arr, ("worker", "fsdp", "tensor"), axis_types=(AxisType.Auto,) * 3)
    return Mesh(arr, ("worker", "fsdp", "tensor"))


def spec_for(axes: Sequence[Optional[str]], rules: Optional[dict] = None) -> P:
    rules = rules or _CTX.rules
    parts = []
    for ax in axes:
        mapped = rules[ax]
        if len(mapped) == 0:
            parts.append(None)
        elif len(mapped) == 1:
            parts.append(mapped[0])
        else:
            parts.append(tuple(mapped))
    return P(*parts)


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh], rules: Optional[dict] = None):
    prev_mesh, prev_rules = _CTX.mesh, _CTX.rules
    _CTX.mesh = mesh
    _CTX.rules = rules or LOGICAL_RULES
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev_mesh, prev_rules


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def fit_spec(spec: P, shape: Sequence[int], mesh: Mesh) -> P:
    """Drop sharding on dims the mesh axes don't divide (replicate instead).

    Safety net for awkward dims (e.g. 28 attention heads on tp=16): jit
    argument shardings require divisibility, so non-dividing assignments are
    demoted to replication rather than failing the lowering."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, part in zip(shape, parts):
        if part is None:
            out.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        prod = 1
        for a in axes:
            prod *= mesh.shape[a]
        out.append(part if dim % prod == 0 else None)
    return P(*out)


def constrain(x, axes: Sequence[Optional[str]]):
    """with_sharding_constraint through the logical rule table (no-op off-mesh)."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = fit_spec(spec_for(axes), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def sharding_for(axes: Sequence[Optional[str]], mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for(axes))


def tree_shardings(mesh: Mesh, axes_tree, prefix: Tuple[Optional[str], ...] = (), rules: Optional[dict] = None):
    """Map a tree of logical-axes tuples to NamedShardings (optionally
    prepending ``prefix`` axes, e.g. ("worker",) for stacked states)."""

    def one(axes):
        return NamedSharding(mesh, spec_for(tuple(prefix) + tuple(axes), rules))

    return jax.tree.map(one, axes_tree, is_leaf=lambda t: isinstance(t, tuple))


def anchor_axes(axes_tree):
    """Axes for the anchor model: same as params but the fsdp-sharded dim is
    additionally sharded over the worker axis (identical across workers)."""

    def one(axes):
        out = []
        for ax in axes:
            if ax == "embed":
                out.append("anchor_embed")
            elif ax == "experts":
                out.append("anchor_experts")
            else:
                out.append(ax)
        return tuple(out)

    return jax.tree.map(one, axes_tree, is_leaf=lambda t: isinstance(t, tuple))
