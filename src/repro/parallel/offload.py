"""Host offload of packed planes with double-buffered chunk streaming.

The paper hides the boundary collective behind the tau local steps; the
same window hides host<->device traffic.  Optimizer-state buckets
(``PackedSGDState``/``PackedAdamState``) and anchor/inflight buckets are
*host-resident between boundaries* as a :class:`HostPlane` — each flat
dtype bucket split into fixed-size chunks stacked along a leading axis —
and streamed back chunk-by-chunk exactly where they are consumed:

* opt state: per local step through :func:`streamed_update`, a
  ``lax.scan`` over chunks whose carry holds ONE staged device chunk per
  state plane while the body prefetches the next — the two in-flight
  device-side staging buffers.  The fused ``kernels/opt_step`` math runs
  per chunk (the ops accept any ``(..., n)`` buffer), so the update is
  bitwise-identical to the plane-resident path: chunking is a pad +
  reshape whose zero tail every optimizer maps to zero and the unchunk
  drops.
* anchor/inflight/vars: whole-plane :func:`restore_plane` before the
  window (the H2D copies have no data dependency on the local scan, so
  the scheduler overlaps them with the tau steps — the prefetch), and
  :func:`offload_plane` after the boundary consumes them (the D2H).

Chunk shapes are compile-time: :class:`OffloadPlan` is a static hashable
table derived from :class:`~repro.parallel.packing.Layout` bucket sizes,
lead-agnostic so one plan serves the worker-stacked ``(m, n)`` opt
buckets, the flat ``(n,)`` anchor, and f32 ``with_dtype`` shadows.

Memory-kind placement is advisory: on backends that expose a
``pinned_host`` memory space (TPU) every chunk hand-off is annotated
with ``jax.device_put(..., TransferToMemoryKind(...))`` (legal inside
jit on jax 0.4.x); on single-memory backends (CPU, where the only kind
is ``unpinned_host``) the stream is structural-only and the annotations
are skipped.  The program shape — and therefore the parity and staging
guarantees — is identical either way.  See DESIGN.md §9.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.packing import LANE, Layout, Packed, _round_up

try:  # jax 0.4.x keeps this in a private module; jax >= 0.5 re-exports it.
    from jax.sharding import TransferToMemoryKind  # type: ignore
except ImportError:  # pragma: no cover - version dependent
    try:
        from jax._src.sharding_impls import TransferToMemoryKind  # type: ignore
    except ImportError:  # pragma: no cover
        TransferToMemoryKind = None

HOST_KIND = "pinned_host"
DEFAULT_CHUNK_MB = 64.0


@functools.lru_cache(maxsize=None)
def host_memory_kind() -> Optional[str]:
    """``"pinned_host"`` when the default backend has a distinct host
    memory space, else ``None`` (single-memory backends: the stream is
    structural and placement annotations are skipped)."""
    if TransferToMemoryKind is None:
        return None
    try:
        kinds = {m.kind for m in jax.devices()[0].addressable_memories()}
    except Exception:  # pragma: no cover - backend without memories API
        return None
    return HOST_KIND if HOST_KIND in kinds else None


def _to_host(x):
    kind = host_memory_kind()
    return jax.device_put(x, TransferToMemoryKind(kind)) if kind else x


def _to_device(x):
    kind = host_memory_kind()
    return jax.device_put(x, TransferToMemoryKind("device")) if kind else x


# ---------------------------------------------------------------------------
# Static chunk table


@dataclasses.dataclass(frozen=True)
class OffloadPlan:
    """Per-bucket chunk grid, aligned with ``Layout.bucket_sizes``.

    ``chunk_elems[b]`` is a LANE multiple (so chunk slices hit the
    pad-free fast path of the opt kernels) and
    ``num_chunks[b] * chunk_elems[b] >= bucket_sizes[b]`` — the chunked
    form is zero-padded up to the grid and the tail is dropped on
    unchunk.  Hashable so it can ride in pytree aux data (scan carries,
    jit static args).
    """

    chunk_elems: Tuple[int, ...]
    num_chunks: Tuple[int, ...]

    @classmethod
    def for_layout(cls, layout: Layout, chunk_mb: float = DEFAULT_CHUNK_MB) -> "OffloadPlan":
        chunk_elems = []
        num_chunks = []
        for n, dt in zip(layout.bucket_sizes, layout.bucket_dtypes):
            itemsize = jnp.dtype(dt).itemsize
            c = int(chunk_mb * (1 << 20)) // itemsize
            c = max(LANE, (c // LANE) * LANE)
            c = min(c, _round_up(max(n, 1)))
            chunk_elems.append(c)
            num_chunks.append(-(-max(n, 1) // c))
        return cls(tuple(chunk_elems), tuple(num_chunks))

    def grid(self, bucket: int) -> Tuple[int, int]:
        """(num_chunks, chunk_elems) for one bucket."""
        return self.num_chunks[bucket], self.chunk_elems[bucket]


def chunk_buffer(buf: jax.Array, num_chunks: int, chunk_elems: int) -> jax.Array:
    """``lead + (n,)`` -> ``(num_chunks,) + lead + (chunk_elems,)``:
    zero-pad the flat axis to the chunk grid, split, move the chunk axis
    to the front.  Exact inverse of :func:`unchunk_buffer`."""
    lead, n = buf.shape[:-1], buf.shape[-1]
    padded = num_chunks * chunk_elems
    if padded != n:
        buf = jnp.pad(buf, [(0, 0)] * len(lead) + [(0, padded - n)])
    buf = buf.reshape(lead + (num_chunks, chunk_elems))
    return jnp.moveaxis(buf, -2, 0)


def unchunk_buffer(chunks: jax.Array, n: int) -> jax.Array:
    """Inverse of :func:`chunk_buffer`: drop the pad, restore the flat axis."""
    num_chunks, chunk_elems = chunks.shape[0], chunks.shape[-1]
    lead = chunks.shape[1:-1]
    buf = jnp.moveaxis(chunks, 0, -2).reshape(lead + (num_chunks * chunk_elems,))
    return buf[..., :n]


# ---------------------------------------------------------------------------
# HostPlane: the between-boundaries form of a Packed plane


@jax.tree_util.register_pytree_node_class
class HostPlane:
    """Chunked, host-resident form of a :class:`Packed` plane.

    Flattens to one chunk stack per bucket (same arity as ``Packed``)
    with ``(layout, plan)`` as static aux, so it slots into scan
    carries, eval_shape specs, and checkpointable pytrees wherever the
    resident plane did.
    """

    __slots__ = ("chunks", "layout", "plan")

    def __init__(self, chunks: Sequence[jax.Array], layout: Layout, plan: OffloadPlan):
        self.chunks = tuple(chunks)
        self.layout = layout
        self.plan = plan

    def tree_flatten(self):
        return self.chunks, (self.layout, self.plan)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(tuple(children), *aux)

    @property
    def nbytes(self) -> int:
        """Total chunked (padded) bytes — the host residency cost."""
        total = 0
        for ch in self.chunks:
            size = 1
            for d in ch.shape:
                size *= d
            total += size * ch.dtype.itemsize
        return total

    @property
    def lead_shape(self) -> Tuple[int, ...]:
        return tuple(self.chunks[0].shape[1:-1]) if self.chunks else ()

    def __repr__(self):  # pragma: no cover - debug aid
        grids = list(zip(self.plan.num_chunks, self.plan.chunk_elems))
        return f"HostPlane(lead={self.lead_shape}, grids={grids})"


def offload_plane(px: Packed, plan: OffloadPlan) -> HostPlane:
    """Chunk a resident plane and hand it to host memory (the D2H leg)."""
    chunks = tuple(
        _to_host(chunk_buffer(buf, plan.num_chunks[b], plan.chunk_elems[b]))
        for b, buf in enumerate(px.buffers)
    )
    return HostPlane(chunks, px.layout, plan)


def restore_plane(hp: HostPlane) -> Packed:
    """Bring a host plane back device-resident (the H2D leg)."""
    buffers = tuple(
        unchunk_buffer(_to_device(ch), hp.layout.bucket_sizes[b])
        for b, ch in enumerate(hp.chunks)
    )
    return Packed(buffers, hp.layout)


def _is_packed(x) -> bool:
    return isinstance(x, Packed)


def _is_host(x) -> bool:
    return isinstance(x, HostPlane)


def is_offloaded(tree) -> bool:
    """True when any leaf plane in ``tree`` is a :class:`HostPlane`."""
    found = False

    def visit(x):
        nonlocal found
        found = found or isinstance(x, HostPlane)
        return x

    jax.tree_util.tree_map(visit, tree, is_leaf=_is_host)
    return found


def tree_offload(tree, plan: OffloadPlan):
    """Offload every ``Packed`` plane in a state pytree (vars/inflight/
    opt state); non-plane leaves (scalars, masks) pass through."""
    return jax.tree_util.tree_map(
        lambda x: offload_plane(x, plan) if isinstance(x, Packed) else x,
        tree,
        is_leaf=_is_packed,
    )


def tree_restore(tree):
    """Restore every :class:`HostPlane` in a state pytree to a resident
    ``Packed`` plane; other leaves pass through."""
    return jax.tree_util.tree_map(
        lambda x: restore_plane(x) if isinstance(x, HostPlane) else x,
        tree,
        is_leaf=_is_host,
    )


def plan_of(tree) -> Optional[OffloadPlan]:
    """The :class:`OffloadPlan` carried by the first HostPlane in ``tree``."""
    plan = None

    def visit(x):
        nonlocal plan
        if plan is None and isinstance(x, HostPlane):
            plan = x.plan
        return x

    jax.tree_util.tree_map(visit, tree, is_leaf=_is_host)
    return plan


def host_nbytes(tree) -> int:
    """Total host-resident bytes across every HostPlane in ``tree``."""
    total = 0

    def visit(x):
        nonlocal total
        if isinstance(x, HostPlane):
            total += x.nbytes
        return x

    jax.tree_util.tree_map(visit, tree, is_leaf=_is_host)
    return total


# ---------------------------------------------------------------------------
# Double-buffered streamed optimizer update


def streamed_update(
    apply_chunk: Callable,
    state: Tuple[HostPlane, ...],
    px: Packed,
    pg: Packed,
) -> Tuple[Packed, Tuple[HostPlane, ...]]:
    """Run ``apply_chunk(x_c, g_c, *state_c) -> (x_c', *state_c')`` over
    the plane, streaming the host-resident state planes through two
    device staging buffers per bucket.

    Per bucket a ``lax.scan`` walks the chunk grid: the carry holds the
    *staged* device copy of chunk ``i`` of each state plane, the body
    prefetches chunk ``i+1`` (clamped at the last chunk so the epilogue
    fetch is a no-op re-fetch, keeping the carry shape fixed) while the
    fused opt kernel updates chunk ``i``, then sends the updated state
    chunk back to host.  staged + prefetch = the two in-flight staging
    buffers; the params/grad chunks are device-resident throughout.
    """
    if not state:
        raise ValueError("streamed_update needs at least one host state plane")
    plan = state[0].plan
    new_x = []
    new_state_chunks = [[] for _ in state]
    for b, (x_buf, g_buf) in enumerate(zip(px.buffers, pg.buffers)):
        num_chunks, chunk_elems = plan.grid(b)
        n = px.layout.bucket_sizes[b]
        xh = chunk_buffer(x_buf, num_chunks, chunk_elems)
        gh = chunk_buffer(g_buf, num_chunks, chunk_elems)
        stacks = tuple(hp.chunks[b] for hp in state)

        def fetch(i, stacks=stacks, num_chunks=num_chunks):
            j = jnp.minimum(i, num_chunks - 1)
            return tuple(
                _to_device(jax.lax.dynamic_index_in_dim(s, j, axis=0, keepdims=False))
                for s in stacks
            )

        def body(staged, xs, fetch=fetch):
            i, x_c, g_c = xs
            nxt = fetch(i + 1)  # prefetch: in flight while chunk i computes
            outs = apply_chunk(x_c, g_c, *staged)
            return nxt, (outs[0],) + tuple(_to_host(s) for s in outs[1:])

        idx = jnp.arange(num_chunks, dtype=jnp.int32)
        _, ys = jax.lax.scan(body, fetch(0), (idx, xh, gh))
        new_x.append(unchunk_buffer(ys[0], n))
        for k in range(len(state)):
            new_state_chunks[k].append(ys[1 + k])
    px_new = Packed(tuple(new_x), px.layout)
    state_new = tuple(
        HostPlane(tuple(new_state_chunks[k]), hp.layout, hp.plan)
        for k, hp in enumerate(state)
    )
    return px_new, state_new


# ---------------------------------------------------------------------------
# Stream accounting (shared by dryrun / costprobe / runtime model)


def stream_roundtrip_bytes(state_tree) -> int:
    """Bytes for ONE H2D + D2H round trip of every host plane in
    ``state_tree``.  Opt-state planes make ``tau`` trips per round (one
    per local step), anchor/inflight/vars one; callers apply the
    multiplier."""
    return 2 * host_nbytes(state_tree)


def staging_bytes(plan: OffloadPlan, layout: Layout, state_planes: int) -> int:
    """Device bytes pinned by the double buffer: 2 staging chunks per
    state plane per bucket (the scan carry + the in-body prefetch)."""
    total = 0
    for b, dt in enumerate(layout.bucket_dtypes):
        total += 2 * state_planes * plan.chunk_elems[b] * jnp.dtype(dt).itemsize
    return total
