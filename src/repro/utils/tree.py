"""Small pytree arithmetic helpers used throughout the optimizer/algorithm code.

These are deliberately dtype-preserving: all Local-SGD variants keep their
states in the parameter dtype and these helpers never upcast silently.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: (x * s).astype(x.dtype), a)


def tree_axpy(s, x, y):
    """y + s * x, elementwise over the tree, preserving y's dtypes."""
    return jax.tree.map(lambda xi, yi: (yi + s * xi).astype(yi.dtype), x, y)


def tree_lerp(a, b, alpha):
    """(1 - alpha) * a + alpha * b — the paper's pullback mixing, eq. (4)."""
    return jax.tree.map(
        lambda ai, bi: ((1.0 - alpha) * ai + alpha * bi).astype(ai.dtype), a, b
    )


def tree_dot(a, b):
    leaves = jax.tree.leaves(
        jax.tree.map(lambda x, y: jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32)), a, b)
    )
    return sum(leaves)


def tree_l2_norm(tree):
    return jnp.sqrt(tree_dot(tree, tree))
