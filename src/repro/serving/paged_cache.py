"""Paged KV-cache pools and the physical page allocator (DESIGN.md §10).

One pool per attention segment, with a leading layer axis so the existing
``lax.scan`` cache plumbing in ``apply_model`` slices a per-layer pool
exactly like a per-layer dense cache:

* GQA:  ``pool_k`` / ``pool_v``       — (n, num_pages, page_size, kv_heads, head_dim)
* MLA:  ``pool_ckv`` / ``pool_krope`` — (n, num_pages, page_size, rank)

The page table and lengths are *not* part of the cache pytree: they are
host-owned scheduler state (``serving/scheduler.py``) passed per step as a
:class:`PagedState`, shared by every layer. Physical page 0 is reserved as
the trash page — idle batch rows carry a zero table row + length 0 so their
discarded appends land there (see kernels/paged_attn/ref.py).
"""
from __future__ import annotations

import heapq
import math
from typing import Any, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig

TRASH_PAGE = 0


class PagedState(NamedTuple):
    """Per-step paged-attention operands (device-ready)."""

    page_tables: Any  # (S, max_pages) int32 — physical page per logical page
    lengths: Any  # (S,) int32 — tokens resident before this step's append


def paged_supported(cfg: ModelConfig) -> bool:
    """Paged serving covers pure attention-family text archs (GQA/MLA, full
    or sliding-window, MoE included). Recurrent/hybrid archs (mamba2, rwkv6,
    zamba2) keep the dense engine — their decode state is O(1) in sequence
    length, so there is nothing to page — as do modality frontends and
    mrope's multi-axis positions."""
    from repro.models.transformer import segments

    if cfg is None:  # guard-only engines (validation tests) stay dense
        return False
    if cfg.frontend is not None or cfg.attention is None:
        return False
    if cfg.attention.rope == "mrope":
        return False
    return all(kind in ("attn", "moe") for kind, _ in segments(cfg))


def pages_for(tokens: int, page_size: int) -> int:
    return max(1, math.ceil(tokens / page_size))


def init_paged_pools(
    cfg: ModelConfig, num_pages: int, page_size: int, dtype=None
) -> Dict[str, Any]:
    """Zero-initialized per-segment pools, mirroring ``init_caches``'s
    ``seg{i}`` keying (stacked over each run's layers)."""
    from repro.models.transformer import segments

    if not paged_supported(cfg):
        raise ValueError("paged pools require an attention-only text arch (see paged_supported)")
    dtype = dtype or cfg.param_dtype
    a = cfg.attention
    pools: Dict[str, Any] = {}
    for si, (kind, n) in enumerate(segments(cfg)):
        if a.kind == "mla":
            pools[f"seg{si}"] = dict(
                pool_ckv=jnp.zeros((n, num_pages, page_size, a.kv_lora_rank), dtype),
                pool_krope=jnp.zeros((n, num_pages, page_size, a.qk_rope_head_dim), dtype),
            )
        else:
            pools[f"seg{si}"] = dict(
                pool_k=jnp.zeros((n, num_pages, page_size, a.num_kv_heads, a.head_dim), dtype),
                pool_v=jnp.zeros((n, num_pages, page_size, a.num_kv_heads, a.head_dim), dtype),
            )
    return pools


def pool_bytes(cfg: ModelConfig, num_pages: int, page_size: int, dtype=None) -> int:
    pools = jax.eval_shape(lambda: init_paged_pools(cfg, num_pages, page_size, dtype))
    return sum(leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(pools))


class PageAllocator:
    """Deterministic physical-page allocator. Page 0 (trash) is never handed
    out; free pages are issued lowest-id-first so a replayed arrival trace
    reproduces the exact page assignment."""

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError(f"need >= 2 pages (one is the trash page), got {num_pages}")
        self.num_pages = num_pages
        self._free: List[int] = list(range(1, num_pages))
        heapq.heapify(self._free)

    @property
    def capacity(self) -> int:
        return self.num_pages - 1

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n pages (ascending ids), or None — never a partial grant."""
        if n > len(self._free):
            return None
        return [heapq.heappop(self._free) for _ in range(n)]

    def free(self, pages) -> None:
        for p in pages:
            if not (0 < p < self.num_pages):
                raise ValueError(f"freeing invalid page {p}")
            if p in self._free:
                raise ValueError(f"double free of page {p}")
            heapq.heappush(self._free, p)
