from repro.serving.engine import BatchedEngine, decode_step, generate, prefill

__all__ = ["BatchedEngine", "decode_step", "generate", "prefill"]
