from repro.serving.engine import (
    BatchedEngine,
    decode_step,
    generate,
    hot_swap,
    paged_step,
    prefill,
)
from repro.serving.paged_cache import (
    PageAllocator,
    PagedState,
    init_paged_pools,
    paged_supported,
    pages_for,
    pool_bytes,
)
from repro.serving.scheduler import Request, Scheduler

__all__ = [
    "BatchedEngine",
    "PageAllocator",
    "PagedState",
    "Request",
    "Scheduler",
    "decode_step",
    "generate",
    "hot_swap",
    "init_paged_pools",
    "paged_step",
    "paged_supported",
    "pages_for",
    "pool_bytes",
    "prefill",
]
