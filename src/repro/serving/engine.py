"""Serving: prefill + single-token decode steps and a batched generation engine.

``decode_step`` is the function the decode-shape dry-runs lower: one new
token against a KV/state cache of the benchmark's seq_len. Caches follow the
per-segment layout of ``repro.models.transformer.init_caches``.

Robustness: batch entry points validate shapes up front (an empty or
oversized batch fails with a clear error instead of an XLA trace dump), and
:func:`hot_swap` wraps anchor-checkpoint reads in a bounded
retry-with-backoff — a trainer mid-save produces transiently unreadable
files, and serving should ride through that window, not crash.
"""
from __future__ import annotations

import functools
import time
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig
from repro.models import transformer as T


def prefill(cfg: ModelConfig, params, inputs) -> Tuple[jnp.ndarray, dict]:
    logits, aux = T.apply_model(cfg, params, inputs, mode="prefill")
    return logits, aux["caches"]


def decode_step(cfg: ModelConfig, params, tokens, caches, pos) -> Tuple[jnp.ndarray, dict]:
    """tokens: (B, 1) (text) or (B, K, 1) (audio); pos: scalar absolute position."""
    inputs = dict(tokens=tokens)
    logits, aux = T.apply_model(cfg, params, inputs, mode="decode", caches=caches, decode_pos=pos)
    return logits, aux["caches"]


def _grow_all(caches: dict, cfg: ModelConfig, target_len: int) -> dict:
    from repro.models.layers.attention import grow_cache
    from repro.models.transformer import segments

    out = {}
    segs = segments(cfg)
    for si, (kind, n) in enumerate(segs):
        key = f"seg{si}"
        if key not in caches:
            continue
        c = caches[key]
        if kind in ("attn", "moe", "shared_attn"):
            if kind == "shared_attn":
                out[key] = grow_cache(c, target_len)
            else:
                # stacked over the run's layers: vmap the growth
                out[key] = jax.vmap(lambda ci: grow_cache(ci, target_len))(c)
        else:
            out[key] = c
    return out


def _sample(logits, temperature: float, key):
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


def generate(
    cfg: ModelConfig,
    params,
    prompt: jnp.ndarray,  # (B, S0) int32
    max_new: int,
    temperature: float = 0.0,
    seed: int = 0,
) -> np.ndarray:
    """Greedy/sampled generation for the examples (CPU-scale models)."""
    if prompt.ndim != 2:
        raise ValueError(f"prompt must be (batch, seq) int tokens, got shape {tuple(prompt.shape)}")
    if prompt.shape[0] == 0 or prompt.shape[1] == 0:
        raise ValueError(f"empty prompt batch: shape {tuple(prompt.shape)}")
    if max_new < 1:
        raise ValueError(f"max_new must be >= 1, got {max_new}")
    logits, caches = jax.jit(functools.partial(prefill, cfg))(params, dict(tokens=prompt))
    target_len = prompt.shape[-1] + max_new
    caches = _grow_all(caches, cfg, target_len)
    next_tok = _sample(logits[:, -1], temperature, jax.random.PRNGKey(seed))[:, None]
    step_fn = jax.jit(functools.partial(decode_step, cfg))
    out = [next_tok]
    pos = prompt.shape[1]
    key = jax.random.PRNGKey(seed + 1)
    for i in range(max_new - 1):
        key, sub = jax.random.split(key)
        logits, caches = step_fn(params, next_tok, caches, jnp.asarray(pos + i, jnp.int32))
        next_tok = _sample(logits[:, -1], temperature, sub)[:, None]
        out.append(next_tok)
    return np.concatenate([np.asarray(t) for t in out], axis=1)


def hot_swap(path: str, template, retries: int = 3, backoff: float = 0.05, _sleep: Callable[[float], None] = time.sleep):
    """Restore a params checkpoint for serving, retrying transient read
    failures (a trainer mid-save, a slow network filesystem) with bounded
    exponential backoff: attempt k sleeps ``backoff * 2**k``. Structural
    mismatches (``KeyError``: wrong template) are NOT retried — they cannot
    heal by waiting. Raises the last transient error after ``retries``
    failed attempts."""
    from repro.checkpoint import restore

    import zipfile

    last = None
    for attempt in range(max(int(retries), 1)):
        try:
            return restore(path, template)
        except (OSError, EOFError, ValueError, zipfile.BadZipFile) as e:
            last = e
            _sleep(backoff * (2**attempt))
    raise last


class BatchedEngine:
    """Minimal batched-request server: fixed-slot continuous batching.

    Requests (prompts) queue up; the engine packs up to ``slots`` active
    sequences, prefills new arrivals one-by-one into their slot's cache, and
    decodes all active slots jointly each step — the standard
    serving-throughput structure, CPU-scale.
    """

    def __init__(self, cfg: ModelConfig, params, slots: int = 4, max_len: int = 256):
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if max_len < 2:
            raise ValueError(f"max_len must be >= 2 (one prompt token + one generated), got {max_len}")
        self.cfg, self.params = cfg, params
        self.slots, self.max_len = slots, max_len
        self.queue: list = []
        self.results: dict = {}

    def submit(self, req_id, prompt: np.ndarray, max_new: int):
        prompt = np.asarray(prompt)
        if prompt.ndim != 1 or prompt.shape[0] == 0:
            raise ValueError(
                f"request {req_id!r}: prompt must be a non-empty 1-D token array, got shape {tuple(prompt.shape)}"
            )
        if max_new < 1:
            raise ValueError(f"request {req_id!r}: max_new must be >= 1, got {max_new}")
        if prompt.shape[0] + max_new > self.max_len:
            raise ValueError(
                f"request {req_id!r}: prompt ({prompt.shape[0]}) + max_new ({max_new}) exceeds "
                f"engine max_len ({self.max_len})"
            )
        if req_id in self.results or any(rid == req_id for rid, _, _ in self.queue):
            raise ValueError(f"duplicate request id {req_id!r}")
        self.queue.append((req_id, prompt, max_new))

    def swap_params(self, path: str, retries: int = 3, backoff: float = 0.05) -> None:
        """Hot-swap the served parameters from a checkpoint (see
        :func:`hot_swap`) — the anchor-following deployment path."""
        self.params = hot_swap(path, self.params, retries=retries, backoff=backoff)

    def run(self) -> dict:
        while self.queue:
            batch = self.queue[: self.slots]
            self.queue = self.queue[self.slots :]
            width = max(p.shape[0] for _, p, _ in batch)
            prompts = np.stack([np.pad(p, (width - p.shape[0], 0)) for _, p, _ in batch])
            max_new = max(n for _, _, n in batch)
            toks = generate(self.cfg, self.params, jnp.asarray(prompts), max_new)
            for (rid, _, n), row in zip(batch, toks):
                self.results[rid] = row[:n]
        return self.results
