"""Serving: prefill + decode steps, and the paged continuous-batching engine.

``decode_step`` is the dense-cache decode the decode-shape dry-runs lower for
recurrent archs, and the bitwise oracle the paged path is pinned against
(tests/test_paged_attn.py). ``paged_step`` is the production path: one jitted
program serves both chunked prefill (tokens ``(1, C)``) and joint decode
(tokens ``(slots, 1)``) against the shared page pool — appends are O(tokens)
scatters into pages, never a cache copy or `_grow_all`-style pad chain.

:class:`BatchedEngine` is plane-resident: built on a packed consensus/anchor
plane it reads weights through :class:`ParamView` inside the jitted step, so
``swap_plane`` (a zero-copy buffer swap, applied only between decode steps)
retargets a live server at a freshly averaged anchor without recompiling,
copying, or disturbing in-flight requests. ``swap_params`` composes the
:func:`hot_swap` checkpoint-restore retry path with the same boundary.

Robustness: batch entry points validate shapes up front (an empty or
oversized batch fails with a clear error instead of an XLA trace dump), and
:func:`hot_swap` wraps anchor-checkpoint reads in a bounded
retry-with-backoff — a trainer mid-save produces transiently unreadable
files, and serving should ride through that window, not crash.
"""
from __future__ import annotations

import functools
import time
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig
from repro.models import transformer as T
from repro.parallel.packing import Packed, ParamView
from repro.serving.paged_cache import PagedState, init_paged_pools, pages_for, paged_supported
from repro.serving.scheduler import Request, Scheduler


def prefill(cfg: ModelConfig, params, inputs) -> Tuple[jnp.ndarray, dict]:
    logits, aux = T.apply_model(cfg, params, inputs, mode="prefill")
    return logits, aux["caches"]


def decode_step(cfg: ModelConfig, params, tokens, caches, pos) -> Tuple[jnp.ndarray, dict]:
    """tokens: (B, 1) (text) or (B, K, 1) (audio); pos: scalar absolute position."""
    inputs = dict(tokens=tokens)
    logits, aux = T.apply_model(cfg, params, inputs, mode="decode", caches=caches, decode_pos=pos)
    return logits, aux["caches"]


def paged_step(
    cfg: ModelConfig, params, tokens, caches, page_tables, lengths
) -> Tuple[jnp.ndarray, dict]:
    """One paged-attention step: append ``tokens``' K/V into the slots' pages
    and attend. tokens (S, T) — T == 1 is joint decode across slots, T > 1 a
    prefill chunk (S == 1 in the engine). ``lengths`` is each slot's resident
    token count, i.e. the absolute position of tokens[:, 0]; idle rows carry
    a zero (trash) page-table row and length 0."""
    if isinstance(params, Packed):
        params = ParamView(params)
    t = tokens.shape[1]
    positions = lengths[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
    inputs = dict(tokens=tokens, positions=positions)
    logits, aux = T.apply_model(
        cfg, params, inputs, mode="decode", caches=caches,
        paged=PagedState(jnp.asarray(page_tables), jnp.asarray(lengths)),
    )
    return logits, aux["caches"]


def _grow_all(caches: dict, cfg: ModelConfig, target_len: int) -> dict:
    from repro.models.layers.attention import grow_cache
    from repro.models.transformer import segments

    out = {}
    segs = segments(cfg)
    for si, (kind, n) in enumerate(segs):
        key = f"seg{si}"
        if key not in caches:
            continue
        c = caches[key]
        if kind in ("attn", "moe", "shared_attn"):
            if kind == "shared_attn":
                out[key] = grow_cache(c, target_len)
            else:
                # stacked over the run's layers: vmap the growth
                out[key] = jax.vmap(lambda ci: grow_cache(ci, target_len))(c)
        else:
            out[key] = c
    return out


def _sample(logits, temperature: float, key):
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


def generate(
    cfg: ModelConfig,
    params,
    prompt: jnp.ndarray,  # (B, S0) int32
    max_new: int,
    temperature: float = 0.0,
    seed: int = 0,
) -> np.ndarray:
    """Greedy/sampled generation for the examples (CPU-scale models)."""
    if prompt.ndim != 2:
        raise ValueError(f"prompt must be (batch, seq) int tokens, got shape {tuple(prompt.shape)}")
    if prompt.shape[0] == 0 or prompt.shape[1] == 0:
        raise ValueError(f"empty prompt batch: shape {tuple(prompt.shape)}")
    if max_new < 1:
        raise ValueError(f"max_new must be >= 1, got {max_new}")
    logits, caches = jax.jit(functools.partial(prefill, cfg))(params, dict(tokens=prompt))
    target_len = prompt.shape[-1] + max_new
    caches = _grow_all(caches, cfg, target_len)
    next_tok = _sample(logits[:, -1], temperature, jax.random.PRNGKey(seed))[:, None]
    step_fn = jax.jit(functools.partial(decode_step, cfg))
    out = [next_tok]
    pos = prompt.shape[1]
    key = jax.random.PRNGKey(seed + 1)
    for i in range(max_new - 1):
        key, sub = jax.random.split(key)
        logits, caches = step_fn(params, next_tok, caches, jnp.asarray(pos + i, jnp.int32))
        next_tok = _sample(logits[:, -1], temperature, sub)[:, None]
        out.append(next_tok)
    return np.concatenate([np.asarray(t) for t in out], axis=1)


def hot_swap(path: str, template, retries: int = 3, backoff: float = 0.05, _sleep: Callable[[float], None] = time.sleep):
    """Restore a params checkpoint for serving, retrying transient read
    failures (a trainer mid-save, a slow network filesystem) with bounded
    exponential backoff: attempt k sleeps ``backoff * 2**k``. Structural
    mismatches (``KeyError``: wrong template) are NOT retried — they cannot
    heal by waiting. Raises the last transient error after ``retries``
    failed attempts."""
    from repro.checkpoint import restore

    import zipfile

    last = None
    for attempt in range(max(int(retries), 1)):
        try:
            return restore(path, template)
        except (OSError, EOFError, ValueError, zipfile.BadZipFile) as e:
            last = e
            _sleep(backoff * (2**attempt))
    raise last


class BatchedEngine:
    """Continuous-batching serving engine over a paged KV pool.

    Attention-family text archs run paged (DESIGN.md §10): fixed-size pages
    in a global pool, per-slot page tables, chunked prefill filling pages
    incrementally, and one joint decode program per step across every active
    slot — a short request admits, decodes exactly its own ``max_new`` steps,
    and frees its pages the moment it finishes, regardless of what its
    co-batched neighbours are doing. Prompts are never padded against each
    other (each prefills into its own pages at its own positions), which is
    what makes per-request outputs identical to solo :func:`generate` runs.

    Recurrent/hybrid archs (O(1) decode state — nothing to page) fall back
    to per-request solo generation: exact logits and per-request max_new, at
    fallback throughput.

    ``params`` may be a nested pytree or a packed plane (:class:`Packed`,
    lead ()); a plane is served *in place* through :class:`ParamView` —
    see :meth:`swap_plane`.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        slots: int = 4,
        max_len: int = 256,
        *,
        page_size: int = 16,
        num_pages: Optional[int] = None,
        chunk: int = 32,
        paged="auto",
    ):
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if max_len < 2:
            raise ValueError(f"max_len must be >= 2 (one prompt token + one generated), got {max_len}")
        if page_size < 1 or chunk < 1:
            raise ValueError(f"page_size and chunk must be >= 1, got {page_size}, {chunk}")
        self.cfg = cfg
        self.slots, self.max_len = slots, max_len
        self._plane: Optional[Packed] = None
        self._pending_plane: Optional[Packed] = None
        if isinstance(params, Packed):
            if params.lead_shape != ():
                raise ValueError(f"serving plane must have no lead axis, got {params.lead_shape}")
            self._plane = params
            self.params = None
        else:
            self.params = params
        if paged == "auto":
            self.paged = paged_supported(cfg)
        else:
            self.paged = bool(paged)
            if self.paged and not paged_supported(cfg):
                raise ValueError("paged serving requires an attention-only text arch")
        self.results: dict = {}
        self.queue: list = []  # dense-fallback queue
        self.steps = 0
        if self.paged:
            self.page_size = page_size
            self.chunk = chunk
            self.max_pages = pages_for(max_len, page_size)
            # default pool: full residency for every slot, plus the trash page
            self.num_pages = int(num_pages) if num_pages is not None else slots * self.max_pages + 1
            self.pools = init_paged_pools(cfg, self.num_pages, page_size)
            self.sched = Scheduler(slots, self.num_pages, page_size, self.max_pages)
            # donation lets XLA scatter appends into the pool in place; CPU
            # has no donation support, so skip it there (avoids the warning —
            # the structural no-copy claim is pinned by the jaxpr test)
            donate = (3,) if jax.default_backend() == "tpu" else ()
            self._step_jit = jax.jit(functools.partial(paged_step, cfg), donate_argnums=donate)

    # -- request intake ------------------------------------------------------

    def _known(self, req_id) -> bool:
        if req_id in self.results or any(rid == req_id for rid, *_ in self.queue):
            return True
        if not self.paged:
            return False
        return any(r.rid == req_id for r in self.sched.queue) or any(
            a is not None and a.req.rid == req_id for a in self.sched.active
        )

    def submit(self, req_id, prompt: np.ndarray, max_new: int, stop: Optional[int] = None):
        prompt = np.asarray(prompt)
        if prompt.ndim != 1 or prompt.shape[0] == 0:
            raise ValueError(
                f"request {req_id!r}: prompt must be a non-empty 1-D token array, got shape {tuple(prompt.shape)}"
            )
        if max_new < 1:
            raise ValueError(f"request {req_id!r}: max_new must be >= 1, got {max_new}")
        if prompt.shape[0] + max_new > self.max_len:
            raise ValueError(
                f"request {req_id!r}: prompt ({prompt.shape[0]}) + max_new ({max_new}) exceeds "
                f"engine max_len ({self.max_len})"
            )
        if self._known(req_id):
            raise ValueError(f"duplicate request id {req_id!r}")
        if self.paged:
            self.sched.submit(Request(req_id, prompt.astype(np.int32), int(max_new), stop))
        else:
            self.queue.append((req_id, prompt, int(max_new), stop))

    # -- served parameters ---------------------------------------------------

    @property
    def plane(self) -> Optional[Packed]:
        return self._plane

    def _params_arg(self):
        return self._plane if self._plane is not None else self.params

    def swap_plane(self, plane: Packed) -> None:
        """Queue a zero-copy hot-swap of the served plane. Applied at the
        next :meth:`step` boundary — a decode step in flight finishes on the
        old plane; no step ever mixes weights. The plane's buffers are served
        as-is (no unpack/copy), so passing a live anchor plane from a running
        ``Experiment.fit`` costs nothing but the swap itself."""
        if self._plane is None:
            raise ValueError("engine was built on a per-leaf pytree; swap_plane needs a plane-resident engine")
        if not isinstance(plane, Packed):
            raise TypeError(f"swap_plane takes a Packed plane, got {type(plane).__name__}")
        if plane.lead_shape != ():
            raise ValueError(f"serving plane must have no lead axis, got {plane.lead_shape}")
        if plane.layout != self._plane.layout:
            raise ValueError("swap_plane: layout mismatch with the served plane")
        self._pending_plane = plane

    def swap_params(self, path: str, retries: int = 3, backoff: float = 0.05) -> None:
        """Hot-swap the served parameters from a checkpoint (see
        :func:`hot_swap`) — the anchor-following deployment path. On a
        plane-resident engine the restored tree is packed onto the served
        layout and applied at the same between-steps boundary as
        :meth:`swap_plane`."""
        if self._plane is not None:
            restored = hot_swap(path, self._plane, retries=retries, backoff=backoff)
            self.swap_plane(restored)
        else:
            self.params = hot_swap(path, self.params, retries=retries, backoff=backoff)

    # -- paged engine loop ---------------------------------------------------

    def _run_step(self, tokens, page_tables, lengths):
        logits, self.pools = self._step_jit(
            self._params_arg(),
            jnp.asarray(tokens),
            self.pools,
            jnp.asarray(page_tables),
            jnp.asarray(lengths),
        )
        return logits

    def step(self) -> list:
        """One scheduler tick: apply a pending plane swap, complete finished
        requests (freeing their pages), admit, advance every prefilling slot
        by one chunk, then run one joint decode across active slots. Returns
        the request ids completed this tick."""
        if not self.paged:
            raise RuntimeError("step() drives the paged engine; the dense fallback runs via run()")
        if self._pending_plane is not None:  # between decode steps, never mid-step
            self._plane = self._pending_plane
            self._pending_plane = None
        sched = self.sched
        finished = []
        for i in range(self.slots):
            a = sched.active[i]
            if a is not None and a.finished:
                self.results[a.req.rid] = np.asarray(a.generated, np.int32)
                finished.append(a.req.rid)
                sched.complete(i)
        sched.admit()
        # chunked prefill: each prefilling slot advances one chunk (B=1 call)
        for i in range(self.slots):
            a = sched.active[i]
            if a is None or a.prefill_done:
                continue
            start = a.length
            end = min(start + self.chunk, len(a.req.prompt))
            if not sched.ensure_pages(i, end - 1):
                continue  # evicted itself to make room; requeued
            toks = np.zeros((1, self.chunk), np.int32)
            toks[0, : end - start] = a.req.prompt[start:end]
            logits = self._run_step(toks, sched.table[i : i + 1], np.asarray([start], np.int32))
            a.length = end
            if end == len(a.req.prompt):
                a.prefill_done = True
                a.generated.append(int(np.argmax(np.asarray(logits)[0, end - start - 1])))
        # joint decode across every decode-ready slot
        dec = []
        for i in range(self.slots):
            a = sched.active[i]
            if a is None or not a.prefill_done or a.finished:
                continue
            if sched.ensure_pages(i, a.length):  # the append position
                dec.append((i, a.admit_seq))
        dec = [
            i for i, seq in dec
            if sched.active[i] is not None and sched.active[i].admit_seq == seq
        ]
        if dec:
            toks = np.zeros((self.slots, 1), np.int32)
            tables = np.zeros_like(sched.table)  # idle rows → trash page, length 0
            lens = np.zeros((self.slots,), np.int32)
            for i in dec:
                a = sched.active[i]
                toks[i, 0] = a.generated[-1]
                tables[i] = sched.table[i]
                lens[i] = a.length
            logits = self._run_step(toks, tables, lens)
            nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
            for i in dec:
                a = sched.active[i]
                a.length += 1
                a.generated.append(int(nxt[i]))
        self.steps += 1
        return finished

    def run(self) -> dict:
        if self.paged:
            while self.sched.busy:
                self.step()
            return self.results
        # dense fallback: solo decode per request — exact per-request logits
        # and exactly max_new steps each (no cross-request left-padding, no
        # shared max(max_new))
        while self.queue:
            rid, prompt, max_new, stop = self.queue.pop(0)
            params = ParamView(self._plane) if self._plane is not None else self.params
            row = generate(self.cfg, params, jnp.asarray(prompt)[None], max_new)[0]
            if stop is not None:
                hits = np.nonzero(row == stop)[0]
                if hits.size:
                    row = row[: hits[0] + 1]
            self.results[rid] = row
        return self.results
