"""Serving: prefill + single-token decode steps and a batched generation engine.

``decode_step`` is the function the decode-shape dry-runs lower: one new
token against a KV/state cache of the benchmark's seq_len. Caches follow the
per-segment layout of ``repro.models.transformer.init_caches``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig
from repro.models import transformer as T


def prefill(cfg: ModelConfig, params, inputs) -> Tuple[jnp.ndarray, dict]:
    logits, aux = T.apply_model(cfg, params, inputs, mode="prefill")
    return logits, aux["caches"]


def decode_step(cfg: ModelConfig, params, tokens, caches, pos) -> Tuple[jnp.ndarray, dict]:
    """tokens: (B, 1) (text) or (B, K, 1) (audio); pos: scalar absolute position."""
    inputs = dict(tokens=tokens)
    logits, aux = T.apply_model(cfg, params, inputs, mode="decode", caches=caches, decode_pos=pos)
    return logits, aux["caches"]


def _grow_all(caches: dict, cfg: ModelConfig, target_len: int) -> dict:
    from repro.models.layers.attention import grow_cache
    from repro.models.transformer import segments

    out = {}
    segs = segments(cfg)
    for si, (kind, n) in enumerate(segs):
        key = f"seg{si}"
        if key not in caches:
            continue
        c = caches[key]
        if kind in ("attn", "moe", "shared_attn"):
            if kind == "shared_attn":
                out[key] = grow_cache(c, target_len)
            else:
                # stacked over the run's layers: vmap the growth
                out[key] = jax.vmap(lambda ci: grow_cache(ci, target_len))(c)
        else:
            out[key] = c
    return out


def _sample(logits, temperature: float, key):
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


def generate(
    cfg: ModelConfig,
    params,
    prompt: jnp.ndarray,  # (B, S0) int32
    max_new: int,
    temperature: float = 0.0,
    seed: int = 0,
) -> np.ndarray:
    """Greedy/sampled generation for the examples (CPU-scale models)."""
    logits, caches = jax.jit(functools.partial(prefill, cfg))(params, dict(tokens=prompt))
    target_len = prompt.shape[-1] + max_new
    caches = _grow_all(caches, cfg, target_len)
    next_tok = _sample(logits[:, -1], temperature, jax.random.PRNGKey(seed))[:, None]
    step_fn = jax.jit(functools.partial(decode_step, cfg))
    out = [next_tok]
    pos = prompt.shape[1]
    key = jax.random.PRNGKey(seed + 1)
    for i in range(max_new - 1):
        key, sub = jax.random.split(key)
        logits, caches = step_fn(params, next_tok, caches, jnp.asarray(pos + i, jnp.int32))
        next_tok = _sample(logits[:, -1], temperature, sub)[:, None]
        out.append(next_tok)
    return np.concatenate([np.asarray(t) for t in out], axis=1)


class BatchedEngine:
    """Minimal batched-request server: fixed-slot continuous batching.

    Requests (prompts) queue up; the engine packs up to ``slots`` active
    sequences, prefills new arrivals one-by-one into their slot's cache, and
    decodes all active slots jointly each step — the standard
    serving-throughput structure, CPU-scale.
    """

    def __init__(self, cfg: ModelConfig, params, slots: int = 4, max_len: int = 256):
        self.cfg, self.params = cfg, params
        self.slots, self.max_len = slots, max_len
        self.queue: list = []
        self.results: dict = {}

    def submit(self, req_id, prompt: np.ndarray, max_new: int):
        self.queue.append((req_id, prompt, max_new))

    def run(self) -> dict:
        while self.queue:
            batch = self.queue[: self.slots]
            self.queue = self.queue[self.slots :]
            width = max(p.shape[0] for _, p, _ in batch)
            prompts = np.stack([np.pad(p, (width - p.shape[0], 0)) for _, p, _ in batch])
            max_new = max(n for _, _, n in batch)
            toks = generate(self.cfg, self.params, jnp.asarray(prompts), max_new)
            for (rid, _, n), row in zip(batch, toks):
                self.results[rid] = row[:n]
        return self.results
