"""Continuous-batching scheduler over the paged KV pool (DESIGN.md §10).

Pure host-side bookkeeping — no jax. The engine drives it:

* ``admit()`` fills free slots from the FIFO queue while the next request's
  prompt (plus one decode page) fits the free-page pool.
* ``ensure_pages(slot, upto)`` backs a slot's cache up to position ``upto``,
  evicting under pool exhaustion (youngest admitted first, the oldest active
  request is never evicted, so it can always run to completion — the bound
  that makes every trace drain). Evicted requests are *requeued at the front*
  with their original prompt, never dropped.
* ``complete(slot)`` frees the slot's pages immediately, so a short request
  never waits on the longest one (no head-of-line blocking).

Everything is deterministic given the submit/step sequence: FIFO admission,
slot order by index, eviction by reverse admission order, pages issued
lowest-id-first. ``events`` records (admit | evict | finish) tuples for
replay tests.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, List, Optional

import numpy as np

from repro.serving.paged_cache import PageAllocator, pages_for


@dataclasses.dataclass
class Request:
    rid: Any
    prompt: np.ndarray  # (Lp,) int32
    max_new: int
    stop: Optional[int] = None  # stop token id (included in the output)


@dataclasses.dataclass
class _Active:
    req: Request
    admit_seq: int
    length: int = 0  # tokens resident in the slot's pages
    pages: List[int] = dataclasses.field(default_factory=list)
    generated: List[int] = dataclasses.field(default_factory=list)
    prefill_done: bool = False

    @property
    def finished(self) -> bool:
        if not self.prefill_done:
            return False
        if len(self.generated) >= self.req.max_new:
            return True
        return bool(self.generated) and self.req.stop is not None and self.generated[-1] == self.req.stop


class Scheduler:
    def __init__(self, slots: int, num_pages: int, page_size: int, max_pages_per_slot: int):
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        self.slots = slots
        self.page_size = page_size
        self.max_pages_per_slot = max_pages_per_slot
        self.alloc = PageAllocator(num_pages)
        self.queue: Deque[Request] = deque()
        self.active: List[Optional[_Active]] = [None] * slots
        self.table = np.zeros((slots, max_pages_per_slot), np.int32)  # 0 = trash
        self.events: List[tuple] = []
        self._seq = 0

    # -- queue / lifecycle --------------------------------------------------

    def submit(self, req: Request) -> None:
        need = pages_for(len(req.prompt) + req.max_new - 1, self.page_size)
        if need > self.alloc.capacity or need > self.max_pages_per_slot:
            raise ValueError(
                f"request {req.rid!r} needs {need} pages; pool capacity is "
                f"{self.alloc.capacity}, per-slot table holds {self.max_pages_per_slot}"
            )
        self.queue.append(req)

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(a is not None for a in self.active)

    def lengths(self) -> np.ndarray:
        return np.asarray([a.length if a else 0 for a in self.active], np.int32)

    def admit(self) -> List[int]:
        """FIFO admission into the lowest free slots while pages allow;
        stops at the first request that does not fit (no reordering)."""
        admitted = []
        while self.queue:
            free = [i for i, a in enumerate(self.active) if a is None]
            if not free:
                break
            req = self.queue[0]
            if self.alloc.available < pages_for(len(req.prompt), self.page_size) + 1:
                break
            self.queue.popleft()
            slot = free[0]
            self.active[slot] = _Active(req=req, admit_seq=self._seq)
            self._seq += 1
            self.table[slot] = 0
            self.events.append(("admit", req.rid, slot))
            admitted.append(slot)
        return admitted

    def complete(self, slot: int) -> None:
        a = self.active[slot]
        self.alloc.free(a.pages)
        self.events.append(("finish", a.req.rid))
        self.active[slot] = None
        self.table[slot] = 0

    # -- pages / eviction ---------------------------------------------------

    def _evict(self, slot: int) -> None:
        a = self.active[slot]
        self.alloc.free(a.pages)
        self.events.append(("evict", a.req.rid, slot))
        self.active[slot] = None
        self.table[slot] = 0
        self.queue.appendleft(a.req)  # original request — requeued, not dropped

    def ensure_pages(self, slot: int, upto: int) -> bool:
        """Back slot ``slot`` through token position ``upto`` (0-based),
        evicting youngest-first under exhaustion. Returns False if the slot
        itself was evicted to make room (callers skip it this step)."""
        a = self.active[slot]
        need = pages_for(upto + 1, self.page_size) - len(a.pages)
        while need > 0:
            got = self.alloc.alloc(need)
            if got is not None:
                base = len(a.pages)
                for k, p in enumerate(got):
                    self.table[slot, base + k] = p
                a.pages.extend(got)
                return True
            victims = sorted(
                (i for i, v in enumerate(self.active) if v is not None),
                key=lambda i: self.active[i].admit_seq,
            )
            if len(victims) <= 1:  # only the oldest left; submit() proved it fits
                raise RuntimeError("page pool exhausted with a single active request")
            youngest = victims[-1]
            self._evict(youngest)
            if youngest == slot:
                return False
        return True
