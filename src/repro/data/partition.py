"""IID / non-IID data partitioning across Local-SGD workers (paper §4).

The paper's non-IID setting: every node gets an equal share of the training
set, a large fraction of which (2000 of 3125 = 64%) belongs to a single
class. ``partition_noniid`` reproduces exactly that construction for any
(m, skew) and ``partition_iid`` is the even random split (the paper trains
with data "evenly partitioned across all nodes and not shuffled").
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.data.synthetic import ClassificationData


def partition_iid(data: ClassificationData, m: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(data.n)
    per = data.n // m
    return [idx[i * per : (i + 1) * per] for i in range(m)]


def partition_noniid(
    data: ClassificationData,
    m: int,
    skew: float = 0.64,
    seed: int = 0,
) -> List[np.ndarray]:
    """Each worker i gets ``skew`` of its samples from class (i mod C) and the
    rest uniformly from the remainder. skew=0.64 matches the paper
    (2000/3125)."""
    rng = np.random.default_rng(seed)
    per = data.n // m
    n_major = int(round(per * skew))
    by_class = [np.flatnonzero(data.y == c) for c in range(data.num_classes)]
    for c in by_class:
        rng.shuffle(c)
    cursor = [0] * data.num_classes
    rest_pool = []
    parts: List[np.ndarray] = []
    # first pass: majority class slices
    majors = []
    for i in range(m):
        c = i % data.num_classes
        take = by_class[c][cursor[c] : cursor[c] + n_major]
        cursor[c] += n_major
        majors.append(take)
    for c in range(data.num_classes):
        rest_pool.append(by_class[c][cursor[c] :])
    rest = np.concatenate(rest_pool)
    rng.shuffle(rest)
    n_rest = per - n_major
    for i in range(m):
        minor = rest[i * n_rest : (i + 1) * n_rest]
        part = np.concatenate([majors[i], minor])
        parts.append(part)
    return parts


def skewness(data: ClassificationData, parts: List[np.ndarray]) -> float:
    """Mean max-class fraction across workers (1/C for IID, →1 fully skewed)."""
    fracs = []
    for p in parts:
        counts = np.bincount(data.y[p], minlength=data.num_classes)
        fracs.append(counts.max() / max(len(p), 1))
    return float(np.mean(fracs))
