from repro.data.loaders import (
    ClassificationSplits,
    classification_batch_fn,
    lm_batch_fn,
    make_classification_splits,
    round_batch,
)
from repro.data.partition import partition_iid, partition_noniid, skewness
from repro.data.pipeline import WorkerBatcher, stack_lm_batches
from repro.data.synthetic import ClassificationData, lm_batch_stream, make_classification

__all__ = [
    "ClassificationData",
    "ClassificationSplits",
    "WorkerBatcher",
    "classification_batch_fn",
    "lm_batch_fn",
    "lm_batch_stream",
    "make_classification",
    "make_classification_splits",
    "partition_iid",
    "partition_noniid",
    "round_batch",
    "skewness",
    "stack_lm_batches",
]
