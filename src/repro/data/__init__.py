from repro.data.partition import partition_iid, partition_noniid, skewness
from repro.data.pipeline import WorkerBatcher, stack_lm_batches
from repro.data.synthetic import ClassificationData, lm_batch_stream, make_classification

__all__ = [
    "ClassificationData",
    "WorkerBatcher",
    "lm_batch_stream",
    "make_classification",
    "partition_iid",
    "partition_noniid",
    "skewness",
    "stack_lm_batches",
]
