"""Worker-stacked batch builders shared by the Experiment facade, the
launchers, the examples and the benchmarks.

This is the single home for the synthetic batch wiring that used to be
duplicated across ``launch/train.py``, ``examples/train_lm.py`` and the
classification drivers: a *batch fn* is a zero-arg callable returning one
worker-stacked per-step batch (leaves shaped (m, b, ...)), and
:func:`round_batch` stacks τ of them into the (τ, m, b, ...) layout the
round engine scans over.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig
from repro.data.partition import partition_iid, partition_noniid
from repro.data.pipeline import WorkerBatcher
from repro.data.synthetic import ClassificationData, lm_batch_stream, make_classification


def round_batch(next_batch: Callable, tau: int):
    """Stack τ per-step batches (m, b, ...) into one round batch (τ, m, b, ...)."""
    micro = [next_batch() for _ in range(tau)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *micro)


def lm_batch_fn(cfg: ModelConfig, m: int, batch: int, seq: int, seed: int = 0) -> Callable:
    """Worker-stacked synthetic LM batches for ``cfg``, including the
    modality-frontend variants (vision patch embeddings, audio codebooks)."""
    streams = [lm_batch_stream(batch, seq, cfg.vocab_size, seed=seed + i) for i in range(m)]
    rng = np.random.default_rng(seed)

    def vlm_extra():
        fe = cfg.frontend
        return dict(
            image_embeds=jnp.asarray(
                rng.normal(size=(m, batch, fe.tokens_per_item, fe.embed_dim)).astype(np.float32)
            )
        )

    def next_batch():
        toks, tgts = zip(*[next(s) for s in streams])
        toks, tgts = np.stack(toks), np.stack(tgts)
        fe = cfg.frontend
        if fe is not None and fe.kind == "audio":
            k = fe.num_codebooks
            toks = rng.integers(0, cfg.vocab_size, (m, batch, k, seq)).astype(np.int32)
            tgts = rng.integers(0, cfg.vocab_size, (m, batch, k, seq)).astype(np.int32)
            return dict(tokens=jnp.asarray(toks), targets=jnp.asarray(tgts))
        out = dict(tokens=jnp.asarray(toks), targets=jnp.asarray(tgts))
        if fe is not None and fe.kind == "vision":
            out.update(vlm_extra())
        return out

    return next_batch


@dataclass
class ClassificationSplits:
    """A train/test split plus per-worker index partitions."""

    train: ClassificationData
    test: ClassificationData
    parts: List[np.ndarray]

    @property
    def num_workers(self) -> int:
        return len(self.parts)


def make_classification_splits(
    m: int,
    *,
    n: int = 30000,
    dim: int = 64,
    num_classes: int = 10,
    noise: float = 3.0,
    holdout: int = 4000,
    noniid: bool = False,
    skew: float = 0.64,
    seed: int = 0,
) -> ClassificationSplits:
    """The synthetic classification task (CIFAR-10/ResNet-18 stand-in) split
    into holdout test set + per-worker partitions — previously re-derived in
    quickstart, noniid_stability and benchmarks/common."""
    data = make_classification(n=n, dim=dim, num_classes=num_classes, noise=noise, seed=seed)
    test = type(data)(x=data.x[:holdout], y=data.y[:holdout], num_classes=num_classes)
    train = type(data)(x=data.x[holdout:], y=data.y[holdout:], num_classes=num_classes)
    if noniid:
        parts = partition_noniid(train, m, skew=skew, seed=seed)
    else:
        parts = partition_iid(train, m, seed=seed)
    return ClassificationSplits(train=train, test=test, parts=parts)


def classification_batch_fn(splits: ClassificationSplits, batch_per_worker: int, seed: int = 0) -> Callable:
    """Worker-stacked (x, y) batches from pre-partitioned classification data."""
    batcher = WorkerBatcher(splits.train, splits.parts, batch_per_worker, seed=seed)

    def next_batch():
        x, y = next(batcher)
        return jnp.asarray(x), jnp.asarray(y)

    return next_batch
