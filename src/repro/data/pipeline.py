"""Sharded host data pipeline.

Produces worker-stacked batches: arrays with leading axis m (one slice per
Local-SGD worker), matching the worker-stacked training state. On a real
multi-host deployment each host builds only its local slice and
``jax.make_array_from_process_local_data`` assembles the global array; on a
single host we build the full stacked batch and let the sharding place it.

Sampling is *sequential without shuffling within an epoch* to match the
paper's setup ("evenly partitioned across all nodes and not shuffled").
"""
from __future__ import annotations

from typing import Iterator, List, Optional

import numpy as np

from repro.data.synthetic import ClassificationData


class WorkerBatcher:
    """Iterates worker-stacked (x, y) minibatches from per-worker index sets."""

    def __init__(
        self,
        data: ClassificationData,
        parts: List[np.ndarray],
        batch_per_worker: int,
        seed: int = 0,
        reshuffle_each_epoch: bool = False,
    ):
        self.data = data
        self.parts = [np.asarray(p) for p in parts]
        self.b = batch_per_worker
        self.m = len(parts)
        self.rng = np.random.default_rng(seed)
        self.reshuffle = reshuffle_each_epoch
        self._pos = [0] * self.m

    def steps_per_epoch(self) -> int:
        return min(len(p) for p in self.parts) // self.b

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        xs, ys = [], []
        for i in range(self.m):
            part = self.parts[i]
            if self._pos[i] + self.b > len(part):
                self._pos[i] = 0
                if self.reshuffle:
                    self.rng.shuffle(part)
            sl = part[self._pos[i] : self._pos[i] + self.b]
            self._pos[i] += self.b
            xs.append(self.data.x[sl])
            ys.append(self.data.y[sl])
        return np.stack(xs), np.stack(ys)


def stack_lm_batches(streams, m: int):
    """Zip m per-worker LM token streams into worker-stacked batches."""
    while True:
        toks, tgts = zip(*[next(s) for s in streams])
        yield np.stack(toks), np.stack(tgts)
