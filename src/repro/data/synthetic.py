"""Synthetic datasets.

Two families:

* ``ClassificationData`` — a Gaussian-mixture / random-teacher image-like
  classification task standing in for CIFAR-10 in the paper-reproduction
  benchmarks (Tables 1–2, Figs. 1/4/5). It is small enough to run hundreds
  of steps on CPU while still exhibiting the error–τ tradeoff the paper
  studies (local models drift during a round, pullback re-consolidates).

* ``lm_batch_stream`` — deterministic token streams for the LM architectures
  (a fixed-seed Zipf-ish unigram sampler with a learnable bigram structure so
  loss actually decreases).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Tuple

import numpy as np


@dataclass
class ClassificationData:
    x: np.ndarray  # (n, dim) float32
    y: np.ndarray  # (n,) int32
    num_classes: int

    @property
    def n(self) -> int:
        return self.x.shape[0]


def make_classification(
    n: int = 50_000,
    dim: int = 64,
    num_classes: int = 10,
    noise: float = 0.6,
    seed: int = 0,
    nonlinear: bool = True,
) -> ClassificationData:
    """Random-teacher classification task.

    Labels come from an (optionally nonlinear) random teacher so the Bayes
    error is controlled by ``noise``; class-conditional structure exists so
    non-IID label partitions (paper §4) produce genuinely skewed local
    objectives with inter-worker gradient deviation κ² > 0.
    """
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(num_classes, dim)).astype(np.float32)
    y = rng.integers(0, num_classes, size=(n,)).astype(np.int32)
    x = centers[y] + noise * rng.normal(size=(n, dim)).astype(np.float32)
    if nonlinear:
        w = rng.normal(size=(dim, dim)).astype(np.float32) / np.sqrt(dim)
        x = x + 0.1 * np.tanh(x @ w)
    return ClassificationData(x=x.astype(np.float32), y=y, num_classes=num_classes)


def lm_token_stream(
    vocab_size: int,
    seed: int = 0,
    order: int = 1,
) -> "np.random.Generator":
    raise NotImplementedError("use lm_batch_stream")


def lm_batch_stream(
    batch: int,
    seq_len: int,
    vocab_size: int,
    seed: int = 0,
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Infinite stream of (tokens, targets) with learnable bigram structure.

    Each next-token distribution is a mixture of a global unigram and a
    deterministic bigram permutation — a model can reduce loss well below
    log(vocab) by learning the permutation, so training curves are
    informative.
    """
    rng = np.random.default_rng(seed)
    v = int(vocab_size)
    perm = rng.permutation(v)
    while True:
        toks = np.empty((batch, seq_len + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, v, size=(batch,))
        rand = rng.random((batch, seq_len))
        noise_tok = rng.integers(0, v, size=(batch, seq_len))
        for t in range(seq_len):
            follow = perm[toks[:, t]]
            toks[:, t + 1] = np.where(rand[:, t] < 0.75, follow, noise_tok[:, t])
        yield toks[:, :-1], toks[:, 1:]
