"""Deprecated location — the adaptive-τ machinery moved to ``repro.control``.

The original module mixed the controller, the consensus measurement and
the per-τ program cache into one file (and shipped a shared-mutable
``history: list = None`` default on the controller). The control plane now
lives in :mod:`repro.control` (DESIGN.md §6): ``TauController`` /
``AdaptiveTau`` and ``consensus_drift`` in ``repro.control.controller``,
``TauScheduledTrainer`` (on top of ``RoundProgramCache``) in
``repro.control.program_cache``. This shim re-exports the legacy names
with a :class:`DeprecationWarning`.
"""
from __future__ import annotations

import warnings

_MOVED = {
    "AdaptiveTau": "repro.control",
    "TauScheduledTrainer": "repro.control",
    "consensus_drift": "repro.control",
}

__all__ = sorted(_MOVED)


def __getattr__(name: str):
    if name in _MOVED:
        warnings.warn(
            f"repro.core.adaptive.{name} moved to {_MOVED[name]}.{name}; "
            "repro.core.adaptive is a deprecated alias and will be removed.",
            DeprecationWarning,
            stacklevel=2,
        )
        import repro.control as _control

        return getattr(_control, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_MOVED))
