"""Adaptive communication period (beyond-paper extension).

The paper fixes τ per run and points at its companion work (ref. [14],
AdaComm) for adapting it. We implement the natural controller for
Overlap-Local-SGD: grow τ while the anchor communication stays hidden and
the workers' *consensus distance* stays a small fraction of the parameter
norm, shrink it when local models drift too far (the non-IID failure mode of
Table 2).

    τ_{r+1} = clip(τ_r · 2,      if  drift_r < lo · scale_r
              τ_r,               if  lo·scale ≤ drift ≤ hi·scale
              max(τ_r / 2, 1),   if  drift_r > hi · scale_r)

with drift_r = mean_i ‖x_i − x̄‖ and scale_r = ‖x̄‖. The controller runs on
the host between rounds (τ is a static shape parameter of the compiled round
program; the framework keeps one jitted round_step per τ in a small cache).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class AdaptiveTau:
    tau: int = 1
    tau_min: int = 1
    tau_max: int = 32
    lo: float = 0.01  # drift/scale below this: communicate less often
    hi: float = 0.05  # drift/scale above this: communicate more often
    history: list = None

    def __post_init__(self):
        self.history = []

    def update(self, drift: float, scale: float) -> int:
        ratio = drift / max(scale, 1e-12)
        old = self.tau
        if ratio < self.lo:
            self.tau = min(self.tau * 2, self.tau_max)
        elif ratio > self.hi:
            self.tau = max(self.tau // 2, self.tau_min)
        self.history.append(dict(tau=old, drift_ratio=ratio, next_tau=self.tau))
        return self.tau


def consensus_drift(x_stacked) -> tuple:
    """(mean_i ‖x_i − x̄‖, ‖x̄‖) over the stacked worker params."""
    leaves = jax.tree.leaves(x_stacked)
    sq_drift = 0.0
    sq_scale = 0.0
    for t in leaves:
        tf = t.astype(jnp.float32)
        mean = jnp.mean(tf, axis=0, keepdims=True)
        sq_drift += jnp.sum(jnp.square(tf - mean)) / t.shape[0]
        sq_scale += jnp.sum(jnp.square(mean))
    return jnp.sqrt(sq_drift), jnp.sqrt(sq_scale)


class TauScheduledTrainer:
    """Host-side driver that re-selects τ between rounds.

    ``make_step(tau)`` must return a jitted round_step for that τ; compiled
    steps are cached (τ only takes O(log τ_max) distinct values)."""

    def __init__(self, make_step: Callable[[int], Callable], controller: AdaptiveTau):
        self.make_step = make_step
        self.ctrl = controller
        self._cache: Dict[int, Callable] = {}

    def step_for(self, tau: int) -> Callable:
        if tau not in self._cache:
            self._cache[tau] = self.make_step(tau)
        return self._cache[tau]

    def run_round(self, state, batch_fn):
        tau = self.ctrl.tau
        step = self.step_for(tau)
        batch = batch_fn(tau)
        state, metrics = step(state, batch)
        drift, scale = consensus_drift(state.x)
        self.ctrl.update(float(drift), float(scale))
        return state, metrics, tau
