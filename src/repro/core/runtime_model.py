"""Wall-clock runtime model for the error–runtime tradeoff (paper Figs. 1/4/5).

Simulates per-worker clocks under a straggler model and a communication
model, for every algorithm in the comparison. This is how the paper's
runtime claims are validated quantitatively on CPU-only hardware: the
*convergence* curves come from real training runs; the *time axis* comes
from this model, calibrated with the paper's own measured constants
(ResNet-18/CIFAR-10 on 16 × Titan X over 40 Gbps Ethernet):

    compute ≈ 4.6 s/epoch  (24-25 steps/epoch ⇒ ~0.19 s/step)
    fully-sync all-reduce ≈ 1.5 s/epoch (comm/compute ≈ 34.6% incl. overhead)
    PowerSGD rank-1 compresses 243× but keeps the handshake latency.

Blocking semantics per algorithm:
    sync_sgd   — barrier + blocking all-reduce every step
    powersgd   — barrier + blocking compressed all-reduce every step
    local_sgd  — barrier + blocking all-reduce every τ steps
    easgd      — same barrier structure as local_sgd (z update is synchronous
                 in [19] when run without its (rare) async variant)
    overlap_local_sgd / cocod — NON-blocking: collective launched at a
                 boundary is consumed at the next one; a worker only waits if
                 the collective is still in flight when it arrives there.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

import numpy as np

BLOCKING = {"sync_sgd": 1, "powersgd": 1, "local_sgd": None, "easgd": None}
OVERLAPPED = ("overlap_local_sgd", "cocod")


@dataclass
class RuntimeConfig:
    m: int = 16
    t_step: float = 0.19  # mean compute time per local step (s)
    t_comm: float = 0.065  # full model all-reduce incl. handshake (s)
    t_handshake: float = 0.02  # fixed latency part of any collective
    straggle_std: float = 0.0  # lognormal sigma on per-step compute
    straggle_prob: float = 0.0  # probability of a step slowing by straggle_factor
    straggle_factor: float = 4.0
    powersgd_compression: float = 243.0  # rank-1 payload reduction
    powersgd_codec: float = 0.01  # encode+decode time per step (s)
    seed: int = 0


@dataclass
class RuntimeResult:
    total_time: float
    compute_time: float
    exposed_comm: float  # communication NOT hidden behind compute
    idle_time: float  # straggler-induced waiting
    steps: int

    @property
    def comm_ratio(self) -> float:
        return self.exposed_comm / max(self.compute_time, 1e-12)


def _step_times(cfg: RuntimeConfig, rng, steps: int) -> np.ndarray:
    t = np.full((steps, cfg.m), cfg.t_step)
    if cfg.straggle_std > 0:
        t *= rng.lognormal(mean=0.0, sigma=cfg.straggle_std, size=(steps, cfg.m))
    if cfg.straggle_prob > 0:
        slow = rng.random((steps, cfg.m)) < cfg.straggle_prob
        t = np.where(slow, t * cfg.straggle_factor, t)
    return t


def simulate(algo: str, tau: int, steps: int, cfg: RuntimeConfig) -> RuntimeResult:
    rng = np.random.default_rng(cfg.seed)
    t = _step_times(cfg, rng, steps)
    m = cfg.m

    comm = cfg.t_comm
    if algo == "powersgd":
        comm = cfg.t_handshake + (cfg.t_comm - cfg.t_handshake) / cfg.powersgd_compression + cfg.powersgd_codec
    if algo == "sync_sgd" or algo == "powersgd":
        tau = 1

    compute_total = float(t.sum(axis=0).max())  # critical-path compute
    mean_compute = float(t.sum(axis=0).mean())

    if algo in ("sync_sgd", "powersgd", "local_sgd", "easgd"):
        # barrier every tau steps, then blocking collective
        clock = 0.0
        exposed = 0.0
        idle = 0.0
        worker_clock = np.zeros(m)
        for r in range(steps // tau):
            seg = t[r * tau : (r + 1) * tau].sum(axis=0)
            arrive = worker_clock + seg
            barrier = arrive.max()
            idle += float((barrier - arrive).sum()) / m
            clock = barrier + comm
            exposed += comm
            worker_clock = np.full(m, clock)
        return RuntimeResult(clock, mean_compute, exposed, idle, steps)

    if algo in OVERLAPPED:
        # non-blocking: collective for boundary r completes at
        # max_i(arrival_r) + comm; worker i blocks at boundary r+1 only if
        # that completion is later than its own arrival.
        worker_clock = np.zeros(m)
        ready = 0.0  # completion time of the in-flight collective
        exposed = 0.0
        idle = 0.0
        rounds = steps // tau
        for r in range(rounds):
            seg = t[r * tau : (r + 1) * tau].sum(axis=0)
            arrive = worker_clock + seg
            # wait (only) for the previous round's collective
            stall = np.maximum(ready - arrive, 0.0)
            exposed += float(stall.max())
            idle += float(stall.mean())
            worker_clock = arrive + stall
            # launch this round's collective once all contributions exist
            ready = float(worker_clock.max()) + comm
        total = float(worker_clock.max())
        return RuntimeResult(total, mean_compute, exposed, idle, steps)

    raise ValueError(algo)


def epoch_summary(algo: str, tau: int, steps_per_epoch: int, cfg: RuntimeConfig) -> Dict[str, float]:
    r = simulate(algo, tau, steps_per_epoch, cfg)
    return dict(
        algo=algo,
        tau=tau,
        epoch_time=r.total_time,
        compute=r.compute_time,
        exposed_comm=r.exposed_comm,
        comm_ratio=r.comm_ratio,
        idle=r.idle_time,
    )
