"""Wall-clock runtime model for the error–runtime tradeoff (paper Figs. 1/4/5).

Simulates per-worker clocks under a straggler model and a communication
model, for every algorithm in the comparison. This is how the paper's
runtime claims are validated quantitatively on CPU-only hardware: the
*convergence* curves come from real training runs; the *time axis* comes
from this model. The default constants are the paper's own measured 2020
setup (ResNet-18/CIFAR-10 on 16 × Titan X over 40 Gbps Ethernet):

    compute ≈ 4.6 s/epoch  (24-25 steps/epoch ⇒ ~0.19 s/step)
    fully-sync all-reduce ≈ 1.5 s/epoch (comm/compute ≈ 34.6% incl. overhead)
    PowerSGD rank-1 compresses 243× but keeps the handshake latency.

They are *defaults, not assumptions*: :func:`calibrated_config` rebuilds a
``RuntimeConfig`` from a production dry-run JSON — worker count from the
parallel plan, per-step compute from the roofline, collective time from the
measured boundary-collective bytes over a given link — and
:meth:`repro.fault.plan.FaultPlan.runtime_config` layers a fault plan's
straggler/jitter distributions on top (replacing the hardcoded straggler
knobs). :func:`simulate` accepts an optional ``fault_plan`` whose per-round
compute factors, crash windows, and network jitter drive the clocks: dead
workers drop out of barriers, rejoining workers resume at the round clock.

Blocking semantics per algorithm:
    sync_sgd   — barrier + blocking all-reduce every step
    powersgd   — barrier + blocking compressed all-reduce every step
    local_sgd  — barrier + blocking all-reduce every τ steps
    easgd      — same barrier structure as local_sgd (z update is synchronous
                 in [19] when run without its (rare) async variant)
    overlap_local_sgd / cocod — NON-blocking: collective launched at a
                 boundary is consumed at the next one; a worker only waits if
                 the collective is still in flight when it arrives there.
    gossip_*   — NON-blocking like overlap, but the barrier is per-worker:
                 worker i waits only on its *in-neighbors* for the round's
                 mixing matrix (:mod:`repro.core.topology`), and the
                 collective payload is priced by the topology degree —
                 t_handshake + (t_comm − t_handshake)·degree/(m−1), so the
                 degenerate fully-connected case prices exactly like the
                 global model. This is what lets the error–runtime figures
                 project to thousands-of-worker fleets, where a global
                 barrier is the wrong cost model (a ring worker at m=4096
                 still waits on 2 neighbors and ships 2 model copies).

Shared semantics across branches:
* a trailing ``steps % tau`` partial segment advances the clocks by its
  compute but runs no boundary (there is no round to average);
* an overlapped run's total includes the *final* boundary's in-flight
  collective — the last averaged model does not exist until it completes;
* an all-dead round (possible once crash windows are authoritative in
  :meth:`FaultPlan.mask_at`) skips its collective entirely: clocks advance
  by the round's compute and the round is counted in
  ``RuntimeResult.skipped_rounds``. This mirrors the live path, where
  :func:`repro.fault.membership.from_mask` refuses to build an all-dead
  boundary host-side — the simulator records the hole instead of raising
  mid-sweep.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field, replace
from typing import Dict, Optional

import numpy as np

BLOCKING = {"sync_sgd": 1, "powersgd": 1, "local_sgd": None, "easgd": None}
OVERLAPPED = ("overlap_local_sgd", "cocod")
# overlapped gossip strategies: per-worker neighbor barriers, degree-priced
# collectives; the topology comes from the name (or an explicit override)
GOSSIP = ("gossip_pushsum", "gossip_full", "gossip_ring", "gossip_exp")
_GOSSIP_TOPOLOGY = {
    "gossip_pushsum": "full",
    "gossip_full": "full",
    "gossip_ring": "ring",
    "gossip_exp": "exp",
}


@dataclass
class RuntimeConfig:
    m: int = 16
    t_step: float = 0.19  # mean compute time per local step (s)
    t_comm: float = 0.065  # full model all-reduce incl. handshake (s)
    t_handshake: float = 0.02  # fixed latency part of any collective
    straggle_std: float = 0.0  # lognormal sigma on per-step compute
    straggle_prob: float = 0.0  # probability of a step slowing by straggle_factor
    straggle_factor: float = 4.0
    powersgd_compression: float = 243.0  # rank-1 payload reduction
    powersgd_codec: float = 0.01  # encode+decode time per step (s)
    # host-offload stream (DESIGN.md §9): bytes moved over the host link per
    # round per worker (opt-state round trips × τ + anchor slots × 1) and the
    # measured link bandwidth; 0 disables the term (plane-resident runs)
    offload_bytes_per_round: float = 0.0
    offload_gbps: float = 0.0
    seed: int = 0


@dataclass
class RuntimeResult:
    total_time: float
    compute_time: float  # mean per-worker compute over the run
    exposed_comm: float  # communication NOT hidden behind compute
    idle_time: float  # straggler-induced waiting (per live worker)
    steps: int
    # critical-path compute: the slowest worker's total compute — the floor
    # no schedule can beat (total_time ≥ compute_critical always)
    compute_critical: float = 0.0
    # rounds whose collective was skipped because no worker was live
    skipped_rounds: int = 0
    # host-link transfer NOT hidden behind the τ-step window (offload stream)
    exposed_transfer: float = 0.0

    @property
    def comm_ratio(self) -> float:
        return self.exposed_comm / max(self.compute_time, 1e-12)


def _step_times(cfg: RuntimeConfig, rng, steps: int) -> np.ndarray:
    t = np.full((steps, cfg.m), cfg.t_step)
    if cfg.straggle_std > 0:
        t *= rng.lognormal(mean=0.0, sigma=cfg.straggle_std, size=(steps, cfg.m))
    if cfg.straggle_prob > 0:
        slow = rng.random((steps, cfg.m)) < cfg.straggle_prob
        t = np.where(slow, t * cfg.straggle_factor, t)
    return t


def calibrated_config(dryrun_json, *, link_gbps: float = 40.0, base: Optional[RuntimeConfig] = None) -> RuntimeConfig:
    """A :class:`RuntimeConfig` calibrated from a production dry-run JSON
    (``repro.launch.dryrun``) instead of the paper's 2020 constants.

    * ``m``       — the parallel plan's worker count.
    * ``t_step``  — the roofline's per-round device time (max of compute and
      memory terms) divided by τ: what one local step actually costs on the
      modelled hardware.
    * ``t_comm``  — the measured boundary-collective payload (falling back
      to the packed plane's x-buffer bytes when the boundary probe was
      skipped) over ``link_gbps``, plus the handshake.

    ``dryrun_json`` is a path or an already-loaded result dict; ``base``
    seeds every field not derivable from the JSON (straggler knobs, seed —
    typically :meth:`repro.fault.plan.FaultPlan.runtime_config` output so a
    fault plan's distributions ride on calibrated hardware constants).
    """
    if isinstance(dryrun_json, (str, os.PathLike)):
        with open(dryrun_json) as f:
            d = json.load(f)
    else:
        d = dryrun_json
    cfg = base if base is not None else RuntimeConfig()
    m = int((d.get("plan") or {}).get("workers", cfg.m))
    tau = int(d.get("tau") or 1)
    t_step = cfg.t_step
    roof = d.get("roofline") or {}
    t_round = max(float(roof.get("compute_s") or 0.0), float(roof.get("memory_s") or 0.0))
    if t_round > 0:
        t_step = t_round / max(tau, 1)
    coll_bytes = sum(float(v.get("bytes", 0)) for v in (d.get("boundary_collectives") or {}).values())
    if coll_bytes <= 0:
        coll_bytes = float((d.get("plane") or {}).get("x_buffer_bytes") or 0.0)
    t_comm = cfg.t_comm
    if coll_bytes > 0 and link_gbps > 0:
        t_comm = cfg.t_handshake + coll_bytes / (link_gbps * 1e9 / 8)
    # offloaded dry-runs carry their stream bytes + measured host-link
    # bandwidth; plane-resident JSONs leave both knobs at the base config
    off_bytes, off_gbps = cfg.offload_bytes_per_round, cfg.offload_gbps
    ob = d.get("offload") or {}
    if ob.get("enabled"):
        off_bytes = float(ob.get("stream_bytes_per_round_per_device") or 0.0)
        bw = ob.get("bandwidth") or {}
        rates = [float(bw[k]) for k in ("d2h_gbps", "h2d_gbps") if bw.get(k)]
        if rates:
            off_gbps = min(rates)
    return replace(
        cfg, m=m, t_step=t_step, t_comm=t_comm,
        offload_bytes_per_round=off_bytes, offload_gbps=off_gbps,
    )


def offload_stream_time(cfg: RuntimeConfig) -> float:
    """Seconds the host-offload stream needs per round per worker; 0 when
    the run is plane-resident (either knob unset)."""
    if cfg.offload_bytes_per_round <= 0 or cfg.offload_gbps <= 0:
        return 0.0
    return cfg.offload_bytes_per_round / (cfg.offload_gbps * 1e9)


def offload_schedule(bytes_per_round: float, gbps: float, tau: int, t_step: float) -> dict:
    """The overlap contract of the offload stream against one τ-step window,
    as a JSON-ready block (dry-run's ``offload.schedule``): exposed transfer
    is ``max(0, stream_s − τ·t_step)`` — zero (``hidden=True``) exactly when
    the window is long enough, and ``breakeven_tau`` is the smallest τ that
    hides the stream at this bandwidth and step time."""
    stream_s = bytes_per_round / (gbps * 1e9) if gbps > 0 else float("inf")
    window_s = float(tau) * float(t_step)
    exposed_s = max(0.0, stream_s - window_s)
    breakeven = int(np.ceil(stream_s / t_step)) if t_step > 0 and np.isfinite(stream_s) else None
    return dict(
        stream_bytes_per_round=float(bytes_per_round),
        link_gbps=float(gbps),
        stream_s=stream_s,
        window_s=window_s,
        exposed_s=exposed_s,
        hidden=bool(exposed_s == 0.0),
        breakeven_tau=breakeven,
    )


def _fault_round(r: int, m: int, fault_plan):
    """(live mask, comm-jitter factor) for round r; trivial without a plan."""
    if fault_plan is None:
        return np.ones(m, bool), 1.0
    return fault_plan.mask_at(r), fault_plan.comm_jitter(r)


def gossip_comm_time(cfg: RuntimeConfig, degree: int) -> float:
    """Per-round collective time for a degree-d neighbor exchange: the fixed
    handshake plus the payload term scaled by how many model copies a worker
    actually ships — degree/(m−1) of the fully-connected payload, so the
    degenerate ``full`` topology prices exactly ``t_comm``."""
    return cfg.t_handshake + (cfg.t_comm - cfg.t_handshake) * (degree / max(cfg.m - 1, 1))


def simulate(algo: str, tau: int, steps: int, cfg: RuntimeConfig, fault_plan=None, topology=None) -> RuntimeResult:
    """``fault_plan`` (:class:`repro.fault.plan.FaultPlan`, optional) drives
    degraded rounds: its per-round compute factors scale the step times, its
    crash windows + straggler deadlines take workers out of barriers (the
    deadline policy — an excluded worker cannot hold the round), its network
    jitter scales each round's collective, and a rejoining worker resumes at
    the round clock (the anchor re-sync). Without a plan the clocks are the
    historical fully-live model, value for value.

    ``topology`` (:class:`repro.core.topology.Topology` or a name string)
    selects the gossip barrier structure for the ``gossip_*`` algorithms;
    by default it is derived from the algorithm name over ``cfg.m`` workers.
    """
    rng = np.random.default_rng(cfg.seed)
    t = _step_times(cfg, rng, steps)
    m = cfg.m

    comm = cfg.t_comm
    if algo == "powersgd":
        comm = cfg.t_handshake + (cfg.t_comm - cfg.t_handshake) / cfg.powersgd_compression + cfg.powersgd_codec
    if algo == "sync_sgd" or algo == "powersgd":
        tau = 1

    rounds = steps // tau
    if fault_plan is not None:
        if fault_plan.m != m:
            raise ValueError(f"fault plan is over m={fault_plan.m} workers, config has m={m}")
        if rounds > 0:
            factors = np.stack([fault_plan.round_compute_factors(r) for r in range(rounds)])
            t[: rounds * tau] *= np.repeat(factors, tau, axis=0)

    compute_critical = float(t.sum(axis=0).max())  # critical-path compute
    mean_compute = float(t.sum(axis=0).mean())
    # host-offload stream: a round's window cannot close before its stream
    # lands, so each worker's segment is max(compute, stream) — the excess is
    # exposed transfer. The trailing partial segment (no boundary, partial
    # stream) is left un-stretched: conservative by < one round.
    stream_s = offload_stream_time(cfg)
    exposed_transfer = 0.0
    # the trailing steps % tau partial segment: pure local compute, no
    # boundary — every branch advances the clocks by it after its last round
    tail = t[rounds * tau :].sum(axis=0) if steps > rounds * tau else None

    if algo in ("sync_sgd", "powersgd", "local_sgd", "easgd"):
        # barrier every tau steps (over LIVE workers only), then blocking
        # collective; dead/excluded workers rejoin at the round clock
        exposed = 0.0
        idle = 0.0
        skipped = 0
        worker_clock = np.zeros(m)
        for r in range(rounds):
            seg = t[r * tau : (r + 1) * tau].sum(axis=0)
            if stream_s > 0:
                lag = np.maximum(stream_s - seg, 0.0)
                exposed_transfer += float(lag.max())
                seg = seg + lag
            live, jitter = _fault_round(r, m, fault_plan)
            arrive = worker_clock + seg
            if not live.any():
                # all-dead round: no barrier, no collective — the live path
                # (Membership.from_mask) refuses such a boundary host-side;
                # here the clocks advance by local compute and move on
                skipped += 1
                worker_clock = arrive
                continue
            barrier = arrive[live].max()
            idle += float((barrier - arrive[live]).sum()) / max(int(live.sum()), 1)
            c = comm * jitter
            exposed += c
            worker_clock = np.full(m, barrier + c)
        if tail is not None:
            worker_clock = worker_clock + tail
        total = float(worker_clock.max())
        return RuntimeResult(total, mean_compute, exposed, idle, steps, compute_critical, skipped, exposed_transfer)

    if algo in OVERLAPPED or algo in GOSSIP or topology is not None:
        # non-blocking: the collective launched at boundary r completes comm
        # seconds after every contribution exists; a worker blocks at
        # boundary r+1 only if the completion it must consume is still in
        # flight when it arrives there. The global algorithms wait on (and
        # contribute to) ALL live workers; gossip workers wait only on their
        # live in-neighbors for the round's mixing matrix, and ship a
        # degree-priced payload.
        topo = None
        if algo in GOSSIP or topology is not None:
            from repro.core.topology import Topology, make_topology

            topo = topology or _GOSSIP_TOPOLOGY.get(algo, "full")
            if not isinstance(topo, Topology):
                topo = make_topology(str(topo), m)
            if topo.m != m:
                raise ValueError(f"topology is over m={topo.m} workers, config has m={m}")
            comm = gossip_comm_time(cfg, topo.degree)
        worker_clock = np.zeros(m)
        ready = np.zeros(m)  # per-worker completion time of the in-flight collective
        exposed = 0.0
        idle = 0.0
        skipped = 0
        for r in range(rounds):
            seg = t[r * tau : (r + 1) * tau].sum(axis=0)
            if stream_s > 0:
                lag = np.maximum(stream_s - seg, 0.0)
                exposed_transfer += float(lag.max())
                seg = seg + lag
            live, jitter = _fault_round(r, m, fault_plan)
            if not live.any():
                # all-dead round: nothing launched, nothing consumed; any
                # in-flight collective stays in flight for the next round
                skipped += 1
                worker_clock = worker_clock + seg
                continue
            arrive = worker_clock + seg
            # wait (only) for the previous round's collective
            stall = np.maximum(ready - arrive, 0.0)
            exposed += float(stall[live].max())
            idle += float(stall[live].mean())
            advanced = arrive + stall
            round_clock = float(advanced[live].max())
            if topo is None:
                # global collective: complete once all LIVE contributions
                # exist; excluded workers park at the round clock (re-sync)
                # and — like the live path's anchor re-sync — consume the
                # same collective as everyone else on rejoin
                ready = np.full(m, round_clock + comm * jitter)
            else:
                # per-worker neighbor-set barrier: worker i's mix completes
                # once its live in-neighbors (self included) have advanced
                nb = topo.in_mask(r) & live[None, :]
                vals = np.where(nb, advanced[None, :], -np.inf)
                recv = vals.max(axis=1)
                recv = np.where(np.isfinite(recv), recv, advanced)
                ready = np.where(live, recv + comm * jitter, ready)
            worker_clock = np.where(live, advanced, round_clock)
        if tail is not None:
            worker_clock = worker_clock + tail
        # the final boundary's collective is still in flight at the last
        # arrival: the run is not done until it lands (the last averaged
        # model does not exist before then)
        final_wait = max(0.0, float(ready.max()) - float(worker_clock.max()))
        exposed += final_wait
        total = float(worker_clock.max()) + final_wait
        return RuntimeResult(total, mean_compute, exposed, idle, steps, compute_critical, skipped, exposed_transfer)

    raise ValueError(algo)


def epoch_summary(
    algo: str, tau: int, steps_per_epoch: int, cfg: RuntimeConfig, fault_plan=None, topology=None
) -> Dict[str, float]:
    r = simulate(algo, tau, steps_per_epoch, cfg, fault_plan=fault_plan, topology=topology)
    return dict(
        algo=algo,
        tau=tau,
        epoch_time=r.total_time,
        compute=r.compute_time,
        compute_critical=r.compute_critical,
        exposed_comm=r.exposed_comm,
        exposed_transfer=r.exposed_transfer,
        comm_ratio=r.comm_ratio,
        idle=r.idle_time,
    )
