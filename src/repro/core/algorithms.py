"""Legacy single-hook distributed-optimization algorithms (deprecated shim).

This module is kept as a thin compatibility layer: new code should use the
two-phase :class:`repro.core.strategy.CommStrategy` protocol, where the round
boundary is explicitly split into ``boundary_apply`` (consume the collective
launched last round — eq. 4) and ``boundary_launch`` (start this round's
collective — eq. 5), with the launched-but-unconsumed value carried in
``TrainState.inflight``. Here, by contrast, the overlap property is only
*implicit* in the statement ordering inside ``boundary`` — which is exactly
why the API was redesigned.

The classes below remain the bit-exact reference semantics of the seed:
``repro.training`` wraps them in :class:`repro.core.strategy.LegacyStrategy`
(all work in the apply phase, nothing launched) and the golden equivalence
tests in ``tests/test_strategies.py`` check the native ports against them.

State layout (matches DESIGN.md §3): per-worker quantities carry a leading
worker axis m; the anchor z (and its momentum v) are *unstacked* — they are
identical across workers by construction, so on a mesh they are stored fully
sharded (worker+fsdp axes) and materialize only inside the pullback.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config.base import AlgoConfig
from repro.core.strategy import (  # shared primitives live with the new protocol
    AlgoVars,
    _broadcast_like,
    _constrain_anchor,
    _pullback,
    _worker_mean,
    x_stacked_leading,
)
from repro.utils.tree import tree_lerp


class Algorithm:
    """Base: plain Local SGD behaviour is 'do nothing' hooks. Deprecated —
    subclass :class:`repro.core.strategy.CommStrategy` instead."""

    name = "base"
    needs_anchor = False

    def __init__(self, cfg: AlgoConfig):
        self.cfg = cfg
        self.tau = cfg.tau

    # ---- state ----
    def init_vars(self, x_stacked, axes_tree=None) -> AlgoVars:
        return AlgoVars()

    # ---- per-step hook ----
    def transform_grads(self, grads_stacked, vars: AlgoVars):
        return grads_stacked, vars

    # ---- per-round hook ----
    def boundary(self, x_stacked, vars: AlgoVars, axes_tree=None):
        return x_stacked, vars

    def metrics(self, x_stacked, vars: AlgoVars) -> dict:
        mean = _worker_mean(x_stacked)
        dev = jax.tree.map(lambda xi, mi: jnp.sum(jnp.square(xi.astype(jnp.float32) - mi[None].astype(jnp.float32))), x_stacked, mean)
        total = sum(jax.tree.leaves(dev)) / max(x_stacked_leading(x_stacked), 1)
        return {"consensus_dist": total}


# ---------------------------------------------------------------------------


class SyncSGD(Algorithm):
    """Fully synchronous SGD: gradients averaged across workers every step."""

    name = "sync_sgd"

    def __init__(self, cfg: AlgoConfig):
        super().__init__(cfg)
        self.tau = 1

    def transform_grads(self, grads_stacked, vars):
        g = _worker_mean(grads_stacked)
        return _broadcast_like(g, grads_stacked), vars


class LocalSGD(Algorithm):
    """Periodic model averaging (blocking) — eq. (2) of the paper."""

    name = "local_sgd"

    def boundary(self, x_stacked, vars, axes_tree=None):
        avg = _worker_mean(x_stacked)
        return _broadcast_like(avg, x_stacked), vars


class OverlapLocalSGD(Algorithm):
    """The paper's algorithm (+ momentum variant when anchor_beta > 0).

    boundary order (one jitted program per round, or a scan of rounds):
      1. pullback with the anchor from the PREVIOUS boundary   (eq. 4, no comm)
      2. new anchor = mean over workers of pulled-back models  (eq. 5)
         — momentum variant: v ← β·v + (mean − z); z ← z + v   (eqs. 10–11)
      3. the new anchor's first consumer is next round's pullback
         ⇒ the collective overlaps the next τ local steps.
    """

    name = "overlap_local_sgd"
    needs_anchor = True

    def init_vars(self, x_stacked, axes_tree=None) -> AlgoVars:
        z = jax.tree.map(lambda t: t[0], x_stacked)  # all workers initialized equal
        z = _constrain_anchor(z, axes_tree)
        v = None
        if self.cfg.anchor_beta > 0:
            v = jax.tree.map(jnp.zeros_like, z)
        return AlgoVars(z=z, v=v)

    def boundary(self, x_stacked, vars: AlgoVars, axes_tree=None):
        alpha = self.cfg.alpha
        z_stale = vars.z
        # (1) pullback toward the stale anchor — local, no communication
        x_new = _pullback(x_stacked, z_stale, alpha)
        # (2) anchor sync (overlapped): consumed only at the next boundary
        mean_x = _worker_mean(x_new)
        if vars.v is not None:
            beta = self.cfg.anchor_beta
            v_new = jax.tree.map(
                lambda v, m, z: (beta * v.astype(jnp.float32) + (m.astype(jnp.float32) - z.astype(jnp.float32))).astype(v.dtype),
                vars.v,
                mean_x,
                z_stale,
            )
            z_new = jax.tree.map(lambda z, v: (z.astype(jnp.float32) + v.astype(jnp.float32)).astype(z.dtype), z_stale, v_new)
        else:
            v_new = None
            z_new = mean_x
        z_new = _constrain_anchor(z_new, axes_tree)
        return x_new, AlgoVars(z=z_new, v=v_new, extra=vars.extra)


class EASGD(Algorithm):
    """Elastic-averaging SGD [19] (EAMSGD when the local optimizer has
    momentum): symmetric doubly-stochastic mixing between local models and
    the anchor, z updated with moving rate — communication is blocking in
    the original formulation."""

    name = "easgd"
    needs_anchor = True

    def init_vars(self, x_stacked, axes_tree=None) -> AlgoVars:
        z = jax.tree.map(lambda t: t[0], x_stacked)
        return AlgoVars(z=_constrain_anchor(z, axes_tree))

    def boundary(self, x_stacked, vars: AlgoVars, axes_tree=None):
        alpha = self.cfg.alpha
        z = vars.z
        x_new = _pullback(x_stacked, z, alpha)
        # symmetric update: z ← z + α·Σ_i (x_i − z) = (1−mα)z + mα·mean(x)
        m = x_stacked_leading(x_stacked)
        rate = min(alpha * m, 1.0)
        mean_x = _worker_mean(x_stacked)  # pre-pullback models (symmetric W)
        z_new = tree_lerp(z, mean_x, rate)
        z_new = _constrain_anchor(z_new, axes_tree)
        return x_new, AlgoVars(z=z_new, v=None, extra=vars.extra)


class CoCoDSGD(Algorithm):
    """CoCoD-SGD [20]: at each boundary, relaunch an average of the round's
    *starting* models while local deltas accumulate; apply
    x_i ← avg(x_start) + (x_i − x_start_i). Decoupled like Overlap-Local-SGD
    but without the pullback contraction."""

    name = "cocod"

    def init_vars(self, x_stacked, axes_tree=None) -> AlgoVars:
        # extra = x at the start of the current round (consumed at boundary)
        return AlgoVars(extra=jax.tree.map(jnp.copy, x_stacked))

    def boundary(self, x_stacked, vars: AlgoVars, axes_tree=None):
        x_start = vars.extra
        avg_start = _worker_mean(x_start)  # the overlapped collective
        x_new = jax.tree.map(
            lambda xi, xs, av: (av[None].astype(jnp.float32) + xi.astype(jnp.float32) - xs.astype(jnp.float32)).astype(xi.dtype),
            x_stacked,
            x_start,
            avg_start,
        )
        return x_new, AlgoVars(extra=jax.tree.map(jnp.copy, x_new))


def make_algorithm(cfg: AlgoConfig) -> Algorithm:
    """Deprecated (oracle-only): use :func:`repro.core.strategy.make_strategy`,
    which also covers the delayed-averaging and sparse-anchor strategies the
    legacy single-hook API cannot express. The objects built here remain the
    bit-exact reference the golden equivalence tests pin the native
    strategies against — that is their only supported use."""
    warnings.warn(
        "make_algorithm() builds the deprecated single-hook Algorithm shim (oracle-only); "
        "use repro.core.make_strategy instead",
        DeprecationWarning,
        stacklevel=2,
    )
    table = {
        "overlap_local_sgd": OverlapLocalSGD,
        "local_sgd": LocalSGD,
        "sync_sgd": SyncSGD,
        "easgd": EASGD,
        "cocod": CoCoDSGD,
    }
    if cfg.name == "powersgd":
        from repro.core.powersgd import PowerSGD

        return PowerSGD(cfg)
    if cfg.name not in table:
        raise ValueError(f"unknown algorithm {cfg.name!r}; known: {sorted(table) + ['powersgd']}")
    return table[cfg.name](cfg)
