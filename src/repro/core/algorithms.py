"""Distributed-optimization algorithms: Overlap-Local-SGD and all baselines
the paper compares against.

State layout (matches DESIGN.md §3): per-worker quantities carry a leading
worker axis m; the anchor z (and its momentum v) are *unstacked* — they are
identical across workers by construction, so on a mesh they are stored fully
sharded (worker+fsdp axes) and materialize only inside the pullback.

Each algorithm is a small set of pure hooks consumed by the round engine in
``repro.training.train_loop``:

    transform_grads(g_stacked)     per local step (sync-SGD/PowerSGD live here)
    boundary(x, opt, vars, cfg)    every τ steps (pullback / averaging / anchor sync)

The overlap property is *structural*: ``boundary`` for Overlap-Local-SGD
first applies the pullback using the anchor computed at the PREVIOUS
boundary (paper eq. (4) with z_k), then computes the new anchor mean (eq.
(5)) whose only consumer is the NEXT round's pullback — τ local steps of
compute sit between the reduce-scatter and its consumer, which is exactly
the window XLA's latency-hiding scheduler uses to run the collective in the
background (the paper's "communication thread").
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config.base import AlgoConfig
from repro.kernels.anchor_mix import ops as anchor_ops
from repro.parallel import anchor_axes, constrain, current_mesh, sharding_for, spec_for
from repro.utils.tree import tree_lerp


class AlgoVars(NamedTuple):
    """Algorithm-specific slots (unused slots are empty dicts/None)."""

    z: Any = None  # anchor model (overlap, easgd) — unstacked
    v: Any = None  # anchor momentum (overlap momentum variant)
    extra: Any = None  # powersgd (Q, error) / cocod pending average


def _worker_mean(x_stacked):
    """Average over the worker axis; on a mesh this is the paper's model
    all-reduce (lowered as reduce-scatter when the consumer is sharded)."""
    return jax.tree.map(lambda t: jnp.mean(t.astype(jnp.float32), axis=0).astype(t.dtype), x_stacked)


def _broadcast_like(z, x_stacked):
    return jax.tree.map(lambda zi, xi: jnp.broadcast_to(zi[None], xi.shape), z, x_stacked)


def _constrain_anchor(z, axes_tree):
    """Pin the anchor to its fully-sharded layout (reduce-scatter target)."""
    mesh = current_mesh()
    if mesh is None or axes_tree is None:
        return z
    from repro.parallel.sharding import fit_spec, spec_for
    from jax.sharding import NamedSharding

    a_axes = anchor_axes(axes_tree)

    def one(t, ax):
        spec = fit_spec(spec_for(ax), t.shape, mesh)
        return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))

    return jax.tree.map(
        one,
        z,
        a_axes,
        is_leaf=lambda t: isinstance(t, tuple) and all(isinstance(e, (str, type(None))) for e in t),
    )


def _pullback(x_stacked, z, alpha: float):
    """Paper eq. (4): x_i ← (1−α)·x_i + α·z, for every worker i (fused
    anchor-mix kernel on TPU)."""
    return jax.vmap(lambda xi: anchor_ops.pullback_tree(xi, z, alpha))(x_stacked)


class Algorithm:
    """Base: plain Local SGD behaviour is 'do nothing' hooks."""

    name = "base"
    needs_anchor = False

    def __init__(self, cfg: AlgoConfig):
        self.cfg = cfg
        self.tau = cfg.tau

    # ---- state ----
    def init_vars(self, x_stacked, axes_tree=None) -> AlgoVars:
        return AlgoVars()

    # ---- per-step hook ----
    def transform_grads(self, grads_stacked, vars: AlgoVars):
        return grads_stacked, vars

    # ---- per-round hook ----
    def boundary(self, x_stacked, vars: AlgoVars, axes_tree=None):
        return x_stacked, vars

    def metrics(self, x_stacked, vars: AlgoVars) -> dict:
        mean = _worker_mean(x_stacked)
        dev = jax.tree.map(lambda xi, mi: jnp.sum(jnp.square(xi.astype(jnp.float32) - mi[None].astype(jnp.float32))), x_stacked, mean)
        total = sum(jax.tree.leaves(dev)) / max(x_stacked_leading(x_stacked), 1)
        return {"consensus_dist": total}


def x_stacked_leading(x_stacked) -> int:
    leaves = jax.tree.leaves(x_stacked)
    return int(leaves[0].shape[0]) if leaves else 1


# ---------------------------------------------------------------------------


class SyncSGD(Algorithm):
    """Fully synchronous SGD: gradients averaged across workers every step."""

    name = "sync_sgd"

    def __init__(self, cfg: AlgoConfig):
        super().__init__(cfg)
        self.tau = 1

    def transform_grads(self, grads_stacked, vars):
        g = _worker_mean(grads_stacked)
        return _broadcast_like(g, grads_stacked), vars


class LocalSGD(Algorithm):
    """Periodic model averaging (blocking) — eq. (2) of the paper."""

    name = "local_sgd"

    def boundary(self, x_stacked, vars, axes_tree=None):
        avg = _worker_mean(x_stacked)
        return _broadcast_like(avg, x_stacked), vars


class OverlapLocalSGD(Algorithm):
    """The paper's algorithm (+ momentum variant when anchor_beta > 0).

    boundary order (one jitted program per round, or a scan of rounds):
      1. pullback with the anchor from the PREVIOUS boundary   (eq. 4, no comm)
      2. new anchor = mean over workers of pulled-back models  (eq. 5)
         — momentum variant: v ← β·v + (mean − z); z ← z + v   (eqs. 10–11)
      3. the new anchor's first consumer is next round's pullback
         ⇒ the collective overlaps the next τ local steps.
    """

    name = "overlap_local_sgd"
    needs_anchor = True

    def init_vars(self, x_stacked, axes_tree=None) -> AlgoVars:
        z = jax.tree.map(lambda t: t[0], x_stacked)  # all workers initialized equal
        z = _constrain_anchor(z, axes_tree)
        v = None
        if self.cfg.anchor_beta > 0:
            v = jax.tree.map(jnp.zeros_like, z)
        return AlgoVars(z=z, v=v)

    def boundary(self, x_stacked, vars: AlgoVars, axes_tree=None):
        alpha = self.cfg.alpha
        z_stale = vars.z
        # (1) pullback toward the stale anchor — local, no communication
        x_new = _pullback(x_stacked, z_stale, alpha)
        # (2) anchor sync (overlapped): consumed only at the next boundary
        mean_x = _worker_mean(x_new)
        if vars.v is not None:
            beta = self.cfg.anchor_beta
            v_new = jax.tree.map(
                lambda v, m, z: (beta * v.astype(jnp.float32) + (m.astype(jnp.float32) - z.astype(jnp.float32))).astype(v.dtype),
                vars.v,
                mean_x,
                z_stale,
            )
            z_new = jax.tree.map(lambda z, v: (z.astype(jnp.float32) + v.astype(jnp.float32)).astype(z.dtype), z_stale, v_new)
        else:
            v_new = None
            z_new = mean_x
        z_new = _constrain_anchor(z_new, axes_tree)
        return x_new, AlgoVars(z=z_new, v=v_new, extra=vars.extra)


class EASGD(Algorithm):
    """Elastic-averaging SGD [19] (EAMSGD when the local optimizer has
    momentum): symmetric doubly-stochastic mixing between local models and
    the anchor, z updated with moving rate — communication is blocking in
    the original formulation."""

    name = "easgd"
    needs_anchor = True

    def init_vars(self, x_stacked, axes_tree=None) -> AlgoVars:
        z = jax.tree.map(lambda t: t[0], x_stacked)
        return AlgoVars(z=_constrain_anchor(z, axes_tree))

    def boundary(self, x_stacked, vars: AlgoVars, axes_tree=None):
        alpha = self.cfg.alpha
        z = vars.z
        x_new = _pullback(x_stacked, z, alpha)
        # symmetric update: z ← z + α·Σ_i (x_i − z) = (1−mα)z + mα·mean(x)
        m = x_stacked_leading(x_stacked)
        rate = min(alpha * m, 1.0)
        mean_x = _worker_mean(x_stacked)  # pre-pullback models (symmetric W)
        z_new = tree_lerp(z, mean_x, rate)
        z_new = _constrain_anchor(z_new, axes_tree)
        return x_new, AlgoVars(z=z_new, v=None, extra=vars.extra)


class CoCoDSGD(Algorithm):
    """CoCoD-SGD [20]: at each boundary, relaunch an average of the round's
    *starting* models while local deltas accumulate; apply
    x_i ← avg(x_start) + (x_i − x_start_i). Decoupled like Overlap-Local-SGD
    but without the pullback contraction."""

    name = "cocod"

    def init_vars(self, x_stacked, axes_tree=None) -> AlgoVars:
        # extra = x at the start of the current round (consumed at boundary)
        return AlgoVars(extra=jax.tree.map(jnp.copy, x_stacked))

    def boundary(self, x_stacked, vars: AlgoVars, axes_tree=None):
        x_start = vars.extra
        avg_start = _worker_mean(x_start)  # the overlapped collective
        x_new = jax.tree.map(
            lambda xi, xs, av: (av[None].astype(jnp.float32) + xi.astype(jnp.float32) - xs.astype(jnp.float32)).astype(xi.dtype),
            x_stacked,
            x_start,
            avg_start,
        )
        return x_new, AlgoVars(extra=jax.tree.map(jnp.copy, x_new))


def make_algorithm(cfg: AlgoConfig) -> Algorithm:
    table = {
        "overlap_local_sgd": OverlapLocalSGD,
        "local_sgd": LocalSGD,
        "sync_sgd": SyncSGD,
        "easgd": EASGD,
        "cocod": CoCoDSGD,
    }
    if cfg.name == "powersgd":
        from repro.core.powersgd import PowerSGD

        return PowerSGD(cfg)
    if cfg.name not in table:
        raise ValueError(f"unknown algorithm {cfg.name!r}; known: {sorted(table) + ['powersgd']}")
    return table[cfg.name](cfg)
