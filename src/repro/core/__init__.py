"""Distributed-optimization core.

Two API generations live here:

* ``repro.core.strategy`` — the two-phase :class:`CommStrategy` protocol
  (``boundary_apply`` consumes last round's collective, ``boundary_launch``
  starts this round's; the launched value rides in ``TrainState.inflight``).
  This is the current API; :func:`make_strategy` is the factory, and the
  production surfaces (``repro.api.Experiment``, ``launch/dryrun.py``,
  ``launch/costprobe.py``) resolve exclusively through it.
* ``repro.core.algorithms`` — the legacy single-``boundary``-hook
  ``Algorithm`` classes. **Deprecated, oracle-only**: they remain solely as
  the bit-exact reference semantics the golden equivalence tests compare
  against. Importing any legacy name from ``repro.core`` emits a
  ``DeprecationWarning`` (PEP 562 lazy export below), as does calling
  :func:`make_algorithm` itself. No non-test production code imports them.
"""
import warnings

from repro.core.strategy import (
    AlgoVars,
    CommStrategy,
    CoCoDStrategy,
    DelayedAveragingStrategy,
    EASGDStrategy,
    GossipExpStrategy,
    GossipFullStrategy,
    GossipInflight,
    GossipPushSumStrategy,
    GossipRingStrategy,
    LegacyStrategy,
    LocalSGDStrategy,
    OverlapLocalSGDStrategy,
    PowerSGDStrategy,
    SparseAnchorStrategy,
    SyncSGDStrategy,
    STRATEGIES,
    as_strategy,
    make_strategy,
    resolve_strategy,
    sparsify_topk,
)
from repro.core import mixing, runtime_model, topology

# Legacy names are served lazily so that merely importing repro.core never
# touches the deprecated module, and pulling one of them out warns at the
# import site (``from repro.core import make_algorithm`` → DeprecationWarning).
_LEGACY_NAMES = (
    "Algorithm",
    "CoCoDSGD",
    "EASGD",
    "LocalSGD",
    "OverlapLocalSGD",
    "SyncSGD",
    "make_algorithm",
)


def __getattr__(name):
    if name in _LEGACY_NAMES:
        warnings.warn(
            f"repro.core.{name} is the deprecated single-hook Algorithm shim, kept only "
            "as the bit-exact oracle for the golden equivalence tests; use "
            "repro.core.make_strategy / the two-phase CommStrategy protocol instead",
            DeprecationWarning,
            stacklevel=2,
        )
        from repro.core import algorithms

        return getattr(algorithms, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LEGACY_NAMES))


__all__ = [
    "Algorithm",
    "AlgoVars",
    "CoCoDSGD",
    "CoCoDStrategy",
    "CommStrategy",
    "DelayedAveragingStrategy",
    "EASGD",
    "EASGDStrategy",
    "GossipExpStrategy",
    "GossipFullStrategy",
    "GossipInflight",
    "GossipPushSumStrategy",
    "GossipRingStrategy",
    "LegacyStrategy",
    "LocalSGD",
    "LocalSGDStrategy",
    "OverlapLocalSGD",
    "OverlapLocalSGDStrategy",
    "PowerSGDStrategy",
    "STRATEGIES",
    "SparseAnchorStrategy",
    "SyncSGD",
    "SyncSGDStrategy",
    "as_strategy",
    "make_algorithm",
    "make_strategy",
    "mixing",
    "resolve_strategy",
    "runtime_model",
    "sparsify_topk",
    "topology",
]
