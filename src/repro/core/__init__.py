from repro.core.algorithms import (
    Algorithm,
    AlgoVars,
    CoCoDSGD,
    EASGD,
    LocalSGD,
    OverlapLocalSGD,
    SyncSGD,
    make_algorithm,
)
from repro.core import mixing, runtime_model

__all__ = [
    "Algorithm",
    "AlgoVars",
    "CoCoDSGD",
    "EASGD",
    "LocalSGD",
    "OverlapLocalSGD",
    "SyncSGD",
    "make_algorithm",
    "mixing",
    "runtime_model",
]
