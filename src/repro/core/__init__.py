"""Distributed-optimization core.

Two API generations live here:

* ``repro.core.strategy`` — the two-phase :class:`CommStrategy` protocol
  (``boundary_apply`` consumes last round's collective, ``boundary_launch``
  starts this round's; the launched value rides in ``TrainState.inflight``).
  This is the current API; :func:`make_strategy` is the factory.
* ``repro.core.algorithms`` — the legacy single-``boundary``-hook
  ``Algorithm`` classes, kept as a deprecation shim and as the bit-exact
  reference the golden equivalence tests compare against.
"""
from repro.core.algorithms import (
    Algorithm,
    CoCoDSGD,
    EASGD,
    LocalSGD,
    OverlapLocalSGD,
    SyncSGD,
    make_algorithm,
)
from repro.core.strategy import (
    AlgoVars,
    CommStrategy,
    CoCoDStrategy,
    DelayedAveragingStrategy,
    EASGDStrategy,
    LegacyStrategy,
    LocalSGDStrategy,
    OverlapLocalSGDStrategy,
    PowerSGDStrategy,
    SparseAnchorStrategy,
    SyncSGDStrategy,
    STRATEGIES,
    as_strategy,
    make_strategy,
    sparsify_topk,
)
from repro.core import mixing, runtime_model

__all__ = [
    "Algorithm",
    "AlgoVars",
    "CoCoDSGD",
    "CoCoDStrategy",
    "CommStrategy",
    "DelayedAveragingStrategy",
    "EASGD",
    "EASGDStrategy",
    "LegacyStrategy",
    "LocalSGD",
    "LocalSGDStrategy",
    "OverlapLocalSGD",
    "OverlapLocalSGDStrategy",
    "PowerSGDStrategy",
    "STRATEGIES",
    "SparseAnchorStrategy",
    "SyncSGD",
    "SyncSGDStrategy",
    "as_strategy",
    "make_algorithm",
    "make_strategy",
    "mixing",
    "runtime_model",
    "sparsify_topk",
]
