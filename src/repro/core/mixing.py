"""Mixing-matrix theory utilities (paper §2 matrix form + Appendix A).

The Overlap-Local-SGD boundary is X_{k+1} = [X_k − γ G_k] W_k with the
column-stochastic matrix

    P = [ (1−α)I          (1−α)1/m ]
        [ α·1ᵀ             α       ]      ∈ R^{(m+1)×(m+1)}

These helpers build P, its fixed vector v = [(1−α)1/m, α], the contraction
factor ζ = ‖P − v·1ᵀ‖₂ (Appendix A proves ζ ≤ 1−α via the PageRank
decomposition P = (1−α)A + α·b·1ᵀ), and a dense matrix-form simulator used
by the property tests to verify the *implementation* matches the paper's
algebra exactly (virtual sequence identity, eq. (19)).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def mixing_matrix(m: int, alpha: float) -> np.ndarray:
    P = np.zeros((m + 1, m + 1))
    P[:m, :m] = (1 - alpha) * np.eye(m)
    P[:m, m] = (1 - alpha) / m
    P[m, :m] = alpha
    P[m, m] = alpha
    return P


def fixed_vector(m: int, alpha: float) -> np.ndarray:
    v = np.full(m + 1, (1 - alpha) / m)
    v[m] = alpha
    return v


def zeta(P: np.ndarray, v: np.ndarray) -> float:
    one = np.ones(P.shape[0])
    return float(np.linalg.norm(P - np.outer(v, one), 2))


def easgd_mixing_matrix(m: int, alpha: float) -> np.ndarray:
    """EASGD's symmetric doubly-stochastic counterpart (for comparison).

    x_i ← x_i − ρ(x_i − z); z ← z + ρ Σ_i (x_i − z) with ρ = α/m (the
    original paper's stability regime ρ ≤ 1/m keeps W doubly stochastic)."""
    rho = alpha / m
    P = np.zeros((m + 1, m + 1))
    P[:m, :m] = (1 - rho) * np.eye(m)
    P[:m, m] = rho
    P[m, :m] = rho
    P[m, m] = 1 - m * rho
    return P


class MatrixFormSim:
    """Dense simulator of eq. (8): X_{k+1} = (X_k − γ G_k) W_k.

    X ∈ R^{d×(m+1)} stacks the m local models and the anchor (last column).
    Used by tests to check the production implementation step-for-step.
    """

    def __init__(self, x0: np.ndarray, m: int, alpha: float, tau: int, gamma: float):
        d = x0.shape[0]
        self.X = np.tile(x0[:, None], (1, m + 1))
        self.m, self.alpha, self.tau, self.gamma = m, alpha, tau, gamma
        self.P = mixing_matrix(m, alpha)
        self.k = 0

    def step(self, grads: np.ndarray) -> None:
        """grads: (d, m) per-worker stochastic gradients at the current X."""
        G = np.concatenate([grads, np.zeros((grads.shape[0], 1))], axis=1)
        Xh = self.X - self.gamma * G
        if (self.k + 1) % self.tau == 0:
            self.X = Xh @ self.P
        else:
            self.X = Xh
        self.k += 1

    @property
    def locals(self) -> np.ndarray:
        return self.X[:, : self.m]

    @property
    def anchor(self) -> np.ndarray:
        return self.X[:, self.m]

    def virtual_sequence(self) -> np.ndarray:
        """y_k = (1−α)/m Σ x_i + α z (paper, below eq. (12))."""
        v = fixed_vector(self.m, self.alpha)
        return self.X @ v
