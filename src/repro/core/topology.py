"""Sparse gossip topologies: static mixing matrices over the worker axis.

The paper's boundary collective is fully-connected — every worker waits on
the mean of *all* workers. Stochastic Gradient Push (arXiv 1811.10792,
PAPERS.md) generalizes the same anchor-pullback structure to sparse,
possibly asymmetric neighbor exchanges described by a **column-stochastic
mixing matrix** P: column j says how worker j distributes its mass among
its out-neighbors (Σ_i P[i,j] = 1), and worker i's received mix is

    mix_i = Σ_j P[i,j] · x_j.

This module owns the matrices; :class:`repro.core.strategy.GossipPushSumStrategy`
owns the push-weight recursion that debiases them, and
:mod:`repro.core.runtime_model` prices their neighbor-set barriers.

Three families, all with self-loops (P[j,j] > 0, so a worker never hands
away all of its own mass) and all **doubly stochastic when fully live** —
push weights then stay at their fixed point w ≡ 1 and the gossip mix is a
plain convex neighbor average:

* ``full`` — P = 1/m everywhere: one phase, the degenerate case. Composed
  with a membership mask its rows are exactly the renormalized
  ``Membership.weights``, i.e. the existing masked worker mean.
* ``ring`` — one static phase; each worker averages with its two ring
  neighbors (weights 1/3). Degree 2, independent of m.
* ``exp`` — one-peer exponential (hypercube when m is a power of two):
  ``⌈log2 m⌉`` phases cycled round-robin; in phase l worker j keeps half
  its mass and pushes the other half to ``(j + 2^l) mod m``. Degree 1 per
  round; entries are exact binary fractions (1/2), so push-weight algebra
  is exact in f32.

Membership composition (:func:`compose_membership`) follows the SGP
recipe: a dead worker's row and column are zeroed (it neither sends nor
receives) and every live column is renormalized to sum to 1 — a live
sender redistributes the mass it would have pushed to dead neighbors over
its remaining live out-neighbors (always nonempty: the self-loop).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Tuple

import numpy as np

TOPOLOGIES = ("full", "ring", "exp")


@dataclass(frozen=True)
class Topology:
    """A static, phase-cycled gossip topology over ``m`` workers.

    ``mats`` is the (L, m, m) stack of column-stochastic mixing matrices;
    round r uses phase ``r % L``. ``degree`` is the per-round number of
    *other* in-neighbors a worker waits on (max over phases) — the runtime
    model prices both the neighbor barrier and the collective payload from
    it.
    """

    name: str
    m: int
    mats: np.ndarray = field(repr=False)  # (L, m, m) f32, column-stochastic

    def __post_init__(self):
        assert self.mats.ndim == 3 and self.mats.shape[1:] == (self.m, self.m), self.mats.shape
        col = self.mats.sum(axis=1)
        assert np.allclose(col, 1.0, atol=1e-6), "mixing matrices must be column-stochastic"

    @property
    def num_phases(self) -> int:
        return int(self.mats.shape[0])

    @property
    def is_full(self) -> bool:
        return self.name == "full"

    @property
    def degree(self) -> int:
        """Max in-neighbors excluding self over phases (= what a worker
        waits on per round; ``m - 1`` for the fully-connected case)."""
        deg = 0
        for l in range(self.num_phases):
            mask = self.in_mask(l)
            deg = max(deg, int((mask & ~np.eye(self.m, dtype=bool)).sum(axis=1).max()))
        return deg

    def matrix(self, r: int) -> np.ndarray:
        """Round r's (m, m) mixing matrix (phase ``r % num_phases``)."""
        return self.mats[r % self.num_phases]

    def in_mask(self, r: int) -> np.ndarray:
        """(m, m) bool: ``[i, j]`` — does worker i receive from j in round r
        (self-loops included)?"""
        return self.matrix(r) > 0


def _full_matrix(m: int) -> np.ndarray:
    return np.full((1, m, m), 1.0 / m, np.float32)


def _ring_matrix(m: int) -> np.ndarray:
    if m <= 2:
        return _full_matrix(m)
    P = np.zeros((m, m), np.float32)
    for j in range(m):
        for i in (j - 1, j, j + 1):
            P[i % m, j] = 1.0 / 3.0
    return P[None]


def _exp_matrices(m: int) -> np.ndarray:
    """One-peer exponential: phase l sends half of each worker's mass to the
    peer ``2^l`` slots away. With m a power of two this cycles the hypercube
    dimensions; otherwise the offsets still cover the ring in O(log m)."""
    if m == 1:
        return np.ones((1, 1, 1), np.float32)
    L = max(1, int(math.ceil(math.log2(m))))
    mats = np.zeros((L, m, m), np.float32)
    for l in range(L):
        off = pow(2, l) % m
        for j in range(m):
            mats[l, j, j] += 0.5
            mats[l, (j + off) % m, j] += 0.5
    return mats


def make_topology(name: str, m: int) -> Topology:
    """Build a named topology over ``m`` workers (``full``/``ring``/``exp``)."""
    if m < 1:
        raise ValueError(f"topology needs at least one worker, got m={m}")
    if name == "full":
        mats = _full_matrix(m)
    elif name == "ring":
        mats = _ring_matrix(m)
    elif name == "exp":
        mats = _exp_matrices(m)
    else:
        raise ValueError(f"unknown topology {name!r}; known: {TOPOLOGIES}")
    return Topology(name=name, m=m, mats=mats)


def compose_membership(P, mask):
    """Compose a mixing matrix with a live mask (SGP recipe): dead workers
    neither send nor receive — their rows and columns zero out — and each
    live column renormalizes to sum to 1 over the surviving live rows, so
    the composed matrix stays column-stochastic over the live set.

    ``P`` is an (m, m) matrix (host constant or traced); ``mask`` is the
    (m,) {0,1} membership mask (traced under jit). Called only on degraded
    rounds — ``membership=None`` boundaries use ``P`` as-is, preserving the
    fully-live program bit for bit.
    """
    import jax.numpy as jnp

    live = (jnp.asarray(mask) > 0).astype(jnp.float32)
    Pm = jnp.asarray(P, jnp.float32) * live[:, None] * live[None, :]
    col = jnp.sum(Pm, axis=0)
    return Pm / jnp.where(col > 0, col, 1.0)[None, :]


_CACHE: Dict[Tuple[str, int], Topology] = {}


def cached_topology(name: str, m: int) -> Topology:
    """Memoized :func:`make_topology` — strategies resolve per-(name, m)
    matrices at trace time, once."""
    key = (name, m)
    topo = _CACHE.get(key)
    if topo is None:
        topo = _CACHE[key] = make_topology(name, m)
    return topo
