"""Two-phase communication strategies: the launch/consume contract.

The paper's central claim is *structural*: the anchor collective launched at
one round boundary is only consumed τ local steps later (eqs. 4–5), which is
exactly the window XLA's latency-hiding scheduler uses to run the collective
in the background. In the original ``Algorithm.boundary`` hook this property
lived implicitly in statement ordering — nothing stopped a new algorithm
from accidentally making the collective blocking. ``CommStrategy`` makes the
overlap window a first-class contract by splitting the round boundary into
two phases, with the launched-but-unconsumed collective carried explicitly
as the ``inflight`` slot of ``TrainState``:

    boundary_apply(x, vars, inflight)   consume the collective launched at
                                        the PREVIOUS boundary (the pullback,
                                        eq. 4) — this phase may not start a
                                        new collective.
    boundary_launch(x, vars) -> inflight
                                        start this round's collective (the
                                        anchor mean, eq. 5); its result is
                                        only consumed at the NEXT boundary
                                        (or, for delayed-averaging variants,
                                        k steps into the next round via
                                        ``local_post_update``).

A *blocking* algorithm (Local SGD, EASGD) is expressed by putting its
collective inside ``boundary_apply`` and leaving ``boundary_launch`` empty —
the blocking/overlapped distinction is now visible in the code structure
rather than implied by it.

The round engine (``repro.training.train_loop``) drives, per round:

    τ × [transform_grads → optimizer step → local_post_update(k)]
    boundary_apply(x, vars, inflight)
    boundary_launch(x, vars) -> new inflight

State layout matches the legacy module (DESIGN.md §3): per-worker
quantities carry a leading worker axis m; anchor-shaped quantities are
unstacked and pinned to the fully-sharded anchor layout.

Packed boundary (default, ``AlgoConfig.packed``): eqs. (4)-(5) are pure
memory-bound sweeps, yet a pytree-shaped boundary pays one op per *leaf* —
per-leaf means, per-leaf sharding constraints, one padded kernel launch per
tensor. With ``packed=True`` the round boundary instead runs on the packed
parameter plane (:mod:`repro.parallel.packing`): x is flattened into one
128-lane-aligned buffer per dtype, anchor-shaped state (z, v, error
feedback) and avg-rebase inflight slots *live* packed between boundaries,
and the whole boundary issues one worker-mean collective and one fused
pullback(+momentum) kernel launch regardless of leaf count. The per-leaf
``boundary_apply``/``boundary_launch`` implementations are kept as the
bit-exact reference oracle (``packed=False``); golden tests pin the packed
path to them.

The per-local-step hooks have packed forms too (``transform_grads_packed``,
``local_post_update_packed``): the round engine's packed local step hands
strategies the worker-stacked gradient/parameter planes directly, so
per-step gradient collectives (sync-SGD), compression sweeps (PowerSGD
error feedback) and mid-round consumption (DaSGD rebase) cost O(dtype
buckets) dispatch as well. The base-class defaults fall back through the
pytree view, so a strategy that only implements the per-leaf hooks stays
correct.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import AlgoConfig
from repro.core.topology import cached_topology, compose_membership
from repro.kernels.anchor_mix import ops as anchor_ops
from repro.kernels.consensus_probe import ops as probe_ops
from repro.parallel import anchor_axes, current_mesh
from repro.parallel.packing import Packed, buffer_map, leaf_segments, pack, packed_like, unpack
from repro.utils.tree import tree_lerp


class AlgoVars(NamedTuple):
    """Strategy-owned state slots (unused slots are None)."""

    z: Any = None  # anchor model (overlap, easgd, sparse) — unstacked
    v: Any = None  # anchor momentum (overlap momentum variant)
    extra: Any = None  # powersgd (Q, error) / sparse error feedback / legacy cocod


# ---------------------------------------------------------------------------
# shared tree primitives (also re-exported by repro.core.algorithms)
# ---------------------------------------------------------------------------


def _worker_mean(x_stacked, weights=None):
    """Average over the worker axis; on a mesh this is the paper's model
    all-reduce (lowered as reduce-scatter when the consumer is sharded).
    The fp32 accumulation is fused into the reduction (``dtype=``) so XLA
    never materializes an fp32 copy of the full stacked params.

    ``weights`` ((m,) f32 renormalized membership weights, DESIGN.md §7)
    turns this into the masked mean Σ_i w_i·x_i over live workers; ``None``
    keeps the historical fully-live reduction bit for bit."""
    if weights is None:
        return jax.tree.map(lambda t: jnp.mean(t, axis=0, dtype=jnp.float32).astype(t.dtype), x_stacked)
    wf = weights.astype(jnp.float32)

    def one(t):
        w = wf.reshape((-1,) + (1,) * (t.ndim - 1))
        return jnp.sum(t.astype(jnp.float32) * w, axis=0).astype(t.dtype)

    return jax.tree.map(one, x_stacked)


def _live_where(mask, new_tree, old_tree):
    """Per-leaf ``where`` over the worker axis: live rows take ``new``, dead
    rows keep ``old`` (they are not participating this boundary)."""
    live = mask > 0

    def one(n, o):
        lb = live.reshape((-1,) + (1,) * (n.ndim - 1))
        return jnp.where(lb, n, o)

    return jax.tree.map(one, new_tree, old_tree)


def _mem_weights(membership):
    """The (m,) f32 weights of a membership, or None (fully-live path)."""
    return None if membership is None else membership.weights


def _broadcast_like(z, x_stacked):
    return jax.tree.map(lambda zi, xi: jnp.broadcast_to(zi[None], xi.shape), z, x_stacked)


def _is_axes_leaf(t):
    return isinstance(t, tuple) and all(isinstance(e, (str, type(None))) for e in t)


def _constrain_anchor(z, axes_tree):
    """Pin the anchor to its fully-sharded layout (reduce-scatter target)."""
    mesh = current_mesh()
    if mesh is None or axes_tree is None:
        return z
    from jax.sharding import NamedSharding

    from repro.parallel.sharding import fit_spec, spec_for

    a_axes = anchor_axes(axes_tree)

    def one(t, ax):
        spec = fit_spec(spec_for(ax), t.shape, mesh)
        return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))

    return jax.tree.map(one, z, a_axes, is_leaf=_is_axes_leaf)


def _pullback(x_stacked, z, alpha: float):
    """Paper eq. (4): x_i ← (1−α)·x_i + α·z, for every worker i (fused
    anchor-mix kernel on TPU)."""
    return jax.vmap(lambda xi: anchor_ops.pullback_tree(xi, z, alpha))(x_stacked)


def x_stacked_leading(x_stacked) -> int:
    if isinstance(x_stacked, Packed):
        return int(x_stacked.lead_shape[0]) if x_stacked.lead_shape else 1
    leaves = jax.tree.leaves(x_stacked)
    return int(leaves[0].shape[0]) if leaves else 1


def _as_plane(x_stacked) -> Packed:
    """The worker-stacked plane view of x: pass a ``Packed`` through, pack a
    pytree. The round engine hands packed strategies the plane it already
    carries through the scan, so packed boundaries avoid a re-pack."""
    return x_stacked if isinstance(x_stacked, Packed) else pack(x_stacked, lead=1)


def _stacked_axes(axes_tree):
    """Worker-prefixed logical axes for a stacked (m, ...) copy of params."""
    return jax.tree.map(lambda ax: ("worker",) + tuple(ax), axes_tree, is_leaf=_is_axes_leaf)


# ---------------------------------------------------------------------------
# packed-plane primitives (AlgoConfig.packed boundary path)
# ---------------------------------------------------------------------------

# Logical axes for packed flat buffers (see repro.parallel.sharding rules):
# the per-worker plane shards over fsdp; the anchor plane — identical across
# workers — additionally shards over the worker axis (ZeRO-3 layout).
PACKED_STACKED_AXES = ("worker", "flat_param")
PACKED_ANCHOR_AXES = ("anchor_flat",)


def _pack_anchor(x_stacked) -> Packed:
    """Worker 0's model as a packed anchor plane (all workers start equal).
    Accepts the worker-stacked plane directly (plane-resident state): row 0
    of each buffer *is* worker 0's packed model, padding included."""
    if isinstance(x_stacked, Packed):
        return Packed(tuple(b[0] for b in x_stacked.buffers), x_stacked.layout)
    return pack(jax.tree.map(lambda t: t[0], x_stacked))


def _match_rep(x_in, x_new: Packed):
    """Return the boundary's new x in the representation the engine handed
    in: the plane-resident engine passes (and carries) the ``Packed`` plane,
    per-leaf callers pass and get back the pytree view."""
    return x_new if isinstance(x_in, Packed) else unpack(x_new)


def _packed_worker_mean(p: Packed, weights=None) -> Packed:
    """One mean per dtype bucket over the stacked plane — the boundary's
    single worker-mean collective (vs one per leaf on the tree path).
    ``weights`` selects the masked weighted sum (see :func:`_worker_mean`)."""
    if weights is None:
        return buffer_map(lambda b: jnp.mean(b, axis=0, dtype=jnp.float32).astype(b.dtype), p)
    wf = weights.astype(jnp.float32)
    return buffer_map(lambda b: jnp.sum(b.astype(jnp.float32) * wf[:, None], axis=0).astype(b.dtype), p)


def _packed_live_where(mask, p_new: Packed, p_old: Packed) -> Packed:
    """Packed form of :func:`_live_where`: live rows take the new plane."""
    live = mask > 0
    return buffer_map(lambda n, o: jnp.where(live[:, None], n, o), p_new, p_old, layout=p_new.layout)


def _constrain_anchor_packed(p: Packed, axes_tree=None) -> Packed:
    """Packed-axes story for the anchor constraint: one sharding constraint
    per buffer (``anchor_flat`` → worker+fsdp) instead of one per leaf."""
    mesh = current_mesh()
    if mesh is None or axes_tree is None:
        return p
    from jax.sharding import NamedSharding

    from repro.parallel.sharding import fit_spec, spec_for

    def one(b):
        spec = fit_spec(spec_for(PACKED_ANCHOR_AXES), b.shape, mesh)
        return jax.lax.with_sharding_constraint(b, NamedSharding(mesh, spec))

    return buffer_map(one, p)


def _packed_thresholds(delta_buf, layout, bucket: int, k: float):
    """Per-leaf |top-k| quantile thresholds, broadcast to a per-element plane.

    The quantiles are inherently per-leaf (scalar work, O(leaves)); the
    heavy where/error-feedback sweeps that consume the result stay packed.
    Leaves with ≤1 element are kept dense (threshold −inf), matching
    :func:`sparsify_topk`; padding lanes hold zeros throughout, so the kept
    padding contributes nothing.
    """
    vals, reps = [], []
    for slot in leaf_segments(layout, bucket):
        if slot.size <= 1:
            t = jnp.float32(-jnp.inf)
        else:
            seg = jax.lax.slice_in_dim(delta_buf, slot.offset, slot.offset + slot.size, axis=0)
            t = jnp.quantile(jnp.abs(seg.reshape(-1)), 1.0 - k)
        vals.append(t)
        reps.append(slot.stride)
    total = int(sum(reps))
    return jnp.repeat(jnp.stack(vals), np.asarray(reps), total_repeat_length=total)


# ---------------------------------------------------------------------------
# protocol
# ---------------------------------------------------------------------------


class CommStrategy:
    """Base strategy: plain Local-SGD-without-averaging (all hooks no-ops).

    Subclasses choose where their collective lives:

    * overlapped  — launch it in :meth:`boundary_launch`, consume the carried
      ``inflight`` at the next :meth:`boundary_apply` (or mid-round via
      :meth:`local_post_update`). τ local steps of compute separate producer
      and consumer — the paper's hidden-communication window.
    * blocking    — run it inside :meth:`boundary_apply` and leave
      :meth:`boundary_launch` returning ``None``.

    The ``inflight`` pytree must keep a fixed structure across rounds (it is
    a ``lax.scan`` carry): :meth:`init_inflight` and :meth:`boundary_launch`
    must return structurally identical trees.
    """

    name = "base"
    needs_anchor = False

    # ---- offload contract (DESIGN.md §9) ----
    # Under AlgoConfig.offload the engine keeps vars/inflight host-resident
    # between boundaries and restores them device-side inside the round
    # program. vars always restore before the τ-step scan (they ride its
    # carry); the inflight slot restores at the boundary — UNLESS the
    # strategy consumes it mid-round (DaSGD's local_post_update), in which
    # case this property makes the engine prefetch it before the window.
    # Either H2D copy has no data dependency on the local steps, so the
    # scheduler overlaps it with the window — the same mechanism that hides
    # the boundary collective hides the host link.
    consumes_inflight_midround = False

    def __init__(self, cfg: AlgoConfig):
        self.cfg = cfg
        self.tau = cfg.tau
        # packed boundary (the default): boundary math runs on the packed
        # parameter plane — see module docstring. False = per-leaf oracle.
        self.packed = bool(getattr(cfg, "packed", True))

    # ---- state ----
    def init_vars(self, x_stacked, axes_tree=None) -> AlgoVars:
        return AlgoVars()

    def init_inflight(self, x_stacked, vars: AlgoVars, axes_tree=None):
        """Initial carried collective — what round 0's apply phase consumes."""
        return None

    # ---- per-local-step hooks ----
    def transform_grads(self, grads_stacked, vars: AlgoVars):
        """Gradient-space hook (sync-SGD averaging / PowerSGD compression)."""
        return grads_stacked, vars

    def transform_grads_packed(self, pg: Packed, vars: AlgoVars):
        """Packed-plane form of :meth:`transform_grads`, used by the packed
        local step (``AlgoConfig.packed`` + a packed-capable optimizer):
        grads arrive as one worker-stacked flat buffer per dtype bucket, so
        gradient-space collectives cost O(buckets) ops instead of O(leaves).

        The default is correct for any subclass: if ``transform_grads`` is
        the base identity this is a no-op; otherwise it round-trips through
        the pytree view, so a subclass that only overrides the per-leaf hook
        still gets its semantics (at per-leaf cost) until it provides a
        packed override.
        """
        if type(self).transform_grads is CommStrategy.transform_grads:
            return pg, vars
        grads, vars = self.transform_grads(unpack(pg), vars)
        return pack(grads, layout=pg.layout, lead=1), vars

    def local_post_update(self, x_stacked, vars: AlgoVars, inflight, k_in_round):
        """Mid-round consumption point: called after the optimizer update of
        local step ``k_in_round`` (0-based, traced). Delayed-averaging
        strategies consume ``inflight`` here instead of at the boundary."""
        return x_stacked

    def local_post_update_packed(self, px: Packed, vars: AlgoVars, inflight, k_in_round) -> Packed:
        """Packed-plane form of :meth:`local_post_update`: the packed local
        step keeps x on the plane through the optimizer update, so mid-round
        consumers (DaSGD) rebase the plane directly — no pack/unpack pair
        per local step. Same correct-by-default fallback as
        :meth:`transform_grads_packed`."""
        if type(self).local_post_update is CommStrategy.local_post_update:
            return px
        return pack(self.local_post_update(unpack(px), vars, inflight, k_in_round), layout=px.layout, lead=1)

    # ---- round-boundary phases ----
    def boundary_apply(self, x_stacked, vars: AlgoVars, inflight, axes_tree=None, membership=None):
        """Phase 1 — consume the collective launched last round (eq. 4).

        ``membership`` (:class:`repro.fault.membership.Membership` or None,
        DESIGN.md §7) masks the phase to live workers: dead rows pass
        through untouched and any worker mean renormalizes over the live
        set. ``None`` — the default, and what every clean round passes — is
        the exact pre-fault program."""
        return x_stacked, vars

    def boundary_launch(self, x_stacked, vars: AlgoVars, axes_tree=None, membership=None):
        """Phase 2 — launch this round's collective (eq. 5); returns
        ``(vars, inflight)`` with the launched value carried to the next
        consumption point. ``membership`` as in :meth:`boundary_apply`."""
        return vars, None

    def boundary_round(self, x_stacked, vars: AlgoVars, inflight, axes_tree=None, probe: bool = False, membership=None):
        """One full round boundary: the apply phase then the launch phase.

        This is what the round engine calls. The two-phase contract is
        unchanged — apply may not start a collective, launch's value is
        consumed a round later — but routing both phases through one hook
        lets packed strategies fuse them (the launch-side mean/momentum
        reads the exact plane the apply-side pullback just wrote, so one
        kernel covers both without re-reading x from HBM).

        Packed strategies accept ``x_stacked`` either as a pytree or as the
        already-packed plane, and return x **in the same representation**:
        the plane-resident engine hands over the plane its scan carries and
        gets the plane back (no pack/unpack seam at round granularity);
        per-leaf callers keep pytree-in/pytree-out semantics.

        With ``probe`` the boundary additionally measures the *pre-boundary*
        plane's consensus distance for the adaptive-τ controller
        (DESIGN.md §6) and returns a 4-tuple
        ``(x, vars, inflight, ConsensusStats)``. Pullback-family strategies
        get the stats as fused extra outputs of their existing boundary
        kernels (zero extra launches); strategies whose boundary does not
        read the plane through the pullback run the standalone probe
        (≤ 1 launch per dtype bucket).

        ``membership`` masks the whole boundary to live workers
        (DESIGN.md §7); the probe, when requested, still covers the full
        plane — the consensus measure is defined over all worker slots, and
        fault rounds hold τ anyway (``TauController`` fault_hold).
        """
        if self.packed:
            return self._packed_boundary(x_stacked, vars, inflight, axes_tree, probe=probe, membership=membership)
        return self._boundary_phases(x_stacked, vars, inflight, axes_tree, probe=probe, membership=membership)

    def _boundary_phases(self, x_stacked, vars: AlgoVars, inflight, axes_tree=None, probe: bool = False, membership=None):
        """The shared two-phase composition: apply, then launch."""
        stats = probe_ops.tree_probe(x_stacked) if probe else None
        x_stacked, vars = self.boundary_apply(x_stacked, vars, inflight, axes_tree, membership=membership)
        vars, inflight = self.boundary_launch(x_stacked, vars, axes_tree, membership=membership)
        if probe:
            return x_stacked, vars, inflight, stats
        return x_stacked, vars, inflight

    def _packed_boundary(self, x_stacked, vars: AlgoVars, inflight, axes_tree=None, probe: bool = False, membership=None):
        """Packed-plane boundary; strategies with boundary math override.

        Strategies with *no* boundary math at all (base, sync_sgd,
        powersgd — their collectives live per-step) pass the plane straight
        through. A subclass that overrides only the per-leaf phases falls
        back to the pytree composition, round-tripping a handed-over plane
        through its view so the engine's carry representation is preserved."""
        base_apply = type(self).boundary_apply is CommStrategy.boundary_apply
        base_launch = type(self).boundary_launch is CommStrategy.boundary_launch
        if base_apply and base_launch:
            if probe:
                return x_stacked, vars, None, probe_ops.packed_probe(_as_plane(x_stacked))
            return x_stacked, vars, None  # launch phase would carry None
        if isinstance(x_stacked, Packed):
            outs = self._boundary_phases(unpack(x_stacked), vars, inflight, axes_tree, probe=probe, membership=membership)
            x_tree, vars, inflight = outs[0], outs[1], outs[2]
            px = pack(x_tree, layout=x_stacked.layout, lead=1)
            if probe:
                return px, vars, inflight, outs[3]
            return px, vars, inflight
        return self._boundary_phases(x_stacked, vars, inflight, axes_tree, probe=probe, membership=membership)

    # ---- AOT spec support (launch/specs.py) ----
    def state_axes(self, axes_tree) -> Tuple[Optional[AlgoVars], Any]:
        """(vars_axes, inflight_axes): logical-axes trees mirroring
        ``init_vars``/``init_inflight`` output for sharding-spec
        construction. ``None`` entries mean replicated."""
        return None, None

    # ---- diagnostics ----
    def metrics(self, x_stacked, vars: AlgoVars) -> dict:
        mean = _worker_mean(x_stacked)
        dev = jax.tree.map(
            lambda xi, mi: jnp.sum(jnp.square(xi.astype(jnp.float32) - mi[None].astype(jnp.float32))),
            x_stacked,
            mean,
        )
        total = sum(jax.tree.leaves(dev)) / max(x_stacked_leading(x_stacked), 1)
        return {"consensus_dist": total}


# ---------------------------------------------------------------------------
# ports of the six seed algorithms
# ---------------------------------------------------------------------------


class SyncSGDStrategy(CommStrategy):
    """Fully synchronous SGD: gradient all-reduce every local step (τ=1).

    The collective lives in ``transform_grads`` — per-step and blocking by
    nature; both boundary phases are empty.
    """

    name = "sync_sgd"

    def __init__(self, cfg: AlgoConfig):
        super().__init__(cfg)
        self.tau = 1

    def transform_grads(self, grads_stacked, vars):
        g = _worker_mean(grads_stacked)
        return _broadcast_like(g, grads_stacked), vars

    def transform_grads_packed(self, pg: Packed, vars):
        """The per-step gradient all-reduce as ONE mean per dtype bucket
        (vs one per leaf): the packed local step's only collective."""
        g = _packed_worker_mean(pg)
        return buffer_map(lambda a, b: jnp.broadcast_to(a[None], b.shape), g, pg, layout=pg.layout), vars


class LocalSGDStrategy(CommStrategy):
    """Periodic model averaging — eq. (2). Blocking: the average is both
    computed and consumed inside ``boundary_apply``; nothing is launched."""

    name = "local_sgd"

    def boundary_apply(self, x_stacked, vars, inflight, axes_tree=None, membership=None):
        avg = _worker_mean(x_stacked, _mem_weights(membership))
        x_new = _broadcast_like(avg, x_stacked)
        if membership is not None:
            # dead rows keep their stale params; they re-sync on rejoin
            x_new = _live_where(membership.mask, x_new, x_stacked)
        return x_new, vars

    def _packed_boundary(self, x_stacked, vars, inflight, axes_tree=None, probe: bool = False, membership=None):
        px = _as_plane(x_stacked)
        # standalone probe of the pre-average plane: post-boundary drift is
        # identically zero here, so the controller must see the round-end one
        stats = probe_ops.packed_probe(px) if probe else None
        avg = _packed_worker_mean(px, _mem_weights(membership))
        x_new = buffer_map(lambda a, b: jnp.broadcast_to(a[None], b.shape), avg, px, layout=px.layout)
        if membership is not None:
            x_new = _packed_live_where(membership.mask, x_new, px)
        out = (_match_rep(x_stacked, x_new), vars, None)
        return out + (stats,) if probe else out


class OverlapLocalSGDStrategy(CommStrategy):
    """The paper's algorithm (+ momentum variant when ``anchor_beta`` > 0).

    * apply  (eq. 4): pull every worker toward the anchor carried in
      ``inflight`` — that anchor was launched one full round (τ steps) ago.
    * launch (eq. 5): mean of the pulled-back models becomes the next
      anchor; with momentum, v ← β·v + (mean − z); z ← z + v (eqs. 10–11).
      Its only consumer is the NEXT round's apply, so the collective
      overlaps the next τ local steps.
    """

    name = "overlap_local_sgd"
    needs_anchor = True

    def __init__(self, cfg: AlgoConfig):
        super().__init__(cfg)
        self.momentum = cfg.anchor_beta > 0

    def init_vars(self, x_stacked, axes_tree=None) -> AlgoVars:
        if not self.momentum:
            return AlgoVars()
        if self.packed:
            z = _constrain_anchor_packed(_pack_anchor(x_stacked), axes_tree)
            return AlgoVars(z=z, v=packed_like(z, 0.0))
        z = jax.tree.map(lambda t: t[0], x_stacked)
        z = _constrain_anchor(z, axes_tree)
        return AlgoVars(z=z, v=jax.tree.map(jnp.zeros_like, z))

    def init_inflight(self, x_stacked, vars, axes_tree=None):
        # all workers start equal
        if self.packed:
            return _constrain_anchor_packed(_pack_anchor(x_stacked), axes_tree)
        z = jax.tree.map(lambda t: t[0], x_stacked)
        return _constrain_anchor(z, axes_tree)

    def boundary_apply(self, x_stacked, vars: AlgoVars, inflight, axes_tree=None, membership=None):
        x_new = _pullback(x_stacked, inflight, self.cfg.alpha)
        if membership is not None:
            # dead workers skip the pullback (they were not part of the round)
            x_new = _live_where(membership.mask, x_new, x_stacked)
        if self.momentum:
            # remember the consumed anchor: launch needs it for eq. (10)
            vars = AlgoVars(z=inflight, v=vars.v, extra=vars.extra)
        return x_new, vars

    def boundary_launch(self, x_stacked, vars: AlgoVars, axes_tree=None, membership=None):
        mean_x = _worker_mean(x_stacked, _mem_weights(membership))
        if self.momentum:
            beta = self.cfg.anchor_beta
            v_new = jax.tree.map(
                lambda v, m, z: (beta * v.astype(jnp.float32) + (m.astype(jnp.float32) - z.astype(jnp.float32))).astype(v.dtype),
                vars.v,
                mean_x,
                vars.z,
            )
            z_new = jax.tree.map(
                lambda z, v: (z.astype(jnp.float32) + v.astype(jnp.float32)).astype(z.dtype), vars.z, v_new
            )
            vars = AlgoVars(z=vars.z, v=v_new, extra=vars.extra)
        else:
            z_new = mean_x
        return vars, _constrain_anchor(z_new, axes_tree)

    def _packed_boundary(self, x_stacked, vars: AlgoVars, inflight, axes_tree=None, probe: bool = False, membership=None):
        """Both phases in one fused kernel per dtype bucket: the pullback
        (eq. 4) writes the plane whose worker mean (eq. 5, + momentum
        eqs. 10-11) is computed in the same HBM pass. With ``probe`` the
        same launches also emit the consensus partial sums — zero extra
        kernel launches for the adaptive-τ probe. With ``membership`` the
        same fused kernels run their masked variant (one extra (m,) input,
        same launch count)."""
        alpha = self.cfg.alpha
        weights = _mem_weights(membership)
        px = _as_plane(x_stacked)
        if self.momentum:
            beta = self.cfg.anchor_beta
            outs = [
                anchor_ops.pullback_mean_momentum(bx, bz, bv, alpha, beta, probe=probe, weights=weights)
                for bx, bz, bv in zip(px.buffers, inflight.buffers, vars.v.buffers)
            ]
            x_new = Packed(tuple(o[0] for o in outs), px.layout)
            z_next = Packed(tuple(o[1] for o in outs), inflight.layout)
            v_new = Packed(tuple(o[2] for o in outs), vars.v.layout)
            vars = AlgoVars(z=inflight, v=v_new, extra=vars.extra)
        else:
            outs = [
                anchor_ops.pullback_mean(bx, bz, alpha, probe=probe, weights=weights)
                for bx, bz in zip(px.buffers, inflight.buffers)
            ]
            x_new = Packed(tuple(o[0] for o in outs), px.layout)
            z_next = Packed(tuple(o[1] for o in outs), inflight.layout)
        result = (_match_rep(x_stacked, x_new), vars, _constrain_anchor_packed(z_next, axes_tree))
        if probe:
            stats = probe_ops.stats_from_partials([o[-1] for o in outs], x_stacked_leading(x_stacked))
            return result + (stats,)
        return result

    def state_axes(self, axes_tree):
        if self.packed:
            vars_axes = AlgoVars(z=PACKED_ANCHOR_AXES, v=PACKED_ANCHOR_AXES) if self.momentum else None
            return vars_axes, PACKED_ANCHOR_AXES
        a = anchor_axes(axes_tree)
        vars_axes = AlgoVars(z=a, v=a) if self.momentum else None
        return vars_axes, a


class EASGDStrategy(CommStrategy):
    """Elastic-averaging SGD [19]. Blocking in the original formulation: the
    symmetric mixing collective runs inside ``boundary_apply`` (the worker
    waits on mean(x) before continuing); nothing is launched."""

    name = "easgd"
    needs_anchor = True

    def init_vars(self, x_stacked, axes_tree=None) -> AlgoVars:
        if self.packed:
            return AlgoVars(z=_constrain_anchor_packed(_pack_anchor(x_stacked), axes_tree))
        z = jax.tree.map(lambda t: t[0], x_stacked)
        return AlgoVars(z=_constrain_anchor(z, axes_tree))

    @staticmethod
    def _rate(alpha, x_stacked, membership):
        """z's mixing rate min(α·m_live, 1): a python float on the fully-live
        path (exactly the historical program), traced when masked (m_live is
        data-dependent on the membership)."""
        if membership is None:
            return min(alpha * x_stacked_leading(x_stacked), 1.0)
        return jnp.minimum(alpha * membership.live_count(), 1.0)

    def boundary_apply(self, x_stacked, vars: AlgoVars, inflight, axes_tree=None, membership=None):
        alpha = self.cfg.alpha
        z = vars.z
        x_new = _pullback(x_stacked, z, alpha)
        if membership is not None:
            x_new = _live_where(membership.mask, x_new, x_stacked)
        # symmetric update: z ← z + α·Σ_live (x_i − z) = (1−m_live·α)z + m_live·α·mean_live(x)
        rate = self._rate(alpha, x_stacked, membership)
        mean_x = _worker_mean(x_stacked, _mem_weights(membership))  # pre-pullback models (symmetric W)
        z_new = _constrain_anchor(tree_lerp(z, mean_x, rate), axes_tree)
        return x_new, AlgoVars(z=z_new, v=vars.v, extra=vars.extra)

    def _packed_boundary(self, x_stacked, vars: AlgoVars, inflight, axes_tree=None, probe: bool = False, membership=None):
        alpha = self.cfg.alpha
        rate = self._rate(alpha, x_stacked, membership)
        px = _as_plane(x_stacked)
        # fused pullback + pre-pullback mean (EASGD's symmetric W) per bucket;
        # with probe the same launches emit the consensus partial sums
        outs = [
            anchor_ops.pullback_mean(bx, bz, alpha, mean_pre=True, probe=probe, weights=_mem_weights(membership))
            for bx, bz in zip(px.buffers, vars.z.buffers)
        ]
        x_new = Packed(tuple(o[0] for o in outs), px.layout)
        # z lerp runs at native dtype, mirroring tree_lerp on the tree path
        z_new = Packed(
            tuple(((1.0 - rate) * bz + rate * o[1]).astype(bz.dtype) for o, bz in zip(outs, vars.z.buffers)),
            vars.z.layout,
        )
        z_new = _constrain_anchor_packed(z_new, axes_tree)
        result = (_match_rep(x_stacked, x_new), AlgoVars(z=z_new, v=vars.v, extra=vars.extra), None)
        if probe:
            stats = probe_ops.stats_from_partials([o[-1] for o in outs], x_stacked_leading(x_stacked))
            return result + (stats,)
        return result

    def state_axes(self, axes_tree):
        if self.packed:
            return AlgoVars(z=PACKED_ANCHOR_AXES), None
        return AlgoVars(z=anchor_axes(axes_tree)), None


class _AvgRebaseStrategy(CommStrategy):
    """Shared machinery for strategies whose launched collective is the mean
    of the round's models plus a per-worker copy for delta correction, and
    whose consumption re-bases x_i ← avg(x₀) + (x_i − x₀ᵢ)."""

    class Inflight(NamedTuple):
        avg: Any  # mean of launch-time models (the overlapped collective)
        x0: Any  # per-worker launch-time models (local correction term)

    def init_inflight(self, x_stacked, vars, axes_tree=None):
        if self.packed:
            px = _as_plane(x_stacked)
            return self.Inflight(avg=_packed_worker_mean(px), x0=px)
        return self.Inflight(avg=_worker_mean(x_stacked), x0=jax.tree.map(jnp.copy, x_stacked))

    @staticmethod
    def _rebase_leaf(xi, xs, av):
        """x_i ← avg(x₀) + (x_i − x₀ᵢ); one cast chain shared by the tree
        and packed paths (it is pinned bitwise by the golden tests)."""
        return (av[None].astype(jnp.float32) + xi.astype(jnp.float32) - xs.astype(jnp.float32)).astype(xi.dtype)

    def _rebase(self, x_stacked, inflight):
        return jax.tree.map(self._rebase_leaf, x_stacked, inflight.x0, inflight.avg)

    def _rebase_packed(self, px: Packed, inflight) -> Packed:
        return buffer_map(self._rebase_leaf, px, inflight.x0, inflight.avg, layout=px.layout)

    def _packed_launch(self, px: Packed, weights=None):
        """Launch from an already-packed plane: one mean per dtype bucket;
        the plane itself doubles as the x₀ correction term (no extra copy)."""
        return self.Inflight(avg=_packed_worker_mean(px, weights), x0=px)

    def boundary_launch(self, x_stacked, vars, axes_tree=None, membership=None):
        avg = _worker_mean(x_stacked, _mem_weights(membership))
        return vars, self.Inflight(avg=avg, x0=jax.tree.map(jnp.copy, x_stacked))

    def state_axes(self, axes_tree):
        if self.packed:
            return None, self.Inflight(avg=PACKED_ANCHOR_AXES, x0=PACKED_STACKED_AXES)
        return None, self.Inflight(avg=anchor_axes(axes_tree), x0=_stacked_axes(axes_tree))


class CoCoDStrategy(_AvgRebaseStrategy):
    """CoCoD-SGD [20] in its native two-phase form: launch averages the
    round's *starting* models, apply (one round later) re-bases each worker
    onto that average plus its local delta. Decoupled like Overlap-Local-SGD
    but without the pullback contraction. Equivalent to
    :class:`DelayedAveragingStrategy` with the delay pinned to τ.
    """

    name = "cocod"

    def boundary_apply(self, x_stacked, vars, inflight, axes_tree=None, membership=None):
        x_new = self._rebase(x_stacked, inflight)
        if membership is not None:
            x_new = _live_where(membership.mask, x_new, x_stacked)
        return x_new, vars

    def _packed_boundary(self, x_stacked, vars, inflight, axes_tree=None, probe: bool = False, membership=None):
        px = _as_plane(x_stacked)
        # rebase does not read through the pullback kernels, so the probe is
        # the standalone per-bucket launch on the pre-rebase plane
        stats = probe_ops.packed_probe(px) if probe else None
        x_new = self._rebase_packed(px, inflight)
        if membership is not None:
            x_new = _packed_live_where(membership.mask, x_new, px)
        out = (_match_rep(x_stacked, x_new), vars, self._packed_launch(x_new, _mem_weights(membership)))
        return out + (stats,) if probe else out


class PowerSGDStrategy(CommStrategy):
    """PowerSGD [5]: rank-r gradient compression, synchronous (τ=1). The
    compressed collectives live in ``transform_grads`` (per-step); both
    boundary phases are empty. Delegates the factor math to the legacy
    implementation in :mod:`repro.core.powersgd`."""

    name = "powersgd"

    def __init__(self, cfg: AlgoConfig):
        super().__init__(cfg)
        self.tau = 1
        from repro.core.powersgd import PowerSGD  # deferred: avoids import cycle

        self._impl = PowerSGD(cfg)
        self.rank = self._impl.rank

    def init_vars(self, x_stacked, axes_tree=None) -> AlgoVars:
        if self.packed:
            return self._impl.init_vars_packed(x_stacked, axes_tree)
        return self._impl.init_vars(x_stacked, axes_tree)

    def transform_grads(self, grads_stacked, vars: AlgoVars):
        if vars.extra is not None and isinstance(vars.extra.err, Packed):
            # packed state but a per-leaf caller (e.g. an optimizer without a
            # packed step): route through the plane so the state layout holds
            pg, vars = self._impl.transform_grads_packed(pack(grads_stacked, lead=1), vars)
            return unpack(pg), vars
        return self._impl.transform_grads(grads_stacked, vars)

    def transform_grads_packed(self, pg, vars: AlgoVars):
        return self._impl.transform_grads_packed(pg, vars)


# ---------------------------------------------------------------------------
# new strategies the single-hook API could not express cleanly
# ---------------------------------------------------------------------------


class DelayedAveragingStrategy(_AvgRebaseStrategy):
    """DaSGD-style delayed averaging (arXiv:2006.00441).

    The average of the round's models is launched at the boundary but only
    *applied k local steps into the next round* — modelling a collective
    whose transit time is shorter than a full round. On arrival each worker
    re-bases onto the average plus the local progress it made while the
    collective was in flight:

        after local step k:  x_i ← avg(x₀) + (x_i − x₀ᵢ)

    ``delay_steps`` ∈ [1, τ]; k = τ degenerates to boundary consumption
    (CoCoD). This strategy is only expressible because consumption is a
    separate phase from launch — under the old single ``boundary`` hook the
    apply point was hard-wired to the round boundary.
    """

    name = "delayed_avg"

    def __init__(self, cfg: AlgoConfig):
        super().__init__(cfg)
        if not 1 <= cfg.delay_steps <= cfg.tau:
            raise ValueError(f"delay_steps must be in [1, tau={cfg.tau}], got {cfg.delay_steps}")
        self.delay = cfg.delay_steps

    @property
    def consumes_inflight_midround(self) -> bool:
        # delay < τ: the averaged plane arrives inside the window, so the
        # offloaded engine must prefetch it before the local scan
        return self.delay < self.tau

    def local_post_update(self, x_stacked, vars, inflight, k_in_round):
        if self.delay >= self.tau:  # consumed at the boundary instead
            return x_stacked
        # cond, not where: the rebase only materializes on the arrival step
        arrived = k_in_round == self.delay - 1  # after the delay-th local update
        if self.packed:
            rebase = lambda x: unpack(self._rebase_packed(pack(x, lead=1), inflight))
            return jax.lax.cond(arrived, rebase, lambda x: x, x_stacked)
        return jax.lax.cond(arrived, lambda x: self._rebase(x, inflight), lambda x: x, x_stacked)

    def local_post_update_packed(self, px: Packed, vars, inflight, k_in_round) -> Packed:
        """Mid-round consume directly on the plane the packed optimizer step
        just wrote — the rebase sweeps stay per-bucket, no repacking."""
        if self.delay >= self.tau:
            return px
        arrived = k_in_round == self.delay - 1
        return jax.lax.cond(arrived, lambda p: self._rebase_packed(p, inflight), lambda p: p, px)

    def boundary_apply(self, x_stacked, vars, inflight, axes_tree=None, membership=None):
        # membership masks only the boundary-phase consumption; the mid-round
        # ``local_post_update`` rebase stays unmasked (the collective it
        # consumes was launched under last round's membership — DESIGN.md §7)
        if self.delay >= self.tau:
            x_new = self._rebase(x_stacked, inflight)
            if membership is not None:
                x_new = _live_where(membership.mask, x_new, x_stacked)
            return x_new, vars
        return x_stacked, vars

    def _packed_boundary(self, x_stacked, vars, inflight, axes_tree=None, probe: bool = False, membership=None):
        px = _as_plane(x_stacked)
        stats = probe_ops.packed_probe(px) if probe else None
        weights = _mem_weights(membership)
        if self.delay >= self.tau:
            x_new = self._rebase_packed(px, inflight)
            if membership is not None:
                x_new = _packed_live_where(membership.mask, x_new, px)
            out = (_match_rep(x_stacked, x_new), vars, self._packed_launch(x_new, weights))
            return out + (stats,) if probe else out
        # mid-round consumption already happened; launch from the live plane
        # (x passes through in the caller's representation)
        out = (x_stacked, vars, self._packed_launch(px, weights))
        return out + (stats,) if probe else out


def sparsify_topk(delta, k: float):
    """Keep the top-``k`` fraction of entries of ``delta`` by magnitude
    (per-leaf), zeroing the rest. k ≥ 1 is the identity."""
    if k >= 1.0:
        return delta

    def one(d):
        if d.size <= 1:
            return d
        flat = jnp.abs(d.astype(jnp.float32)).reshape(-1)
        thresh = jnp.quantile(flat, 1.0 - k)
        return jnp.where(jnp.abs(d) >= thresh.astype(d.dtype), d, jnp.zeros_like(d))

    return jax.tree.map(one, delta)


class SparseAnchorStrategy(CommStrategy):
    """LOSCAR-style top-k sparse anchor averaging with delay correction.

    Overlap-Local-SGD where the launched anchor update transmits only the
    top-``sparse_k`` fraction of the anchor *delta* Δ = mean(x) − z by
    magnitude — a sparse collective whose payload shrinks with k. The
    truncated residual is kept as per-leaf error feedback e and folded into
    the next round's delta (the delay correction), so nothing is lost, only
    delayed:

        s   = top_k(Δ + e)          (the sparse collective payload)
        e'  = (Δ + e) − s           (carried correction)
        z'  = z + s                 (next anchor, consumed τ steps later)

    At ``sparse_k = 1`` this is exactly vanilla Overlap-Local-SGD (the
    residual is identically zero and z' = mean(x)).
    """

    name = "sparse_anchor"
    needs_anchor = True

    def __init__(self, cfg: AlgoConfig):
        super().__init__(cfg)
        if not 0.0 < cfg.sparse_k <= 1.0:
            raise ValueError(f"sparse_k must be in (0, 1], got {cfg.sparse_k}")
        self.k = cfg.sparse_k

    def init_vars(self, x_stacked, axes_tree=None) -> AlgoVars:
        if self.packed:
            z = _constrain_anchor_packed(_pack_anchor(x_stacked), axes_tree)
            # f32 shadow of the anchor plane: same bucketing/offsets, so the
            # error feedback stays element-aligned with z across dtypes
            return AlgoVars(z=z, extra=packed_like(z, 0.0, dtype=jnp.float32))
        z = _constrain_anchor(jax.tree.map(lambda t: t[0], x_stacked), axes_tree)
        err = jax.tree.map(lambda t: jnp.zeros(t.shape, jnp.float32), z)
        return AlgoVars(z=z, extra=err)

    def init_inflight(self, x_stacked, vars, axes_tree=None):
        if self.packed:
            return _constrain_anchor_packed(_pack_anchor(x_stacked), axes_tree)
        return _constrain_anchor(jax.tree.map(lambda t: t[0], x_stacked), axes_tree)

    def boundary_apply(self, x_stacked, vars: AlgoVars, inflight, axes_tree=None, membership=None):
        x_new = _pullback(x_stacked, inflight, self.cfg.alpha)
        if membership is not None:
            x_new = _live_where(membership.mask, x_new, x_stacked)
        # the consumed anchor is the base of this round's launched delta
        return x_new, AlgoVars(z=inflight, v=vars.v, extra=vars.extra)

    def boundary_launch(self, x_stacked, vars: AlgoVars, axes_tree=None, membership=None):
        mean_x = _worker_mean(x_stacked, _mem_weights(membership))
        if self.k >= 1.0:  # dense: bitwise-identical to OverlapLocalSGDStrategy
            z_new = mean_x
            err = vars.extra
        else:
            delta = jax.tree.map(
                lambda m, z, e: m.astype(jnp.float32) - z.astype(jnp.float32) + e, mean_x, vars.z, vars.extra
            )
            s = sparsify_topk(delta, self.k)
            err = jax.tree.map(lambda d, si: d - si, delta, s)
            z_new = jax.tree.map(lambda z, si: (z.astype(jnp.float32) + si).astype(z.dtype), vars.z, s)
        z_new = _constrain_anchor(z_new, axes_tree)
        return AlgoVars(z=vars.z, v=vars.v, extra=err), z_new

    def _packed_boundary(self, x_stacked, vars: AlgoVars, inflight, axes_tree=None, probe: bool = False, membership=None):
        px = _as_plane(x_stacked)
        # fused pullback + post-pullback mean; the consumed anchor (inflight)
        # is the base of this round's launched delta. With probe the same
        # launches emit the consensus partial sums.
        outs = [
            anchor_ops.pullback_mean(bx, bz, self.cfg.alpha, probe=probe, weights=_mem_weights(membership))
            for bx, bz in zip(px.buffers, inflight.buffers)
        ]
        x_new = Packed(tuple(o[0] for o in outs), px.layout)
        mean_bufs = tuple(o[1] for o in outs)
        if self.k >= 1.0:  # dense: z' = mean(x), nothing truncated
            z_next = Packed(mean_bufs, inflight.layout)
            err = vars.extra
        else:
            # Δ + e in f32 (one sweep per bucket); top-k thresholds per leaf
            # via static slices of the plane; the where/error-feedback
            # sweeps stay packed
            s_bufs, err_bufs, z_bufs = [], [], []
            for bi, (bm, bz, be) in enumerate(zip(mean_bufs, inflight.buffers, vars.extra.buffers)):
                delta = bm.astype(jnp.float32) - bz.astype(jnp.float32) + be
                thresh = _packed_thresholds(delta, inflight.layout, bi, self.k)
                s = jnp.where(jnp.abs(delta) >= thresh, delta, jnp.zeros_like(delta))
                s_bufs.append(s)
                err_bufs.append(delta - s)
                z_bufs.append((bz.astype(jnp.float32) + s).astype(bz.dtype))
            z_next = Packed(tuple(z_bufs), inflight.layout)
            err = Packed(tuple(err_bufs), vars.extra.layout)
        z_next = _constrain_anchor_packed(z_next, axes_tree)
        result = (_match_rep(x_stacked, x_new), AlgoVars(z=inflight, v=vars.v, extra=err), z_next)
        if probe:
            stats = probe_ops.stats_from_partials([o[-1] for o in outs], x_stacked_leading(x_stacked))
            return result + (stats,)
        return result

    def state_axes(self, axes_tree):
        if self.packed:
            return AlgoVars(z=PACKED_ANCHOR_AXES, extra=PACKED_ANCHOR_AXES), PACKED_ANCHOR_AXES
        a = anchor_axes(axes_tree)
        return AlgoVars(z=a, extra=a), a


class GossipInflight(NamedTuple):
    """A launched gossip push: the neighbor-weighted parameter sums (worker-
    stacked plane or pytree) plus the (m,) f32 pushed push-weights that
    debias them at the next boundary (z_i = mix_i / w_i)."""

    mix: Any
    w: Any


class GossipPushSumStrategy(CommStrategy):
    """Stochastic-Gradient-Push gossip (arXiv 1811.10792) over a sparse
    mixing topology (:mod:`repro.core.topology`).

    Each worker carries a **push weight** w_i (``vars.extra``, init 1). At
    a boundary it pushes its weighted model w_i·x_i and its weight w_i
    through the round's column-stochastic matrix P (asymmetric sends — the
    two-phase protocol's in-flight slot carries them for τ local steps),
    and the *next* boundary debiases the received sums:

        launch:  mix_i  = Σ_j P[i,j]·w_j·x_j      (GossipInflight.mix)
                 w'_i   = Σ_j P[i,j]·w_j          (GossipInflight.w)
        apply:   z_i    = mix_i / w'_i            (weight-normalized average)
                 x_i   ← x_i + α·(z_i − x_i)      (the paper's pullback, eq. 4)

    Column-stochasticity conserves total push-weight mass (Σ_i w_i is
    invariant), so z_i is always a convex combination of neighbor models;
    with the doubly-stochastic fully-live matrices of
    :mod:`repro.core.topology`, w stays at its fixed point w ≡ 1.

    ``membership`` composes into the matrix per the SGP recipe
    (:func:`repro.core.topology.compose_membership`): a dead neighbor's
    column renormalizes away, dead rows pass through (x and w both), and
    a rejoining worker re-syncs host-side from the anchor as usual.

    The degenerate ``full`` topology is special-cased onto the *exact*
    Overlap-Local-SGD (β=0) code path — fused ``pullback_mean`` per bucket,
    ``_worker_mean`` + ``_pullback`` per leaf — so fully-connected gossip
    reproduces the membership-weighted masked mean bit for bit (its matrix
    rows composed with a mask *are* ``Membership.weights``, and w ≡ 1
    analytically).
    """

    name = "gossip_pushsum"
    needs_anchor = False
    # subclasses pin the topology; None defers to cfg.topology
    topology: Optional[str] = None

    def __init__(self, cfg: AlgoConfig):
        super().__init__(cfg)
        self.topo_name = self.topology or getattr(cfg, "topology", "full") or "full"
        self.full = self.topo_name == "full"

    # ---- state ----
    def init_vars(self, x_stacked, axes_tree=None) -> AlgoVars:
        m = x_stacked_leading(x_stacked)
        # per-worker push weights + the phase counter indexing the matrix
        # cycle (boundary hooks receive no round index; the counter rides
        # the scan carry)
        return AlgoVars(extra=(jnp.ones((m,), jnp.float32), jnp.zeros((), jnp.int32)))

    def init_inflight(self, x_stacked, vars: AlgoVars, axes_tree=None):
        if self.full:
            # all workers start equal — identical to OverlapLocalSGD
            if self.packed:
                return _constrain_anchor_packed(_pack_anchor(x_stacked), axes_tree)
            return _constrain_anchor(jax.tree.map(lambda t: t[0], x_stacked), axes_tree)
        m = x_stacked_leading(x_stacked)
        if self.packed:
            mix = _as_plane(x_stacked)
        else:
            mix = jax.tree.map(jnp.copy, x_stacked)
        # w' = 1: round 0's apply debiases by exactly 1.0 (IEEE-exact), so
        # the first pullback is the identity on an equal start
        return GossipInflight(mix=mix, w=jnp.ones((m,), jnp.float32))

    # ---- topology plumbing ----
    def _push_matrix(self, m: int, t, w, membership):
        """Round-t effective push matrix P̃ · diag(w): the membership-composed
        mixing matrix with the senders' push weights folded into the columns,
        so ``mix = Peff @ x`` and ``w' = Peff.sum(axis=1)`` in one materialized
        (m, m) f32 matrix."""
        topo = cached_topology(self.topo_name, m)
        mats = jnp.asarray(topo.mats)
        P = mats[0] if topo.num_phases == 1 else mats[t % topo.num_phases]
        if membership is not None:
            P = compose_membership(P, membership.mask)
        return P * w.astype(jnp.float32)[None, :]

    @staticmethod
    def _tick(vars: AlgoVars) -> AlgoVars:
        w, t = vars.extra
        return AlgoVars(z=vars.z, v=vars.v, extra=(w, t + 1))

    # ---- per-leaf oracle phases ----
    def boundary_apply(self, x_stacked, vars: AlgoVars, inflight, axes_tree=None, membership=None):
        alpha = self.cfg.alpha
        if self.full:
            x_new = _pullback(x_stacked, inflight, alpha)
            if membership is not None:
                x_new = _live_where(membership.mask, x_new, x_stacked)
            return x_new, vars
        w, t = vars.extra
        wmix = inflight.w
        # a row with zero received push mass (a worker that was dead when
        # this collective launched, now rejoining) takes the identity apply:
        # nothing arrived, so there is nothing to debias (0/0 otherwise)
        got = (wmix > 0).astype(jnp.float32)
        wsafe = jnp.where(wmix > 0, wmix, 1.0)

        def debias(ml):
            wb = wsafe.astype(jnp.float32).reshape((-1,) + (1,) * (ml.ndim - 1))
            return (ml.astype(jnp.float32) / wb).astype(ml.dtype)

        z = jax.tree.map(debias, inflight.mix)
        x_new = jax.vmap(lambda xi, zi: anchor_ops.pullback_tree(xi, zi, alpha))(x_stacked, z)
        mask = got if membership is None else got * membership.mask
        x_new = _live_where(mask, x_new, x_stacked)
        w_new = jnp.where(mask > 0, wmix, w)
        return x_new, AlgoVars(z=vars.z, v=vars.v, extra=(w_new, t))

    def boundary_launch(self, x_stacked, vars: AlgoVars, axes_tree=None, membership=None):
        if self.full:
            z_new = _worker_mean(x_stacked, _mem_weights(membership))
            return self._tick(vars), _constrain_anchor(z_new, axes_tree)
        w, t = vars.extra
        Peff = self._push_matrix(x_stacked_leading(x_stacked), t, w, membership)
        mix = jax.tree.map(
            lambda l: jnp.einsum("ij,j...->i...", Peff, l.astype(jnp.float32)).astype(l.dtype),
            x_stacked,
        )
        return self._tick(vars), GossipInflight(mix=mix, w=jnp.sum(Peff, axis=1))

    # ---- packed boundary ----
    def _packed_boundary(self, x_stacked, vars: AlgoVars, inflight, axes_tree=None, probe: bool = False, membership=None):
        alpha = self.cfg.alpha
        px = _as_plane(x_stacked)
        if self.full:
            # the degenerate case rides OverlapLocalSGD's exact fused path:
            # one pullback_mean launch per dtype bucket, masked via weights
            outs = [
                anchor_ops.pullback_mean(bx, bz, alpha, probe=probe, weights=_mem_weights(membership))
                for bx, bz in zip(px.buffers, inflight.buffers)
            ]
            x_new = Packed(tuple(o[0] for o in outs), px.layout)
            z_next = Packed(tuple(o[1] for o in outs), inflight.layout)
            result = (_match_rep(x_stacked, x_new), self._tick(vars), _constrain_anchor_packed(z_next, axes_tree))
            if probe:
                stats = probe_ops.stats_from_partials([o[-1] for o in outs], x_stacked_leading(x_stacked))
                return result + (stats,)
            return result
        # sparse topology: the mix does not read through the fused pullback,
        # so the probe is the standalone per-bucket launch (like cocod)
        stats = probe_ops.packed_probe(px) if probe else None
        w, t = vars.extra
        wmix = inflight.w
        # zero received mass → identity apply (mirrors the per-leaf oracle:
        # a rejoining worker's launched-while-dead row would debias 0/0)
        got = (wmix > 0).astype(jnp.float32)
        wb = jnp.where(wmix > 0, wmix, 1.0).astype(jnp.float32)[:, None]
        x_new = Packed(
            tuple(
                anchor_ops.anchor_mix(bx, (bm.astype(jnp.float32) / wb).astype(bx.dtype), alpha)
                for bx, bm in zip(px.buffers, inflight.mix.buffers)
            ),
            px.layout,
        )
        mask = got if membership is None else got * membership.mask
        x_new = _packed_live_where(mask, x_new, px)
        w_new = jnp.where(mask > 0, wmix, w)
        m = x_stacked_leading(x_stacked)
        Peff = self._push_matrix(m, t, w_new, membership)
        mix = buffer_map(lambda b: (Peff @ b.astype(jnp.float32)).astype(b.dtype), x_new)
        vars = AlgoVars(z=vars.z, v=vars.v, extra=(w_new, t + 1))
        out = (_match_rep(x_stacked, x_new), vars, GossipInflight(mix=mix, w=jnp.sum(Peff, axis=1)))
        return out + (stats,) if probe else out

    # ---- AOT spec support ----
    def state_axes(self, axes_tree):
        # vars — push weights (m,) + phase counter — replicate
        if self.full:
            infl = PACKED_ANCHOR_AXES if self.packed else anchor_axes(axes_tree)
            return None, infl
        if self.packed:
            return None, GossipInflight(mix=PACKED_STACKED_AXES, w=None)
        return None, GossipInflight(mix=_stacked_axes(axes_tree), w=None)


class GossipFullStrategy(GossipPushSumStrategy):
    """Fully-connected gossip: bitwise the membership-weighted masked mean."""

    name = "gossip_full"
    topology = "full"


class GossipRingStrategy(GossipPushSumStrategy):
    """Static ring gossip: each worker averages with its two ring neighbors."""

    name = "gossip_ring"
    topology = "ring"


class GossipExpStrategy(GossipPushSumStrategy):
    """One-peer exponential (hypercube) gossip: log₂(m) cycled phases."""

    name = "gossip_exp"
    topology = "exp"


# ---------------------------------------------------------------------------
# legacy adapter + factory
# ---------------------------------------------------------------------------


class LegacyStrategy(CommStrategy):
    """Adapter: runs a legacy single-hook ``Algorithm`` under the two-phase
    protocol. Everything the old ``boundary`` did happens in
    ``boundary_apply`` (i.e. treated as blocking); nothing is launched. This
    preserves the seed semantics bit-for-bit — it is the reference the
    golden equivalence tests compare the native ports against."""

    def __init__(self, algorithm):
        self.algorithm = algorithm
        self.cfg = algorithm.cfg
        self.tau = algorithm.tau
        self.name = algorithm.name
        self.needs_anchor = algorithm.needs_anchor
        self.packed = False  # legacy semantics are the per-leaf reference

    def init_vars(self, x_stacked, axes_tree=None) -> AlgoVars:
        return self.algorithm.init_vars(x_stacked, axes_tree)

    def transform_grads(self, grads_stacked, vars):
        return self.algorithm.transform_grads(grads_stacked, vars)

    def boundary_apply(self, x_stacked, vars, inflight, axes_tree=None, membership=None):
        if membership is not None:
            raise ValueError(
                "legacy algorithms predate the membership contract; run fault "
                "plans against a native strategy (DESIGN.md §7)"
            )
        return self.algorithm.boundary(x_stacked, vars, axes_tree)

    def state_axes(self, axes_tree):
        # mirror the legacy algorithms' state layout: sharded anchor (+ its
        # momentum for the overlap momentum variant), worker-stacked cocod
        # round-start copy; anything else (powersgd factors) replicates
        a = anchor_axes(axes_tree)
        z_ax = a if self.needs_anchor else None
        v_ax = a if (self.name == "overlap_local_sgd" and getattr(self.cfg, "anchor_beta", 0) > 0) else None
        extra_ax = _stacked_axes(axes_tree) if self.name == "cocod" else None
        if z_ax is None and v_ax is None and extra_ax is None:
            return None, None
        return AlgoVars(z=z_ax, v=v_ax, extra=extra_ax), None

    def metrics(self, x_stacked, vars):
        return self.algorithm.metrics(x_stacked, vars)


def as_strategy(algorithm_or_strategy) -> CommStrategy:
    """Coerce either API to a CommStrategy (legacy Algorithms get wrapped)."""
    if isinstance(algorithm_or_strategy, CommStrategy):
        return algorithm_or_strategy
    from repro.core.algorithms import Algorithm

    if isinstance(algorithm_or_strategy, Algorithm):
        return LegacyStrategy(algorithm_or_strategy)
    raise TypeError(f"expected CommStrategy or Algorithm, got {type(algorithm_or_strategy)!r}")


STRATEGIES = {
    "overlap_local_sgd": OverlapLocalSGDStrategy,
    "local_sgd": LocalSGDStrategy,
    "sync_sgd": SyncSGDStrategy,
    "easgd": EASGDStrategy,
    "cocod": CoCoDStrategy,
    "powersgd": PowerSGDStrategy,
    "delayed_avg": DelayedAveragingStrategy,
    "sparse_anchor": SparseAnchorStrategy,
    "gossip_pushsum": GossipPushSumStrategy,
    "gossip_full": GossipFullStrategy,
    "gossip_ring": GossipRingStrategy,
    "gossip_exp": GossipExpStrategy,
}

_ALIASES = {
    "dasgd": "delayed_avg",
    "loscar": "sparse_anchor",
    "overlap": "overlap_local_sgd",
    "sgp": "gossip_pushsum",
}


def make_strategy(cfg: AlgoConfig) -> CommStrategy:
    name = _ALIASES.get(cfg.name, cfg.name)
    if name not in STRATEGIES:
        raise ValueError(f"unknown strategy {cfg.name!r}; known: {sorted(STRATEGIES) + sorted(_ALIASES)}")
    return STRATEGIES[name](cfg)


def resolve_strategy(strategy) -> CommStrategy:
    """The one strategy-resolution chain: a name becomes an ``AlgoConfig``
    (library defaults), an ``AlgoConfig`` goes through :func:`make_strategy`,
    and instances (including legacy ``Algorithm`` objects, wrapped
    transparently) pass through :func:`as_strategy`.
    ``repro.api.Experiment`` (which re-exports this as the public surface),
    the production dry-run (``launch/dryrun.py``) and the cost probes
    (``launch/costprobe.py``) all lower through it, so the program the
    dry-run cost-models is the program training runs."""
    if isinstance(strategy, str):
        strategy = AlgoConfig(name=strategy)
    if isinstance(strategy, AlgoConfig):
        return make_strategy(strategy)
    return as_strategy(strategy)
