"""PowerSGD [5] — rank-r gradient compression with error feedback.

The paper uses PowerSGD as its strongest gradient-compression baseline
(Fig. 4). Implementation follows Vogels et al.: per 2-D-reshaped gradient
M = g + e (error feedback), one power-iteration step
P = QR(mean_i(M_i Q)), Q' = mean_i(M_iᵀ P), decoded ĝ = P Q'ᵀ; vectors
(1-D leaves) are all-reduced uncompressed. Both means are worker-axis
collectives of *rank-r factors* — the compression. Runs every step
(tau = 1, synchronous), so in the runtime model its latency is
handshake + compressed payload + encode/decode, matching the paper's
observation that handshake cost cannot be compressed away.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.config.base import AlgoConfig
from repro.core.algorithms import Algorithm, AlgoVars, _broadcast_like, _worker_mean


class PowerState(NamedTuple):
    q: Any  # per-leaf (b, r) factors — shared across workers
    err: Any  # per-leaf per-worker error feedback (stacked)


def _mat_shape(shape) -> tuple:
    a = shape[0]
    b = 1
    for s in shape[1:]:
        b *= s
    return a, b


class PowerSGD(Algorithm):
    name = "powersgd"

    def __init__(self, cfg: AlgoConfig):
        super().__init__(cfg)
        self.tau = 1
        self.rank = cfg.powersgd_rank

    def init_vars(self, x_stacked, axes_tree=None) -> AlgoVars:
        r = self.rank

        def init_q(t):
            shape = t.shape[1:]  # drop worker axis
            if len(shape) < 2:
                return None
            a, b = _mat_shape(shape)
            key = jax.random.PRNGKey(hash(shape) % (2**31))
            return jax.random.normal(key, (b, min(r, a, b)), jnp.float32)

        q = jax.tree.map(init_q, x_stacked)
        err = jax.tree.map(lambda t: jnp.zeros(t.shape, jnp.float32), x_stacked)
        return AlgoVars(extra=PowerState(q=q, err=err))

    def transform_grads(self, grads_stacked, vars: AlgoVars):
        st: PowerState = vars.extra

        def leaf(g, q, e):
            m = g.shape[0]
            shape = g.shape[1:]
            if q is None:  # 1-D (or scalar) leaf: plain all-reduce
                mean = jnp.mean(g.astype(jnp.float32), axis=0)
                return jnp.broadcast_to(mean, g.shape).astype(g.dtype), None, jnp.zeros_like(e)
            a, b = _mat_shape(shape)
            M = g.astype(jnp.float32).reshape(m, a, b) + e.reshape(m, a, b)
            P = jnp.mean(M @ q, axis=0)  # (a, r) — all-reduce of rank-r factor
            P, _ = jnp.linalg.qr(P)
            Qn = jnp.mean(jnp.einsum("mab,ar->mbr", M, P), axis=0)  # (b, r) — all-reduce
            ghat = (P @ Qn.T)[None]  # (1, a, b), identical across workers
            new_e = (M - ghat).reshape((m,) + shape)
            ghat_full = jnp.broadcast_to(ghat, (m, a, b)).reshape((m,) + shape)
            return ghat_full.astype(g.dtype), Qn, new_e

        flat_g, tdef = jax.tree.flatten(grads_stacked)
        flat_q = tdef.flatten_up_to(st.q)
        flat_e = tdef.flatten_up_to(st.err)
        outs = [leaf(g, q, e) for g, q, e in zip(flat_g, flat_q, flat_e)]
        new_g = tdef.unflatten([o[0] for o in outs])
        new_q = tdef.unflatten([o[1] if o[1] is not None else q for o, q in zip(outs, flat_q)])
        new_e = tdef.unflatten([o[2] for o in outs])
        return new_g, AlgoVars(z=vars.z, v=vars.v, extra=PowerState(q=new_q, err=new_e))

    def compressed_bytes(self, param_bytes_2d: int, a: int, b: int) -> int:
        return 4 * self.rank * (a + b)
