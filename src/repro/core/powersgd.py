"""PowerSGD [5] — rank-r gradient compression with error feedback.

The paper uses PowerSGD as its strongest gradient-compression baseline
(Fig. 4). Implementation follows Vogels et al.: per 2-D-reshaped gradient
M = g + e (error feedback), one power-iteration step
P = QR(mean_i(M_i Q)), Q' = mean_i(M_iᵀ P), decoded ĝ = P Q'ᵀ; vectors
(1-D leaves) are all-reduced uncompressed. Both means are worker-axis
collectives of *rank-r factors* — the compression. Runs every step
(tau = 1, synchronous), so in the runtime model its latency is
handshake + compressed payload + encode/decode, matching the paper's
observation that handshake cost cannot be compressed away.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import AlgoConfig
from repro.core.algorithms import Algorithm, AlgoVars, _broadcast_like, _worker_mean
from repro.parallel.packing import Packed, buffer_map, leaf_segments, pack, packed_like, view_leaf


class PowerState(NamedTuple):
    q: Any  # per-leaf (b, r) factors — shared across workers
    err: Any  # error feedback: per-leaf stacked tree, or (packed path) an
    #           f32 Packed shadow of the worker-stacked gradient plane


def _mat_shape(shape) -> tuple:
    a = shape[0]
    b = 1
    for s in shape[1:]:
        b *= s
    return a, b


class PowerSGD(Algorithm):
    name = "powersgd"

    def __init__(self, cfg: AlgoConfig):
        super().__init__(cfg)
        self.tau = 1
        self.rank = cfg.powersgd_rank

    def _init_q(self, x_stacked):
        r = self.rank

        def q_for(shape):
            if len(shape) < 2:
                return None
            a, b = _mat_shape(shape)
            key = jax.random.PRNGKey(hash(shape) % (2**31))
            return jax.random.normal(key, (b, min(r, a, b)), jnp.float32)

        if isinstance(x_stacked, Packed):
            # plane-resident state: per-leaf shapes come from the layout
            # table (slot shapes already exclude the worker lead)
            lay = x_stacked.layout
            return jax.tree_util.tree_unflatten(lay.treedef, [q_for(s.shape) for s in lay.slots])
        return jax.tree.map(lambda t: q_for(t.shape[1:]), x_stacked)

    def init_vars(self, x_stacked, axes_tree=None) -> AlgoVars:
        err = jax.tree.map(lambda t: jnp.zeros(t.shape, jnp.float32), x_stacked)
        return AlgoVars(extra=PowerState(q=self._init_q(x_stacked), err=err))

    def init_vars_packed(self, x_stacked, axes_tree=None) -> AlgoVars:
        """Packed-plane state: q factors stay per-leaf (they ARE the rank-r
        compression), the error feedback lives as an f32 shadow of the
        worker-stacked gradient plane (same buckets/offsets as the params).
        Accepts the plane itself (plane-resident state) or the stacked
        pytree."""
        px = x_stacked if isinstance(x_stacked, Packed) else pack(x_stacked, lead=1)
        err = packed_like(px, 0.0, dtype=jnp.float32)
        return AlgoVars(extra=PowerState(q=self._init_q(x_stacked), err=err))

    def transform_grads(self, grads_stacked, vars: AlgoVars):
        st: PowerState = vars.extra

        def leaf(g, q, e):
            m = g.shape[0]
            shape = g.shape[1:]
            if q is None:  # 1-D (or scalar) leaf: plain all-reduce
                mean = jnp.mean(g.astype(jnp.float32), axis=0)
                return jnp.broadcast_to(mean, g.shape).astype(g.dtype), None, jnp.zeros_like(e)
            a, b = _mat_shape(shape)
            M = g.astype(jnp.float32).reshape(m, a, b) + e.reshape(m, a, b)
            P = jnp.mean(M @ q, axis=0)  # (a, r) — all-reduce of rank-r factor
            P, _ = jnp.linalg.qr(P)
            Qn = jnp.mean(jnp.einsum("mab,ar->mbr", M, P), axis=0)  # (b, r) — all-reduce
            ghat = (P @ Qn.T)[None]  # (1, a, b), identical across workers
            new_e = (M - ghat).reshape((m,) + shape)
            ghat_full = jnp.broadcast_to(ghat, (m, a, b)).reshape((m,) + shape)
            return ghat_full.astype(g.dtype), Qn, new_e

        flat_g, tdef = jax.tree.flatten(grads_stacked)
        flat_q = tdef.flatten_up_to(st.q)
        flat_e = tdef.flatten_up_to(st.err)
        outs = [leaf(g, q, e) for g, q, e in zip(flat_g, flat_q, flat_e)]
        new_g = tdef.unflatten([o[0] for o in outs])
        new_q = tdef.unflatten([o[1] if o[1] is not None else q for o, q in zip(outs, flat_q)])
        new_e = tdef.unflatten([o[2] for o in outs])
        return new_g, AlgoVars(z=vars.z, v=vars.v, extra=PowerState(q=new_q, err=new_e))

    def transform_grads_packed(self, pg: Packed, vars: AlgoVars):
        """PowerSGD over the packed gradient plane.

        The rank-r factor math (power iteration, QR, the two factor
        collectives) is *inherently* per-matrix — that per-leaf work is the
        compression itself and stays. Everything elementwise around it is
        rerouted over the plane:

        * error-feedback add  M = g + e      — one f32 sweep per bucket;
        * decode cast + error update  e' = M − ĝ  — one masked sweep per
          bucket (the static mask marks compressed slots; uncompressed slots
          carry zero error, as in the per-leaf path).

        The plain all-reduce of the uncompressed (1-D/scalar) leaves stays
        *per-leaf*, like the factor collectives: those leaves are the small
        tail of the plane, and a single per-bucket mean would sweep (and,
        on a mesh, all-reduce) the whole gradient plane — full-plane traffic
        for an algorithm whose point is rank-r traffic compression. Factor
        reads go through :func:`view_leaf` (static slices of the plane) and
        decoded ĝ blocks are scattered into a zeroed decode plane with
        static-offset ``dynamic_update_slice`` — layout ops, not kernel
        launches. Numerics are bitwise identical to :meth:`transform_grads`;
        pinned by the golden differential suite.
        """
        st: PowerState = vars.extra  # q: per-leaf factors, err: f32 Packed
        layout = pg.layout
        m = int(pg.lead_shape[0])
        f32 = st.err.layout
        # (1) error-feedback add, one sweep per bucket
        M = buffer_map(lambda g, e: g.astype(jnp.float32) + e, pg, st.err, layout=f32)
        # (2) assemble the decode plane ĝ: rank-r decodes for ≥2-D leaves,
        #     per-leaf worker-means (the oracle's plain all-reduce) for the
        #     uncompressed tail. The scatter back onto the plane is pack()
        #     itself — one mechanism (and one copy of the jax-0.4.x
        #     DUS-not-concatenate partitioning workaround) for every plane
        #     build in the repo; padding lanes stay zero
        flat_q = layout.treedef.flatten_up_to(st.q)
        new_q = list(flat_q)
        gh_leaves = []
        for slot, q in zip(layout.slots, flat_q):
            if q is None:  # 1-D/scalar: mean of the raw gradient, no error
                gi = view_leaf(pg, slot.index).reshape(m, slot.size)
                mean = jnp.mean(gi.astype(jnp.float32), axis=0)
                gh = jnp.broadcast_to(mean[None], (m, slot.size))
            else:
                a, b = _mat_shape(slot.shape)
                Mi = view_leaf(M, slot.index).reshape(m, a, b)
                P = jnp.mean(Mi @ q, axis=0)  # (a, r) — all-reduce of rank-r factor
                P, _ = jnp.linalg.qr(P)
                Qn = jnp.mean(jnp.einsum("mab,ar->mbr", Mi, P), axis=0)  # (b, r) — all-reduce
                gh = jnp.broadcast_to((P @ Qn.T)[None], (m, a, b)).reshape(m, slot.size)
                new_q[slot.index] = Qn
            gh_leaves.append(gh.reshape((m,) + slot.shape))
        ghat = pack(layout.treedef.unflatten(gh_leaves), layout=f32, lead=1)
        masks = _compressed_masks(layout, flat_q)
        new_g = buffer_map(lambda gh, g: gh.astype(g.dtype), ghat, pg, layout=layout)
        err_bufs = tuple(
            jnp.where(mk, Mb - gb, 0.0) for mk, Mb, gb in zip(masks, M.buffers, ghat.buffers)
        )
        new_err = Packed(err_bufs, f32)
        return new_g, AlgoVars(
            z=vars.z, v=vars.v, extra=PowerState(q=layout.treedef.unflatten(new_q), err=new_err)
        )

    def compressed_bytes(self, param_bytes_2d: int, a: int, b: int) -> int:
        return 4 * self.rank * (a + b)


def _compressed_masks(layout, flat_q):
    """Per-bucket element masks: True where the element belongs to a
    rank-compressed (≥2-D) leaf. Built as a runtime ``jnp.repeat`` of
    O(slots) per-slot flags (the ``_packed_thresholds`` pattern) — a
    trace-time full-plane bool literal would embed a plane-sized constant
    in the HLO at exactly the model scale the packed path targets."""
    masks = []
    for b in range(layout.num_buckets):
        segs = leaf_segments(layout, b)
        vals = jnp.asarray(np.array([flat_q[s.index] is not None for s in segs], bool))
        reps = np.array([s.stride for s in segs], np.int64)
        masks.append(jnp.repeat(vals, np.asarray(reps), total_repeat_length=int(reps.sum())))
    return tuple(masks)
