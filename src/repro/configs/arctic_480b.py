"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base] — dense-MoE
hybrid: every layer has a 128-expert top-2 MoE *in parallel with* a dense
residual FFN."""
from repro.config import (
    ArchConfig,
    AttentionConfig,
    ModelConfig,
    MoEConfig,
    ParallelPlan,
    register,
)

MODEL = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    d_ff=4864,
    vocab_size=32000,
    attention=AttentionConfig(
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        rope_theta=10000.0,
    ),
    moe=MoEConfig(
        num_experts=128,
        top_k=2,
        expert_ff=4864,
        dense_residual_ff=4864,
    ),
    layer_pattern=("moe",) * 35,
    source="hf:Snowflake/snowflake-arctic-base",
)

ARCH = register(
    ArchConfig(
        model=MODEL,
        plans={
            # 480B cannot hold >1 local replica in a single 256-chip v5e pod:
            # single-pod runs w=1 (degenerate Local-SGD; see DESIGN.md §3),
            # multi-pod scales the worker axis across pods (w=2).
            "default": ParallelPlan(workers=1, fsdp=16, tensor=16),
        },
        train_microbatch=16,
        long_context_policy="swa_variant",
    )
)
