"""H2O-Danube-1.8B [arXiv:2401.16818] — llama/mistral mix with sliding-window
attention (the model card trains with mistral-style SWA)."""
from repro.config import ArchConfig, AttentionConfig, ModelConfig, ParallelPlan, register

MODEL = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    d_ff=6912,
    vocab_size=32000,
    attention=AttentionConfig(
        num_heads=32,
        num_kv_heads=8,
        head_dim=80,
        sliding_window=4096,
        rope_theta=10000.0,
    ),
    source="arXiv:2401.16818",
)

ARCH = register(
    ArchConfig(
        model=MODEL,
        plans={"default": ParallelPlan(workers=16, fsdp=1, tensor=16)},
        train_microbatch=8,
        long_context_policy="native",  # SWA is part of the architecture
    )
)
