"""Command-R 35B [hf:CohereForAI/c4ai-command-r-v01] — GQA, no biases,
parallel attn∥FFN blocks, LayerNorm, tied embeddings, logit_scale 0.0625."""
from repro.config import ArchConfig, AttentionConfig, ModelConfig, ParallelPlan, register

MODEL = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    d_ff=22528,
    vocab_size=256000,
    attention=AttentionConfig(
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        rope_theta=8_000_000.0,
    ),
    use_parallel_block=True,
    tie_embeddings=True,
    logit_scale=0.0625,
    source="hf:CohereForAI/c4ai-command-r-v01",
)

ARCH = register(
    ArchConfig(
        model=MODEL,
        plans={"default": ParallelPlan(workers=4, fsdp=4, tensor=16)},
        train_microbatch=8,
        long_context_policy="swa_variant",
    )
)
