"""Assigned-architecture configs. Importing this package registers all archs."""
from repro.configs import (  # noqa: F401
    arctic_480b,
    command_r_35b,
    deepseek_v3_671b,
    h2o_danube_1_8b,
    mistral_large_123b,
    musicgen_large,
    qwen2_7b,
    qwen2_vl_7b,
    rwkv6_7b,
    zamba2_1_2b,
)

ASSIGNED = [
    "qwen2-7b",
    "h2o-danube-1.8b",
    "command-r-35b",
    "mistral-large-123b",
    "qwen2-vl-7b",
    "zamba2-1.2b",
    "arctic-480b",
    "deepseek-v3-671b",
    "musicgen-large",
    "rwkv6-7b",
]
