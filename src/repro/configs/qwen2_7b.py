"""Qwen2-7B [arXiv:2407.10671] — dense, GQA (28H / 4 KV), QKV bias."""
from repro.config import ArchConfig, AttentionConfig, ModelConfig, ParallelPlan, register

MODEL = ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    d_ff=18944,
    vocab_size=152064,
    attention=AttentionConfig(
        num_heads=28,
        num_kv_heads=4,
        head_dim=128,
        qkv_bias=True,
        rope_theta=1_000_000.0,
    ),
    norm_eps=1e-6,
    source="arXiv:2407.10671",
)

ARCH = register(
    ArchConfig(
        model=MODEL,
        plans={
            "default": ParallelPlan(workers=16, fsdp=1, tensor=16),
        },
        train_microbatch=4,
        long_context_policy="swa_variant",  # full attention: long_500k runs the labelled SWA variant
    )
)
