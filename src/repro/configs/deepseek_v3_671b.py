"""DeepSeek-V3 671B [arXiv:2412.19437] — MLA, 1 shared + 256 routed experts
top-8 (sigmoid router, normalized gates), first 3 layers dense, MTP module."""
from repro.config import (
    ArchConfig,
    AttentionConfig,
    ModelConfig,
    MoEConfig,
    ParallelPlan,
    register,
)

MODEL = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    d_ff=18432,  # dense layers (first 3)
    vocab_size=129280,
    attention=AttentionConfig(
        kind="mla",
        num_heads=128,
        num_kv_heads=128,
        head_dim=192,  # qk_nope + qk_rope
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        rope_theta=10000.0,
    ),
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        expert_ff=2048,
        num_shared_experts=1,
        shared_expert_ff=2048,
        first_k_dense=3,
    ),
    layer_pattern=("attn",) * 3 + ("moe",) * 58,
    mtp_depth=1,
    source="arXiv:2412.19437",
)

ARCH = register(
    ArchConfig(
        model=MODEL,
        plans={
            # 671B: one replica needs the whole pod (w=1 single-pod; w=2 multi-pod).
            "default": ParallelPlan(workers=1, fsdp=16, tensor=16),
        },
        train_microbatch=16,
        long_context_policy="swa_variant",
    )
)
