"""MusicGen-large [arXiv:2306.05284] — decoder-only over EnCodec tokens
(4 codebooks, delay pattern applied by the data pipeline; the EnCodec
conv codec itself is the stubbed audio frontend). GELU MLPs, MHA.

Positional scheme: the released model uses sinusoidal embeddings; we use
RoPE (TPU-idiomatic; noted in DESIGN.md hardware-adaptation table)."""
from repro.config import (
    ArchConfig,
    AttentionConfig,
    FrontendConfig,
    ModelConfig,
    ParallelPlan,
    register,
)

MODEL = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    d_ff=8192,
    vocab_size=2048,
    attention=AttentionConfig(
        num_heads=32,
        num_kv_heads=32,
        head_dim=64,
        rope_theta=10000.0,
    ),
    act="gelu",
    frontend=FrontendConfig(kind="audio", embed_dim=2048, tokens_per_item=1500, num_codebooks=4),
    source="arXiv:2306.05284",
)

ARCH = register(
    ArchConfig(
        model=MODEL,
        plans={"default": ParallelPlan(workers=16, fsdp=1, tensor=16)},
        train_microbatch=8,
        long_context_policy="swa_variant",
    )
)
