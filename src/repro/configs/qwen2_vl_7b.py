"""Qwen2-VL-7B [arXiv:2409.12191] — Qwen2-7B backbone + M-RoPE + dynamic-
resolution ViT (stubbed: ``input_specs`` provides precomputed patch
embeddings of the ViT output dim; the learned projector is part of this
model)."""
from repro.config import (
    ArchConfig,
    AttentionConfig,
    FrontendConfig,
    ModelConfig,
    ParallelPlan,
    register,
)

MODEL = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    d_ff=18944,
    vocab_size=152064,
    attention=AttentionConfig(
        num_heads=28,
        num_kv_heads=4,
        head_dim=128,
        qkv_bias=True,
        rope="mrope",
        rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24),  # (t, h, w) bands over head_dim/2 = 64
    ),
    frontend=FrontendConfig(kind="vision", embed_dim=1280, tokens_per_item=1024),
    norm_eps=1e-6,
    source="arXiv:2409.12191",
)

ARCH = register(
    ArchConfig(
        model=MODEL,
        plans={"default": ParallelPlan(workers=16, fsdp=1, tensor=16)},
        train_microbatch=4,
        long_context_policy="swa_variant",
    )
)
