"""Zamba2-1.2B [arXiv:2411.15242] — Mamba2 backbone with a weight-shared
attention block interleaved (every 7th position here; the released model
shares one transformer block invoked periodically — we keep the shared-
weights property, dropping only the per-invocation LoRA deltas, noted in
DESIGN.md)."""
from repro.config import (
    ArchConfig,
    AttentionConfig,
    ModelConfig,
    ParallelPlan,
    SSMConfig,
    register,
)

_PATTERN = tuple("shared_attn" if i % 7 == 6 else "mamba2" for i in range(38))

MODEL = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    d_ff=8192,
    vocab_size=32000,
    attention=AttentionConfig(
        num_heads=32,
        num_kv_heads=32,
        head_dim=64,
        sliding_window=4096,  # keeps long_500k serveable; full attn within 4k
        rope_theta=10000.0,
    ),
    ssm=SSMConfig(kind="mamba2", state_dim=64, num_heads=64, head_dim=64, expand=2, conv_width=4, chunk_size=128),
    layer_pattern=_PATTERN,
    shared_attn_every=7,
    tie_embeddings=True,
    source="arXiv:2411.15242",
)

ARCH = register(
    ArchConfig(
        model=MODEL,
        plans={"default": ParallelPlan(workers=16, fsdp=1, tensor=16)},
        train_microbatch=8,
        long_context_policy="native",  # SSM state + windowed shared-attn
    )
)
