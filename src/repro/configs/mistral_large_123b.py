"""Mistral-Large-Instruct-2407 (123B) [hf:mistralai/Mistral-Large-Instruct-2407]."""
from repro.config import ArchConfig, AttentionConfig, ModelConfig, ParallelPlan, register

MODEL = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12288,
    d_ff=28672,
    vocab_size=32768,
    attention=AttentionConfig(
        num_heads=96,
        num_kv_heads=8,
        head_dim=128,
        rope_theta=1_000_000.0,
    ),
    source="hf:mistralai/Mistral-Large-Instruct-2407",
)

ARCH = register(
    ArchConfig(
        model=MODEL,
        plans={"default": ParallelPlan(workers=2, fsdp=8, tensor=16)},
        train_microbatch=8,
        long_context_policy="swa_variant",
    )
)
