"""RWKV-6 "Finch" 7B [arXiv:2404.05892] — attention-free; time-mix with
data-dependent decay + channel-mix. 64 heads × 64 head_dim."""
from repro.config import ArchConfig, ModelConfig, ParallelPlan, SSMConfig, register

MODEL = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=65536,
    attention=None,
    ssm=SSMConfig(kind="rwkv6", num_heads=64, head_dim=64, chunk_size=32),
    layer_pattern=("rwkv6",) * 32,
    source="arXiv:2404.05892",
)

ARCH = register(
    ArchConfig(
        model=MODEL,
        plans={"default": ParallelPlan(workers=16, fsdp=1, tensor=16)},
        train_microbatch=4,
        long_context_policy="native",  # constant-size recurrent state
    )
)
