"""Jitted round programs keyed by τ (DESIGN.md §6).

τ is a static shape parameter of the compiled round program — the round
batch carries τ as its leading axis, so every distinct τ is a distinct
XLA program. The controller only ever doubles or halves τ inside
[τ_min, τ_max], so a run touches at most O(log τ_max) distinct values;
:class:`RoundProgramCache` memoizes the compiled program per τ and counts
compilations so tests can pin that bound.
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.control.controller import TauController, consensus_drift


class RoundProgramCache:
    """Memoized ``make_program(tau) -> program`` with a compilation counter.

    ``make_program`` is called at most once per distinct τ; ``compilations``
    counts those calls (the O(log τ_max) bound the cache exists to enforce).
    """

    def __init__(self, make_program: Callable[[int], Callable]):
        self.make_program = make_program
        self._programs: Dict[int, Callable] = {}
        self.compilations = 0

    def program_for(self, tau: int) -> Callable:
        if tau not in self._programs:
            self._programs[tau] = self.make_program(tau)
            self.compilations += 1
        return self._programs[tau]

    @property
    def taus(self):
        """τ values with a compiled program (sorted)."""
        return sorted(self._programs)

    def __len__(self) -> int:
        return len(self._programs)

    def __contains__(self, tau: int) -> bool:
        return tau in self._programs


class TauScheduledTrainer:
    """Host-side driver that re-selects τ between rounds (legacy surface).

    ``make_step(tau)`` must return a jitted round_step for that τ; compiled
    steps are cached through :class:`RoundProgramCache`. Kept for the
    pre-control-plane API (``repro.core.adaptive``): it measures consensus
    on the *post*-boundary state with the per-leaf oracle. The production
    path is ``Experiment.fit(adaptive_tau=...)``, which reads the fused
    pre-boundary probe out of the round program's metrics instead.
    """

    def __init__(self, make_step: Callable[[int], Callable], controller: TauController):
        self.programs = RoundProgramCache(make_step)
        self.ctrl = controller

    @property
    def make_step(self) -> Callable[[int], Callable]:
        return self.programs.make_program

    @property
    def _cache(self) -> Dict[int, Callable]:
        # legacy attribute: the underlying {tau: program} dict
        return self.programs._programs

    def step_for(self, tau: int) -> Callable:
        return self.programs.program_for(tau)

    def run_round(self, state, batch_fn):
        tau = self.ctrl.tau
        step = self.step_for(tau)
        batch = batch_fn(tau)
        state, metrics = step(state, batch)
        drift, scale = consensus_drift(state.x)
        self.ctrl.update(float(drift), float(scale))
        return state, metrics, tau
