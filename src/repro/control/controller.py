"""Adaptive-τ controller — the host-side decision loop (DESIGN.md §6).

The paper fixes τ per run and points at its companion work (ref. [14],
AdaComm) for adapting it. The natural controller for Overlap-Local-SGD:
grow τ while the anchor communication stays hidden and the workers'
*consensus distance* stays a small fraction of the parameter norm, shrink
it when local models drift too far (the non-IID failure mode of Table 2).

    τ_{r+1} = clip(τ_r · 2,      if  drift_r < lo · scale_r
              τ_r,               if  lo·scale ≤ drift ≤ hi·scale
              max(τ_r / 2, 1),   if  drift_r > hi · scale_r)

with drift_r = mean_i ‖x_i − x̄‖ and scale_r = ‖x̄‖, both measured on the
*pre-boundary* plane by the fused consensus probe
(:mod:`repro.kernels.consensus_probe`). The strict inequalities are the
hysteresis band: a ratio sitting inside [lo, hi] — including exactly on
either edge — holds τ, so the controller cannot flap between two values
on a boundary-riding signal.

The controller runs on the host between rounds: τ is a *static shape
parameter* of the compiled round program (the round batch's leading axis),
so changing it selects a different jitted program from
:class:`repro.control.program_cache.RoundProgramCache` — the doubling
/halving rule means at most O(log τ_max) programs ever compile.

``warmup_rounds`` holds τ fixed while the freshly initialized workers are
still scattering (the first rounds' drift reflects initialization, not the
data distribution); ``cooldown_rounds`` holds τ for N rounds after every
change so a decision is judged on drift measured *at the new τ*, not on
the stale pre-change signal.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List

import jax
import jax.numpy as jnp


@dataclass
class TauController:
    """AdaComm-style multiplicative τ controller with hysteresis.

    Telemetry: every :meth:`update` appends one structured record to
    ``history`` with keys ``round``, ``tau`` (the τ the round ran at),
    ``drift``, ``scale``, ``drift_ratio``, ``decision`` (one of
    ``warmup | cooldown | grow | shrink | hold | clamp | fault_hold``) and
    ``next_tau``; records of fault rounds additionally carry a ``fault``
    key with the harness's reason string. The training loop surfaces these
    records as the run's τ schedule.
    """

    tau: int = 1
    tau_min: int = 1
    tau_max: int = 32
    lo: float = 0.01  # drift/scale below this: communicate less often
    hi: float = 0.05  # drift/scale above this: communicate more often
    warmup_rounds: int = 0  # hold τ for the first N rounds
    cooldown_rounds: int = 0  # hold τ for N rounds after every change
    history: List[dict] = field(default_factory=list)
    _round: int = field(default=0, init=False, repr=False)
    _cooldown: int = field(default=0, init=False, repr=False)

    def update(self, drift: float, scale: float, fault: "str | None" = None) -> int:
        """Consume one round's consensus stats, return the next round's τ.

        ``fault`` (a reason string from the fault harness, e.g.
        ``"crash+deadline"``) marks a degraded round: τ is held — the
        round's drift was measured under a partial membership, so acting on
        it would let a crash masquerade as a non-IID drift signal — and the
        record carries the reason. Fault holds do not consume cooldown:
        the post-change observation window resumes on the next clean round.
        """
        ratio = float(drift) / max(float(scale), 1e-12)
        old = self.tau
        if fault is not None:
            decision = "fault_hold"
        elif self._round < self.warmup_rounds:
            decision = "warmup"
        elif self._cooldown > 0:
            decision = "cooldown"
            self._cooldown -= 1
        elif ratio < self.lo:
            self.tau = min(self.tau * 2, self.tau_max)
            decision = "grow" if self.tau != old else "clamp"
        elif ratio > self.hi:
            self.tau = max(self.tau // 2, self.tau_min)
            decision = "shrink" if self.tau != old else "clamp"
        else:
            decision = "hold"
        if decision in ("grow", "shrink"):
            self._cooldown = self.cooldown_rounds
        record = dict(
            round=self._round,
            tau=old,
            drift=float(drift),
            scale=float(scale),
            drift_ratio=ratio,
            decision=decision,
            next_tau=self.tau,
        )
        if fault is not None:
            record["fault"] = str(fault)
        self.history.append(record)
        self._round += 1
        return self.tau

    @property
    def taus_seen(self) -> List[int]:
        """Distinct τ values the schedule has run at (sorted)."""
        return sorted({h["tau"] for h in self.history} | {self.tau})


@dataclass
class AdaptiveTau(TauController):
    """Back-compat name for :class:`TauController` (the original controller
    from ``repro.core.adaptive``, which shipped with a shared-mutable
    ``history: list = None`` default — now a proper ``default_factory``).
    Same defaults, no warmup/cooldown; history records are a superset of
    the legacy ``{tau, drift_ratio, next_tau}`` schema."""


def consensus_drift(x_stacked) -> tuple:
    """(mean_i ‖x_i − x̄‖, ‖x̄‖) over the stacked worker params.

    The bit-exact per-leaf oracle the fused probe's differential tests pin
    against; works on pytrees and on ``Packed`` planes alike (the plane's
    buffers are its leaves, and padding lanes hold zeros)."""
    leaves = jax.tree.leaves(x_stacked)
    sq_drift = 0.0
    sq_scale = 0.0
    for t in leaves:
        tf = t.astype(jnp.float32)
        mean = jnp.mean(tf, axis=0, keepdims=True)
        sq_drift += jnp.sum(jnp.square(tf - mean)) / t.shape[0]
        sq_scale += jnp.sum(jnp.square(mean))
    return jnp.sqrt(sq_drift), jnp.sqrt(sq_scale)
