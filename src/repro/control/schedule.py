"""Simulated τ schedules for the dry-run cost model (DESIGN.md §6).

A fixed-τ dry-run records one round program's cost; an adaptive-τ run is a
*sequence* of round programs selected by the controller. This module makes
that sequence cost-modelable without running training:

* ``per_tau_costs`` — the composed train cost (``launch/costprobe.py``) is
  linear in τ by construction (every part's multiplier is τ-proportional
  except the once-per-round boundary), so per-τ program costs extrapolate
  exactly from one composed probe.
* ``simulate_trajectory`` — drives a :class:`TauController` against a
  documented reference drift model,

      drift/scale ≈ r0 · √τ / √(1 + t),    t = local steps taken so far

  (drift grows like √τ with the round length — the local-SGD deviation
  bound — and decays as optimization converges). This is a *planning*
  signal, not a prediction of any particular run; it exercises the exact
  controller code the live path uses.
* ``schedule_block`` — the dry-run JSON block: controller config, the
  simulated trajectory, per-τ costs/round-times, and the scheduled total
  wall-clock next to the fixed-τ baseline over the same local-step budget
  (both from :mod:`repro.core.runtime_model`).
"""
from __future__ import annotations

import math
from typing import Iterable, List, Optional

from repro.control.controller import TauController
from repro.core.runtime_model import BLOCKING, GOSSIP, OVERLAPPED, RuntimeConfig, simulate

# strategies the runtime model has no entry for, mapped onto the entry with
# the same blocking structure (delayed_avg consumes mid-round like CoCoD;
# the sparse anchor keeps Overlap-Local-SGD's launch/consume window)
_RUNTIME_ALGO = {"delayed_avg": "cocod", "sparse_anchor": "overlap_local_sgd"}


def runtime_algo(strategy: str) -> str:
    """Map a strategy name onto the runtime model's algorithm set."""
    if strategy in BLOCKING or strategy in OVERLAPPED or strategy in GOSSIP:
        return strategy
    return _RUNTIME_ALGO.get(strategy, "local_sgd")


def per_tau_costs(composed: dict, taus: Iterable[int]) -> List[dict]:
    """Extrapolate a composed train cost (``costprobe.composed_cost``) to a
    set of τ values. Every part's multiplier except the boundary's scales
    linearly with τ (blocks and embed_head run τ·n_micro times, the
    optimizer τ times, the boundary once), so this is exact per-part
    arithmetic, not a fit."""
    tau0 = int(composed["tau"])
    rows = []
    for tau in taus:
        row = dict(tau=int(tau), flops=0.0, bytes=0.0, coll=0.0)
        for label, p in composed["parts"].items():
            mult = p["mult"] if label == "boundary" else p["mult"] * tau / tau0
            for k in ("flops", "bytes", "coll"):
                row[k] += mult * p[k]
        rows.append(row)
    return rows


def simulate_trajectory(ctrl: TauController, rounds: int, r0: Optional[float] = None, fault_plan=None) -> List[dict]:
    """Drive ``ctrl`` for ``rounds`` rounds of the reference drift model and
    return its telemetry history. Mutates ``ctrl`` (pass a fresh instance).

    ``r0`` anchors the model: it is the drift ratio of the very first round
    at τ=1. The default sits on the controller's upper threshold, so the
    schedule starts communication-bound and relaxes as the √(1+t) decay
    sets in — the trajectory sweeps shrink/hold/grow territory.

    ``fault_plan`` (:class:`repro.fault.plan.FaultPlan`) marks each round's
    fault reason into the controller exactly as the live harness does: a
    degraded round is a ``fault_hold`` and its record carries the reason —
    the trajectory proves adaptive-τ and fault handling compose."""
    if r0 is None:
        r0 = ctrl.hi
    t = 0  # local steps taken
    for r in range(rounds):
        tau = ctrl.tau
        ratio = r0 * math.sqrt(tau) / math.sqrt(1.0 + t)
        fault = fault_plan.fault_reason(r) if fault_plan is not None else None
        ctrl.update(drift=ratio, scale=1.0, fault=fault)
        t += tau
    return ctrl.history


def _round_time(algo: str, tau: int, rt: RuntimeConfig, amortize: int = 8) -> float:
    """Mean per-round wall-clock at a given τ, amortized over a few rounds
    so overlapped algorithms pay (or hide) their in-flight collective."""
    res = simulate(algo, tau, tau * amortize, rt)
    return res.total_time / amortize


def schedule_block(
    strategy: str,
    ctrl: TauController,
    *,
    rounds: int = 50,
    rt: Optional[RuntimeConfig] = None,
    composed: Optional[dict] = None,
    r0: Optional[float] = None,
    fault_plan=None,
) -> dict:
    """Build the dry-run's ``tau_schedule`` JSON block.

    Simulates the controller trajectory, prices every τ the schedule
    touches (runtime-model round time; composed flops/bytes/coll when a
    composed cost is supplied), and totals the scheduled run against the
    fixed-τ baseline spending the same local-step budget at the starting τ.

    ``fault_plan`` threads the fault schedule through both halves: the
    trajectory records ``fault_hold`` decisions on degraded rounds, and the
    runtime config (unless explicitly given) takes the plan's straggler/
    jitter distributions via :meth:`FaultPlan.runtime_config`.
    """
    if rt is None:
        rt = fault_plan.runtime_config() if fault_plan is not None else RuntimeConfig()
    algo = runtime_algo(strategy)
    tau0 = ctrl.tau
    history = simulate_trajectory(ctrl, rounds, r0=r0, fault_plan=fault_plan)
    taus = ctrl.taus_seen
    times = {tau: _round_time(algo, tau, rt) for tau in taus}
    per_tau = [dict(tau=tau, round_time_s=times[tau]) for tau in taus]
    if composed is not None:
        for row, costs in zip(per_tau, per_tau_costs(composed, taus)):
            row.update({k: costs[k] for k in ("flops", "bytes", "coll")})
    total_steps = sum(h["tau"] for h in history)
    total_time = sum(times[h["tau"]] for h in history)
    fixed_rounds = max(total_steps // tau0, 1)
    fixed_time = _round_time(algo, tau0, rt) * fixed_rounds
    return dict(
        controller=dict(
            tau0=tau0,
            tau_min=ctrl.tau_min,
            tau_max=ctrl.tau_max,
            lo=ctrl.lo,
            hi=ctrl.hi,
            warmup_rounds=ctrl.warmup_rounds,
            cooldown_rounds=ctrl.cooldown_rounds,
        ),
        rounds=rounds,
        total_local_steps=total_steps,
        trajectory=[
            dict(
                round=h["round"],
                tau=h["tau"],
                drift_ratio=h["drift_ratio"],
                decision=h["decision"],
                next_tau=h["next_tau"],
                **({"fault": h["fault"]} if "fault" in h else {}),
            )
            for h in history
        ],
        per_tau=per_tau,
        compiled_programs=len(taus),
        total_time_s=total_time,
        fixed_tau_time_s=fixed_time,
    )
