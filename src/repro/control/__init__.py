"""Adaptive-τ control plane (DESIGN.md §6).

The paper fixes the communication period τ per run; this package makes it
a *live* control variable on the production path:

* :mod:`repro.control.controller` — the host-side :class:`TauController`
  (AdaComm-style multiplicative rule with a hysteresis band, warmup,
  cooldown and clamps) plus the bit-exact per-leaf ``consensus_drift``
  oracle its fused measurement kernel is pinned against.
* :mod:`repro.control.program_cache` — τ is a static shape parameter of
  the compiled round program; :class:`RoundProgramCache` keeps the
  O(log τ_max) jitted programs the doubling/halving rule can reach.
* :mod:`repro.control.schedule` — τ-*schedule* cost modelling for the
  dry-run: per-τ program costs extrapolated from one composed probe and a
  controller trajectory simulated against the runtime model.

The measurement side lives in :mod:`repro.kernels.consensus_probe` (fused
into the boundary kernels); the drive side is
``repro.api.Experiment.fit(adaptive_tau=...)``.
"""
from repro.control.controller import AdaptiveTau, TauController, consensus_drift
from repro.control.program_cache import RoundProgramCache, TauScheduledTrainer
from repro.control.schedule import per_tau_costs, runtime_algo, schedule_block, simulate_trajectory

__all__ = [
    "AdaptiveTau",
    "TauController",
    "consensus_drift",
    "RoundProgramCache",
    "TauScheduledTrainer",
    "per_tau_costs",
    "runtime_algo",
    "schedule_block",
    "simulate_trajectory",
]
