"""The ``Experiment`` facade: one config-driven entrypoint for training,
evaluation and serving.

Collapses the argparse drivers that used to re-implement the same wiring
(model init → strategy → optimizer → train state → jitted round step → batch
loop) into a single declarative object:

    from repro.api import Experiment

    exp = Experiment(arch="qwen2-7b", strategy="overlap_local_sgd",
                     workers=4, rounds=20)
    result = exp.fit()
    print(exp.evaluate())          # held-out loss of the consensus model
    engine = exp.serve(slots=4)    # batched generation on the fitted params

Two task families are supported:

* **LM** — ``arch`` names a registered architecture (reduced variant by
  default) or is a full ``ModelConfig``; data is the synthetic token stream.
* **classification** — ``task=ClassificationSpec(...)`` runs the paper's
  CIFAR-10 stand-in (MLP on synthetic classification), the substrate of the
  Table/Figure benchmarks.

``strategy`` accepts a name, an ``AlgoConfig``, a two-phase ``CommStrategy``
instance, or a legacy ``Algorithm`` (wrapped transparently) — including the
DaSGD-style ``delayed_avg`` and LOSCAR-style ``sparse_anchor`` strategies.

With the default packed strategies (and a packed-capable optimizer) the
fitted ``state.x`` is *plane-resident* — the worker-stacked flat
``Packed`` parameter plane rather than a pytree; ``consensus()`` /
``evaluate()`` / ``serve()`` read it through the pytree view transparently
(``repro.training.params_view``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import AlgoConfig, ModelConfig, OptimizerConfig, ParallelPlan, get_arch
from repro.control import RoundProgramCache, TauController
from repro.core.strategy import CommStrategy, resolve_strategy
from repro.data.loaders import (
    ClassificationSplits,
    classification_batch_fn,
    lm_batch_fn,
    make_classification_splits,
    round_batch,
)
from repro.models import transformer as T
from repro.models.classifier import accuracy, init_mlp, mlp_loss
from repro.optim import from_config as opt_from_config
from repro.optim import schedules
from repro.optim.optimizers import Optimizer
from repro.training import consensus_params, make_round_step, make_train_state


@dataclass
class ClassificationSpec:
    """The synthetic classification task (paper §4's CIFAR-10 stand-in)."""

    n: int = 30000
    dim: int = 64
    num_classes: int = 10
    noise: float = 3.0
    holdout: int = 4000
    noniid: bool = False
    skew: float = 0.64
    batch_per_worker: int = 32
    hidden: Tuple[int, ...] = (128, 64)
    seed: int = 0
    # pre-built splits (shared across experiments, e.g. a benchmark grid);
    # overrides the generation parameters above
    splits: Optional[ClassificationSplits] = None


@dataclass
class TokenStream:
    """Synthetic LM token-stream spec (bigram-structured, per-worker seeds)."""

    batch_per_worker: int = 2
    seq_len: int = 64
    seed: int = 0


@dataclass
class FitResult:
    losses: List[float]  # per-round mean loss
    state: Any  # final TrainState
    rounds: int
    steps: int  # local steps taken (rounds × τ)
    wall_s: float
    # adaptive-τ runs only: one controller telemetry record per round
    # (round/tau/drift/scale/drift_ratio/decision/next_tau — DESIGN.md §6)
    tau_schedule: Optional[List[dict]] = None
    # faulted runs only: the harness's membership records — one per round
    # where the fleet departed from fully-live (round/live/excluded/resynced/
    # reason — DESIGN.md §7)
    fault_log: Optional[List[dict]] = None

    @property
    def final_loss(self) -> float:
        return self.losses[-1] if self.losses else float("nan")


@dataclass
class Experiment:
    """Declarative training/serving experiment. See module docstring."""

    arch: Union[str, ModelConfig, None] = None
    task: Optional[ClassificationSpec] = None
    strategy: Union[str, AlgoConfig, CommStrategy, Any] = "overlap_local_sgd"
    optimizer: Union[str, OptimizerConfig, Optimizer] = field(default_factory=OptimizerConfig)
    data: Optional[TokenStream] = None
    parallel: Optional[ParallelPlan] = None  # reserved for mesh runs (see launch/dryrun.py)
    workers: int = 4
    rounds: int = 20
    schedule: Optional[Callable] = None  # lr schedule; default derives from optimizer config
    grad_clip: float = 0.0
    microbatch: Optional[int] = None
    full: bool = False  # use the full (not reduced) registered model config
    seed: int = 0

    def __post_init__(self):
        if self.arch is None and self.task is None:
            self.task = ClassificationSpec()
        if self.arch is not None and self.task is not None:
            raise ValueError("specify either arch= (LM) or task= (classification), not both")
        self._built = False
        self.state = None

    # -- construction -------------------------------------------------------

    def _resolve_strategy(self) -> CommStrategy:
        return resolve_strategy(self.strategy)

    def _resolve_optimizer(self) -> Tuple[Optimizer, Callable]:
        o = self.optimizer
        if isinstance(o, str):
            o = OptimizerConfig(name=o)
        if isinstance(o, OptimizerConfig):
            sched = self.schedule or schedules.from_config(o)
            return opt_from_config(o), sched
        if self.schedule is None:
            raise ValueError(
                "a raw Optimizer instance carries no learning rate; pass schedule= "
                "(e.g. schedules.constant(lr)) or use an OptimizerConfig"
            )
        return o, self.schedule

    def build(self) -> "Experiment":
        """Resolve configs into params/state/step-fn/batch-fn (idempotent)."""
        if self._built:
            return self
        self.strategy_obj = self._resolve_strategy()
        self.opt_obj, self.schedule_fn = self._resolve_optimizer()
        key = jax.random.PRNGKey(self.seed)

        if self.task is not None:
            spec = self.task
            self.splits = spec.splits or make_classification_splits(
                self.workers,
                n=spec.n,
                dim=spec.dim,
                num_classes=spec.num_classes,
                noise=spec.noise,
                holdout=spec.holdout,
                noniid=spec.noniid,
                skew=spec.skew,
                seed=spec.seed,
            )
            if self.splits.num_workers != self.workers:
                raise ValueError(
                    f"task splits have {self.splits.num_workers} partitions but workers={self.workers}"
                )
            self.model_cfg = None
            self.params, self.axes = init_mlp(key, spec.dim, spec.num_classes, hidden=spec.hidden)
            self.loss_fn = mlp_loss
            self.next_batch = classification_batch_fn(self.splits, spec.batch_per_worker, seed=spec.seed)
        else:
            if isinstance(self.arch, ModelConfig):
                cfg = self.arch
            else:
                model = get_arch(self.arch).model
                cfg = model if self.full else model.reduced()
            self.model_cfg = cfg
            stream = self.data or TokenStream()
            self.params, self.axes = T.init_model(cfg, key)
            self.loss_fn = lambda p, b: T.lm_loss(cfg, p, b)
            self.next_batch = lm_batch_fn(
                cfg, self.workers, stream.batch_per_worker, stream.seq_len, seed=stream.seed
            )

        self.state = make_train_state(self.params, self.workers, self.opt_obj, self.strategy_obj, self.axes)
        self.step_fn = jax.jit(
            make_round_step(
                self.loss_fn,
                self.opt_obj,
                self.strategy_obj,
                self.schedule_fn,
                self.axes,
                grad_clip=self.grad_clip,
                microbatch=self.microbatch,
            )
        )
        self._built = True
        return self

    # -- introspection ------------------------------------------------------

    @property
    def tau(self) -> int:
        self.build()
        return self.strategy_obj.tau

    @property
    def num_params(self) -> int:
        self.build()
        return sum(int(x.size) for x in jax.tree.leaves(self.params))

    # -- training -----------------------------------------------------------

    def fit(
        self,
        rounds: Optional[int] = None,
        steps: Optional[int] = None,
        log: Optional[Callable[[int, float], None]] = None,
        adaptive_tau: Optional[TauController] = None,
        faults: Optional[Any] = None,
    ) -> FitResult:
        """Run the round loop. ``steps`` (local steps) is an alternative to
        ``rounds``: rounds = steps // τ. ``log(round_idx, mean_loss)`` is
        called once per round when given. Fitting continues from the current
        state, so repeated calls accumulate training.

        ``adaptive_tau`` hands the round loop to a
        :class:`repro.control.TauController` (DESIGN.md §6): each round runs
        at the controller's current τ through a per-τ jitted program cache,
        with the fused consensus probe feeding the controller between
        rounds. The returned :class:`FitResult` carries the realized τ
        schedule; ``steps`` then counts the actual local steps taken.

        ``faults`` (a :class:`repro.fault.FaultPlan`) runs the loop under
        the deterministic fault harness (DESIGN.md §7): each round's
        membership mask is resolved before the round, rejoining workers are
        re-synced from the anchor, and degraded rounds run the
        membership-masked boundary. Composes with ``adaptive_tau`` — fault
        rounds become ``fault_hold`` controller decisions."""
        self.build()
        if faults is not None:
            return self._fit_faulted(faults, rounds or self.rounds, log, ctrl=adaptive_tau)
        if adaptive_tau is not None:
            return self._fit_adaptive(adaptive_tau, rounds or self.rounds, log)
        tau = self.strategy_obj.tau
        if rounds is None:
            rounds = (steps // tau) if steps is not None else self.rounds
        losses: List[float] = []
        t0 = time.time()
        state = self.state
        for r in range(rounds):
            rb = round_batch(self.next_batch, tau)
            state, ms = self.step_fn(state, rb)
            loss = float(np.asarray(ms["loss"]).mean())
            losses.append(loss)
            if log is not None:
                log(r, loss)
        self.state = state
        return FitResult(
            losses=losses, state=state, rounds=rounds, steps=rounds * tau, wall_s=time.time() - t0
        )

    def _ensure_tau_programs(self) -> None:
        if not hasattr(self, "tau_programs"):
            probed = make_round_step(
                self.loss_fn,
                self.opt_obj,
                self.strategy_obj,
                self.schedule_fn,
                self.axes,
                grad_clip=self.grad_clip,
                microbatch=self.microbatch,
                probe=True,
            )
            # one jit wrapper per τ: each distinct τ is a distinct XLA
            # program (different scan trip count / batch shape)
            self.tau_programs = RoundProgramCache(lambda tau: jax.jit(probed))

    def _fit_adaptive(self, ctrl: TauController, rounds: int, log) -> FitResult:
        """The adaptive-τ round loop: τ is a static shape parameter (the
        round batch's leading axis), so the controller swaps between the
        O(log τ_max) compiled programs held by ``self.tau_programs``; the
        probe-enabled round step surfaces ``consensus_drift``/``_scale``
        metrics that drive the controller's next decision."""
        self._ensure_tau_programs()
        losses: List[float] = []
        first = len(ctrl.history)
        total_steps = 0
        t0 = time.time()
        state = self.state
        for r in range(rounds):
            tau = ctrl.tau
            step = self.tau_programs.program_for(tau)
            rb = round_batch(self.next_batch, tau)
            state, ms = step(state, rb)
            losses.append(float(np.asarray(ms["loss"]).mean()))
            ctrl.update(float(ms["consensus_drift"]), float(ms["consensus_scale"]))
            total_steps += tau
            if log is not None:
                log(r, losses[-1])
        self.state = state
        return FitResult(
            losses=losses,
            state=state,
            rounds=rounds,
            steps=total_steps,
            wall_s=time.time() - t0,
            tau_schedule=list(ctrl.history[first:]),
        )

    def _fit_faulted(self, plan, rounds: int, log, ctrl: Optional[TauController] = None) -> FitResult:
        """The fault-harness round loop (DESIGN.md §7). Each round:
        ``harness.before_round`` resolves the plan's membership (re-syncing
        rejoining workers' plane slices from the anchor) and stashes it in
        the state; the round program masks its boundary accordingly. A
        membership toggling between ``None`` (fully live) and a mask only
        retraces the jitted step once per structure — two programs total per
        τ. With ``ctrl``, fault rounds are fed into the controller as
        ``fault_hold`` decisions so a crash cannot masquerade as drift."""
        from repro.fault import FaultHarness, FaultPlan

        if not isinstance(plan, FaultPlan):
            raise TypeError(f"faults= expects a repro.fault.FaultPlan, got {type(plan).__name__}")
        if plan.m != self.workers:
            raise ValueError(f"fault plan is over m={plan.m} workers, experiment has workers={self.workers}")
        harness = FaultHarness(plan)
        if ctrl is not None:
            self._ensure_tau_programs()
        losses: List[float] = []
        first = len(ctrl.history) if ctrl is not None else 0
        total_steps = 0
        t0 = time.time()
        state = self.state
        for r in range(rounds):
            state = harness.before_round(state, r)
            tau = ctrl.tau if ctrl is not None else self.strategy_obj.tau
            step = self.tau_programs.program_for(tau) if ctrl is not None else self.step_fn
            rb = round_batch(self.next_batch, tau)
            state, ms = step(state, rb)
            losses.append(float(np.asarray(ms["loss"]).mean()))
            if ctrl is not None:
                ctrl.update(
                    float(ms["consensus_drift"]),
                    float(ms["consensus_scale"]),
                    fault=harness.fault_reason(r),
                )
            total_steps += tau
            if log is not None:
                log(r, losses[-1])
        # leave the experiment fully live: a later fit() without faults=
        # must run the unmasked (budget-pinned) program
        self.state = state._replace(membership=None)
        return FitResult(
            losses=losses,
            state=self.state,
            rounds=rounds,
            steps=total_steps,
            wall_s=time.time() - t0,
            tau_schedule=list(ctrl.history[first:]) if ctrl is not None else None,
            fault_log=list(harness.records),
        )

    # -- evaluation ---------------------------------------------------------

    def consensus(self):
        """Float32 consensus (averaged) model — the paper's evaluation point."""
        if self.state is None:
            self.build()
        return jax.tree.map(lambda t: t.astype(jnp.float32), consensus_params(self.state))

    def consensus_plane(self):
        """The consensus model as a packed plane (lead ()): the worker mean
        of each plane bucket, cast back to the bucket dtype. Plane-resident
        experiments only — this is what the plane-resident serving engine
        consumes, and what ``swap_plane`` retargets a live engine at."""
        from repro.parallel.packing import Packed

        if self.state is None:
            self.build()
        x = self.state.x
        if not isinstance(x, Packed):
            raise ValueError("consensus_plane() requires a plane-resident (packed) experiment; use consensus()")
        bufs = tuple(jnp.mean(b.astype(jnp.float32), axis=0).astype(b.dtype) for b in x.buffers)
        return Packed(bufs, x.layout)

    def anchor_plane(self):
        """The live anchor plane z (the strategy's slow consensus weights),
        shared by reference — zero-copy, so serving it reflects exactly the
        buffers the trainer averages into. Anchor-based packed strategies
        only."""
        from repro.parallel.packing import Packed

        if self.state is None:
            self.build()
        z = getattr(self.state.vars, "z", None) if self.state.vars is not None else None
        if not isinstance(z, Packed):
            raise ValueError("anchor_plane() requires a packed anchor strategy (state.vars.z is the plane)")
        return z

    def evaluate(self, eval_batches: int = 8) -> dict:
        """Evaluate the consensus model: classification → held-out accuracy;
        LM → mean loss on fresh held-out token batches."""
        self.build()
        p = self.consensus()
        if self.task is not None:
            acc = accuracy(p, jnp.asarray(self.splits.test.x), jnp.asarray(self.splits.test.y))
            return {"test_acc": float(acc)}
        cfg = self.model_cfg
        p = jax.tree.map(lambda t: t.astype(cfg.param_dtype), p)
        if not hasattr(self, "_eval_fn"):  # cache: one compile per experiment
            stream = self.data or TokenStream()
            self._eval_stream = lm_batch_fn(
                cfg, 1, stream.batch_per_worker, stream.seq_len, seed=stream.seed + 7919
            )
            self._eval_fn = jax.jit(lambda prm, b: self.loss_fn(prm, b)[0])
        losses = []
        for _ in range(eval_batches):
            batch = jax.tree.map(lambda t: t[0], self._eval_stream())  # drop the worker axis
            losses.append(float(self._eval_fn(p, batch)))
        return {"eval_loss": float(np.mean(losses))}

    # -- serving ------------------------------------------------------------

    def serve(self, slots: int = 4, max_len: int = 256, **engine_kw):
        """Batched generation engine over the fitted consensus params (LM
        experiments only). Plane-resident experiments are served through the
        plane directly (no unpack copy) — DESIGN.md §10 — so a later
        ``engine.swap_plane(exp.anchor_plane())`` hot-swaps a freshly
        averaged anchor into the running engine between decode steps."""
        from repro.parallel.packing import Packed
        from repro.serving import BatchedEngine

        self.build()
        if self.model_cfg is None:
            raise ValueError("serve() requires an LM experiment (arch=...), not a classification task")
        cfg = self.model_cfg
        if isinstance(self.state.x, Packed):
            p = self.consensus_plane()
        else:
            p = jax.tree.map(lambda t: t.astype(cfg.param_dtype), self.consensus())
        return BatchedEngine(cfg, p, slots=slots, max_len=max_len, **engine_kw)
