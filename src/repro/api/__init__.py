"""Public facade: config-driven training/evaluation/serving entrypoints."""
from repro.api.experiment import ClassificationSpec, Experiment, FitResult, TokenStream, resolve_strategy
from repro.control import TauController

__all__ = [
    "ClassificationSpec",
    "Experiment",
    "FitResult",
    "TauController",
    "TokenStream",
    "resolve_strategy",
]
