"""Public facade: config-driven training/evaluation/serving entrypoints."""
from repro.api.experiment import ClassificationSpec, Experiment, FitResult, TokenStream, resolve_strategy

__all__ = ["ClassificationSpec", "Experiment", "FitResult", "TokenStream", "resolve_strategy"]
