"""Mixture-of-Experts FFN with top-k routing.

Covers both assigned MoE styles:
* Arctic  [hf:Snowflake/snowflake-arctic-base] — 128 experts top-2 with a
  dense SwiGLU FFN *in parallel* (residual MoE).
* DeepSeek-V3 [arXiv:2412.19437] — 1 shared + 256 routed experts top-8,
  sigmoid routing with normalized top-k gates, first-k layers dense.

Dispatch is scatter/gather based (dropless up to a capacity factor): tokens
are assigned slots in per-expert buffers sized ``capacity``; the buffers are
sharded over the ``experts`` logical axis (expert parallelism), so on a real
mesh the scatter/gather pair lowers to all-to-all style collectives between
the data and expert shards. The auxiliary load-balance loss follows the
switch-transformer form. Router statistics are returned so Overlap-Local-SGD
can (optionally) all-reduce them only at round boundaries — local routers
drift during a round exactly like the rest of the local model.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config.base import MoEConfig
from repro.models.layers.mlp import init_swiglu, swiglu
from repro.parallel import constrain


def init_moe(b, name: str, d_model: int, cfg: MoEConfig):
    e, f = cfg.num_experts, cfg.expert_ff
    with b.scope(name):
        b.param("router", (d_model, e), ("embed_no_shard", None), init="normal", scale=0.02, dtype=jnp.float32)
        b.param("wi_gate", (e, d_model, f), ("experts", "embed_no_shard", "expert_ff"))
        b.param("wi_up", (e, d_model, f), ("experts", "embed_no_shard", "expert_ff"))
        b.param("wo", (e, f, d_model), ("experts", "expert_ff", "embed_no_shard"))
        if cfg.num_shared_experts:
            init_swiglu(b, "shared", d_model, cfg.shared_expert_ff * cfg.num_shared_experts)
        if cfg.dense_residual_ff:
            init_swiglu(b, "dense_residual", d_model, cfg.dense_residual_ff)


def moe_apply(params, cfg: MoEConfig, x, act: str = "silu", capacity_factor: float = 0.0) -> Tuple[jnp.ndarray, dict]:
    """x: (B, S, d) -> (out, router_stats).

    capacity_factor overrides cfg.capacity_factor when > 0 (serving paths use
    a higher factor so prefill/decode are effectively dropless)."""
    b_, s, d = x.shape
    t = b_ * s
    cf = capacity_factor if capacity_factor > 0 else cfg.capacity_factor
    xt = constrain(x.reshape(t, d), ("act_tokens", None))
    logits = (xt.astype(jnp.float32)) @ params["router"]  # (T, E)
    e = cfg.num_experts
    k = cfg.top_k

    if cfg.num_shared_experts:  # deepseek-style sigmoid router, normalized gates
        scores = jax.nn.sigmoid(logits)
        gate_vals, idx = jax.lax.top_k(scores, k)
        gates = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)
        probs = scores / (scores.sum(-1, keepdims=True) + 1e-9)
    else:  # softmax router (arctic)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, idx = jax.lax.top_k(probs, k)
        gates = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    capacity = int(max(k, round(t * k / e * cf)))
    capacity = min(capacity, t)  # a token can use an expert at most once

    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)  # (T, k, E)
    assigned = onehot.sum(1)  # (T, E) 0/1
    # position of each token within its expert's buffer (first-come order)
    pos_in_expert = jnp.cumsum(assigned, axis=0) - assigned  # (T, E)
    pos_k = jnp.take_along_axis(pos_in_expert, idx, axis=1)  # (T, k)
    keep = pos_k < capacity
    gates = jnp.where(keep, gates, 0.0)

    flat_slot = jnp.where(keep, idx * capacity + pos_k, e * capacity)  # overflow -> dropped row
    # dispatch: scatter TOKEN IDS (tiny) into the slot table, then gather the
    # hidden vectors — keeps every large tensor sharded (token dim on fsdp,
    # expert dim on fsdp after the gather); the gather/scatter pair is the
    # all-to-all of expert parallelism.
    slot_token = jnp.full((e * capacity + 1,), t, jnp.int32)
    token_ids = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[:, None], (t, k))
    slot_token = slot_token.at[flat_slot.reshape(-1)].set(token_ids.reshape(-1), mode="drop")
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), x.dtype)], axis=0)
    buf = xt_pad[slot_token[: e * capacity]].reshape(e, capacity, d)
    buf = constrain(buf, ("act_experts", None, None))

    # expert computation (grouped einsum over the expert-parallel axis)
    g = jnp.einsum("ecd,edf->ecf", buf, params["wi_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["wi_up"])
    g = constrain(g, ("act_experts", None, "act_expert_ff"))
    a = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    y = jnp.einsum("ecf,efd->ecd", a * u, params["wo"])
    y = constrain(y, ("act_experts", None, None))

    # combine: gather each token's k slots, weight by gates
    y_flat = jnp.concatenate([y.reshape(e * capacity, d), jnp.zeros((1, d), y.dtype)], axis=0)
    gathered = y_flat[flat_slot]  # (T, k, d)
    gathered = constrain(gathered, ("act_tokens", None, None))
    out = jnp.einsum("tkd,tk->td", gathered.astype(jnp.float32), gates.astype(jnp.float32)).astype(x.dtype)
    out = constrain(out, ("act_tokens", None)).reshape(b_, s, d)

    if cfg.num_shared_experts:
        out = out + swiglu(params["shared"], x, act)
    if cfg.dense_residual_ff:
        out = out + swiglu(params["dense_residual"], x, act)

    # switch-style aux loss: E * sum_e f_e * p_e
    frac_tokens = assigned.astype(jnp.float32).mean(0) * (e / k)  # load fraction (normalized)
    mean_prob = probs.mean(0)
    aux = e * jnp.sum(frac_tokens / e * mean_prob) * k  # == E * mean(f_e p_e) form
    stats = dict(
        aux_loss=aux,
        load=frac_tokens,
        mean_prob=mean_prob,
        dropped=1.0 - jnp.mean(keep.astype(jnp.float32)),
    )
    return out, stats
