"""Normalization layers (RMSNorm and bias-free LayerNorm).

``rmsnorm`` routes through the fused Pallas kernel when
``repro.kernels.flags.use_pallas()`` is on (TPU runtime / interpret tests)
and the pure-jnp reference otherwise (CPU, dry-run lowering).

Like every layer module, apply functions take ``params`` as the dict/
``ParamView`` access protocol (see :mod:`repro.models.params`): a key
lookup may materialize a window of the packed parameter plane.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import flags as kflags
from repro.kernels.rmsnorm import ops as rms_ops
from repro.kernels.rmsnorm import ref as rms_ref


def init_rmsnorm(b, name: str, dim: int):
    with b.scope(name):
        b.param("scale", (dim,), (None,), init="ones")


def rmsnorm(params, x, eps: float = 1e-5):
    if kflags.use_pallas():
        return rms_ops.rmsnorm(x, params["scale"], eps=eps)
    return rms_ref.rmsnorm(x, params["scale"], eps=eps)


def init_layernorm(b, name: str, dim: int):
    with b.scope(name):
        b.param("scale", (dim,), (None,), init="ones")


def layernorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) / jnp.sqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)
