"""RWKV-6 "Finch" block [arXiv:2404.05892]: time-mix with data-dependent
decay (WKV recurrence) + channel-mix.

Faithful structure: token-shift ddlerp (low-rank data-dependent
interpolation between x_t and x_{t-1}) feeding r/k/v/w/g projections; decay
w_t = exp(-exp(w0 + lora_w(x_w))); per-head WKV state with bonus u; grouped
RMS-norm on heads; squared-ReLU channel-mix. Decode carries
(last_token_timemix, last_token_channelmix, wkv_state).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import SSMConfig
from repro.kernels import flags as kflags
from repro.kernels.rwkv6_wkv import ops as wkv_ops
from repro.kernels.rwkv6_wkv import ref as wkv_ref
from repro.models.layers.norms import init_rmsnorm, rmsnorm
from repro.parallel import constrain

_MIX = ("r", "k", "v", "w", "g")
_LORA_RANK = 32
_DECAY_RANK = 64


def init_rwkv6(b, name: str, d_model: int, cfg: SSMConfig):
    h, n = cfg.num_heads, cfg.head_dim
    d_attn = h * n
    with b.scope(name):
        # time-mix
        b.param("mu_x", (d_model,), (None,), init="constant", scale=0.5)
        b.param("mix_w1", (d_model, len(_MIX) * _LORA_RANK), ("embed", "lora"))
        b.param("mix_w2", (len(_MIX), _LORA_RANK, d_model), (None, "lora", "embed_no_shard"))
        b.param("mu", (len(_MIX), d_model), (None, None), init="constant", scale=0.5)
        b.param("wr", (d_model, h * n), ("embed", "ff"))
        b.param("wk", (d_model, h * n), ("embed", "ff"))
        b.param("wv", (d_model, h * n), ("embed", "ff"))
        b.param("wg", (d_model, d_attn), ("embed", "ff"))
        b.param("w0", (h, n), (None, None), init="constant", scale=-2.0)
        b.param("decay_w1", (d_model, _DECAY_RANK), ("embed", "lora"))
        b.param("decay_w2", (_DECAY_RANK, h * n), ("lora", "ff"))
        b.param("u_bonus", (h, n), (None, None), init="normal", scale=0.3)
        init_rmsnorm(b, "gnorm", n)
        b.param("wo", (d_attn, d_model), ("ff", "embed"))
        # channel-mix
        b.param("cmix_mu_k", (d_model,), (None,), init="constant", scale=0.5)
        b.param("cmix_mu_r", (d_model,), (None,), init="constant", scale=0.5)


def init_rwkv6_ffn(b, name: str, d_model: int, d_ff: int):
    with b.scope(name):
        b.param("wk", (d_model, d_ff), ("embed", "ff"))
        b.param("wv", (d_ff, d_model), ("ff", "embed"))
        b.param("wr", (d_model, d_model), ("embed", "embed_no_shard"))


def _shift(x, last):
    """x_{t-1} stream: shift right by one; position 0 takes ``last``."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def rwkv6_timemix_apply(
    params,
    cfg: SSMConfig,
    x,
    *,
    mode: str = "train",
    cache: Optional[dict] = None,
    eps: float = 1e-5,
) -> Tuple[jnp.ndarray, Optional[dict]]:
    b_, s, d = x.shape
    h, n = cfg.num_heads, cfg.head_dim
    last = cache["tm_last"][:, None, :] if cache is not None else None
    prev = _shift(x, last)
    dx = prev - x

    # ddlerp: x_s = x + dx * (mu_s + lora_s(x + dx * mu_x))
    base = x + dx * params["mu_x"]
    lora = jnp.tanh(base @ params["mix_w1"]).reshape(b_, s, len(_MIX), _LORA_RANK)
    lora = jnp.einsum("bsmr,mrd->bsmd", lora, params["mix_w2"])
    mixed = x[:, :, None, :] + dx[:, :, None, :] * (params["mu"] + lora)  # (B,S,5,d)
    xr, xk, xv, xw, xg = [mixed[:, :, i] for i in range(len(_MIX))]

    r = constrain(xr @ params["wr"], ("batch", "seq", "act_ff")).reshape(b_, s, h, n)
    k = constrain(xk @ params["wk"], ("batch", "seq", "act_ff")).reshape(b_, s, h, n)
    v = constrain(xv @ params["wv"], ("batch", "seq", "act_ff")).reshape(b_, s, h, n)
    g = jax.nn.silu(constrain(xg @ params["wg"], ("batch", "seq", "act_ff")))

    dlora = (jnp.tanh(xw @ params["decay_w1"]) @ params["decay_w2"]).reshape(b_, s, h, n)
    w = jnp.exp(-jnp.exp((params["w0"] + dlora).astype(jnp.float32)))  # (B,S,H,N) in (0,1)

    new_cache = None
    if mode in ("train", "prefill"):
        if kflags.use_pallas():
            y, st = wkv_ops.wkv(r, k, v, w.astype(r.dtype), params["u_bonus"], cfg.chunk_size)
        else:
            y, st = wkv_ref.wkv_chunked(r, k, v, w, params["u_bonus"], chunk=cfg.chunk_size)
        if mode == "prefill":
            new_cache = dict(wkv_state=st, tm_last=x[:, -1], kind="rwkv")
    else:
        assert cache is not None and s == 1
        y, st = wkv_ops.wkv_decode_step(
            cache["wkv_state"], r[:, 0], k[:, 0], v[:, 0], w[:, 0], params["u_bonus"]
        )
        y = y[:, None]
        new_cache = dict(wkv_state=st, tm_last=x[:, 0], kind="rwkv")

    y = rmsnorm(params["gnorm"], y, eps).reshape(b_, s, h * n) * g
    return y @ params["wo"], new_cache


def rwkv6_channelmix_apply(
    params_tm,
    params_ffn,
    x,
    *,
    cache: Optional[dict] = None,
) -> Tuple[jnp.ndarray, Optional[dict]]:
    last = cache["cm_last"][:, None, :] if cache is not None else None
    prev = _shift(x, last)
    dx = prev - x
    xk = x + dx * params_tm["cmix_mu_k"]
    xr = x + dx * params_tm["cmix_mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ params_ffn["wk"]))
    kk = constrain(kk, ("batch", "seq", "act_ff"))
    out = jax.nn.sigmoid(xr @ params_ffn["wr"]) * (kk @ params_ffn["wv"])
    new_cache = dict(cm_last=x[:, -1]) if cache is not None else None
    return out, new_cache


def make_rwkv_cache(batch: int, d_model: int, cfg: SSMConfig, dtype) -> dict:
    h, n = cfg.num_heads, cfg.head_dim
    return dict(
        wkv_state=jnp.zeros((batch, h, n, n), jnp.float32),
        tm_last=jnp.zeros((batch, d_model), dtype),
        cm_last=jnp.zeros((batch, d_model), dtype),
        kind="rwkv",
    )
