"""Attention layers: GQA (with bias / QK-norm / sliding-window options) and
DeepSeek-V3 MLA (multi-head latent attention), each with train / prefill /
decode paths.

Compute dispatch:
* TPU runtime (or forced) → Pallas flash-attention kernel (VMEM-tiled
  online softmax, causal + sliding window + GQA).
* otherwise → pure-jnp paths: exact masked softmax for short sequences,
  KV-chunked online softmax (`chunked` in kernels/flash_attention/ref.py)
  for long ones, so the dry-run HLO has flash-like memory behaviour instead
  of an S×S materialization.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import AttentionConfig
from repro.kernels import flags as kflags
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.paged_attn import ops as pa_ops
from repro.models.layers import rope as rope_mod
from repro.models.layers.norms import init_rmsnorm, rmsnorm
from repro.parallel import constrain

# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def init_gqa(b, name: str, d_model: int, cfg: AttentionConfig):
    """Projections are stored FUSED over (heads × head_dim) so the tensor-
    parallel axis always divides the sharded dim (28 heads × tp=16 would not
    divide; 28·128 = 3584 does). Activations are reshaped to heads after the
    matmul and GSPMD propagates the sharding through the reshape."""
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    with b.scope(name):
        b.param("wq", (d_model, h * hd), ("embed", "ff"))
        b.param("wk", (d_model, kv * hd), ("embed", "ff"))
        b.param("wv", (d_model, kv * hd), ("embed", "ff"))
        b.param("wo", (h * hd, d_model), ("ff", "embed"))
        if cfg.qkv_bias:
            b.param("bq", (h * hd,), ("ff",), init="zeros")
            b.param("bk", (kv * hd,), ("ff",), init="zeros")
            b.param("bv", (kv * hd,), ("ff",), init="zeros")
        if cfg.out_bias:
            b.param("bo", (d_model,), (None,), init="zeros")


def init_qk_norm(b, name: str, cfg: AttentionConfig):
    with b.scope(name):
        init_rmsnorm(b, "q_norm", cfg.head_dim)
        init_rmsnorm(b, "k_norm", cfg.head_dim)


def _project_qkv(params, cfg: AttentionConfig, x):
    b_, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = constrain(q, ("batch", "seq", "act_ff"))
    k = constrain(k, ("batch", "seq", "act_ff"))
    v = constrain(v, ("batch", "seq", "act_ff"))
    return (
        q.reshape(b_, s, h, hd),
        k.reshape(b_, s, kv, hd),
        v.reshape(b_, s, kv, hd),
    )


def _sdpa(q, k, v, *, causal: bool, window: Optional[int], q_offset) -> jnp.ndarray:
    """Dispatch: Pallas flash kernel / jnp reference. q:(B,Sq,H,D) k,v:(B,Sk,Hkv,D)."""
    if kflags.use_pallas():
        return fa_ops.flash_attention(q, k, v, causal=causal, window=window, q_offset=q_offset)
    return fa_ref.mha_reference(q, k, v, causal=causal, window=window, q_offset=q_offset)


def gqa_apply(
    params,
    cfg: AttentionConfig,
    x,  # (B, S, d_model)
    cos,
    sin,
    *,
    mode: str = "train",  # train | prefill | decode
    cache: Optional[dict] = None,
    eps: float = 1e-5,
    qk_norm_params=None,
    paged=None,  # serving.paged_cache.PagedState — paged-pool decode
) -> Tuple[jnp.ndarray, Optional[dict]]:
    q, k, v = _project_qkv(params, cfg, x)
    if qk_norm_params is not None:
        q = rmsnorm(qk_norm_params["q_norm"], q, eps)
        k = rmsnorm(qk_norm_params["k_norm"], k, eps)
    if cfg.rope != "none" and cos is not None:
        q = rope_mod.apply_rope(q, cos, sin)
        k = rope_mod.apply_rope(k, cos, sin)
    q = q / jnp.sqrt(jnp.asarray(cfg.head_dim, q.dtype))
    window = cfg.sliding_window

    new_cache = None
    if mode == "train":
        out = _sdpa(q, k, v, causal=True, window=window, q_offset=0)
    elif mode == "prefill":
        out = _sdpa(q, k, v, causal=True, window=window, q_offset=0)
        new_cache = _init_cache_from_prefill(k, v, window)
    elif mode == "decode":
        assert cache is not None
        if paged is not None and "pool_k" in cache:
            pool_k = pa_ops.paged_append(cache["pool_k"], k, paged.page_tables, paged.lengths)
            pool_v = pa_ops.paged_append(cache["pool_v"], v, paged.page_tables, paged.lengths)
            out = pa_ops.paged_attend_gqa(
                q, pool_k, pool_v, paged.page_tables, paged.lengths, window=window
            )
            new_cache = dict(pool_k=pool_k, pool_v=pool_v)
        else:
            k_all, v_all, positions, pos = _cache_append(cache, k, v, window)
            out = _decode_attend(q, k_all, v_all, positions=positions, pos=pos, window=window)
            new_cache = dict(cache)
            new_cache.update(k=k_all, v=v_all, positions=positions, pos=pos + 1)
    else:
        raise ValueError(mode)

    b_, s = out.shape[0], out.shape[1]
    y = out.astype(x.dtype).reshape(b_, s, cfg.num_heads * cfg.head_dim) @ params["wo"]
    if cfg.out_bias:
        y = y + params["bo"]
    return y, new_cache


# -- KV cache helpers (full + ring-buffer sliding window) -------------------


def _init_cache_from_prefill(k, v, window: Optional[int]) -> dict:
    s = k.shape[1]
    if window is not None and s > window:
        k = k[:, -window:]
        v = v[:, -window:]
        positions = jnp.arange(s - k.shape[1], s, dtype=jnp.int32)
    else:
        positions = jnp.arange(s, dtype=jnp.int32)
    return dict(
        k=k,
        v=v,
        positions=positions,
        pos=jnp.asarray(s, jnp.int32),
        kind="window" if window else "full",
    )


def grow_cache(cache: dict, new_len: int) -> dict:
    """Extend a prefill cache's buffers to ``new_len`` slots (generation)."""
    if "ckv" in cache:  # MLA latent cache
        cur = cache["ckv"].shape[1]
        if cur >= new_len:
            return cache
        pad = new_len - cur
        out = dict(cache)
        out["ckv"] = jnp.pad(cache["ckv"], ((0, 0), (0, pad), (0, 0)))
        out["krope"] = jnp.pad(cache["krope"], ((0, 0), (0, pad), (0, 0)))
        return out
    if "k" not in cache:
        return cache  # recurrent caches don't grow
    cur = cache["k"].shape[1]
    if cur >= new_len:
        return cache
    pad = new_len - cur
    out = dict(cache)
    out["k"] = jnp.pad(cache["k"], ((0, 0), (0, pad), (0, 0), (0, 0)))
    out["v"] = jnp.pad(cache["v"], ((0, 0), (0, pad), (0, 0), (0, 0)))
    out["positions"] = jnp.concatenate(
        [cache["positions"], jnp.full((pad,), -1, jnp.int32)]
    )
    return out


def make_decode_cache(batch: int, max_len: int, cfg: AttentionConfig, dtype, window: Optional[int] = None) -> dict:
    """Preallocated cache for pure-decode benchmarks (cache 'already full')."""
    window = window if window is not None else cfg.sliding_window
    length = min(max_len, window) if window else max_len
    kv = cfg.num_kv_heads
    if cfg.kind == "mla":
        return dict(
            ckv=jnp.zeros((batch, length, cfg.kv_lora_rank), dtype),
            krope=jnp.zeros((batch, length, cfg.qk_rope_head_dim), dtype),
            pos=jnp.asarray(max_len - 1, jnp.int32),
            kind="mla",
        )
    pos = max_len - 1
    idx = jnp.arange(length, dtype=jnp.int32)
    # warm ring buffer: slot i holds the most recent absolute position ≡ i (mod L)
    positions = pos - ((pos - idx) % length)
    return dict(
        k=jnp.zeros((batch, length, kv, cfg.head_dim), dtype),
        v=jnp.zeros((batch, length, kv, cfg.head_dim), dtype),
        positions=positions,
        pos=jnp.asarray(pos, jnp.int32),
        kind="window" if window else "full",
    )


def _cache_append(cache: dict, k_new, v_new, window: Optional[int]):
    """Write the new token's K/V at its ring slot; returns updated buffers."""
    pos = cache["pos"]
    length = cache["k"].shape[1]
    slot = pos % length
    k_all = cache["k"].at[:, slot].set(k_new[:, 0])
    v_all = cache["v"].at[:, slot].set(v_new[:, 0])
    positions = cache["positions"].at[slot].set(pos)
    return k_all, v_all, positions, pos


def _decode_attend(q, k, v, *, positions, pos, window: Optional[int]):
    """Single-token attention against the cache.

    q: (B,1,H,D); k/v: (B,L,Hkv,D); positions (L,) holds each slot's absolute
    token position (−1 = empty), which makes the same code path correct for
    growing caches, warm ring buffers, and sliding windows.
    """
    b, _, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, 1, kvh, g, d)
    scores = jnp.einsum("bqhgd,blhd->bhgql", qg.astype(jnp.float32), k.astype(jnp.float32))
    valid = (positions >= 0) & (positions <= pos)
    if window is not None:
        valid &= positions > (pos - window)
    scores = jnp.where(valid[None, None, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgql,blhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, 1, h, d)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3, arXiv:2412.19437)
# ---------------------------------------------------------------------------


def init_mla(b, name: str, d_model: int, cfg: AttentionConfig, eps: float = 1e-5):
    """MLA projections fused over (heads × per-head dims) — see init_gqa."""
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    with b.scope(name):
        if cfg.q_lora_rank:
            b.param("wdq", (d_model, cfg.q_lora_rank), ("embed", "lora"))
            init_rmsnorm(b, "q_norm", cfg.q_lora_rank)
            b.param("wuq", (cfg.q_lora_rank, h * (dn + dr)), ("lora", "ff"))
        else:
            b.param("wq", (d_model, h * (dn + dr)), ("embed", "ff"))
        b.param("wdkv", (d_model, cfg.kv_lora_rank), ("embed", "lora"))
        init_rmsnorm(b, "kv_norm", cfg.kv_lora_rank)
        b.param("wuk", (cfg.kv_lora_rank, h * dn), ("lora", "ff"))
        b.param("wuv", (cfg.kv_lora_rank, h * dv), ("lora", "ff"))
        b.param("wkr", (d_model, dr), ("embed", None))
        b.param("wo", (h * dv, d_model), ("ff", "embed"))


def mla_apply(
    params,
    cfg: AttentionConfig,
    x,
    cos,
    sin,
    *,
    mode: str = "train",
    cache: Optional[dict] = None,
    eps: float = 1e-5,
    paged=None,  # serving.paged_cache.PagedState — paged-pool decode
) -> Tuple[jnp.ndarray, Optional[dict]]:
    b_, s, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    if cfg.q_lora_rank:
        ql = rmsnorm(params["q_norm"], x @ params["wdq"], eps)
        q = ql @ params["wuq"]
    else:
        q = x @ params["wq"]
    q = constrain(q, ("batch", "seq", "act_ff")).reshape(b_, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope_mod.apply_rope(q_rope, cos, sin)

    ckv = rmsnorm(params["kv_norm"], x @ params["wdkv"], eps)  # (B,S,r)
    k_rope = rope_mod.apply_rope((x @ params["wkr"])[:, :, None, :], cos, sin)  # (B,S,1,dr)

    scale = 1.0 / jnp.sqrt(jnp.asarray(dn + dr, jnp.float32))

    if mode in ("train", "prefill"):
        k_nope = constrain(ckv @ params["wuk"], ("batch", "seq", "act_ff")).reshape(b_, s, h, dn)
        v = constrain(ckv @ params["wuv"], ("batch", "seq", "act_ff")).reshape(b_, s, h, dv)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b_, s, h, dr))], axis=-1)
        qcat = jnp.concatenate([q_nope, q_rope], axis=-1) * scale.astype(x.dtype)
        # pad v to qk head dim for the fused kernel, slice after
        dqk = dn + dr
        if kflags.use_pallas() and dv <= dqk:
            vpad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dqk - dv)))
            out = fa_ops.flash_attention(qcat, k, vpad, causal=True, window=None, q_offset=0)[..., :dv]
        else:
            out = fa_ref.mha_reference(qcat, k, v, causal=True, window=None, q_offset=0)
        new_cache = None
        if mode == "prefill":
            new_cache = dict(ckv=ckv, krope=k_rope[:, :, 0, :], pos=jnp.asarray(s, jnp.int32), kind="mla")
    elif paged is not None and cache is not None and "pool_ckv" in cache:
        # paged absorbed decode: latents scatter into the shared page pool
        pool_ckv = pa_ops.paged_append(cache["pool_ckv"], ckv, paged.page_tables, paged.lengths)
        pool_kr = pa_ops.paged_append(
            cache["pool_krope"], k_rope[:, :, 0, :], paged.page_tables, paged.lengths
        )
        wuk = params["wuk"].reshape(cfg.kv_lora_rank, h, dn)
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, wuk)
        o_lat = pa_ops.paged_attend_mla(
            q_lat, q_rope, pool_ckv, pool_kr, paged.page_tables, paged.lengths, scale=scale
        )
        wuv = params["wuv"].reshape(cfg.kv_lora_rank, h, dv)
        out = jnp.einsum("bshr,rhk->bshk", o_lat, wuv.astype(jnp.float32))
        new_cache = dict(pool_ckv=pool_ckv, pool_krope=pool_kr)
    else:  # decode — absorbed formulation: score via the latent cache directly
        assert cache is not None
        pos = cache["pos"]
        ckv_all = cache["ckv"].at[:, jnp.minimum(pos, cache["ckv"].shape[1] - 1)].set(ckv[:, 0])
        kr_all = cache["krope"].at[:, jnp.minimum(pos, cache["krope"].shape[1] - 1)].set(k_rope[:, 0, 0])
        # absorb W_uk into q: (B,1,h,dn) x (r,h,dn) -> (B,1,h,r)
        wuk = params["wuk"].reshape(cfg.kv_lora_rank, h, dn)
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, wuk)
        s_nope = jnp.einsum("bshr,blr->bhsl", q_lat.astype(jnp.float32), ckv_all.astype(jnp.float32))
        s_rope = jnp.einsum("bshk,blk->bhsl", q_rope.astype(jnp.float32), kr_all.astype(jnp.float32))
        scores = (s_nope + s_rope) * scale
        valid = jnp.arange(ckv_all.shape[1]) <= pos
        scores = jnp.where(valid[None, None, None, :], scores, -jnp.inf)
        p = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhsl,blr->bshr", p, ckv_all.astype(jnp.float32))  # (B,1,h,r)
        wuv = params["wuv"].reshape(cfg.kv_lora_rank, h, dv)
        out = jnp.einsum("bshr,rhk->bshk", o_lat, wuv.astype(jnp.float32))
        new_cache = dict(ckv=ckv_all, krope=kr_all, pos=pos + 1, kind="mla")

    sq = out.shape[1]
    y = out.astype(x.dtype).reshape(b_, sq, h * dv) @ params["wo"]
    return y, new_cache
