"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE [arXiv:2409.12191] splits the head_dim/2 rotary frequencies into
(temporal, height, width) sections; text tokens use identical (t,h,w)
positions so M-RoPE degenerates to RoPE for pure text, while vision patch
tokens carry their 2-D grid coordinates.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_cos_sin(positions: jnp.ndarray, head_dim: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions: (..., S) int -> cos/sin (..., S, head_dim/2) f32."""
    freqs = rope_freqs(head_dim, theta)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(
    positions: jnp.ndarray,  # (B, 3, S) int — (t, h, w) per token
    head_dim: int,
    theta: float,
    sections: Tuple[int, ...],
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """M-RoPE: frequency bands are assigned to (t,h,w) sections."""
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(head_dim, theta)  # (half,)
    # angle for all 3 position streams: (B, 3, S, half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    # select stream per frequency band
    sec_id = jnp.repeat(jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=half)  # (half,)
    ang = jnp.moveaxis(ang, 1, -2)  # (B, S, 3, half)
    ang_sel = jnp.take_along_axis(ang, sec_id[None, None, None, :], axis=-2)[..., 0, :]  # (B,S,half)
    return jnp.cos(ang_sel), jnp.sin(ang_sel)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, D); cos/sin: (..., S, D/2) broadcast over heads.

    Rotate-half convention (Llama/Qwen): pairs are (x[..., :D/2], x[..., D/2:]).
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    xf1 = x1.astype(jnp.float32)
    xf2 = x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s], axis=-1)
    return out.astype(x.dtype)


def text_positions(batch: int, seq: int, offset=0) -> jnp.ndarray:
    return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None, :] + offset, (batch, seq))


def text_mrope_positions(batch: int, seq: int, offset=0) -> jnp.ndarray:
    p = text_positions(batch, seq, offset)
    return jnp.broadcast_to(p[:, None, :], (batch, 3, seq))
