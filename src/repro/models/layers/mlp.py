"""Feed-forward layers: SwiGLU (llama-family) and plain GELU MLP (musicgen).

Model code is written per-worker: activations are (B, S, ...). The trainer
vmaps over the Local-SGD worker axis; sharding constraints specified here
apply to the per-worker view and the worker axis sharding propagates from
the stacked operands (verified: constraints compose correctly under vmap).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel import constrain


def init_swiglu(b, name: str, d_model: int, d_ff: int):
    with b.scope(name):
        b.param("wi_gate", (d_model, d_ff), ("embed", "ff"))
        b.param("wi_up", (d_model, d_ff), ("embed", "ff"))
        b.param("wo", (d_ff, d_model), ("ff", "embed"))


def swiglu(params, x, act: str = "silu"):
    g = x @ params["wi_gate"]
    u = x @ params["wi_up"]
    g = constrain(g, ("batch", "seq", "act_ff"))
    u = constrain(u, ("batch", "seq", "act_ff"))
    a = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    return (a * u) @ params["wo"]


def init_gelu_mlp(b, name: str, d_model: int, d_ff: int):
    with b.scope(name):
        b.param("wi", (d_model, d_ff), ("embed", "ff"))
        b.param("bi", (d_ff,), ("ff",), init="zeros")
        b.param("wo", (d_ff, d_model), ("ff", "embed"))
        b.param("bo", (d_model,), (None,), init="zeros")


def gelu_mlp(params, x):
    h = x @ params["wi"] + params["bi"]
    h = constrain(h, ("batch", "seq", "act_ff"))
    return jax.nn.gelu(h) @ params["wo"] + params["bo"]
