"""Mamba2 block (SSD) as used by Zamba2 [arXiv:2411.15242].

in_proj → [gate z | conv-stream (x, B, C) | dt] → causal conv1d → SSD scan
→ gated RMSNorm → out_proj. Train/prefill use the chunked SSD (Pallas kernel
on TPU, chunked-jnp otherwise); decode is the O(1) recurrent step carrying
(conv_state, ssd_state).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import SSMConfig
from repro.kernels import flags as kflags
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.kernels.ssd_scan import ref as ssd_ref
from repro.models.layers.norms import init_rmsnorm, rmsnorm
from repro.parallel import constrain


def _dims(d_model: int, cfg: SSMConfig):
    d_inner = cfg.expand * d_model
    heads = d_inner // cfg.head_dim
    groups = 1
    return d_inner, heads, groups


def init_mamba2(b, name: str, d_model: int, cfg: SSMConfig):
    d_inner, heads, groups = _dims(d_model, cfg)
    n = cfg.state_dim
    conv_dim = d_inner + 2 * groups * n
    with b.scope(name):
        b.param("in_proj", (d_model, 2 * d_inner + 2 * groups * n + heads), ("embed", "ff"))
        b.param("conv_w", (cfg.conv_width, conv_dim), ("conv", "ff"))
        b.param("conv_b", (conv_dim,), ("ff",), init="zeros")
        b.param("a_log", (heads,), (None,), init="constant", scale=0.0)
        b.param("dt_bias", (heads,), (None,), init="zeros")
        b.param("d_skip", (heads,), (None,), init="ones")
        init_rmsnorm(b, "norm", d_inner)
        b.param("out_proj", (d_inner, d_model), ("ff", "embed"))


def _split(params, cfg: SSMConfig, d_model: int, xz):
    d_inner, heads, groups = _dims(d_model, cfg)
    n = cfg.state_dim
    z, xbc, dt = jnp.split(xz, [d_inner, 2 * d_inner + 2 * groups * n], axis=-1)
    return z, xbc, dt, d_inner, heads, groups, n


def _causal_conv(xbc, conv_w, conv_b, width: int):
    # xbc: (B,S,C); depthwise causal conv via width-shifted adds (width ≤ 4)
    out = xbc * conv_w[-1]
    for i in range(1, width):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, : xbc.shape[1]]
        out = out + shifted * conv_w[-1 - i]
    return jax.nn.silu(out + conv_b)


def mamba2_apply(
    params,
    cfg: SSMConfig,
    x,  # (B,S,d_model)
    *,
    mode: str = "train",
    cache: Optional[dict] = None,
    eps: float = 1e-5,
) -> Tuple[jnp.ndarray, Optional[dict]]:
    b_, s, d_model = x.shape
    xz = x @ params["in_proj"]
    z, xbc, dt, d_inner, heads, groups, n = _split(params, cfg, d_model, xz)
    A = -jnp.exp(params["a_log"].astype(jnp.float32))
    new_cache = None

    if mode in ("train", "prefill"):
        xbc_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"], cfg.conv_width)
        xs, B, C = jnp.split(xbc_conv, [d_inner, d_inner + groups * n], axis=-1)
        xs = constrain(xs, ("batch", "seq", "act_ff"))
        xh = xs.reshape(b_, s, heads, cfg.head_dim)
        Bh = B.reshape(b_, s, groups, n)
        Ch = C.reshape(b_, s, groups, n)
        dt_s = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
        if kflags.use_pallas():
            y, st = ssd_ops.ssd_scan(xh, dt_s, A, Bh, Ch, params["d_skip"], cfg.chunk_size)
        else:
            y, st = ssd_ref.ssd_chunked(xh, dt_s, A, Bh, Ch, params["d_skip"], chunk=cfg.chunk_size)
        y = y.reshape(b_, s, d_inner)
        if mode == "prefill":
            conv_state = jnp.pad(xbc, ((0, 0), (cfg.conv_width - 1, 0), (0, 0)))[:, -(cfg.conv_width - 1) :]
            new_cache = dict(ssd_state=st, conv_state=conv_state, kind="mamba")
    else:  # decode: single step
        assert cache is not None and s == 1
        conv_state = cache["conv_state"]  # (B, width-1, conv_dim)
        window = jnp.concatenate([conv_state, xbc], axis=1)  # (B, width, conv_dim)
        conv_out = jnp.einsum("bwc,wc->bc", window, params["conv_w"]) + params["conv_b"]
        xbc_conv = jax.nn.silu(conv_out)[:, None, :]
        xs, B, C = jnp.split(xbc_conv, [d_inner, d_inner + groups * n], axis=-1)
        dt_s = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
        y, st = ssd_ops.ssd_decode_step(
            cache["ssd_state"],
            xs[:, 0].reshape(b_, heads, cfg.head_dim),
            dt_s,
            A,
            B[:, 0].reshape(b_, groups, n),
            C[:, 0].reshape(b_, groups, n),
            params["d_skip"],
        )
        y = y.reshape(b_, 1, d_inner)
        new_cache = dict(ssd_state=st, conv_state=window[:, 1:], kind="mamba")

    y = rmsnorm(params["norm"], y * jax.nn.silu(z), eps)
    return y @ params["out_proj"], new_cache


def make_mamba_cache(batch: int, d_model: int, cfg: SSMConfig, dtype) -> dict:
    d_inner, heads, groups = _dims(d_model, cfg)
    n = cfg.state_dim
    conv_dim = d_inner + 2 * groups * n
    return dict(
        ssd_state=jnp.zeros((batch, heads, cfg.head_dim, n), jnp.float32),
        conv_state=jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
        kind="mamba",
    )
