"""Composable decoder-only transformer covering all assigned architectures.

A model is a sequence of *segments*: maximal runs of identical block kinds
("attn" | "moe" | "mamba2" | "rwkv6" | "shared_attn"). Each homogeneous run
stores its parameters stacked with a leading layer axis and is applied with
``lax.scan`` — compile time and HLO size stay O(#segments), not O(#layers),
which matters when dry-running 88-layer models × 40 configs. Zamba2's
weight-*shared* attention block is stored once at top level and applied at
every "shared_attn" position.

Modes: "train" (full sequence, causal), "prefill" (returns KV/state caches),
"decode" (one token against caches). VLM/audio modality frontends are stubs
per the assignment: the model consumes precomputed patch/frame embeddings
(vision) or EnCodec codebook tokens (audio).

``params`` may be the built nested dict or a
:class:`repro.models.params.ParamView` over the packed parameter plane
(plane-resident training): every access below goes through the shared
dict/``get``/``in`` protocol, a leaf read materializes one plane window
(fused into its consumer), and the ``lax.scan`` over a stacked segment
consumes the view's ``(n, ...)`` windows directly — so the same apply code
is differentiated with the plane buffers as the primal, without this module
ever importing the packing layer's layout machinery.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models import params as P
from repro.models.layers import attention as attn_mod
from repro.models.layers import mamba2 as mamba_mod
from repro.models.layers import mlp as mlp_mod
from repro.models.layers import moe as moe_mod
from repro.models.layers import rope as rope_mod
from repro.models.layers import rwkv6 as rwkv_mod
from repro.models.layers.norms import init_layernorm, init_rmsnorm, layernorm, rmsnorm
from repro.parallel import constrain

# ---------------------------------------------------------------------------
# pattern segmentation
# ---------------------------------------------------------------------------


def segments(cfg: ModelConfig) -> List[Tuple[str, int]]:
    out: List[Tuple[str, int]] = []
    for kind in cfg.pattern():
        if out and out[-1][0] == kind and kind != "shared_attn":
            out[-1] = (kind, out[-1][1] + 1)
        else:
            out.append((kind, 1))
    return out


# ---------------------------------------------------------------------------
# per-block init
# ---------------------------------------------------------------------------


def _init_block(b, cfg: ModelConfig, kind: str):
    d = cfg.d_model
    if kind in ("attn", "moe", "shared_attn"):
        a = cfg.attention
        norm_init = init_layernorm if cfg.use_parallel_block else init_rmsnorm
        norm_init(b, "ln1", d)
        if a.kind == "mla":
            attn_mod.init_mla(b, "attn", d, a)
        else:
            attn_mod.init_gqa(b, "attn", d, a)
            if cfg.use_qk_norm:
                attn_mod.init_qk_norm(b, "qknorm", a)
        if not cfg.use_parallel_block:
            init_rmsnorm(b, "ln2", d)
        if kind == "moe":
            moe_mod.init_moe(b, "ffn", d, cfg.moe)
        else:
            mlp_kind = "gelu" if cfg.act == "gelu" else "swiglu"
            if mlp_kind == "gelu":
                mlp_mod.init_gelu_mlp(b, "ffn", d, cfg.d_ff)
            else:
                mlp_mod.init_swiglu(b, "ffn", d, cfg.d_ff)
    elif kind == "mamba2":
        init_rmsnorm(b, "ln1", d)
        mamba_mod.init_mamba2(b, "block", d, cfg.ssm)
    elif kind == "rwkv6":
        init_rmsnorm(b, "ln1", d)
        init_rmsnorm(b, "ln2", d)
        rwkv_mod.init_rwkv6(b, "tm", d, cfg.ssm)
        rwkv_mod.init_rwkv6_ffn(b, "cm", d, cfg.d_ff)
    else:
        raise ValueError(kind)


def _init_stacked(key, dtype, cfg: ModelConfig, kind: str, n: int, abstract: bool = False):
    """Stacked params for a scanned run of n identical blocks."""
    if abstract:
        one, axes = P.build(_init_block, key, dtype, cfg, kind, abstract=True)
        stacked = jax.tree.map(lambda s: jax.ShapeDtypeStruct((n,) + tuple(s.shape), s.dtype), one)
    else:

        def one_fn(k):
            prm, _ = P.build(_init_block, k, dtype, cfg, kind)
            return prm

        keys = jax.random.split(key, n)
        stacked = jax.vmap(one_fn)(keys)
        _, axes = P.build(_init_block, key, dtype, cfg, kind)
    axes = jax.tree.map(lambda a: (None,) + a, axes, is_leaf=lambda t: isinstance(t, tuple))
    return stacked, axes


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------


def init_model(cfg: ModelConfig, key, abstract: bool = False) -> Tuple[dict, dict]:
    """Build (params, logical-axes) trees. ``abstract=True`` returns
    ShapeDtypeStructs — used by the dry-run to describe multi-hundred-B
    parameter trees without allocating anything."""
    b = P.Builder(key, cfg.param_dtype, abstract=abstract)
    d = cfg.d_model
    fe = cfg.frontend
    if fe is not None and fe.kind == "audio":
        b.param("tok_emb", (fe.num_codebooks, cfg.vocab_size, d), (None, "vocab", "embed"), init="normal")
    else:
        b.param("tok_emb", (cfg.vocab_size, d), ("vocab", "embed"), init="normal")
    if fe is not None and fe.kind == "vision":
        with b.scope("projector"):
            b.param("w1", (fe.embed_dim, d), ("embed_no_shard", "embed"))
            b.param("w2", (d, d), ("embed", "embed_no_shard"))
    init_rmsnorm(b, "final_norm", d)
    if not cfg.tie_embeddings:
        if fe is not None and fe.kind == "audio":
            b.param("head", (fe.num_codebooks, d, cfg.vocab_size), (None, "embed", "vocab"))
        else:
            b.param("head", (d, cfg.vocab_size), ("embed", "vocab"))
    if cfg.shared_attn_every:
        with b.scope("shared_block"):
            _init_block(b, cfg, "shared_attn")
    if cfg.mtp_depth:
        with b.scope("mtp"):
            init_rmsnorm(b, "ln_in", d)
            b.param("proj", (2 * d, d), ("embed_no_shard", "embed"))
            _init_block(b, cfg, "attn")

    params, axes = b.params, b.axes
    key_layers = key if abstract else jax.random.fold_in(key, 7)
    for si, (kind, n) in enumerate(segments(cfg)):
        if kind == "shared_attn":
            continue
        sub, sub_axes = _init_stacked(
            key_layers if abstract else jax.random.fold_in(key_layers, si),
            cfg.param_dtype,
            cfg,
            kind,
            n,
            abstract=abstract,
        )
        params[f"seg{si}"] = sub
        axes[f"seg{si}"] = sub_axes
    return params, axes


# ---------------------------------------------------------------------------
# per-block apply
# ---------------------------------------------------------------------------


def _apply_block(cfg: ModelConfig, kind: str, prm, x, cos, sin, *, mode: str, cache, eps, paged=None):
    stats = None
    if kind in ("attn", "moe", "shared_attn"):
        a = cfg.attention
        if cfg.use_parallel_block:  # command-r: x + attn(ln(x)) + ffn(ln(x))
            h = layernorm(prm["ln1"], x, eps)
            if a.kind == "mla":
                y_attn, new_cache = attn_mod.mla_apply(
                    prm["attn"], a, h, cos, sin, mode=mode, cache=cache, eps=eps, paged=paged
                )
            else:
                y_attn, new_cache = attn_mod.gqa_apply(
                    prm["attn"], a, h, cos, sin, mode=mode, cache=cache, eps=eps,
                    qk_norm_params=prm.get("qknorm"), paged=paged,
                )
            if kind == "moe":
                y_ffn, stats = moe_mod.moe_apply(prm["ffn"], cfg.moe, h, cfg.act, capacity_factor=0.0 if mode == "train" else 4.0)
            elif cfg.act == "gelu":
                y_ffn = mlp_mod.gelu_mlp(prm["ffn"], h)
            else:
                y_ffn = mlp_mod.swiglu(prm["ffn"], h, cfg.act)
            x = x + y_attn + y_ffn
        else:
            h = rmsnorm(prm["ln1"], x, eps)
            if a.kind == "mla":
                y, new_cache = attn_mod.mla_apply(
                    prm["attn"], a, h, cos, sin, mode=mode, cache=cache, eps=eps, paged=paged
                )
            else:
                y, new_cache = attn_mod.gqa_apply(
                    prm["attn"], a, h, cos, sin, mode=mode, cache=cache, eps=eps,
                    qk_norm_params=prm.get("qknorm"), paged=paged,
                )
            x = x + y
            h2 = rmsnorm(prm["ln2"], x, eps)
            if kind == "moe":
                y2, stats = moe_mod.moe_apply(prm["ffn"], cfg.moe, h2, cfg.act, capacity_factor=0.0 if mode == "train" else 4.0)
            elif cfg.act == "gelu":
                y2 = mlp_mod.gelu_mlp(prm["ffn"], h2)
            else:
                y2 = mlp_mod.swiglu(prm["ffn"], h2, cfg.act)
            x = x + y2
    elif kind == "mamba2":
        h = rmsnorm(prm["ln1"], x, eps)
        y, new_cache = mamba_mod.mamba2_apply(prm["block"], cfg.ssm, h, mode=mode, cache=cache, eps=eps)
        x = x + y
    elif kind == "rwkv6":
        h = rmsnorm(prm["ln1"], x, eps)
        y, tm_cache = rwkv_mod.rwkv6_timemix_apply(prm["tm"], cfg.ssm, h, mode=mode, cache=cache, eps=eps)
        x = x + y
        h2 = rmsnorm(prm["ln2"], x, eps)
        y2, cm_cache = rwkv_mod.rwkv6_channelmix_apply(prm["tm"], prm["cm"], h2, cache=cache)
        x = x + y2
        new_cache = None
        if tm_cache is not None:
            new_cache = dict(tm_cache)
            if cm_cache is not None:
                new_cache.update(cm_cache)
            else:
                new_cache["cm_last"] = h2[:, -1]
    else:
        raise ValueError(kind)
    x = constrain(x, ("batch", "seq", "act_embed"))
    return x, new_cache, stats


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------


def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> dict:
    """Preallocated per-segment caches for pure-decode (cache 'full' at pos=max_len-1)."""
    dtype = dtype or cfg.param_dtype
    caches: Dict[str, Any] = {}
    for si, (kind, n) in enumerate(segments(cfg)):
        one = _init_block_cache(cfg, kind, batch, max_len, dtype)
        if kind == "shared_attn":
            caches[f"seg{si}"] = one
        else:
            caches[f"seg{si}"] = jax.tree.map(lambda t: jnp.broadcast_to(t[None], (n,) + t.shape), one)
    return caches


def _init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int, dtype):
    if kind in ("attn", "moe", "shared_attn"):
        c = attn_mod.make_decode_cache(batch, max_len, cfg.attention, dtype)
        c.pop("kind")
        return c
    if kind == "mamba2":
        c = mamba_mod.make_mamba_cache(batch, cfg.d_model, cfg.ssm, dtype)
        c.pop("kind")
        return c
    if kind == "rwkv6":
        c = rwkv_mod.make_rwkv_cache(batch, cfg.d_model, cfg.ssm, dtype)
        c.pop("kind")
        return c
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _embed(cfg: ModelConfig, params, inputs) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Returns (x, loss_mask). inputs: dict with tokens / image_embeds / positions."""
    fe = cfg.frontend
    if fe is not None and fe.kind == "audio":
        toks = inputs["tokens"]  # (B, K, S)
        # tok_emb: (K, V, d); summed gather per codebook (delay pattern applied
        # by the data pipeline)
        x = sum(params["tok_emb"][k][toks[:, k]] for k in range(fe.num_codebooks))
        return x.astype(cfg.param_dtype), None
    toks = inputs["tokens"]
    x = params["tok_emb"][toks]
    mask = None
    if fe is not None and fe.kind == "vision" and "image_embeds" in inputs:
        img = inputs["image_embeds"].astype(cfg.param_dtype)  # (B, S_img, vit)
        proj = jax.nn.gelu(img @ params["projector"]["w1"]) @ params["projector"]["w2"]
        x = jnp.concatenate([proj, x], axis=1)
        s_img = img.shape[1]
        mask = jnp.concatenate(
            [jnp.zeros((x.shape[0], s_img), bool), jnp.ones((x.shape[0], toks.shape[1]), bool)], axis=1
        )
    return x.astype(cfg.param_dtype), mask


def _rope_for(cfg: ModelConfig, inputs, batch: int, seq: int, offset) -> Tuple[Optional[jnp.ndarray], Optional[jnp.ndarray]]:
    a = cfg.attention
    if a is None or a.rope == "none":
        return None, None
    if a.kind == "mla":
        dim = a.qk_rope_head_dim
    else:
        dim = a.head_dim
    if a.rope == "mrope":
        pos = inputs.get("positions")
        if pos is None:
            pos = rope_mod.text_mrope_positions(batch, seq, offset)
        return rope_mod.mrope_cos_sin(pos, dim, a.rope_theta, a.mrope_sections)
    pos = inputs.get("positions")
    if pos is None:
        pos = rope_mod.text_positions(batch, seq, offset)
    return rope_mod.rope_cos_sin(pos, dim, a.rope_theta)


def apply_model(
    cfg: ModelConfig,
    params: dict,
    inputs: dict,
    *,
    mode: str = "train",
    caches: Optional[dict] = None,
    remat: bool = False,
    decode_pos=None,
    paged=None,  # serving.paged_cache.PagedState — paged-pool decode (DESIGN.md §10)
) -> Tuple[jnp.ndarray, dict]:
    """Returns (logits, aux) where aux has 'caches', 'moe_aux', 'loss_mask',
    'hidden' (pre-head activations, for MTP). With ``paged`` set (decode
    only), per-row token positions come from ``inputs['positions']`` and the
    attention caches are page pools shared across rows."""
    x, loss_mask = _embed(cfg, params, inputs)
    b_, s = x.shape[0], x.shape[1]
    offset = decode_pos if mode == "decode" else 0
    cos, sin = _rope_for(cfg, inputs, b_, s, offset if offset is not None else 0)
    eps = cfg.norm_eps

    moe_aux = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {}

    for si, (kind, n) in enumerate(segments(cfg)):
        seg_key = f"seg{si}"
        if kind == "shared_attn":
            prm = params["shared_block"]
            cache = caches.get(seg_key) if caches else None
            x, nc, stats = _apply_block(cfg, kind, prm, x, cos, sin, mode=mode, cache=cache, eps=eps, paged=paged)
            if nc is not None:
                nc.pop("kind", None)
                new_caches[seg_key] = nc
            continue

        seg_params = params[seg_key]
        seg_caches = caches.get(seg_key) if caches else None

        def body(carry, layer_in, _kind=kind):
            xx, aux = carry
            prm_i, cache_i = layer_in
            xx, nc, stats = _apply_block(
                cfg, _kind, prm_i, xx, cos, sin, mode=mode, cache=cache_i, eps=eps, paged=paged
            )
            if stats is not None:
                aux = aux + stats["aux_loss"]
            if nc is not None:
                nc.pop("kind", None)
            return (xx, aux), nc

        body_fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) if remat else body
        (x, moe_aux), seg_new_caches = jax.lax.scan(body_fn, (x, moe_aux), (seg_params, seg_caches))
        if seg_new_caches is not None and mode != "train":
            new_caches[seg_key] = seg_new_caches

    hidden = rmsnorm(params["final_norm"], x, eps)
    logits = _head(cfg, params, hidden)
    aux = dict(caches=new_caches, moe_aux=moe_aux, loss_mask=loss_mask, hidden=hidden)
    return logits, aux


def _head(cfg: ModelConfig, params, hidden):
    fe = cfg.frontend
    if fe is not None and fe.kind == "audio":
        logits = jnp.einsum("bsd,kdv->bksv", hidden, params["head"])
    elif cfg.tie_embeddings:
        logits = hidden @ params["tok_emb"].T
    else:
        logits = hidden @ params["head"]
    logits = logits * cfg.logit_scale
    return constrain(logits, ("batch", "seq", "act_vocab") if logits.ndim == 3 else ("batch", None, "seq", "act_vocab"))


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def softmax_xent(logits, targets, mask=None) -> jnp.ndarray:
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def lm_loss(cfg: ModelConfig, params, batch, *, remat: bool = False) -> Tuple[jnp.ndarray, dict]:
    """batch: dict(tokens=..., targets=..., [image_embeds, positions]).

    Audio (musicgen): tokens (B,K,S), targets (B,K,S); loss averaged over
    codebooks. VLM: loss masked to text positions. MTP (DeepSeek-V3): one
    extra next-next-token prediction module, weight 0.3.
    """
    logits, aux = apply_model(cfg, params, batch, mode="train", remat=remat)
    fe = cfg.frontend
    targets = batch["targets"]
    if fe is not None and fe.kind == "audio":
        loss = softmax_xent(logits, targets)  # (B,K,S,V) vs (B,K,S)
    elif fe is not None and fe.kind == "vision":
        # logits cover [img ; text]; targets only for text tokens
        s_text = targets.shape[1]
        loss = softmax_xent(logits[:, -s_text:], targets)
    else:
        loss = softmax_xent(logits, targets)
    metrics = dict(xent=loss)
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux["moe_aux"] / max(cfg.num_layers, 1)
        metrics["moe_aux"] = aux["moe_aux"]
    if cfg.mtp_depth and fe is None:
        mtp_l = _mtp_loss(cfg, params, batch, aux["hidden"])
        loss = loss + 0.3 * mtp_l
        metrics["mtp"] = mtp_l
    metrics["loss"] = loss
    return loss, metrics


def _mtp_loss(cfg: ModelConfig, params, batch, hidden):
    """DeepSeek-V3 multi-token prediction: predict t+2 from [h_t ; emb(t+1)]."""
    toks = batch["tokens"]
    tgt = batch["targets"]
    emb_next = params["tok_emb"][tgt]  # embedding of token t+1
    mtp = params["mtp"]
    h = rmsnorm(mtp["ln_in"], hidden, cfg.norm_eps)
    z = jnp.concatenate([h[:, :-1], emb_next[:, :-1].astype(h.dtype)], axis=-1) @ mtp["proj"]
    b_, s = z.shape[0], z.shape[1]
    cos, sin = _rope_for(cfg, {}, b_, s, 0)
    z, _, _ = _apply_block(cfg, "attn", mtp, z, cos, sin, mode="train", cache=None, eps=cfg.norm_eps)
    logits2 = _head(cfg, params, z)
    return softmax_xent(logits2, tgt[:, 1:])
