"""Parameter construction with logical-axis bookkeeping.

``Builder`` creates parameter pytrees (plain nested dicts) while recording a
parallel tree of *logical axis names* for every leaf — the sharding layer
(repro.parallel.sharding) resolves those names to mesh axes. This keeps model
code free of mesh details while guaranteeing the axes tree always matches the
params tree structurally.

At *apply* time model code may receive either the built dict or a
:class:`ParamView` — the lazy, path-keyed window view of the packed
parameter plane that plane-resident training differentiates through
(re-exported here so model code never imports the packing layer's
``Layout`` machinery). Both support the same access surface
(``params[key]`` / ``params.get`` / ``key in params`` / ``lax.scan`` over a
stacked-layer subtree), so apply functions are written once against that
protocol.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.packing import ParamView  # noqa: F401  (model-facing re-export)


class Builder:
    def __init__(self, key: jax.Array, dtype=jnp.float32, abstract: bool = False):
        self._key = key
        self.dtype = dtype
        self.abstract = abstract  # create ShapeDtypeStructs (dry-run: no compute)
        self.params: dict = {}
        self.axes: dict = {}
        self._path: list = []

    # -- scoping ----------------------------------------------------------
    @contextlib.contextmanager
    def scope(self, name: str):
        self._path.append(str(name))
        try:
            yield self
        finally:
            self._path.pop()

    def _insert(self, name: str, value, axes):
        p, a = self.params, self.axes
        for part in self._path:
            p = p.setdefault(part, {})
            a = a.setdefault(part, {})
        if name in p:
            raise ValueError(f"duplicate param {'/'.join(self._path + [name])}")
        p[name] = value
        a[name] = tuple(axes)

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    # -- creation ---------------------------------------------------------
    def param(
        self,
        name: str,
        shape: Sequence[int],
        axes: Sequence[Optional[str]],
        init: str = "fan_in",
        scale: Optional[float] = None,
        dtype=None,
    ) -> jax.Array:
        assert len(shape) == len(axes), (name, shape, axes)
        dtype = dtype or self.dtype
        if self.abstract:
            v = jax.ShapeDtypeStruct(tuple(shape), dtype)
            self._insert(name, v, axes)
            return v
        if init == "zeros":
            v = jnp.zeros(shape, dtype)
        elif init == "ones":
            v = jnp.ones(shape, dtype)
        elif init == "normal":
            s = 0.02 if scale is None else scale
            v = (jax.random.normal(self._next_key(), shape, jnp.float32) * s).astype(dtype)
        elif init == "fan_in":
            fan_in = shape[0] if len(shape) >= 2 else max(int(np.prod(shape)), 1)
            if len(shape) == 3:  # (experts, d_in, d_out)
                fan_in = shape[1]
            s = (1.0 / np.sqrt(fan_in)) if scale is None else scale / np.sqrt(fan_in)
            v = (jax.random.normal(self._next_key(), shape, jnp.float32) * s).astype(dtype)
        elif init == "constant":
            v = jnp.full(shape, scale, dtype)
        else:
            raise ValueError(init)
        self._insert(name, v, axes)
        return v


def build(fn, key, dtype, *args, abstract: bool = False, **kwargs) -> Tuple[dict, dict]:
    """Run ``fn(builder, *args, **kwargs)``; return (params, axes) trees."""
    b = Builder(key, dtype, abstract=abstract)
    fn(b, *args, **kwargs)
    return b.params, b.axes


def num_params(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
