"""Small MLP classifier — the CIFAR-10/ResNet-18 stand-in for the paper-
reproduction benchmarks (Tables 1–2, Figs. 1/4/5).

The paper's phenomena (error–τ tradeoff, non-IID drift, pullback
stabilization) are optimizer-level; a 2-hidden-layer MLP on the synthetic
classification task exhibits all of them at CPU scale while keeping the
300-epoch algorithm grid tractable.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import params as P


def init_mlp(key, dim: int, num_classes: int, hidden: Tuple[int, ...] = (128, 64), dtype=jnp.float32):
    def body(b):
        last = dim
        for i, h in enumerate(hidden):
            b.param(f"w{i}", (last, h), ("embed", "ff"))
            b.param(f"b{i}", (h,), ("ff",), init="zeros")
            last = h
        b.param("w_out", (last, num_classes), ("ff", None))
        b.param("b_out", (num_classes,), (None,), init="zeros")

    return P.build(body, key, dtype)


def mlp_apply(params, x):
    # params: built dict or a ParamView over the packed plane (plane-resident
    # training) — both serve the `in`/`[]` access protocol used here
    h = x
    i = 0
    while f"w{i}" in params:
        h = jnp.tanh(h @ params[f"w{i}"] + params[f"b{i}"])
        i += 1
    return h @ params["w_out"] + params["b_out"]


def mlp_loss(params, batch):
    """batch: (x (b,dim), y (b,)) -> (loss, metrics)."""
    x, y = batch
    logits = mlp_apply(params, x)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None].astype(jnp.int32), axis=-1)[:, 0]
    loss = jnp.mean(lse - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
    return loss, dict(loss=loss, acc=acc)


def accuracy(params, x, y, batch: int = 4096) -> float:
    n = x.shape[0]
    correct = 0
    for i in range(0, n, batch):
        logits = mlp_apply(params, x[i : i + batch])
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y[i : i + batch]))
    return correct / n
