#!/usr/bin/env python3
"""Docs-reference check: every `DESIGN.md` / `EXPERIMENTS.md` citation in the
source tree must resolve — the cited file exists, and when the citation names
a section (`DESIGN.md §Arch-applicability`, `EXPERIMENTS.md §Perf`, …) that
section header exists in the document. Run by CI next to the tier-1 suite
(and wrapped by tests/test_docs_refs.py) so a docstring can never cite a
dangling document again.

Usage: python tools/check_doc_refs.py [repo_root]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# directories scanned for citations, relative to the repo root
SCAN_DIRS = ("src", "benchmarks", "tests", "examples")
DOC_NAMES = ("DESIGN", "EXPERIMENTS")

# `DESIGN.md §3`, `EXPERIMENTS.md §Perf`, or a bare `DESIGN.md` mention
CITE_RE = re.compile(r"\b(%s)\.md(?:\s*§([A-Za-z0-9_-]+))?" % "|".join(DOC_NAMES))


def collect_citations(repo_root: Path):
    """-> sorted {(doc, section_or_None, "path:line")}."""
    cites = set()
    for d in SCAN_DIRS:
        root = repo_root / d
        if not root.is_dir():
            continue
        for py in sorted(root.rglob("*.py")):
            for lineno, line in enumerate(py.read_text().splitlines(), 1):
                for m in CITE_RE.finditer(line):
                    where = f"{py.relative_to(repo_root)}:{lineno}"
                    cites.add((m.group(1), m.group(2), where))
    return sorted(cites, key=lambda c: (c[0], c[1] or "", c[2]))


def _has_section(doc_text: str, section: str) -> bool:
    """A cited §section resolves iff some markdown header line contains the
    literal `§section` token (not a longer token sharing the prefix)."""
    pat = re.compile(r"§%s(?![\w-])" % re.escape(section))
    return any(
        pat.search(line) for line in doc_text.splitlines() if line.lstrip().startswith("#")
    )


def check(repo_root: Path):
    """-> list of error strings (empty = all citations resolve)."""
    errors = []
    doc_texts = {}
    for doc, section, where in collect_citations(repo_root):
        path = repo_root / f"{doc}.md"
        if doc not in doc_texts:
            doc_texts[doc] = path.read_text() if path.is_file() else None
        if doc_texts[doc] is None:
            errors.append(f"{where}: cites {doc}.md, which does not exist")
            continue
        if section is not None and not _has_section(doc_texts[doc], section):
            errors.append(f"{where}: cites {doc}.md §{section}, but no such section header")
    return errors


def main(argv) -> int:
    repo_root = Path(argv[1]) if len(argv) > 1 else Path(__file__).resolve().parent.parent
    cites = collect_citations(repo_root)
    errors = check(repo_root)
    for e in errors:
        print(f"DOC-REF ERROR: {e}", file=sys.stderr)
    print(f"doc-ref check: {len(cites)} citation(s), {len(errors)} unresolved")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
