"""Adaptive-τ training (DESIGN.md §6): a ``TauController`` drives the
communication period live, fed by the fused consensus-distance probe.

Two runs of Overlap-Local-SGD on the synthetic classification task:

* IID workers — consensus drift stays a small fraction of the parameter
  norm, so the controller *grows* τ (fewer boundaries, more hidden
  communication);
* non-IID workers (64% single-class per worker) — local models scatter
  during long rounds, so a controller started at a large τ *shrinks* it.

Each distinct τ compiles one round program (τ is a static shape
parameter); the run touches at most O(log τ_max) programs.

    PYTHONPATH=src python examples/adaptive_tau.py [--rounds 8]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import ClassificationSpec, Experiment, TauController


def run(name: str, noniid: bool, ctrl: TauController, rounds: int) -> None:
    exp = Experiment(
        task=ClassificationSpec(noniid=noniid),
        strategy="overlap_local_sgd",
        workers=4,
        seed=0,
    )
    res = exp.fit(rounds=rounds, adaptive_tau=ctrl)
    print(f"\n{name}: start τ={res.tau_schedule[0]['tau']}, band=[{ctrl.lo}, {ctrl.hi}]")
    print(f"  {'round':>5} {'τ':>3} {'drift/scale':>12} {'decision':>9} {'next τ':>6}   loss")
    for h, loss in zip(res.tau_schedule, res.losses):
        print(
            f"  {h['round']:5d} {h['tau']:3d} {h['drift_ratio']:12.4f} "
            f"{h['decision']:>9} {h['next_tau']:6d}   {loss:.4f}"
        )
    taus = sorted({h["tau"] for h in res.tau_schedule})
    print(
        f"  {res.steps} local steps over {res.rounds} rounds; "
        f"τ visited {taus}; {len(exp.tau_programs)} compiled round programs; "
        f"test_acc={exp.evaluate()['test_acc']:.4f}"
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    args = ap.parse_args()
    # IID: drift ratio starts ~0.02 at τ=1 — below lo, so τ grows
    run("IID (τ grows)", False, TauController(tau=1, tau_min=1, tau_max=8, lo=0.05, hi=0.5), args.rounds)
    # non-IID: drift ratio at τ=8 starts ~0.22 — above hi, so τ shrinks
    run("non-IID (τ shrinks)", True, TauController(tau=8, tau_min=1, tau_max=8, lo=0.01, hi=0.15), args.rounds)
