"""End-to-end LM pretraining driver with Overlap-Local-SGD, built through
the ``repro.api.Experiment`` facade.

Trains a decoder-only transformer (reduced Qwen2-family block structure) on
the synthetic bigram-structured token stream for a few hundred rounds, with
checkpointing. Presets:

    --preset tiny   ~3M params,  m=4,  runs in ~2 min on CPU (default)
    --preset 100m   ~100M params, m=8 — the "real" config for a TPU slice
                    (runs on CPU too, just slowly; same code path)

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import checkpoint
from repro.api import Experiment, TokenStream
from repro.config import AlgoConfig, AttentionConfig, ModelConfig, OptimizerConfig
from repro.optim import schedules

PRESETS = dict(
    tiny=dict(layers=4, d_model=128, d_ff=512, heads=4, kv=2, vocab=512, m=4, batch=8, seq=128),
    m100=dict(layers=12, d_model=768, d_ff=3072, heads=12, kv=4, vocab=32000, m=8, batch=8, seq=512),
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=list(PRESETS), default="tiny")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--alpha", type=float, default=0.6)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt.npz")
    args = ap.parse_args()
    p = PRESETS[args.preset if args.preset != "100m" else "m100"]

    cfg = ModelConfig(
        name=f"lm-{args.preset}",
        family="dense",
        num_layers=p["layers"],
        d_model=p["d_model"],
        d_ff=p["d_ff"],
        vocab_size=p["vocab"],
        attention=AttentionConfig(num_heads=p["heads"], num_kv_heads=p["kv"], head_dim=p["d_model"] // p["heads"], qkv_bias=True),
        dtype="float32",
    )
    exp = Experiment(
        arch=cfg,
        strategy=AlgoConfig(name="overlap_local_sgd", tau=args.tau, alpha=args.alpha, anchor_beta=0.7),
        optimizer=OptimizerConfig(name="adamw", lr=3e-3, weight_decay=0.01),
        schedule=schedules.cosine(3e-3, warmup_steps=20, total_steps=args.steps),
        data=TokenStream(batch_per_worker=p["batch"], seq_len=p["seq"]),
        workers=p["m"],
    )
    print(f"model: {exp.num_params/1e6:.1f}M params, {p['m']} Overlap-Local-SGD workers, tau={args.tau}")

    import time

    t0 = time.time()
    res = exp.fit(
        steps=args.steps,
        log=lambda r, loss: r % 10 == 0 and print(f"round {r:4d}  loss {loss:.4f}  ({time.time()-t0:.0f}s)"),
    )
    checkpoint.save(args.ckpt, exp.state)
    print(f"done: final loss {res.final_loss:.4f} "
          f"(vs ln(V)={np.log(p['vocab']):.2f} random); checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
