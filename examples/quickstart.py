"""Quickstart: Overlap-Local-SGD vs fully-synchronous SGD on 16 simulated
workers (classification task) through the ``repro.api.Experiment`` facade,
~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py [--tau 2] [--alpha 0.6] [--steps 600]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import ClassificationSpec, Experiment
from repro.config import AlgoConfig, OptimizerConfig
from repro.optim import schedules


def run(algo_name: str, tau: int, alpha: float, steps: int, m: int = 16) -> None:
    exp = Experiment(
        task=ClassificationSpec(n=30000, holdout=4000, batch_per_worker=32),
        strategy=AlgoConfig(name=algo_name, tau=tau, alpha=alpha, anchor_beta=0.7),
        optimizer=OptimizerConfig(name="sgd", lr=0.1, momentum=0.9, nesterov=True),
        schedule=schedules.warmup_step_decay(0.1, 20, (steps // 2,)),
        workers=m,
    )
    rounds = steps // exp.tau
    every = max(1, rounds // 10)
    res = exp.fit(steps=steps, log=lambda r, loss: r % every == 0 and print(f"  round {r:4d}  loss {loss:.4f}"))
    acc = exp.evaluate()["test_acc"]
    print(f"{algo_name} (tau={exp.tau}, alpha={alpha}): test acc {acc:.4f}  [{res.wall_s:.1f}s]\n")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--alpha", type=float, default=0.6)
    ap.add_argument("--steps", type=int, default=600)
    args = ap.parse_args()
    print("== fully-synchronous SGD baseline ==")
    run("sync_sgd", 1, 0.0, args.steps)
    print("== Overlap-Local-SGD (the paper's algorithm) ==")
    run("overlap_local_sgd", args.tau, args.alpha, args.steps)
