"""Quickstart: Overlap-Local-SGD vs fully-synchronous SGD on 16 simulated
workers (classification task), ~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py [--tau 2] [--alpha 0.6] [--steps 600]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import AlgoConfig, OptimizerConfig
from repro.core import make_algorithm
from repro.data import WorkerBatcher, make_classification, partition_iid
from repro.models.classifier import accuracy, init_mlp, mlp_loss
from repro.optim import from_config as opt_from_config, schedules
from repro.training import consensus_params, make_round_step, make_train_state


def run(algo_name: str, tau: int, alpha: float, steps: int, m: int = 16) -> None:
    data = make_classification(n=30000, dim=64, num_classes=10, noise=3.0, seed=0)
    test_x, test_y = jnp.asarray(data.x[:4000]), jnp.asarray(data.y[:4000])
    train = type(data)(x=data.x[4000:], y=data.y[4000:], num_classes=10)
    parts = partition_iid(train, m)

    algo = make_algorithm(AlgoConfig(name=algo_name, tau=tau, alpha=alpha, anchor_beta=0.7))
    tau = algo.tau
    opt = opt_from_config(OptimizerConfig(name="sgd", lr=0.1, momentum=0.9, nesterov=True))
    params, axes = init_mlp(jax.random.PRNGKey(0), 64, 10)
    state = make_train_state(params, m, opt, algo, axes)
    step = jax.jit(make_round_step(mlp_loss, opt, algo, schedules.warmup_step_decay(0.1, 20, (steps // 2,)), axes))
    batcher = WorkerBatcher(train, parts, 32)

    t0 = time.time()
    for r in range(steps // tau):
        micro = [tuple(map(jnp.asarray, next(batcher))) for _ in range(tau)]
        rb = jax.tree.map(lambda *xs: jnp.stack(xs), *micro)
        state, ms = step(state, rb)
        if r % max(1, (steps // tau) // 10) == 0:
            print(f"  round {r:4d}  loss {float(np.asarray(ms['loss']).mean()):.4f}")
    p = jax.tree.map(lambda t: t.astype(jnp.float32), consensus_params(state))
    acc = accuracy(p, test_x, test_y)
    print(f"{algo_name} (tau={tau}, alpha={alpha}): test acc {acc:.4f}  [{time.time()-t0:.1f}s]\n")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--alpha", type=float, default=0.6)
    ap.add_argument("--steps", type=int, default=600)
    args = ap.parse_args()
    print("== fully-synchronous SGD baseline ==")
    run("sync_sgd", 1, 0.0, args.steps)
    print(f"== Overlap-Local-SGD (the paper's algorithm) ==")
    run("overlap_local_sgd", args.tau, args.alpha, args.steps)
