"""Paper §4 non-IID experiment in miniature: each worker's data is 64%
single-class. Shows Overlap-Local-SGD staying stable at large τ where
CoCoD-SGD degrades (Table 2's phenomenon). All four runs share one dataset
split through ``ClassificationSpec(splits=...)``.

    PYTHONPATH=src python examples/noniid_stability.py [--tau 24]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.api import ClassificationSpec, Experiment
from repro.config import AlgoConfig, OptimizerConfig
from repro.data import make_classification_splits, skewness
from repro.optim import schedules


def run(algo_name: str, tau: int, steps: int, splits, m: int) -> None:
    exp = Experiment(
        task=ClassificationSpec(splits=splits, batch_per_worker=32),
        strategy=AlgoConfig(name=algo_name, tau=tau, alpha=0.6, anchor_beta=0.7),
        optimizer=OptimizerConfig(name="sgd", lr=0.1, momentum=0.9, nesterov=True),
        schedule=schedules.warmup_step_decay(0.1, 20, (steps // 2,)),
        workers=m,
    )
    res = exp.fit(steps=steps)
    acc = exp.evaluate()["test_acc"]
    tail = np.mean(res.losses[-10:])
    print(f"{algo_name:20s} tau={exp.tau:3d}  final_loss={tail:8.4f}  test_acc={acc:.4f}  "
          f"{'UNSTABLE' if not np.isfinite(tail) or tail > res.losses[0] else 'stable'}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tau", type=int, default=24)
    ap.add_argument("--steps", type=int, default=720)
    args = ap.parse_args()
    m = 16
    splits = make_classification_splits(m, n=30000, holdout=4000, noniid=True, skew=0.64)
    print(f"non-IID partitions: mean majority-class fraction = {skewness(splits.train, splits.parts):.2f}\n")
    for algo in ("sync_sgd", "cocod", "easgd", "overlap_local_sgd"):
        run(algo, args.tau if algo not in ("sync_sgd",) else 1, args.steps, splits, m)
