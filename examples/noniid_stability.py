"""Paper §4 non-IID experiment in miniature: each worker's data is 64%
single-class. Shows Overlap-Local-SGD staying stable at large τ where
CoCoD-SGD degrades (Table 2's phenomenon).

    PYTHONPATH=src python examples/noniid_stability.py [--tau 24]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import AlgoConfig, OptimizerConfig
from repro.core import make_algorithm
from repro.data import WorkerBatcher, make_classification, partition_noniid, skewness
from repro.models.classifier import accuracy, init_mlp, mlp_loss
from repro.optim import from_config as opt_from_config, schedules
from repro.training import consensus_params, make_round_step, make_train_state


def run(algo_name: str, tau: int, steps: int, data, test, parts, m: int) -> None:
    algo = make_algorithm(AlgoConfig(name=algo_name, tau=tau, alpha=0.6, anchor_beta=0.7))
    opt = opt_from_config(OptimizerConfig(name="sgd", lr=0.1, momentum=0.9, nesterov=True))
    params, axes = init_mlp(jax.random.PRNGKey(0), 64, 10)
    state = make_train_state(params, m, opt, algo, axes)
    step = jax.jit(make_round_step(mlp_loss, opt, algo, schedules.warmup_step_decay(0.1, 20, (steps // 2,)), axes))
    batcher = WorkerBatcher(data, parts, 32)
    losses = []
    for r in range(steps // tau):
        micro = [tuple(map(jnp.asarray, next(batcher))) for _ in range(tau)]
        state, ms = step(state, jax.tree.map(lambda *xs: jnp.stack(xs), *micro))
        losses.append(float(np.asarray(ms["loss"]).mean()))
    p = jax.tree.map(lambda t: t.astype(jnp.float32), consensus_params(state))
    acc = accuracy(p, jnp.asarray(test.x), jnp.asarray(test.y))
    tail = np.mean(losses[-10:])
    print(f"{algo_name:20s} tau={tau:3d}  final_loss={tail:8.4f}  test_acc={acc:.4f}  "
          f"{'UNSTABLE' if not np.isfinite(tail) or tail > losses[0] else 'stable'}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tau", type=int, default=24)
    ap.add_argument("--steps", type=int, default=720)
    args = ap.parse_args()
    m = 16
    data = make_classification(n=30000, dim=64, num_classes=10, noise=3.0, seed=0)
    test = type(data)(x=data.x[:4000], y=data.y[:4000], num_classes=10)
    train = type(data)(x=data.x[4000:], y=data.y[4000:], num_classes=10)
    parts = partition_noniid(train, m, skew=0.64)
    print(f"non-IID partitions: mean majority-class fraction = {skewness(train, parts):.2f}\n")
    for algo in ("sync_sgd", "cocod", "easgd", "overlap_local_sgd"):
        run(algo, args.tau if algo not in ("sync_sgd",) else 1, args.steps, train, test, parts, m)
