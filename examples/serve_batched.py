"""Batched serving example: a reduced Qwen2 model behind the fixed-slot
continuous-batching engine, plus a single long-context decode with the
sliding-window ring buffer.

    PYTHONPATH=src python examples/serve_batched.py
"""
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_arch
from repro.models import transformer as T
from repro.serving import BatchedEngine, generate

rng = np.random.default_rng(0)

# 1) batched request serving
cfg = get_arch("qwen2-7b").model.reduced()
params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
engine = BatchedEngine(cfg, params, slots=4)
for i in range(8):
    prompt = rng.integers(0, cfg.vocab_size, size=(4 + i % 3,)).astype(np.int32)
    engine.submit(f"user-{i}", prompt, max_new=8)
t0 = time.time()
results = engine.run()
print(f"served {len(results)} requests in {time.time()-t0:.1f}s")
for rid in sorted(results):
    print(f"  {rid}: {results[rid].tolist()}")

# 2) long-context decode with a sliding-window ring buffer (h2o-danube style)
cfg2 = get_arch("h2o-danube-1.8b").model.reduced()
cfg2 = dataclasses.replace(cfg2, attention=dataclasses.replace(cfg2.attention, sliding_window=16))
params2, _ = T.init_model(cfg2, jax.random.PRNGKey(1))
prompt = jnp.asarray(rng.integers(0, cfg2.vocab_size, (1, 12)), jnp.int32)
out = generate(cfg2, params2, prompt, max_new=32)  # generates far past the window
print(f"\nSWA long generation (window 16, 12+32 tokens): {out[0][:16].tolist()}...")

# 3) recurrent-state decode (RWKV6: O(1) memory in sequence length)
cfg3 = get_arch("rwkv6-7b").model.reduced()
params3, _ = T.init_model(cfg3, jax.random.PRNGKey(2))
out3 = generate(cfg3, params3, prompt % cfg3.vocab_size, max_new=16)
print(f"RWKV6 recurrent decode: {out3[0].tolist()}")
