"""Paper Fig. 4(b)/5(b): communication-to-computation ratio.

Claim (§4): at τ=2, Overlap-Local-SGD reduces the ratio from 34.6% (fully-
sync) to 1.5%. We reproduce it with the calibrated runtime model, then
re-derive the same quantity for the LLM workloads from the dry-run's
collective bytes (the beyond-paper part: the paper's comm constants replaced
by roofline terms from the compiled artifacts)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import csv_row
from repro.core.runtime_model import RuntimeConfig, epoch_summary

STEPS_PER_EPOCH = 24
RT = RuntimeConfig(m=16, t_step=4.6 / STEPS_PER_EPOCH, t_comm=1.5 / STEPS_PER_EPOCH, t_handshake=0.02)


def run(quick: bool = False):
    rows = []
    for algo, tau in (("sync_sgd", 1), ("powersgd", 1), ("local_sgd", 2), ("local_sgd", 8), ("overlap_local_sgd", 2), ("overlap_local_sgd", 8), ("cocod", 2)):
        s = epoch_summary(algo, tau, STEPS_PER_EPOCH, RT)
        rows.append(dict(kind="paper_calibrated", **s))
    # dry-run-derived: exposed-comm ratio for the train_4k pairs
    for path in sorted(glob.glob("experiments/dryrun/*train_4k*16-16.json")):
        d = json.load(open(path))
        roof = d["roofline"]
        compute = max(roof["compute_s"], roof["memory_s"])  # per-round critical path proxy
        comm = roof["collective_s"]
        boundary_coll = d.get("composed", {}).get("parts", {}).get("boundary", {}).get("coll", 0)
        rows.append(
            dict(
                kind="dryrun",
                algo=d.get("algorithm", "overlap_local_sgd"),
                arch=d["arch"],
                comm_ratio=comm / max(compute, 1e-12),
                anchor_bytes=boundary_coll,
                epoch_time=None,
            )
        )
    return rows


def main(emit):
    rows = run()
    for r in rows:
        if r["kind"] == "paper_calibrated":
            emit(
                csv_row(
                    f"fig4/{r['algo']}/tau{r['tau']}",
                    r["epoch_time"] * 1e6,
                    f"comm_ratio={r['comm_ratio']:.4f};exposed_comm_s={r['exposed_comm']:.3f}",
                )
            )
        else:
            emit(csv_row(f"fig4/dryrun/{r['arch']}", 0.0, f"collective_vs_dominant={r['comm_ratio']:.4f};anchor_coll_bytes={r['anchor_bytes']:.3e}"))
    sync = next(r for r in rows if r.get("algo") == "sync_sgd")
    ours = next(r for r in rows if r.get("algo") == "overlap_local_sgd" and r.get("tau") == 2)
    emit(
        csv_row(
            "fig4/check/headline",
            0.0,
            f"sync_ratio={sync['comm_ratio']:.3f}(paper 0.346);overlap_tau2_ratio={ours['comm_ratio']:.3f}(paper 0.015)",
        )
    )
    return rows
