"""Kernel microbenchmarks.

On this CPU-only container the Pallas kernels execute in interpret mode
(orders of magnitude slower than compiled; correctness only), so the timed
numbers are the jnp reference paths under jit — the same code the dry-run
lowers — plus derived arithmetic throughput. The Pallas variants are timed
once in interpret mode purely to prove the harness runs them end-to-end.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.kernels.anchor_mix import ref as am_ref
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.rmsnorm import ref as rms_ref
from repro.kernels.rwkv6_wkv import ref as wkv_ref
from repro.kernels.ssd_scan import ref as ssd_ref


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    rows = []

    b, s, h, d = 2, 512, 8, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, 2, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, 2, d)).astype(np.float32))
    f = jax.jit(lambda q, k, v: fa_ref.chunked_mha(q, k, v, block_q=128, block_k=128))
    us = _time(f, q, k, v)
    flops = 4 * b * h * s * s * d
    rows.append(("kernel/flash_attention_chunked_512", us, f"gflops={flops/us/1e3:.1f}"))

    x = jnp.asarray(rng.normal(size=(4096, 2048)).astype(np.float32))
    sc = jnp.asarray(rng.normal(size=(2048,)).astype(np.float32))
    us = _time(jax.jit(rms_ref.rmsnorm), x, sc)
    rows.append(("kernel/rmsnorm_4096x2048", us, f"gbps={(x.size*2*4)/us/1e3:.1f}"))

    xs = jnp.asarray(rng.normal(size=(2, 256, 8, 32)).astype(np.float32))
    dt = jnp.asarray(np.abs(rng.normal(size=(2, 256, 8))).astype(np.float32))
    A = jnp.asarray(-np.abs(rng.normal(size=(8,))).astype(np.float32))
    B = jnp.asarray(rng.normal(size=(2, 256, 1, 16)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(2, 256, 1, 16)).astype(np.float32))
    Dp = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))
    f = jax.jit(lambda *a: ssd_ref.ssd_chunked(*a, chunk=64)[0])
    us = _time(f, xs, dt, A, B, C, Dp)
    rows.append(("kernel/ssd_scan_256", us, "chunk=64"))

    r = jnp.asarray(rng.normal(size=(2, 256, 4, 32)).astype(np.float32))
    kk = jnp.asarray(rng.normal(size=(2, 256, 4, 32)).astype(np.float32))
    vv = jnp.asarray(rng.normal(size=(2, 256, 4, 32)).astype(np.float32))
    w = jnp.asarray(0.3 + 0.69 * rng.random((2, 256, 4, 32)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    f = jax.jit(lambda *a: wkv_ref.wkv_chunked(*a, chunk=32)[0])
    us = _time(f, r, kk, vv, w, u)
    rows.append(("kernel/rwkv6_wkv_256", us, "chunk=32"))

    xa = jnp.asarray(rng.normal(size=(1 << 20,)).astype(np.float32))
    za = jnp.asarray(rng.normal(size=(1 << 20,)).astype(np.float32))
    f = jax.jit(lambda x, z: am_ref.anchor_mix(x, z, 0.6))
    us = _time(f, xa, za)
    rows.append(("kernel/anchor_mix_1M", us, f"gbps={(3*xa.size*4)/us/1e3:.1f}"))
    return rows


def main(emit):
    for name, us, derived in run():
        emit(csv_row(name, us, derived))
