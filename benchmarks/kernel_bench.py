"""Kernel microbenchmarks.

On this CPU-only container the Pallas kernels execute in interpret mode
(orders of magnitude slower than compiled; correctness only), so the timed
numbers are the jnp reference paths under jit — the same code the dry-run
lowers — plus derived arithmetic throughput. The Pallas variants are timed
once in interpret mode purely to prove the harness runs them end-to-end.

The ``boundary/*`` rows time one full Overlap-Local-SGD round boundary
(eqs. 4–5 + anchor momentum) over a many-leaf synthetic parameter tree, on
the packed flat-plane path vs the per-leaf reference path — the perf claim
of the packed parameter plane (ISSUE 2), persisted into BENCH_kernels.json
by benchmarks/run.py.

The ``localstep/*`` rows time one local optimizer step the same two ways:
per-leaf (vmapped tree optimizer, O(leaves) ops) vs packed (plane-resident
training — flat bucket cotangents into one fused ``kernels/opt_step``
update per dtype bucket). The ``fwdstep``/``gradflow`` rows time the AD
chain itself: forward/grad with the plane as the primal (ParamView window
reads, flat cotangents) vs the retired per-step pack/unpack chain (unpack →
tree grad → DUS-scatter the gradient pytree back onto the plane).

The ``boundary/<arch>/*`` rows time the round boundary per architecture on
the 8-device dry-run (host) smoke mesh via a subprocess (the device-count
XLA flag must be set before jax initializes) — sharded lowering included,
so the per-arch packed-vs-per-leaf trajectory tracks what the dry-run
actually compiles. ``REPRO_BENCH_QUICK=1`` shrinks shapes/iters/arch count
for the CI smoke step.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import QUICK, csv_row
from repro.config import AlgoConfig
from repro.control import consensus_drift
from repro.core import make_strategy
from repro.kernels.consensus_probe import ops as probe_ops
from repro.kernels.anchor_mix import ref as am_ref
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.rmsnorm import ref as rms_ref
from repro.kernels.rwkv6_wkv import ref as wkv_ref
from repro.kernels.ssd_scan import ref as ssd_ref
from repro.optim import adamw, sgd
from repro.parallel import offload as off
from repro.parallel.packing import ParamView, pack, unpack


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def _synthetic_tree(rng, n_layers: int, width: int):
    """A transformer-ish parameter tree: n_layers × {matrix, vector, norm}
    (+ embedding) — ≥ 50 leaves of mixed, mostly lane-ragged shapes."""
    p = {"embed": jnp.asarray(rng.normal(size=(width * 4, width)), np.float32)}
    for i in range(n_layers):
        p[f"layer{i}"] = {
            "w": jnp.asarray(rng.normal(size=(width, width)), np.float32),
            "b": jnp.asarray(rng.normal(size=(width,)), np.float32),
            "scale": jnp.asarray(rng.normal(size=(width + 1,)), np.float32),
        }
    return p


def boundary_rows(quick: bool = False, m: int = 4, n_layers: int = 80, width: int = 48):
    """Packed plane vs per-leaf reference for one full round boundary, on a
    production-depth tree (80 layers → 241 leaves): the regime the packed
    plane targets, where per-leaf dispatch dominates the memory sweeps."""
    if quick:
        n_layers, width = 40, 32  # same dispatch-bound regime, 121 leaves, ~4× less data
    rng = np.random.default_rng(0)
    params = _synthetic_tree(rng, n_layers, width)
    n_leaves = len(jax.tree.leaves(params))
    n_elems = sum(l.size for l in jax.tree.leaves(params))
    x = jax.tree.map(lambda t: jnp.tile(t[None], (m,) + (1,) * t.ndim), params)
    x = jax.tree.map(
        lambda t: t + 0.01 * jnp.arange(m, dtype=np.float32).reshape((m,) + (1,) * (t.ndim - 1)), x
    )
    # useful bytes per boundary (f32, fused-pass model: read x+z+v, write
    # x+z+v) — the SAME basis for both rows, so effective_gbps is directly
    # comparable across modes (higher = better); the per-leaf path actually
    # moves more than this (it re-reads x between sweeps)
    nbytes = (2 * m * n_elems + 4 * n_elems) * 4

    # worker-stacked plane bytes of the timed x — the same quantity the
    # dry-run records as plane.x_buffer_bytes, keying these rows against
    # dry-run JSONs (EXPERIMENTS.md §Perf)
    from repro.parallel.packing import pack

    plane_bytes = jax.eval_shape(lambda t: pack(t, lead=1), x).nbytes

    rows = []
    us_by_mode = {}
    for packed in (True, False):
        cfg = AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=0.7, packed=packed)
        strat = make_strategy(cfg)
        vars_ = strat.init_vars(x, None)
        inflight = strat.init_inflight(x, vars_, None)
        fn = jax.jit(lambda xx, vv, ff: strat.boundary_round(xx, vv, ff, None))
        us = _time(fn, x, vars_, inflight, iters=3 if quick else 20)
        us_by_mode[packed] = us
        mode = "packed" if packed else "perleaf"
        rows.append(
            (
                f"boundary/overlap_momentum_{mode}_{n_leaves}leaf",
                us,
                f"effective_gbps={nbytes/us/1e3:.1f} leaves={n_leaves} elems={n_elems} m={m} "
                f"strategy={strat.name} plane_bytes={plane_bytes}",
            )
        )
    rows.append(
        (
            f"boundary/packed_speedup_{n_leaves}leaf",
            us_by_mode[True],
            f"speedup_x={us_by_mode[False]/us_by_mode[True]:.2f} baseline_us={us_by_mode[False]:.1f}",
        )
    )
    return rows


def local_step_rows(quick: bool = False, m: int = 4, n_layers: int = 80, width: int = 48):
    """Packed vs per-leaf local optimizer step at the production-depth
    241-leaf config.

    Both modes run the full per-step chain the round engine executes after
    the backward pass — per-leaf: vmapped tree step; packed (plane-resident
    training): gradients already live as flat bucket cotangents, so the
    chain is just one fused update per dtype bucket — plus an identical
    elementwise gradient oracle standing in for the backward output (the
    fwdstep/gradflow rows time the AD chain itself)."""
    if quick:
        n_layers, width = 40, 32
    rng = np.random.default_rng(0)
    params = _synthetic_tree(rng, n_layers, width)
    n_leaves = len(jax.tree.leaves(params))
    n_elems = sum(l.size for l in jax.tree.leaves(params))
    x = jax.tree.map(lambda t: jnp.tile(t[None], (m,) + (1,) * t.ndim), params)
    lr = jnp.float32(0.05)
    iters = 5 if quick else 30

    # useful f32 bytes per fused step (same basis both modes): sgd reads
    # x,g,mom and writes x,mom; adamw reads x,g,mu,nu and writes x,mu,nu
    opts = {
        "sgd": (sgd(momentum=0.9, nesterov=True, weight_decay=1e-4), 5),
        "adamw": (adamw(weight_decay=1e-4), 7),
    }
    rows = []
    for opt_name, (opt, passes) in opts.items():
        nbytes = passes * m * n_elems * 4

        def f_leaf(o, xx):
            gg = jax.tree.map(lambda t: t * 0.01, xx)
            return jax.vmap(lambda oi, xi, gi: opt.step(oi, xi, gi, lr))(o, xx, gg)

        def f_packed(o, pxx):
            # plane-resident: the backward hands over flat bucket cotangents
            # directly — no pack/unpack in the step chain
            gg = jax.tree.map(lambda b: b * 0.01, pxx)
            return opt.step_packed(o, pxx, gg, lr)

        px = pack(x, lead=1)
        us_by_mode = {}
        for mode, fn, args in (
            ("packed", jax.jit(f_packed), (opt.init_packed(px), px)),
            ("perleaf", jax.jit(f_leaf), (jax.vmap(opt.init)(x), x)),
        ):
            us = _time(fn, *args, iters=iters)
            us_by_mode[mode] = us
            rows.append(
                (
                    f"localstep/{opt_name}_{mode}_{n_leaves}leaf",
                    us,
                    f"effective_gbps={nbytes/us/1e3:.1f} leaves={n_leaves} elems={n_elems} m={m}",
                )
            )
        rows.append(
            (
                f"localstep/{opt_name}_packed_speedup_{n_leaves}leaf",
                us_by_mode["packed"],
                f"speedup_x={us_by_mode['perleaf']/us_by_mode['packed']:.2f} baseline_us={us_by_mode['perleaf']:.1f}",
            )
        )
    return rows


def plane_rows(quick: bool = False, m: int = 4, n_layers: int = 80, width: int = 48):
    """``fwdstep``/``gradflow`` rows (plane-resident training): forward pass
    and gradient computation with the packed plane as the primal — params
    read through ParamView windows, cotangents arriving as flat per-bucket
    buffers — vs the per-step pack/unpack chain (unpack the plane, grad the
    pytree, DUS-scatter the gradient tree back onto the plane) that the
    round engine ran before the plane went end-to-end. Same 241-leaf
    synthetic tree as the boundary/localstep rows; the loss touches every
    leaf elementwise so both directions sweep the whole plane."""
    if quick:
        n_layers, width = 40, 32
    rng = np.random.default_rng(0)
    params = _synthetic_tree(rng, n_layers, width)
    n_leaves = len(jax.tree.leaves(params))
    n_elems = sum(l.size for l in jax.tree.leaves(params))
    x = jax.tree.map(lambda t: jnp.tile(t[None], (m,) + (1,) * t.ndim), params)
    px = pack(x, lead=1)
    iters = 5 if quick else 30

    def tree_loss(p):  # touches every leaf; stands in for the model forward
        return sum(0.5 * jnp.sum(jnp.square(l)) for l in jax.tree.leaves(p))

    def plane_loss(pxx):  # the engine's formulation: stacked view, vmapped loss
        view = ParamView(pxx).materialize()
        return jnp.sum(jax.vmap(tree_loss)(view))

    fwd = {
        "plane": jax.jit(plane_loss),
        "packunpack": jax.jit(lambda pxx: jnp.sum(jax.vmap(tree_loss)(unpack(pxx)))),
    }
    grad = {
        "plane": jax.jit(jax.grad(plane_loss)),
        "packunpack": jax.jit(
            lambda pxx: pack(jax.vmap(jax.grad(tree_loss))(unpack(pxx)), layout=pxx.layout, lead=1)
        ),
    }
    rows = []
    for group, fns, nbytes in (
        ("fwdstep", fwd, m * n_elems * 4),  # read the plane once
        ("gradflow", grad, 2 * m * n_elems * 4),  # read plane, write cotangent plane
    ):
        us_by_mode = {}
        for mode, fn in fns.items():
            us = _time(fn, px, iters=iters)
            us_by_mode[mode] = us
            rows.append(
                (
                    f"{group}/{mode}_{n_leaves}leaf",
                    us,
                    f"effective_gbps={nbytes/us/1e3:.1f} leaves={n_leaves} elems={n_elems} m={m}",
                )
            )
        rows.append(
            (
                f"{group}/plane_speedup_{n_leaves}leaf",
                us_by_mode["plane"],
                f"speedup_x={us_by_mode['packunpack']/us_by_mode['plane']:.2f} "
                f"baseline_us={us_by_mode['packunpack']:.1f}",
            )
        )
    return rows


def consensus_probe_rows(quick: bool = False, m: int = 4, n_layers: int = 80, width: int = 48):
    """Adaptive-τ consensus probe (DESIGN.md §6) on the production-depth
    synthetic tree.

    ``consensusprobe/packed_*``: the plane probe (one sweep over the flat
    bucket buffers) vs the unfused per-leaf two-pass reduction
    (``repro.control.consensus_drift``: mean + squared-deviation reductions
    per leaf, O(leaves) dispatch) — the controller's measurement cost when
    the strategy has no boundary kernel to fuse into.

    ``consensusprobe/boundary_*``: one full Overlap-Local-SGD round boundary
    with and without ``probe=True`` — the fused-probe overhead on the path
    adaptive fits actually run (the partial sums ride the pullback kernels,
    so the expected overhead is the extra write of a (2, 128) buffer)."""
    if quick:
        n_layers, width = 40, 32
    rng = np.random.default_rng(0)
    params = _synthetic_tree(rng, n_layers, width)
    n_leaves = len(jax.tree.leaves(params))
    n_elems = sum(l.size for l in jax.tree.leaves(params))
    x = jax.tree.map(lambda t: jnp.tile(t[None], (m,) + (1,) * t.ndim), params)
    x = jax.tree.map(
        lambda t: t + 0.01 * jnp.arange(m, dtype=np.float32).reshape((m,) + (1,) * (t.ndim - 1)), x
    )
    px = pack(x, lead=1)
    iters = 5 if quick else 30
    nbytes = m * n_elems * 4  # one f32 sweep of the stacked plane

    rows = []
    us_probe = _time(jax.jit(probe_ops.packed_probe), px, iters=iters)
    us_leaf = _time(jax.jit(consensus_drift), x, iters=iters)
    rows.append(
        (
            f"consensusprobe/packed_probe_{n_leaves}leaf",
            us_probe,
            f"gbps={nbytes/us_probe/1e3:.1f} leaves={n_leaves} elems={n_elems} m={m}",
        )
    )
    rows.append(
        (
            f"consensusprobe/perleaf_twopass_{n_leaves}leaf",
            us_leaf,
            f"gbps={nbytes/us_leaf/1e3:.1f} leaves={n_leaves} elems={n_elems} m={m}",
        )
    )
    rows.append(
        (
            f"consensusprobe/packed_speedup_{n_leaves}leaf",
            us_probe,
            f"speedup_x={us_leaf/us_probe:.2f} baseline_us={us_leaf:.1f}",
        )
    )

    cfg = AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=0.7, packed=True)
    strat = make_strategy(cfg)
    vars_ = strat.init_vars(px, None)
    inflight = strat.init_inflight(px, vars_, None)
    us_by_probe = {}
    for probe in (False, True):
        fn = jax.jit(lambda xx, vv, ff: strat.boundary_round(xx, vv, ff, None, probe=probe))
        us_by_probe[probe] = _time(fn, px, vars_, inflight, iters=iters)
    rows.append(
        (
            f"consensusprobe/boundary_plain_{n_leaves}leaf",
            us_by_probe[False],
            f"leaves={n_leaves} elems={n_elems} m={m}",
        )
    )
    rows.append(
        (
            f"consensusprobe/boundary_probed_{n_leaves}leaf",
            us_by_probe[True],
            f"overhead_pct={100*(us_by_probe[True]/us_by_probe[False]-1):.1f} "
            f"baseline_us={us_by_probe[False]:.1f}",
        )
    )
    return rows


def offload_rows(quick: bool = False, m: int = 4, n_layers: int = 80, width: int = 48):
    """Host-offload plane rows (DESIGN.md §9) at the production-depth
    241-leaf config.

    ``offload/stream_*``: the chunked D2H/H2D stream of the packed opt
    state — ``tree_offload`` (chunk + host placement) / ``tree_restore`` —
    plus the raw host-link copy rate (``costprobe.measure_host_bandwidth``),
    the bandwidth the dry-run's offload schedule block is priced with. This
    CPU container has no separate host memory space, so the stream rows
    time the chunking sweeps themselves; the copy rows time the runtime's
    actual copy path.

    ``offload/localstep_*``: one local optimizer step with host-resident
    state (``step_streamed``: double-buffered chunk scan — prefetch chunk
    i+1 while applying i) vs the plane-resident fused step. The ratio is
    the per-step cost the τ window must amortize for the offload plane to
    be free (the dry-run's ``breakeven_tau``)."""
    from repro.launch.costprobe import measure_host_bandwidth

    if quick:
        n_layers, width = 40, 32
    rng = np.random.default_rng(0)
    params = _synthetic_tree(rng, n_layers, width)
    n_leaves = len(jax.tree.leaves(params))
    x = jax.tree.map(lambda t: jnp.tile(t[None], (m,) + (1,) * t.ndim), params)
    px = pack(x, lead=1)
    # 64 KiB chunks: the synthetic bucket is ~0.8 MB, so the stream walks a
    # real multi-chunk grid (~13 chunks) like the production plane does
    chunk_mb = 1 / 16
    plan = off.OffloadPlan.for_layout(px.layout, chunk_mb=chunk_mb)
    pg = jax.tree.map(lambda b: b * 0.01, px)
    lr = jnp.float32(0.05)
    iters = 3 if quick else 20
    n_chunks = int(sum(plan.num_chunks))

    rows = []
    bw = measure_host_bandwidth(nbytes=(8 << 20) if quick else (64 << 20))
    for d, g in (("h2d", bw["h2d_gbps"]), ("d2h", bw["d2h_gbps"])):
        rows.append(
            (
                f"offload/hostlink_copy_{d}",
                bw["probe_bytes"] / (g * 1e3),
                f"gbps={g:.2f} bytes={bw['probe_bytes']}",
            )
        )

    opt0 = sgd(momentum=0.9, nesterov=True, weight_decay=1e-4)
    st = opt0.init_packed(px)
    st_host = off.tree_offload(st, plan)
    sbytes = off.host_nbytes(st_host)
    us_d2h = _time(jax.jit(lambda s: off.tree_offload(s, plan)), st, iters=iters)
    us_h2d = _time(jax.jit(off.tree_restore), st_host, iters=iters)
    for d, us in (("d2h", us_d2h), ("h2d", us_h2d)):
        rows.append(
            (
                f"offload/stream_{d}_{n_leaves}leaf",
                us,
                f"gbps={sbytes/us/1e3:.1f} chunks={n_chunks} chunk_mb={chunk_mb} bytes={sbytes} m={m}",
            )
        )

    for opt_name, opt in (("sgd", opt0), ("adamw", adamw(weight_decay=1e-4))):
        st = opt.init_packed(px)
        st_h = off.tree_offload(st, plan)
        us_res = _time(jax.jit(lambda o, xx: opt.step_packed(o, xx, pg, lr)), st, px, iters=iters)
        us_str = _time(jax.jit(lambda o, xx: opt.step_streamed(o, xx, pg, lr)), st_h, px, iters=iters)
        rows.append(
            (
                f"offload/localstep_{opt_name}_resident_{n_leaves}leaf",
                us_res,
                f"leaves={n_leaves} m={m}",
            )
        )
        rows.append(
            (
                f"offload/localstep_{opt_name}_offloaded_{n_leaves}leaf",
                us_str,
                f"overhead_x={us_str/us_res:.2f} baseline_us={us_res:.1f} chunks={n_chunks} m={m}",
            )
        )
    return rows


_ARCH_BOUNDARY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import jax, jax.numpy as jnp
from repro.config import AlgoConfig, get_arch, InputShape
from repro.core import make_strategy
from repro.launch import specs
from repro.launch.mesh import make_smoke_mesh
from repro.models import transformer as T
from repro.parallel import mesh_context

arch, iters = "{arch}", {iters}
mesh = make_smoke_mesh()
cfg = get_arch(arch).model.reduced()
shape = InputShape("small_train", seq_len=32, global_batch=8, mode="train")
with mesh_context(mesh, specs.rules_for(shape)):
    params, axes = T.init_model(cfg, jax.random.PRNGKey(0))
    x = jax.tree.map(lambda t: jnp.tile(t[None], (2,) + (1,) * t.ndim), params)
    n_leaves = len(jax.tree.leaves(params))
    us_by_mode = {{}}
    for packed in (True, False):
        acfg = AlgoConfig(name="overlap_local_sgd", tau=2, alpha=0.6, anchor_beta=0.7, packed=packed)
        strat = make_strategy(acfg)
        vars_ = strat.init_vars(x, axes)
        inflight = strat.init_inflight(x, vars_, axes)
        fn = jax.jit(lambda xx, vv, ff: strat.boundary_round(xx, vv, ff, axes))
        out = fn(x, vars_, inflight)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(x, vars_, inflight)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / iters * 1e6
        mode = "packed" if packed else "perleaf"
        us_by_mode[packed] = us
        print(f"ROW boundary/{arch}/overlap_momentum_" + mode + f",{{us:.1f}},leaves={{n_leaves}} mesh=2x2x2 note=host_sim")
    # NOTE: on the host-simulated mesh collectives run on CPU threads and the
    # fully-sharded anchor plane pays resharding a real interconnect hides, so
    # packed can lose here; the row tracks the dry-run-mesh trajectory (e.g.
    # for the jax>=0.5 partial-sharding re-evaluation), not TPU-relative perf.
    print(f"ROW boundary/{arch}/packed_speedup,{{us_by_mode[True]:.1f}},"
          f"speedup_x={{us_by_mode[False]/us_by_mode[True]:.2f}} baseline_us={{us_by_mode[False]:.1f}} note=host_sim")
"""


def arch_boundary_rows(quick: bool = False):
    """Per-arch round-boundary timings on the 8-device dry-run (host) smoke
    mesh — ROADMAP item. Subprocess per arch: the device-count flag must be
    set before jax initializes, and the bench process must stay
    single-device for the other rows."""
    archs = ["h2o-danube-1.8b"] if quick else ["h2o-danube-1.8b", "qwen2-7b"]
    iters = 3 if quick else 10
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.pop("XLA_FLAGS", None)
    rows = []
    for arch in archs:
        script = _ARCH_BOUNDARY_SCRIPT.format(arch=arch, iters=iters)
        try:
            proc = subprocess.run(
                [sys.executable, "-c", script], env=env, capture_output=True, text=True, timeout=900
            )
        except subprocess.TimeoutExpired:
            rows.append((f"boundary/{arch}/error", 0.0, "timeout"))
            continue
        if proc.returncode != 0:
            # keep a trimmed stderr tail in the derived field (commas would
            # break the CSV/JSON row parsing) so CI failures are debuggable
            tail = " ".join(proc.stderr[-300:].replace(",", ";").split())
            rows.append((f"boundary/{arch}/error", 0.0, f"rc={proc.returncode} stderr={tail}"))
            continue
        for line in proc.stdout.splitlines():
            if line.startswith("ROW "):
                name, us, derived = line[4:].split(",", 2)
                rows.append((name, float(us), derived))
    return rows


def run(quick: bool = False):
    quick = quick or QUICK
    rng = np.random.default_rng(0)
    rows = []

    b, s, h, d = 2, 512, 8, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, 2, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, 2, d)).astype(np.float32))
    f = jax.jit(lambda q, k, v: fa_ref.chunked_mha(q, k, v, block_q=128, block_k=128))
    us = _time(f, q, k, v)
    flops = 4 * b * h * s * s * d
    rows.append(("kernel/flash_attention_chunked_512", us, f"gflops={flops/us/1e3:.1f}"))

    x = jnp.asarray(rng.normal(size=(4096, 2048)).astype(np.float32))
    sc = jnp.asarray(rng.normal(size=(2048,)).astype(np.float32))
    us = _time(jax.jit(rms_ref.rmsnorm), x, sc)
    rows.append(("kernel/rmsnorm_4096x2048", us, f"gbps={(x.size*2*4)/us/1e3:.1f}"))

    xs = jnp.asarray(rng.normal(size=(2, 256, 8, 32)).astype(np.float32))
    dt = jnp.asarray(np.abs(rng.normal(size=(2, 256, 8))).astype(np.float32))
    A = jnp.asarray(-np.abs(rng.normal(size=(8,))).astype(np.float32))
    B = jnp.asarray(rng.normal(size=(2, 256, 1, 16)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(2, 256, 1, 16)).astype(np.float32))
    Dp = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))
    f = jax.jit(lambda *a: ssd_ref.ssd_chunked(*a, chunk=64)[0])
    us = _time(f, xs, dt, A, B, C, Dp)
    rows.append(("kernel/ssd_scan_256", us, "chunk=64"))

    r = jnp.asarray(rng.normal(size=(2, 256, 4, 32)).astype(np.float32))
    kk = jnp.asarray(rng.normal(size=(2, 256, 4, 32)).astype(np.float32))
    vv = jnp.asarray(rng.normal(size=(2, 256, 4, 32)).astype(np.float32))
    w = jnp.asarray(0.3 + 0.69 * rng.random((2, 256, 4, 32)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    f = jax.jit(lambda *a: wkv_ref.wkv_chunked(*a, chunk=32)[0])
    us = _time(f, r, kk, vv, w, u)
    rows.append(("kernel/rwkv6_wkv_256", us, "chunk=32"))

    n_mix = 1 << (17 if quick else 20)
    xa = jnp.asarray(rng.normal(size=(n_mix,)).astype(np.float32))
    za = jnp.asarray(rng.normal(size=(n_mix,)).astype(np.float32))
    f = jax.jit(lambda x, z: am_ref.anchor_mix(x, z, 0.6))
    us = _time(f, xa, za)
    label = "1M" if n_mix == 1 << 20 else f"{n_mix >> 10}K"
    rows.append((f"kernel/anchor_mix_{label}", us, f"gbps={(3*xa.size*4)/us/1e3:.1f}"))

    rows.extend(boundary_rows(quick))
    rows.extend(local_step_rows(quick))
    rows.extend(plane_rows(quick))
    rows.extend(consensus_probe_rows(quick))
    rows.extend(offload_rows(quick))
    rows.extend(arch_boundary_rows(quick))
    return rows


def main(emit):
    for name, us, derived in run():
        emit(csv_row(name, us, derived))
