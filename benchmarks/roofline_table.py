"""Aggregate experiments/dryrun/*.json into the §Roofline table."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import csv_row

BOTTLENECK_HINTS = {
    "compute": "increase per-chip work (bigger microbatch) or quantize",
    "memory": "fuse elementwise chains / wider microbatch to raise arithmetic intensity",
    "collective": "shrink anchor payload (reduce-scatter sharding) or raise tau",
}


def rows(dirpath="experiments/dryrun"):
    out = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        d = json.load(open(path))
        r = d["roofline"]
        out.append(
            dict(
                arch=d["arch"],
                shape=d["shape"],
                mesh=d["mesh"],
                variant=d.get("variant", "faithful"),
                algorithm=d.get("algorithm", "-"),
                compute_s=r["compute_s"],
                memory_s=r["memory_s"],
                collective_s=r["collective_s"],
                dominant=r["dominant"],
                useful=d.get("useful_flops_ratio"),
                peak_gb=d["memory"]["peak_per_device"] / 1e9,
                fits=d["memory"].get("fits_hbm_16g"),
            )
        )
    return out


def main(emit):
    for r in rows():
        emit(
            csv_row(
                f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                r[r["dominant"] + "_s"] * 1e6,
                (
                    f"compute_s={r['compute_s']:.4f};memory_s={r['memory_s']:.4f};"
                    f"collective_s={r['collective_s']:.4f};dominant={r['dominant']};"
                    f"useful_flops_ratio={r['useful'] if r['useful'] is None else round(r['useful'],3)};"
                    f"peak_gb={r['peak_gb']:.1f};variant={r['variant']}"
                ),
            )
        )


def markdown_table(dirpath="experiments/dryrun") -> str:
    lines = [
        "| arch | shape | mesh | variant | compute (s) | memory (s) | collective (s) | dominant | MODEL/HLO flops | peak GB/dev | one-line action |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows(dirpath):
        useful = f"{r['useful']:.2f}" if r["useful"] else "-"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['variant']} | {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | **{r['dominant']}** | {useful} | {r['peak_gb']:.1f} | {BOTTLENECK_HINTS[r['dominant']]} |"
        )
    return "\n".join(lines)
