"""Serving throughput: paged continuous batching vs dense solo decoding.

A seeded synthetic many-user trace (ragged prompt lengths and budgets, all
requests queued up front) is served two ways over identical params:

* ``serving/paged/<arch>`` — the paged ``BatchedEngine`` (page-pool KV,
  chunked prefill, joint decode across slots, evict/requeue under pressure);
* ``serving/dense_solo/<arch>`` — the exactness baseline the engine is
  pinned against: per-request ``generate`` over a dense cache, one request
  at a time.

Derived fields: ``tok_s`` (generated tokens per wall-second), ``requests``,
``speedup`` (paged row only). Persisted to BENCH_serving.json by
benchmarks/run.py (quick mode → BENCH_serving_quick.json), the measured
tokens/s row EXPERIMENTS.md §Serving tracks. Numbers are host-CPU: they
order the engines and size the batching win, they are not accelerator
throughput.
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

QUICK = os.environ.get("REPRO_BENCH_QUICK", "0") == "1"

ARCHS = ["qwen2-7b"] if QUICK else ["qwen2-7b", "h2o-danube-1.8b", "deepseek-v3-671b"]
N_REQ = 6 if QUICK else 24
SLOTS = 4
MAX_LEN = 64


def _trace(vocab: int, n: int):
    rng = np.random.default_rng(1234)
    return [
        (
            f"r{i}",
            rng.integers(1, vocab, (int(rng.integers(4, 48)),)).astype(np.int32),
            int(rng.integers(4, 24)),
        )
        for i in range(n)
    ]


def run():
    from repro.config import get_arch
    from repro.models import transformer as T
    from repro.serving import BatchedEngine, generate

    rows = []
    for arch in ARCHS:
        cfg = dataclasses.replace(get_arch(arch).model.reduced(), dtype="float32")
        params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
        trace = _trace(cfg.vocab_size, N_REQ)

        eng = BatchedEngine(cfg, params, slots=SLOTS, max_len=MAX_LEN, page_size=16, chunk=16)
        for rid, prompt, mn in trace:
            eng.submit(rid, prompt, mn)
        eng.step()  # exclude the two trace compilations (chunk + joint decode)
        t0 = time.time()
        res = eng.run()
        dt_paged = time.time() - t0
        toks = sum(len(v) for v in res.values())

        generate(cfg, params, jnp.asarray(trace[0][1])[None], 2)  # compile
        t0 = time.time()
        solo_toks = 0
        for rid, prompt, mn in trace:
            solo_toks += generate(cfg, params, jnp.asarray(prompt)[None], mn).shape[1]
        dt_solo = time.time() - t0

        tok_s_paged = toks / dt_paged
        tok_s_solo = solo_toks / dt_solo
        rows.append(
            (
                f"serving/paged/{arch}",
                dt_paged * 1e6,
                f"tok_s={tok_s_paged:.1f} requests={len(res)} speedup={tok_s_paged / tok_s_solo:.2f}",
            )
        )
        rows.append(
            (f"serving/dense_solo/{arch}", dt_solo * 1e6, f"tok_s={tok_s_solo:.1f} requests={len(trace)}")
        )
    return rows


def csv_row(name, us, derived):
    return f"{name},{us:.0f},{derived}"


def main(emit) -> None:
    for r in run():
        emit(csv_row(*r))
