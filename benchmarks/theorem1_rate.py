"""Theorem 1 empirical check: with γ = (1/L)·√(m/K), the averaged gradient
norm (1/K)Σ E‖∇F(y_k)‖² should scale like 1/√(mK) once K dominates the
O(1/K) terms. We run the matrix-form simulator on a noisy strongly-convex
quadratic with known L and measure the scaling exponent across K."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row
from repro.core.mixing import MatrixFormSim

D, M, TAU, ALPHA = 8, 8, 4, 0.6


def avg_grad_norm(K: int, seed: int = 0) -> float:
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(D, D)) / np.sqrt(D)
    H = A.T @ A + 0.1 * np.eye(D)  # ∇F(x) = H x ; L = λmax(H)
    L = float(np.linalg.eigvalsh(H).max())
    gamma = (1.0 / L) * np.sqrt(M / K)
    sim = MatrixFormSim(rng.normal(size=D) * 3, M, ALPHA, TAU, gamma)
    total = 0.0
    sigma = 0.5
    for k in range(K):
        y = sim.virtual_sequence()
        total += float(np.sum((H @ y) ** 2))
        grads = H @ sim.locals + sigma * rng.normal(size=(D, M))
        sim.step(grads)
    return total / K


def run(quick: bool = False):
    Ks = (256, 1024, 4096) if quick else (256, 1024, 4096, 16384)
    rows = []
    for K in Ks:
        vals = [avg_grad_norm(K, seed=s) for s in range(3)]
        rows.append(dict(K=K, grad_norm=float(np.mean(vals))))
    # fit slope of log(grad_norm) vs log(K)
    xs = np.log([r["K"] for r in rows])
    ys = np.log([r["grad_norm"] for r in rows])
    slope = float(np.polyfit(xs, ys, 1)[0])
    return rows, slope


def main(emit):
    rows, slope = run()
    for r in rows:
        emit(csv_row(f"theorem1/K{r['K']}", 0.0, f"avg_grad_norm={r['grad_norm']:.5e}"))
    emit(csv_row("theorem1/check/slope", 0.0, f"logK_slope={slope:.3f} (theory ≈ -0.5 for 1/sqrt(mK))"))
    return rows
