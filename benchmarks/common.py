"""Shared harness for the paper-reproduction benchmarks.

``train_run`` executes a full training run of one algorithm configuration on
the synthetic classification task (the CIFAR-10/ResNet-18 stand-in; see
DESIGN.md §5) and returns loss curves + test accuracy. All Table/Figure
benchmarks are thin grids over this.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import AlgoConfig, OptimizerConfig
from repro.core import make_algorithm
from repro.data import WorkerBatcher, make_classification, partition_iid, partition_noniid
from repro.models.classifier import accuracy, init_mlp, mlp_loss
from repro.optim import from_config as opt_from_config
from repro.optim import schedules
from repro.training import consensus_params, make_round_step, make_train_state

QUICK = os.environ.get("REPRO_BENCH_QUICK", "0") == "1"

M = 16  # paper: 16 workers
DIM, CLASSES = 64, 10


@dataclass
class RunResult:
    algo: str
    tau: int
    losses: List[float]
    test_acc: float
    wall_s: float


_DATA = {}


def get_data(noniid: bool):
    key = ("noniid" if noniid else "iid",)
    if key not in _DATA:
        n = 25000 if QUICK else 50000
        # noise calibrated so the task has irreducible error (sync accuracy
        # ≈ 0.77) — in the fully-separable regime every algorithm reaches
        # 100% and the paper's τ-tradeoff is invisible
        data = make_classification(n=n, dim=DIM, num_classes=CLASSES, noise=3.0, seed=0)
        holdout = 5000
        test = type(data)(x=data.x[:holdout], y=data.y[:holdout], num_classes=CLASSES)
        train = type(data)(x=data.x[holdout:], y=data.y[holdout:], num_classes=CLASSES)
        if noniid:
            parts = partition_noniid(train, M, skew=0.64, seed=0)
        else:
            parts = partition_iid(train, M, seed=0)
        _DATA[key] = (train, test, parts)
    return _DATA[key]


def train_run(
    algo_name: str,
    tau: int,
    *,
    alpha: float = 0.6,
    anchor_beta: float = 0.7,
    lr: float = 0.2,
    steps: Optional[int] = None,
    noniid: bool = False,
    batch: int = 8,
    seed: int = 0,
    local_momentum: float = 0.9,
) -> RunResult:
    train, test, parts = get_data(noniid)
    steps = steps or (300 if QUICK else 900)
    acfg = AlgoConfig(name=algo_name, tau=tau, alpha=alpha, anchor_beta=anchor_beta)
    algo = make_algorithm(acfg)
    tau_eff = algo.tau
    # noise-dominated regime (paper's tradeoff is visible before LR decay):
    # warmup 2%, single ×0.1 decay at 85%
    rounds = steps // tau_eff
    sched = schedules.warmup_step_decay(lr, int(0.02 * steps), (int(0.85 * steps),))
    opt = opt_from_config(OptimizerConfig(name="sgd", lr=lr, momentum=local_momentum, nesterov=True, weight_decay=1e-4))
    params, axes = init_mlp(jax.random.PRNGKey(seed), DIM, CLASSES, hidden=(32,))
    state = make_train_state(params, M, opt, algo, axes)
    step = jax.jit(make_round_step(mlp_loss, opt, algo, sched, axes))
    batcher = WorkerBatcher(train, parts, batch, seed=seed)
    losses = []
    t0 = time.time()
    for r in range(rounds):
        micro = []
        for _ in range(tau_eff):
            x, y = next(batcher)
            micro.append((jnp.asarray(x), jnp.asarray(y)))
        rb = jax.tree.map(lambda *xs: jnp.stack(xs), *micro)
        state, ms = step(state, rb)
        losses.append(float(np.asarray(ms["loss"]).mean()))
    p = jax.tree.map(lambda t: t.astype(jnp.float32), consensus_params(state))
    acc = accuracy(p, jnp.asarray(test.x), jnp.asarray(test.y))
    return RunResult(algo=algo_name, tau=tau, losses=losses, test_acc=acc, wall_s=time.time() - t0)


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
