"""Shared harness for the paper-reproduction benchmarks.

``train_run`` executes a full training run of one algorithm configuration on
the synthetic classification task (the CIFAR-10/ResNet-18 stand-in; see
DESIGN.md §5) through ``repro.api.Experiment`` and returns loss curves +
test accuracy. All Table/Figure benchmarks are thin grids over this.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import List, Optional

from repro.api import ClassificationSpec, Experiment, TauController
from repro.config import AlgoConfig, OptimizerConfig
from repro.data import make_classification_splits
from repro.optim import schedules

QUICK = os.environ.get("REPRO_BENCH_QUICK", "0") == "1"

M = 16  # paper: 16 workers
DIM, CLASSES = 64, 10


@dataclass
class RunResult:
    algo: str
    tau: int
    losses: List[float]
    test_acc: float
    wall_s: float
    # adaptive-τ runs only: controller telemetry, one record per round
    # (round/tau/drift/scale/drift_ratio/decision/next_tau — DESIGN.md §6)
    tau_schedule: Optional[List[dict]] = None


_DATA = {}


def get_data(noniid: bool):
    """Shared splits for the whole benchmark grid (one generation per mode)."""
    key = ("noniid" if noniid else "iid",)
    if key not in _DATA:
        n = 25000 if QUICK else 50000
        # noise calibrated so the task has irreducible error (sync accuracy
        # ≈ 0.77) — in the fully-separable regime every algorithm reaches
        # 100% and the paper's τ-tradeoff is invisible
        _DATA[key] = make_classification_splits(
            M, n=n, dim=DIM, num_classes=CLASSES, noise=3.0, holdout=5000,
            noniid=noniid, skew=0.64, seed=0,
        )
    return _DATA[key]


def train_run(
    algo_name: str,
    tau: int,
    *,
    alpha: float = 0.6,
    anchor_beta: float = 0.7,
    lr: float = 0.2,
    steps: Optional[int] = None,
    noniid: bool = False,
    batch: int = 8,
    seed: int = 0,
    local_momentum: float = 0.9,
    adaptive_tau: Optional[TauController] = None,
) -> RunResult:
    splits = get_data(noniid)
    steps = steps or (300 if QUICK else 900)
    exp = Experiment(
        task=ClassificationSpec(splits=splits, batch_per_worker=batch, hidden=(32,), seed=seed),
        strategy=AlgoConfig(name=algo_name, tau=tau, alpha=alpha, anchor_beta=anchor_beta),
        optimizer=OptimizerConfig(
            name="sgd", lr=lr, momentum=local_momentum, nesterov=True, weight_decay=1e-4
        ),
        # noise-dominated regime (paper's tradeoff is visible before LR decay):
        # warmup 2%, single ×0.1 decay at 85%
        schedule=schedules.warmup_step_decay(lr, int(0.02 * steps), (int(0.85 * steps),)),
        workers=M,
        seed=seed,
    )
    if adaptive_tau is not None:
        # spend the same local-step budget as a fixed-τ run, one round at a
        # time so the controller's τ growth cannot overshoot the budget
        losses: List[float] = []
        wall = 0.0
        taken = 0
        while taken < steps:
            r1 = exp.fit(rounds=1, adaptive_tau=adaptive_tau)
            losses += r1.losses
            wall += r1.wall_s
            taken += r1.steps
        acc = exp.evaluate()["test_acc"]
        return RunResult(
            algo=algo_name,
            tau=adaptive_tau.tau,
            losses=losses,
            test_acc=acc,
            wall_s=wall,
            tau_schedule=list(adaptive_tau.history),
        )
    res = exp.fit(steps=steps)
    acc = exp.evaluate()["test_acc"]
    return RunResult(algo=algo_name, tau=tau, losses=res.losses, test_acc=acc, wall_s=res.wall_s)


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
