"""Paper Fig. 1 / Fig. 4(a): error–runtime Pareto frontier.

Convergence comes from real training runs (loss per round); wall-clock per
round comes from the calibrated runtime model (paper constants: 16 nodes,
4.6 s compute/epoch over ~24 steps, 1.5 s fully-sync comm/epoch on 40 Gbps).
Claim: Overlap-Local-SGD dominates — near-sync accuracy at near-zero exposed
communication; each point is one (algo, τ)."""
from __future__ import annotations

from benchmarks.common import csv_row, train_run
from repro.api import TauController
from repro.core.runtime_model import RuntimeConfig, simulate

STEPS_PER_EPOCH = 24
RT = RuntimeConfig(m=16, t_step=4.6 / STEPS_PER_EPOCH, t_comm=1.5 / STEPS_PER_EPOCH, t_handshake=0.02)

POINTS = (
    ("sync_sgd", 1),
    ("powersgd", 1),
    ("local_sgd", 1),
    ("local_sgd", 2),
    ("local_sgd", 8),
    ("local_sgd", 24),
    ("overlap_local_sgd", 1),
    ("overlap_local_sgd", 2),
    ("overlap_local_sgd", 8),
    ("overlap_local_sgd", 24),
)


def _round_time(algo: str, tau: int, amortize: int = 8):
    """Mean per-round (time, exposed comm) at a fixed τ, amortized so the
    overlapped collective settles into steady state."""
    res = simulate(algo, tau, tau * amortize, RT)
    return res.total_time / amortize, res.exposed_comm / amortize


def adaptive_point():
    """The adaptive-τ frontier point (DESIGN.md §6): one controller-driven
    run of Overlap-Local-SGD, priced per-round at the τ each round ran at."""
    algo = "overlap_local_sgd"
    ctrl = TauController(tau=1, tau_min=1, tau_max=24, lo=0.05, hi=0.5)
    r = train_run(algo, 1, adaptive_tau=ctrl)
    steps = sum(h["tau"] for h in r.tau_schedule)
    times = {t: _round_time(algo, t) for t in {h["tau"] for h in r.tau_schedule}}
    sim_time = sum(times[h["tau"]][0] for h in r.tau_schedule)
    exposed = sum(times[h["tau"]][1] for h in r.tau_schedule)
    return dict(
        algo=algo,
        tau="adaptive",
        acc=r.test_acc,
        sim_time=sim_time,
        exposed_comm=exposed,
        per_epoch=sim_time / max(steps / STEPS_PER_EPOCH, 1e-9),
        taus=sorted({h["tau"] for h in r.tau_schedule}),
        rounds=len(r.tau_schedule),
    )


def run(quick: bool = False):
    rows = []
    for algo, tau in POINTS:
        r = train_run(algo, tau)
        steps = len(r.losses) * max(tau, 1)
        rt = simulate(algo, tau, steps, RT)
        rows.append(
            dict(
                algo=algo,
                tau=tau,
                acc=r.test_acc,
                sim_time=rt.total_time,
                exposed_comm=rt.exposed_comm,
                per_epoch=rt.total_time / max(steps / STEPS_PER_EPOCH, 1e-9),
            )
        )
    rows.append(adaptive_point())
    return rows


def main(emit):
    rows = run()
    for r in rows:
        derived = f"test_acc={r['acc']:.4f};epoch_s={r['per_epoch']:.2f};exposed_comm_s={r['exposed_comm']:.2f}"
        if r["tau"] == "adaptive":
            derived += f";taus={'/'.join(map(str, r['taus']))};rounds={r['rounds']}"
        emit(csv_row(f"fig1/{r['algo']}/tau_{r['tau']}" if r["tau"] == "adaptive" else f"fig1/{r['algo']}/tau{r['tau']}", r["sim_time"] * 1e6, derived))
    # Pareto check: overlap tau=2 should not be dominated by any other point
    ours = next(r for r in rows if r["algo"] == "overlap_local_sgd" and r["tau"] == 2)
    dominated = any(
        (r["sim_time"] < ours["sim_time"] and r["acc"] > ours["acc"] + 0.005) for r in rows if r is not ours
    )
    emit(csv_row("fig1/check/pareto_tau2", 0.0, f"overlap_tau2_dominated={dominated}"))
    return rows
