# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
# Kernel rows are additionally persisted machine-readably to BENCH_kernels.json
# (name -> {us, gbps?, derived}) so the packed-boundary perf trajectory is
# trackable across PRs. ``--only <module>`` runs a single benchmark module
# (the CI smoke step uses ``--only kernel_bench`` under REPRO_BENCH_QUICK=1).
from __future__ import annotations

import argparse
import json
import os
import re
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# quick (CI-smoke) runs write to a separate, gitignored file so they never
# clobber the committed full-mode perf trajectory
BENCH_JSON = os.path.join(_ROOT, "BENCH_kernels.json")
BENCH_JSON_QUICK = os.path.join(_ROOT, "BENCH_kernels_quick.json")
SERVING_JSON = os.path.join(_ROOT, "BENCH_serving.json")
SERVING_JSON_QUICK = os.path.join(_ROOT, "BENCH_serving_quick.json")


def _derived_fields(derived: str) -> dict:
    """Parse k=v tokens out of the derived column (gbps/gflops/speedup...)."""
    out = {}
    for key, val in re.findall(r"(\w+)=([-\w.]+)", derived):
        try:
            out[key] = float(val)
        except ValueError:
            out[key] = val
    return out


def write_kernel_json(rows, path: str = None) -> str:
    quick = os.environ.get("REPRO_BENCH_QUICK", "0") == "1"
    if path is None:
        path = BENCH_JSON_QUICK if quick else BENCH_JSON
    payload = {
        name: dict(us=round(us, 1), **_derived_fields(derived)) for name, us, derived in rows
    }
    payload["_meta"] = dict(
        quick=quick,
        schema="name -> {us (per call), derived throughput fields}",
    )
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return path


def main() -> None:
    from benchmarks import fig1_error_runtime, fig4_comm_ratio, kernel_bench, roofline_table, serving_bench, table1_iid, table2_noniid, theorem1_rate

    mods = [kernel_bench, serving_bench, theorem1_rate, fig4_comm_ratio, roofline_table, table1_iid, table2_noniid, fig1_error_runtime]
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None, help="run a single benchmark module by name")
    args = ap.parse_args()
    if args.only:
        wanted = [m for m in mods if m.__name__.split(".")[-1] == args.only]
        if not wanted:
            raise SystemExit(f"unknown benchmark {args.only!r}; known: {[m.__name__.split('.')[-1] for m in mods]}")
        mods = wanted

    def emit(line: str) -> None:
        print(line, flush=True)

    emit("name,us_per_call,derived")
    t0 = time.time()
    failures = []
    for mod in mods:
        name = mod.__name__.split(".")[-1]
        t = time.time()
        try:
            if mod is kernel_bench:
                rows = kernel_bench.run()
                for r in rows:
                    emit(kernel_bench.csv_row(*r))
                json_path = write_kernel_json(rows)
                emit(f"bench/kernel_bench/json,{0:.0f},{json_path}")
            elif mod is serving_bench:
                rows = serving_bench.run()
                for r in rows:
                    emit(serving_bench.csv_row(*r))
                quick = os.environ.get("REPRO_BENCH_QUICK", "0") == "1"
                json_path = write_kernel_json(rows, SERVING_JSON_QUICK if quick else SERVING_JSON)
                emit(f"bench/serving_bench/json,{0:.0f},{json_path}")
            else:
                mod.main(emit)
            emit(f"bench/{name}/elapsed,{(time.time()-t)*1e6:.0f},ok")
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            emit(f"bench/{name}/elapsed,{(time.time()-t)*1e6:.0f},FAILED:{type(e).__name__}:{e}")
    emit(f"bench/total_elapsed,{(time.time()-t0)*1e6:.0f},done")
    # --only is the CI-smoke contract: a failed module must fail the step.
    # Full sweeps keep the degrade-gracefully contract (FAILED rows, exit 0).
    if failures and args.only:
        raise SystemExit(f"benchmark modules failed: {failures}")


if __name__ == "__main__":
    main()
