# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import fig1_error_runtime, fig4_comm_ratio, kernel_bench, roofline_table, table1_iid, table2_noniid, theorem1_rate

    def emit(line: str) -> None:
        print(line, flush=True)

    emit("name,us_per_call,derived")
    t0 = time.time()
    for mod in (kernel_bench, theorem1_rate, fig4_comm_ratio, roofline_table, table1_iid, table2_noniid, fig1_error_runtime):
        name = mod.__name__.split(".")[-1]
        t = time.time()
        try:
            mod.main(emit)
            emit(f"bench/{name}/elapsed,{(time.time()-t)*1e6:.0f},ok")
        except Exception as e:  # noqa: BLE001
            emit(f"bench/{name}/elapsed,{(time.time()-t)*1e6:.0f},FAILED:{type(e).__name__}:{e}")
    emit(f"bench/total_elapsed,{(time.time()-t0)*1e6:.0f},done")


if __name__ == "__main__":
    main()
