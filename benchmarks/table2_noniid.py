"""Paper Table 2: the same grid under NON-IID partitions (64% single-class
per worker, the paper's construction). Claims to validate:
  (a) Overlap-Local-SGD stays stable at large τ where CoCoD degrades/diverges;
  (b) Local-SGD variants can beat fully-sync SGD here (paper: 91.5% vs 85.9%).
"""
from __future__ import annotations

import math

from benchmarks.common import csv_row, train_run

TAUS = (1, 2, 8, 24)
ALGOS = (("cocod", {}), ("easgd", {"alpha": 0.043}), ("overlap_local_sgd", {}))


def run(quick: bool = False):
    rows = []
    sync = train_run("sync_sgd", 1, noniid=True)
    rows.append(dict(algo="sync_sgd", tau=1, acc=sync.test_acc, diverged=False, wall_s=sync.wall_s))
    for algo, kw in ALGOS:
        for tau in TAUS:
            r = train_run(algo, tau, noniid=True, **kw)
            diverged = not math.isfinite(r.losses[-1]) or r.losses[-1] > 2 * r.losses[0]
            rows.append(dict(algo=algo, tau=tau, acc=r.test_acc, diverged=diverged, wall_s=r.wall_s))
    return rows


def main(emit):
    rows = run()
    by = {(r["algo"], r["tau"]): r for r in rows}
    for r in rows:
        emit(
            csv_row(
                f"table2/{r['algo']}/tau{r['tau']}",
                r["wall_s"] * 1e6,
                f"test_acc={r['acc']:.4f};diverged={r['diverged']}",
            )
        )
    for tau in (8, 24):
        ours = by[("overlap_local_sgd", tau)]
        cocod = by[("cocod", tau)]
        emit(
            csv_row(
                f"table2/check/tau{tau}",
                0.0,
                f"ours_stable={not ours['diverged']};ours={ours['acc']:.4f};cocod={cocod['acc']:.4f}",
            )
        )
    return rows
