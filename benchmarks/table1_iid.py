"""Paper Table 1: Local-SGD-variant comparison, IID partitions.

Grid: {CoCoD-SGD, EAMSGD, Overlap-Local-SGD} × τ ∈ {1,2,8,24}, plus the
fully-synchronous SGD reference. The paper's claims to validate:
  (a) Ours ≥ CoCoD ≥ EAMSGD at every τ;
  (b) accuracy degrades as τ grows (error–communication tradeoff);
  (c) Ours at τ∈{1,2} matches or beats fully-sync SGD.
"""
from __future__ import annotations

from benchmarks.common import csv_row, train_run

TAUS = (1, 2, 8, 24)
ALGOS = (("cocod", {}), ("easgd", {"alpha": 0.043}), ("overlap_local_sgd", {}))
# EASGD's stability requires alpha ~ O(1/m) for its symmetric update ([19]
# uses beta/m with beta<1); 0.043 ≈ 0.7/16 mirrors the original tuning.


def run(quick: bool = False):
    rows = []
    sync = train_run("sync_sgd", 1)
    rows.append(dict(algo="sync_sgd", tau=1, acc=sync.test_acc, wall_s=sync.wall_s))
    for algo, kw in ALGOS:
        for tau in TAUS:
            r = train_run(algo, tau, **kw)
            rows.append(dict(algo=algo, tau=tau, acc=r.test_acc, wall_s=r.wall_s))
    return rows


def main(emit):
    rows = run()
    by = {(r["algo"], r["tau"]): r["acc"] for r in rows}
    for r in rows:
        emit(csv_row(f"table1/{r['algo']}/tau{r['tau']}", r["wall_s"] * 1e6, f"test_acc={r['acc']:.4f}"))
    # headline checks
    for tau in TAUS:
        ours, cocod, eam = by[("overlap_local_sgd", tau)], by[("cocod", tau)], by[("easgd", tau)]
        emit(csv_row(f"table1/check/tau{tau}", 0.0, f"ours={ours:.4f};cocod={cocod:.4f};eamsgd={eam:.4f};ours_best={ours >= max(cocod, eam) - 0.005}"))
    emit(csv_row("table1/check/sync_ref", 0.0, f"sync={by[('sync_sgd', 1)]:.4f};ours_tau2={by[('overlap_local_sgd', 2)]:.4f}"))
    return rows
